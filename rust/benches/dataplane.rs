//! Dataplane hot-path benches (`cargo bench --bench dataplane`): the
//! switch ALU aggregation (L1 mirror), quantization, descriptor hashing
//! and the multicast shard encoding. These are the per-packet costs that
//! bound simulated packets/second.

use std::time::Duration;

use canary::switch::alu;
use canary::switch::canary::Dataplane;
use canary::switch::shards;
use canary::util::bench::{bench, throughput};
use canary::util::rng::Rng;

fn main() {
    println!("== dataplane benches ==");
    let t = Duration::from_millis(400);

    // saturating accumulate: 256-lane payload (the per-packet ALU work)
    let mut rng = Rng::new(3);
    let mut acc: Vec<i32> = (0..256).map(|_| rng.i32()).collect();
    let pkt: Vec<i32> = (0..256).map(|_| rng.i32()).collect();
    let m = bench("sat_accumulate_256_lanes_x1k", t, || {
        for _ in 0..1000 {
            alu::sat_accumulate(&mut acc, &pkt);
        }
        std::hint::black_box(&acc);
    });
    println!(
        "   -> {:.2} G lanes/s ({:.1} M packets/s)\n",
        throughput(&m, 256_000.0) / 1e9,
        throughput(&m, 1000.0) / 1e6
    );

    // quantize path (host-side gradient packing)
    let xs: Vec<f32> = (0..4096).map(|i| (i as f32).sin()).collect();
    let m = bench("quantize_4096_f32", t, || {
        std::hint::black_box(alu::quantize_vec(&xs, 20));
    });
    println!(
        "   -> {:.2} G elems/s\n",
        throughput(&m, 4096.0) / 1e9
    );

    // descriptor slot hashing
    let dp = Dataplane::new(32 * 1024, 7);
    let m = bench("descriptor_slot_hash_x1M", t, || {
        let mut acc = 0u32;
        for key in 0..1_000_000u64 {
            acc = acc.wrapping_add(dp.slot_of(key));
        }
        std::hint::black_box(acc);
    });
    println!(
        "   -> {:.0} M hashes/s\n",
        throughput(&m, 1_000_000.0) / 1e6
    );

    // multicast shard encode/decode (Section 4.2)
    let mut rng = Rng::new(9);
    let bitmaps: Vec<u64> = (0..1024).map(|_| rng.next_u64()).collect();
    let m = bench("shard_encode_decode_64p4s_x1k", t, || {
        for &b in &bitmaps {
            let keys = shards::encode(b, 64, 4);
            std::hint::black_box(shards::decode(&keys, 64, 4));
        }
    });
    println!(
        "   -> {:.2} M bitmaps/s\n",
        throughput(&m, 1024.0) / 1e6
    );
}
