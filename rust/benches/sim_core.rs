//! Simulator-core micro-benchmarks (`cargo bench --bench sim_core`):
//! event-loop throughput, link/queue operations, RNG, hashing — the L3
//! hot paths profiled in EXPERIMENTS.md §Perf.

use std::time::Duration;

use canary::collectives::{runner, Algo};
use canary::config::{FatTreeConfig, SimConfig};
use canary::traffic::TrafficSpec;
use canary::util::bench::{bench, throughput};
use canary::util::rng::Rng;
use canary::workload::{JobBuilder, ScenarioBuilder};

fn main() {
    println!("== sim_core benches ==");
    let t = Duration::from_millis(400);

    // raw event throughput: a full small-topology canary allreduce
    let sc = ScenarioBuilder::new(FatTreeConfig::small())
        .traffic(Some(TrafficSpec::uniform()))
        .job(JobBuilder::new(Algo::Canary).hosts(32).data_bytes(256 << 10));
    let mut events = 0u64;
    let m = bench("canary_allreduce_256KiB_32hosts_cong", t, || {
        let mut exp = sc.build(1);
        runner::run_to_completion(&mut exp.net, u64::MAX);
        events = exp.net.events_processed;
    });
    println!(
        "   -> {:.2} M events/s ({} events per run)\n",
        throughput(&m, events as f64) / 1e6,
        events
    );

    // same run, value-carrying (payload aggregation on every hop)
    let sc_v = sc.clone().sim(SimConfig::default().with_values(true));
    let m = bench("canary_allreduce_values_256KiB", t, || {
        let mut exp = sc_v.build(1);
        runner::run_to_completion(&mut exp.net, u64::MAX);
    });
    println!(
        "   -> values overhead vs size-only: see ratio above\n{}",
        ""
    );
    let _ = m;

    // calendar-queue scheduler in isolation: near-future pushes (the
    // hot case — every entry lands inside the wheel window)
    use canary::sim::{Event, EventQueue};
    let m = bench("scheduler_push_pop_10k_near", t, || {
        let mut q = EventQueue::new();
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            q.push(rng.next_u64() % 1_000_000, Event::TxDone { link: 0 });
        }
        while q.pop().is_some() {}
    });
    println!(
        "   -> {:.2} M ops/s\n",
        throughput(&m, 20_000.0) / 1e6
    );

    // far-future timers: entries beyond the wheel horizon take the
    // overflow heap and migrate back as the window slides
    let m = bench("scheduler_push_pop_10k_far", t, || {
        let mut q = EventQueue::new();
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            q.push(
                rng.next_u64() % 40_000_000_000, // up to 40 ms
                Event::TxDone { link: 0 },
            );
        }
        while q.pop().is_some() {}
    });
    println!(
        "   -> {:.2} M ops/s\n",
        throughput(&m, 20_000.0) / 1e6
    );

    // packet arena churn: steady-state alloc/free through the free list
    use canary::sim::{Packet, PacketArena, PacketKind};
    let m = bench("arena_alloc_free_10k", t, || {
        let mut a = PacketArena::new();
        let mut live = Vec::with_capacity(64);
        for i in 0..10_000u32 {
            live.push(a.alloc(Packet::data(PacketKind::Background, 0, i)));
            if live.len() == 64 {
                for id in live.drain(..) {
                    a.free(id);
                }
            }
        }
        for id in live.drain(..) {
            a.free(id);
        }
        std::hint::black_box(a.slot_count());
    });
    println!(
        "   -> {:.2} M alloc+free/s\n",
        throughput(&m, 10_000.0) / 1e6
    );

    // RNG
    let mut rng = Rng::new(7);
    let m = bench("rng_next_u64_x1M", t, || {
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        std::hint::black_box(acc);
    });
    println!(
        "   -> {:.0} M draws/s\n",
        throughput(&m, 1_000_000.0) / 1e6
    );
}
