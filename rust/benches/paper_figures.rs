//! Paper-figure benches (`cargo bench --bench paper_figures`): run the
//! CI-scale version of every figure experiment end to end and time it.
//! One bench per table/figure of the evaluation section — the full-scale
//! series are produced by the `figures` binary (`figures all --scale
//! full`); these keep the whole harness exercised on every `cargo bench`.
//!
//! The `traffic_patterns`, `transport_reactive` and `placement_locality`
//! sweeps additionally record their timing to
//! `results/BENCH_traffic.json` / `results/BENCH_transport.json` /
//! `results/BENCH_placement.json` so per-commit tooling can track the
//! end-to-end cost of the beyond-paper harnesses. The `scale` sweep
//! writes `results/BENCH_scale.json` with per-cell engine throughput
//! and the headline events/sec that `scripts/check_bench.py` gates CI
//! on.

use std::time::Duration;

use canary::figures::{self, Opts, Scale};
use canary::util::json::{obj, Value};

fn opts() -> Opts {
    Opts {
        scale: Scale::Ci,
        seeds: 1,
        out: std::env::temp_dir()
            .join("canary_bench_results")
            .to_string_lossy()
            .to_string(),
    }
}

fn run(
    name: &str,
    f: impl Fn(&Opts) -> canary::report::Series,
) -> (Duration, usize) {
    let o = opts();
    let t0 = std::time::Instant::now();
    let series = f(&o);
    let elapsed = t0.elapsed();
    println!(
        "{:<28} {:>8.2?}   ({} rows)",
        name,
        elapsed,
        series.rows.len()
    );
    (elapsed, series.rows.len())
}

fn main() {
    println!("== paper figure benches (CI scale) ==");
    run("fig2_goodput", figures::fig2);
    run("fig6_single_switch", figures::fig6);
    run("fig7a_goodput_vs_trees", figures::fig7a);
    run("fig7b_link_utilization", figures::fig7b);
    run("fig8_goodput_vs_hosts", figures::fig8);
    run("fig9_runtime_vs_size", figures::fig9);
    run("fig10a_concurrent", figures::fig10a);
    run("fig10b_link_util_20jobs", figures::fig10b);
    run("fig11_noise_timeout", figures::fig11);
    run("mem_model", figures::mem);
    run("clos3_multitier", figures::clos3);
    let (traffic_time, traffic_rows) =
        run("traffic_patterns", figures::traffic);
    let (transport_time, transport_rows) =
        run("transport_reactive", figures::transport);
    let (placement_time, placement_rows) =
        run("placement_locality", figures::placement);
    run("scale_weak_sweep", figures::scale);
    run("churn_sweep", figures::churn);
    run("ablation_lb", figures::ablation_lb);

    // machine-readable entries for the sweeps (per-commit tracking)
    let _ = std::fs::create_dir_all("results");
    for (file, name, time, rows) in [
        (
            "results/BENCH_traffic.json",
            "traffic_patterns",
            traffic_time,
            traffic_rows,
        ),
        (
            "results/BENCH_transport.json",
            "transport_reactive",
            transport_time,
            transport_rows,
        ),
        (
            "results/BENCH_placement.json",
            "placement_locality",
            placement_time,
            placement_rows,
        ),
    ] {
        let entry = obj(vec![
            ("bench", Value::Str(name.into())),
            ("scale", Value::Str("ci".into())),
            ("seconds", Value::Float(time.as_secs_f64())),
            ("rows", Value::Int(rows as i64)),
        ]);
        match std::fs::write(file, entry.to_json()) {
            Ok(()) => println!("wrote {file}"),
            Err(e) => eprintln!("{file} write failed: {e}"),
        }
    }

    // the scale sweep writes its own richer entry (per-cell events/sec
    // + the gated headline) into the bench out dir; publish it next to
    // the other BENCH files for artifact upload / check_bench.py
    let scale_src = format!("{}/BENCH_scale.json", opts().out);
    match std::fs::copy(&scale_src, "results/BENCH_scale.json") {
        Ok(_) => println!("wrote results/BENCH_scale.json"),
        Err(e) => eprintln!("copying {scale_src} failed: {e}"),
    }

    // same for the churn sweep (completion/recovery percentiles per
    // timeout x fault-level x engine cell)
    let churn_src = format!("{}/BENCH_churn.json", opts().out);
    match std::fs::copy(&churn_src, "results/BENCH_churn.json") {
        Ok(_) => println!("wrote results/BENCH_churn.json"),
        Err(e) => eprintln!("copying {churn_src} failed: {e}"),
    }
}
