//! Paper-figure benches (`cargo bench --bench paper_figures`): run the
//! CI-scale version of every figure experiment end to end and time it.
//! One bench per table/figure of the evaluation section — the full-scale
//! series are produced by the `figures` binary (`figures all --scale
//! full`); these keep the whole harness exercised on every `cargo bench`.

use std::time::Duration;

use canary::figures::{self, Opts, Scale};

fn run(name: &str, f: impl Fn(&Opts) -> canary::report::Series) {
    let o = Opts {
        scale: Scale::Ci,
        seeds: 1,
        out: std::env::temp_dir()
            .join("canary_bench_results")
            .to_string_lossy()
            .to_string(),
    };
    let t0 = std::time::Instant::now();
    let series = f(&o);
    println!(
        "{:<28} {:>8.2?}   ({} rows)",
        name,
        t0.elapsed(),
        series.rows.len()
    );
}

fn main() {
    println!("== paper figure benches (CI scale) ==");
    let _ = Duration::from_millis(1);
    run("fig2_goodput", figures::fig2);
    run("fig6_single_switch", figures::fig6);
    run("fig7a_goodput_vs_trees", figures::fig7a);
    run("fig7b_link_utilization", figures::fig7b);
    run("fig8_goodput_vs_hosts", figures::fig8);
    run("fig9_runtime_vs_size", figures::fig9);
    run("fig10a_concurrent", figures::fig10a);
    run("fig10b_link_util_20jobs", figures::fig10b);
    run("fig11_noise_timeout", figures::fig11);
    run("mem_model", figures::mem);
    run("clos3_multitier", figures::clos3);
    run("ablation_lb", figures::ablation_lb);
}
