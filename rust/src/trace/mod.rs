//! Run-time telemetry: time-series sampling, per-job lifecycle spans,
//! dynamic aggregation-tree capture, and the per-block causal profiler
//! (DESIGN.md §2.7, §2.9).
//!
//! The [`Tracer`] is owned by the [`Network`] and threaded through
//! `Ctx`, so every layer (switch dataplane, host engines, the
//! collective runner) can emit records without extra plumbing. Four
//! collectors live behind one `Option` box:
//!
//! 1. **Sampler** — on a configurable cadence the engine snapshots
//!    per-link queue depth / utilization, live arena packets, ECN
//!    marks, and live aggregation descriptors into a ring buffer.
//! 2. **Spans** — structured job-lifecycle events (install → kick →
//!    first/last send → aggregated → broadcast → complete/stalled,
//!    plus retransmission and fault-fallback markers).
//! 3. **Trees** — one record per Canary partial-aggregate forward:
//!    which switch, which ports contributed, expected vs actual
//!    fan-in, and whether the timeout (rather than fan-in
//!    completion) fired it. This is the realized dynamic tree.
//! 4. **Flight recorder** — a per-packet hop log for a deterministic
//!    per-job sample of blocks (`TraceSpec::trace_blocks`), splitting
//!    every hop into queueing / serialization / propagation, plus
//!    aggregation-wait records for the time a block sat in a Canary
//!    descriptor, a static-tree slot, or at the leader before moving
//!    on. [`critical_paths`] reconstructs each traced block's
//!    max-latency contributor chain from these logs.
//!
//! **Zero-footprint when off.** A disabled tracer is a `None` box:
//! every hook is a single branch, no RNG is drawn, no event is
//! scheduled, and no metric moves — seeded fingerprints are
//! bit-identical with tracing on or off (pinned in `tests/trace.rs`).
//! The sampler event itself is dispatched *outside* the
//! `events_processed` counter for the same reason. Block sampling
//! draws from a dedicated `util/rng` stream derived from the run seed,
//! never from the simulation RNG.

use std::collections::{BTreeMap, VecDeque};

use crate::report::Series;
use crate::sim::packet::PacketKind;
use crate::sim::{Link, Network, NodeBody, Time, US};
use crate::util::json::{obj, Value};
use crate::util::rng::{splitmix64, Rng};
use crate::util::stats::Histogram;

/// Recorder configuration: cadence plus per-collector capacity caps
/// (the sampler ring evicts oldest, span/tree logs stop appending and
/// count drops — a trace must never OOM a long run).
#[derive(Clone, Debug)]
pub struct TraceSpec {
    /// Sampler cadence in picoseconds (default 1 µs).
    pub cadence_ps: Time,
    /// Sampler ring capacity in samples (oldest evicted beyond this).
    pub ring_capacity: usize,
    /// Span log cap; further spans are counted as dropped.
    pub max_spans: usize,
    /// Tree-record log cap; further records are counted as dropped.
    pub max_tree_records: usize,
    /// Flight recorder: blocks sampled per job (0 = hop logging off;
    /// selection is seed-derived, see [`Tracer::register_job`]).
    pub trace_blocks: u32,
    /// Hop-log cap; further hops are counted as dropped.
    pub max_hops: usize,
    /// Wait-record cap; further waits are counted as dropped.
    pub max_waits: usize,
}

impl Default for TraceSpec {
    fn default() -> TraceSpec {
        TraceSpec {
            cadence_ps: US,
            ring_capacity: 4096,
            max_spans: 65_536,
            max_tree_records: 65_536,
            trace_blocks: 0,
            max_hops: 131_072,
            max_waits: 16_384,
        }
    }
}

impl TraceSpec {
    /// Builder: override the sampler cadence (picoseconds).
    pub fn with_cadence(mut self, ps: Time) -> TraceSpec {
        self.cadence_ps = ps.max(1);
        self
    }

    /// Builder: sample `n` blocks per job into the flight recorder
    /// (0 keeps hop logging off; the other collectors are unaffected).
    pub fn with_blocks(mut self, n: u32) -> TraceSpec {
        self.trace_blocks = n;
        self
    }
}

/// Per-link state captured by one sampler tick. Only *active* links
/// (transmitted since the previous tick, non-empty queue, or down)
/// are recorded, which keeps big idle fabrics cheap.
#[derive(Clone, Copy, Debug)]
pub struct LinkSample {
    pub link: u32,
    pub queued_bytes: u64,
    pub class0_bytes: u64,
    /// Fraction of the sampling interval the link spent serializing.
    pub util: f64,
    pub drops: u64,
    pub alive: bool,
}

/// One sampler tick: global gauges plus the active-link snapshot.
#[derive(Clone, Debug)]
pub struct Sample {
    pub t_ps: Time,
    pub arena_live: u32,
    pub ecn_marks: u64,
    pub live_descriptors: u64,
    pub links: Vec<LinkSample>,
}

/// Job-lifecycle span kinds, in rough temporal order (the derived
/// `Ord` follows that order — the sharded-engine merge uses it as a
/// sort tie-breaker).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Job installed into the fabric (trees programmed, hosts armed).
    Install,
    /// Participant woken at the job's start time.
    Kick,
    /// A host injected its first block.
    FirstSend,
    /// A host injected its final block.
    LastSend,
    /// Leader observed a block fully aggregated.
    Aggregated,
    /// Leader broadcast a finished block to the group.
    Broadcast,
    /// Leader received a retransmission request (loss recovery).
    RetransReq,
    /// Leader opened a new retry round for a damaged block.
    RetryRound,
    /// Host fell back to direct-to-leader sends (fault recovery).
    Fallback,
    /// One host finished all of its blocks.
    HostDone,
    /// The whole job completed.
    Complete,
    /// The run ended with this job still incomplete.
    Stalled,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Install => "install",
            SpanKind::Kick => "kick",
            SpanKind::FirstSend => "first_send",
            SpanKind::LastSend => "last_send",
            SpanKind::Aggregated => "aggregated",
            SpanKind::Broadcast => "broadcast",
            SpanKind::RetransReq => "retrans_req",
            SpanKind::RetryRound => "retry_round",
            SpanKind::Fallback => "fallback",
            SpanKind::HostDone => "host_done",
            SpanKind::Complete => "complete",
            SpanKind::Stalled => "stalled",
        }
    }
}

/// One lifecycle event. `detail` is kind-specific (participant count
/// for install, host count for aggregated, round for retry, rank for
/// host_done, ...).
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub t_ps: Time,
    pub kind: SpanKind,
    pub job: u32,
    pub node: u32,
    pub block: Option<u32>,
    pub detail: u64,
}

/// One realized aggregation-tree edge set: a Canary switch forwarding
/// its (possibly partial) accumulator upstream for one block.
#[derive(Clone, Copy, Debug)]
pub struct TreeRecord {
    pub t_ps: Time,
    pub tenant: u32,
    pub block: u32,
    pub switch: u32,
    /// Bitmap of ingress ports that contributed to this aggregation.
    pub children: u64,
    /// Packets actually merged before the forward.
    pub contributed: u32,
    /// Fan-in the descriptor expected.
    pub expected: u32,
    /// True when the aggregation timeout fired the forward (partial).
    pub via_timeout: bool,
    /// Descriptor residency: allocation to forward.
    pub latency_ps: Time,
}

impl TreeRecord {
    /// Achieved fan-in as a fraction of the expected fan-in.
    pub fn fanin_fraction(&self) -> f64 {
        if self.expected == 0 {
            1.0
        } else {
            self.contributed as f64 / self.expected as f64
        }
    }
}

/// One link hop of a traced block's packet, recorded when the packet
/// leaves the transmitter. The ps-exact decomposition holds by
/// construction: the packet was enqueued at `t_enq`, waited
/// `queue_ps` for the port, serialized for `ser_ps` and propagated
/// for `prop_ps`, so it is delivered at
/// `t_enq + queue_ps + ser_ps + prop_ps` exactly.
#[derive(Clone, Copy, Debug)]
pub struct HopRecord {
    pub tenant: u16,
    /// Wire block id (unique per retry round).
    pub block: u32,
    pub kind: PacketKind,
    pub link: u32,
    pub from: u32,
    pub to: u32,
    /// Enqueue time on the port FIFO.
    pub t_enq: Time,
    pub queue_ps: Time,
    pub ser_ps: Time,
    pub prop_ps: Time,
}

impl HopRecord {
    /// Delivery time at `to`.
    pub fn t_deliver(&self) -> Time {
        self.t_enq + self.queue_ps + self.ser_ps + self.prop_ps
    }
}

/// Time a traced block sat resident at a node before moving on: in a
/// Canary descriptor or static-tree slot before the upstream forward,
/// or at the leader between its first packet contribution and the
/// broadcast. `via_timeout` marks residency ended by the aggregation
/// timeout — the timeout penalty of the paper's best-effort forwards.
#[derive(Clone, Copy, Debug)]
pub struct WaitRecord {
    pub tenant: u16,
    /// Wire block id.
    pub block: u32,
    pub node: u32,
    pub t_start: Time,
    pub t_end: Time,
    pub via_timeout: bool,
}

/// Seed-derived per-job block selection for the flight recorder.
#[derive(Clone, Debug)]
struct TracedJob {
    total_blocks: u32,
    sel: Vec<bool>,
}

/// Live collector state; exists only while tracing is enabled.
#[derive(Debug)]
struct TraceState {
    spec: TraceSpec,
    samples: VecDeque<Sample>,
    samples_evicted: u64,
    spans: Vec<Span>,
    spans_dropped: u64,
    trees: Vec<TreeRecord>,
    trees_dropped: u64,
    hops: Vec<HopRecord>,
    hops_dropped: u64,
    waits: Vec<WaitRecord>,
    waits_dropped: u64,
    /// Per-tenant sampled-block selection.
    traced: BTreeMap<u16, TracedJob>,
    /// `busy_ps` per link at the previous tick (utilization deltas).
    prev_busy: Vec<u64>,
    prev_t: Time,
}

/// The recorder. Disabled is the default and costs one branch per
/// hook; see the module docs for the zero-footprint contract.
#[derive(Debug, Default)]
pub struct Tracer {
    state: Option<Box<TraceState>>,
}

impl Tracer {
    /// A disabled tracer (the `Network::new` default).
    pub fn off() -> Tracer {
        Tracer { state: None }
    }

    /// An enabled tracer with the given spec.
    pub fn on(spec: TraceSpec) -> Tracer {
        Tracer {
            state: Some(Box::new(TraceState {
                spec,
                samples: VecDeque::new(),
                samples_evicted: 0,
                spans: Vec::new(),
                spans_dropped: 0,
                trees: Vec::new(),
                trees_dropped: 0,
                hops: Vec::new(),
                hops_dropped: 0,
                waits: Vec::new(),
                waits_dropped: 0,
                traced: BTreeMap::new(),
                prev_busy: Vec::new(),
                prev_t: 0,
            })),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.state.is_some()
    }

    /// Sampler cadence, if tracing is enabled.
    pub fn cadence_ps(&self) -> Option<Time> {
        self.state.as_ref().map(|s| s.spec.cadence_ps)
    }

    /// Record one sampler tick. Called by the engine's `TraceSample`
    /// event only — never on the untraced path.
    pub fn sample(
        &mut self,
        now: Time,
        links: &[Link],
        arena_live: u32,
        live_descriptors: u64,
        ecn_marks: u64,
    ) {
        let Some(s) = self.state.as_mut() else { return };
        s.prev_busy.resize(links.len(), 0);
        let interval = now.saturating_sub(s.prev_t);
        let mut snap = Vec::new();
        for (i, l) in links.iter().enumerate() {
            let delta = l.busy_ps.saturating_sub(s.prev_busy[i]);
            s.prev_busy[i] = l.busy_ps;
            if delta == 0 && l.queued_bytes == 0 && l.alive {
                continue; // idle link: skip to bound memory
            }
            let util = if interval > 0 {
                (delta as f64 / interval as f64).min(1.0)
            } else {
                0.0
            };
            snap.push(LinkSample {
                link: i as u32,
                queued_bytes: l.queued_bytes,
                class0_bytes: l.class0_bytes(),
                util,
                drops: l.drops,
                alive: l.alive,
            });
        }
        s.prev_t = now;
        if s.samples.len() >= s.spec.ring_capacity {
            s.samples.pop_front();
            s.samples_evicted += 1;
        }
        s.samples.push_back(Sample {
            t_ps: now,
            arena_live,
            ecn_marks,
            live_descriptors,
            links: snap,
        });
    }

    /// Record a job-lifecycle span.
    #[inline]
    pub fn span(
        &mut self,
        t_ps: Time,
        kind: SpanKind,
        job: u32,
        node: u32,
        block: Option<u32>,
        detail: u64,
    ) {
        let Some(s) = self.state.as_mut() else { return };
        if s.spans.len() >= s.spec.max_spans {
            s.spans_dropped += 1;
            return;
        }
        s.spans.push(Span {
            t_ps,
            kind,
            job,
            node,
            block,
            detail,
        });
    }

    /// Record a realized-tree forward (Canary dataplane only).
    #[inline]
    pub fn tree(&mut self, rec: TreeRecord) {
        let Some(s) = self.state.as_mut() else { return };
        if s.trees.len() >= s.spec.max_tree_records {
            s.trees_dropped += 1;
            return;
        }
        s.trees.push(rec);
    }

    /// Choose which of `tenant`'s blocks the flight recorder follows.
    /// Called once per job at installation. The selection is drawn from
    /// a dedicated stream derived from the run seed and the tenant id —
    /// never from the simulation RNG — so a traced run's packet
    /// schedule (and fingerprint) is bit-identical to an untraced one,
    /// and the same seed always samples the same blocks.
    pub fn register_job(&mut self, seed: u64, tenant: u16, total_blocks: u32) {
        let Some(s) = self.state.as_mut() else { return };
        if s.spec.trace_blocks == 0 || total_blocks == 0 {
            return;
        }
        let mut mix = seed ^ ((tenant as u64) << 32) ^ 0xF11C_97B1_0E57_C0DE;
        let mut rng = Rng::new(splitmix64(&mut mix));
        let k = (s.spec.trace_blocks as usize).min(total_blocks as usize);
        let mut sel = vec![false; total_blocks as usize];
        for i in rng.sample_indices(total_blocks as usize, k) {
            sel[i] = true;
        }
        s.traced.insert(tenant, TracedJob { total_blocks, sel });
    }

    /// Is `wire_block` of `tenant` being followed? Retry rounds reuse
    /// the original index modulo `total_blocks`, so a traced block
    /// stays traced across rounds.
    #[inline]
    pub fn is_traced(&self, tenant: u16, wire_block: u32) -> bool {
        let Some(s) = self.state.as_ref() else { return false };
        match s.traced.get(&tenant) {
            Some(j) => j.sel[(wire_block % j.total_blocks) as usize],
            None => false,
        }
    }

    /// Record one link hop (flight recorder). Packets of untraced
    /// blocks — and everything when tracing is off — fall out on the
    /// first branches.
    #[inline]
    pub fn hop(&mut self, rec: HopRecord) {
        let Some(s) = self.state.as_mut() else { return };
        let Some(j) = s.traced.get(&rec.tenant) else { return };
        if !j.sel[(rec.block % j.total_blocks) as usize] {
            return;
        }
        if s.hops.len() >= s.spec.max_hops {
            s.hops_dropped += 1;
            return;
        }
        s.hops.push(rec);
    }

    /// Record an aggregation-wait (flight recorder; same filtering as
    /// [`Tracer::hop`]).
    #[inline]
    pub fn wait(&mut self, rec: WaitRecord) {
        let Some(s) = self.state.as_mut() else { return };
        let Some(j) = s.traced.get(&rec.tenant) else { return };
        if !j.sel[(rec.block % j.total_blocks) as usize] {
            return;
        }
        if s.waits.len() >= s.spec.max_waits {
            s.waits_dropped += 1;
            return;
        }
        s.waits.push(rec);
    }

    // --- read side (all empty/zero when disabled) ---

    pub fn n_samples(&self) -> usize {
        self.state.as_ref().map_or(0, |s| s.samples.len())
    }

    pub fn samples(&self) -> impl Iterator<Item = &Sample> {
        self.state.iter().flat_map(|s| s.samples.iter())
    }

    pub fn spans(&self) -> &[Span] {
        match &self.state {
            Some(s) => &s.spans,
            None => &[],
        }
    }

    pub fn tree_records(&self) -> &[TreeRecord] {
        match &self.state {
            Some(s) => &s.trees,
            None => &[],
        }
    }

    pub fn hops(&self) -> &[HopRecord] {
        match &self.state {
            Some(s) => &s.hops,
            None => &[],
        }
    }

    pub fn waits(&self) -> &[WaitRecord] {
        match &self.state {
            Some(s) => &s.waits,
            None => &[],
        }
    }

    /// (samples evicted, spans dropped, tree records dropped).
    pub fn dropped(&self) -> (u64, u64, u64) {
        self.state.as_ref().map_or((0, 0, 0), |s| {
            (s.samples_evicted, s.spans_dropped, s.trees_dropped)
        })
    }

    /// Flight-recorder overflow counters: (hops dropped, waits dropped).
    pub fn flight_dropped(&self) -> (u64, u64) {
        self.state
            .as_ref()
            .map_or((0, 0), |s| (s.hops_dropped, s.waits_dropped))
    }

    /// An empty recorder sharing this tracer's spec, block selection
    /// and sampler baseline — one per shard of a space-parallel run
    /// (`sim/shard.rs`). Forking a disabled tracer stays disabled, so
    /// the zero-footprint contract survives sharding.
    pub fn fork_for_shard(&self) -> Tracer {
        let Some(s) = self.state.as_ref() else {
            return Tracer::off();
        };
        Tracer {
            state: Some(Box::new(TraceState {
                spec: s.spec.clone(),
                samples: VecDeque::new(),
                samples_evicted: 0,
                spans: Vec::new(),
                spans_dropped: 0,
                trees: Vec::new(),
                trees_dropped: 0,
                hops: Vec::new(),
                hops_dropped: 0,
                waits: Vec::new(),
                waits_dropped: 0,
                traced: s.traced.clone(),
                prev_busy: s.prev_busy.clone(),
                prev_t: s.prev_t,
            })),
        }
    }

    /// Fold per-shard recorders back into this (master) tracer in a
    /// canonical order, so a sharded run's trace artifacts are a
    /// deterministic function of (scenario, shard count):
    ///
    /// - sampler ticks are unioned by tick time — gauges add (each
    ///   shard counted only its own arena/descriptors/marks), per-link
    ///   snapshots concatenate and sort by link id;
    /// - spans/trees/hops/waits concatenate and stable-sort by their
    ///   natural time-major keys;
    /// - drop counters add, and the merged logs are re-capped to the
    ///   spec limits.
    ///
    /// No-op when tracing is off (the forks were all off too).
    pub fn merge_shards(&mut self, shards: Vec<Tracer>) {
        let Some(s) = self.state.as_mut() else { return };
        let mut by_t: BTreeMap<Time, Sample> = BTreeMap::new();
        let mut absorb = |samples: &mut VecDeque<Sample>| {
            for sm in samples.drain(..) {
                let e = by_t.entry(sm.t_ps).or_insert_with(|| Sample {
                    t_ps: sm.t_ps,
                    arena_live: 0,
                    ecn_marks: 0,
                    live_descriptors: 0,
                    links: Vec::new(),
                });
                e.arena_live += sm.arena_live;
                e.ecn_marks += sm.ecn_marks;
                e.live_descriptors += sm.live_descriptors;
                e.links.extend(sm.links);
            }
        };
        absorb(&mut s.samples);
        for shard in shards {
            let Some(mut f) = shard.state else { continue };
            absorb(&mut f.samples);
            s.samples_evicted += f.samples_evicted;
            s.spans.append(&mut f.spans);
            s.spans_dropped += f.spans_dropped;
            s.trees.append(&mut f.trees);
            s.trees_dropped += f.trees_dropped;
            s.hops.append(&mut f.hops);
            s.hops_dropped += f.hops_dropped;
            s.waits.append(&mut f.waits);
            s.waits_dropped += f.waits_dropped;
        }
        for mut sm in by_t.into_values() {
            sm.links.sort_by_key(|l| l.link);
            if s.samples.len() >= s.spec.ring_capacity {
                s.samples.pop_front();
                s.samples_evicted += 1;
            }
            s.samples.push_back(sm);
        }
        s.spans.sort_by_key(|sp| {
            (sp.t_ps, sp.job, sp.node, sp.kind, sp.block, sp.detail)
        });
        s.trees.sort_by_key(|t| {
            (t.t_ps, t.switch, t.tenant, t.block, t.contributed)
        });
        s.hops.sort_by_key(|h| {
            (h.t_enq, h.link, h.tenant, h.block, h.queue_ps)
        });
        s.waits.sort_by_key(|w| {
            (w.t_start, w.node, w.tenant, w.block, w.t_end)
        });
        let spans_cap = s.spec.max_spans;
        if s.spans.len() > spans_cap {
            s.spans_dropped += (s.spans.len() - spans_cap) as u64;
            s.spans.truncate(spans_cap);
        }
        let trees_cap = s.spec.max_tree_records;
        if s.trees.len() > trees_cap {
            s.trees_dropped += (s.trees.len() - trees_cap) as u64;
            s.trees.truncate(trees_cap);
        }
        let hops_cap = s.spec.max_hops;
        if s.hops.len() > hops_cap {
            s.hops_dropped += (s.hops.len() - hops_cap) as u64;
            s.hops.truncate(hops_cap);
        }
        let waits_cap = s.spec.max_waits;
        if s.waits.len() > waits_cap {
            s.waits_dropped += (s.waits.len() - waits_cap) as u64;
            s.waits.truncate(waits_cap);
        }
    }
}

/// Decode a port bitmap into the contributing port list.
fn ports_of(children: u64) -> Vec<Value> {
    (0..64)
        .filter(|p| children & (1u64 << p) != 0)
        .map(Value::Int)
        .collect()
}

/// Short wire-kind label for path steps.
fn kind_label(k: PacketKind) -> &'static str {
    match k {
        PacketKind::CanaryReduce => "canary_reduce",
        PacketKind::CanaryBroadcast => "canary_broadcast",
        PacketKind::CanaryRestore => "canary_restore",
        PacketKind::CanaryRetransData => "canary_retrans_data",
        PacketKind::CanaryRetransReq => "canary_retrans_req",
        PacketKind::CanaryFailure => "canary_failure",
        PacketKind::CanaryDirect => "canary_direct",
        PacketKind::StaticReduce => "static_reduce",
        PacketKind::StaticBroadcast => "static_broadcast",
        PacketKind::Ring => "ring",
        PacketKind::Background => "background",
        PacketKind::TransportAck => "transport_ack",
        PacketKind::TransportCnp => "transport_cnp",
    }
}

/// One step of a reconstructed critical path: a link hop (`from != to`
/// unless the fabric loops) or an aggregation wait (`from == to`,
/// labelled `agg_wait` / `timeout_wait`). Exactly one component group
/// is nonzero per step, and the step covers `[t_start, t_end]`
/// contiguously with its neighbours.
#[derive(Clone, Debug)]
pub struct PathStep {
    pub from: u32,
    pub to: u32,
    pub t_start: Time,
    pub t_end: Time,
    pub label: &'static str,
    pub queue_ps: Time,
    pub ser_ps: Time,
    pub prop_ps: Time,
    pub agg_wait_ps: Time,
    pub timeout_penalty_ps: Time,
}

/// The critical path of one traced block: the max-latency contributor
/// chain from the first host send on the chain through every
/// aggregation point to the last result delivery. Because the steps
/// tile `[t_start, t_end]` with no gaps, the five components sum to
/// the end-to-end latency ps-exactly ([`BlockPath::e2e_ps`] ==
/// [`BlockPath::components_ps`]; pinned in `tests/trace.rs`).
#[derive(Clone, Debug)]
pub struct BlockPath {
    pub tenant: u16,
    /// Wire block id.
    pub block: u32,
    pub t_start: Time,
    pub t_end: Time,
    pub queue_ps: Time,
    pub ser_ps: Time,
    pub prop_ps: Time,
    pub agg_wait_ps: Time,
    pub timeout_penalty_ps: Time,
    pub n_hops: u32,
    pub n_waits: u32,
    pub steps: Vec<PathStep>,
}

impl BlockPath {
    /// Measured end-to-end latency of the chain.
    pub fn e2e_ps(&self) -> Time {
        self.t_end - self.t_start
    }

    /// Sum of the five attributed components.
    pub fn components_ps(&self) -> Time {
        self.queue_ps
            + self.ser_ps
            + self.prop_ps
            + self.agg_wait_ps
            + self.timeout_penalty_ps
    }
}

/// Reconstruct per-block critical paths from hop and wait logs.
///
/// Per (tenant, wire-block) group: anchor on the *last* delivery of a
/// result-carrying packet into a host (broadcast or retransmitted
/// data; any-kind fallback covers the ring, whose every hop is
/// host-to-host data), then walk causally backwards. A hop's enqueue
/// at `(node, t)` is explained by either a wait record ending at
/// exactly `(node, t)` — whose start is the delivery of the *earliest*
/// contributor, the packet that sat resident — or by the hop delivered
/// at exactly `(node, t)` (same-instant forwarding). Chaining through
/// the earliest contributor is what makes the chain the *max-latency*
/// one: at a timed-out Canary descriptor the attributed slack is the
/// full aggregation timeout, at a static slot it is the whole
/// residency. The walk ends at a send with no recorded cause — the
/// chain's first host injection.
pub fn reconstruct_paths(
    hops: &[HopRecord],
    waits: &[WaitRecord],
    is_host: impl Fn(u32) -> bool,
) -> Vec<BlockPath> {
    let mut groups: BTreeMap<(u16, u32), (Vec<usize>, Vec<usize>)> =
        BTreeMap::new();
    for (i, h) in hops.iter().enumerate() {
        groups.entry((h.tenant, h.block)).or_default().0.push(i);
    }
    for (i, w) in waits.iter().enumerate() {
        groups.entry((w.tenant, w.block)).or_default().1.push(i);
    }
    let mut out = Vec::new();
    for ((tenant, block), (his, wis)) in groups {
        if his.is_empty() {
            continue; // waits alone give no deliverable chain
        }
        let anchor = his
            .iter()
            .filter(|&&hi| {
                let h = &hops[hi];
                is_host(h.to)
                    && matches!(
                        h.kind,
                        PacketKind::CanaryBroadcast
                            | PacketKind::CanaryRetransData
                            | PacketKind::StaticBroadcast
                    )
            })
            .max_by_key(|&&hi| hops[hi].t_deliver())
            .or_else(|| his.iter().max_by_key(|&&hi| hops[hi].t_deliver()));
        let Some(&anchor) = anchor else { continue };

        let mut cur = anchor;
        let t_end = hops[cur].t_deliver();
        let mut rsteps: Vec<PathStep> = Vec::new();
        // hop durations are strictly positive, so the cursor time
        // strictly decreases; the guard only bounds degenerate logs
        let mut guard = his.len() + wis.len() + 4;
        let t_start = loop {
            let h = &hops[cur];
            rsteps.push(PathStep {
                from: h.from,
                to: h.to,
                t_start: h.t_enq,
                t_end: h.t_deliver(),
                label: kind_label(h.kind),
                queue_ps: h.queue_ps,
                ser_ps: h.ser_ps,
                prop_ps: h.prop_ps,
                agg_wait_ps: 0,
                timeout_penalty_ps: 0,
            });
            let mut t = h.t_enq;
            let node = h.from;
            guard -= 1;
            if guard == 0 {
                break t;
            }
            if let Some(&wi) = wis
                .iter()
                .find(|&&wi| waits[wi].node == node && waits[wi].t_end == t)
            {
                let w = &waits[wi];
                let slack = w.t_end - w.t_start;
                let to = w.via_timeout;
                rsteps.push(PathStep {
                    from: node,
                    to: node,
                    t_start: w.t_start,
                    t_end: w.t_end,
                    label: if to { "timeout_wait" } else { "agg_wait" },
                    queue_ps: 0,
                    ser_ps: 0,
                    prop_ps: 0,
                    agg_wait_ps: if to { 0 } else { slack },
                    timeout_penalty_ps: if to { slack } else { 0 },
                });
                t = w.t_start;
            }
            match his
                .iter()
                .find(|&&hi| hops[hi].to == node && hops[hi].t_deliver() == t)
            {
                Some(&hi) => cur = hi,
                None => break t,
            }
        };
        rsteps.reverse();
        let mut p = BlockPath {
            tenant,
            block,
            t_start,
            t_end,
            queue_ps: 0,
            ser_ps: 0,
            prop_ps: 0,
            agg_wait_ps: 0,
            timeout_penalty_ps: 0,
            n_hops: 0,
            n_waits: 0,
            steps: Vec::new(),
        };
        for st in &rsteps {
            p.queue_ps += st.queue_ps;
            p.ser_ps += st.ser_ps;
            p.prop_ps += st.prop_ps;
            p.agg_wait_ps += st.agg_wait_ps;
            p.timeout_penalty_ps += st.timeout_penalty_ps;
            if st.label == "agg_wait" || st.label == "timeout_wait" {
                p.n_waits += 1;
            } else {
                p.n_hops += 1;
            }
        }
        p.steps = rsteps;
        out.push(p);
    }
    out
}

/// Critical paths of every traced block in `net` (empty when the
/// flight recorder was off or sampled nothing).
pub fn critical_paths(net: &Network) -> Vec<BlockPath> {
    reconstruct_paths(net.tracer.hops(), net.tracer.waits(), |n| {
        matches!(net.nodes[n as usize].body, NodeBody::Host(_))
    })
}

/// Write the four trace artifacts (`trace_timeline.csv`,
/// `trace_spans.csv`, `trace_trees.json`,
/// `trace_critical_paths.json`) under `dir` and return the written
/// paths. The timeline carries one global gauge row per tick
/// (`link == -1`) plus one row per active link, so the file is
/// non-empty whenever the sampler ran at all; the global row also
/// surfaces the sampler ring's eviction count (`samples_dropped`), so
/// an overflowing ring is visible instead of silently shedding the
/// oldest ticks.
pub fn export(net: &Network, dir: &str) -> std::io::Result<Vec<String>> {
    let tr = &net.tracer;
    let mut paths = Vec::new();

    let mut timeline = Series::new(
        "trace_timeline",
        &[
            "t_us",
            "link",
            "from",
            "to",
            "queued_bytes",
            "class0_bytes",
            "util_pct",
            "drops",
            "alive",
            "arena_live",
            "live_desc",
            "ecn_marks",
            "samples_dropped",
        ],
    );
    let (samples_dropped, _, _) = tr.dropped();
    for s in tr.samples() {
        let t_us = s.t_ps as f64 / US as f64;
        let total_q: u64 = s.links.iter().map(|l| l.queued_bytes).sum();
        let total_c0: u64 = s.links.iter().map(|l| l.class0_bytes).sum();
        timeline.push_display(&[
            &format!("{t_us:.3}"),
            &-1i64,
            &-1i64,
            &-1i64,
            &total_q,
            &total_c0,
            &"",
            &"",
            &"",
            &s.arena_live,
            &s.live_descriptors,
            &s.ecn_marks,
            &samples_dropped,
        ]);
        for l in &s.links {
            let (from, to) = {
                let link = &net.links[l.link as usize];
                (link.from as i64, link.to as i64)
            };
            timeline.push_display(&[
                &format!("{t_us:.3}"),
                &(l.link as i64),
                &from,
                &to,
                &l.queued_bytes,
                &l.class0_bytes,
                &format!("{:.1}", 100.0 * l.util),
                &l.drops,
                &(l.alive as u8),
                &"",
                &"",
                &"",
                &"",
            ]);
        }
    }
    paths.push(timeline.write_csv(dir)?);

    let mut spans = Series::new(
        "trace_spans",
        &["t_us", "kind", "job", "node", "block", "detail"],
    );
    for sp in tr.spans() {
        spans.push_display(&[
            &format!("{:.3}", sp.t_ps as f64 / US as f64),
            &sp.kind.name(),
            &sp.job,
            &sp.node,
            &sp.block.map_or(-1, |b| b as i64),
            &sp.detail,
        ]);
    }
    paths.push(spans.write_csv(dir)?);

    paths.push(export_trees(net, dir)?);
    paths.push(export_critical_paths(net, dir)?);
    Ok(paths)
}

/// `trace_critical_paths.json`: one reconstructed critical path per
/// traced block plus the flight-recorder volume/overflow counters.
/// Every numeric field is an integer picosecond count — no float
/// formatting — so identical runs serialize byte-identically (pinned
/// in `tests/trace.rs`).
fn export_critical_paths(net: &Network, dir: &str) -> std::io::Result<String> {
    let block_paths = critical_paths(net);
    let tr = &net.tracer;
    let (hops_dropped, waits_dropped) = tr.flight_dropped();
    let path_vals: Vec<Value> = block_paths
        .iter()
        .map(|p| {
            let steps: Vec<Value> = p
                .steps
                .iter()
                .map(|s| {
                    obj(vec![
                        ("from", Value::Int(s.from as i64)),
                        ("to", Value::Int(s.to as i64)),
                        ("t_start_ps", Value::Int(s.t_start as i64)),
                        ("t_end_ps", Value::Int(s.t_end as i64)),
                        ("kind", Value::Str(s.label.into())),
                        ("queue_ps", Value::Int(s.queue_ps as i64)),
                        ("ser_ps", Value::Int(s.ser_ps as i64)),
                        ("prop_ps", Value::Int(s.prop_ps as i64)),
                        ("agg_wait_ps", Value::Int(s.agg_wait_ps as i64)),
                        (
                            "timeout_penalty_ps",
                            Value::Int(s.timeout_penalty_ps as i64),
                        ),
                    ])
                })
                .collect();
            obj(vec![
                ("tenant", Value::Int(p.tenant as i64)),
                ("block", Value::Int(p.block as i64)),
                ("t_start_ps", Value::Int(p.t_start as i64)),
                ("t_end_ps", Value::Int(p.t_end as i64)),
                ("e2e_ps", Value::Int(p.e2e_ps() as i64)),
                ("total_ps", Value::Int(p.components_ps() as i64)),
                ("queueing_ps", Value::Int(p.queue_ps as i64)),
                ("serialization_ps", Value::Int(p.ser_ps as i64)),
                ("propagation_ps", Value::Int(p.prop_ps as i64)),
                ("agg_wait_ps", Value::Int(p.agg_wait_ps as i64)),
                (
                    "timeout_penalty_ps",
                    Value::Int(p.timeout_penalty_ps as i64),
                ),
                ("hops", Value::Int(p.n_hops as i64)),
                ("waits", Value::Int(p.n_waits as i64)),
                ("steps", Value::Array(steps)),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("blocks_traced", Value::Int(block_paths.len() as i64)),
        ("hops_recorded", Value::Int(tr.hops().len() as i64)),
        ("hops_dropped", Value::Int(hops_dropped as i64)),
        ("waits_recorded", Value::Int(tr.waits().len() as i64)),
        ("waits_dropped", Value::Int(waits_dropped as i64)),
        ("paths", Value::Array(path_vals)),
    ]);
    std::fs::create_dir_all(dir)?;
    let path = std::path::Path::new(dir).join("trace_critical_paths.json");
    std::fs::write(&path, doc.to_json())?;
    Ok(path.to_string_lossy().to_string())
}

/// `trace_trees.json`: per-(tenant, block) realized-tree forwards, a
/// fan-in-fraction histogram, and timeout/partial totals.
fn export_trees(net: &Network, dir: &str) -> std::io::Result<String> {
    let tr = &net.tracer;
    let recs = tr.tree_records();
    let mut blocks: BTreeMap<String, Vec<Value>> = BTreeMap::new();
    let mut hist = Histogram::new(0.0, 1.0, 10);
    let mut timeout_forwards = 0i64;
    let mut partial_forwards = 0i64;
    for r in recs {
        hist.add(r.fanin_fraction());
        if r.via_timeout {
            timeout_forwards += 1;
        }
        if r.contributed < r.expected {
            partial_forwards += 1;
        }
        blocks
            .entry(format!("t{}/b{}", r.tenant, r.block))
            .or_default()
            .push(obj(vec![
                ("t_us", Value::Float(r.t_ps as f64 / US as f64)),
                ("switch", Value::Int(r.switch as i64)),
                ("ports", Value::Array(ports_of(r.children))),
                ("contributed", Value::Int(r.contributed as i64)),
                ("expected", Value::Int(r.expected as i64)),
                ("via_timeout", Value::Bool(r.via_timeout)),
                (
                    "latency_us",
                    Value::Float(r.latency_ps as f64 / US as f64),
                ),
            ]));
    }
    let (_, _, trees_dropped) = tr.dropped();
    let doc = obj(vec![
        ("forwards_total", Value::Int(recs.len() as i64)),
        ("timeout_forwards", Value::Int(timeout_forwards)),
        ("partial_forwards", Value::Int(partial_forwards)),
        ("dropped_records", Value::Int(trees_dropped as i64)),
        (
            "fanin_histogram",
            obj(vec![
                ("lo", Value::Float(0.0)),
                ("hi", Value::Float(1.0)),
                (
                    "counts",
                    Value::Array(
                        hist.counts
                            .iter()
                            .map(|&c| Value::Int(c as i64))
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "blocks",
            Value::Object(
                blocks
                    .into_iter()
                    .map(|(k, v)| (k, Value::Array(v)))
                    .collect(),
            ),
        ),
    ]);
    std::fs::create_dir_all(dir)?;
    let path = std::path::Path::new(dir).join("trace_trees.json");
    std::fs::write(&path, doc.to_json())?;
    Ok(path.to_string_lossy().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_tracer_is_inert_and_empty() {
        let mut t = Tracer::off();
        assert!(!t.enabled());
        assert_eq!(t.cadence_ps(), None);
        t.span(5, SpanKind::Kick, 0, 1, None, 0);
        t.tree(TreeRecord {
            t_ps: 5,
            tenant: 0,
            block: 0,
            switch: 9,
            children: 0b11,
            contributed: 2,
            expected: 3,
            via_timeout: true,
            latency_ps: 1,
        });
        assert_eq!(t.n_samples(), 0);
        assert!(t.spans().is_empty());
        assert!(t.tree_records().is_empty());
        assert_eq!(t.dropped(), (0, 0, 0));
    }

    #[test]
    fn ring_evicts_oldest_sample() {
        let spec = TraceSpec {
            ring_capacity: 2,
            ..TraceSpec::default()
        };
        let mut t = Tracer::on(spec);
        for i in 1..=3u64 {
            t.sample(i * US, &[], i as u32, 0, 0);
        }
        assert_eq!(t.n_samples(), 2);
        assert_eq!(t.dropped().0, 1);
        let first = t.samples().next().unwrap();
        assert_eq!(first.t_ps, 2 * US);
    }

    #[test]
    fn span_and_tree_caps_count_drops() {
        let spec = TraceSpec {
            max_spans: 1,
            max_tree_records: 1,
            ..TraceSpec::default()
        };
        let mut t = Tracer::on(spec);
        for i in 0..3 {
            t.span(i, SpanKind::FirstSend, 0, 0, Some(0), 0);
            t.tree(TreeRecord {
                t_ps: i,
                tenant: 0,
                block: 0,
                switch: 0,
                children: 1,
                contributed: 1,
                expected: 2,
                via_timeout: false,
                latency_ps: 0,
            });
        }
        assert_eq!(t.spans().len(), 1);
        assert_eq!(t.tree_records().len(), 1);
        assert_eq!(t.dropped(), (0, 2, 2));
    }

    #[test]
    fn fanin_fraction_handles_zero_expected() {
        let mut r = TreeRecord {
            t_ps: 0,
            tenant: 0,
            block: 0,
            switch: 0,
            children: 0,
            contributed: 3,
            expected: 4,
            via_timeout: false,
            latency_ps: 0,
        };
        assert_eq!(r.fanin_fraction(), 0.75);
        r.expected = 0;
        assert_eq!(r.fanin_fraction(), 1.0);
    }

    #[test]
    fn ports_decode_from_bitmap() {
        let ports: Vec<i64> = ports_of(0b1010_0001)
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(ports, vec![0, 5, 7]);
    }

    fn hop(
        tenant: u16,
        block: u32,
        kind: PacketKind,
        from: u32,
        to: u32,
        t_enq: Time,
        queue: Time,
        ser: Time,
        prop: Time,
    ) -> HopRecord {
        HopRecord {
            tenant,
            block,
            kind,
            link: 0,
            from,
            to,
            t_enq,
            queue_ps: queue,
            ser_ps: ser,
            prop_ps: prop,
        }
    }

    #[test]
    fn block_sampling_is_seeded_and_bounded() {
        let mk = || {
            let mut t = Tracer::on(TraceSpec::default().with_blocks(3));
            t.register_job(42, 1, 10);
            t
        };
        let (a, b) = (mk(), mk());
        let sel: Vec<bool> = (0..10).map(|i| a.is_traced(1, i)).collect();
        assert_eq!(sel.iter().filter(|&&s| s).count(), 3);
        for i in 0..10 {
            assert_eq!(a.is_traced(1, i), b.is_traced(1, i));
            // retry rounds reuse the selection modulo total_blocks
            assert_eq!(a.is_traced(1, i), a.is_traced(1, i + 10));
        }
        // unregistered tenants are never traced
        assert!(!a.is_traced(2, 0));
    }

    #[test]
    fn hop_and_wait_filter_untraced_and_count_drops() {
        let spec = TraceSpec::default().with_blocks(1);
        let mut t = Tracer::on(TraceSpec {
            max_hops: 1,
            max_waits: 1,
            ..spec
        });
        t.register_job(7, 0, 1); // the single block is traced
        for i in 0..3u64 {
            t.hop(hop(0, 0, PacketKind::Ring, 0, 1, i, 0, 1, 1));
            t.wait(WaitRecord {
                tenant: 0,
                block: 0,
                node: 1,
                t_start: i,
                t_end: i + 1,
                via_timeout: false,
            });
            // unregistered tenant: silently filtered, not a drop
            t.hop(hop(9, 0, PacketKind::Ring, 0, 1, i, 0, 1, 1));
        }
        assert_eq!(t.hops().len(), 1);
        assert_eq!(t.waits().len(), 1);
        assert_eq!(t.flight_dropped(), (2, 2));
        // PR 7 collectors untouched
        assert_eq!(t.dropped(), (0, 0, 0));
    }

    #[test]
    fn reconstruct_attributes_components_exactly() {
        // host 0 -> switch 1 (timed-out descriptor) -> leader host 2
        // (aggregation wait) -> broadcast back to host 0
        let hops = vec![
            hop(0, 5, PacketKind::CanaryReduce, 0, 1, 0, 10, 20, 30),
            hop(0, 5, PacketKind::CanaryReduce, 1, 2, 1060, 0, 20, 30),
            hop(0, 5, PacketKind::CanaryBroadcast, 2, 0, 1200, 5, 20, 30),
            // a second contributor that is NOT on the critical chain
            hop(0, 5, PacketKind::CanaryReduce, 3, 1, 500, 0, 20, 30),
        ];
        let waits = vec![
            WaitRecord {
                tenant: 0,
                block: 5,
                node: 1,
                t_start: 60,
                t_end: 1060,
                via_timeout: true,
            },
            WaitRecord {
                tenant: 0,
                block: 5,
                node: 2,
                t_start: 1110,
                t_end: 1200,
                via_timeout: false,
            },
        ];
        let paths =
            reconstruct_paths(&hops, &waits, |n| n == 0 || n == 2);
        assert_eq!(paths.len(), 1);
        let p = &paths[0];
        assert_eq!((p.tenant, p.block), (0, 5));
        assert_eq!((p.t_start, p.t_end), (0, 1255));
        assert_eq!(p.timeout_penalty_ps, 1000);
        assert_eq!(p.agg_wait_ps, 90);
        assert_eq!(p.queue_ps, 15);
        assert_eq!(p.ser_ps, 60);
        assert_eq!(p.prop_ps, 90);
        assert_eq!(p.n_hops, 3);
        assert_eq!(p.n_waits, 2);
        assert_eq!(p.steps.len(), 5);
        // the headline invariant: components tile the e2e latency
        assert_eq!(p.components_ps(), p.e2e_ps());
    }
}
