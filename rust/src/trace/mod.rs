//! Run-time telemetry: time-series sampling, per-job lifecycle spans,
//! and dynamic aggregation-tree capture (DESIGN.md §2.7).
//!
//! The [`Tracer`] is owned by the [`Network`] and threaded through
//! `Ctx`, so every layer (switch dataplane, host engines, the
//! collective runner) can emit records without extra plumbing. Three
//! collectors live behind one `Option` box:
//!
//! 1. **Sampler** — on a configurable cadence the engine snapshots
//!    per-link queue depth / utilization, live arena packets, ECN
//!    marks, and live aggregation descriptors into a ring buffer.
//! 2. **Spans** — structured job-lifecycle events (install → kick →
//!    first/last send → aggregated → broadcast → complete/stalled,
//!    plus retransmission and fault-fallback markers).
//! 3. **Trees** — one record per Canary partial-aggregate forward:
//!    which switch, which ports contributed, expected vs actual
//!    fan-in, and whether the timeout (rather than fan-in
//!    completion) fired it. This is the realized dynamic tree.
//!
//! **Zero-footprint when off.** A disabled tracer is a `None` box:
//! every hook is a single branch, no RNG is drawn, no event is
//! scheduled, and no metric moves — seeded fingerprints are
//! bit-identical with tracing on or off (pinned in `tests/trace.rs`).
//! The sampler event itself is dispatched *outside* the
//! `events_processed` counter for the same reason.

use std::collections::{BTreeMap, VecDeque};

use crate::report::Series;
use crate::sim::{Link, Network, Time, US};
use crate::util::json::{obj, Value};
use crate::util::stats::Histogram;

/// Recorder configuration: cadence plus per-collector capacity caps
/// (the sampler ring evicts oldest, span/tree logs stop appending and
/// count drops — a trace must never OOM a long run).
#[derive(Clone, Debug)]
pub struct TraceSpec {
    /// Sampler cadence in picoseconds (default 1 µs).
    pub cadence_ps: Time,
    /// Sampler ring capacity in samples (oldest evicted beyond this).
    pub ring_capacity: usize,
    /// Span log cap; further spans are counted as dropped.
    pub max_spans: usize,
    /// Tree-record log cap; further records are counted as dropped.
    pub max_tree_records: usize,
}

impl Default for TraceSpec {
    fn default() -> TraceSpec {
        TraceSpec {
            cadence_ps: US,
            ring_capacity: 4096,
            max_spans: 65_536,
            max_tree_records: 65_536,
        }
    }
}

impl TraceSpec {
    /// Builder: override the sampler cadence (picoseconds).
    pub fn with_cadence(mut self, ps: Time) -> TraceSpec {
        self.cadence_ps = ps.max(1);
        self
    }
}

/// Per-link state captured by one sampler tick. Only *active* links
/// (transmitted since the previous tick, non-empty queue, or down)
/// are recorded, which keeps big idle fabrics cheap.
#[derive(Clone, Copy, Debug)]
pub struct LinkSample {
    pub link: u32,
    pub queued_bytes: u64,
    pub class0_bytes: u64,
    /// Fraction of the sampling interval the link spent serializing.
    pub util: f64,
    pub drops: u64,
    pub alive: bool,
}

/// One sampler tick: global gauges plus the active-link snapshot.
#[derive(Clone, Debug)]
pub struct Sample {
    pub t_ps: Time,
    pub arena_live: u32,
    pub ecn_marks: u64,
    pub live_descriptors: u64,
    pub links: Vec<LinkSample>,
}

/// Job-lifecycle span kinds, in rough temporal order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Job installed into the fabric (trees programmed, hosts armed).
    Install,
    /// Participant woken at the job's start time.
    Kick,
    /// A host injected its first block.
    FirstSend,
    /// A host injected its final block.
    LastSend,
    /// Leader observed a block fully aggregated.
    Aggregated,
    /// Leader broadcast a finished block to the group.
    Broadcast,
    /// Leader received a retransmission request (loss recovery).
    RetransReq,
    /// Leader opened a new retry round for a damaged block.
    RetryRound,
    /// Host fell back to direct-to-leader sends (fault recovery).
    Fallback,
    /// One host finished all of its blocks.
    HostDone,
    /// The whole job completed.
    Complete,
    /// The run ended with this job still incomplete.
    Stalled,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Install => "install",
            SpanKind::Kick => "kick",
            SpanKind::FirstSend => "first_send",
            SpanKind::LastSend => "last_send",
            SpanKind::Aggregated => "aggregated",
            SpanKind::Broadcast => "broadcast",
            SpanKind::RetransReq => "retrans_req",
            SpanKind::RetryRound => "retry_round",
            SpanKind::Fallback => "fallback",
            SpanKind::HostDone => "host_done",
            SpanKind::Complete => "complete",
            SpanKind::Stalled => "stalled",
        }
    }
}

/// One lifecycle event. `detail` is kind-specific (participant count
/// for install, host count for aggregated, round for retry, rank for
/// host_done, ...).
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub t_ps: Time,
    pub kind: SpanKind,
    pub job: u32,
    pub node: u32,
    pub block: Option<u32>,
    pub detail: u64,
}

/// One realized aggregation-tree edge set: a Canary switch forwarding
/// its (possibly partial) accumulator upstream for one block.
#[derive(Clone, Copy, Debug)]
pub struct TreeRecord {
    pub t_ps: Time,
    pub tenant: u32,
    pub block: u32,
    pub switch: u32,
    /// Bitmap of ingress ports that contributed to this aggregation.
    pub children: u64,
    /// Packets actually merged before the forward.
    pub contributed: u32,
    /// Fan-in the descriptor expected.
    pub expected: u32,
    /// True when the aggregation timeout fired the forward (partial).
    pub via_timeout: bool,
    /// Descriptor residency: allocation to forward.
    pub latency_ps: Time,
}

impl TreeRecord {
    /// Achieved fan-in as a fraction of the expected fan-in.
    pub fn fanin_fraction(&self) -> f64 {
        if self.expected == 0 {
            1.0
        } else {
            self.contributed as f64 / self.expected as f64
        }
    }
}

/// Live collector state; exists only while tracing is enabled.
#[derive(Debug)]
struct TraceState {
    spec: TraceSpec,
    samples: VecDeque<Sample>,
    samples_evicted: u64,
    spans: Vec<Span>,
    spans_dropped: u64,
    trees: Vec<TreeRecord>,
    trees_dropped: u64,
    /// `busy_ps` per link at the previous tick (utilization deltas).
    prev_busy: Vec<u64>,
    prev_t: Time,
}

/// The recorder. Disabled is the default and costs one branch per
/// hook; see the module docs for the zero-footprint contract.
#[derive(Debug, Default)]
pub struct Tracer {
    state: Option<Box<TraceState>>,
}

impl Tracer {
    /// A disabled tracer (the `Network::new` default).
    pub fn off() -> Tracer {
        Tracer { state: None }
    }

    /// An enabled tracer with the given spec.
    pub fn on(spec: TraceSpec) -> Tracer {
        Tracer {
            state: Some(Box::new(TraceState {
                spec,
                samples: VecDeque::new(),
                samples_evicted: 0,
                spans: Vec::new(),
                spans_dropped: 0,
                trees: Vec::new(),
                trees_dropped: 0,
                prev_busy: Vec::new(),
                prev_t: 0,
            })),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.state.is_some()
    }

    /// Sampler cadence, if tracing is enabled.
    pub fn cadence_ps(&self) -> Option<Time> {
        self.state.as_ref().map(|s| s.spec.cadence_ps)
    }

    /// Record one sampler tick. Called by the engine's `TraceSample`
    /// event only — never on the untraced path.
    pub fn sample(
        &mut self,
        now: Time,
        links: &[Link],
        arena_live: u32,
        live_descriptors: u64,
        ecn_marks: u64,
    ) {
        let Some(s) = self.state.as_mut() else { return };
        s.prev_busy.resize(links.len(), 0);
        let interval = now.saturating_sub(s.prev_t);
        let mut snap = Vec::new();
        for (i, l) in links.iter().enumerate() {
            let delta = l.busy_ps.saturating_sub(s.prev_busy[i]);
            s.prev_busy[i] = l.busy_ps;
            if delta == 0 && l.queued_bytes == 0 && l.alive {
                continue; // idle link: skip to bound memory
            }
            let util = if interval > 0 {
                (delta as f64 / interval as f64).min(1.0)
            } else {
                0.0
            };
            snap.push(LinkSample {
                link: i as u32,
                queued_bytes: l.queued_bytes,
                class0_bytes: l.class0_bytes(),
                util,
                drops: l.drops,
                alive: l.alive,
            });
        }
        s.prev_t = now;
        if s.samples.len() >= s.spec.ring_capacity {
            s.samples.pop_front();
            s.samples_evicted += 1;
        }
        s.samples.push_back(Sample {
            t_ps: now,
            arena_live,
            ecn_marks,
            live_descriptors,
            links: snap,
        });
    }

    /// Record a job-lifecycle span.
    #[inline]
    pub fn span(
        &mut self,
        t_ps: Time,
        kind: SpanKind,
        job: u32,
        node: u32,
        block: Option<u32>,
        detail: u64,
    ) {
        let Some(s) = self.state.as_mut() else { return };
        if s.spans.len() >= s.spec.max_spans {
            s.spans_dropped += 1;
            return;
        }
        s.spans.push(Span {
            t_ps,
            kind,
            job,
            node,
            block,
            detail,
        });
    }

    /// Record a realized-tree forward (Canary dataplane only).
    #[inline]
    pub fn tree(&mut self, rec: TreeRecord) {
        let Some(s) = self.state.as_mut() else { return };
        if s.trees.len() >= s.spec.max_tree_records {
            s.trees_dropped += 1;
            return;
        }
        s.trees.push(rec);
    }

    // --- read side (all empty/zero when disabled) ---

    pub fn n_samples(&self) -> usize {
        self.state.as_ref().map_or(0, |s| s.samples.len())
    }

    pub fn samples(&self) -> impl Iterator<Item = &Sample> {
        self.state.iter().flat_map(|s| s.samples.iter())
    }

    pub fn spans(&self) -> &[Span] {
        match &self.state {
            Some(s) => &s.spans,
            None => &[],
        }
    }

    pub fn tree_records(&self) -> &[TreeRecord] {
        match &self.state {
            Some(s) => &s.trees,
            None => &[],
        }
    }

    /// (samples evicted, spans dropped, tree records dropped).
    pub fn dropped(&self) -> (u64, u64, u64) {
        self.state.as_ref().map_or((0, 0, 0), |s| {
            (s.samples_evicted, s.spans_dropped, s.trees_dropped)
        })
    }
}

/// Decode a port bitmap into the contributing port list.
fn ports_of(children: u64) -> Vec<Value> {
    (0..64)
        .filter(|p| children & (1u64 << p) != 0)
        .map(Value::Int)
        .collect()
}

/// Write the three trace artifacts (`trace_timeline.csv`,
/// `trace_spans.csv`, `trace_trees.json`) under `dir` and return the
/// written paths. The timeline carries one global gauge row per tick
/// (`link == -1`) plus one row per active link, so the file is
/// non-empty whenever the sampler ran at all.
pub fn export(net: &Network, dir: &str) -> std::io::Result<Vec<String>> {
    let tr = &net.tracer;
    let mut paths = Vec::new();

    let mut timeline = Series::new(
        "trace_timeline",
        &[
            "t_us",
            "link",
            "from",
            "to",
            "queued_bytes",
            "class0_bytes",
            "util_pct",
            "drops",
            "alive",
            "arena_live",
            "live_desc",
            "ecn_marks",
        ],
    );
    for s in tr.samples() {
        let t_us = s.t_ps as f64 / US as f64;
        let total_q: u64 = s.links.iter().map(|l| l.queued_bytes).sum();
        let total_c0: u64 = s.links.iter().map(|l| l.class0_bytes).sum();
        timeline.push_display(&[
            &format!("{t_us:.3}"),
            &-1i64,
            &-1i64,
            &-1i64,
            &total_q,
            &total_c0,
            &"",
            &"",
            &"",
            &s.arena_live,
            &s.live_descriptors,
            &s.ecn_marks,
        ]);
        for l in &s.links {
            let (from, to) = {
                let link = &net.links[l.link as usize];
                (link.from as i64, link.to as i64)
            };
            timeline.push_display(&[
                &format!("{t_us:.3}"),
                &(l.link as i64),
                &from,
                &to,
                &l.queued_bytes,
                &l.class0_bytes,
                &format!("{:.1}", 100.0 * l.util),
                &l.drops,
                &(l.alive as u8),
                &"",
                &"",
                &"",
            ]);
        }
    }
    paths.push(timeline.write_csv(dir)?);

    let mut spans = Series::new(
        "trace_spans",
        &["t_us", "kind", "job", "node", "block", "detail"],
    );
    for sp in tr.spans() {
        spans.push_display(&[
            &format!("{:.3}", sp.t_ps as f64 / US as f64),
            &sp.kind.name(),
            &sp.job,
            &sp.node,
            &sp.block.map_or(-1, |b| b as i64),
            &sp.detail,
        ]);
    }
    paths.push(spans.write_csv(dir)?);

    paths.push(export_trees(net, dir)?);
    Ok(paths)
}

/// `trace_trees.json`: per-(tenant, block) realized-tree forwards, a
/// fan-in-fraction histogram, and timeout/partial totals.
fn export_trees(net: &Network, dir: &str) -> std::io::Result<String> {
    let tr = &net.tracer;
    let recs = tr.tree_records();
    let mut blocks: BTreeMap<String, Vec<Value>> = BTreeMap::new();
    let mut hist = Histogram::new(0.0, 1.0, 10);
    let mut timeout_forwards = 0i64;
    let mut partial_forwards = 0i64;
    for r in recs {
        hist.add(r.fanin_fraction());
        if r.via_timeout {
            timeout_forwards += 1;
        }
        if r.contributed < r.expected {
            partial_forwards += 1;
        }
        blocks
            .entry(format!("t{}/b{}", r.tenant, r.block))
            .or_default()
            .push(obj(vec![
                ("t_us", Value::Float(r.t_ps as f64 / US as f64)),
                ("switch", Value::Int(r.switch as i64)),
                ("ports", Value::Array(ports_of(r.children))),
                ("contributed", Value::Int(r.contributed as i64)),
                ("expected", Value::Int(r.expected as i64)),
                ("via_timeout", Value::Bool(r.via_timeout)),
                (
                    "latency_us",
                    Value::Float(r.latency_ps as f64 / US as f64),
                ),
            ]));
    }
    let (_, _, trees_dropped) = tr.dropped();
    let doc = obj(vec![
        ("forwards_total", Value::Int(recs.len() as i64)),
        ("timeout_forwards", Value::Int(timeout_forwards)),
        ("partial_forwards", Value::Int(partial_forwards)),
        ("dropped_records", Value::Int(trees_dropped as i64)),
        (
            "fanin_histogram",
            obj(vec![
                ("lo", Value::Float(0.0)),
                ("hi", Value::Float(1.0)),
                (
                    "counts",
                    Value::Array(
                        hist.counts
                            .iter()
                            .map(|&c| Value::Int(c as i64))
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "blocks",
            Value::Object(
                blocks
                    .into_iter()
                    .map(|(k, v)| (k, Value::Array(v)))
                    .collect(),
            ),
        ),
    ]);
    std::fs::create_dir_all(dir)?;
    let path = std::path::Path::new(dir).join("trace_trees.json");
    std::fs::write(&path, doc.to_json())?;
    Ok(path.to_string_lossy().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_tracer_is_inert_and_empty() {
        let mut t = Tracer::off();
        assert!(!t.enabled());
        assert_eq!(t.cadence_ps(), None);
        t.span(5, SpanKind::Kick, 0, 1, None, 0);
        t.tree(TreeRecord {
            t_ps: 5,
            tenant: 0,
            block: 0,
            switch: 9,
            children: 0b11,
            contributed: 2,
            expected: 3,
            via_timeout: true,
            latency_ps: 1,
        });
        assert_eq!(t.n_samples(), 0);
        assert!(t.spans().is_empty());
        assert!(t.tree_records().is_empty());
        assert_eq!(t.dropped(), (0, 0, 0));
    }

    #[test]
    fn ring_evicts_oldest_sample() {
        let spec = TraceSpec {
            ring_capacity: 2,
            ..TraceSpec::default()
        };
        let mut t = Tracer::on(spec);
        for i in 1..=3u64 {
            t.sample(i * US, &[], i as u32, 0, 0);
        }
        assert_eq!(t.n_samples(), 2);
        assert_eq!(t.dropped().0, 1);
        let first = t.samples().next().unwrap();
        assert_eq!(first.t_ps, 2 * US);
    }

    #[test]
    fn span_and_tree_caps_count_drops() {
        let spec = TraceSpec {
            max_spans: 1,
            max_tree_records: 1,
            ..TraceSpec::default()
        };
        let mut t = Tracer::on(spec);
        for i in 0..3 {
            t.span(i, SpanKind::FirstSend, 0, 0, Some(0), 0);
            t.tree(TreeRecord {
                t_ps: i,
                tenant: 0,
                block: 0,
                switch: 0,
                children: 1,
                contributed: 1,
                expected: 2,
                via_timeout: false,
                latency_ps: 0,
            });
        }
        assert_eq!(t.spans().len(), 1);
        assert_eq!(t.tree_records().len(), 1);
        assert_eq!(t.dropped(), (0, 2, 2));
    }

    #[test]
    fn fanin_fraction_handles_zero_expected() {
        let mut r = TreeRecord {
            t_ps: 0,
            tenant: 0,
            block: 0,
            switch: 0,
            children: 0,
            contributed: 3,
            expected: 4,
            via_timeout: false,
            latency_ps: 0,
        };
        assert_eq!(r.fanin_fraction(), 0.75);
        r.expected = 0;
        assert_eq!(r.fanin_fraction(), 1.0);
    }

    #[test]
    fn ports_decode_from_bitmap() {
        let ports: Vec<i64> = ports_of(0b1010_0001)
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(ports, vec![0, 5, 7]);
    }
}
