//! Figure/table regeneration harness — one function per paper figure
//! (DESIGN.md §5 maps each to its experiment).
//!
//! Usage (binary `figures`):
//!
//! ```text
//! figures all            [--scale full|half|ci] [--seeds N] [--out DIR]
//! figures fig2|fig6|fig7a|fig7b|fig8|fig9|fig10a|fig10b|fig11|mem|clos3
//!         |traffic|transport|placement|scale|churn|trace|critical-path
//!         |ablation ...
//! ```
//!
//! `full` reproduces the paper's parameters (1024 hosts, 4 MiB, 5 seeds —
//! minutes of wall time); `half` shrinks data size and seeds; `ci` runs a
//! 64-host network for smoke testing. Every series is printed and written
//! to `results/<name>.csv`. Independent runs (seeds, traffic cells) fan
//! out over OS threads ([`crate::util::par`]) with deterministic result
//! ordering. All experiments are assembled through the
//! [`ScenarioBuilder`] path; the `RandomUniform` placement keeps every
//! *single-job* series (fig2/6/7/8/9/11, mem, clos3, traffic,
//! ablation) bit-identical to the pre-redesign harness. The fig10
//! multi-tenant series use the builder's pool-based placement, which
//! draws differently than the retired `build_multi_tenant` shuffle, so
//! those two series differ from pre-redesign CSVs at the same seed.

use crate::collectives::{runner, Algo};
use crate::config::{ClosConfig, FatTreeConfig, SimConfig};
use crate::faults::FaultSpec;
use crate::loadbalance::LoadBalancer;
use crate::metrics::{
    average_network_utilization, memory_model_bytes, utilization_histogram,
};
use crate::report::Series;
use crate::sim::{ps_to_us, US};
use crate::topology::Clos;
use crate::trace::TraceSpec;
use crate::traffic::TrafficSpec;
use crate::transport::TransportSpec;
use crate::util::cli::Args;
use crate::util::json::{obj, Value};
use crate::util::par::par_map;
use crate::util::stats::{mean, percentile, percentile_sorted, stddev};
use crate::workload::{JobBuilder, Placement, ScenarioBuilder};

/// Experiment scale knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Paper parameters: 1024 hosts, 4 MiB, 5 seeds.
    Full,
    /// Paper topology, 1 MiB, 2 seeds (good fidelity, ~10x faster).
    Half,
    /// 64-host network, 256 KiB, 1 seed (smoke).
    Ci,
}

impl Scale {
    fn parse(s: &str) -> Result<Scale, String> {
        match s {
            "full" => Ok(Scale::Full),
            "half" => Ok(Scale::Half),
            "ci" => Ok(Scale::Ci),
            _ => Err(format!("unknown scale '{s}' (full|half|ci)")),
        }
    }

    pub fn topo(self) -> FatTreeConfig {
        match self {
            Scale::Full | Scale::Half => FatTreeConfig::paper(),
            Scale::Ci => FatTreeConfig::small(),
        }
    }

    /// 3-tier counterpart of [`Scale::topo`] (same host counts).
    pub fn topo3(self) -> ClosConfig {
        match self {
            Scale::Full | Scale::Half => ClosConfig::paper3(),
            Scale::Ci => ClosConfig::small3(),
        }
    }

    pub fn data_bytes(self) -> u64 {
        match self {
            Scale::Full => 4 << 20,
            Scale::Half => 1 << 20,
            Scale::Ci => 256 << 10,
        }
    }

    pub fn seeds(self) -> u64 {
        match self {
            Scale::Full => 5,
            Scale::Half => 2,
            Scale::Ci => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Scale::Full => "full",
            Scale::Half => "half",
            Scale::Ci => "ci",
        }
    }

    /// Per-host data size for the weak-scaling sweep (fixed per rung so
    /// total work grows linearly with the host count).
    pub fn scale_sweep_bytes(self) -> u64 {
        match self {
            Scale::Full => 512 << 10,
            Scale::Half => 128 << 10,
            Scale::Ci => 16 << 10,
        }
    }
}

/// Shared harness options.
pub struct Opts {
    pub scale: Scale,
    pub seeds: u64,
    pub out: String,
}

impl Opts {
    fn scaled_hosts(&self, frac_percent: u32) -> u32 {
        (self.scale.topo().n_hosts() * frac_percent / 100).max(2)
    }
}

fn algo_list(with_ring: bool, trees: &[u8]) -> Vec<Algo> {
    let mut v = Vec::new();
    if with_ring {
        v.push(Algo::Ring);
    }
    for &t in trees {
        v.push(Algo::StaticTree { n_trees: t });
    }
    v.push(Algo::Canary);
    v
}

/// Run one scenario over `seeds` placements (fanned out across OS
/// threads, per-seed order preserved); returns per-seed goodputs of the
/// first job.
fn goodputs(sc: &ScenarioBuilder, seeds: u64) -> Vec<f64> {
    par_map(seeds as usize, |s| {
        let mut exp = sc.build(1000 + s as u64);
        let r = runner::run_to_completion(&mut exp.net, u64::MAX);
        r[0].goodput_gbps.unwrap_or(0.0)
    })
}

fn runtimes_us(sc: &ScenarioBuilder, seeds: u64) -> Vec<f64> {
    par_map(seeds as usize, |s| {
        let mut exp = sc.build(1000 + s as u64);
        let r = runner::run_to_completion(&mut exp.net, u64::MAX);
        r[0].runtime_ps.map(ps_to_us).unwrap_or(f64::NAN)
    })
}

/// The standard single-job scenario every 2-tier figure starts from.
fn base_scenario(
    o: &Opts,
    algo: Algo,
    hosts: u32,
    congestion: bool,
) -> ScenarioBuilder {
    ScenarioBuilder::new(o.scale.topo())
        .traffic(congestion.then(TrafficSpec::uniform))
        .job(JobBuilder::new(algo).hosts(hosts).data_bytes(o.scale.data_bytes()))
}

fn finish(s: Series, o: &Opts) -> Series {
    s.print();
    match s.write_csv(&o.out) {
        Ok(p) => println!("wrote {p}\n"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    s
}

/// Fig. 2 — goodput at 1 % and 75 % of hosts, +/- congestion.
pub fn fig2(o: &Opts) -> Series {
    let mut s = Series::new(
        "fig2_goodput_small_vs_large",
        &["hosts_pct", "algo", "congestion", "goodput_gbps", "stddev"],
    );
    for &pct in &[1u32, 75] {
        let hosts = o.scaled_hosts(pct);
        for algo in algo_list(true, &[1]) {
            for &cong in &[false, true] {
                let sc = base_scenario(o, algo, hosts, cong);
                let g = goodputs(&sc, o.seeds);
                s.push(vec![
                    pct.to_string(),
                    algo.name(),
                    cong.to_string(),
                    format!("{:.1}", mean(&g)),
                    format!("{:.1}", stddev(&g)),
                ]);
            }
        }
    }
    finish(s, o)
}

/// Fig. 6 — single-switch goodput vs payload size (P4 calibration).
/// The "prototype" column is the line-rate bound 100G * payload/wire that
/// the Tofino achieves (the paper's Fig. 6 shows both at that bound).
pub fn fig6(o: &Opts) -> Series {
    let mut s = Series::new(
        "fig6_single_switch_goodput",
        &["payload_bytes", "prototype_bound_gbps", "sim_gbps"],
    );
    for &payload in &[128u32, 256, 512, 1024] {
        let wire =
            payload + crate::sim::packet::HEADER_OVERHEAD_BYTES;
        let bound = 100.0 * payload as f64 / wire as f64;
        let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
            .sim(SimConfig::default().with_payload(payload))
            .job(
                JobBuilder::new(Algo::Canary).hosts(2).data_bytes(4 << 20),
            );
        let g = goodputs(&sc, 1);
        s.push(vec![
            payload.to_string(),
            format!("{bound:.1}"),
            format!("{:.1}", g[0]),
        ]);
    }
    finish(s, o)
}

/// Fig. 7a — goodput with 512 hosts vs number of static trees.
pub fn fig7a(o: &Opts) -> Series {
    let mut s = Series::new(
        "fig7a_goodput_vs_trees",
        &["algo", "congestion", "goodput_gbps", "stddev"],
    );
    let hosts = o.scaled_hosts(50);
    for algo in algo_list(false, &[1, 2, 4, 8]) {
        for &cong in &[false, true] {
            let sc = base_scenario(o, algo, hosts, cong);
            let g = goodputs(&sc, o.seeds);
            s.push(vec![
                algo.name(),
                cong.to_string(),
                format!("{:.1}", mean(&g)),
                format!("{:.1}", stddev(&g)),
            ]);
        }
    }
    finish(s, o)
}

/// Fig. 7b — link-utilization distribution (10 % buckets) + the quoted
/// average network utilization, with congestion.
pub fn fig7b(o: &Opts) -> Series {
    let mut s = Series::new(
        "fig7b_link_utilization",
        &["algo", "bucket_mid_pct", "fraction", "avg_util_pct"],
    );
    let hosts = o.scaled_hosts(50);
    for algo in algo_list(false, &[1, 4]) {
        let sc = base_scenario(o, algo, hosts, true);
        let mut exp = sc.build(1000);
        runner::run_to_completion(&mut exp.net, u64::MAX);
        let end = exp.net.now;
        let h = utilization_histogram(&exp.net, end);
        let avg = 100.0 * average_network_utilization(&exp.net, end);
        for (i, f) in h.fractions().iter().enumerate() {
            s.push(vec![
                algo.name(),
                format!("{:.0}", 100.0 * h.bucket_mid(i)),
                format!("{f:.3}"),
                format!("{avg:.1}"),
            ]);
        }
    }
    finish(s, o)
}

/// Fig. 8 — goodput vs fraction of hosts running the allreduce.
pub fn fig8(o: &Opts) -> Series {
    let mut s = Series::new(
        "fig8_goodput_vs_hosts",
        &["hosts_pct", "algo", "goodput_gbps", "stddev"],
    );
    for &pct in &[5u32, 10, 20, 35, 50, 75] {
        let hosts = o.scaled_hosts(pct);
        for algo in algo_list(true, &[1, 4]) {
            let sc = base_scenario(o, algo, hosts, true);
            let g = goodputs(&sc, o.seeds);
            s.push(vec![
                pct.to_string(),
                algo.name(),
                format!("{:.1}", mean(&g)),
                format!("{:.1}", stddev(&g)),
            ]);
        }
    }
    finish(s, o)
}

/// Fig. 9 — runtime vs message size, 20 % hosts, +/- congestion.
pub fn fig9(o: &Opts) -> Series {
    let mut s = Series::new(
        "fig9_runtime_vs_size",
        &["size_bytes", "algo", "congestion", "runtime_us", "stddev"],
    );
    let hosts = o.scaled_hosts(20);
    let sizes: &[u64] = match o.scale {
        Scale::Ci => &[1 << 10, 64 << 10, 1 << 20],
        _ => &[1 << 10, 16 << 10, 256 << 10, 4 << 20, 16 << 20],
    };
    for &size in sizes {
        for algo in algo_list(true, &[4]) {
            for &cong in &[false, true] {
                let sc = ScenarioBuilder::new(o.scale.topo())
                    .traffic(cong.then(TrafficSpec::uniform))
                    .job(JobBuilder::new(algo).hosts(hosts).data_bytes(size));
                let r = runtimes_us(&sc, o.seeds);
                s.push(vec![
                    size.to_string(),
                    algo.name(),
                    cong.to_string(),
                    format!("{:.1}", mean(&r)),
                    format!("{:.1}", stddev(&r)),
                ]);
            }
        }
    }
    finish(s, o)
}

/// The Fig. 10 multi-tenant scenario: `n_jobs` equal concurrent
/// allreduces partitioning the cluster, all of the same `algo`.
fn multi_tenant(o: &Opts, algo: Algo, n_jobs: u32) -> ScenarioBuilder {
    let topo = o.scale.topo();
    let per_job = (topo.n_hosts() / n_jobs).max(1);
    ScenarioBuilder::new(topo).jobs(
        n_jobs,
        JobBuilder::new(algo)
            .hosts(per_job)
            .data_bytes(o.scale.data_bytes()),
    )
}

/// Fig. 10a — average goodput of N concurrent allreduces.
pub fn fig10a(o: &Opts) -> Series {
    let mut s = Series::new(
        "fig10a_concurrent_allreduces",
        &["n_jobs", "algo", "avg_goodput_gbps", "stddev"],
    );
    let jobs_list: &[u32] = match o.scale {
        Scale::Ci => &[1, 2, 4],
        _ => &[1, 2, 4, 8, 16, 32],
    };
    for &n_jobs in jobs_list {
        for algo in algo_list(true, &[1, 4]) {
            let sc = multi_tenant(o, algo, n_jobs);
            let per_seed = par_map(o.seeds as usize, |seed| {
                let mut exp = sc.build(2000 + seed as u64);
                let results =
                    runner::run_to_completion(&mut exp.net, u64::MAX);
                let gs: Vec<f64> = results
                    .iter()
                    .filter_map(|r| r.goodput_gbps)
                    .collect();
                mean(&gs)
            });
            s.push(vec![
                n_jobs.to_string(),
                algo.name(),
                format!("{:.1}", mean(&per_seed)),
                format!("{:.1}", stddev(&per_seed)),
            ]);
        }
    }
    finish(s, o)
}

/// Fig. 10b — link utilization with 20 concurrent allreduces.
pub fn fig10b(o: &Opts) -> Series {
    let mut s = Series::new(
        "fig10b_link_utilization_20jobs",
        &["algo", "bucket_mid_pct", "fraction", "avg_util_pct"],
    );
    let n_jobs = match o.scale {
        Scale::Ci => 4,
        _ => 20,
    };
    for algo in algo_list(false, &[1, 4]) {
        let mut exp = multi_tenant(o, algo, n_jobs).build(2000);
        runner::run_to_completion(&mut exp.net, u64::MAX);
        let end = exp.net.now;
        let h = utilization_histogram(&exp.net, end);
        let avg = 100.0 * average_network_utilization(&exp.net, end);
        for (i, f) in h.fractions().iter().enumerate() {
            s.push(vec![
                algo.name(),
                format!("{:.0}", 100.0 * h.bucket_mid(i)),
                format!("{f:.3}"),
                format!("{avg:.1}"),
            ]);
        }
    }
    finish(s, o)
}

/// Fig. 11 — goodput vs noise probability x timeout, +/- congestion.
pub fn fig11(o: &Opts) -> Series {
    let mut s = Series::new(
        "fig11_noise_and_timeout",
        &[
            "noise_pct",
            "timeout_us",
            "algo",
            "congestion",
            "goodput_gbps",
        ],
    );
    let hosts = o.scaled_hosts(50);
    for &noise in &[0.0001f64, 0.001, 0.01, 0.1] {
        for &cong in &[false, true] {
            for &timeout_us in &[1u64, 2, 3] {
                let sc = base_scenario(o, Algo::Canary, hosts, cong).sim(
                    SimConfig::default()
                        .with_timeout(timeout_us * US)
                        .with_noise(noise, US),
                );
                let g = goodputs(&sc, o.seeds.min(2));
                s.push(vec![
                    format!("{}", noise * 100.0),
                    timeout_us.to_string(),
                    "canary".into(),
                    cong.to_string(),
                    format!("{:.1}", mean(&g)),
                ]);
            }
            // static-4 comparison point (timeout not applicable)
            let sc = base_scenario(
                o,
                Algo::StaticTree { n_trees: 4 },
                hosts,
                cong,
            )
            .sim(SimConfig::default().with_noise(noise, US));
            let g = goodputs(&sc, o.seeds.min(2));
            s.push(vec![
                format!("{}", noise * 100.0),
                "-".into(),
                "static4".into(),
                cong.to_string(),
                format!("{:.1}", mean(&g)),
            ]);
        }
    }
    finish(s, o)
}

/// §3.2.2 — switch memory model vs measured descriptor residency.
pub fn mem(o: &Opts) -> Series {
    let mut s = Series::new(
        "mem_model_vs_measured",
        &[
            "timeout_us",
            "model_kib",
            "measured_peak_descriptors",
            "measured_peak_kib",
            "mean_residency_us",
        ],
    );
    for &timeout_us in &[1u64, 2, 4] {
        let model = memory_model_bytes(
            12.5e9,
            5,
            300e-9,
            timeout_us as f64 * 1e-6,
            1e-6,
        ) / 1024.0;
        let sc = base_scenario(o, Algo::Canary, o.scaled_hosts(50), false)
            .sim(SimConfig::default().with_timeout(timeout_us * US));
        let mut exp = sc.build(3000);
        runner::run_to_completion(&mut exp.net, u64::MAX);
        let m = &exp.net.metrics;
        let peak = m.descriptor_high_water;
        let desc_bytes = sc.sim.payload_bytes as u64 + 64;
        let freed = m.descriptors_freed.max(1);
        s.push(vec![
            timeout_us.to_string(),
            format!("{model:.0}"),
            peak.to_string(),
            format!("{:.0}", (peak * desc_bytes) as f64 / 1024.0),
            format!(
                "{:.1}",
                ps_to_us(m.descriptor_residency_ps / freed)
            ),
        ]);
    }
    finish(s, o)
}

/// Beyond-paper scale-up (DESIGN.md §4/§5): the congestion-aware vs
/// static-tree comparison on a 3-tier pod Clos, sweeping the fabric's
/// oversubscription ratio. On a tapered fabric the fixed trees funnel
/// through scarcer core links, so congestion awareness matters more —
/// this is the regime Flare/SOAR identify as the scaling frontier.
pub fn clos3(o: &Opts) -> Series {
    let mut s = Series::new(
        "clos3_multitier_goodput",
        &["oversub", "algo", "congestion", "goodput_gbps", "stddev"],
    );
    for &(num, den) in &[(1u32, 1u32), (2, 1), (4, 1)] {
        let topo = o.scale.topo3().with_oversub(num, den);
        let hosts = (topo.n_hosts() / 2).max(2);
        // only tree counts the fabric can root on distinct switches —
        // a heavily tapered CI-scale core may have a single spine, and
        // a "static4" label on a one-tree run would be a lie
        let trees: Vec<u8> = [1u8, 4]
            .into_iter()
            .filter(|&n| n as u32 <= topo.n_spine())
            .collect();
        for algo in algo_list(true, &trees) {
            for &cong in &[false, true] {
                let sc = ScenarioBuilder::new(topo)
                    .traffic(cong.then(TrafficSpec::uniform))
                    .job(
                        JobBuilder::new(algo)
                            .hosts(hosts)
                            .data_bytes(o.scale.data_bytes()),
                    );
                let g = goodputs(&sc, o.seeds);
                s.push(vec![
                    format!("{num}:{den}"),
                    algo.name(),
                    cong.to_string(),
                    format!("{:.1}", mean(&g)),
                    format!("{:.1}", stddev(&g)),
                ]);
            }
        }
    }
    finish(s, o)
}

/// Traffic-pattern sweep (DESIGN.md §5, beyond-paper): Canary vs static
/// trees vs ring under every traffic-engine pattern at three load
/// points, on both the 2-tier paper fabric and the oversubscribed
/// 3-tier pod Clos. Each cell reports allreduce goodput plus the
/// background flows' completion-time percentiles — congestion awareness
/// should win more as the pattern skews (incast/hotspot) and the FCT
/// tail shows what that victory costs the cross traffic.
pub fn traffic(o: &Opts) -> Series {
    let mut s = Series::new(
        "traffic_patterns",
        &[
            "topo",
            "pattern",
            "load",
            "algo",
            "goodput_gbps",
            "goodput_stddev",
            "fct_p50_us",
            "fct_p99_us",
            "flows_completed_pct",
        ],
    );
    let (fan_in, hot_k) = match o.scale {
        Scale::Ci => (8, 4),
        _ => (32, 16),
    };
    let patterns = [
        TrafficSpec::uniform(),
        TrafficSpec::permutation(),
        TrafficSpec::incast(fan_in),
        TrafficSpec::hotspot(hot_k, 0.9),
        TrafficSpec::empirical(),
    ];
    let loads = [0.3f64, 0.6, 1.0];

    struct Cell {
        topo_name: &'static str,
        topo: ClosConfig,
        spec: TrafficSpec,
        algo: Algo,
    }
    let mut cells = Vec::new();
    for (topo_name, topo) in
        [("clos2", o.scale.topo()), ("clos3", o.scale.topo3())]
    {
        // as in clos3: only tree counts the fabric can root on
        // distinct switches
        let trees: Vec<u8> = [1u8, 4]
            .into_iter()
            .filter(|&n| n as u32 <= topo.n_spine())
            .collect();
        for pattern in &patterns {
            for &load in &loads {
                for algo in algo_list(true, &trees) {
                    cells.push(Cell {
                        topo_name,
                        topo,
                        spec: pattern.with_load(load),
                        algo,
                    });
                }
            }
        }
    }

    let seeds = o.seeds.max(1);
    let results = par_map(cells.len(), |i| {
        let c = &cells[i];
        let hosts = (c.topo.n_hosts() / 2).max(2);
        let mut gs = Vec::new();
        let mut fct_us: Vec<f64> = Vec::new();
        let (mut started, mut completed) = (0u64, 0u64);
        for seed in 0..seeds {
            let sc = ScenarioBuilder::new(c.topo)
                .traffic(Some(c.spec))
                .job(
                    JobBuilder::new(c.algo)
                        .hosts(hosts)
                        .data_bytes(o.scale.data_bytes()),
                );
            let mut exp = sc.build(4000 + seed);
            let r = runner::run_to_completion(&mut exp.net, u64::MAX);
            gs.push(r[0].goodput_gbps.unwrap_or(0.0));
            let f = &exp.net.metrics.flows;
            started += f.started;
            completed += f.completed;
            fct_us.extend(f.fct_ps.iter().map(|&p| ps_to_us(p)));
        }
        // sort in the worker: both quantiles read the sorted buffer
        fct_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (gs, fct_us, started, completed)
    });

    for (c, (gs, fct_us, started, completed)) in
        cells.iter().zip(results)
    {
        let completed_pct = if started == 0 {
            0.0
        } else {
            100.0 * completed as f64 / started as f64
        };
        s.push(vec![
            c.topo_name.to_string(),
            c.spec.name(),
            format!("{:.2}", c.spec.load),
            c.algo.name(),
            format!("{:.1}", mean(&gs)),
            format!("{:.1}", stddev(&gs)),
            format!("{:.1}", percentile_sorted(&fct_us, 50.0)),
            format!("{:.1}", percentile_sorted(&fct_us, 99.0)),
            format!("{completed_pct:.1}"),
        ]);
    }
    finish(s, o)
}

/// Reactive-transport sweep (DESIGN.md §2.4, beyond-paper): reactive vs
/// unreactive cross traffic under incast overload, for every engine on
/// the 2-tier paper fabric and the oversubscribed 3-tier pod Clos. The
/// unreactive (`none`) column is the paper's worst-case congestion:
/// senders never back off and policer-dropped flows die silently. The
/// DCQCN/Swift columns answer the question the paper leaves open — does
/// congestion-aware aggregation still win when the competing traffic is
/// transport-governed and backs off on its own? Each cell reports the
/// reduction goodput plus what the transport did for the cross traffic
/// (completion fraction, FCT tail, marks/CNPs/retransmits).
pub fn transport(o: &Opts) -> Series {
    let mut s = Series::new(
        "transport_reactive_cross_traffic",
        &[
            "topo",
            "transport",
            "algo",
            "goodput_gbps",
            "goodput_stddev",
            "flows_completed_pct",
            "fct_p50_us",
            "fct_p99_us",
            "ecn_marks",
            "cnps",
            "retrans_pkts",
        ],
    );
    let fan_in = match o.scale {
        Scale::Ci => 8,
        _ => 32,
    };
    let transports = [
        TransportSpec::None,
        TransportSpec::Dcqcn,
        TransportSpec::Swift,
    ];

    struct Cell {
        topo_name: &'static str,
        topo: ClosConfig,
        tp: TransportSpec,
        algo: Algo,
    }
    let mut cells = Vec::new();
    for (topo_name, topo) in
        [("clos2", o.scale.topo()), ("clos3", o.scale.topo3())]
    {
        for &tp in &transports {
            for algo in algo_list(true, &[1]) {
                cells.push(Cell {
                    topo_name,
                    topo,
                    tp,
                    algo,
                });
            }
        }
    }

    let seeds = o.seeds.max(1);
    let results = par_map(cells.len(), |i| {
        let c = &cells[i];
        let hosts = (c.topo.n_hosts() / 2).max(2);
        let spec = TrafficSpec::incast(fan_in).with_transport(c.tp);
        let mut gs = Vec::new();
        let mut fct_us: Vec<f64> = Vec::new();
        let (mut started, mut completed) = (0u64, 0u64);
        let (mut marks, mut cnps, mut retrans) = (0u64, 0u64, 0u64);
        for seed in 0..seeds {
            let sc = ScenarioBuilder::new(c.topo).traffic(Some(spec)).job(
                JobBuilder::new(c.algo)
                    .hosts(hosts)
                    .data_bytes(o.scale.data_bytes()),
            );
            let mut exp = sc.build(5000 + seed);
            let r = runner::run_to_completion(&mut exp.net, u64::MAX);
            gs.push(r[0].goodput_gbps.unwrap_or(0.0));
            let m = &exp.net.metrics;
            started += m.flows.started;
            completed += m.flows.completed;
            marks += m.ecn_marks;
            cnps += m.flows.cnps_received;
            retrans += m.flows.retrans_pkts;
            fct_us.extend(m.flows.fct_ps.iter().map(|&p| ps_to_us(p)));
        }
        fct_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (gs, fct_us, started, completed, marks, cnps, retrans)
    });

    for (c, (gs, fct_us, started, completed, marks, cnps, retrans)) in
        cells.iter().zip(results)
    {
        let completed_pct = if started == 0 {
            0.0
        } else {
            100.0 * completed as f64 / started as f64
        };
        s.push(vec![
            c.topo_name.to_string(),
            c.tp.name().to_string(),
            c.algo.name(),
            format!("{:.1}", mean(&gs)),
            format!("{:.1}", stddev(&gs)),
            format!("{completed_pct:.1}"),
            format!("{:.1}", percentile_sorted(&fct_us, 50.0)),
            format!("{:.1}", percentile_sorted(&fct_us, 99.0)),
            marks.to_string(),
            cnps.to_string(),
            retrans.to_string(),
        ]);
    }
    finish(s, o)
}

/// Placement-locality sweep (beyond-paper, new with the Collective API):
/// random vs clustered-by-leaf vs striped placement for Canary, the
/// static trees and the ring, with and without uniform cross traffic.
/// Clustering keeps reduction traffic under few leaves (little for
/// congestion awareness to dodge); striping forces every block across
/// the spine where the static trees' fixed paths collide with the cross
/// traffic — the congestion-awareness gap should widen from clustered
/// to random to striped.
pub fn placement(o: &Opts) -> Series {
    let mut s = Series::new(
        "placement_locality",
        &["placement", "algo", "congestion", "goodput_gbps", "stddev"],
    );
    let hosts = o.scaled_hosts(50);
    let policies = [
        Placement::RandomUniform,
        Placement::ClusteredByLeaf,
        Placement::Striped,
    ];

    struct Cell {
        policy: Placement,
        algo: Algo,
        cong: bool,
    }
    let mut cells = Vec::new();
    for policy in &policies {
        for algo in algo_list(true, &[1, 4]) {
            for &cong in &[false, true] {
                cells.push(Cell {
                    policy: policy.clone(),
                    algo,
                    cong,
                });
            }
        }
    }
    let seeds = o.seeds.max(1);
    // one worker per cell; seeds run serially inside (as in `traffic`)
    // so the fan-out is never nested
    let results = par_map(cells.len(), |i| {
        let c = &cells[i];
        let sc = ScenarioBuilder::new(o.scale.topo())
            .traffic(c.cong.then(TrafficSpec::uniform))
            .job(
                JobBuilder::new(c.algo)
                    .hosts(hosts)
                    .data_bytes(o.scale.data_bytes())
                    .placement(c.policy.clone()),
            );
        (0..seeds)
            .map(|s| {
                let mut exp = sc.build(1000 + s);
                let r = runner::run_to_completion(&mut exp.net, u64::MAX);
                r[0].goodput_gbps.unwrap_or(0.0)
            })
            .collect::<Vec<f64>>()
    });
    for (c, g) in cells.iter().zip(results) {
        s.push(vec![
            c.policy.name(),
            c.algo.name(),
            c.cong.to_string(),
            format!("{:.1}", mean(&g)),
            format!("{:.1}", stddev(&g)),
        ]);
    }
    finish(s, o)
}

/// Weak-scaling engine sweep (DESIGN.md §2.5, EXPERIMENTS.md §Scale):
/// 64 → 4096 hosts across 2- and 3-tier Clos fabrics, ring vs static
/// trees vs Canary, ± uniform cross traffic at 50 % load, with fixed
/// per-host data so total work grows with the fabric. Each cell
/// reports the usual goodput/runtime *plus* the engine-throughput
/// numbers the scheduler+arena rewrite is accountable for: events
/// dispatched, events/sec, peak live packets and the arena high-water
/// mark. Alongside the CSV it writes `BENCH_scale.json` — the recorded
/// point of the perf trajectory that `scripts/check_bench.py` gates CI
/// on. The gated headline events/sec comes from a *serial* re-run of
/// the largest Canary cell (the sweep itself fans cells over worker
/// threads, which is right for wall time but makes per-cell events/sec
/// contention-noisy).
///
/// Coverage note (no silent caps): the host-based ring is excluded
/// from the cross-traffic column on the 4096-host rung only — its
/// 2(N−1)-step serial dependency makes that cell latency-bound
/// (~8 ms of simulated time), so line-rate cross traffic would pour
/// ~10⁹ events into a cell that measures the fabric, not the engine.
/// The exclusion is visible in the series (no row), not papered over.
pub fn scale(o: &Opts) -> Series {
    let mut s = Series::new(
        "scale_weak_sweep",
        &[
            "hosts",
            "tiers",
            "algo",
            "cross",
            "shards",
            "events",
            "events_per_sec_m",
            "peak_live_pkts",
            "arena_slots",
            "runtime_us",
            "goodput_gbps",
        ],
    );
    // the ladder: every rung that fits the 64-port radix bound on each
    // tier count (4096 hosts only exist as a 3-tier fabric)
    let shapes: Vec<ClosConfig> = vec![
        ClosConfig::small(),                    // 64 hosts, 2-tier
        ClosConfig::small3(),                   // 64 hosts, 3-tier
        ClosConfig::two_tier(16, 16, 16),       // 256 hosts, 2-tier
        ClosConfig::three_tier(8, 8, 4, 4, 4),  // 256 hosts, 3-tier
        ClosConfig::paper(),                    // 1024 hosts, 2-tier
        ClosConfig::paper3(),                   // 1024 hosts, 3-tier
        ClosConfig::huge3(),                    // 4096 hosts, 3-tier
    ];
    let data_bytes = o.scale.scale_sweep_bytes();
    let cross_spec = TrafficSpec::uniform().with_load(0.5);

    struct Cell {
        topo: ClosConfig,
        algo: Algo,
        cross: bool,
    }
    let mut cells = Vec::new();
    for &topo in &shapes {
        // static4 wherever the fabric can root 4 distinct trees (every
        // ladder rung can; tiny fabrics would degrade to static1)
        let trees: Vec<u8> =
            if topo.n_spine() >= 4 { vec![4] } else { vec![1] };
        for algo in algo_list(true, &trees) {
            for &cross in &[false, true] {
                if cross && algo == Algo::Ring && topo.n_hosts() >= 4096 {
                    continue; // latency-bound cell; see the doc note
                }
                cells.push(Cell { topo, algo, cross });
            }
        }
    }

    let run_cell = |topo: ClosConfig, algo: Algo, cross: bool| {
        let sc = ScenarioBuilder::new(topo)
            .traffic(cross.then_some(cross_spec))
            .job(
                JobBuilder::new(algo)
                    .hosts((topo.n_hosts() / 2).max(2))
                    .data_bytes(data_bytes),
            );
        let mut exp = sc.build(6000);
        let r = runner::run_to_completion(&mut exp.net, u64::MAX);
        (
            exp.net.metrics.engine.clone(),
            r[0].runtime_ps,
            r[0].goodput_gbps,
        )
    };

    let results = par_map(cells.len(), |i| {
        let c = &cells[i];
        run_cell(c.topo, c.algo, c.cross)
    });

    let mut cell_values = Vec::new();
    for (c, (engine, runtime_ps, goodput)) in cells.iter().zip(&results) {
        s.push(vec![
            c.topo.n_hosts().to_string(),
            c.topo.tiers.to_string(),
            c.algo.name(),
            c.cross.to_string(),
            "0".into(), // serial engine (cfg.shards == 0)
            engine.events.to_string(),
            format!("{:.2}", engine.events_per_sec() / 1e6),
            engine.peak_live_packets.to_string(),
            engine.arena_slots.to_string(),
            format!(
                "{:.1}",
                runtime_ps.map(ps_to_us).unwrap_or(f64::NAN)
            ),
            format!("{:.1}", goodput.unwrap_or(0.0)),
        ]);
        cell_values.push(obj(vec![
            ("hosts", Value::Int(c.topo.n_hosts() as i64)),
            ("tiers", Value::Int(c.topo.tiers as i64)),
            ("algo", Value::Str(c.algo.name())),
            ("cross", Value::Bool(c.cross)),
            ("shards", Value::Int(0)),
            ("events", Value::Int(engine.events as i64)),
            ("events_per_sec", Value::Float(engine.events_per_sec())),
            (
                "peak_live_pkts",
                Value::Int(engine.peak_live_packets as i64),
            ),
            ("arena_slots", Value::Int(engine.arena_slots as i64)),
        ]));
    }

    // sharded-engine rungs: >=32k-host fabrics swept across a shards
    // axis (DESIGN.md §2.10). These run one at a time — never under
    // par_map — because each sharded run owns the machine's cores;
    // timing them concurrently would measure scheduler contention,
    // not the engine. `shards == 0` rows above are the serial engine;
    // the `shards == 1` rung here exercises the PDES split/merge path
    // with one worker (bit-identical fingerprint to serial, pinned by
    // tests/pdes.rs) so the two columns are directly comparable.
    let shard_shapes: Vec<ClosConfig> = match o.scale {
        // 32768 hosts (3-tier) always; the 131072-host 4-tier fabric
        // only at full scale, where minutes of wall time are expected
        Scale::Full => {
            vec![ClosConfig::giant3(), ClosConfig::colossal4()]
        }
        _ => vec![ClosConfig::giant3()],
    };
    let shard_axis: &[u32] = match o.scale {
        Scale::Full => &[1, 2, 4, 8],
        Scale::Half | Scale::Ci => &[1, 4],
    };
    // per-host payload shrinks with scale so the CI cell stays a
    // smoke test (one block per host) while full remains a real bench
    let shard_bytes: u64 = match o.scale {
        Scale::Full => 64 << 10,
        Scale::Half => 16 << 10,
        Scale::Ci => 1 << 10,
    };
    for &topo in &shard_shapes {
        for &n_shards in shard_axis {
            let sc = ScenarioBuilder::new(topo)
                .sim(SimConfig::default().with_shards(n_shards))
                .job(
                    JobBuilder::new(Algo::Canary)
                        .hosts((topo.n_hosts() / 2).max(2))
                        .data_bytes(shard_bytes),
                );
            let mut exp = sc.build(6000);
            let r = runner::run_to_completion(&mut exp.net, u64::MAX);
            let engine = exp.net.metrics.engine.clone();
            s.push(vec![
                topo.n_hosts().to_string(),
                topo.tiers.to_string(),
                Algo::Canary.name(),
                "false".into(),
                n_shards.to_string(),
                engine.events.to_string(),
                format!("{:.2}", engine.events_per_sec() / 1e6),
                engine.peak_live_packets.to_string(),
                engine.arena_slots.to_string(),
                format!(
                    "{:.1}",
                    r[0].runtime_ps.map(ps_to_us).unwrap_or(f64::NAN)
                ),
                format!("{:.1}", r[0].goodput_gbps.unwrap_or(0.0)),
            ]);
            cell_values.push(obj(vec![
                ("hosts", Value::Int(topo.n_hosts() as i64)),
                ("tiers", Value::Int(topo.tiers as i64)),
                ("algo", Value::Str(Algo::Canary.name())),
                ("cross", Value::Bool(false)),
                ("shards", Value::Int(n_shards as i64)),
                ("events", Value::Int(engine.events as i64)),
                (
                    "events_per_sec",
                    Value::Float(engine.events_per_sec()),
                ),
                (
                    "peak_live_pkts",
                    Value::Int(engine.peak_live_packets as i64),
                ),
                ("arena_slots", Value::Int(engine.arena_slots as i64)),
            ]));
        }
    }

    // headline: the biggest Canary cell under cross traffic, re-run
    // serially so events/sec is free of worker-thread contention —
    // this is the number check_bench.py gates against its baseline
    let head_topo = *shapes.last().expect("ladder is non-empty");
    let (head, _, _) = run_cell(head_topo, Algo::Canary, true);
    println!(
        "scale headline (canary, {} hosts, cross): \
         {:.2} M events/s ({} events in {:.3}s)",
        head_topo.n_hosts(),
        head.events_per_sec() / 1e6,
        head.events,
        head.wall_secs,
    );

    let entry = obj(vec![
        ("bench", Value::Str("scale_weak_sweep".into())),
        ("scale", Value::Str(o.scale.name().into())),
        (
            "headline_cell",
            Value::Str(format!(
                "canary_{}hosts_{}tier_cross",
                head_topo.n_hosts(),
                head_topo.tiers
            )),
        ),
        ("headline_events", Value::Int(head.events as i64)),
        ("headline_seconds", Value::Float(head.wall_secs)),
        ("events_per_sec", Value::Float(head.events_per_sec())),
        ("cells", Value::Array(cell_values)),
    ]);
    let path = format!("{}/BENCH_scale.json", o.out);
    let _ = std::fs::create_dir_all(&o.out);
    match std::fs::write(&path, entry.to_json()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("{path} write failed: {e}"),
    }
    finish(s, o)
}

/// Churn timeout-sensitivity sweep (DESIGN.md §2.6, EXPERIMENTS.md
/// §Churn): aggregation timeout x fault level x engine on the 2- and
/// 3-tier fabrics. Each fault level flaps that many distinct
/// leaf-uplinks mid-operation (staggered, 25 us windows); per cell we
/// report completion %, mean goodput of the completed seeds, and
/// recovery time (runtime minus the same engine's fault-free baseline
/// at the same seed) at p50/p95 — written to `BENCH_churn.json` for
/// the bench harness. Canary sweeps its aggregation timeout; static
/// tree and ring run their documented degradation semantics (stall
/// when the failed path is load-bearing — `completed == false`).
pub fn churn(o: &Opts) -> Series {
    let mut s = Series::new(
        "churn_timeout_sensitivity",
        &[
            "topo",
            "algo",
            "timeout_us",
            "flaps",
            "completion_pct",
            "goodput_gbps",
            "recovery_p50_us",
            "recovery_p95_us",
            "partial_aggs",
            "dead_drops",
        ],
    );
    let data_bytes = o.scale.scale_sweep_bytes();
    // Canary sweeps the aggregation timeout; static/ring have none
    // (timeout_us = 0 in the output marks "not applicable")
    struct Engine {
        algo: Algo,
        timeout_us: u64,
    }
    let engines = [
        Engine { algo: Algo::Canary, timeout_us: 1 },
        Engine { algo: Algo::Canary, timeout_us: 4 },
        Engine { algo: Algo::Canary, timeout_us: 16 },
        Engine { algo: Algo::StaticTree { n_trees: 1 }, timeout_us: 0 },
        Engine { algo: Algo::Ring, timeout_us: 0 },
    ];
    const FLAP_LEVELS: [u32; 3] = [0, 1, 3];

    #[derive(Clone, Copy)]
    struct Cell {
        label: &'static str,
        topo: ClosConfig,
        algo: Algo,
        timeout_us: u64,
        flaps: u32,
    }
    let topos: [(&'static str, ClosConfig); 2] =
        [("clos2", o.scale.topo()), ("clos3", o.scale.topo3())];
    let mut cells = Vec::new();
    for &(label, topo) in &topos {
        for e in &engines {
            for &flaps in &FLAP_LEVELS {
                cells.push(Cell {
                    label,
                    topo,
                    algo: e.algo,
                    timeout_us: e.timeout_us,
                    flaps,
                });
            }
        }
    }

    // flap `n` distinct leaf-uplinks: leaf i <-> its first tier-2
    // parent, down at (5 + 10i) us for 25 us — mid-operation for every
    // scale's data size
    let flap_spec = |topo: ClosConfig, n: u32| {
        let ft = Clos { cfg: topo };
        let mut f = FaultSpec::default();
        for i in 0..n.min(topo.tier_size(1)) {
            let leaf = ft.switch_id(1, i);
            let parent = ft.switch_id(2, ft.parent_index(1, i, 0));
            let down = (5 + 10 * i as u64) * US;
            f = f.with_link_flap(leaf, parent, down, down + 25 * US);
        }
        f
    };

    let seeds = o.seeds.max(1) as usize;
    // generous bound: stalled runs end when their event queue drains,
    // the bound only caps pathological livelock
    let max_t = 1_000_000 * US;
    let results = par_map(cells.len() * seeds, |i| {
        let c = &cells[i / seeds];
        let seed = 1000 + (i % seeds) as u64;
        let mut sim = SimConfig::default();
        if c.algo == Algo::Canary {
            // leader-driven loss recovery on; sweep the aggregation
            // timeout
            sim = sim
                .with_timeout(c.timeout_us * US)
                .with_retrans(200 * US, true);
        }
        let sc = ScenarioBuilder::new(c.topo)
            .sim(sim)
            .faults(flap_spec(c.topo, c.flaps))
            .job(
                JobBuilder::new(c.algo)
                    .hosts((c.topo.n_hosts() / 2).max(2))
                    .data_bytes(data_bytes),
            );
        let mut exp = sc.build(seed);
        let r = runner::run_to_completion(&mut exp.net, max_t);
        (
            r[0].completed,
            r[0].runtime_ps,
            r[0].goodput_gbps,
            exp.net.metrics.partial_aggregates,
            exp.net.metrics.drops_link_down,
        )
    });

    let mut cell_values = Vec::new();
    for (ci, c) in cells.iter().enumerate() {
        let rs = &results[ci * seeds..(ci + 1) * seeds];
        // fault-free baseline of the same engine cell: FLAP_LEVELS
        // starts with 0 and is the innermost loop, so the baseline is
        // `flap_pos` cells back
        let flap_pos = FLAP_LEVELS
            .iter()
            .position(|&f| f == c.flaps)
            .expect("cell flap level not in FLAP_LEVELS");
        let base = &results[(ci - flap_pos) * seeds..(ci - flap_pos + 1) * seeds];
        let recovery_us: Vec<f64> = rs
            .iter()
            .zip(base)
            .filter_map(|(r, b)| match (r.1, b.1) {
                (Some(rt), Some(bt)) => {
                    Some(ps_to_us(rt.saturating_sub(bt)))
                }
                _ => None,
            })
            .collect();
        let completed = rs.iter().filter(|r| r.0).count();
        let completion_pct = 100.0 * completed as f64 / seeds as f64;
        let goodput: Vec<f64> =
            rs.iter().filter_map(|r| r.2).collect();
        let partials: u64 = rs.iter().map(|r| r.3).sum();
        let dead_drops: u64 = rs.iter().map(|r| r.4).sum();
        // quantiles via the one shared implementation (util::stats)
        let p50 = percentile(&recovery_us, 50.0);
        let p95 = percentile(&recovery_us, 95.0);
        s.push(vec![
            c.label.to_string(),
            c.algo.name(),
            c.timeout_us.to_string(),
            c.flaps.to_string(),
            format!("{completion_pct:.0}"),
            format!("{:.1}", mean(&goodput)),
            format!("{p50:.1}"),
            format!("{p95:.1}"),
            partials.to_string(),
            dead_drops.to_string(),
        ]);
        cell_values.push(obj(vec![
            ("topo", Value::Str(c.label.into())),
            ("algo", Value::Str(c.algo.name())),
            ("timeout_us", Value::Int(c.timeout_us as i64)),
            ("flaps", Value::Int(c.flaps as i64)),
            ("completion_pct", Value::Float(completion_pct)),
            ("goodput_gbps", Value::Float(mean(&goodput))),
            ("recovery_p50_us", Value::Float(p50)),
            ("recovery_p95_us", Value::Float(p95)),
            ("partial_aggregates", Value::Int(partials as i64)),
            ("drops_link_down", Value::Int(dead_drops as i64)),
        ]));
    }

    let entry = obj(vec![
        ("bench", Value::Str("churn_sweep".into())),
        ("scale", Value::Str(o.scale.name().into())),
        ("seeds", Value::Int(seeds as i64)),
        ("cells", Value::Array(cell_values)),
    ]);
    let path = format!("{}/BENCH_churn.json", o.out);
    let _ = std::fs::create_dir_all(&o.out);
    match std::fs::write(&path, entry.to_json()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("{path} write failed: {e}"),
    }
    finish(s, o)
}

/// Telemetry demo: one traced tiny3 churn run (leaf-uplink flap plus a
/// 16x straggler, 1 µs aggregation timeout) that exercises all three
/// trace collectors and writes `trace_timeline.csv`,
/// `trace_spans.csv`, and `trace_trees.json` under `<out>/trace`
/// (EXPERIMENTS.md "Trace workflow"; render with
/// `scripts/plot_trace.py`).
pub fn trace_cell(o: &Opts) -> Series {
    let topo = ClosConfig::tiny3();
    let ft = Clos { cfg: topo };
    let leaf = ft.switch_id(1, 0);
    let parent = ft.switch_id(2, ft.parent_index(1, 0, 0));
    let faults = FaultSpec::default()
        .with_link_flap(leaf, parent, 5 * US, 30 * US)
        .with_straggler(3, 16);
    let sim = SimConfig::default().with_timeout(US).with_retrans(200 * US, true);
    let sc = ScenarioBuilder::new(topo)
        .sim(sim)
        .faults(faults)
        .trace(Some(TraceSpec::default()))
        .job(
            JobBuilder::new(Algo::Canary)
                .hosts(topo.n_hosts())
                .data_bytes(16 << 10),
        );
    let mut exp = sc.build(17);
    runner::run_to_completion(&mut exp.net, 1_000_000 * US);

    let dir = format!("{}/trace", o.out);
    match crate::trace::export(&exp.net, &dir) {
        Ok(paths) => {
            for p in paths {
                println!("wrote {p}");
            }
        }
        Err(e) => eprintln!("trace export to {dir} failed: {e}"),
    }

    let trees = exp.net.tracer.tree_records();
    let timeout_fwds = trees.iter().filter(|r| r.via_timeout).count();
    let partial_fwds = trees
        .iter()
        .filter(|r| r.contributed < r.expected)
        .count();
    let mut s = Series::new("trace_demo", &["metric", "value"]);
    let rows: [(&str, u64); 5] = [
        ("samples", exp.net.tracer.n_samples() as u64),
        ("spans", exp.net.tracer.spans().len() as u64),
        ("tree_forwards", trees.len() as u64),
        ("timeout_forwards", timeout_fwds as u64),
        ("partial_forwards", partial_fwds as u64),
    ];
    for (k, v) in rows {
        s.push(vec![k.to_string(), v.to_string()]);
    }
    finish(s, o)
}

/// Critical-path sweep (flight recorder, DESIGN.md §2.9): latency
/// attribution for ring/static/canary on the 2- and 3-tier fabrics,
/// with and without incast cross traffic. Each cell traces 4
/// seed-selected blocks, reconstructs their critical paths, and
/// reports the mean end-to-end latency plus stacked component
/// percentages — where a slow block's time went: queueing,
/// serialization, propagation, aggregation wait, or timeout penalty
/// (the last is Canary's congestion-avoidance price; it should buy
/// back queueing under incast).
pub fn critical_path(o: &Opts) -> Series {
    let mut s = Series::new(
        "critical_path_components",
        &[
            "topo",
            "algo",
            "cross_traffic",
            "paths",
            "mean_e2e_us",
            "queue_pct",
            "ser_pct",
            "prop_pct",
            "agg_wait_pct",
            "timeout_pct",
        ],
    );
    let fan_in = match o.scale {
        Scale::Ci => 8,
        _ => 32,
    };
    struct Cell {
        topo_name: &'static str,
        topo: ClosConfig,
        algo: Algo,
        cross: bool,
    }
    let mut cells = Vec::new();
    for (topo_name, topo) in
        [("clos2", o.scale.topo()), ("clos3", o.scale.topo3())]
    {
        let trees: Vec<u8> = [1u8]
            .into_iter()
            .filter(|&n| n as u32 <= topo.n_spine())
            .collect();
        for algo in algo_list(true, &trees) {
            for cross in [false, true] {
                cells.push(Cell {
                    topo_name,
                    topo,
                    algo,
                    cross,
                });
            }
        }
    }

    let results = par_map(cells.len(), |i| {
        let c = &cells[i];
        let hosts = (c.topo.n_hosts() / 2).max(2);
        let mut sim = SimConfig::default();
        if matches!(c.algo, Algo::Canary) {
            // timeouts armed so the penalty component can show up
            sim = sim.with_timeout(US).with_retrans(200 * US, true);
        }
        let sc = ScenarioBuilder::new(c.topo)
            .sim(sim)
            .traffic(c.cross.then(|| TrafficSpec::incast(fan_in)))
            .trace(Some(TraceSpec::default().with_blocks(4)))
            .job(
                JobBuilder::new(c.algo)
                    .hosts(hosts)
                    .data_bytes(o.scale.scale_sweep_bytes()),
            );
        let mut exp = sc.build(7000);
        runner::run_to_completion(&mut exp.net, u64::MAX);
        let paths = crate::trace::critical_paths(&exp.net);
        // [e2e, queue, ser, prop, agg_wait, timeout] summed over paths
        let mut tot = [0u64; 6];
        for p in &paths {
            tot[0] += p.e2e_ps();
            tot[1] += p.queue_ps;
            tot[2] += p.ser_ps;
            tot[3] += p.prop_ps;
            tot[4] += p.agg_wait_ps;
            tot[5] += p.timeout_penalty_ps;
        }
        (paths.len() as u64, tot)
    });

    for (c, (n, tot)) in cells.iter().zip(results) {
        let e2e = tot[0].max(1) as f64;
        let pct = |x: u64| format!("{:.1}", 100.0 * x as f64 / e2e);
        let mean_us = if n == 0 {
            0.0
        } else {
            tot[0] as f64 / n as f64 / 1e6
        };
        s.push(vec![
            c.topo_name.to_string(),
            c.algo.name(),
            c.cross.to_string(),
            n.to_string(),
            format!("{mean_us:.1}"),
            pct(tot[1]),
            pct(tot[2]),
            pct(tot[3]),
            pct(tot[4]),
            pct(tot[5]),
        ]);
    }
    finish(s, o)
}

/// Ablation: Canary goodput under different load balancers (design-choice
/// bench called out in DESIGN.md §5).
pub fn ablation_lb(o: &Opts) -> Series {
    let mut s = Series::new(
        "ablation_load_balancers",
        &["lb", "congestion", "goodput_gbps", "stddev"],
    );
    let hosts = o.scaled_hosts(50);
    let policies: Vec<(&str, LoadBalancer)> = vec![
        ("adaptive", LoadBalancer::DefaultAdaptive { threshold: 0.5 }),
        ("ecmp", LoadBalancer::Ecmp),
        ("minqueue", LoadBalancer::MinQueue),
        ("flowlet", LoadBalancer::Flowlet { gap_ps: 5 * US }),
    ];
    for (name, lb) in policies {
        for &cong in &[false, true] {
            let sc = base_scenario(o, Algo::Canary, hosts, cong).lb(lb.clone());
            let g = goodputs(&sc, o.seeds);
            s.push(vec![
                name.to_string(),
                cong.to_string(),
                format!("{:.1}", mean(&g)),
                format!("{:.1}", stddev(&g)),
            ]);
        }
    }
    finish(s, o)
}

/// Entry point for the `figures` binary.
pub fn main_entry() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(argv, &["scale", "seeds", "out"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let scale = match Scale::parse(args.get_or("scale", "half")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let o = Opts {
        scale,
        seeds: args
            .get_parse("seeds", scale.seeds())
            .unwrap_or(scale.seeds()),
        out: args.get_or("out", "results").to_string(),
    };
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let t0 = std::time::Instant::now();
    match which {
        "fig2" => drop(fig2(&o)),
        "fig6" => drop(fig6(&o)),
        "fig7a" => drop(fig7a(&o)),
        "fig7b" => drop(fig7b(&o)),
        "fig8" => drop(fig8(&o)),
        "fig9" => drop(fig9(&o)),
        "fig10a" => drop(fig10a(&o)),
        "fig10b" => drop(fig10b(&o)),
        "fig11" => drop(fig11(&o)),
        "mem" => drop(mem(&o)),
        "clos3" => drop(clos3(&o)),
        "traffic" => drop(traffic(&o)),
        "transport" => drop(transport(&o)),
        "placement" => drop(placement(&o)),
        "scale" => drop(scale(&o)),
        "churn" => drop(churn(&o)),
        "trace" => drop(trace_cell(&o)),
        "critical-path" => drop(critical_path(&o)),
        "ablation" => drop(ablation_lb(&o)),
        "all" => {
            drop(fig2(&o));
            drop(fig6(&o));
            drop(fig7a(&o));
            drop(fig7b(&o));
            drop(fig8(&o));
            drop(fig9(&o));
            drop(fig10a(&o));
            drop(fig10b(&o));
            drop(fig11(&o));
            drop(mem(&o));
            drop(clos3(&o));
            drop(traffic(&o));
            drop(transport(&o));
            drop(placement(&o));
            drop(scale(&o));
            drop(churn(&o));
            drop(trace_cell(&o));
            drop(critical_path(&o));
            drop(ablation_lb(&o));
        }
        other => {
            eprintln!(
                "unknown figure '{other}' \
                 (fig2|fig6|fig7a|fig7b|fig8|fig9|fig10a|fig10b|fig11|mem\
                 |clos3|traffic|transport|placement|scale|churn|trace\
                 |critical-path|ablation|all)"
            );
            std::process::exit(2);
        }
    }
    eprintln!("total: {:.1}s", t0.elapsed().as_secs_f64());
}
