//! Fat-tree topology builder (Leiserson fat tree, 2 levels — the paper's
//! Section 5.2 network: 32 leaves x 32 hosts + 32 spines, all 100 Gbps).
//!
//! Node-id layout: hosts `[0, H)`, leaves `[H, H+L)`, spines
//! `[H+L, H+L+S)`. Leaf ports: `[0, hosts_per_leaf)` down to hosts, then
//! one up-port per spine. Spine port `l` goes down to leaf `l`.

use crate::config::{FatTreeConfig, SimConfig};
use crate::host::HostState;
use crate::loadbalance::LoadBalancer;
use crate::sim::{Network, NodeBody, NodeId};
use crate::switch::{canary::Dataplane, SwitchRole, SwitchState};

/// Topology handle with id arithmetic helpers.
#[derive(Clone, Copy, Debug)]
pub struct FatTree {
    pub cfg: FatTreeConfig,
}

impl FatTree {
    pub fn n_hosts(&self) -> u32 {
        self.cfg.n_hosts()
    }

    pub fn host_id(&self, i: u32) -> NodeId {
        debug_assert!(i < self.n_hosts());
        i
    }

    pub fn leaf_id(&self, l: u32) -> NodeId {
        debug_assert!(l < self.cfg.n_leaf);
        self.n_hosts() + l
    }

    pub fn spine_id(&self, s: u32) -> NodeId {
        debug_assert!(s < self.cfg.n_spine);
        self.n_hosts() + self.cfg.n_leaf + s
    }

    pub fn leaf_of_host(&self, h: NodeId) -> u32 {
        h / self.cfg.hosts_per_leaf
    }

    /// Leaf-local port of a host.
    pub fn leaf_host_port(&self, h: NodeId) -> u16 {
        (h % self.cfg.hosts_per_leaf) as u16
    }

    /// Leaf port going up to spine `s`.
    pub fn leaf_up_port(&self, s: u32) -> u16 {
        (self.cfg.hosts_per_leaf + s) as u16
    }

    /// Spine port going down to leaf `l`.
    pub fn spine_down_port(&self, l: u32) -> u16 {
        l as u16
    }

    pub fn all_hosts(&self) -> Vec<NodeId> {
        (0..self.n_hosts()).collect()
    }

    pub fn all_spines(&self) -> Vec<NodeId> {
        (0..self.cfg.n_spine).map(|s| self.spine_id(s)).collect()
    }
}

/// Build the network: nodes, links, and per-switch routing facts.
pub fn build(
    topo_cfg: FatTreeConfig,
    sim_cfg: SimConfig,
    lb: LoadBalancer,
) -> (Network, FatTree) {
    let ft = FatTree { cfg: topo_cfg };
    let mut net = Network::new(sim_cfg);
    let h = ft.n_hosts();
    let hpl = topo_cfg.hosts_per_leaf;

    // hosts first (ids 0..H)
    for i in 0..h {
        let rng = net.rng.fork(i as u64);
        net.add_node(NodeBody::Host(Box::new(HostState::new(i, rng))));
    }
    // leaf switches
    for l in 0..topo_cfg.n_leaf {
        let id = h + l;
        net.add_node(NodeBody::Switch(Box::new(SwitchState {
            id,
            role: SwitchRole::Leaf {
                index: l,
                first_host: l * hpl,
            },
            lb: lb.clone(),
            lb_state: Default::default(),
            n_hosts: h,
            n_leaf: topo_cfg.n_leaf,
            hosts_per_leaf: hpl,
            n_spine: topo_cfg.n_spine,
            failed: false,
            canary: Dataplane::new(net.cfg.descriptor_slots, id as u64),
            static_tree: Default::default(),
        })));
    }
    // spine switches
    for s in 0..topo_cfg.n_spine {
        let id = h + topo_cfg.n_leaf + s;
        net.add_node(NodeBody::Switch(Box::new(SwitchState {
            id,
            role: SwitchRole::Spine { index: s },
            lb: lb.clone(),
            lb_state: Default::default(),
            n_hosts: h,
            n_leaf: topo_cfg.n_leaf,
            hosts_per_leaf: hpl,
            n_spine: topo_cfg.n_spine,
            failed: false,
            canary: Dataplane::new(net.cfg.descriptor_slots, id as u64),
            static_tree: Default::default(),
        })));
    }

    // host <-> leaf links. Port orderings must match the routing
    // assumptions: a host's port 0 is its uplink; a leaf's ports
    // [0, hpl) are its hosts in order; then one up-port per spine.
    //
    // Leaf ports are created in this order because `add_link` assigns
    // the next free out-port of `from`.
    for l in 0..topo_cfg.n_leaf {
        let leaf = ft.leaf_id(l);
        for j in 0..hpl {
            let host = l * hpl + j;
            // leaf out-port j -> host in-port 0
            net.add_link(leaf, host, 0);
        }
    }
    for i in 0..h {
        let leaf = ft.leaf_id(ft.leaf_of_host(i));
        // host out-port 0 -> leaf in-port (host-local index)
        net.add_link(i, leaf, ft.leaf_host_port(i));
    }
    // leaf <-> spine links
    for l in 0..topo_cfg.n_leaf {
        let leaf = ft.leaf_id(l);
        for s in 0..topo_cfg.n_spine {
            let spine = ft.spine_id(s);
            // leaf up-port (hpl + s) -> spine in-port l
            net.add_link(leaf, spine, ft.spine_down_port(l));
        }
    }
    for s in 0..topo_cfg.n_spine {
        let spine = ft.spine_id(s);
        for l in 0..topo_cfg.n_leaf {
            let leaf = ft.leaf_id(l);
            // spine out-port l -> leaf in-port (hpl + s)
            net.add_link(spine, leaf, ft.leaf_up_port(s));
        }
    }

    (net, ft)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NodeBody;

    #[test]
    fn paper_shape() {
        let (net, ft) = build(
            FatTreeConfig::paper(),
            SimConfig::default(),
            LoadBalancer::default(),
        );
        assert_eq!(net.nodes.len(), 1024 + 64);
        // host-leaf: 2*1024 directed; leaf-spine: 2*32*32 directed
        assert_eq!(net.links.len(), 2 * 1024 + 2 * 32 * 32);
        assert_eq!(ft.leaf_of_host(0), 0);
        assert_eq!(ft.leaf_of_host(1023), 31);
    }

    #[test]
    fn port_wiring_is_consistent() {
        let (net, ft) = build(
            FatTreeConfig::tiny(),
            SimConfig::default(),
            LoadBalancer::default(),
        );
        // host 5 (leaf 1, local port 1): its uplink must terminate at
        // leaf 1's in-port 1
        let host = 5;
        let uplink = net.nodes[host as usize].ports[0];
        let l = &net.links[uplink];
        assert_eq!(l.to, ft.leaf_id(1));
        assert_eq!(l.to_port, 1);

        // leaf 0's up-port to spine 1 must land on spine 1 in-port 0
        let leaf0 = ft.leaf_id(0);
        let up = net.nodes[leaf0 as usize].ports
            [ft.leaf_up_port(1) as usize];
        let l = &net.links[up];
        assert_eq!(l.to, ft.spine_id(1));
        assert_eq!(l.to_port, 0);

        // spine 0's port to leaf 1 lands on leaf 1's up-port for spine 0
        let spine0 = ft.spine_id(0);
        let down = net.nodes[spine0 as usize].ports
            [ft.spine_down_port(1) as usize];
        let l = &net.links[down];
        assert_eq!(l.to, ft.leaf_id(1));
        assert_eq!(l.to_port, ft.leaf_up_port(0));
    }

    #[test]
    fn all_nodes_have_expected_port_counts() {
        let cfg = FatTreeConfig::small(); // 4 leaves x 16 hosts, 4 spines
        let (net, _) = build(cfg, SimConfig::default(), LoadBalancer::default());
        for n in &net.nodes {
            match &n.body {
                NodeBody::Host(_) => assert_eq!(n.ports.len(), 1),
                NodeBody::Switch(sw) => match sw.role {
                    crate::switch::SwitchRole::Leaf { .. } => {
                        assert_eq!(n.ports.len(), 16 + 4)
                    }
                    crate::switch::SwitchRole::Spine { .. } => {
                        assert_eq!(n.ports.len(), 4)
                    }
                },
            }
        }
    }
}
