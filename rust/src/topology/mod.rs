//! Multi-tier folded-Clos topology builder and id/port arithmetic
//! (DESIGN.md §4).
//!
//! The fabric is an XGFT-style fat tree with one uplink per host,
//! described by a [`ClosConfig`]: per tier `t`, `down[t-1]` children per
//! switch and `up[t-1]` parents per tier-`t-1` node. The paper's
//! Section 5.2 network is the 2-tier special case (32 leaves x 32 hosts
//! + 32 spines); 3-tier pod fabrics with configurable oversubscription
//! are first-class.
//!
//! Node-id layout: hosts `[0, H)`, then switches tier by tier — tier 1
//! (leaves/ToRs) first, the top tier (spines/cores) last. Within a
//! tier, a switch index combines its *top* label (which subtree of the
//! tiers above it sits in) and its *bottom* label (which redundant copy
//! it is): `index = top * W_t + bot`, where `W_t = prod(up[..t])`.
//! For the 2-tier paper network this reduces to the legacy fixed
//! layout: hosts `[0, H)`, leaves `[H, H+L)`, spines `[H+L, H+L+S)`,
//! leaf ports `[0, hosts_per_leaf)` down then one up-port per spine,
//! and spine port `l` down to leaf `l` — bit-for-bit the same ids,
//! ports and link order as the original 2-level builder.
//!
//! Port layout on a tier-`t` switch: ports `[0, down[t-1])` go down,
//! one per child in child order; ports `[down[t-1], ..)` go up, one per
//! parent in parent order. Routing is valley-free up/down: a packet
//! climbs (with adaptive up-port choice, [`Hop::Up`]) until the
//! destination is in its down-subtree, then descends deterministically.

use crate::config::{ClosConfig, SimConfig};
use crate::host::HostState;
use crate::loadbalance::LoadBalancer;
use crate::sim::{Network, NodeBody, NodeId};
use crate::switch::SwitchState;

/// Topology handle with the id/port arithmetic. `Copy`, so experiments
/// and switches can carry it by value.
#[derive(Clone, Copy, Debug)]
pub struct Clos {
    pub cfg: ClosConfig,
}

/// Backwards-compatible name (the 2-tier call sites and tests).
pub type FatTree = Clos;

/// One routing step, as computed by [`Clos::hop`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hop {
    /// The packet is addressed to this very node.
    Local,
    /// Exactly one valid egress port: a down-hop, or an up-hop that
    /// must stay aligned with a switch destination's bottom label.
    Port(u16),
    /// Any of the `n` up-ports starting at `base` reaches the
    /// destination; `dflt` is the destination-derived default offset
    /// the adaptive load balancer starts from.
    Up { base: u16, n: u16, dflt: u16 },
}

impl Clos {
    /// Number of switch tiers.
    #[inline]
    pub fn tiers(&self) -> u8 {
        self.cfg.tiers
    }

    #[inline]
    pub fn n_hosts(&self) -> u32 {
        self.cfg.n_hosts()
    }

    /// `prod(down[..t])`: hosts under one tier-`t` switch.
    #[inline]
    fn hosts_below(&self, t: u8) -> u32 {
        self.cfg.down[..t as usize].iter().product()
    }

    /// `W_t = prod(up[..t])`: bottom-label arity at tier `t`.
    #[inline]
    pub(crate) fn w(&self, t: u8) -> u32 {
        self.cfg.up[..t as usize].iter().product()
    }

    /// Parent digit of the aligned climb from tier `tier` toward a
    /// switch with bottom label `bot` — the other half of the shared
    /// label arithmetic ([`Clos::parent_index`]), used by both the
    /// router and the static-tree control plane.
    pub fn climb_digit(&self, tier: u8, bot: u32) -> u32 {
        (bot / self.w(tier)) % self.cfg.up[tier as usize]
    }

    /// First node id of tier `t`'s switches.
    pub fn tier_base(&self, t: u8) -> NodeId {
        debug_assert!((1..=self.tiers()).contains(&t));
        self.n_hosts()
            + (1..t).map(|j| self.cfg.tier_size(j)).sum::<u32>()
    }

    /// Node id of the tier-`t` switch with the given within-tier index.
    pub fn switch_id(&self, t: u8, index: u32) -> NodeId {
        debug_assert!(index < self.cfg.tier_size(t));
        self.tier_base(t) + index
    }

    /// Tier of a node: 0 for hosts, `1..=tiers` for switches.
    pub fn node_tier(&self, node: NodeId) -> u8 {
        if node < self.n_hosts() {
            return 0;
        }
        let mut t = 1;
        while t < self.tiers()
            && node >= self.tier_base(t) + self.cfg.tier_size(t)
        {
            t += 1;
        }
        t
    }

    /// `(tier, within-tier index)` of a switch node id.
    pub fn switch_at(&self, node: NodeId) -> (u8, u32) {
        let t = self.node_tier(node);
        debug_assert!(t > 0, "node {node} is a host");
        (t, node - self.tier_base(t))
    }

    /// Index (at `tier + 1`) of the parent of the tier-`tier` switch
    /// `idx` reached via parent digit `c`. The single source of the
    /// label arithmetic shared by the link builder ([`build`]), the
    /// router ([`Clos::hop`]) and the static-tree control plane
    /// (`install_static_job` in [`crate::collectives::runner`]).
    pub fn parent_index(&self, tier: u8, idx: u32, c: u32) -> u32 {
        debug_assert!(tier < self.tiers() && c < self.cfg.up[tier as usize]);
        let w_t = self.w(tier);
        let m_up = self.cfg.down[tier as usize];
        let (top, bot) = (idx / w_t, idx % w_t);
        (top / m_up) * (w_t * self.cfg.up[tier as usize]) + c * w_t + bot
    }

    /// Up-port of a tier-`tier` switch toward its parent digit `c`.
    pub fn up_port(&self, tier: u8, c: u32) -> u16 {
        (self.cfg.down[tier as usize - 1] + c) as u16
    }

    /// Pick the next hop for a packet at `at` destined to `dst`.
    ///
    /// Hosts have a single uplink (port 0). A switch routes down when
    /// the destination is in its subtree, up otherwise; up-hops toward
    /// a *switch* destination above this tier are port-forced (they
    /// must follow the destination's bottom label), all other up-hops
    /// are free for the load balancer ([`Hop::Up`]).
    pub fn hop(&self, at: NodeId, dst: NodeId) -> Hop {
        if at < self.n_hosts() {
            return if at == dst { Hop::Local } else { Hop::Port(0) };
        }
        let (t, idx) = self.switch_at(at);
        self.hop_at(t, idx, dst)
    }

    /// [`Clos::hop`] for a switch whose `(tier, index)` the caller
    /// already knows (`SwitchState` caches both) — keeps the per-packet
    /// path free of the id-to-tier scan.
    pub fn hop_at(&self, t: u8, idx: u32, dst: NodeId) -> Hop {
        let m = self.cfg.down[t as usize - 1];
        let n_up = if t == self.tiers() {
            0
        } else {
            self.cfg.up[t as usize]
        };
        let wt = self.w(t);
        let (top_a, bot_a) = (idx / wt, idx % wt);

        if dst < self.n_hosts() {
            // host destination: down iff it is in our subtree
            if dst / self.hosts_below(t) == top_a {
                let port = (dst / self.hosts_below(t - 1)) % m;
                return Hop::Port(port as u16);
            }
            debug_assert!(n_up > 0, "top tier covers every host");
            return Hop::Up {
                base: m as u16,
                n: n_up as u16,
                dflt: (dst % n_up) as u16,
            };
        }

        // switch destination
        let (dt, didx) = self.switch_at(dst);
        if (dt, didx) == (t, idx) {
            return Hop::Local;
        }
        let wd = self.w(dt);
        let (top_d, bot_d) = (didx / wd, didx % wd);
        if dt > t {
            // above us: climb along the destination's bottom label
            debug_assert!(
                bot_d % wt == bot_a,
                "unroutable: switch {dst} is not in tier-{t}/{idx}'s up-cone"
            );
            return Hop::Port(self.up_port(t, self.climb_digit(t, bot_d)));
        }
        // at or below our tier: down iff it is our descendant
        let shift = self.hosts_below(t) / self.hosts_below(dt);
        if top_d / shift == top_a && bot_d == bot_a % wd {
            let port =
                (top_d / (self.hosts_below(t - 1) / self.hosts_below(dt))) % m;
            return Hop::Port(port as u16);
        }
        assert!(
            n_up > 0,
            "unroutable: tier-{t}/{idx} (top tier) to non-descendant \
             switch {dst}"
        );
        Hop::Up {
            base: m as u16,
            n: n_up as u16,
            dflt: (dst % n_up) as u16,
        }
    }

    // ---- legacy-named helpers (tier 1 = "leaf", top tier = "spine");
    //      still the vocabulary of the host/leader protocols ----------

    pub fn host_id(&self, i: u32) -> NodeId {
        debug_assert!(i < self.n_hosts());
        i
    }

    pub fn leaf_id(&self, l: u32) -> NodeId {
        self.switch_id(1, l)
    }

    pub fn spine_id(&self, s: u32) -> NodeId {
        self.switch_id(self.tiers(), s)
    }

    /// Tier-1 (leaf/ToR) index of a host.
    pub fn leaf_of_host(&self, h: NodeId) -> u32 {
        h / self.cfg.down[0]
    }

    /// Leaf-local down-port of a host.
    pub fn leaf_host_port(&self, h: NodeId) -> u16 {
        (h % self.cfg.down[0]) as u16
    }

    /// Leaf up-port toward its parent with bottom digit `c`.
    pub fn leaf_up_port(&self, c: u32) -> u16 {
        (self.cfg.down[0] + c) as u16
    }

    /// Top-tier down-port toward the child with top digit `x`.
    pub fn spine_down_port(&self, x: u32) -> u16 {
        x as u16
    }

    pub fn all_hosts(&self) -> Vec<NodeId> {
        (0..self.n_hosts()).collect()
    }

    /// Per-node space-partition label for the sharded engine
    /// ([`crate::sim::shard`], DESIGN.md §2.10): hosts and non-top
    /// switches are labelled with their top-level subtree — the pod in
    /// a 3-tier fabric, the leaf group in the 2-tier case — and
    /// top-tier switches get `u32::MAX` (they belong to no subtree and
    /// are dealt round-robin across shards at run time). Every link
    /// except host/switch-to-top-tier uplinks and top-tier downlinks
    /// stays inside one group, so conservative windowing only has to
    /// hand packets across shards at the core crossing.
    pub fn shard_groups(&self) -> Vec<u32> {
        let t_top = self.tiers();
        // hosts under one top-level subtree
        let per_pod = self.hosts_below(t_top - 1).max(1);
        let n_sw: u32 = (1..=t_top).map(|t| self.cfg.tier_size(t)).sum();
        let mut g = Vec::with_capacity((self.n_hosts() + n_sw) as usize);
        for h in 0..self.n_hosts() {
            g.push(h / per_pod);
        }
        for t in 1..=t_top {
            if t == t_top {
                g.extend(
                    std::iter::repeat(u32::MAX)
                        .take(self.cfg.tier_size(t) as usize),
                );
                continue;
            }
            // tier-t subtrees per pod
            let per = (per_pod / self.hosts_below(t)).max(1);
            let w_t = self.w(t);
            for idx in 0..self.cfg.tier_size(t) {
                g.push((idx / w_t) / per);
            }
        }
        g
    }

    /// All top-tier switches (the candidate static-tree roots).
    pub fn all_spines(&self) -> Vec<NodeId> {
        let t = self.tiers();
        (0..self.cfg.tier_size(t)).map(|s| self.switch_id(t, s)).collect()
    }
}

/// Build the network: nodes, links, and per-switch routing facts.
pub fn build(
    topo_cfg: ClosConfig,
    sim_cfg: SimConfig,
    lb: LoadBalancer,
) -> (Network, Clos) {
    topo_cfg
        .validate()
        .unwrap_or_else(|e| panic!("invalid topology: {e}"));
    let ft = Clos { cfg: topo_cfg };
    let mut net = Network::new(sim_cfg);
    let h = ft.n_hosts();
    let tiers = ft.tiers();
    let slots = net.cfg.descriptor_slots;

    // hosts first (ids 0..H)
    for i in 0..h {
        let rng = net.rng.fork(i as u64);
        net.add_node(NodeBody::Host(Box::new(HostState::new(i, rng))));
    }
    // switches, tier by tier
    for t in 1..=tiers {
        for idx in 0..topo_cfg.tier_size(t) {
            net.add_node(NodeBody::Switch(Box::new(SwitchState::new(
                ft,
                t,
                idx,
                lb.clone(),
                slots,
            ))));
        }
    }

    // Links. Port orderings must match the routing assumptions: a
    // host's port 0 is its uplink; a switch's ports [0, down) are its
    // children in child order, then one up-port per parent in parent
    // order. `add_link` assigns the next free out-port of `from`, so
    // every switch's down links are created before its up links.
    //
    // tier-1 down links to hosts, then host uplinks
    let m1 = topo_cfg.down[0];
    for l in 0..topo_cfg.tier_size(1) {
        let leaf = ft.leaf_id(l);
        for j in 0..m1 {
            // leaf out-port j -> host in-port 0
            net.add_link(leaf, l * m1 + j, 0);
        }
    }
    for i in 0..h {
        // host out-port 0 -> leaf in-port (host-local index)
        net.add_link(i, ft.leaf_id(ft.leaf_of_host(i)), ft.leaf_host_port(i));
    }
    // tier t <-> tier t+1 links
    for t in 1..tiers {
        let m_up = topo_cfg.down[t as usize]; // children per tier-(t+1) switch
        let w_t = ft.w(t);
        let w_next = topo_cfg.up[t as usize];
        // up links of tier t, in parent order
        for idx in 0..topo_cfg.tier_size(t) {
            let id = ft.switch_id(t, idx);
            let my_digit = ((idx / w_t) % m_up) as u16; // parent's down-port
            for c in 0..w_next {
                let pidx = ft.parent_index(t, idx, c);
                net.add_link(id, ft.switch_id(t + 1, pidx), my_digit);
            }
        }
        // down links of tier t+1, in child order
        for pidx in 0..topo_cfg.tier_size(t + 1) {
            let pid = ft.switch_id(t + 1, pidx);
            let (ptop, pbot) = (pidx / (w_t * w_next), pidx % (w_t * w_next));
            let c_digit = pbot / w_t; // our digit in the child's parent order
            for x in 0..m_up {
                let cidx = (ptop * m_up + x) * w_t + pbot % w_t;
                // child's in-port: its up-port toward us
                net.add_link(pid, ft.switch_id(t, cidx), ft.up_port(t, c_digit));
            }
        }
    }

    net.shard_group = ft.shard_groups();

    (net, ft)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FatTreeConfig;
    use crate::sim::NodeBody;
    use crate::switch::SwitchRole;

    #[test]
    fn paper_shape() {
        let (net, ft) = build(
            FatTreeConfig::paper(),
            SimConfig::default(),
            LoadBalancer::default(),
        );
        assert_eq!(net.nodes.len(), 1024 + 64);
        // host-leaf: 2*1024 directed; leaf-spine: 2*32*32 directed
        assert_eq!(net.links.len(), 2 * 1024 + 2 * 32 * 32);
        assert_eq!(ft.leaf_of_host(0), 0);
        assert_eq!(ft.leaf_of_host(1023), 31);
    }

    #[test]
    fn port_wiring_is_consistent() {
        let (net, ft) = build(
            FatTreeConfig::tiny(),
            SimConfig::default(),
            LoadBalancer::default(),
        );
        // host 5 (leaf 1, local port 1): its uplink must terminate at
        // leaf 1's in-port 1
        let host = 5;
        let uplink = net.nodes[host as usize].ports[0];
        let l = &net.links[uplink];
        assert_eq!(l.to, ft.leaf_id(1));
        assert_eq!(l.to_port, 1);

        // leaf 0's up-port to spine 1 must land on spine 1 in-port 0
        let leaf0 = ft.leaf_id(0);
        let up = net.nodes[leaf0 as usize].ports
            [ft.leaf_up_port(1) as usize];
        let l = &net.links[up];
        assert_eq!(l.to, ft.spine_id(1));
        assert_eq!(l.to_port, 0);

        // spine 0's port to leaf 1 lands on leaf 1's up-port for spine 0
        let spine0 = ft.spine_id(0);
        let down = net.nodes[spine0 as usize].ports
            [ft.spine_down_port(1) as usize];
        let l = &net.links[down];
        assert_eq!(l.to, ft.leaf_id(1));
        assert_eq!(l.to_port, ft.leaf_up_port(0));
    }

    #[test]
    fn all_nodes_have_expected_port_counts() {
        let cfg = FatTreeConfig::small(); // 4 leaves x 16 hosts, 4 spines
        let (net, _) = build(cfg, SimConfig::default(), LoadBalancer::default());
        for n in &net.nodes {
            match &n.body {
                NodeBody::Host(_) => assert_eq!(n.ports.len(), 1),
                NodeBody::Switch(sw) => match sw.role() {
                    SwitchRole::Leaf => assert_eq!(n.ports.len(), 16 + 4),
                    SwitchRole::Spine => assert_eq!(n.ports.len(), 4),
                    SwitchRole::Aggregation { .. } => {
                        panic!("no aggregation tier in a 2-tier build")
                    }
                },
            }
        }
    }

    #[test]
    fn three_tier_shape_and_roles() {
        let cfg = ClosConfig::small3(); // 4 pods x 4 ToRs x 4 hosts
        let (net, ft) = build(cfg, SimConfig::default(), LoadBalancer::default());
        assert_eq!(net.nodes.len(), (64 + 16 + 8 + 4) as usize);
        // directed links: 2 * (64 host uplinks + 16 ToRs x 2 + 8 aggs x 2)
        assert_eq!(net.links.len(), 2 * (64 + 32 + 16));
        let mut counts = [0u32; 3];
        for n in &net.nodes {
            if let NodeBody::Switch(sw) = &n.body {
                match sw.role() {
                    SwitchRole::Leaf => {
                        counts[0] += 1;
                        assert_eq!(n.ports.len(), 4 + 2);
                    }
                    SwitchRole::Aggregation { tier } => {
                        counts[1] += 1;
                        assert_eq!(tier, 2);
                        assert_eq!(n.ports.len(), 4 + 2);
                    }
                    SwitchRole::Spine => {
                        counts[2] += 1;
                        assert_eq!(n.ports.len(), 4);
                    }
                }
            }
        }
        assert_eq!(counts, [16, 8, 4]);
        assert_eq!(ft.all_spines().len(), 4);
    }

    #[test]
    fn shard_groups_follow_pods() {
        // 2-tier paper fabric: the "pod" is a leaf group.
        let (net, ft) = build(
            FatTreeConfig::paper(),
            SimConfig::default(),
            LoadBalancer::default(),
        );
        let g = &net.shard_group;
        assert_eq!(g.len(), net.nodes.len());
        assert_eq!(g[0], 0);
        assert_eq!(g[31], 0);
        assert_eq!(g[32], 1);
        assert_eq!(g[1023], 31);
        // leaf l belongs to group l; spines are unpinned
        assert_eq!(g[ft.leaf_id(7) as usize], 7);
        assert_eq!(g[ft.spine_id(0) as usize], u32::MAX);
        assert_eq!(g[ft.spine_id(31) as usize], u32::MAX);

        // 3-tier: hosts, ToRs and aggs of one pod share a group.
        let (net, ft) = build(
            ClosConfig::small3(),
            SimConfig::default(),
            LoadBalancer::default(),
        );
        let g = &net.shard_group;
        for h in 0..64u32 {
            assert_eq!(g[h as usize], h / 16, "host {h}");
        }
        for tor in 0..16u32 {
            assert_eq!(g[ft.switch_id(1, tor) as usize], tor / 4, "tor {tor}");
        }
        for agg in 0..8u32 {
            let id = ft.switch_id(2, agg) as usize;
            assert!(g[id] < 4, "agg {agg} must sit in a pod");
            // every agg shares its group with the hosts it serves
            let some_host = (g[id] * 16) as usize;
            assert_eq!(g[id], g[some_host]);
        }
        for core in ft.all_spines() {
            assert_eq!(g[core as usize], u32::MAX);
        }

        // a non-core link never crosses groups
        for l in &net.links {
            let (a, b) = (g[l.from as usize], g[l.to as usize]);
            if a != u32::MAX && b != u32::MAX {
                assert_eq!(a, b, "link {}->{} crosses pods", l.from, l.to);
            }
        }
    }

    #[test]
    fn three_tier_up_down_hops() {
        let cfg = ClosConfig::small3();
        let (net, ft) = build(cfg, SimConfig::default(), LoadBalancer::default());
        // host 0 (pod 0, ToR 0) -> host 63 (pod 3): ToR goes up free,
        // agg goes up free, core goes down deterministically
        let tor0 = ft.leaf_id(0);
        match ft.hop(tor0, 63) {
            Hop::Up { base, n, .. } => {
                assert_eq!(base, 4);
                assert_eq!(n, 2);
            }
            other => panic!("expected free up-hop, got {other:?}"),
        }
        // a core reaches every host going down
        let core = ft.spine_id(0);
        for hst in [0u32, 17, 42, 63] {
            match ft.hop(core, hst) {
                Hop::Port(p) => assert!(p < 4),
                other => panic!("core must route down, got {other:?}"),
            }
        }
        // ToR -> core climb is bottom-aligned (forced ports)
        let path_ok = {
            let mut at = tor0;
            let dst = ft.spine_id(3);
            let mut hops = 0;
            while at != dst && hops < 4 {
                let port = match ft.hop(at, dst) {
                    Hop::Port(p) => p,
                    Hop::Up { .. } => {
                        panic!("climb to a switch must be port-forced")
                    }
                    Hop::Local => break,
                };
                let link = net.nodes[at as usize].ports[port as usize];
                at = net.links[link].to;
                hops += 1;
            }
            at == dst
        };
        assert!(path_ok, "ToR must reach any core in aligned up-hops");
    }
}
