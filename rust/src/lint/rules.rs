//! The five lint rules (D1–D5). Each is a pure function over the
//! pre-split [`SourceLine`]s of one file; `lint_cli_docs` (D5) is the
//! one cross-file rule. See the module docs and DESIGN.md §2.8 for
//! what each rule protects and how to allowlist a site.

use std::collections::BTreeSet;
use std::path::Path;

use super::{
    fp_excluded_reason, has_ident, ident_before, idents, is_ident_byte, report_site,
    site_annotation, split_source, word_pos, Finding, Rule, SourceLine,
};

/// Container methods whose call on a hash-ordered receiver observes
/// iteration order (D1).
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "values",
    "values_mut",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// Ambient-entropy tokens (D3): anything that seeds itself from the
/// OS or the process makes runs non-reproducible.
const RNG_DENY: &[&str] = &[
    "thread_rng",
    "from_entropy",
    "OsRng",
    "StdRng",
    "SmallRng",
    "RandomState",
    "getrandom",
    "rand_core",
];

/// Files where wall-clock reads are expected by design (D2): the
/// bench harness and the figure-generation driver, which time real
/// work and never feed a simulation.
const WALL_CLOCK_ALLOWED: &[&str] = &["util/bench.rs", "figures/mod.rs"];

/// Structs whose counter fields the fingerprint must cover (D4).
const FP_STRUCTS: &[&str] = &["Metrics", "FlowStats", "EngineStats"];

/// Run the per-file rules (D1–D4) over one source file. `file` is the
/// root-relative path with `/` separators (used for allowlists and in
/// findings).
pub fn lint_source(file: &str, text: &str) -> Vec<Finding> {
    let lines = split_source(text);
    let mut out = Vec::new();
    d1_unordered_iter(file, &lines, &mut out);
    d2_wall_clock(file, &lines, &mut out);
    d3_rng(file, &lines, &mut out);
    d4_fingerprint(file, &lines, &mut out);
    out
}

/// Does this line declare a binding or field of a hash-ordered type?
/// Recognizes the `name: ...Hash{Map,Set}<...>` and
/// `let [mut] name = Hash{Map,Set}::new()` shapes (fields, lets,
/// struct-literal initializers). Returns the binding name. Function
/// parameters are out of scope — the declarations that matter for
/// determinism are fields and locals, and a narrow shape keeps the
/// false-positive rate at zero.
fn hash_binding(code: &str) -> Option<String> {
    if !has_ident(code, "HashMap") && !has_ident(code, "HashSet") {
        return None;
    }
    let mut t = code.trim_start();
    loop {
        let mut changed = false;
        let kws = ["pub(crate)", "pub(super)", "pub", "let", "mut", "static"];
        for kw in kws {
            if let Some(rest) = t.strip_prefix(kw) {
                if rest.starts_with([' ', '\t']) {
                    t = rest.trim_start();
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    let name_len = t.bytes().take_while(|&c| is_ident_byte(c)).count();
    if name_len == 0 {
        return None;
    }
    let name = &t[..name_len];
    let rest = t[name_len..].trim_start();
    // a real binder is followed by `:` (field/typed let) or `=` —
    // keywords like `for`/`if` never are, so they filter themselves
    let binds = (rest.starts_with(':') && !rest.starts_with("::"))
        || (rest.starts_with('=') && !rest.starts_with("=="));
    if binds {
        Some(name.to_string())
    } else {
        None
    }
}

/// Does this line (or one of the next three, for multi-line
/// statements) sort the result? A `.sort*` call right after the
/// iteration counts as "provably sorts before use".
fn sorts_nearby(lines: &[SourceLine], idx: usize) -> bool {
    lines[idx..lines.len().min(idx + 4)].iter().any(|l| {
        l.code.contains(".sort(")
            || l.code.contains(".sort_by(")
            || l.code.contains(".sort_by_key(")
            || l.code.contains(".sort_unstable(")
            || l.code.contains(".sort_unstable_by(")
            || l.code.contains(".sort_unstable_by_key(")
    })
}

/// The hash-ordered binding this line iterates, if any: either an
/// `.iter()`-family call whose receiver is a known hash binding, or a
/// `for ... in <expr>` whose expression mentions one.
fn iter_site(code: &str, hashed: &BTreeSet<String>) -> Option<String> {
    for m in ITER_METHODS {
        let pat = format!(".{m}(");
        let mut from = 0usize;
        while let Some(p) = code[from..].find(&pat) {
            let dot = from + p;
            from = dot + pat.len();
            if let Some(recv) = ident_before(code, dot) {
                if hashed.contains(recv) {
                    return Some(recv.to_string());
                }
            }
        }
    }
    if let Some(fpos) = word_pos(code, "for") {
        let rest = &code[fpos + 3..];
        if let Some(inpos) = word_pos(rest, "in") {
            let expr = &rest[inpos + 2..];
            let expr = expr.split('{').next().unwrap_or(expr);
            for id in idents(expr) {
                if hashed.contains(id) {
                    return Some(id.to_string());
                }
            }
        }
    }
    None
}

/// D1: unordered iteration over hash containers. Per-file binding
/// tracking (names are collected only from this file's declarations),
/// so a `jobs: Vec<_>` in one module is never confused with a
/// `jobs: HashMap<_, _>` in another.
fn d1_unordered_iter(file: &str, lines: &[SourceLine], out: &mut Vec<Finding>) {
    let mut hashed = BTreeSet::new();
    for l in lines {
        if let Some(name) = hash_binding(&l.code) {
            hashed.insert(name);
        }
    }
    if hashed.is_empty() {
        return;
    }
    for (idx, l) in lines.iter().enumerate() {
        let Some(name) = iter_site(&l.code, &hashed) else {
            continue;
        };
        if sorts_nearby(lines, idx) {
            continue;
        }
        report_site(
            out,
            lines,
            file,
            idx,
            Rule::UnorderedIter,
            format!(
                "iteration over hash-ordered `{name}` observes the \
                 process-random hasher order; sort first or annotate \
                 `// lint: allow(unordered-iter, <reason>)`"
            ),
        );
    }
}

/// D2: wall-clock containment. Allowlisted harness files may read the
/// clock freely; a file that defines `fn fingerprint` may never (no
/// annotation can excuse it); everywhere else needs a reasoned
/// `allow(wall-clock, ...)`.
fn d2_wall_clock(file: &str, lines: &[SourceLine], out: &mut Vec<Finding>) {
    if WALL_CLOCK_ALLOWED.iter().any(|a| file.ends_with(a)) {
        return;
    }
    let defines_fp = lines.iter().any(|l| l.code.contains("fn fingerprint"));
    for (idx, l) in lines.iter().enumerate() {
        let instant = has_ident(&l.code, "Instant");
        if !instant && !has_ident(&l.code, "SystemTime") {
            continue;
        }
        if defines_fp {
            out.push(Finding {
                file: file.to_string(),
                line: idx + 1,
                rule: Rule::WallClock,
                message: "wall-clock type in a file that defines \
                          `fn fingerprint` (not allowlistable)"
                    .to_string(),
            });
            continue;
        }
        report_site(
            out,
            lines,
            file,
            idx,
            Rule::WallClock,
            "wall-clock type outside the bench/figure allowlist; \
             annotate `// lint: allow(wall-clock, <reason>)`"
                .to_string(),
        );
    }
}

/// D3: RNG discipline. Randomness must come from the seeded
/// generators in `util/rng.rs`; ambient-entropy tokens and `rand::`
/// paths are flagged (annotatable, but nothing in-tree should be).
fn d3_rng(file: &str, lines: &[SourceLine], out: &mut Vec<Finding>) {
    if file.ends_with("util/rng.rs") {
        return; // the sanctioned implementation itself
    }
    for (idx, l) in lines.iter().enumerate() {
        let mut hit = RNG_DENY.iter().copied().find(|&t| has_ident(&l.code, t));
        if hit.is_none() {
            let rand_path = word_pos(&l.code, "rand")
                .is_some_and(|p| l.code[p + 4..].starts_with("::"));
            if rand_path {
                hit = Some("rand::");
            }
        }
        let Some(token) = hit else {
            continue;
        };
        report_site(
            out,
            lines,
            file,
            idx,
            Rule::Rng,
            format!(
                "`{token}` bypasses the seeded util/rng.rs generators \
                 (runs stop being reproducible)"
            ),
        );
    }
}

/// Field declaration `name: Type` on this line (struct bodies).
fn field_decl(code: &str) -> Option<(String, String)> {
    let mut t = code.trim_start();
    for kw in ["pub(crate)", "pub(super)", "pub"] {
        if let Some(rest) = t.strip_prefix(kw) {
            if rest.starts_with([' ', '\t']) {
                t = rest.trim_start();
            }
        }
    }
    let name_len = t.bytes().take_while(|&c| is_ident_byte(c)).count();
    if name_len == 0 {
        return None;
    }
    let name = &t[..name_len];
    let rest = t[name_len..].trim_start();
    let ty = rest.strip_prefix(':')?;
    if ty.starts_with(':') {
        return None; // `::` path, not a field
    }
    let ty = ty.trim().trim_end_matches(',').trim();
    Some((name.to_string(), ty.to_string()))
}

/// Is this field type a counter the fingerprint should cover?
/// Unsigned integers, plus arrays and vectors of them. Floats
/// (wall-clock measurements) and nested structs are covered by their
/// own fields/rules.
fn is_counter_type(ty: &str) -> bool {
    for base in ["u16", "u32", "u64", "u128", "usize"] {
        if ty == base
            || ty.starts_with(&format!("[{base}"))
            || ty.starts_with(&format!("Vec<{base}"))
        {
            return true;
        }
    }
    false
}

/// Line range (exclusive of the header) of `struct <name> { ... }`.
fn struct_body(lines: &[SourceLine], name: &str) -> Option<(usize, usize)> {
    let header = format!("struct {name}");
    let start = lines.iter().position(|l| {
        word_pos(&l.code, &header).is_some() && l.code.contains('{')
    })?;
    let end = brace_span_end(lines, start)?;
    Some((start + 1, end))
}

/// Index of the line that closes the brace block opened on `start`.
fn brace_span_end(lines: &[SourceLine], start: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut opened = false;
    for (idx, l) in lines.iter().enumerate().skip(start) {
        for c in l.code.bytes() {
            match c {
                b'{' => {
                    depth += 1;
                    opened = true;
                }
                b'}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return Some(idx);
        }
    }
    None
}

/// D4: fingerprint coverage. Only active in a file that defines
/// `fn fingerprint`: every counter field of the metrics structs must
/// be mentioned in the fingerprint body or carry
/// `// fp: excluded(<reason>)`.
fn d4_fingerprint(file: &str, lines: &[SourceLine], out: &mut Vec<Finding>) {
    let fp = lines.iter().position(|l| l.code.contains("fn fingerprint"));
    let Some(fp_start) = fp else {
        return;
    };
    let fp_end = brace_span_end(lines, fp_start).unwrap_or(lines.len() - 1);
    let mut covered = BTreeSet::new();
    for l in &lines[fp_start..=fp_end.min(lines.len() - 1)] {
        for id in idents(&l.code) {
            covered.insert(id.to_string());
        }
    }
    for sname in FP_STRUCTS {
        let Some((body_start, body_end)) = struct_body(lines, sname) else {
            continue;
        };
        for idx in body_start..body_end {
            let Some((fname, ty)) = field_decl(&lines[idx].code) else {
                continue;
            };
            if !is_counter_type(&ty) || covered.contains(&fname) {
                continue;
            }
            let ann = site_annotation(lines, idx, fp_excluded_reason);
            match ann {
                Some(reason) if !reason.is_empty() => {}
                Some(_) => out.push(Finding {
                    file: file.to_string(),
                    line: idx + 1,
                    rule: Rule::FpCoverage,
                    message: format!(
                        "`fp: excluded` on `{sname}::{fname}` needs a \
                         reason: `fp: excluded(<why>)`"
                    ),
                }),
                None => out.push(Finding {
                    file: file.to_string(),
                    line: idx + 1,
                    rule: Rule::FpCoverage,
                    message: format!(
                        "counter `{sname}::{fname}` is missing from \
                         `fingerprint()`; mix it or annotate \
                         `// fp: excluded(<reason>)`"
                    ),
                }),
            }
        }
    }
}

/// D5: CLI/doc sync. Every flag literal in `main.rs`'s known-flag
/// list must appear as `--flag` in the repository README.
pub fn lint_cli_docs(root: &Path) -> Vec<Finding> {
    let main_path = root.join("src/main.rs");
    let Ok(main_text) = std::fs::read_to_string(&main_path) else {
        return Vec::new(); // no CLI in this tree (fixture trees)
    };
    let readme = std::fs::read_to_string(root.join("README.md"))
        .or_else(|_| std::fs::read_to_string(root.join("../README.md")));
    let lines = split_source(&main_text);
    let parse = lines.iter().position(|l| l.code.contains("Args::parse"));
    let Some(start) = parse else {
        return Vec::new();
    };
    let end = paren_span_end(&lines, start).unwrap_or(start);
    let mut out = Vec::new();
    let Ok(readme) = readme else {
        out.push(Finding {
            file: "src/main.rs".to_string(),
            line: start + 1,
            rule: Rule::CliDoc,
            message: "README.md not found next to the crate; cannot \
                      check CLI flag documentation"
                .to_string(),
        });
        return out;
    };
    for (idx, l) in lines.iter().enumerate().take(end + 1).skip(start) {
        for flag in &l.strings {
            if !readme.contains(&format!("--{flag}")) {
                out.push(Finding {
                    file: "src/main.rs".to_string(),
                    line: idx + 1,
                    rule: Rule::CliDoc,
                    message: format!(
                        "flag `--{flag}` is in the known-flag list but \
                         undocumented in README.md"
                    ),
                });
            }
        }
    }
    out
}

/// Index of the line that closes the parenthesis block opened on
/// `start` (the `Args::parse(...)` call spans several lines).
fn paren_span_end(lines: &[SourceLine], start: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut opened = false;
    for (idx, l) in lines.iter().enumerate().skip(start) {
        for c in l.code.bytes() {
            match c {
                b'(' => {
                    depth += 1;
                    opened = true;
                }
                b')' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return Some(idx);
        }
    }
    None
}
