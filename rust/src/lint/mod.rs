//! `canary lint` — repo-specific static analysis for determinism and
//! ownership discipline (DESIGN.md §2.8).
//!
//! A token-level scanner over `rust/src/**` — no syntax tree, no
//! external crates, consistent with the workspace's zero-dependency
//! rule. Five rules guard the properties every figure, fingerprint pin
//! and CI determinism job in this repo rests on:
//!
//! - **D1 `unordered-iter`** — iterating a `HashMap`/`HashSet` binding
//!   observes the process-random hasher order, so any such iteration
//!   that can reach events, metrics or exported rows is a
//!   cross-process nondeterminism hazard. Sites must provably sort
//!   (a `.sort*` call on the same or a following line) or carry
//!   `// lint: allow(unordered-iter, <reason>)`.
//! - **D2 `wall-clock`** — `Instant`/`SystemTime` are allowed only in
//!   the bench/figure harness allowlist or under
//!   `// lint: allow(wall-clock, <reason>)`, and never in a file that
//!   defines `fn fingerprint` (no annotation can excuse that).
//! - **D3 `rng`** — all randomness flows through the seeded
//!   generators in `util/rng.rs`; ambient-entropy tokens
//!   (`thread_rng`, `OsRng`, `RandomState`, ...) are flagged.
//! - **D4 `fp-coverage`** — every counter field of the metrics
//!   structs must appear in `fingerprint()` or carry
//!   `// fp: excluded(<reason>)`, so new counters cannot silently
//!   escape the digest.
//! - **D5 `cli-doc`** — every flag in `main.rs`'s known-flag list
//!   must be documented as `--flag` in README.md.
//!
//! Annotations live in line comments on the flagged line or on a
//! comment-only line directly above it, and must carry a non-empty
//! reason — a bare `allow(...)` is itself a finding.

pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

/// Which rule produced a finding.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// D1: iteration over a hash-ordered container.
    UnorderedIter,
    /// D2: wall-clock type outside the allowlist.
    WallClock,
    /// D3: randomness outside `util/rng.rs`.
    Rng,
    /// D4: counter field missing from `fingerprint()`.
    FpCoverage,
    /// D5: CLI flag undocumented in README.md.
    CliDoc,
}

impl Rule {
    /// The annotation key / report tag for this rule.
    pub fn key(self) -> &'static str {
        match self {
            Rule::UnorderedIter => "unordered-iter",
            Rule::WallClock => "wall-clock",
            Rule::Rng => "rng",
            Rule::FpCoverage => "fp-coverage",
            Rule::CliDoc => "cli-doc",
        }
    }
}

/// One lint violation: file, 1-based line, rule and message.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.rule.key(),
            self.message
        )
    }
}

/// One physical source line, split into code and comment text. String
/// literal *contents* are blanked out of `code` (the quotes remain as
/// token boundaries) and collected into `strings` in order, so rules
/// never token-match prose and D5 can still read flag-name literals.
#[derive(Clone, Debug, Default)]
pub struct SourceLine {
    pub code: String,
    pub comment: String,
    pub strings: Vec<String>,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum St {
    Code,
    /// Inside `/* ... */`, with nesting depth.
    Block(u32),
    /// Inside a `"..."` literal.
    Str,
    /// Inside a raw string literal with this many `#`s.
    Raw(u8),
}

pub(crate) fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

fn raw_open(b: &[u8], i: usize) -> Option<u8> {
    // at b[i] == 'r': matches `r"` or `r#...#"`
    let mut j = i + 1;
    let mut hashes = 0u8;
    while b.get(j) == Some(&b'#') && hashes < u8::MAX {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some(hashes)
    } else {
        None
    }
}

fn closes_raw(b: &[u8], quote: usize, hashes: u8) -> bool {
    let mut j = quote + 1;
    for _ in 0..hashes {
        if b.get(j) != Some(&b'#') {
            return false;
        }
        j += 1;
    }
    true
}

/// Split Rust source into per-line code/comment/string-literal parts.
/// Byte-level state machine: line comments, nested block comments,
/// plain and raw strings, char literals vs. lifetimes. Multi-byte
/// UTF-8 only ever appears inside comments and strings here, where
/// fidelity does not matter for token matching.
pub fn split_source(text: &str) -> Vec<SourceLine> {
    let mut out = Vec::new();
    let mut st = St::Code;
    let mut lit = String::new();
    for raw in text.lines() {
        let b = raw.as_bytes();
        let mut line = SourceLine::default();
        let mut i = 0usize;
        while i < b.len() {
            match st {
                St::Code => {
                    let c = b[i];
                    if c == b'/' && b.get(i + 1) == Some(&b'/') {
                        line.comment.push_str(&raw[i + 2..]);
                        i = b.len();
                    } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
                        st = St::Block(1);
                        line.code.push(' ');
                        i += 2;
                    } else if c == b'"' {
                        st = St::Str;
                        line.code.push('"');
                        i += 1;
                    } else if c == b'r'
                        && (i == 0 || !is_ident_byte(b[i - 1]))
                        && raw_open(b, i).is_some()
                    {
                        let hashes = raw_open(b, i).unwrap_or(0);
                        st = St::Raw(hashes);
                        line.code.push('"');
                        i += 2 + hashes as usize;
                    } else if c == b'\'' {
                        // char literal vs. lifetime: a literal closes
                        // within a couple of bytes, a lifetime does not
                        if b.get(i + 1) == Some(&b'\\') {
                            let mut j = i + 2;
                            while j < b.len() && b[j] != b'\'' {
                                j += 1;
                            }
                            line.code.push_str("' '");
                            i = j + 1;
                        } else if b.get(i + 2) == Some(&b'\'') {
                            line.code.push_str("' '");
                            i += 3;
                        } else {
                            // lifetime marker
                            line.code.push('\'');
                            i += 1;
                        }
                    } else {
                        line.code.push(c as char);
                        i += 1;
                    }
                }
                St::Block(depth) => {
                    if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        st = if depth == 1 {
                            St::Code
                        } else {
                            St::Block(depth - 1)
                        };
                        i += 2;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        st = St::Block(depth + 1);
                        i += 2;
                    } else {
                        line.comment.push(b[i] as char);
                        i += 1;
                    }
                }
                St::Str => {
                    if b[i] == b'\\' {
                        if let Some(&e) = b.get(i + 1) {
                            lit.push(e as char);
                        }
                        i += 2;
                    } else if b[i] == b'"' {
                        line.strings.push(std::mem::take(&mut lit));
                        line.code.push('"');
                        st = St::Code;
                        i += 1;
                    } else {
                        lit.push(b[i] as char);
                        i += 1;
                    }
                }
                St::Raw(hashes) => {
                    if b[i] == b'"' && closes_raw(b, i, hashes) {
                        line.strings.push(std::mem::take(&mut lit));
                        line.code.push('"');
                        st = St::Code;
                        i += 1 + hashes as usize;
                    } else {
                        lit.push(b[i] as char);
                        i += 1;
                    }
                }
            }
        }
        if st == St::Str || matches!(st, St::Raw(_)) {
            lit.push('\n'); // literal continues on the next line
        }
        out.push(line);
    }
    out
}

/// Iterate the identifier tokens of a code fragment.
pub(crate) fn idents(code: &str) -> impl Iterator<Item = &str> {
    code.split(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
        .filter(|t| !t.is_empty())
}

/// Does `code` contain `word` as a whole identifier token?
pub(crate) fn has_ident(code: &str, word: &str) -> bool {
    idents(code).any(|t| t == word)
}

/// First whole-word position of `word` in `code`.
pub(crate) fn word_pos(code: &str, word: &str) -> Option<usize> {
    let b = code.as_bytes();
    let mut from = 0usize;
    while let Some(p) = code[from..].find(word) {
        let abs = from + p;
        from = abs + word.len();
        let before = abs == 0 || !is_ident_byte(b[abs - 1]);
        let end = abs + word.len();
        let after = end >= b.len() || !is_ident_byte(b[end]);
        if before && after {
            return Some(abs);
        }
    }
    None
}

/// The identifier ending immediately before byte `pos` (e.g. the
/// receiver of a `.method(` call), if any.
pub(crate) fn ident_before(code: &str, pos: usize) -> Option<&str> {
    let b = code.as_bytes();
    let mut start = pos;
    while start > 0 && is_ident_byte(b[start - 1]) {
        start -= 1;
    }
    if start == pos {
        None
    } else {
        Some(&code[start..pos])
    }
}

/// Parse `lint: allow(<key>[, reason])` out of a comment. `None` when
/// absent; `Some(reason)` (possibly empty — itself a finding) when
/// present.
pub(crate) fn allow_reason(comment: &str, key: &str) -> Option<String> {
    let pat = format!("lint: allow({key}");
    let pos = comment.find(&pat)?;
    let rest = &comment[pos + pat.len()..];
    match rest.as_bytes().first() {
        Some(b')') => Some(String::new()),
        Some(b',') => {
            let body = &rest[1..];
            let end = body.find(')').unwrap_or(body.len());
            Some(body[..end].trim().to_string())
        }
        _ => None,
    }
}

/// Parse `fp: excluded(<reason>)` out of a comment.
pub(crate) fn fp_excluded_reason(comment: &str) -> Option<String> {
    let pat = "fp: excluded(";
    let pos = comment.find(pat)?;
    let body = &comment[pos + pat.len()..];
    let end = body.find(')').unwrap_or(body.len());
    Some(body[..end].trim().to_string())
}

/// Annotation lookup for the site at `idx`: the line's own trailing
/// comment, or a comment-only line directly above.
pub(crate) fn site_annotation(
    lines: &[SourceLine],
    idx: usize,
    parse: impl Fn(&str) -> Option<String>,
) -> Option<String> {
    if let Some(r) = parse(&lines[idx].comment) {
        return Some(r);
    }
    if idx > 0 && lines[idx - 1].code.trim().is_empty() {
        return parse(&lines[idx - 1].comment);
    }
    None
}

/// Push either nothing (annotated with a reason), a missing-reason
/// finding, or the base finding for the site at `idx`.
pub(crate) fn report_site(
    out: &mut Vec<Finding>,
    lines: &[SourceLine],
    file: &str,
    idx: usize,
    rule: Rule,
    message: String,
) {
    let key = rule.key();
    let ann = site_annotation(lines, idx, |c| allow_reason(c, key));
    match ann {
        Some(reason) if !reason.is_empty() => {}
        Some(_) => out.push(Finding {
            file: file.to_string(),
            line: idx + 1,
            rule,
            message: format!(
                "`lint: allow({key})` needs a reason: \
                 `allow({key}, <why>)`"
            ),
        }),
        None => out.push(Finding {
            file: file.to_string(),
            line: idx + 1,
            rule,
            message,
        }),
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn rel_name(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.to_string_lossy().replace('\\', "/")
}

/// Lint every `.rs` file under `<root>/src` (D1–D4) plus the CLI/doc
/// sync rule (D5) against `<root>/src/main.rs` and the repository
/// README. Findings come back sorted by (file, line, rule) so output
/// is deterministic and diffable.
pub fn lint_tree(root: &Path) -> Vec<Finding> {
    let mut files = Vec::new();
    collect_rs(&root.join("src"), &mut files);
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let Ok(text) = fs::read_to_string(path) else {
            continue;
        };
        let rel = rel_name(root, path);
        findings.extend(rules::lint_source(&rel, &text));
    }
    findings.extend(rules::lint_cli_docs(root));
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(text: &str) -> Vec<String> {
        split_source(text).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn comments_are_stripped_from_code() {
        let c = code_of("let x = 1; // HashMap here\n/* for y in z */ ok");
        assert!(!c[0].contains("HashMap"), "{c:?}");
        assert!(!c[1].contains("for"), "{c:?}");
        assert!(c[1].contains("ok"), "{c:?}");
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let c = code_of("a /* x /* y */ z */ b\n/* open\nstill */ tail");
        assert!(c[0].contains('a') && c[0].contains('b'), "{c:?}");
        assert!(!c[0].contains('z'), "{c:?}");
        assert!(c[1].is_empty() || c[1].trim().is_empty(), "{c:?}");
        assert!(c[2].contains("tail"), "{c:?}");
    }

    #[test]
    fn string_contents_are_blanked_but_collected() {
        let lines = split_source("print(\"for x in map.iter()\"); y");
        assert!(!lines[0].code.contains("iter"), "{:?}", lines[0]);
        assert!(lines[0].code.contains('y'));
        assert_eq!(lines[0].strings, vec!["for x in map.iter()"]);
    }

    #[test]
    fn raw_strings_and_escapes() {
        let lines = split_source("let s = r#\"a \"quoted\" b\"#; t");
        assert_eq!(lines[0].strings, vec!["a \"quoted\" b"]);
        assert!(lines[0].code.contains('t'));
        let esc = split_source("let s = \"a\\\"b\"; u");
        assert_eq!(esc[0].strings, vec!["a\"b"]);
        assert!(esc[0].code.contains('u'));
    }

    #[test]
    fn char_literals_are_not_strings_or_lifetimes() {
        let lines = split_source("let c = '\"'; let s = \"x\"; f::<'a>()");
        assert_eq!(lines[0].strings, vec!["x"]);
        assert!(lines[0].code.contains("f::<'a>()"), "{:?}", lines[0]);
    }

    #[test]
    fn ident_matching_is_whole_word() {
        assert!(has_ident("for x in map { }", "map"));
        assert!(!has_ident("for x in remap { }", "map"));
        assert!(!has_ident("for x in map_b { }", "map"));
        assert_eq!(word_pos("x formula for y", "for"), Some(10));
    }

    #[test]
    fn annotation_grammar() {
        assert_eq!(
            allow_reason(" lint: allow(unordered-iter, sorted below)", "unordered-iter"),
            Some("sorted below".to_string())
        );
        assert_eq!(
            allow_reason(" lint: allow(unordered-iter)", "unordered-iter"),
            Some(String::new())
        );
        assert_eq!(allow_reason(" lint: allow(rngx)", "rng"), None);
        assert_eq!(
            fp_excluded_reason(" fp: excluded(derived)"),
            Some("derived".to_string())
        );
    }
}
