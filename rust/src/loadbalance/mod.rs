//! Congestion-aware traffic load balancing (paper Section 2.1, 5.2).
//!
//! Canary is orthogonal to the load-balancing algorithm; switches can use
//! any scheme to pick the next hop toward the root/leader. We implement
//! the paper's simulated default (send on a destination-derived default
//! up-port unless its queue occupancy exceeds 50 %, then pick the up-port
//! with the fewest enqueued bytes), plus ECMP, per-packet min-queue
//! (DRILL-like), and flowlet switching (CONGA/LetFlow-like) for the
//! ablation benches.

use std::collections::HashMap;

use crate::sim::{Ctx, Time};
use crate::util::rng::splitmix64;

/// Load-balancing policy for a switch's up-ports.
#[derive(Clone, Debug)]
pub enum LoadBalancer {
    /// Paper default: destination-hash default port; if its occupancy
    /// exceeds `threshold` (0.5 in the paper), re-route to the up-port
    /// with the fewest enqueued bytes.
    DefaultAdaptive { threshold: f64 },
    /// Congestion-oblivious hash of the flow label.
    Ecmp,
    /// Per-packet least-loaded port (maximal adaptivity).
    MinQueue,
    /// Flowlet switching: a flow re-picks the least-loaded port only
    /// after an idle gap, otherwise stays put (avoids reordering).
    Flowlet { gap_ps: Time },
}

impl Default for LoadBalancer {
    fn default() -> Self {
        LoadBalancer::DefaultAdaptive { threshold: 0.5 }
    }
}

/// Flowlet-table sweeps run every this many selections (amortizes the
/// `retain` scan to O(1) per packet).
const FLOWLET_SWEEP_EVERY: u32 = 1024;
/// Entries idle for more than this many flowlet gaps are evicted. Any
/// entry past *one* gap already re-picks its port on the next packet,
/// so eviction at 4 gaps can never change a routing decision — it only
/// bounds the table.
const FLOWLET_EVICT_GAPS: u64 = 4;

/// Mutable per-switch LB state (only flowlets need any).
#[derive(Clone, Debug, Default)]
pub struct LbState {
    /// flow -> (up-port offset, last-seen time)
    flowlets: HashMap<u64, (u16, Time)>,
    /// Selections since the last stale-entry sweep.
    since_sweep: u32,
    /// Reconvergence counter: selections where the port the policy
    /// would otherwise have used (hash default, cached flowlet port)
    /// was dead and the selector re-routed around it. Stays zero on a
    /// healthy fabric — congestion-driven re-picks don't count.
    pub dead_reroutes: u64,
}

impl LbState {
    /// Live flowlet-table entries (eviction bound, `tests`).
    pub fn flowlet_count(&self) -> usize {
        self.flowlets.len()
    }

    /// Amortized eviction of stale entries: every
    /// [`FLOWLET_SWEEP_EVERY`] selections, drop entries idle longer
    /// than [`FLOWLET_EVICT_GAPS`] flowlet gaps. Without this the
    /// table grows monotonically with every flow the switch ever saw
    /// (long runs leak memory and slow the hash map).
    fn maybe_sweep(&mut self, now: Time, gap_ps: Time) {
        self.since_sweep += 1;
        if self.since_sweep < FLOWLET_SWEEP_EVERY {
            return;
        }
        self.since_sweep = 0;
        let cutoff = FLOWLET_EVICT_GAPS * gap_ps;
        // lint: allow(unordered-iter, pure idle-cutoff predicate; no per-entry side effects)
        self.flowlets
            .retain(|_, &mut (_, last)| now.saturating_sub(last) <= cutoff);
    }
}

/// Pick an up-port offset in `[0, n_up)` for a packet with flow label
/// `flow`, destination-derived default `dflt`, and traffic `class`
/// (0 = reduction/control, 1 = background).
///
/// Signals are **per class** (virtual-channel occupancy, as in the
/// paper's SST/merlin substrate): a flow reacts to its own class's
/// congestion on each port. Service is a single shared FIFO, so classes
/// share the line rate proportionally to their arrivals.
pub fn select_up(
    lb: &LoadBalancer,
    state: &mut LbState,
    ctx: &Ctx,
    up_base_port: u16,
    n_up: u16,
    dflt: u16,
    flow: u64,
    class: usize,
) -> u16 {
    debug_assert!(n_up > 0 && dflt < n_up);
    // dead up-links are never a valid choice (link-level liveness is
    // what real adaptive fabrics key off after a failure)
    let alive = |off: u16| ctx.port_alive(up_base_port + off);
    match lb {
        LoadBalancer::DefaultAdaptive { threshold } => {
            let dead = !alive(dflt);
            if dead
                || ctx.port_class_occupancy(up_base_port + dflt, class)
                    > *threshold
            {
                if dead {
                    state.dead_reroutes += 1;
                }
                min_queue_port(ctx, up_base_port, n_up, class)
            } else {
                dflt
            }
        }
        LoadBalancer::Ecmp => {
            let mut h = flow ^ 0x9E37_79B9_7F4A_7C15;
            let port = (splitmix64(&mut h) % n_up as u64) as u16;
            if alive(port) {
                port
            } else {
                state.dead_reroutes += 1;
                min_queue_port(ctx, up_base_port, n_up, class)
            }
        }
        // MinQueue has no sticky choice to reconverge from — it already
        // skips dead ports on every selection
        LoadBalancer::MinQueue => {
            min_queue_port(ctx, up_base_port, n_up, class)
        }
        LoadBalancer::Flowlet { gap_ps } => {
            let now = ctx.now;
            state.maybe_sweep(now, *gap_ps);
            let entry = state.flowlets.get(&flow).copied();
            let port = match entry {
                // a live cached port within the gap sticks; a dead one
                // breaks the flowlet immediately (reconvergence)
                Some((p, last)) if now.saturating_sub(last) <= *gap_ps => {
                    if alive(p) {
                        p
                    } else {
                        state.dead_reroutes += 1;
                        min_queue_port(ctx, up_base_port, n_up, class)
                    }
                }
                _ => min_queue_port(ctx, up_base_port, n_up, class),
            };
            state.flowlets.insert(flow, (port, now));
            port
        }
    }
}

/// Live up-port offset with the fewest enqueued bytes of this class
/// (ties -> lowest index, keeping runs deterministic). Falls back to
/// port 0 if all are dead (the packet will be dropped at the link —
/// nothing better exists).
fn min_queue_port(
    ctx: &Ctx,
    up_base_port: u16,
    n_up: u16,
    class: usize,
) -> u16 {
    let mut best = 0u16;
    let mut best_bytes = u64::MAX;
    for off in 0..n_up {
        if !ctx.port_alive(up_base_port + off) {
            continue;
        }
        let b = ctx.port_class_bytes(up_base_port + off, class);
        if b < best_bytes {
            best_bytes = b;
            best = off;
        }
    }
    best
}

/// Parse a policy name from CLI/config text.
pub fn parse_policy(name: &str) -> Result<LoadBalancer, String> {
    match name {
        "adaptive" | "default" => {
            Ok(LoadBalancer::DefaultAdaptive { threshold: 0.5 })
        }
        "ecmp" => Ok(LoadBalancer::Ecmp),
        "minqueue" | "drill" => Ok(LoadBalancer::MinQueue),
        "flowlet" => Ok(LoadBalancer::Flowlet {
            gap_ps: 5 * crate::sim::US,
        }),
        other => Err(format!(
            "unknown load balancer '{other}' \
             (adaptive|ecmp|minqueue|flowlet)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flowlet_table_evicts_stale_entries() {
        // drive maybe_sweep directly (select_up needs a full Ctx): many
        // distinct flows touch the table, time advances past the
        // eviction horizon, and the sweep bounds the map
        let gap = 5 * crate::sim::US;
        let mut state = LbState::default();
        let mut now: Time = 0;
        for flow in 0..10_000u64 {
            now += crate::sim::US; // 1 us between new flows
            state.flowlets.insert(flow, (0, now));
            state.maybe_sweep(now, gap);
        }
        // only flows seen within the last 4 gaps (20 us) may survive a
        // sweep; the table must be far below the 10k flows ever seen
        assert!(
            state.flowlet_count() < 2 * FLOWLET_SWEEP_EVERY as usize,
            "flowlet table leaked: {} entries",
            state.flowlet_count()
        );
        // entries inside the idle horizon survive
        let mut fresh = LbState::default();
        fresh.flowlets.insert(7, (3, 100));
        for _ in 0..FLOWLET_SWEEP_EVERY {
            fresh.maybe_sweep(200, gap);
        }
        assert_eq!(fresh.flowlet_count(), 1, "live entry evicted");
    }

    #[test]
    fn parse_names() {
        assert!(matches!(
            parse_policy("adaptive").unwrap(),
            LoadBalancer::DefaultAdaptive { .. }
        ));
        assert!(matches!(parse_policy("ecmp").unwrap(), LoadBalancer::Ecmp));
        assert!(matches!(
            parse_policy("drill").unwrap(),
            LoadBalancer::MinQueue
        ));
        assert!(parse_policy("nope").is_err());
    }
}
