//! # Canary-RS
//!
//! Full-system reproduction of *"Canary: Congestion-Aware In-Network
//! Allreduce Using Dynamic Trees"* (De Sensi et al., 2023).
//!
//! Three layers (see DESIGN.md §1):
//!
//! - **L3 (this crate)**: the coordinator — a packet-level discrete-event
//!   simulator of multi-tier Clos fabrics (the paper's 2-tier fat tree
//!   and oversubscribed 3-tier pod networks, [`topology`]), the Canary
//!   switch dataplane and host/leader protocol, the static-tree and
//!   ring baselines, a flow-level traffic engine with adversarial
//!   congestion patterns ([`traffic`]), the figure/bench harness, and a
//!   data-parallel trainer that drives real gradients through the
//!   simulated network.
//! - **L2 (python/compile/model.py)**: a JAX transformer LM whose
//!   train-step is AOT-lowered to HLO text and executed from Rust via
//!   PJRT ([`runtime`]).
//! - **L1 (python/compile/kernels/)**: Pallas kernels for the switch-ALU
//!   saturating aggregation and fixed-point quantization, mirrored
//!   bit-for-bit by [`switch::alu`].
//!
//! Quick start:
//!
//! ```no_run
//! use canary::collectives::{runner, Algo, Collective};
//! use canary::workload::{JobBuilder, Placement, ScenarioBuilder};
//!
//! // the paper's single-allreduce protocol...
//! let sc = ScenarioBuilder::paper_default(Algo::Canary);
//! let mut exp = sc.build(42);
//! let results = runner::run_to_completion(&mut exp.net, u64::MAX);
//! println!("goodput: {:?} Gbps", results[0].goodput_gbps);
//!
//! // ...or any mix of collectives, placements and tenants
//! let sc = ScenarioBuilder::new(canary::config::ClosConfig::small())
//!     .job(
//!         JobBuilder::new(Algo::Canary)
//!             .collective(Collective::Reduce { root: 0 })
//!             .hosts(16)
//!             .placement(Placement::ClusteredByLeaf),
//!     )
//!     .job(JobBuilder::new(Algo::Ring).hosts(8).start_at(5_000_000));
//! let mut exp = sc.build(7);
//! runner::run_to_completion(&mut exp.net, u64::MAX);
//! ```

pub mod collectives;
pub mod config;
pub mod faults;
pub mod figures;
pub mod host;
pub mod lint;
pub mod loadbalance;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod switch;
pub mod topology;
pub mod trace;
pub mod traffic;
pub mod train;
pub mod transport;
pub mod util;
pub mod workload;
