//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and execute them from Rust.
//!
//! HLO **text** is the interchange format — the `xla` crate's
//! xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit ids),
//! while the text parser reassigns ids. Python never runs at request
//! time: after `make artifacts` the Rust binary is self-contained.
//!
//! In this offline build the PJRT bindings are a vendored stub
//! ([`xla`], DESIGN.md §7): manifests still parse, but compiling or
//! executing artifacts reports PJRT as unavailable and every caller
//! (trainer, parity tests, `canary info`) degrades gracefully.

pub mod xla;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Error, Result};
use crate::util::json::{self, Value};

/// Parsed `manifest.json`: artifact signatures + model configs + golden
/// parity vectors.
#[derive(Debug)]
pub struct Manifest {
    pub packet_lanes: usize,
    pub artifacts: BTreeMap<String, ArtifactSig>,
    pub models: BTreeMap<String, ModelInfo>,
}

/// One artifact's file and I/O signature.
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Model hyper-parameters from the manifest.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub frac_bits: u32,
    pub param_count: usize,
}

fn tensor_sig(v: &Value) -> Result<TensorSig> {
    Ok(TensorSig {
        dtype: v
            .expect("dtype")
            .as_str()
            .ok_or_else(|| Error::msg("dtype not a string"))?
            .to_string(),
        shape: v
            .expect("shape")
            .int_vec()
            .ok_or_else(|| Error::msg("shape not ints"))?
            .into_iter()
            .map(|i| i as usize)
            .collect(),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.json — run `make artifacts` first",
                    dir.display()
                )
            })?;
        let v = json::parse(&text).map_err(|e| Error::msg(format!("manifest: {e}")))?;
        let mut artifacts = BTreeMap::new();
        for (name, art) in v
            .expect("artifacts")
            .as_object()
            .ok_or_else(|| Error::msg("artifacts not an object"))?
        {
            let inputs = art
                .expect("inputs")
                .as_array()
                .unwrap()
                .iter()
                .map(tensor_sig)
                .collect::<Result<Vec<_>>>()?;
            let outputs = art
                .expect("outputs")
                .as_array()
                .unwrap()
                .iter()
                .map(tensor_sig)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    file: art.expect("file").as_str().unwrap().to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        let mut models = BTreeMap::new();
        for (name, m) in v
            .expect("models")
            .as_object()
            .ok_or_else(|| Error::msg("models not an object"))?
        {
            let get = |k: &str| -> Result<usize> {
                Ok(m.expect(k)
                    .as_i64()
                    .ok_or_else(|| Error::msg(format!("{k} not an int")))?
                    as usize)
            };
            models.insert(
                name.clone(),
                ModelInfo {
                    vocab: get("vocab")?,
                    d_model: get("d_model")?,
                    n_layers: get("n_layers")?,
                    seq_len: get("seq_len")?,
                    batch: get("batch")?,
                    frac_bits: get("frac_bits")? as u32,
                    param_count: get("param_count")?,
                },
            );
        }
        Ok(Manifest {
            packet_lanes: v.expect("packet_lanes").as_i64().unwrap() as usize,
            artifacts,
            models,
        })
    }
}

/// A compiled artifact, ready to execute.
pub struct Executable {
    pub name: String,
    pub sig: ArtifactSig,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the un-tupled outputs.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.sig.inputs.len() {
            return Err(Error::msg(format!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.sig.inputs.len(),
                inputs.len()
            )));
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True
        Ok(result.to_tuple()?)
    }
}

/// The PJRT runtime: a CPU client plus the artifact directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client and read the manifest.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            manifest,
        })
    }

    /// Default artifact dir: `$CANARY_ARTIFACTS` or `<crate>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("CANARY_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            })
    }

    /// Load + compile one artifact by manifest name.
    pub fn compile(&self, name: &str) -> Result<Executable> {
        let sig = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| Error::msg(format!("no artifact named '{name}'")))?
            .clone();
        let path = self.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::msg("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable {
            name: name.to_string(),
            sig,
            exe,
        })
    }
}

// ---- literal marshalling helpers ------------------------------------------

/// f32 slice -> rank-1 literal.
pub fn lit_f32(xs: &[f32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// i32 slice -> rank-1 literal.
pub fn lit_i32(xs: &[i32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// i32 slice -> rank-2 literal of `[rows, cols]`.
pub fn lit_i32_2d(xs: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(xs.len(), rows * cols);
    Ok(xla::Literal::vec1(xs).reshape(&[rows as i64, cols as i64])?)
}

/// f32 scalar literal.
pub fn lit_f32_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// u32 scalar literal.
pub fn lit_u32_scalar(x: u32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Literal -> Vec<f32>.
pub fn to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Literal -> Vec<i32>.
pub fn to_i32(l: &xla::Literal) -> Result<Vec<i32>> {
    Ok(l.to_vec::<i32>()?)
}

/// Scalar literal -> f32.
pub fn to_f32_scalar(l: &xla::Literal) -> Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}
