//! Offline stand-in for the `xla` PJRT bindings (DESIGN.md §7).
//!
//! The real integration loads HLO text through xla_extension's PJRT CPU
//! client; that crate (and its ~GB native bundle) is not vendorable in
//! this offline build environment. This module keeps the exact API
//! surface [`crate::runtime`] consumes so the crate, its tests and the
//! trainer all compile and run — every PJRT entry point returns a clear
//! "unavailable" error, and callers ([`crate::runtime::Runtime::load`],
//! the `pjrt_parity` tests, `canary train`) already degrade gracefully
//! when the runtime cannot come up. Swapping this file for
//! `use xla::*;` of the real crate restores bit-parity execution.

use crate::util::error::{Error, Result};

const UNAVAILABLE: &str = "PJRT unavailable: this build vendors a stub \
     for the `xla` crate (offline environment, DESIGN.md §7); native \
     kernel execution runs via python/compile instead";

fn unavailable<T>() -> Result<T> {
    Err(Error::msg(UNAVAILABLE))
}

/// Host-side tensor handle (stub).
#[derive(Clone, Debug)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_xs: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: Copy>(_x: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        unavailable()
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// PJRT client handle (stub); [`PjRtClient::cpu`] always errors, which
/// is what gates every downstream PJRT path.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(
        &self,
        _inputs: &[Literal],
    ) -> Result<Vec<Vec<Literal>>> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(Literal::vec1(&[1i32]).to_vec::<i32>().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
