//! Generational packet arena: slab storage + free list for every
//! in-flight [`Packet`], so the event hot path never touches the
//! allocator (EXPERIMENTS.md §Perf).
//!
//! Packets used to ride the event heap as `Box<Packet>` — one
//! malloc/free per link hop, the second-largest cost in the event loop
//! after the heap itself. Now the simulator core owns all live packets
//! in one `Vec` of slots; events and port queues carry a copyable
//! 8-byte [`PacketId`] and the arena recycles freed slots through a
//! free list, so steady-state forwarding performs zero heap
//! allocations (payload lanes, when carried, keep their own box and
//! move with the packet).
//!
//! Ids are **generational**: each slot counts how many times it has
//! been reused, and an id is only valid while its generation matches
//! the slot's. A stale id (kept across a free, e.g. by a buggy handler
//! that both forwards and frees) can therefore never alias the
//! unrelated packet that now occupies the slot — `get`/`try_take`
//! return `None`, the panicking accessors abort loudly
//! (`tests/scheduler.rs` pins the rejection).

use super::packet::Packet;

/// Handle to a live packet in the [`PacketArena`]. Small and `Copy`:
/// this is what `Event::Arrive` and the link FIFOs carry.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PacketId {
    index: u32,
    generation: u32,
}

struct Slot {
    generation: u32,
    packet: Option<Packet>,
}

/// Slab of all in-flight packets, with generational reuse.
#[derive(Default)]
pub struct PacketArena {
    slots: Vec<Slot>,
    free: Vec<u32>,
    live: u32,
    peak_live: u32,
    allocs: u64,
}

impl PacketArena {
    pub fn new() -> PacketArena {
        PacketArena::default()
    }

    /// Store `packet`, reusing a freed slot when one exists (steady
    /// state: the free list covers every alloc, so the slab never
    /// grows past the peak number of simultaneously live packets).
    pub fn alloc(&mut self, packet: Packet) -> PacketId {
        self.allocs += 1;
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
        match self.free.pop() {
            Some(index) => {
                let slot = &mut self.slots[index as usize];
                debug_assert!(slot.packet.is_none());
                slot.packet = Some(packet);
                PacketId {
                    index,
                    generation: slot.generation,
                }
            }
            None => {
                let index = self.slots.len() as u32;
                self.slots.push(Slot {
                    generation: 0,
                    packet: Some(packet),
                });
                PacketId {
                    index,
                    generation: 0,
                }
            }
        }
    }

    /// Shared access; `None` if `id` is stale (freed slot or recycled
    /// generation).
    pub fn get(&self, id: PacketId) -> Option<&Packet> {
        let slot = self.slots.get(id.index as usize)?;
        if slot.generation != id.generation {
            return None;
        }
        slot.packet.as_ref()
    }

    /// Mutable access; `None` if `id` is stale.
    pub fn get_mut(&mut self, id: PacketId) -> Option<&mut Packet> {
        let slot = self.slots.get_mut(id.index as usize)?;
        if slot.generation != id.generation {
            return None;
        }
        slot.packet.as_mut()
    }

    /// Move the packet out and retire the slot (its generation bumps,
    /// so `id` — and any copy of it — is dead from here on).
    pub fn try_take(&mut self, id: PacketId) -> Option<Packet> {
        let slot = self.slots.get_mut(id.index as usize)?;
        if slot.generation != id.generation {
            return None;
        }
        let packet = slot.packet.take()?;
        slot.generation = slot.generation.wrapping_add(1);
        self.free.push(id.index);
        self.live -= 1;
        Some(packet)
    }

    /// Like [`try_take`](Self::try_take) but treats a stale id as the
    /// engine bug it is.
    pub fn take(&mut self, id: PacketId) -> Packet {
        self.try_take(id)
            .unwrap_or_else(|| panic!("stale {id:?} taken from arena"))
    }

    /// Drop the packet behind `id` (loss paths: dead links, policer,
    /// fault injection).
    pub fn free(&mut self, id: PacketId) {
        let p = self.try_take(id);
        debug_assert!(p.is_some(), "stale {id:?} freed");
        drop(p);
    }

    /// Packets currently in flight (events + port queues).
    pub fn live(&self) -> u32 {
        self.live
    }

    /// High-water mark of simultaneously live packets.
    pub fn peak_live(&self) -> u32 {
        self.peak_live
    }

    /// Slots ever created — the arena's memory footprint, equal to
    /// [`peak_live`](Self::peak_live) by construction (the free list
    /// absorbs all churn).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Total allocations served (slab growth + free-list reuse).
    pub fn allocs(&self) -> u64 {
        self.allocs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::packet::PacketKind;

    fn pkt(dst: u32) -> Packet {
        Packet::data(PacketKind::Background, 0, dst)
    }

    #[test]
    fn alloc_take_roundtrip() {
        let mut a = PacketArena::new();
        let id = a.alloc(pkt(7));
        assert_eq!(a.live(), 1);
        assert_eq!(a.get(id).unwrap().dst, 7);
        let p = a.take(id);
        assert_eq!(p.dst, 7);
        assert_eq!(a.live(), 0);
    }

    #[test]
    fn slots_recycle_through_the_free_list() {
        let mut a = PacketArena::new();
        for i in 0..100 {
            let id = a.alloc(pkt(i));
            a.free(id);
        }
        assert_eq!(a.slot_count(), 1, "one slot serves serial churn");
        assert_eq!(a.peak_live(), 1);
        assert_eq!(a.allocs(), 100);
    }

    #[test]
    fn stale_generation_is_rejected() {
        let mut a = PacketArena::new();
        let id = a.alloc(pkt(1));
        a.free(id);
        let recycled = a.alloc(pkt(2));
        assert_eq!(recycled.index, id.index, "slot was recycled");
        assert!(a.get(id).is_none(), "stale id must not read the new packet");
        assert!(a.get_mut(id).is_none());
        assert!(a.try_take(id).is_none());
        assert_eq!(a.get(recycled).unwrap().dst, 2);
    }

    #[test]
    fn peak_tracks_simultaneous_liveness() {
        let mut a = PacketArena::new();
        let ids: Vec<PacketId> = (0..5).map(|i| a.alloc(pkt(i))).collect();
        assert_eq!(a.peak_live(), 5);
        for id in ids {
            a.free(id);
        }
        assert_eq!(a.live(), 0);
        assert_eq!(a.peak_live(), 5);
        assert_eq!(a.slot_count(), 5);
    }

    #[test]
    #[should_panic(expected = "stale")]
    fn take_panics_on_double_free() {
        let mut a = PacketArena::new();
        let id = a.alloc(pkt(0));
        a.free(id);
        a.take(id);
    }
}
