//! Packet-level discrete-event network simulator.
//!
//! This is the substrate standing in for the paper's modified SST setup
//! (Section 5.2): output-queued switches, links with serialization +
//! propagation delay, 100 Gbps ports, and hosts that inject at line rate.
//! Time is in integer **picoseconds** (1 byte at 100 Gbps = 80 ps), so all
//! scheduling is exact and runs are bit-reproducible.

pub mod arena;
pub mod event;
pub mod invariants;
pub mod network;
pub mod packet;
pub mod shard;

pub use arena::{PacketArena, PacketId};
pub use event::{Event, EventQueue};
pub use network::{Ctx, Link, LinkId, Network, Node, NodeBody, NodeId};
pub use packet::{Packet, PacketKind, Payload};

/// Simulation time in picoseconds.
pub type Time = u64;

/// Picoseconds per nanosecond/microsecond/millisecond.
pub const NS: Time = 1_000;
pub const US: Time = 1_000_000;
pub const MS: Time = 1_000_000_000;

/// 100 Gbps = 12.5 bytes/ns -> 80 ps per byte.
pub const PS_PER_BYTE_100G: u64 = 80;

/// Convert picoseconds to fractional microseconds (for reporting).
pub fn ps_to_us(ps: Time) -> f64 {
    ps as f64 / US as f64
}

/// Goodput in Gbit/s for `bytes` of application data moved in `ps`.
pub fn goodput_gbps(bytes: u64, ps: Time) -> f64 {
    if ps == 0 {
        return 0.0;
    }
    (bytes as f64 * 8.0) / (ps as f64 / 1000.0) // bits / ns = Gbps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversions() {
        assert_eq!(NS * 1000, US);
        assert_eq!(US * 1000, MS);
        // 1250 bytes at 100G = 100 ns
        assert_eq!(1250 * PS_PER_BYTE_100G, 100 * NS);
    }

    #[test]
    fn goodput_math() {
        // 12.5 GB in 1 s = 100 Gbps
        let gbps = goodput_gbps(12_500_000_000, 1_000_000 * US);
        assert!((gbps - 100.0).abs() < 1e-9);
        assert_eq!(goodput_gbps(10, 0), 0.0);
    }
}
