//! End-of-run conservation audit (DESIGN.md §2.8) — the runtime half
//! of the `canary lint` discipline pass.
//!
//! [`audit`] recomputes, from scratch, every piece of state the
//! simulator otherwise maintains incrementally, and cross-checks the
//! two: per-link byte accounting against the actual FIFO contents,
//! PFC pause refcounts against the pausing links, the packet-arena
//! ownership contract (every live id referenced exactly once, by a
//! link FIFO or an in-flight `Arrive` event), and the descriptor
//! ledger against the switch tables. On a fault-free drained run it
//! additionally demands that everything emptied.
//!
//! [`enforce`] runs at the end of every `Network::run`/`run_all`
//! segment in debug builds, and in release builds when
//! `SimConfig::paranoid` is set (`--paranoid` on the CLI). The audit
//! is read-only — no RNG draws, no event scheduling — so a paranoid
//! run fingerprints identically to a normal one.

use std::collections::HashSet;

use super::arena::PacketId;
use super::event::Event;
use super::network::{Network, NodeBody};

/// Run every conservation check. `Ok(())` or the full list of
/// violations (all checks run; nothing short-circuits, so a failure
/// report localizes the bug as tightly as possible).
pub fn audit(net: &Network) -> Result<(), Vec<String>> {
    let mut v: Vec<String> = Vec::new();

    // 1. Per-link byte accounting, recomputed from the FIFO itself.
    for (li, link) in net.links.iter().enumerate() {
        let mut by_class = [0u64; 2];
        for q in &link.queue {
            by_class[q.class as usize] += q.bytes as u64;
        }
        let total = by_class[0] + by_class[1];
        if total != link.queued_bytes {
            v.push(format!(
                "link {li}: queued_bytes {} != {total} recomputed \
                 from the FIFO",
                link.queued_bytes
            ));
        }
        if by_class != link.class_bytes {
            v.push(format!(
                "link {li}: class_bytes {:?} != recomputed {by_class:?}",
                link.class_bytes
            ));
        }
        if link.busy && link.queue.is_empty() {
            v.push(format!("link {li}: busy with an empty FIFO"));
        }
        if link.alive != (link.down_refs == 0) {
            v.push(format!(
                "link {li}: alive={} inconsistent with down_refs={}",
                link.alive, link.down_refs
            ));
        }
    }

    // 2. PFC pause refcounts: node_paused[n] must equal the number of
    // currently-pausing output links of n.
    for (n, &paused) in net.node_paused.iter().enumerate() {
        let actual = net
            .links
            .iter()
            .filter(|l| l.from as usize == n && l.pausing)
            .count() as u32;
        if paused != actual {
            v.push(format!(
                "node {n}: node_paused={paused} but {actual} output \
                 links are pausing"
            ));
        }
    }

    // 3. Arena ownership: every live packet id is held exactly once,
    // by a link FIFO entry or a pending Arrive event.
    let mut seen: HashSet<PacketId> = HashSet::new();
    let mut refs: u32 = 0;
    let mut dups: u32 = 0;
    let mut stale: u32 = 0;
    {
        let mut note = |id: PacketId| {
            refs += 1;
            if !seen.insert(id) {
                dups += 1;
            }
            if net.arena.get(id).is_none() {
                stale += 1;
            }
        };
        for link in &net.links {
            for q in &link.queue {
                note(q.id);
            }
        }
        net.queue.for_each_pending(|ev| {
            if let Event::Arrive { packet, .. } = ev {
                note(*packet);
            }
        });
    }
    if dups > 0 {
        v.push(format!("arena: {dups} packet id(s) referenced twice"));
    }
    if stale > 0 {
        v.push(format!(
            "arena: {stale} stale packet id(s) still referenced \
             (freed while queued)"
        ));
    }
    if refs != net.arena.live() {
        v.push(format!(
            "arena: {} live slot(s) but {refs} reference(s) in FIFOs \
             and pending events (leak or double-free)",
            net.arena.live()
        ));
    }

    // 4. Descriptor ledger. The live gauge must always equal
    // allocated - freed; the switch tables must account for every
    // live descriptor unless a switch failure cleared soft state
    // without going through the metric hooks (clear_soft_state).
    let m = &net.metrics;
    if m.descriptors_freed > m.descriptors_allocated {
        v.push(format!(
            "descriptors: freed {} > allocated {}",
            m.descriptors_freed, m.descriptors_allocated
        ));
    }
    let balance = m.descriptors_allocated.saturating_sub(m.descriptors_freed);
    if m.descriptors_live != balance {
        v.push(format!(
            "descriptors: live gauge {} != allocated - freed = {balance}",
            m.descriptors_live
        ));
    }
    if m.switch_failures == 0 {
        let mut table_live: u64 = 0;
        for node in &net.nodes {
            if let NodeBody::Switch(sw) = &node.body {
                table_live += sw.canary.live_descriptors() as u64;
                table_live += sw.static_tree.inflight.len() as u64;
            }
        }
        if table_live != m.descriptors_live {
            v.push(format!(
                "descriptors: {table_live} resident in switch tables \
                 but live gauge says {}",
                m.descriptors_live
            ));
        }
    }

    // 5. A fault-free run that drained its event queue with every
    // allreduce finished must have emptied everything: stranded
    // descriptors or live packets here are leaks, full stop. (Faulted
    // runs legitimately strand descriptors — a lost broadcast leaves
    // table entries behind by design — so they are exempt. So is a
    // single shard of a space-parallel run: its local queue can drain
    // while packets it still hosts are waiting on traffic from other
    // shards — the merged network passes through here afterwards with
    // `shard == None` and gets the full check.)
    let clean = m.switch_failures == 0
        && m.link_flaps == 0
        && m.drops_injected == 0
        && m.drops_link_down == 0
        && m.jobs_stalled == 0;
    let drained = net.shard.is_none()
        && net.queue.is_empty()
        && !net.jobs.is_empty()
        && net.all_reduce_jobs_done();
    if clean && drained {
        if net.arena.live() != 0 {
            v.push(format!(
                "drained clean run: {} packet(s) still live in the \
                 arena",
                net.arena.live()
            ));
        }
        if m.descriptors_live != 0 {
            v.push(format!(
                "drained clean run: {} descriptor(s) still live",
                m.descriptors_live
            ));
        }
    }

    if v.is_empty() {
        Ok(())
    } else {
        Err(v)
    }
}

/// Panic with the full violation list if [`audit`] fails. Called at
/// the end of every run segment in debug builds and under
/// `--paranoid`.
pub fn enforce(net: &Network) {
    if let Err(violations) = audit(net) {
        panic!(
            "conservation audit failed, {} violation(s):\n  {}",
            violations.len(),
            violations.join("\n  ")
        );
    }
}
