//! Space-parallel sharded event engine: conservative bounded-window
//! PDES over the pod/leaf-group partition (DESIGN.md §2.10).
//!
//! The fabric is split by [`Network::shard_group`] (set by
//! `topology::build`: pods in a 3-tier Clos, leaf groups in the 2-tier
//! case; top-tier switches are dealt round-robin). Each shard is a
//! full `Network` whose vectors keep *global* length — remote nodes
//! and links are cheap stubs — so no id is ever translated. Shards
//! advance in lockstep over the lookahead grid: the window width is
//! the minimum link propagation delay ([`Network::lookahead`]), every
//! window is one grid cell `[k*w, (k+1)*w)` anchored at 0, and a
//! packet crossing shards inside a cell arrives no earlier than the
//! cell's end, so handing it over at the barrier never reorders
//! anything.
//!
//! Determinism is by construction, not by luck:
//!
//! * every runtime event is keyed `(time, owning actor, per-actor
//!   seq)` by the node or link that owns it ([`super::event`]), so the
//!   key of any event is a pure function of that actor's own history —
//!   identical under any shard count;
//! * per-node fabric RNG streams (ECN, loss) are pure functions of
//!   `(seed, node)`, never of the dispatch interleaving;
//! * the serial engine walks the exact same cell sequence with the
//!   same boundary-only completion rule, so `--shards 1` is
//!   bit-identical to it and `--shards N` is invariant in `N`
//!   (`tests/pdes.rs` and the CI `determinism` job pin both).
//!
//! Cross-shard traffic flows through per-(src,dst) ordered outboxes
//! ([`PacketHandoff`]); the coordinator routes them between windows.
//! Worker threads are persistent for the whole run (one per shard,
//! `std::thread::scope`), each processing one `Window` command per
//! barrier.

use std::collections::BTreeSet;
use std::sync::mpsc;
use std::sync::Arc;

use crate::trace::Tracer;

use super::event::Event;
use super::network::{cell_end, Link, Network, Node, NodeBody};
use super::Time;

/// One packet crossing shards: the owner-computed canonical `Arrive`
/// key, the (global) link it traveled, and the payload moved out of
/// the sending shard's arena. The receiving shard re-allocates it and
/// schedules the arrival under the same key at the next barrier —
/// always before the arrival time, which sits at least one lookahead
/// past the sending cell.
pub(crate) struct PacketHandoff {
    pub(crate) key: u128,
    pub(crate) link: usize,
    pub(crate) pkt: super::packet::Packet,
}

/// Sink-side flow registration crossing shards (`Ctx::flow_start`):
/// applied by the owning shard at the next barrier, before the flow's
/// first delivery can possibly happen.
pub(crate) struct FlowHandoff {
    pub(crate) flow: u64,
    pub(crate) born: Time,
    pub(crate) expected_pkts: u32,
}

/// Per-shard runtime state, attached to a `Network` only while it is
/// one shard of a space-parallel run (`Network::shard`).
pub(crate) struct ShardRt {
    /// This shard's index.
    pub(crate) me: u16,
    /// Owning shard of every node (shared, read-only).
    pub(crate) node_shard: Arc<Vec<u16>>,
    /// Outgoing packet handoffs, one ordered channel per destination
    /// shard; swapped out and routed at each window barrier.
    pub(crate) pkt_out: Vec<Vec<PacketHandoff>>,
    /// Outgoing flow registrations, one channel per destination shard.
    pub(crate) flow_out: Vec<Vec<FlowHandoff>>,
}

impl ShardRt {
    fn new(me: u16, node_shard: Arc<Vec<u16>>, shards: usize) -> ShardRt {
        ShardRt {
            me,
            node_shard,
            pkt_out: (0..shards).map(|_| Vec::new()).collect(),
            flow_out: (0..shards).map(|_| Vec::new()).collect(),
        }
    }
}

/// Owning shard of every node. Grouped nodes map contiguously
/// (`group * shards / groups`), top-tier switches (`u32::MAX`)
/// round-robin by id. A network without shard-group labels (hand-built
/// test fabrics) degrades to one populated shard — still correct, just
/// not parallel.
fn shard_plan(net: &Network, shards: usize) -> Vec<u16> {
    let n = net.nodes.len();
    if shards <= 1 || net.shard_group.len() != n {
        return vec![0; n];
    }
    let Some(&gmax) =
        net.shard_group.iter().filter(|&&g| g != u32::MAX).max()
    else {
        return vec![0; n];
    };
    let groups = gmax as u64 + 1;
    net.shard_group
        .iter()
        .enumerate()
        .map(|(id, &g)| {
            if g == u32::MAX {
                (id % shards) as u16
            } else {
                ((g as u64 * shards as u64) / groups) as u16
            }
        })
        .collect()
}

/// The PFC pause locality argument, checked at split time: the only
/// cross-shard read in the dataplane is `node_paused[link.to]` on an
/// *up*-link's serve path, and a node pauses its inputs only while one
/// of its own up-outputs is over-watermark. Every cross-shard up-link
/// must therefore point at a node with no up-outputs (a top-tier
/// switch), whose pause count is structurally zero — making the zeroed
/// remote `node_paused` entries exact, not approximate.
fn assert_pause_locality(net: &Network, plan: &[u16]) {
    for l in &net.links {
        if plan[l.from as usize] == plan[l.to as usize] || l.from >= l.to {
            continue;
        }
        let head_has_up = net.nodes[l.to as usize]
            .ports
            .iter()
            .any(|&o| net.links[o].from < net.links[o].to);
        assert!(
            !head_has_up,
            "cross-shard up-link {}->{} points below the top tier; \
             the shard plan would make PFC pause state non-local",
            l.from, l.to
        );
    }
}

/// A stub standing in for a node owned by another shard: correct id,
/// no ports, no in-links, never dispatched to. Its fabric RNG mirrors
/// the real node's seeding for uniformity but is never drawn from.
fn stub_node(id: u32, seed: u64) -> Node {
    Node {
        id,
        body: NodeBody::Host(Box::new(crate::host::HostState::new(
            id,
            crate::util::rng::Rng::new(seed ^ id as u64),
        ))),
        ports: Vec::new(),
        in_links: Vec::new(),
        seq: 0,
        fab_rng: super::network::fab_rng_for(seed, id),
    }
}

/// Commands the coordinator sends to a shard worker.
enum Cmd {
    /// Process one grid cell: apply the inbound handoffs, then drain
    /// every local event strictly before `bound`.
    Window {
        bound: Time,
        pkts: Vec<PacketHandoff>,
        flows: Vec<FlowHandoff>,
    },
    Stop,
}

/// Per-job progress snapshot a worker reports at each barrier.
#[derive(Clone, Copy)]
struct JobReport {
    finish: Option<Time>,
    hosts: u32,
}

/// One worker's barrier report.
struct Report {
    shard: usize,
    next_time: Option<Time>,
    pkt_out: Vec<Vec<PacketHandoff>>,
    flow_out: Vec<Vec<FlowHandoff>>,
    jobs: Vec<JobReport>,
}

/// Completion-rule facts the coordinator needs per job, captured once
/// at split time.
struct JobMeta {
    allreduce: bool,
    root: Option<u32>,
    participants: u32,
    /// Ranks already finished before the split (each shard's clone
    /// starts from this count, so the global tally subtracts the
    /// duplicates).
    base_hosts: u32,
    done_at_split: bool,
}

/// Run `net` space-parallel with `net.cfg.shards` shards. Splits the
/// network, drives the bounded-window barrier loop on worker threads,
/// and merges everything back so the caller sees exactly the state a
/// serial run would have produced. Returns the end time (same contract
/// as `Network::run`/`run_all`).
pub(crate) fn run_sharded(
    net: &mut Network,
    max_time: Time,
    stop_on_done: bool,
) -> Time {
    // lint: allow(wall-clock, engine.wall_secs timer; measurement-only, never fed back)
    let t0 = std::time::Instant::now();
    let w = net.lookahead();
    let shards = net.cfg.shards.max(1) as usize;
    let plan = Arc::new(shard_plan(net, shards));
    assert_pause_locality(net, &plan);

    let seed = net.cfg.seed;
    let base_now = net.now;
    let setup_seq = net.queue.next_seq();
    let jobs_meta: Vec<JobMeta> = net
        .jobs
        .iter()
        .map(|j| JobMeta {
            allreduce: j.spec.algo.is_allreduce(),
            root: j.spec.collective.completion_rank(),
            participants: j.spec.participants.len() as u32,
            base_hosts: j.hosts_finished,
            done_at_split: j.finish.is_some(),
        })
        .collect();

    // ---- split ----------------------------------------------------
    let mut shard_nets: Vec<Network> = (0..shards)
        .map(|s| {
            let mut sn = Network::new(net.cfg.clone());
            sn.now = base_now;
            sn.jobs = net.jobs.clone();
            sn.faults = net.faults.clone();
            sn.host_slowdown = net.host_slowdown.clone();
            sn.tracer = net.tracer.fork_for_shard();
            sn.queue.set_next_seq(setup_seq);
            sn.shard =
                Some(Box::new(ShardRt::new(s as u16, plan.clone(), shards)));
            sn
        })
        .collect();

    // route every pending event to its owner (link endpoints are still
    // in place — the links move below). Arrive payloads migrate to the
    // destination shard's arena; TraceSample ticks replicate to every
    // shard under their original key so the samplers stay in lockstep.
    for (key, ev) in net.queue.drain_entries() {
        match ev {
            Event::Arrive { link, packet } => {
                let d = plan[net.links[link].to as usize] as usize;
                let pkt = net.arena.take(packet);
                let id = shard_nets[d].arena.alloc(pkt);
                shard_nets[d]
                    .queue
                    .push_keyed(key, Event::Arrive { link, packet: id });
            }
            Event::TxDone { link } => {
                let s = plan[net.links[link].from as usize] as usize;
                shard_nets[s].queue.push_keyed(key, Event::TxDone { link });
            }
            Event::LinkDownOne { link, count } => {
                let s = plan[net.links[link].from as usize] as usize;
                shard_nets[s]
                    .queue
                    .push_keyed(key, Event::LinkDownOne { link, count });
            }
            Event::LinkUpOne { link, count } => {
                let s = plan[net.links[link].from as usize] as usize;
                shard_nets[s]
                    .queue
                    .push_keyed(key, Event::LinkUpOne { link, count });
            }
            Event::SwitchTimeout { node, slot, generation } => {
                shard_nets[plan[node as usize] as usize].queue.push_keyed(
                    key,
                    Event::SwitchTimeout { node, slot, generation },
                );
            }
            Event::HostTimer { node, timer } => {
                shard_nets[plan[node as usize] as usize]
                    .queue
                    .push_keyed(key, Event::HostTimer { node, timer });
            }
            Event::JobWake { node, job } => {
                shard_nets[plan[node as usize] as usize]
                    .queue
                    .push_keyed(key, Event::JobWake { node, job });
            }
            Event::Fail { node } => {
                shard_nets[plan[node as usize] as usize]
                    .queue
                    .push_keyed(key, Event::Fail { node });
            }
            Event::Recover { node } => {
                shard_nets[plan[node as usize] as usize]
                    .queue
                    .push_keyed(key, Event::Recover { node });
            }
            Event::TraceSample => {
                for sn in shard_nets.iter_mut() {
                    sn.queue.push_keyed(key, Event::TraceSample);
                }
            }
        }
    }

    // distribute links (real to the owner — FIFO payloads migrate into
    // its arena — stubs elsewhere) and nodes, in id order so every
    // shard's vectors stay globally indexed
    for (li, mut link) in std::mem::take(&mut net.links).into_iter().enumerate()
    {
        let owner = plan[link.from as usize] as usize;
        for (s, sn) in shard_nets.iter_mut().enumerate() {
            if s == owner {
                continue;
            }
            sn.links.push(Link::new(
                link.from,
                link.from_port,
                link.to,
                link.to_port,
                &net.cfg,
            ));
            debug_assert_eq!(sn.links.len() - 1, li);
        }
        for q in link.queue.iter_mut() {
            let pkt = net.arena.take(q.id);
            q.id = shard_nets[owner].arena.alloc(pkt);
        }
        shard_nets[owner].links.insert(li, link);
    }
    assert_eq!(
        net.arena.live(),
        0,
        "split left packets behind in the master arena"
    );
    let master_paused = std::mem::take(&mut net.node_paused);
    for (id, node) in std::mem::take(&mut net.nodes).into_iter().enumerate() {
        let owner = plan[id] as usize;
        let mut slot = Some(node);
        for (s, sn) in shard_nets.iter_mut().enumerate() {
            if s == owner {
                sn.nodes.push(slot.take().unwrap());
                // a node's pause count is driven by its own up-outputs,
                // which the owner also owns; remote copies are zero by
                // the locality argument checked above
                sn.node_paused.push(master_paused[id]);
            } else {
                sn.nodes.push(stub_node(id as u32, seed));
                sn.node_paused.push(0);
            }
        }
    }

    // ---- barrier loop ---------------------------------------------
    let mut next_times: Vec<Option<Time>> =
        shard_nets.iter().map(|sn| sn.queue.next_time()).collect();
    let mut inbox_pkts: Vec<Vec<PacketHandoff>> =
        (0..shards).map(|_| Vec::new()).collect();
    let mut inbox_flows: Vec<Vec<FlowHandoff>> =
        (0..shards).map(|_| Vec::new()).collect();
    let mut shard_jobs: Vec<Vec<JobReport>> = (0..shards)
        .map(|_| {
            net.jobs
                .iter()
                .map(|j| JobReport {
                    finish: j.finish,
                    hosts: j.hosts_finished,
                })
                .collect()
        })
        .collect();
    let mut final_now: Option<Time> = None;

    let mut done_nets: Vec<Option<Network>> =
        (0..shards).map(|_| None).collect();
    std::thread::scope(|scope| {
        let (rep_tx, rep_rx) = mpsc::channel::<Report>();
        let (fin_tx, fin_rx) = mpsc::channel::<(usize, Network)>();
        let mut cmd_txs: Vec<mpsc::Sender<Cmd>> = Vec::with_capacity(shards);
        for (s, mut sn) in shard_nets.drain(..).enumerate() {
            let (tx, rx) = mpsc::channel::<Cmd>();
            cmd_txs.push(tx);
            let rep = rep_tx.clone();
            let fin = fin_tx.clone();
            scope.spawn(move || {
                while let Ok(cmd) = rx.recv() {
                    let Cmd::Window { bound, pkts, flows } = cmd else {
                        break;
                    };
                    // inbound registrations land before any event of
                    // this window — the flow's first delivery is at
                    // least one full lookahead after its start
                    for f in flows {
                        sn.metrics.flows.register(f.flow, f.born, f.expected_pkts);
                    }
                    for h in pkts {
                        // conservative-lookahead causality: a handoff
                        // sent at t crossed a link with latency >= w,
                        // so it arrives at or after the sending cell's
                        // end — never in this shard's past
                        debug_assert!(
                            (h.key >> 64) as Time >= sn.now,
                            "causality violated: handoff at t={} behind \
                             shard clock {}",
                            (h.key >> 64) as Time,
                            sn.now,
                        );
                        let id = sn.arena.alloc(h.pkt);
                        sn.queue.push_keyed(
                            h.key,
                            Event::Arrive { link: h.link, packet: id },
                        );
                    }
                    while let Some((t, ev)) = sn.queue.pop_before(bound) {
                        sn.dispatch(t, ev);
                    }
                    let rt = sn.shard.as_mut().expect("worker net is a shard");
                    let pkt_out =
                        rt.pkt_out.iter_mut().map(std::mem::take).collect();
                    let flow_out =
                        rt.flow_out.iter_mut().map(std::mem::take).collect();
                    let jobs = sn
                        .jobs
                        .iter()
                        .map(|j| JobReport {
                            finish: j.finish,
                            hosts: j.hosts_finished,
                        })
                        .collect();
                    let _ = rep.send(Report {
                        shard: s,
                        next_time: sn.queue.next_time(),
                        pkt_out,
                        flow_out,
                        jobs,
                    });
                }
                // per-shard audit (check 5 knows a shard's local queue
                // may legitimately be non-drained/non-empty)
                sn.maybe_audit();
                let _ = fin.send((s, sn));
            });
        }
        drop(rep_tx);
        drop(fin_tx);

        loop {
            // the earliest pending work anywhere: shard-local events
            // plus handoffs not yet delivered (their event time is the
            // key's upper 64 bits)
            let mut global_next: Option<Time> =
                next_times.iter().flatten().copied().min();
            for v in &inbox_pkts {
                for h in v {
                    let t = (h.key >> 64) as Time;
                    global_next =
                        Some(global_next.map_or(t, |g| g.min(t)));
                }
            }
            let Some(next) = global_next else {
                break; // drained (pending flow registrations merge below)
            };
            if next > max_time {
                final_now = Some(max_time);
                break;
            }
            let bound = cell_end(next, w).min(max_time.saturating_add(1));
            for (s, tx) in cmd_txs.iter().enumerate() {
                tx.send(Cmd::Window {
                    bound,
                    pkts: std::mem::take(&mut inbox_pkts[s]),
                    flows: std::mem::take(&mut inbox_flows[s]),
                })
                .expect("shard worker died mid-run");
            }
            for _ in 0..shards {
                let r = rep_rx.recv().expect("shard worker died mid-run");
                next_times[r.shard] = r.next_time;
                for (d, v) in r.pkt_out.into_iter().enumerate() {
                    inbox_pkts[d].extend(v);
                }
                for (d, v) in r.flow_out.into_iter().enumerate() {
                    inbox_flows[d].extend(v);
                }
                shard_jobs[r.shard] = r.jobs;
            }
            // job completion is checked only at cell boundaries — the
            // serial engine applies the identical rule, which is what
            // keeps the stop decision shard-count-invariant
            if stop_on_done
                && !jobs_meta.is_empty()
                && jobs_meta.iter().enumerate().all(|(j, m)| {
                    if !m.allreduce || m.done_at_split {
                        return true;
                    }
                    if shard_jobs.iter().any(|sj| sj[j].finish.is_some()) {
                        return true;
                    }
                    if m.root.is_some() {
                        return false;
                    }
                    // each rank finishes on exactly one shard; every
                    // clone started from base_hosts, so subtract the
                    // duplicated baseline
                    let total: u32 = shard_jobs
                        .iter()
                        .map(|sj| sj[j].hosts - m.base_hosts)
                        .sum::<u32>()
                        + m.base_hosts;
                    total == m.participants
                })
            {
                break;
            }
        }
        for tx in &cmd_txs {
            let _ = tx.send(Cmd::Stop);
        }
        for _ in 0..shards {
            let (s, sn) = fin_rx.recv().expect("shard worker lost at stop");
            done_nets[s] = Some(sn);
        }
    });

    // ---- merge -----------------------------------------------------
    let n_nodes = plan.len();
    let mut merged_nodes: Vec<Option<Node>> =
        (0..n_nodes).map(|_| None).collect();
    let mut merged_links: Vec<Option<Link>> = Vec::new();
    let mut merged_paused: Vec<u32> = vec![0; n_nodes];
    let mut tracers: Vec<Tracer> = Vec::with_capacity(shards);
    let mut sample_keys: BTreeSet<u128> = BTreeSet::new();
    let mut end_now = base_now;
    let mut merged_seq = net.queue.next_seq();
    let (mut peak, mut slots, mut allocs) = (0u64, 0u64, 0u64);

    for (s, sn) in done_nets.into_iter().enumerate() {
        let mut sn = sn.expect("missing shard network at merge");
        end_now = end_now.max(sn.now);
        net.events_processed += sn.events_processed;
        merged_seq = merged_seq.max(sn.queue.next_seq());
        for (id, node) in
            std::mem::take(&mut sn.nodes).into_iter().enumerate()
        {
            if plan[id] == s as u16 {
                merged_paused[id] = sn.node_paused[id];
                merged_nodes[id] = Some(node);
            }
        }
        if merged_links.is_empty() {
            merged_links = (0..sn.links.len()).map(|_| None).collect();
        }
        for (li, mut link) in
            std::mem::take(&mut sn.links).into_iter().enumerate()
        {
            if plan[link.from as usize] != s as u16 {
                continue;
            }
            for q in link.queue.iter_mut() {
                let pkt = sn.arena.take(q.id);
                q.id = net.arena.alloc(pkt);
            }
            merged_links[li] = Some(link);
        }
        for (key, ev) in sn.queue.drain_entries() {
            match ev {
                Event::Arrive { link, packet } => {
                    let pkt = sn.arena.take(packet);
                    let id = net.arena.alloc(pkt);
                    net.queue.push_keyed(
                        key,
                        Event::Arrive { link, packet: id },
                    );
                }
                // every shard carries a lockstep replica of the
                // sampler tick — keep exactly one per key
                Event::TraceSample => {
                    if sample_keys.insert(key) {
                        net.queue.push_keyed(key, Event::TraceSample);
                    }
                }
                other => net.queue.push_keyed(key, other),
            }
        }
        assert_eq!(
            sn.arena.live(),
            0,
            "shard {s} leaked {} packet(s) across the merge",
            sn.arena.live()
        );
        peak += sn.arena.peak_live() as u64;
        slots += sn.arena.slot_count() as u64;
        allocs += sn.arena.allocs();
        net.metrics.merge(&sn.metrics);
        for (j, job) in sn.jobs.iter().enumerate() {
            net.jobs[j].merge_from(job);
        }
        tracers.push(std::mem::replace(&mut sn.tracer, Tracer::off()));
    }

    // handoffs still in the coordinator's inboxes when the run stopped
    // are in-flight packets: rematerialize them exactly as the serial
    // engine would hold them (pending Arrive events under their keys)
    for v in inbox_pkts {
        for h in v {
            let id = net.arena.alloc(h.pkt);
            net.queue
                .push_keyed(h.key, Event::Arrive { link: h.link, packet: id });
        }
    }
    for v in inbox_flows {
        for f in v {
            net.metrics.flows.register(f.flow, f.born, f.expected_pkts);
        }
    }

    net.nodes = merged_nodes
        .into_iter()
        .map(|n| n.expect("node lost in merge"))
        .collect();
    net.links = merged_links
        .into_iter()
        .map(|l| l.expect("link lost in merge"))
        .collect();
    net.node_paused = merged_paused;
    net.queue.set_next_seq(merged_seq);
    net.tracer.merge_shards(tracers);
    net.now = final_now.unwrap_or(end_now);

    let e = &mut net.metrics.engine;
    e.events = net.events_processed;
    e.wall_secs += t0.elapsed().as_secs_f64();
    e.peak_live_packets = peak;
    e.arena_slots = slots;
    e.arena_allocs = allocs;

    net.maybe_audit();
    net.now
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClosConfig, SimConfig};
    use crate::loadbalance::LoadBalancer;
    use crate::util::rng::Rng;

    /// The causality theorem the conservative engine rests on: with
    /// window width w = min link latency, an event sent at time t
    /// inside cell [k*w, (k+1)*w) produces cross-shard work no earlier
    /// than t + w, which is at or past the cell end — so handing
    /// packets over only at barriers can never deliver into a shard's
    /// past. Checked over random (t, w) pairs including the u64 edge.
    #[test]
    fn lookahead_grid_never_delivers_into_the_past() {
        let mut rng = Rng::new(0x9DE5);
        for i in 0..10_000 {
            let w = 1 + rng.gen_range(1 << 20);
            let t = if i % 97 == 0 {
                // near (not at) the u64 edge: cell_end saturates to
                // MAX, which is still strictly past any t < MAX
                u64::MAX - 1 - rng.gen_range(1 << 20)
            } else {
                rng.next_u64() >> (rng.gen_range(40) + 1)
            };
            let end = cell_end(t, w);
            assert!(end > t, "cell end {end} not past t={t} (w={w})");
            assert!(
                end <= t.saturating_add(w),
                "cell end {end} overshoots t+w (t={t}, w={w})"
            );
            // earliest possible cross-shard arrival from this cell
            assert!(
                t.saturating_add(w) >= end,
                "arrival t+w={} inside the sending cell (end {end})",
                t.saturating_add(w)
            );
            // the grid is anchored at 0: cell ends are multiples of w
            if end != u64::MAX {
                assert_eq!(end % w, 0, "cell end {end} off-grid (w={w})");
            }
            // monotone: later events never land in earlier cells
            assert!(cell_end(t.saturating_add(1), w) >= end);
        }
    }

    fn built(cfg: ClosConfig) -> Network {
        crate::topology::build(cfg, SimConfig::default(), LoadBalancer::default()).0
    }

    /// The split plan is total, in-range, pure in its inputs, and
    /// keeps every non-top-tier link shard-local — the structural fact
    /// `assert_pause_locality` and the barrier protocol both rest on.
    #[test]
    fn shard_plan_is_total_and_pause_local() {
        for shards in [1usize, 2, 3, 4, 8] {
            for cfg in [ClosConfig::tiny(), ClosConfig::small3()] {
                let net = built(cfg);
                let plan = shard_plan(&net, shards);
                assert_eq!(plan.len(), net.nodes.len());
                assert!(plan.iter().all(|&s| (s as usize) < shards.max(1)));
                assert_eq!(plan, shard_plan(&net, shards), "plan not pure");
                if shards <= 1 {
                    assert!(plan.iter().all(|&s| s == 0));
                }
                assert_pause_locality(&net, &plan);
                // only links touching a top-tier switch may cross
                for l in &net.links {
                    let top = |id: u32| {
                        net.shard_group[id as usize] == u32::MAX
                    };
                    if !top(l.from) && !top(l.to) {
                        assert_eq!(
                            plan[l.from as usize], plan[l.to as usize],
                            "non-top link {} -> {} crosses shards",
                            l.from, l.to
                        );
                    }
                }
            }
        }
    }

    /// A network whose shard labels are absent (hand-built fabrics
    /// that bypass `topology::build`) degrades to one populated shard
    /// instead of splitting on garbage.
    #[test]
    fn missing_labels_degrade_to_one_shard() {
        let mut net = built(ClosConfig::tiny());
        net.shard_group.clear();
        let plan = shard_plan(&net, 4);
        assert!(plan.iter().all(|&s| s == 0));
    }
}
