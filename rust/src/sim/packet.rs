//! Packet model: the Canary wire format (paper Section 4.1) plus the
//! baseline/background packet kinds, all carried by one struct so the
//! simulator core stays protocol-agnostic.

use super::network::NodeId;

/// Canary header: destination 4 + id 4 + counter 2 + hosts 2 + children 4 +
/// switch address 2 + flags/padding 1 = 19 bytes (paper Section 5.1).
pub const CANARY_HEADER_BYTES: u32 = 19;
/// Ethernet header + framing overhead (paper Section 5.1: 14 + 24).
pub const ETH_OVERHEAD_BYTES: u32 = 38;
/// Total per-packet header overhead (19 + 38 = 57 bytes, Section 5.1).
pub const HEADER_OVERHEAD_BYTES: u32 =
    CANARY_HEADER_BYTES + ETH_OVERHEAD_BYTES;
/// Default payload in the scale simulations: 256 4-byte elements
/// (Section 5.1). Configurable via `SimConfig::payload_bytes`.
pub const PACKET_LANES: usize = 256;
pub const PAYLOAD_BYTES: u32 = (PACKET_LANES * 4) as u32;
/// Full wire size of a default max-payload Canary packet.
pub const WIRE_BYTES: u32 = PAYLOAD_BYTES + HEADER_OVERHEAD_BYTES;

/// Protocol role of a packet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PacketKind {
    /// Canary reduce-phase data, flowing toward the leader host.
    CanaryReduce,
    /// Canary broadcast-phase data, flowing down the recorded tree.
    CanaryBroadcast,
    /// Leader -> collided switch: bootstrap a local broadcast
    /// (tree restoration, Section 3.2.1). Children bitmap in `restore`.
    CanaryRestore,
    /// Unicast retransmission of a finished block's result to one host.
    CanaryRetransData,
    /// Host -> leader retransmission request (loss suspected).
    CanaryRetransReq,
    /// Leader -> hosts: reduce this block again with a fresh id
    /// (Section 3.3; carries the retry round in `meta`).
    CanaryFailure,
    /// Host -> leader direct contribution (host-based fallback / bypass).
    CanaryDirect,
    /// Static-tree reduce-phase data (SHARP/SwitchML/ATP-style).
    StaticReduce,
    /// Static-tree broadcast-phase data.
    StaticBroadcast,
    /// Ring allreduce data; `meta` carries the step index.
    Ring,
    /// Background random-uniform injection traffic (congestion generator).
    /// With a reactive transport, `counter` carries the per-flow
    /// sequence number, `hosts` the flow's total packet count and
    /// `meta` the send timestamp (`crate::transport`).
    Background,
    /// Sink -> sender cumulative ACK (`counter` = contiguous prefix,
    /// `meta` = largest one-way delay since the last ACK, for Swift).
    TransportAck,
    /// Sink -> sender DCQCN congestion notification (CE echo).
    TransportCnp,
}

impl PacketKind {
    /// Number of variants — sizes the per-kind delivery counters
    /// (`Metrics::pkts_by_kind`); keep in sync with the enum.
    pub const COUNT: usize = 13;

    /// Background traffic (and its transport control frames) is
    /// droppable on queue overflow; reduction control/data is treated
    /// as lossless unless fault injection is on (DESIGN.md: hosts
    /// window their injection, so reduction queues stay bounded; drops
    /// of reduction packets come from `faults`).
    pub fn droppable(self) -> bool {
        matches!(
            self,
            PacketKind::Background
                | PacketKind::TransportAck
                | PacketKind::TransportCnp
        )
    }
}

/// Optional value-carrying payload. Perf-figure runs use `None` (sizes
/// only); correctness tests and the trainer carry real lanes that the
/// switches aggregate with the saturating ALU.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    None,
    Lanes(Box<[i32]>),
}

impl Payload {
    pub fn lanes(&self) -> Option<&[i32]> {
        match self {
            Payload::None => None,
            Payload::Lanes(v) => Some(v),
        }
    }
}

/// A simulated packet. Fields beyond the Canary header exist only inside
/// the simulator (kind tags, flow labels); the modelled *wire size* is
/// explicit in `wire_bytes` and is all the links ever see.
#[derive(Clone, Debug)]
pub struct Packet {
    pub kind: PacketKind,
    /// Originating host (or switch for partial-aggregate packets).
    pub src: NodeId,
    /// Destination node: the leader host (Canary), the root switch
    /// (static trees), the peer (ring/background).
    pub dst: NodeId,
    /// Tenant / application id (multitenancy, Section 3.4).
    pub tenant: u16,
    /// Reduction block id within the tenant (unique per retry round).
    pub block: u32,
    /// Static-tree index the block was assigned to (round-robin).
    pub tree: u8,
    /// Number of host contributions already aggregated (Fig. 3).
    pub counter: u32,
    /// Total hosts participating in the reduction (Fig. 3).
    pub hosts: u32,
    /// If set, switches forward without processing (Section 4.1).
    pub bypass: bool,
    /// Collision report: (switch address, ingress port) appended when a
    /// descriptor could not be stored (Section 3.2.1).
    pub collision: Option<(NodeId, u16)>,
    /// Children port bitmap carried by a restoration packet.
    pub restore: u64,
    /// Protocol scratch (ring step, retry round, bg message id, ...).
    pub meta: u64,
    /// Flow label for ECMP/flowlet hashing.
    pub flow: u64,
    /// ECN Congestion Experienced: set by a switch queue whose class-1
    /// backlog exceeds the RED-style marking threshold
    /// (`SimConfig::ecn_kmin_bytes`/`ecn_kmax_bytes`); echoed by sinks
    /// as CNPs under DCQCN. Never set when transport is off.
    pub ecn: bool,
    /// Modelled size on the wire, including headers.
    pub wire_bytes: u32,
    pub payload: Payload,
}

impl Packet {
    /// A max-payload reduction data packet skeleton.
    pub fn data(kind: PacketKind, src: NodeId, dst: NodeId) -> Packet {
        Packet {
            kind,
            src,
            dst,
            tenant: 0,
            block: 0,
            tree: 0,
            counter: 0,
            hosts: 0,
            bypass: false,
            collision: None,
            restore: 0,
            meta: 0,
            flow: 0,
            ecn: false,
            wire_bytes: WIRE_BYTES,
            payload: Payload::None,
        }
    }

    /// Canary descriptor key (Section 3.1.3: table indexed by id).
    pub fn block_key(&self) -> u64 {
        ((self.tenant as u64) << 32) | self.block as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_sizes_match_paper() {
        // 1024 B payload + 19 B canary + 38 B eth/framing = 1081 B
        assert_eq!(WIRE_BYTES, 1081);
        assert_eq!(CANARY_HEADER_BYTES, 19);
    }

    #[test]
    fn block_key_disambiguates_tenants() {
        let mut a = Packet::data(PacketKind::CanaryReduce, 0, 1);
        let mut b = a.clone();
        a.tenant = 1;
        a.block = 7;
        b.tenant = 2;
        b.block = 7;
        assert_ne!(a.block_key(), b.block_key());
    }

    #[test]
    fn droppable_only_background() {
        assert!(PacketKind::Background.droppable());
        assert!(PacketKind::TransportAck.droppable());
        assert!(PacketKind::TransportCnp.droppable());
        assert!(!PacketKind::CanaryReduce.droppable());
        assert!(!PacketKind::StaticBroadcast.droppable());
    }
}
