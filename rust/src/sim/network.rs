//! Network state and the discrete-event dispatch loop.
//!
//! The `Network` owns all nodes, directed links, the calendar-queue
//! scheduler, the packet arena, the fault plan and the job table.
//! Protocol logic lives in `crate::switch` and `crate::host`; they
//! receive a [`Ctx`] that exposes exactly the mutable state a node may
//! touch (its ports, the event queue, the arena, metrics, the RNG and
//! its job entry) so the borrow structure stays simple. Delivered
//! packets are handed to handlers as arena ids ([`PacketId`]); a
//! handler must consume each id exactly once — [`Ctx::take`] to own
//! the packet, [`Ctx::forward`] to pass it on zero-copy, or
//! [`Ctx::free`] to drop it.

use std::collections::VecDeque;

use crate::collectives::JobRuntime;
use crate::config::SimConfig;
use crate::faults::{FaultEvent, FaultPlan};
use crate::host::HostState;
use crate::metrics::Metrics;
use crate::switch::SwitchState;
use crate::trace::Tracer;
use crate::util::rng::Rng;

use super::arena::{PacketArena, PacketId};
use super::event::{link_key, node_key, Event, EventQueue};
use super::packet::{Packet, PacketKind};
use super::shard::{FlowHandoff, PacketHandoff, ShardRt};
use super::Time;

/// Node identifier (dense, indexes `Network::nodes`).
pub type NodeId = u32;
/// Link identifier (dense, indexes `Network::links`).
pub type LinkId = usize;

/// A directed link with a single shared output-queued port buffer.
///
/// Serialization: the head packet occupies the transmitter for
/// `wire_bytes * ps_per_byte`; it then propagates for `latency_ps`
/// (which also folds in the per-hop switch pipeline latency, as in the
/// paper's ~300 ns/hop figure).
///
/// Queueing: one FIFO per port (as in the paper's SST setup) — classes
/// share the line rate proportionally to their arrivals. Background
/// traffic is policed by per-class byte drops; reduction traffic is kept
/// lossless via PFC-style pause/resume on the up-link DAG (deadlock-free
/// by construction), which bounds the reduction backlog the way a
/// credit-based fabric does.
#[derive(Debug)]
pub struct Link {
    pub from: NodeId,
    pub from_port: u16,
    pub to: NodeId,
    pub to_port: u16,
    pub ps_per_byte: u64,
    pub latency_ps: Time,
    /// Port buffer capacity: the 50 %-occupancy adaptive-routing
    /// threshold and the PFC pause watermarks reference this; droppable
    /// traffic overflowing its class share is discarded.
    pub capacity_bytes: u64,
    /// Total bytes across both classes (adaptive-routing signal).
    pub queued_bytes: u64,
    /// Single shared FIFO (the paper's switches have one output buffer
    /// per port; classes share it proportionally to their arrivals).
    /// Entries carry the arena id plus the two fields the port logic
    /// reads per packet (size, class), so serving the queue never
    /// chases the arena. `pub(crate)` (like the accounting fields
    /// below) so `sim::invariants` can recompute state from scratch.
    pub(crate) queue: VecDeque<QueuedPkt>,
    /// Per-class byte accounting (policing, PFC, diagnostics).
    pub(crate) class_bytes: [u64; 2],
    /// True while this link's class-0 backlog exceeds the pause
    /// watermark — it then contributes to pausing its sender node's
    /// inputs (PFC-style lossless backpressure; DESIGN.md).
    pub(crate) pausing: bool,
    pub(crate) busy: bool,
    /// Links go down when their endpoints fail or a scheduled flap
    /// hits (fault injection). Kept in sync with `down_refs` so every
    /// read site stays a plain flag test.
    pub alive: bool,
    /// Count of active down-causes (overlapping flap windows and
    /// switch-failure intervals stack): the link is alive iff zero.
    pub(crate) down_refs: u32,
    /// Per-link event sequence counter: TxDone/Arrive events are keyed
    /// `(time, link-actor, seq)` so their dispatch order is a pure
    /// function of this link's own history — the property the sharded
    /// engine needs for shard-count-invariant replay (DESIGN.md §2.10).
    pub(crate) seq: u32,
    // --- metrics ---
    pub busy_ps: u64,
    pub bytes_tx: u64,
    pub drops: u64,
}

/// One port-FIFO entry: the arena id plus the size/class the port
/// logic needs on every serve.
#[derive(Clone, Copy, Debug)]
pub(crate) struct QueuedPkt {
    pub(crate) id: PacketId,
    pub(crate) bytes: u32,
    pub(crate) class: u8,
    /// When the packet joined this port FIFO — feeds the flight
    /// recorder's queueing-delay split; never read on the hot path.
    pub(crate) enq_ps: Time,
}

#[inline]
fn class_of(p: &Packet) -> usize {
    if p.kind.droppable() {
        1
    } else {
        0
    }
}

impl Link {
    pub fn new(
        from: NodeId,
        from_port: u16,
        to: NodeId,
        to_port: u16,
        cfg: &SimConfig,
    ) -> Link {
        Link {
            from,
            from_port,
            to,
            to_port,
            ps_per_byte: cfg.link_ps_per_byte,
            latency_ps: cfg.link_latency_ps,
            capacity_bytes: cfg.port_queue_capacity,
            queued_bytes: 0,
            queue: VecDeque::new(),
            class_bytes: [0, 0],
            pausing: false,
            busy: false,
            alive: true,
            down_refs: 0,
            seq: 0,
            busy_ps: 0,
            bytes_tx: 0,
            drops: 0,
        }
    }

    /// Queue occupancy as a fraction of the logical capacity.
    #[inline]
    pub fn occupancy(&self) -> f64 {
        self.queued_bytes as f64 / self.capacity_bytes as f64
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Class-0 (reduction) backlog in bytes.
    pub fn class0_bytes(&self) -> u64 {
        self.class_bytes[0]
    }

    /// May the head packet be served? FIFO order is strict (one shared
    /// buffer, so a paused class-0 head blocks the port — real PFC
    /// head-of-line behaviour). `blocked0` = destination node paused.
    fn head_serveable(&self, blocked0: bool) -> bool {
        match self.queue.front() {
            None => false,
            Some(q) => !(blocked0 && q.class == 0),
        }
    }

    /// Pause watermarks (hysteresis) as a function of port capacity.
    #[inline]
    fn pause_hi(&self) -> u64 {
        self.capacity_bytes
    }

    #[inline]
    fn pause_lo(&self) -> u64 {
        self.capacity_bytes / 2
    }

    /// Up-direction link? (Node ids order hosts < tier-1 switches < ...
    /// < top tier, so links toward the spine/core always increase the
    /// id, at any tier count.) Backpressure is generated by — and
    /// blocks — up-links only: the up DAG is acyclic, which makes the
    /// PFC-style pausing deadlock-free. Down-direction queues are
    /// modelled with ample buffering instead (DESIGN.md §2).
    #[inline]
    fn is_up(&self) -> bool {
        self.from < self.to
    }
}

/// Node payload: host protocol engine or switch dataplane.
pub enum NodeBody {
    Host(Box<HostState>),
    Switch(Box<SwitchState>),
}

/// A network node and its outgoing ports.
pub struct Node {
    pub id: NodeId,
    pub body: NodeBody,
    /// Outgoing link per local port index.
    pub ports: Vec<LinkId>,
    /// Links terminating at this node (for backpressure re-kicks).
    pub in_links: Vec<LinkId>,
    /// Per-node event sequence counter (timers, wakes): keys this
    /// node's self-scheduled events independently of every other actor.
    pub(crate) seq: u32,
    /// Per-node fabric RNG (ECN marking, loss injection): seeded purely
    /// from `(cfg.seed, id)`, so the draw stream a node sees is the
    /// same no matter how the fabric is sharded.
    pub(crate) fab_rng: Rng,
}

/// Everything a protocol handler may touch while processing one event.
pub struct Ctx<'a> {
    pub now: Time,
    pub node_id: NodeId,
    /// Outgoing link ids of this node, indexed by port.
    pub ports: &'a [LinkId],
    pub links: &'a mut [Link],
    pub queue: &'a mut EventQueue,
    pub arena: &'a mut PacketArena,
    /// This node's fabric RNG (see [`Node::fab_rng`]).
    pub rng: &'a mut Rng,
    pub metrics: &'a mut Metrics,
    pub jobs: &'a mut [JobRuntime],
    pub cfg: &'a SimConfig,
    /// This node's event-key sequence counter (see [`Node::seq`]).
    pub(crate) actor_seq: &'a mut u32,
    /// Sharded-engine runtime, when this network is one shard of a
    /// space-parallel run (`sim/shard.rs`); `None` in the serial engine.
    pub(crate) shard: Option<&'a mut ShardRt>,
    /// Per-node count of over-watermark output queues (paused inputs).
    pub node_paused: &'a mut [u32],
    /// Straggler factor of this node (1 = nominal). Every delay passed
    /// to [`Ctx::host_timer`] is stretched by it, so a straggler host
    /// runs its whole protocol clock — injection pacing, retry timers —
    /// `slowdown`x slower (fault injection; only ever > 1 for hosts).
    pub slowdown: u32,
    /// Telemetry recorder (`trace/`): disabled by default, in which
    /// case every hook is a single branch (zero-footprint contract).
    pub tracer: &'a mut Tracer,
}

impl<'a> Ctx<'a> {
    /// Enqueue a freshly built `packet` on this node's outgoing `port`
    /// (allocates an arena slot — recycled from the free list in
    /// steady state).
    pub fn send(&mut self, port: u16, packet: Packet) {
        let id = self.arena.alloc(packet);
        self.forward(port, id);
    }

    /// Enqueue the live packet `id` on `port` without moving it out of
    /// the arena — the zero-copy path for pure forwarding hops.
    pub fn forward(&mut self, port: u16, id: PacketId) {
        let link_id = self.ports[port as usize];
        enqueue_on_link(
            self.links,
            self.queue,
            self.arena,
            self.metrics,
            self.now,
            link_id,
            id,
            self.node_paused,
            self.cfg,
            self.rng,
        );
    }

    /// Read a delivered packet's fields in place.
    pub fn pkt(&self, id: PacketId) -> &Packet {
        self.arena
            .get(id)
            .unwrap_or_else(|| panic!("stale {id:?} read by a handler"))
    }

    /// Take ownership of a delivered packet (frees its arena slot).
    pub fn take(&mut self, id: PacketId) -> Packet {
        self.arena.take(id)
    }

    /// Drop a delivered packet (frees its arena slot).
    pub fn free(&mut self, id: PacketId) {
        self.arena.free(id);
    }

    /// Class-0 backlog on `port` (host NIC pacing input).
    pub fn port_class0_bytes(&self, port: u16) -> u64 {
        self.links[self.ports[port as usize]].class0_bytes()
    }

    /// Is the link behind `port` alive? (Adaptive routing and the
    /// failure recovery path must steer around dead links.)
    pub fn port_alive(&self, port: u16) -> bool {
        self.links[self.ports[port as usize]].alive
    }

    /// Next event key owned by this node (self-scheduled events only,
    /// so the stream is shard-invariant).
    #[inline]
    fn node_event_key(&mut self, at: Time) -> u128 {
        let seq = *self.actor_seq;
        *self.actor_seq += 1;
        node_key(at, self.node_id, seq)
    }

    /// Schedule a host timer event. A straggler host's timers are
    /// stretched by its slowdown factor (1 for everyone else, so the
    /// arithmetic is bit-identical in the nominal case).
    pub fn host_timer(&mut self, delay: Time, timer: u64) {
        let at = self.now + delay * self.slowdown as Time;
        let key = self.node_event_key(at);
        self.queue.push_keyed(
            key,
            Event::HostTimer {
                node: self.node_id,
                timer,
            },
        );
    }

    /// Schedule a canary descriptor timeout.
    pub fn switch_timeout(&mut self, delay: Time, slot: u32, generation: u64) {
        let at = self.now + delay;
        let key = self.node_event_key(at);
        self.queue.push_keyed(
            key,
            Event::SwitchTimeout {
                node: self.node_id,
                slot,
                generation,
            },
        );
    }

    /// Schedule a wake event for this node (injection loops).
    pub fn wake(&mut self, delay: Time, job: u32) {
        let at = self.now + delay;
        let key = self.node_event_key(at);
        self.queue.push_keyed(
            key,
            Event::JobWake {
                node: self.node_id,
                job,
            },
        );
    }

    /// Announce a new flow: sender-side offered accounting here, sink-
    /// side FCT registration on the shard that owns `dst` (locally in
    /// the serial engine). The registration is applied at the next
    /// window barrier when `dst` is remote — always before the flow's
    /// first delivery, which is at least one lookahead away.
    pub fn flow_start(
        &mut self,
        dst: NodeId,
        flow: u64,
        born: Time,
        expected_pkts: u32,
        bytes: u64,
    ) {
        self.metrics.flows.on_offer(bytes);
        let remote = match self.shard.as_deref() {
            Some(rt) => rt.node_shard[dst as usize] != rt.me,
            None => false,
        };
        if remote {
            let rt = self.shard.as_deref_mut().unwrap();
            let d = rt.node_shard[dst as usize] as usize;
            rt.flow_out[d].push(FlowHandoff {
                flow,
                born,
                expected_pkts,
            });
        } else {
            self.metrics.flows.register(flow, born, expected_pkts);
        }
    }

    /// Occupancy of the queue at `port` (adaptive-routing input).
    pub fn port_occupancy(&self, port: u16) -> f64 {
        self.links[self.ports[port as usize]].occupancy()
    }

    /// Queued bytes on `port`.
    pub fn port_queued_bytes(&self, port: u16) -> u64 {
        self.links[self.ports[port as usize]].queued_bytes
    }

    /// Per-class occupancy at `port` — the adaptive-routing signal. Real
    /// VC-based fabrics (and the paper's SST/merlin substrate) expose a
    /// *virtual-channel* buffer per class, so a flow reacts to its own
    /// class's congestion, not to other classes' backlogs.
    pub fn port_class_occupancy(&self, port: u16, class: usize) -> f64 {
        let l = &self.links[self.ports[port as usize]];
        l.class_bytes[class] as f64 / l.capacity_bytes as f64
    }

    /// Per-class queued bytes at `port`.
    pub fn port_class_bytes(&self, port: u16, class: usize) -> u64 {
        self.links[self.ports[port as usize]].class_bytes[class]
    }
}

/// Shared enqueue logic (also used by the dispatch loop itself). Takes
/// ownership of the arena entry `id`: it either joins the port FIFO or
/// is freed on a drop path.
#[allow(clippy::too_many_arguments)]
fn enqueue_on_link(
    links: &mut [Link],
    queue: &mut EventQueue,
    arena: &mut PacketArena,
    metrics: &mut Metrics,
    now: Time,
    link_id: LinkId,
    id: PacketId,
    node_paused: &mut [u32],
    cfg: &SimConfig,
    rng: &mut Rng,
) {
    let link = &mut links[link_id];
    if !link.alive {
        metrics.drops_link_down += 1;
        arena.free(id);
        return;
    }
    let packet = arena
        .get_mut(id)
        .unwrap_or_else(|| panic!("stale {id:?} enqueued"));
    let size = packet.wire_bytes as u64;
    let class = class_of(packet);
    // droppable traffic is policed against its class share of the port
    if class == 1 && link.class_bytes[1] + size > link.capacity_bytes {
        link.drops += 1;
        metrics.drops_overflow += 1;
        arena.free(id);
        return;
    }
    // ECN: RED-style CE marking on the class-1 backlog (reactive
    // transport, DESIGN.md §2.4). Only *data* frames are marked — the
    // 64 B ACK/CNP control frames share the droppable class but nobody
    // reads their CE bit, and marking them would dilute the signal.
    // Off by default — a single branch and zero RNG draws, so legacy
    // runs stay bit-identical.
    if cfg.ecn_enabled
        && packet.kind == PacketKind::Background
        && !packet.ecn
    {
        let q = link.class_bytes[1] + size;
        let mark = if q >= cfg.ecn_kmax_bytes {
            true
        } else if q > cfg.ecn_kmin_bytes {
            let p = (q - cfg.ecn_kmin_bytes) as f64
                / (cfg.ecn_kmax_bytes - cfg.ecn_kmin_bytes).max(1) as f64;
            rng.chance(p)
        } else {
            false
        };
        if mark {
            packet.ecn = true;
            metrics.ecn_marks += 1;
        }
    }
    let entry = QueuedPkt {
        id,
        bytes: packet.wire_bytes,
        class: class as u8,
        enq_ps: now,
    };
    link.queued_bytes += size;
    link.class_bytes[class] += size;
    link.queue.push_back(entry);
    // lossless backpressure: an over-watermark class-0 backlog on an
    // up-port pauses the up-inputs of the node this port belongs to
    if class == 0
        && link.is_up()
        && !link.pausing
        && link.class_bytes[0] > link.pause_hi()
    {
        link.pausing = true;
        node_paused[link.from as usize] += 1;
    }
    if !link.busy {
        start_tx(links, node_paused, queue, now, link_id);
    }
}

fn start_tx(
    links: &mut [Link],
    node_paused: &[u32],
    queue: &mut EventQueue,
    now: Time,
    link_id: LinkId,
) {
    let link = &mut links[link_id];
    debug_assert!(!link.busy);
    if !link.alive {
        // a dead transmitter serves nothing; `link_bring_up` re-kicks
        return;
    }
    let blocked0 = link.is_up() && node_paused[link.to as usize] > 0;
    if !link.head_serveable(blocked0) {
        return;
    }
    link.busy = true;
    let head_bytes = link.queue.front().unwrap().bytes as u64;
    let ser = head_bytes * link.ps_per_byte;
    link.busy_ps += ser;
    let seq = link.seq;
    link.seq += 1;
    queue.push_keyed(
        link_key(now + ser, link_id, seq),
        Event::TxDone { link: link_id },
    );
}

/// Deterministic per-node fabric RNG (ECN marking, loss injection): a
/// pure function of the run seed and the node id — never drawn from the
/// master RNG — so each node's stream is identical under any sharding.
pub(crate) fn fab_rng_for(seed: u64, id: NodeId) -> Rng {
    Rng::new(
        seed ^ 0xFA85_EED0_CA11_A8D7
            ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    )
}

/// End of the lookahead-grid cell containing `t`: the smallest multiple
/// of `w` strictly greater than `t`. The grid is anchored at 0, so every
/// engine — serial or sharded, at any shard count — walks the exact same
/// sequence of cells; a handoff sent during a cell arrives no earlier
/// than its end (`arrive = send + latency >= cell_end` because
/// `latency >= w`), always landing in a strictly later cell.
pub(crate) fn cell_end(t: Time, w: Time) -> Time {
    (t / w).saturating_add(1).saturating_mul(w)
}

/// The simulated network.
pub struct Network {
    pub nodes: Vec<Node>,
    pub links: Vec<Link>,
    pub queue: EventQueue,
    /// Slab of all in-flight packets (`sim/arena.rs`).
    pub arena: PacketArena,
    pub now: Time,
    pub rng: Rng,
    pub metrics: Metrics,
    pub jobs: Vec<JobRuntime>,
    pub faults: FaultPlan,
    pub cfg: SimConfig,
    pub events_processed: u64,
    /// Per-node count of over-watermark up-ports (inputs paused while
    /// non-zero).
    pub node_paused: Vec<u32>,
    /// Per-node straggler factor (1 = nominal; set from the fault
    /// plan's `StragglerHost` events at `kick_jobs`).
    pub host_slowdown: Vec<u32>,
    /// Telemetry recorder; `Tracer::off()` unless a `TraceSpec` was
    /// installed (see `workload::ScenarioBuilder::trace`).
    pub tracer: Tracer,
    /// Per-node space-partition group (pod / leaf group), set by
    /// `topology::build`; top-tier switches carry `u32::MAX` and are
    /// spread round-robin. Empty on hand-built networks — the sharded
    /// engine then degrades to one populated shard (still correct).
    pub shard_group: Vec<u32>,
    /// Sharded-engine runtime state; `Some` only while this network is
    /// one shard of a space-parallel run (`sim/shard.rs`).
    pub(crate) shard: Option<Box<ShardRt>>,
}

impl Network {
    pub fn new(cfg: SimConfig) -> Network {
        let rng = Rng::new(cfg.seed);
        Network {
            nodes: Vec::new(),
            links: Vec::new(),
            queue: EventQueue::new(),
            arena: PacketArena::new(),
            now: 0,
            rng,
            metrics: Metrics::default(),
            jobs: Vec::new(),
            faults: FaultPlan::default(),
            cfg,
            events_processed: 0,
            node_paused: Vec::new(),
            host_slowdown: Vec::new(),
            tracer: Tracer::off(),
            shard_group: Vec::new(),
            shard: None,
        }
    }

    /// Add a node, returning its id.
    pub fn add_node(&mut self, body: NodeBody) -> NodeId {
        let id = self.nodes.len() as NodeId;
        self.nodes.push(Node {
            id,
            body,
            ports: Vec::new(),
            in_links: Vec::new(),
            seq: 0,
            fab_rng: fab_rng_for(self.cfg.seed, id),
        });
        self.node_paused.push(0);
        self.host_slowdown.push(1);
        id
    }

    /// Add a directed link `from.port -> to.in_port`; port indices must be
    /// appended in order.
    pub fn add_link(&mut self, from: NodeId, to: NodeId, to_port: u16) -> LinkId {
        let from_port = self.nodes[from as usize].ports.len() as u16;
        let link = Link::new(from, from_port, to, to_port, &self.cfg);
        let id = self.links.len();
        self.links.push(link);
        self.nodes[from as usize].ports.push(id);
        self.nodes[to as usize].in_links.push(id);
        id
    }

    /// Schedule the initial events of every installed job (at each
    /// job's start-time offset).
    pub fn kick_jobs(&mut self) {
        for (job_idx, job) in self.jobs.iter().enumerate() {
            for &h in job.spec.participants.iter() {
                self.queue.push(
                    job.spec.start_ps,
                    Event::JobWake {
                        node: h,
                        job: job_idx as u32,
                    },
                );
            }
            self.tracer.span(
                job.spec.start_ps,
                crate::trace::SpanKind::Kick,
                job_idx as u32,
                job.spec.participants.first().copied().unwrap_or(0),
                None,
                job.spec.participants.len() as u64,
            );
        }
        // arm the telemetry sampler; with tracing off nothing is
        // scheduled at all (the zero-footprint contract)
        if self.tracer.enabled() {
            self.queue.push(0, Event::TraceSample);
        }
        // convert the declarative fault timeline into sim events; an
        // empty timeline schedules nothing (and draws nothing from the
        // RNG), so it is provably inert (tests/churn.rs). Node-pair
        // and switch faults are pre-resolved into per-directed-link
        // events here, while the whole topology is still in one piece:
        // each resulting event has a single owning link/node, which is
        // what lets the sharded engine route it to exactly one shard.
        // `count` is set on one directed link per flap pair so the
        // flap/recovery counters keep their per-pair semantics.
        for ev in self.faults.events.clone() {
            match ev {
                FaultEvent::LinkFlap { a, b, down_at, up_at } => {
                    let ls = self.links_between(a, b);
                    for (i, &li) in ls.iter().enumerate() {
                        self.queue.push(
                            down_at,
                            Event::LinkDownOne { link: li, count: i == 0 },
                        );
                    }
                    for (i, &li) in ls.iter().enumerate() {
                        self.queue.push(
                            up_at,
                            Event::LinkUpOne { link: li, count: i == 0 },
                        );
                    }
                }
                FaultEvent::SwitchFail { switch, at, recover_at } => {
                    self.queue.push(at, Event::Fail { node: switch });
                    for li in self.touching_links(switch) {
                        self.queue.push(
                            at,
                            Event::LinkDownOne { link: li, count: false },
                        );
                    }
                    if let Some(r) = recover_at {
                        self.queue.push(r, Event::Recover { node: switch });
                        for li in self.touching_links(switch) {
                            self.queue.push(
                                r,
                                Event::LinkUpOne { link: li, count: false },
                            );
                        }
                    }
                }
                FaultEvent::StragglerHost { host, slowdown } => {
                    if slowdown > 1 {
                        self.metrics.straggler_slowdowns += 1;
                    }
                    self.host_slowdown[host as usize] = slowdown;
                }
            }
        }
    }

    /// True when every allreduce job has finished.
    pub fn all_reduce_jobs_done(&self) -> bool {
        self.jobs
            .iter()
            .all(|j| !j.spec.algo.is_allreduce() || j.finish.is_some())
    }

    /// Conservative PDES lookahead: the minimum propagation delay of
    /// any link in the fabric. Every cross-link event lands at least
    /// this far in the future, so a window of width `lookahead()` can
    /// be processed to completion before any neighbour's output can
    /// affect it (DESIGN.md §2.10).
    pub(crate) fn lookahead(&self) -> Time {
        let w = self
            .links
            .iter()
            .map(|l| l.latency_ps)
            .min()
            .unwrap_or(self.cfg.link_latency_ps);
        assert!(w > 0, "zero link latency breaks the PDES lookahead");
        w
    }

    /// Run until all allreduce jobs complete, the event queue drains, or
    /// `max_time` is reached. Returns the end time.
    pub fn run(&mut self, max_time: Time) -> Time {
        if self.cfg.shards > 0 {
            return super::shard::run_sharded(self, max_time, true);
        }
        self.run_serial(max_time, true)
    }

    /// Run every event up to `max_time` without the early job-completion
    /// exit (used by pure-traffic tests).
    pub fn run_all(&mut self, max_time: Time) -> Time {
        if self.cfg.shards > 0 {
            return super::shard::run_sharded(self, max_time, false);
        }
        self.run_serial(max_time, false)
    }

    /// The single-threaded bounded-window engine. Events are drained
    /// one lookahead-grid cell `[k*w, (k+1)*w)` at a time, skipping
    /// straight to the cell holding the next pending event; job
    /// completion is only checked at cell boundaries. Both rules match
    /// the sharded engine exactly (same grid anchored at 0, same skip,
    /// same boundary-only completion), which is what makes `--shards 1`
    /// bit-identical to this loop and `--shards N` invariant in N.
    fn run_serial(&mut self, max_time: Time, stop_on_done: bool) -> Time {
        // lint: allow(wall-clock, engine.wall_secs timer; measurement-only, never fed back)
        let t0 = std::time::Instant::now();
        let w = self.lookahead();
        loop {
            let Some(next) = self.queue.next_time() else {
                break;
            };
            if next > max_time {
                self.now = max_time;
                break;
            }
            let bound = cell_end(next, w).min(max_time.saturating_add(1));
            while let Some((t, ev)) = self.queue.pop_before(bound) {
                self.dispatch(t, ev);
            }
            if stop_on_done
                && !self.jobs.is_empty()
                && self.all_reduce_jobs_done()
            {
                break;
            }
        }
        self.note_engine_stats(t0.elapsed().as_secs_f64());
        self.maybe_audit();
        self.now
    }

    /// End-of-segment conservation audit: always in debug builds,
    /// opt-in via `--paranoid` in release. Read-only (no RNG draws,
    /// no scheduling), so it cannot perturb the run fingerprint.
    pub(crate) fn maybe_audit(&self) {
        if cfg!(debug_assertions) || self.cfg.paranoid {
            super::invariants::enforce(self);
        }
    }

    /// Fold this run segment's throughput numbers into the metrics
    /// (events/sec over accumulated wall time, arena high-water marks).
    /// Wall time is measurement-only — it never feeds back into the
    /// simulation, so determinism is untouched.
    fn note_engine_stats(&mut self, wall_secs: f64) {
        let e = &mut self.metrics.engine;
        e.events = self.events_processed;
        e.wall_secs += wall_secs;
        e.peak_live_packets = self.arena.peak_live() as u64;
        e.arena_slots = self.arena.slot_count() as u64;
        e.arena_allocs = self.arena.allocs();
    }

    pub(crate) fn dispatch(&mut self, time: Time, event: Event) {
        // sampler ticks are observational: they mutate nothing the
        // simulation reads, stay outside `events_processed`, and do
        // not advance `now` (a trailing tick after the last real
        // event must not move the end-of-run clock), so a traced run
        // fingerprints identically to an untraced one
        if let Event::TraceSample = event {
            self.trace_sample(time);
            return;
        }
        self.now = time;
        self.events_processed += 1;
        match event {
            Event::TxDone { link } => self.tx_done(link),
            Event::Arrive { link, packet } => self.deliver(link, packet),
            Event::SwitchTimeout {
                node,
                slot,
                generation,
            } => self.with_ctx(node, |body, ctx| {
                if let NodeBody::Switch(sw) = body {
                    crate::switch::handle_timeout(sw, ctx, slot, generation);
                }
            }),
            Event::HostTimer { node, timer } => {
                self.with_ctx(node, |body, ctx| {
                    if let NodeBody::Host(h) = body {
                        crate::host::handle_timer(h, ctx, timer);
                    }
                })
            }
            Event::JobWake { node, job } => self.with_ctx(node, |body, ctx| {
                if let NodeBody::Host(h) = body {
                    crate::host::handle_wake(h, ctx, job);
                }
            }),
            Event::Fail { node } => self.fail_switch(node),
            Event::Recover { node } => self.recover_switch(node),
            Event::LinkDownOne { link, count } => {
                if count {
                    self.metrics.link_flaps += 1;
                }
                self.link_take_down(link);
            }
            Event::LinkUpOne { link, count } => {
                if count {
                    self.metrics.link_recoveries += 1;
                }
                self.link_bring_up(link);
            }
            Event::TraceSample => unreachable!("handled before dispatch"),
        }
    }

    /// One telemetry sampler tick: snapshot link/arena/descriptor
    /// gauges and re-arm. The tick re-arms only while the queue holds
    /// other work, so it never keeps a drained simulation alive.
    fn trace_sample(&mut self, at: Time) {
        let live_desc: u64 = self
            .nodes
            .iter()
            .map(|n| match &n.body {
                NodeBody::Switch(sw) => sw.canary.live_descriptors() as u64,
                NodeBody::Host(_) => 0,
            })
            .sum();
        let arena_live = self.arena.live();
        let ecn = self.metrics.ecn_marks;
        self.tracer
            .sample(at, &self.links, arena_live, live_desc, ecn);
        if let Some(cadence) = self.tracer.cadence_ps() {
            if !self.queue.is_empty() {
                self.queue.push(at + cadence, Event::TraceSample);
            }
        }
    }

    fn tx_done(&mut self, link_id: LinkId) {
        let link = &mut self.links[link_id];
        link.busy = false;
        let entry = link
            .queue
            .pop_front()
            .expect("TxDone with empty queue");
        let class = entry.class as usize;
        let size = entry.bytes as u64;
        link.queued_bytes -= size;
        link.class_bytes[class] -= size;
        link.bytes_tx += size;
        let alive = link.alive;
        // hysteresis: un-pause when the class-0 backlog drains below LO
        let mut unpaused_node = None;
        if link.pausing && link.class_bytes[0] < link.pause_lo() {
            link.pausing = false;
            let from = link.from as usize;
            self.node_paused[from] -= 1;
            if self.node_paused[from] == 0 {
                unpaused_node = Some(from);
            }
        }
        if alive {
            // flight recorder: log the finished hop. TxDone fires at
            // txstart + serialization, so queueing is recovered as
            // (now - ser) - enq; the delivery time t_enq + queue + ser
            // + prop equals the Arrive timestamp exactly. A single
            // branch when tracing is off. Logged *before* the arrival
            // is scheduled — a cross-shard handoff takes the packet
            // out of this arena right below.
            if self.tracer.enabled() {
                let link = &self.links[link_id];
                if let Some(p) = self.arena.get(entry.id) {
                    let ser = entry.bytes as u64 * link.ps_per_byte;
                    self.tracer.hop(crate::trace::HopRecord {
                        tenant: p.tenant,
                        block: p.block,
                        kind: p.kind,
                        link: link_id as u32,
                        from: link.from,
                        to: link.to,
                        t_enq: entry.enq_ps,
                        queue_ps: (self.now - ser)
                            .saturating_sub(entry.enq_ps),
                        ser_ps: ser,
                        prop_ps: link.latency_ps,
                    });
                }
            }
            // the Arrive key is computed by the link's *owner* as a
            // pure function of the link's own history — identical no
            // matter which shard (if any) the destination lives on
            let (key, dst) = {
                let link = &mut self.links[link_id];
                let seq = link.seq;
                link.seq += 1;
                let at = self.now + link.latency_ps;
                (link_key(at, link_id, seq), link.to)
            };
            let remote = self
                .shard
                .as_ref()
                .is_some_and(|rt| rt.node_shard[dst as usize] != rt.me);
            if remote {
                // cross-shard handoff: move the payload out of this
                // shard's arena; the owner shard re-allocates it and
                // schedules the Arrive under the same canonical key at
                // the next window barrier (always before `at` — the
                // propagation delay is at least one lookahead)
                let pkt = self.arena.take(entry.id);
                let rt = self.shard.as_mut().expect("remote implies shard");
                let d = rt.node_shard[dst as usize] as usize;
                rt.pkt_out[d].push(PacketHandoff {
                    key,
                    link: link_id,
                    pkt,
                });
            } else {
                self.queue.push_keyed(
                    key,
                    Event::Arrive {
                        link: link_id,
                        packet: entry.id,
                    },
                );
            }
        } else {
            self.metrics.drops_link_down += 1;
            self.arena.free(entry.id);
        }
        let link = &self.links[link_id];
        if link.queue_len() > 0 {
            start_tx(
                &mut self.links,
                &self.node_paused,
                &mut self.queue,
                self.now,
                link_id,
            );
        }
        // resume the up-links that were blocked on this node
        if let Some(node) = unpaused_node {
            self.rekick_node_inputs(node);
        }
    }

    /// Restart any idle, backlogged up-link feeding `node` (after its
    /// pause count drops to zero — via drain hysteresis or because a
    /// pausing output died).
    fn rekick_node_inputs(&mut self, node: usize) {
        let ins = self.nodes[node].in_links.clone();
        for l in ins {
            let link = &self.links[l];
            if !link.busy && link.is_up() && link.queue_len() > 0 {
                start_tx(
                    &mut self.links,
                    &self.node_paused,
                    &mut self.queue,
                    self.now,
                    l,
                );
            }
        }
    }

    fn deliver(&mut self, link_id: LinkId, id: PacketId) {
        let (to, in_port) = {
            let l = &self.links[link_id];
            (l.to, l.to_port)
        };
        let kind = self
            .arena
            .get(id)
            .unwrap_or_else(|| panic!("stale {id:?} delivered"))
            .kind;
        // random loss injection on reduction traffic (fault tolerance
        // runs); droppable background/transport frames already have
        // their own loss story (the class-1 policer + RTO recovery).
        // Drawn from the *destination node's* fabric RNG so the loss
        // pattern a node sees is shard-invariant.
        if self.faults.loss_prob > 0.0
            && !kind.droppable()
            && self.nodes[to as usize]
                .fab_rng
                .chance(self.faults.loss_prob)
        {
            self.metrics.drops_injected += 1;
            self.arena.free(id);
            return;
        }
        self.metrics.on_delivery(kind);
        // the handler owns the arena entry from here: it must take,
        // forward or free it
        self.with_ctx(to, |body, ctx| match body {
            NodeBody::Switch(sw) => {
                crate::switch::handle_packet(sw, ctx, in_port, id)
            }
            NodeBody::Host(h) => {
                crate::host::handle_packet(h, ctx, in_port, id)
            }
        });
    }

    /// Borrow-split helper: hand the node body plus a [`Ctx`] over the
    /// rest of the network to `f`.
    fn with_ctx<F: FnOnce(&mut NodeBody, &mut Ctx)>(
        &mut self,
        node: NodeId,
        f: F,
    ) {
        let Network {
            nodes,
            links,
            queue,
            arena,
            metrics,
            jobs,
            cfg,
            now,
            node_paused,
            host_slowdown,
            tracer,
            shard,
            ..
        } = self;
        let n = &mut nodes[node as usize];
        let Node {
            body,
            ports,
            seq,
            fab_rng,
            ..
        } = n;
        let mut ctx = Ctx {
            now: *now,
            node_id: node,
            ports: ports.as_slice(),
            links,
            queue,
            arena,
            rng: fab_rng,
            metrics,
            jobs,
            cfg,
            actor_seq: seq,
            shard: shard.as_deref_mut(),
            node_paused,
            slowdown: host_slowdown[node as usize],
            tracer,
        };
        f(body, &mut ctx);
    }

    /// Every directed link touching `node` (its out-ports plus the
    /// links terminating at it).
    fn touching_links(&self, node: NodeId) -> Vec<LinkId> {
        let n = &self.nodes[node as usize];
        n.ports.iter().chain(n.in_links.iter()).copied().collect()
    }

    /// Both directed links between `a` and `b` (a flap kills the cable,
    /// not one direction).
    fn links_between(&self, a: NodeId, b: NodeId) -> Vec<LinkId> {
        let ls: Vec<LinkId> = self.nodes[a as usize]
            .ports
            .iter()
            .copied()
            .filter(|&l| self.links[l].to == b)
            .chain(
                self.nodes[b as usize]
                    .ports
                    .iter()
                    .copied()
                    .filter(|&l| self.links[l].to == a),
            )
            .collect();
        assert!(!ls.is_empty(), "fault plan flaps nonexistent link {a}<->{b}");
        ls
    }

    /// Take one down-reference on link `li` (overlapping flap windows
    /// and switch-failure intervals stack via the refcount). On the
    /// 0 -> 1 edge the link dies: every queued packet is dropped and
    /// freed (a downed link drops/queues nothing — the serializing
    /// head, if any, stays and is dropped by its pending `TxDone`),
    /// its pause contribution is released, and senders the release
    /// unblocks are re-kicked. Leak-free by construction: the random-
    /// fault-timeline property test in tests/churn.rs drains the run
    /// and asserts zero live arena packets.
    fn link_take_down(&mut self, li: LinkId) {
        let link = &mut self.links[li];
        link.down_refs += 1;
        if link.down_refs > 1 {
            return; // already down via another fault window
        }
        link.alive = false;
        // flush the FIFO from the tail, keeping the in-flight head for
        // its TxDone (which frees it on the dead-link branch)
        let keep = usize::from(link.busy);
        let mut dropped: Vec<QueuedPkt> = Vec::new();
        while link.queue.len() > keep {
            dropped.push(link.queue.pop_back().unwrap());
        }
        for q in &dropped {
            let size = q.bytes as u64;
            link.queued_bytes -= size;
            link.class_bytes[q.class as usize] -= size;
        }
        // dead links stop pausing anyone
        let mut unpaused = None;
        if link.pausing {
            link.pausing = false;
            let from = link.from as usize;
            self.node_paused[from] -= 1;
            if self.node_paused[from] == 0 {
                unpaused = Some(from);
            }
        }
        for q in dropped {
            self.metrics.drops_link_down += 1;
            self.arena.free(q.id);
        }
        if let Some(node) = unpaused {
            self.rekick_node_inputs(node);
        }
    }

    /// Release one down-reference on link `li`; on the 1 -> 0 edge the
    /// link revives and resumes serving (its queue is normally empty —
    /// enqueues drop while down — but a pre-fault head may still be
    /// serializing, and routing may have kept feeding a live reverse
    /// direction).
    fn link_bring_up(&mut self, li: LinkId) {
        let link = &mut self.links[li];
        debug_assert!(link.down_refs > 0, "bring-up on a live link");
        link.down_refs = link.down_refs.saturating_sub(1);
        if link.down_refs > 0 {
            return; // still held down by an overlapping fault
        }
        link.alive = true;
        if !link.busy && link.queue_len() > 0 {
            start_tx(
                &mut self.links,
                &self.node_paused,
                &mut self.queue,
                self.now,
                li,
            );
        }
    }

    /// Fault injection: kill a switch — its soft state is lost
    /// (Section 3.3: treated like packet loss by the protocol). The
    /// take-down of its links rides as separate per-link
    /// [`Event::LinkDownOne`] events at the same timestamp (scheduled
    /// by [`Network::kick_jobs`]), so each one has a single owning
    /// shard; soft-state loss and link death touch disjoint state and
    /// therefore commute across shards.
    pub fn fail_switch(&mut self, node: NodeId) {
        self.metrics.switch_failures += 1;
        if let NodeBody::Switch(sw) =
            &mut self.nodes[node as usize].body
        {
            crate::switch::clear_soft_state(sw);
        }
    }

    /// Fault injection: revive a failed switch. Its links come back up
    /// (via the paired [`Event::LinkUpOne`] events) but the soft state
    /// stays lost — in-flight reductions that depended on it recover
    /// through the protocol (leader timeouts, retransmission,
    /// re-reduction), not through state restoration.
    pub fn recover_switch(&mut self, node: NodeId) {
        self.metrics.switch_recoveries += 1;
        let _ = node;
    }

    /// Convenience: total wall-clock utilization of a link over `[0, end]`.
    pub fn link_utilization(&self, link: LinkId, end: Time) -> f64 {
        if end == 0 {
            return 0.0;
        }
        self.links[link].busy_ps as f64 / end as f64
    }
}
