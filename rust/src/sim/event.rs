//! Event heap for the discrete-event engine.
//!
//! Events are ordered by (time, sequence). The sequence number makes the
//! order of simultaneous events deterministic (insertion order), which
//! keeps whole runs bit-reproducible from the seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::packet::Packet;
use super::Time;

/// All event kinds the engine dispatches.
#[derive(Debug)]
pub enum Event {
    /// Packet finishes propagation and arrives at `links[link].to`.
    /// Boxed: keeps heap entries small — heap sift cost dominates the
    /// event loop otherwise (EXPERIMENTS.md §Perf).
    Arrive { link: usize, packet: Box<Packet> },
    /// Sender port of `links[link]` finished serializing; pop next.
    TxDone { link: usize },
    /// Canary descriptor timeout (switch, table slot, generation).
    SwitchTimeout { node: u32, slot: u32, generation: u64 },
    /// Host protocol timer (retransmission, noise-delayed send, ...).
    HostTimer { node: u32, timer: u64 },
    /// Scheduled switch/link failure (fault injection).
    Fail { node: u32 },
    /// Generic job kick-off (start a host's injection loop).
    JobWake { node: u32, job: u32 },
}

struct HeapEntry {
    /// `(time << 64) | seq` — one u128 comparison per sift step instead
    /// of two u64 compares (the heap dominates the event loop; see
    /// EXPERIMENTS.md §Perf).
    key: u128,
    event: Event,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other.key.cmp(&self.key)
    }
}

/// Deterministic min-heap of timestamped events.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<HeapEntry>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: Time, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        let key = ((time as u128) << 64) | seq as u128;
        self.heap.push(HeapEntry { key, event });
    }

    pub fn pop(&mut self) -> Option<(Time, Event)> {
        self.heap
            .pop()
            .map(|e| (((e.key >> 64) as Time), e.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::TxDone { link: 3 });
        q.push(10, Event::TxDone { link: 1 });
        q.push(20, Event::TxDone { link: 2 });
        let order: Vec<Time> = std::iter::from_fn(|| q.pop().map(|(t, _)| t))
            .collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..5 {
            q.push(7, Event::TxDone { link: i });
        }
        let mut links = Vec::new();
        while let Some((_, Event::TxDone { link })) = q.pop() {
            links.push(link);
        }
        assert_eq!(links, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn len_tracks() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(1, Event::TxDone { link: 0 });
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
