//! Event scheduler for the discrete-event engine: a hierarchical
//! calendar queue (timing wheel + overflow heap).
//!
//! Events are ordered by (time, sequence). The sequence number makes
//! the order of simultaneous events deterministic (insertion order),
//! which keeps whole runs bit-reproducible from the seed.
//!
//! The old implementation was one global `BinaryHeap`: every push/pop
//! paid an `O(log n)` sift over the whole frontier, and with hundreds
//! of thousands of in-flight events on the 1024–4096-host fabrics the
//! sift was the single largest cost in the event loop (EXPERIMENTS.md
//! §Perf). The calendar queue exploits what a network simulator knows
//! about its own future: almost every scheduled event lands within a
//! few link-hops of *now*. Time is bucketed into `2^SLOT_SHIFT` ps
//! slots (~65.5 ns — about one MTU serialization at 100 Gbps) across a
//! `WHEEL_SLOTS`-wide window (~268 µs); a push into the window is an
//! O(1) `Vec` append, and only the handful of events sharing the
//! *current* slot ever enter a comparison-ordered heap. Far-future
//! events (multi-ms retransmission timers) wait in an overflow heap
//! and migrate into the wheel as the window slides over them.
//!
//! Determinism argument: every entry carries the same
//! `(time << 64) | seq` key the old heap ordered by. The wheel only
//! partitions entries by time slot — all entries of slot `s` are
//! dumped into the `current` heap before any of them pops, pushes into
//! the live slot go straight to `current`, and the overflow heap is
//! drained into the window *ahead* of the slots it covers — so pops
//! are globally key-ordered, exactly like the reference heap
//! (`tests/scheduler.rs` pins the equivalence on random streams with
//! duplicate timestamps; the seeded-run fingerprint pin and the CI
//! `determinism` job hold the end-to-end guarantee).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::arena::PacketId;
use super::Time;

/// Actor id of setup-time pushes (job kicks, fault timeline, trace
/// sampler): the plain [`EventQueue::push`] path. Sorts *after* every
/// node/link actor at the same timestamp and can never collide with
/// one (node ids stay below `1 << 31`; link actors carry
/// [`ACTOR_LINK_BIT`]).
pub const ACTOR_SETUP: u32 = 0xFFFF_FFFF;

/// High bit distinguishing link actors from node actors in an event
/// key, so a link and a node with the same index never collide.
pub const ACTOR_LINK_BIT: u32 = 0x8000_0000;

/// Canonical key of a runtime event: `time(64) | actor(32) | seq(32)`.
///
/// The sharded engine (sim/shard.rs) relies on every runtime event
/// being keyed by its *owner* — the node or directed link whose
/// per-actor counter stamps `seq` — so the key of any given event is
/// identical no matter which shard computes it, and merging per-shard
/// streams by key reproduces the serial engine's dispatch order
/// exactly (DESIGN.md §2.10).
#[inline]
pub fn event_key(time: Time, actor: u32, seq: u32) -> u128 {
    ((time as u128) << 64) | ((actor as u128) << 32) | seq as u128
}

/// Key of an event owned by directed link `link`.
#[inline]
pub fn link_key(time: Time, link: usize, seq: u32) -> u128 {
    event_key(time, ACTOR_LINK_BIT | link as u32, seq)
}

/// Key of an event owned by node `node`.
#[inline]
pub fn node_key(time: Time, node: u32, seq: u32) -> u128 {
    event_key(time, node, seq)
}

/// Wheel slot width: `2^16` ps = 65.536 ns.
const SLOT_SHIFT: u32 = 16;
/// Wheel width in slots (must be a power of two): 4096 slots ≈ 268 µs
/// of look-ahead — beyond every per-hop delay and the common protocol
/// timers; only multi-ms timers take the overflow path.
const WHEEL_SLOTS: u64 = 1 << 12;
const WHEEL_MASK: u64 = WHEEL_SLOTS - 1;

/// All event kinds the engine dispatches.
#[derive(Debug)]
pub enum Event {
    /// Packet finishes propagation and arrives at `links[link].to`.
    /// Carries a copyable arena id, not the packet: scheduler entries
    /// stay 32 bytes and the hot path never touches the allocator
    /// (`sim/arena.rs`, EXPERIMENTS.md §Perf).
    Arrive { link: usize, packet: PacketId },
    /// Sender port of `links[link]` finished serializing; pop next.
    TxDone { link: usize },
    /// Canary descriptor timeout (switch, table slot, generation).
    SwitchTimeout { node: u32, slot: u32, generation: u64 },
    /// Host protocol timer (retransmission, noise-delayed send, ...).
    HostTimer { node: u32, timer: u64 },
    /// Scheduled switch failure (fault injection): all links touching
    /// `node` go down and its soft state is lost.
    Fail { node: u32 },
    /// Scheduled switch recovery: the links come back; the soft state
    /// stays lost (leaders re-reduce, Section 3.3 loss equivalence).
    Recover { node: u32 },
    /// Scheduled down edge for one *directed* link (fault timeline,
    /// pre-resolved at kick time so each event is owned by exactly one
    /// shard). `count` is set on one directed link per flap pair so the
    /// flap metrics keep their per-pair semantics.
    LinkDownOne { link: usize, count: bool },
    /// Scheduled up edge for one directed link.
    LinkUpOne { link: usize, count: bool },
    /// Generic job kick-off (start a host's injection loop).
    JobWake { node: u32, job: u32 },
    /// Telemetry sampler tick (`trace/`). Scheduled only while tracing
    /// is enabled; re-arms itself while other work is pending and is
    /// dispatched *outside* the `events_processed` counter so traced
    /// runs keep fingerprints comparable to untraced ones.
    TraceSample,
}

struct HeapEntry {
    /// `(time << 64) | seq` — one u128 comparison per sift step instead
    /// of two u64 compares.
    key: u128,
    event: Event,
}

impl HeapEntry {
    #[inline]
    fn slot(&self) -> u64 {
        ((self.key >> 64) as u64) >> SLOT_SHIFT
    }
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other.key.cmp(&self.key)
    }
}

/// Deterministic min-priority scheduler of timestamped events
/// (calendar queue; same push/pop surface as the old global heap).
pub struct EventQueue {
    /// Entries of the slot the clock currently occupies (plus any
    /// defensively accepted past-time pushes) — the only entries that
    /// ever pay heap sift cost.
    current: BinaryHeap<HeapEntry>,
    /// `WHEEL_SLOTS` buckets of future entries within the window;
    /// bucket `s & WHEEL_MASK` holds exactly the entries of absolute
    /// slot `s` for the one `s` inside `(cur_slot, cur_slot + WHEEL_SLOTS)`.
    wheel: Vec<Vec<HeapEntry>>,
    /// One bit per bucket: non-empty. Advancing the clock scans words,
    /// not buckets.
    occupied: Vec<u64>,
    /// Entries in the wheel (not counting `current`/`overflow`).
    wheel_len: usize,
    /// Entries at or beyond the window horizon.
    overflow: BinaryHeap<HeapEntry>,
    /// Absolute slot index of the `current` epoch.
    cur_slot: u64,
    next_seq: u64,
    len: usize,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue {
            current: BinaryHeap::new(),
            wheel: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            occupied: vec![0u64; (WHEEL_SLOTS / 64) as usize],
            wheel_len: 0,
            overflow: BinaryHeap::new(),
            cur_slot: 0,
            next_seq: 0,
            len: 0,
        }
    }
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: Time, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        // a queue-lifetime counter cannot reach 2^32 setup pushes; the
        // truncation keeps insertion-order tie-breaks exact
        debug_assert!(seq <= u32::MAX as u64);
        self.push_keyed(event_key(time, ACTOR_SETUP, seq as u32), event);
    }

    /// Push with a caller-computed canonical key ([`event_key`]). The
    /// runtime paths (timers, TxDone/Arrive) key events by their owning
    /// node or link so the sharded engine reproduces serial order.
    pub fn push_keyed(&mut self, key: u128, event: Event) {
        let entry = HeapEntry { key, event };
        self.len += 1;
        let slot = entry.slot();
        if slot <= self.cur_slot {
            // the live slot (or, defensively, the past): straight into
            // the ordered heap so it pops before everything later
            self.current.push(entry);
        } else if slot < self.cur_slot + WHEEL_SLOTS {
            self.bucket_push(slot, entry);
        } else {
            self.overflow.push(entry);
        }
    }

    pub fn pop(&mut self) -> Option<(Time, Event)> {
        loop {
            if let Some(e) = self.current.pop() {
                self.len -= 1;
                return Some(((e.key >> 64) as Time, e.event));
            }
            // `current` is dry: advance the clock to the next populated
            // slot. Window invariant (re-established by `advance_to`):
            // overflow entries are all at/beyond the horizon, so the
            // wheel — when non-empty — always holds the earliest event.
            if self.wheel_len > 0 {
                let slot = self.next_wheel_slot();
                self.advance_to(slot);
            } else if let Some(top) = self.overflow.peek() {
                let slot = top.slot();
                self.advance_to(slot);
            } else {
                return None;
            }
        }
    }

    /// Pop the earliest event strictly before `bound`, leaving later
    /// events untouched. The bounded-window engine processes one
    /// lookahead cell at a time with this; `pop()` is `pop_before(MAX)`.
    pub fn pop_before(&mut self, bound: Time) -> Option<(Time, Event)> {
        loop {
            if let Some(top) = self.current.peek() {
                let t = (top.key >> 64) as Time;
                if t >= bound {
                    // every wheel/overflow entry is in a later slot
                    // than `current`'s, hence also >= bound
                    return None;
                }
                let e = self.current.pop().unwrap();
                self.len -= 1;
                return Some((t, e.event));
            }
            // `current` is dry: advance only while the next populated
            // slot *starts* before the bound (its entries may still
            // individually be at/after it — the peek above filters)
            let slot = if self.wheel_len > 0 {
                self.next_wheel_slot()
            } else if let Some(top) = self.overflow.peek() {
                top.slot()
            } else {
                return None;
            };
            if (slot << SLOT_SHIFT) >= bound {
                return None;
            }
            self.advance_to(slot);
        }
    }

    /// Timestamp of the earliest pending event without popping it.
    pub fn next_time(&self) -> Option<Time> {
        if let Some(top) = self.current.peek() {
            return Some((top.key >> 64) as Time);
        }
        if self.wheel_len > 0 {
            // the next populated slot precedes every other wheel slot
            // and the whole overflow heap; min inside it is global min
            let slot = self.next_wheel_slot();
            let b = (slot & WHEEL_MASK) as usize;
            return self.wheel[b].iter().map(|e| (e.key >> 64) as Time).min();
        }
        self.overflow.peek().map(|top| (top.key >> 64) as Time)
    }

    /// Remove and return every pending entry with its key, in
    /// arbitrary order (the caller re-pushes by key). Used when
    /// merging per-shard queues back into one engine.
    pub fn drain_entries(&mut self) -> Vec<(u128, Event)> {
        let mut out = Vec::with_capacity(self.len);
        out.extend(self.current.drain().map(|e| (e.key, e.event)));
        for bucket in &mut self.wheel {
            out.extend(bucket.drain(..).map(|e| (e.key, e.event)));
        }
        for w in &mut self.occupied {
            *w = 0;
        }
        self.wheel_len = 0;
        out.extend(self.overflow.drain().map(|e| (e.key, e.event)));
        self.len = 0;
        out
    }

    /// Raw setup-push counter (see [`EventQueue::set_next_seq`]).
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Seed the setup-push counter. A freshly split shard queue starts
    /// where the global queue's counter stopped so replicated setup
    /// entries (the trace sampler tick) keep their original keys and
    /// later plain pushes cannot collide with them.
    pub fn set_next_seq(&mut self, seq: u64) {
        self.next_seq = seq;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Visit every pending event across all three tiers (current
    /// epoch, wheel buckets, overflow) in arbitrary order. Read-only;
    /// used by the end-of-run conservation audit
    /// (`sim::invariants`), never on the hot path.
    pub fn for_each_pending(&self, mut f: impl FnMut(&Event)) {
        for e in &self.current {
            f(&e.event);
        }
        for bucket in &self.wheel {
            for e in bucket {
                f(&e.event);
            }
        }
        for e in &self.overflow {
            f(&e.event);
        }
    }

    #[inline]
    fn bucket_push(&mut self, slot: u64, entry: HeapEntry) {
        let b = (slot & WHEEL_MASK) as usize;
        if self.wheel[b].is_empty() {
            self.occupied[b >> 6] |= 1u64 << (b & 63);
        }
        self.wheel[b].push(entry);
        self.wheel_len += 1;
    }

    /// Move the clock to `slot`: dump that bucket into `current`, then
    /// slide the window — overflow entries now inside the horizon
    /// migrate to their buckets (each entry migrates at most once).
    fn advance_to(&mut self, slot: u64) {
        debug_assert!(slot > self.cur_slot);
        self.cur_slot = slot;
        let b = (slot & WHEEL_MASK) as usize;
        if !self.wheel[b].is_empty() {
            self.wheel_len -= self.wheel[b].len();
            self.occupied[b >> 6] &= !(1u64 << (b & 63));
            let mut bucket = std::mem::take(&mut self.wheel[b]);
            self.current.extend(bucket.drain(..));
            // hand the emptied allocation back for reuse
            self.wheel[b] = bucket;
        }
        let horizon = self.cur_slot + WHEEL_SLOTS;
        while let Some(top) = self.overflow.peek() {
            let s = top.slot();
            if s >= horizon {
                break;
            }
            let entry = self.overflow.pop().unwrap();
            if s <= self.cur_slot {
                self.current.push(entry);
            } else {
                self.bucket_push(s, entry);
            }
        }
    }

    /// First populated absolute slot after `cur_slot` (caller
    /// guarantees `wheel_len > 0`), via the occupancy bitmap.
    fn next_wheel_slot(&self) -> u64 {
        let words = self.occupied.len();
        let start = ((self.cur_slot + 1) & WHEEL_MASK) as usize;
        let (w0, bit0) = (start >> 6, start & 63);
        let mut found = None;
        let masked = self.occupied[w0] & (!0u64 << bit0);
        if masked != 0 {
            found = Some((w0 << 6) + masked.trailing_zeros() as usize);
        } else {
            for i in 1..=words {
                let w = (w0 + i) % words;
                let m = if w == w0 {
                    // wrapped all the way: the bits below `bit0`
                    self.occupied[w] & !(!0u64 << bit0)
                } else {
                    self.occupied[w]
                };
                if m != 0 {
                    found = Some((w << 6) + m.trailing_zeros() as usize);
                    break;
                }
            }
        }
        let residue =
            found.expect("wheel_len > 0 with empty occupancy bitmap") as u64;
        // map the bucket residue back to the one absolute slot it can
        // hold, in (cur_slot, cur_slot + WHEEL_SLOTS)
        let next = self.cur_slot + 1;
        next + ((residue + WHEEL_SLOTS - (next & WHEEL_MASK)) & WHEEL_MASK)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::TxDone { link: 3 });
        q.push(10, Event::TxDone { link: 1 });
        q.push(20, Event::TxDone { link: 2 });
        let order: Vec<Time> = std::iter::from_fn(|| q.pop().map(|(t, _)| t))
            .collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..5 {
            q.push(7, Event::TxDone { link: i });
        }
        let mut links = Vec::new();
        while let Some((_, Event::TxDone { link })) = q.pop() {
            links.push(link);
        }
        assert_eq!(links, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn len_tracks() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(1, Event::TxDone { link: 0 });
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }

    /// Entries across all three tiers (current slot, wheel window,
    /// overflow) interleave into one key-ordered stream.
    #[test]
    fn wheel_and_overflow_interleave_in_order() {
        let mut q = EventQueue::new();
        let horizon = WHEEL_SLOTS << SLOT_SHIFT;
        let times = [
            0,                   // current slot
            1,                   // current slot, later seq
            1 << SLOT_SHIFT,     // first wheel bucket
            horizon - 1,         // last wheel bucket
            horizon,             // first overflow entry
            horizon * 7 + 12345, // deep overflow
        ];
        // push in reverse so insertion order disagrees with time order
        for (i, &t) in times.iter().enumerate().rev() {
            q.push(t, Event::TxDone { link: i });
        }
        let popped: Vec<Time> =
            std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(popped, times);
    }

    /// Pushing at (or before) the time currently being popped still
    /// orders after already-popped entries and by sequence among ties.
    #[test]
    fn push_at_now_lands_in_the_live_slot() {
        let mut q = EventQueue::new();
        let far = 100 << SLOT_SHIFT;
        q.push(far, Event::TxDone { link: 0 });
        assert_eq!(q.pop().unwrap().0, far); // clock advanced to `far`
        q.push(far, Event::TxDone { link: 1 }); // same slot, zero delay
        q.push(far + 2, Event::TxDone { link: 2 });
        q.push(far, Event::TxDone { link: 3 });
        let order: Vec<usize> = std::iter::from_fn(|| {
            q.pop().map(|(_, e)| match e {
                Event::TxDone { link } => link,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(order, vec![1, 3, 2]);
    }

    /// `pop_before` stops exactly at the bound and leaves later
    /// entries poppable, across all three storage tiers.
    #[test]
    fn pop_before_respects_the_bound() {
        let mut q = EventQueue::new();
        let horizon = WHEEL_SLOTS << SLOT_SHIFT;
        let times = [3, 40, 1 << SLOT_SHIFT, horizon + 9];
        for (i, &t) in times.iter().enumerate() {
            q.push(t, Event::TxDone { link: i });
        }
        assert_eq!(q.next_time(), Some(3));
        let mut before: Vec<Time> =
            std::iter::from_fn(|| q.pop_before(41).map(|(t, _)| t)).collect();
        assert_eq!(before, vec![3, 40]);
        assert_eq!(q.next_time(), Some(1 << SLOT_SHIFT));
        // a fresh push below the bound is still caught by a later call
        q.push(40, Event::TxDone { link: 9 });
        before = std::iter::from_fn(|| q.pop_before(41).map(|(t, _)| t))
            .collect();
        assert_eq!(before, vec![40]);
        let rest: Vec<Time> =
            std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(rest, vec![1 << SLOT_SHIFT, horizon + 9]);
    }

    /// Keyed pushes interleave with plain pushes in key order: at equal
    /// times, node/link actors precede the setup actor.
    #[test]
    fn keyed_pushes_order_by_actor_then_seq() {
        let mut q = EventQueue::new();
        q.push(7, Event::TxDone { link: 100 }); // ACTOR_SETUP
        q.push_keyed(link_key(7, 2, 0), Event::TxDone { link: 2 });
        q.push_keyed(node_key(7, 5, 1), Event::TxDone { link: 51 });
        q.push_keyed(node_key(7, 5, 0), Event::TxDone { link: 50 });
        let order: Vec<usize> = std::iter::from_fn(|| {
            q.pop().map(|(_, e)| match e {
                Event::TxDone { link } => link,
                _ => unreachable!(),
            })
        })
        .collect();
        // node 5 (seq 0 then 1), link 2 (bit 31 set), setup last
        assert_eq!(order, vec![50, 51, 2, 100]);
    }

    /// `drain_entries` + `push_keyed` round-trips the full pending set.
    #[test]
    fn drain_entries_round_trips() {
        let mut q = EventQueue::new();
        let horizon = WHEEL_SLOTS << SLOT_SHIFT;
        let times = [5, 1 << SLOT_SHIFT, horizon * 3 + 1];
        for (i, &t) in times.iter().enumerate() {
            q.push(t, Event::TxDone { link: i });
        }
        let entries = q.drain_entries();
        assert!(q.is_empty());
        let mut q2 = EventQueue::new();
        for (key, ev) in entries {
            q2.push_keyed(key, ev);
        }
        let popped: Vec<Time> =
            std::iter::from_fn(|| q2.pop().map(|(t, _)| t)).collect();
        assert_eq!(popped, times);
    }

    /// Overflow entries migrate into the window as the clock slides,
    /// without ever overtaking wheel entries.
    #[test]
    fn overflow_migrates_behind_the_window() {
        let mut q = EventQueue::new();
        let horizon = WHEEL_SLOTS << SLOT_SHIFT;
        // wheel entry early, overflow entries that later join the wheel
        q.push(5, Event::TxDone { link: 0 });
        q.push(horizon + 5, Event::TxDone { link: 1 });
        q.push(2 * horizon + 5, Event::TxDone { link: 2 });
        assert_eq!(q.pop().unwrap().0, 5);
        // after the first advance past `horizon`, entry 1 is in the
        // window; pushing a nearer event must still pop first
        q.push(horizon + 1, Event::TxDone { link: 3 });
        let order: Vec<Time> =
            std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![horizon + 1, horizon + 5, 2 * horizon + 5]);
    }
}
