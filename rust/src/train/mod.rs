//! Data-parallel trainer: the end-to-end validation driver.
//!
//! Each step:
//! 1. every worker executes the AOT `train_step` HLO (L2 model + L1
//!    quantize kernel) through PJRT on its own synthetic batch;
//! 2. the per-worker fixed-point gradients are allreduced — the values
//!    with the same saturating ALU the simulated switches use, the
//!    *timing* through the simulated fat tree running Canary (or a
//!    baseline) under congestion;
//! 3. the summed gradient feeds the AOT `apply_update` HLO.
//!
//! The loss curve plus per-step simulated communication time go to
//! stdout / EXPERIMENTS.md.

use crate::util::error::{Error, Result};

use crate::collectives::{runner, Algo};
use crate::config::{FatTreeConfig, SimConfig};
use crate::runtime::{
    lit_f32, lit_f32_scalar, lit_i32, lit_i32_2d, lit_u32_scalar, to_f32,
    to_f32_scalar, to_i32, Executable, Runtime,
};
use crate::sim::Time;
use crate::switch::alu;
use crate::traffic::TrafficSpec;
use crate::util::rng::Rng;
use crate::workload::{JobBuilder, ScenarioBuilder};

/// Trainer configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model preset name (must exist in the manifest: tiny/base/...).
    pub preset: String,
    pub workers: usize,
    pub steps: usize,
    pub lr: f32,
    /// Allreduce algorithm whose *communication time* is simulated.
    pub algo: Algo,
    /// Simulate the gradient allreduce on the fat tree each
    /// `comm_every` steps (0 = never; keeps long runs fast).
    pub comm_every: usize,
    /// Put congestion on the simulated network.
    pub congestion: bool,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "base".into(),
            workers: 4,
            steps: 100,
            lr: 0.5,
            algo: Algo::Canary,
            comm_every: 10,
            congestion: true,
            seed: 0xBEEF,
        }
    }
}

/// One step's record.
#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    pub mean_loss: f32,
    /// Simulated allreduce time for this step's gradient, if simulated.
    pub comm_ps: Option<Time>,
    pub wall_ms: f64,
}

/// The trainer: compiled executables + parameter state.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub frac_bits: u32,
    pub param_count: usize,
    pub vocab: usize,
    pub batch: usize,
    pub seq_len: usize,
    init: Executable,
    step_exe: Executable,
    apply: Executable,
    pub params: Vec<f32>,
    rng: Rng,
}

impl Trainer {
    /// Load artifacts and initialize parameters.
    pub fn new(rt: &Runtime, cfg: TrainConfig) -> Result<Trainer> {
        let model = rt
            .manifest
            .models
            .get(&cfg.preset)
            .ok_or_else(|| {
                Error::msg(format!(
                    "preset '{}' not in manifest (have: {:?}); \
                     re-run `make artifacts PRESETS=...`",
                    cfg.preset,
                    rt.manifest.models.keys().collect::<Vec<_>>()
                ))
            })?
            .clone();
        let init = rt.compile(&format!("{}_init_params", cfg.preset))?;
        let step_exe = rt.compile(&format!("{}_train_step", cfg.preset))?;
        let apply = rt.compile(&format!("{}_apply_update", cfg.preset))?;
        let out = init.run(&[lit_u32_scalar(cfg.seed as u32)])?;
        let params = to_f32(&out[0])?;
        assert_eq!(params.len(), model.param_count);
        let rng = Rng::new(cfg.seed);
        Ok(Trainer {
            frac_bits: model.frac_bits,
            param_count: model.param_count,
            vocab: model.vocab,
            batch: model.batch,
            seq_len: model.seq_len,
            cfg,
            init,
            step_exe,
            apply,
            params,
            rng,
        })
    }

    /// Synthetic learnable corpus: noisy affine Markov chains over the
    /// vocabulary (the model can drive loss well below ln(V)).
    pub fn make_batch(&mut self, worker: usize) -> Vec<i32> {
        let v = self.vocab as u64;
        let mut out = Vec::with_capacity(self.batch * self.seq_len);
        for _ in 0..self.batch {
            let mut tok = self.rng.gen_range(v);
            out.push(tok as i32);
            for _ in 1..self.seq_len {
                tok = if self.rng.chance(0.05) {
                    self.rng.gen_range(v) // 5 % noise
                } else {
                    (tok * 5 + 17 + worker as u64 % 2) % v
                };
                out.push(tok as i32);
            }
        }
        out
    }

    /// Run one data-parallel step; returns (mean loss, summed qgrads).
    pub fn step_compute(&mut self) -> Result<(f32, Vec<i32>)> {
        let mut qsum = vec![0i32; self.param_count];
        let mut loss_sum = 0.0f32;
        for w in 0..self.cfg.workers {
            let tokens = self.make_batch(w);
            let tok_lit = lit_i32_2d(&tokens, self.batch, self.seq_len)?;
            let out =
                self.step_exe.run(&[lit_f32(&self.params), tok_lit])?;
            loss_sum += to_f32_scalar(&out[0])?;
            let qg = to_i32(&out[1])?;
            // the allreduce: saturating fixed-point sum — bit-identical
            // to what the simulated switches compute (switch::alu)
            alu::sat_accumulate(&mut qsum, &qg);
        }
        Ok((loss_sum / self.cfg.workers as f32, qsum))
    }

    /// Apply the summed gradient (dequantize + average + SGD in HLO).
    pub fn step_apply(&mut self, qsum: &[i32]) -> Result<()> {
        let out = self.apply.run(&[
            lit_f32(&self.params),
            lit_i32(qsum),
            lit_f32_scalar(self.cfg.lr),
            lit_f32_scalar(self.cfg.workers as f32),
        ])?;
        self.params = to_f32(&out[0])?;
        Ok(())
    }

    /// Simulate the timing of this step's gradient allreduce on the
    /// fat tree (Canary or baseline, with congestion).
    pub fn simulate_comm(&mut self, step: usize) -> Option<Time> {
        let grad_bytes = (self.param_count * 4) as u64;
        let sim = SimConfig::default().with_seed(
            self.cfg.seed ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15),
        );
        let sc = ScenarioBuilder::new(FatTreeConfig::small())
            .sim(sim)
            .traffic(self.cfg.congestion.then(TrafficSpec::uniform))
            .job(
                JobBuilder::new(self.cfg.algo)
                    .hosts(self.cfg.workers as u32)
                    .data_bytes(grad_bytes),
            );
        let mut exp = sc.build(self.cfg.seed + step as u64);
        let results = runner::run_to_completion(&mut exp.net, u64::MAX);
        results[0].runtime_ps
    }

    /// Re-initialize parameters (fresh training run).
    pub fn reset(&mut self, seed: u32) -> Result<()> {
        let out = self.init.run(&[lit_u32_scalar(seed)])?;
        self.params = to_f32(&out[0])?;
        Ok(())
    }

    /// Full training loop with logging.
    pub fn train(&mut self) -> Result<Vec<StepLog>> {
        let mut logs = Vec::with_capacity(self.cfg.steps);
        for step in 0..self.cfg.steps {
            // lint: allow(wall-clock, step wall-time for logs only; never fed back into the sim)
            let t0 = std::time::Instant::now();
            let (loss, qsum) = self.step_compute()?;
            let comm_ps = if self.cfg.comm_every > 0
                && step % self.cfg.comm_every == 0
            {
                self.simulate_comm(step)
            } else {
                None
            };
            self.step_apply(&qsum)?;
            let log = StepLog {
                step,
                mean_loss: loss,
                comm_ps,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            };
            logs.push(log);
        }
        Ok(logs)
    }
}
