//! Result emission: CSV files under `results/` plus paper-style
//! markdown/ASCII rows on stdout, one series per figure.

use std::fs;
use std::io::Write as _;
use std::path::Path;

/// A tabular result series (one figure or table).
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Series {
    pub fn new(name: &str, columns: &[&str]) -> Series {
        Series {
            name: name.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// `fmt_row!`-style convenience for mixed numeric rows.
    pub fn push_display(&mut self, row: &[&dyn std::fmt::Display]) {
        self.push(row.iter().map(|v| format!("{v}")).collect());
    }

    /// Write `results/<name>.csv`.
    pub fn write_csv(&self, dir: &str) -> std::io::Result<String> {
        fs::create_dir_all(dir)?;
        let path = Path::new(dir).join(format!("{}.csv", self.name));
        let mut f = fs::File::create(&path)?;
        writeln!(f, "{}", self.columns.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path.to_string_lossy().to_string())
    }

    /// Print as an aligned table.
    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect();
        println!("== {} ==", self.name);
        println!("{}", header.join("  "));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            println!("{}", cells.join("  "));
        }
        println!();
    }
}

/// Format helper: Gbps with 1 decimal.
pub fn gbps(x: Option<f64>) -> String {
    match x {
        Some(v) => format!("{v:.1}"),
        None => "timeout".to_string(),
    }
}

/// Format helper: microseconds with 1 decimal.
pub fn us(x: Option<u64>) -> String {
    match x {
        Some(v) => format!("{:.1}", v as f64 / 1e6),
        None => "timeout".to_string(),
    }
}

/// One-line human summary of a run's background-flow statistics
/// (started/completed counts, completion fraction, FCT p50/p99). With a
/// reactive transport active, a second line reports what it did
/// (CE echoes, CNPs, retransmissions, duplicates, abandoned flows).
pub fn flow_summary(f: &crate::metrics::FlowStats) -> String {
    let p = f.fct_percentiles_us(&[50.0, 99.0]);
    let mut line = format!(
        "flows: {} started, {} completed ({:.1}%)  \
         fct p50 {:.1} us  p99 {:.1} us",
        f.started,
        f.completed,
        100.0 * f.completion_fraction(),
        p[0],
        p[1],
    );
    let transport_active = f.ecn_delivered
        + f.cnps_sent
        + f.acks_received
        + f.retrans_pkts
        + f.rto_fired
        > 0;
    if transport_active {
        line.push_str(&format!(
            "\ntransport: ce {}  cnps {}/{}  retrans {} pkts \
             ({} rto, {} dup, {} abandoned)  goodput/throughput {}/{} B",
            f.ecn_delivered,
            f.cnps_received,
            f.cnps_sent,
            f.retrans_pkts,
            f.rto_fired,
            f.dup_pkts,
            f.abandoned,
            f.goodput_bytes(),
            f.throughput_bytes(),
        ));
    }
    line
}

/// One-line engine-throughput summary (events/sec over the dispatch
/// loop's wall time, arena peaks) — the numbers the `figures scale`
/// sweep records per cell and `scripts/check_bench.py` gates on.
pub fn engine_summary(m: &crate::metrics::Metrics) -> String {
    let e = &m.engine;
    format!(
        "engine: {:.2} M events/s ({} events, {:.3}s wall)  \
         peak live pkts {}  arena slots {} ({} allocs)",
        e.events_per_sec() / 1e6,
        e.events,
        e.wall_secs,
        e.peak_live_packets,
        e.arena_slots,
        e.arena_allocs,
    )
}

/// One-line churn/fault summary: what the fault plan did to the run
/// (flaps, switch deaths/recoveries, stragglers, drops on dead links,
/// partial aggregates the timeouts emitted, job completion split).
/// Meant to be printed only when some fault counter moved — see
/// [`fault_activity`].
pub fn fault_summary(m: &crate::metrics::Metrics) -> String {
    format!(
        "faults: {} flaps ({} recovered)  {} switch fails \
         ({} recovered)  {} stragglers  {} link-down drops  \
         {} injected drops  {} partial aggregates  \
         jobs {} completed / {} stalled",
        m.link_flaps,
        m.link_recoveries,
        m.switch_failures,
        m.switch_recoveries,
        m.straggler_slowdowns,
        m.drops_link_down,
        m.drops_injected,
        m.partial_aggregates,
        m.jobs_completed,
        m.jobs_stalled,
    )
}

/// Per-component latency breakdown of the flight recorder's critical
/// paths (one row per traced block): where the block's end-to-end time
/// went, as percentages of serialization / queueing / propagation /
/// aggregation wait / timeout penalty. The components tile the path
/// exactly (trace-module invariant), so the percentage columns sum to
/// 100 up to rounding.
pub fn critical_path_breakdown(
    paths: &[crate::trace::BlockPath],
) -> Series {
    let mut s = Series::new(
        "critical_path_breakdown",
        &[
            "tenant", "block", "e2e_us", "queue_pct", "ser_pct",
            "prop_pct", "agg_wait_pct", "timeout_pct", "hops", "waits",
        ],
    );
    for p in paths {
        let e2e = p.e2e_ps().max(1) as f64;
        let pct = |c: u64| format!("{:.1}", 100.0 * c as f64 / e2e);
        s.push(vec![
            p.tenant.to_string(),
            p.block.to_string(),
            format!("{:.3}", p.e2e_ps() as f64 / 1e6),
            pct(p.queue_ps),
            pct(p.ser_ps),
            pct(p.prop_ps),
            pct(p.agg_wait_ps),
            pct(p.timeout_penalty_ps),
            p.n_hops.to_string(),
            p.n_waits.to_string(),
        ]);
    }
    s
}

/// Did any fault machinery engage this run? (Gates printing the
/// [`fault_summary`] line so clean runs stay visually unchanged.)
pub fn fault_activity(m: &crate::metrics::Metrics) -> bool {
    m.link_flaps
        + m.link_recoveries
        + m.switch_failures
        + m.switch_recoveries
        + m.straggler_slowdowns
        + m.drops_link_down
        + m.drops_injected
        + m.partial_aggregates
        + m.jobs_stalled
        > 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_summary_reads_sanely() {
        let m = crate::metrics::Metrics {
            engine: crate::metrics::EngineStats {
                events: 4_000_000,
                wall_secs: 2.0,
                peak_live_packets: 1234,
                arena_slots: 1234,
                ..Default::default()
            },
            ..Default::default()
        };
        let line = engine_summary(&m);
        assert!(line.contains("2.00 M events/s"), "{line}");
        assert!(line.contains("peak live pkts 1234"), "{line}");
    }

    #[test]
    fn fault_summary_reads_sanely() {
        let mut m = crate::metrics::Metrics::default();
        assert!(!fault_activity(&m), "clean metrics reported activity");
        m.link_flaps = 2;
        m.link_recoveries = 2;
        m.partial_aggregates = 5;
        m.jobs_completed = 1;
        assert!(fault_activity(&m));
        let line = fault_summary(&m);
        assert!(line.contains("2 flaps (2 recovered)"), "{line}");
        assert!(line.contains("5 partial aggregates"), "{line}");
        assert!(line.contains("jobs 1 completed / 0 stalled"), "{line}");
    }

    #[test]
    fn csv_roundtrip() {
        let mut s = Series::new("unit_test_series", &["a", "b"]);
        s.push(vec!["1".into(), "2.5".into()]);
        s.push(vec!["3".into(), "x".into()]);
        let dir = std::env::temp_dir().join("canary_report_test");
        let path = s.write_csv(dir.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n3,x\n");
    }

    #[test]
    fn formatting() {
        assert_eq!(gbps(Some(12.34)), "12.3");
        assert_eq!(gbps(None), "timeout");
        assert_eq!(us(Some(1_500_000)), "1.5");
    }

    #[test]
    fn formatting_edge_cases() {
        // zero is a value, not a timeout
        assert_eq!(gbps(Some(0.0)), "0.0");
        assert_eq!(us(Some(0)), "0.0");
        assert_eq!(us(None), "timeout");
        // sub-microsecond times and huge (stalled-run) times
        assert_eq!(us(Some(100_000)), "0.1");
        assert_eq!(us(Some(u64::MAX)), format!("{:.1}", u64::MAX as f64 / 1e6));
    }

    #[test]
    fn flow_summary_handles_an_idle_engine() {
        // nothing started: no division blow-up, percentiles are 0
        let f = crate::metrics::FlowStats::default();
        let line = flow_summary(&f);
        assert!(line.contains("0 started"), "{line}");
        assert!(line.contains("(0.0%)"), "{line}");
        assert!(line.contains("p50 0.0 us"), "{line}");
        assert!(
            !line.contains("transport:"),
            "idle stats printed a transport line: {line}"
        );
    }

    #[test]
    fn flow_summary_transport_line_appears_with_activity() {
        let mut f = crate::metrics::FlowStats::default();
        f.on_start(1, 0, 1, 100);
        f.cnps_sent = 3;
        let line = flow_summary(&f);
        assert!(line.contains("transport:"), "{line}");
        assert!(line.contains("cnps 0/3"), "{line}");
    }

    #[test]
    fn fault_summary_survives_saturated_counters() {
        // u64::MAX everywhere must format, not overflow or panic
        let m = crate::metrics::Metrics {
            link_flaps: u64::MAX,
            link_recoveries: u64::MAX,
            switch_failures: u64::MAX,
            switch_recoveries: u64::MAX,
            straggler_slowdowns: u64::MAX,
            drops_link_down: u64::MAX,
            drops_injected: u64::MAX,
            partial_aggregates: u64::MAX,
            jobs_completed: u64::MAX,
            jobs_stalled: u64::MAX,
            ..Default::default()
        };
        let line = fault_summary(&m);
        assert!(line.contains(&u64::MAX.to_string()), "{line}");
    }

    #[test]
    fn flow_summary_reads_sanely() {
        let mut f = crate::metrics::FlowStats::default();
        f.on_start(1, 0, 1, 100);
        f.on_delivery(1, 2_000_000, 100);
        let line = flow_summary(&f);
        assert!(line.contains("1 started"), "{line}");
        assert!(line.contains("(100.0%)"), "{line}");
        assert!(line.contains("p50 2.0 us"), "{line}");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut s = Series::new("x", &["a", "b"]);
        s.push(vec!["1".into()]);
    }

    #[test]
    fn critical_path_breakdown_percentages_tile() {
        let p = crate::trace::BlockPath {
            tenant: 0,
            block: 7,
            t_start: 0,
            t_end: 1_000_000,
            queue_ps: 100_000,
            ser_ps: 200_000,
            prop_ps: 200_000,
            agg_wait_ps: 250_000,
            timeout_penalty_ps: 250_000,
            n_hops: 3,
            n_waits: 2,
            steps: vec![],
        };
        let s = critical_path_breakdown(&[p]);
        assert_eq!(s.rows.len(), 1);
        let row = &s.rows[0];
        assert_eq!(row[0], "0");
        assert_eq!(row[1], "7");
        assert_eq!(row[2], "1.000"); // 1 µs
        assert_eq!(row[3], "10.0");
        assert_eq!(row[4], "20.0");
        assert_eq!(row[5], "20.0");
        assert_eq!(row[6], "25.0");
        assert_eq!(row[7], "25.0");
        let total: f64 = (3..8).map(|i| row[i].parse::<f64>().unwrap()).sum();
        assert!((total - 100.0).abs() < 1e-9, "{total}");
    }
}
