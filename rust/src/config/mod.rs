//! Typed simulation / experiment configuration.
//!
//! Defaults reproduce the paper's Section 5.2 setup: 100 Gbps links,
//! ~300 ns per hop, 1 µs Canary timeout, 32 Ki descriptor slots (the
//! Tofino prototype allocated 32 K descriptors), and MTU-bounded packets
//! with 256 4-byte payload elements.

use crate::sim::{Time, MS, NS, PS_PER_BYTE_100G, US};

/// Physical + protocol constants for one simulation run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Serialization cost (80 ps/byte = 100 Gbps).
    pub link_ps_per_byte: u64,
    /// Propagation + switch pipeline latency per hop.
    pub link_latency_ps: Time,
    /// Logical per-port queue capacity (adaptive threshold reference;
    /// droppable traffic overflowing it is discarded).
    pub port_queue_capacity: u64,
    /// Reduction-packet payload bytes. 1024 (256 x 4 B elements) in the
    /// scale simulations (Section 5.1's extrapolated packet), 128 on the
    /// Tofino prototype (Fig. 6).
    pub payload_bytes: u32,
    /// Canary descriptor timeout (Section 3.1.1).
    pub canary_timeout_ps: Time,
    /// Canary descriptor table slots per switch (Section 5.1: 32 K).
    pub descriptor_slots: u32,
    /// Per-host in-flight block cap; 0 = open-loop line-rate streaming
    /// (the paper's calibrated setup — in-flight blocks are then bounded
    /// by the bandwidth-delay product, Section 3.2.2).
    pub host_window: u32,
    /// Arm per-block loss-recovery timers. Off by default (pure timing
    /// runs on a lossless fabric); fault-tolerance experiments turn it
    /// on together with a FaultPlan.
    pub arm_retrans_timers: bool,
    /// Host retransmission timeout (Section 3.3: ~2 RTT).
    pub retrans_timeout_ps: Time,
    /// In-network retries before falling back to host-based reduction.
    pub max_retries: u32,
    /// Carry and aggregate real int32 lanes (correctness mode) instead of
    /// modelling sizes only (perf mode).
    pub carry_values: bool,
    /// Probability that a host delays a send by `noise_delay_ps`
    /// (Section 5.2.5 noise experiment).
    pub noise_prob: f64,
    pub noise_delay_ps: Time,
    /// Background-traffic message/flow size for the fixed-size traffic
    /// patterns (one destination draw per message; the `empirical`
    /// pattern samples sizes from its bundled CDF instead —
    /// `crate::traffic`).
    pub bg_message_bytes: u64,
    /// ECN CE marking on class-1 (background) queues. Off by default;
    /// the scenario builder turns it on when the cross traffic runs a
    /// reactive transport (`crate::transport`). With it off the mark
    /// path is one branch and zero RNG draws, so legacy runs stay
    /// bit-identical.
    pub ecn_enabled: bool,
    /// RED-style marking ramp: no CE below `kmin` bytes of
    /// instantaneous class-1 backlog, always CE above `kmax`, linear
    /// probability in between. `kmin == kmax` gives the deterministic
    /// DCTCP-style step threshold.
    pub ecn_kmin_bytes: u64,
    pub ecn_kmax_bytes: u64,
    /// Background-flow retransmission timeout (reactive transport loss
    /// recovery; doubled per retry round up to 16x).
    pub transport_rto_ps: Time,
    /// Run the end-of-segment conservation audit (`sim::invariants`)
    /// even in release builds (`--paranoid` on the CLI). Debug builds
    /// always audit. The audit is read-only, so this cannot change a
    /// run's fingerprint — only whether accounting bugs abort it.
    pub paranoid: bool,
    /// Space-parallel shard count for the bounded-window PDES engine
    /// (`sim/shard.rs`). `0` (default) selects the serial engine;
    /// `--shards 1` runs the sharded machinery with one worker and is
    /// pinned bit-identical to serial; `N > 1` partitions the fabric by
    /// pod/leaf group across `N` worker threads (deterministic for any
    /// fixed `N` — and fingerprint-identical to serial, see
    /// DESIGN.md §2.10).
    pub shards: u32,
    /// Master seed; every stochastic choice derives from it.
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            link_ps_per_byte: PS_PER_BYTE_100G,
            link_latency_ps: 300 * NS,
            port_queue_capacity: 131072,
            payload_bytes: 1024,
            canary_timeout_ps: US,
            descriptor_slots: 32 * 1024,
            host_window: 0,
            arm_retrans_timers: false,
            // Loss-recovery timer. The paper sets ~2 RTT, where RTT is
            // what a host *observes* (including aggregation timeouts and
            // queueing). A fixed default must exceed any clean completion
            // gap or spurious failure rounds melt the operation down;
            // fault-tolerance experiments override this downward.
            retrans_timeout_ps: 20 * MS,
            max_retries: 3,
            carry_values: false,
            noise_prob: 0.0,
            noise_delay_ps: US,
            bg_message_bytes: 64 * 1024,
            ecn_enabled: false,
            // 1/8 and 1/2 of the port capacity: the ramp saturates well
            // before the class-1 policer starts dropping, so reactive
            // senders see CE before they see loss.
            ecn_kmin_bytes: 16 * 1024,
            ecn_kmax_bytes: 64 * 1024,
            // Generous relative to worst-case queueing (~10.5 us to
            // drain a full port at 100G): RTOs should mean loss, not
            // patience. Spurious retransmits are deduplicated at the
            // sink either way.
            transport_rto_ps: 200 * US,
            paranoid: false,
            shards: 0,
            seed: 0xCA11A8,
        }
    }
}

impl SimConfig {
    /// Round-trip estimate host->spine->host for timer defaults.
    pub fn rtt_estimate(&self) -> Time {
        // 4 hops each way + serialization of one MTU packet per hop
        let per_hop = self.link_latency_ps
            + crate::sim::packet::WIRE_BYTES as u64 * self.link_ps_per_byte;
        8 * per_hop
    }

    /// Builder-style helpers used throughout the experiments.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_timeout(mut self, t: Time) -> Self {
        self.canary_timeout_ps = t;
        self
    }

    pub fn with_values(mut self, on: bool) -> Self {
        self.carry_values = on;
        self
    }

    pub fn with_paranoid(mut self, on: bool) -> Self {
        self.paranoid = on;
        self
    }

    /// Select the space-parallel engine with `n` shards (0 = serial).
    pub fn with_shards(mut self, n: u32) -> Self {
        self.shards = n;
        self
    }

    pub fn with_noise(mut self, prob: f64, delay: Time) -> Self {
        self.noise_prob = prob;
        self.noise_delay_ps = delay;
        self
    }

    pub fn with_slots(mut self, slots: u32) -> Self {
        self.descriptor_slots = slots;
        self
    }

    pub fn with_window(mut self, w: u32) -> Self {
        self.host_window = w;
        self
    }

    pub fn with_retrans(mut self, timeout: Time, arm: bool) -> Self {
        self.retrans_timeout_ps = timeout;
        self.arm_retrans_timers = arm;
        self
    }

    pub fn with_payload(mut self, bytes: u32) -> Self {
        self.payload_bytes = bytes;
        self
    }

    /// Message/flow size for the fixed-size background-traffic patterns.
    pub fn with_bg_bytes(mut self, bytes: u64) -> Self {
        self.bg_message_bytes = bytes;
        self
    }

    /// Enable class-1 ECN marking with the given RED ramp (bytes).
    pub fn with_ecn(mut self, kmin: u64, kmax: u64) -> Self {
        assert!(kmin <= kmax, "ECN kmin must not exceed kmax");
        self.ecn_enabled = true;
        self.ecn_kmin_bytes = kmin;
        self.ecn_kmax_bytes = kmax;
        self
    }

    /// Background-flow retransmission timeout (reactive transport).
    pub fn with_transport_rto(mut self, rto: Time) -> Self {
        self.transport_rto_ps = rto;
        self
    }

    /// Full wire size of a reduction data packet under this config.
    pub fn wire_bytes(&self) -> u32 {
        self.payload_bytes + crate::sim::packet::HEADER_OVERHEAD_BYTES
    }

    /// Payload lanes (4-byte elements) per packet.
    pub fn lanes(&self) -> usize {
        (self.payload_bytes / 4) as usize
    }
}

/// Maximum number of switch tiers a [`ClosConfig`] can describe.
pub const MAX_TIERS: usize = 4;

/// Multi-tier folded-Clos topology shape (an XGFT in the Öhring et al.
/// parametrization, specialized to one uplink per host).
///
/// Tier `t` (1-based, `1..=tiers`) is described by two radixes:
///
/// - `down[t-1]` — children per tier-`t` switch (`down[0]` = hosts per
///   leaf/ToR).
/// - `up[t-1]` — tier-`t` parents of each tier-`t-1` node (`up[0]` = 1,
///   one NIC uplink per host).
///
/// The oversubscription ratio at tier `t < tiers` is
/// `down[t-1] : up[t]` (downlinks vs uplinks of a tier-`t` switch).
///
/// The paper's Section 5.2 network is the 2-tier
/// [`ClosConfig::paper()`]: 1024 hosts, 32 leaves x 32 hosts, 32
/// spines, non-blocking. [`ClosConfig::paper3()`] scales the same host
/// count onto a 3-tier pod fabric with a 2:1 oversubscription at both
/// lower tiers — the regime where congestion awareness matters most.
///
/// `FatTreeConfig` remains as an alias for the 2-tier call sites.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClosConfig {
    /// Number of switch tiers (2 = leaf/spine, 3 = ToR/agg/core).
    pub tiers: u8,
    /// `down[t-1]`: children per tier-`t` switch.
    pub down: [u32; MAX_TIERS],
    /// `up[t-1]`: tier-`t` parents per tier-`t-1` node; `up[0] == 1`.
    pub up: [u32; MAX_TIERS],
}

/// Backwards-compatible name for the 2-tier call sites.
pub type FatTreeConfig = ClosConfig;

impl ClosConfig {
    /// Arbitrary-tier constructor. `down` and `up` must both have
    /// `tiers` entries; see the field docs for their meaning.
    pub fn custom(down: &[u32], up: &[u32]) -> Self {
        assert_eq!(down.len(), up.len(), "down/up arity mismatch");
        assert!(
            (2..=MAX_TIERS).contains(&down.len()),
            "tiers must be in 2..={MAX_TIERS}"
        );
        let mut cfg = ClosConfig {
            tiers: down.len() as u8,
            down: [0; MAX_TIERS],
            up: [0; MAX_TIERS],
        };
        cfg.down[..down.len()].copy_from_slice(down);
        cfg.up[..up.len()].copy_from_slice(up);
        cfg
    }

    /// Classic 2-tier leaf/spine fabric.
    pub fn two_tier(n_leaf: u32, hosts_per_leaf: u32, n_spine: u32) -> Self {
        ClosConfig::custom(&[hosts_per_leaf, n_leaf], &[1, n_spine])
    }

    /// 3-tier pod fabric: `n_pods` pods of `tors_per_pod` ToRs (each
    /// with `hosts_per_tor` hosts and `aggs_per_pod` uplinks); each
    /// aggregation switch has `cores_per_group` core uplinks, so the
    /// core layer has `aggs_per_pod * cores_per_group` switches.
    pub fn three_tier(
        hosts_per_tor: u32,
        tors_per_pod: u32,
        n_pods: u32,
        aggs_per_pod: u32,
        cores_per_group: u32,
    ) -> Self {
        ClosConfig::custom(
            &[hosts_per_tor, tors_per_pod, n_pods],
            &[1, aggs_per_pod, cores_per_group],
        )
    }

    /// The paper's Section 5.2 network: 1024 hosts, 32x32 leaves,
    /// 32 spines (non-blocking).
    pub fn paper() -> Self {
        ClosConfig::two_tier(32, 32, 32)
    }

    /// Small 2-tier instance for unit tests (64 hosts).
    pub fn small() -> Self {
        ClosConfig::two_tier(4, 16, 4)
    }

    /// Tiny 2-tier instance for exhaustive tests (8 hosts).
    pub fn tiny() -> Self {
        ClosConfig::two_tier(2, 4, 2)
    }

    /// 1024 hosts on a 3-tier pod fabric, 2:1 oversubscribed at the ToR
    /// and aggregation tiers (the beyond-paper scale-up experiment).
    pub fn paper3() -> Self {
        // 8 pods x 8 ToRs x 16 hosts; 8 aggs/pod, 32 cores.
        ClosConfig::three_tier(16, 8, 8, 8, 4)
    }

    /// 64-host 3-tier instance for CI-scale runs (2:1 oversubscribed).
    pub fn small3() -> Self {
        // 4 pods x 4 ToRs x 4 hosts; 2 aggs/pod, 4 cores.
        ClosConfig::three_tier(4, 4, 4, 2, 2)
    }

    /// 8-host 3-tier instance for exhaustive tests.
    pub fn tiny3() -> Self {
        ClosConfig::three_tier(2, 2, 2, 2, 2)
    }

    /// 4096 hosts on a 3-tier pod fabric (16 pods x 16 ToRs x 16
    /// hosts, 2:1 oversubscribed at both lower tiers) — the largest
    /// rung of the `figures scale` weak-scaling sweep. 4x the paper's
    /// host count; a 2-tier shape cannot reach it inside the 64-port
    /// radix bound, which is itself the paper's scaling argument for
    /// multi-tier fabrics.
    pub fn huge3() -> Self {
        ClosConfig::three_tier(16, 16, 16, 8, 8)
    }

    /// 32768 hosts on a 3-tier pod fabric (32 pods x 32 ToRs x 32
    /// hosts; 2:1 oversubscribed at the ToR tier, 4:1 at aggregation)
    /// — the first sharded-engine rung of `figures scale`, an order of
    /// magnitude past `huge3`.
    pub fn giant3() -> Self {
        ClosConfig::three_tier(32, 32, 32, 16, 8)
    }

    /// 131072 hosts on a 4-tier fabric (the 128k rung; serial runs at
    /// this scale are impractical — it exists for the sharded engine).
    pub fn colossal4() -> Self {
        ClosConfig::custom(&[16, 16, 16, 32], &[1, 8, 8, 8])
    }

    /// Rescale the uplink radixes so every switch tier below the top is
    /// `num:den` oversubscribed (downlinks : uplinks). `1:1` is
    /// non-blocking; `4:1` is a heavily tapered fabric. When the ratio
    /// does not divide a tier's down radix exactly, the uplink count is
    /// floored (nearest achievable taper); the CLI rejects inexact
    /// ratios so reported and built shapes never silently diverge.
    pub fn with_oversub(mut self, num: u32, den: u32) -> Self {
        assert!(num > 0 && den > 0, "oversub ratio terms must be > 0");
        for t in 1..self.tiers as usize {
            self.up[t] = (self.down[t - 1] * den / num).max(1);
        }
        self
    }

    pub fn n_hosts(&self) -> u32 {
        self.down[..self.tiers as usize].iter().product()
    }

    /// Switches at tier `t` (1-based): one per (top, bottom) label pair,
    /// `prod(down[t..]) * prod(up[..t])` in the XGFT counting.
    pub fn tier_size(&self, t: u8) -> u32 {
        debug_assert!((1..=self.tiers).contains(&t));
        let tops: u64 = self.down[t as usize..self.tiers as usize]
            .iter()
            .map(|&m| m as u64)
            .product();
        let bots: u64 = self.up[..t as usize]
            .iter()
            .map(|&w| w as u64)
            .product();
        (tops * bots) as u32
    }

    pub fn n_switches(&self) -> u32 {
        (1..=self.tiers).map(|t| self.tier_size(t)).sum()
    }

    // -- 2-tier-era accessors (still meaningful on deeper fabrics:
    //    "leaf" = tier 1, "spine" = the top tier) --------------------

    /// Hosts attached to one leaf/ToR switch.
    pub fn hosts_per_leaf(&self) -> u32 {
        self.down[0]
    }

    /// Number of tier-1 (leaf/ToR) switches.
    pub fn n_leaf(&self) -> u32 {
        self.tier_size(1)
    }

    /// Number of top-tier (spine/core) switches.
    pub fn n_spine(&self) -> u32 {
        self.tier_size(self.tiers)
    }

    /// Sanity-check the shape: tier count, radix bounds (the switch
    /// children bitmaps are `u64`, so total port radix must stay <= 64),
    /// and the host-uplink convention.
    pub fn validate(&self) -> Result<(), String> {
        if !(2..=MAX_TIERS as u8).contains(&self.tiers) {
            return Err(format!(
                "tiers must be in 2..={MAX_TIERS}, got {}",
                self.tiers
            ));
        }
        if self.up[0] != 1 {
            return Err(format!(
                "up[0] (host uplinks) must be 1, got {}",
                self.up[0]
            ));
        }
        for t in 1..=self.tiers as usize {
            let m = self.down[t - 1];
            let w = if t < self.tiers as usize { self.up[t] } else { 0 };
            if m == 0 {
                return Err(format!("down[{}] must be >= 1", t - 1));
            }
            if t < self.tiers as usize && w == 0 {
                return Err(format!("up[{t}] must be >= 1"));
            }
            if m + w > 64 {
                return Err(format!(
                    "tier-{t} switch radix {} exceeds 64 ports \
                     (children bitmaps are u64)",
                    m + w
                ));
            }
        }
        let hosts: u64 = self.down[..self.tiers as usize]
            .iter()
            .map(|&m| m as u64)
            .product();
        let switches: u64 =
            (1..=self.tiers).map(|t| self.tier_size(t) as u64).sum();
        if hosts == 0 || hosts + switches > (1 << 26) {
            return Err(format!(
                "degenerate node count: {hosts} hosts + {switches} switches"
            ));
        }
        Ok(())
    }

    /// Parse a topology from its JSON description, e.g.
    /// `{"tiers": 3, "down": [16, 8, 8], "up": [1, 8, 4]}`.
    pub fn from_json(text: &str) -> Result<ClosConfig, String> {
        let v = crate::util::json::parse(text)?;
        let tiers = v
            .get("tiers")
            .and_then(|t| t.as_i64())
            .ok_or("missing integer key 'tiers'")? as usize;
        if !(2..=MAX_TIERS).contains(&tiers) {
            return Err(format!("tiers must be in 2..={MAX_TIERS}"));
        }
        let arr = |key: &str| -> Result<Vec<u32>, String> {
            let xs = v
                .get(key)
                .and_then(|a| a.int_vec())
                .ok_or_else(|| format!("missing int array '{key}'"))?;
            if xs.len() != tiers {
                return Err(format!("{key} must have {tiers} entries"));
            }
            xs.into_iter()
                .map(|i| {
                    u32::try_from(i).map_err(|_| {
                        format!("{key} entry {i} out of range")
                    })
                })
                .collect()
        };
        let cfg = ClosConfig::custom(&arr("down")?, &arr("up")?);
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Parse an `A:B` oversubscription ratio (e.g. `2:1`).
pub fn parse_oversub(s: &str) -> Result<(u32, u32), String> {
    let (a, b) = s
        .split_once(':')
        .ok_or_else(|| format!("bad oversub '{s}' (expected A:B)"))?;
    let parse = |x: &str| {
        x.parse::<u32>()
            .ok()
            .filter(|&v| v > 0)
            .ok_or_else(|| format!("bad oversub term '{x}'"))
    };
    Ok((parse(a)?, parse(b)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_setup() {
        let c = SimConfig::default();
        assert_eq!(c.link_ps_per_byte, 80); // 100 Gbps
        assert_eq!(c.link_latency_ps, 300_000); // 300 ns
        assert_eq!(c.canary_timeout_ps, 1_000_000); // 1 us
        assert_eq!(c.descriptor_slots, 32768);
        let t = FatTreeConfig::paper();
        assert_eq!(t.n_hosts(), 1024);
        assert_eq!(t.n_switches(), 64);
    }

    #[test]
    fn builders_compose() {
        let c = SimConfig::default()
            .with_seed(7)
            .with_timeout(3 * US)
            .with_values(true)
            .with_noise(0.1, US);
        assert_eq!(c.seed, 7);
        assert_eq!(c.canary_timeout_ps, 3 * US);
        assert!(c.carry_values);
        assert_eq!(c.noise_prob, 0.1);
    }

    #[test]
    fn rtt_estimate_is_sane() {
        let c = SimConfig::default();
        // ~8 hops of ~386 ns each => a few microseconds
        assert!(c.rtt_estimate() > 2 * US && c.rtt_estimate() < 10 * US);
    }

    #[test]
    fn three_tier_counts() {
        let t = ClosConfig::paper3();
        assert_eq!(t.tiers, 3);
        assert_eq!(t.n_hosts(), 1024);
        assert_eq!(t.n_leaf(), 64); // 8 pods x 8 ToRs
        assert_eq!(t.tier_size(2), 64); // 8 pods x 8 aggs
        assert_eq!(t.n_spine(), 32); // 8 x 4 cores
        assert_eq!(t.n_switches(), 160);
        assert!(t.validate().is_ok());
        // 2:1 oversubscription at both lower tiers
        assert_eq!(t.down[0], 2 * t.up[1]);
        assert_eq!(t.down[1], 2 * t.up[2]);
    }

    #[test]
    fn huge3_counts() {
        let t = ClosConfig::huge3();
        assert_eq!(t.n_hosts(), 4096);
        assert!(t.validate().is_ok());
        // 2:1 oversubscription at ToR and aggregation tiers
        assert_eq!(t.down[0], 2 * t.up[1]);
        assert_eq!(t.down[1], 2 * t.up[2]);
        assert!(t.n_spine() >= 4, "static4 needs 4 distinct roots");
    }

    #[test]
    fn giant3_and_colossal4_counts() {
        let t = ClosConfig::giant3();
        assert_eq!(t.n_hosts(), 32_768);
        assert!(t.validate().is_ok());
        assert_eq!(t.n_leaf(), 1024); // 32 pods x 32 ToRs
        assert_eq!(t.down[0], 2 * t.up[1]); // 2:1 at the ToR tier
        let c = ClosConfig::colossal4();
        assert_eq!(c.tiers, 4);
        assert_eq!(c.n_hosts(), 131_072);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn shards_builder() {
        let c = SimConfig::default();
        assert_eq!(c.shards, 0, "serial engine is the default");
        assert_eq!(c.with_shards(4).shards, 4);
    }

    #[test]
    fn oversub_rescaling() {
        let t = ClosConfig::paper3().with_oversub(1, 1);
        assert_eq!(t.up[1], 16);
        assert_eq!(t.up[2], 8);
        assert!(t.validate().is_ok());
        let t = ClosConfig::paper3().with_oversub(4, 1);
        assert_eq!(t.up[1], 4);
        assert_eq!(t.up[2], 2);
        // the 2-tier paper network is non-blocking already
        assert_eq!(ClosConfig::paper().with_oversub(1, 1), ClosConfig::paper());
    }

    #[test]
    fn validation_rejects_fat_radix() {
        // 60 hosts + 16 uplinks on one ToR > 64 ports
        let bad = ClosConfig::custom(&[60, 4, 4], &[1, 16, 2]);
        assert!(bad.validate().is_err());
        assert!(ClosConfig::small3().validate().is_ok());
        assert!(ClosConfig::tiny3().validate().is_ok());
    }

    #[test]
    fn json_round_trip() {
        let t = ClosConfig::from_json(
            r#"{"tiers": 3, "down": [16, 8, 8], "up": [1, 8, 4]}"#,
        )
        .unwrap();
        assert_eq!(t, ClosConfig::paper3());
        assert!(ClosConfig::from_json(r#"{"tiers": 9}"#).is_err());
        assert!(ClosConfig::from_json(r#"{"down": [2, 2]}"#).is_err());
        // out-of-range radixes must error, not truncate
        assert!(ClosConfig::from_json(
            r#"{"tiers": 3, "down": [4294967297, 8, 8], "up": [1, 8, 4]}"#
        )
        .is_err());
    }

    #[test]
    fn oversub_parsing() {
        assert_eq!(parse_oversub("2:1").unwrap(), (2, 1));
        assert_eq!(parse_oversub("1:1").unwrap(), (1, 1));
        assert!(parse_oversub("2").is_err());
        assert!(parse_oversub("0:1").is_err());
    }
}
