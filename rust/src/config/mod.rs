//! Typed simulation / experiment configuration.
//!
//! Defaults reproduce the paper's Section 5.2 setup: 100 Gbps links,
//! ~300 ns per hop, 1 µs Canary timeout, 32 Ki descriptor slots (the
//! Tofino prototype allocated 32 K descriptors), and MTU-bounded packets
//! with 256 4-byte payload elements.

use crate::sim::{Time, MS, NS, PS_PER_BYTE_100G, US};

/// Physical + protocol constants for one simulation run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Serialization cost (80 ps/byte = 100 Gbps).
    pub link_ps_per_byte: u64,
    /// Propagation + switch pipeline latency per hop.
    pub link_latency_ps: Time,
    /// Logical per-port queue capacity (adaptive threshold reference;
    /// droppable traffic overflowing it is discarded).
    pub port_queue_capacity: u64,
    /// Reduction-packet payload bytes. 1024 (256 x 4 B elements) in the
    /// scale simulations (Section 5.1's extrapolated packet), 128 on the
    /// Tofino prototype (Fig. 6).
    pub payload_bytes: u32,
    /// Canary descriptor timeout (Section 3.1.1).
    pub canary_timeout_ps: Time,
    /// Canary descriptor table slots per switch (Section 5.1: 32 K).
    pub descriptor_slots: u32,
    /// Per-host in-flight block cap; 0 = open-loop line-rate streaming
    /// (the paper's calibrated setup — in-flight blocks are then bounded
    /// by the bandwidth-delay product, Section 3.2.2).
    pub host_window: u32,
    /// Arm per-block loss-recovery timers. Off by default (pure timing
    /// runs on a lossless fabric); fault-tolerance experiments turn it
    /// on together with a FaultPlan.
    pub arm_retrans_timers: bool,
    /// Host retransmission timeout (Section 3.3: ~2 RTT).
    pub retrans_timeout_ps: Time,
    /// In-network retries before falling back to host-based reduction.
    pub max_retries: u32,
    /// Carry and aggregate real int32 lanes (correctness mode) instead of
    /// modelling sizes only (perf mode).
    pub carry_values: bool,
    /// Probability that a host delays a send by `noise_delay_ps`
    /// (Section 5.2.5 noise experiment).
    pub noise_prob: f64,
    pub noise_delay_ps: Time,
    /// Background-traffic message size (one random destination per
    /// message).
    pub bg_message_bytes: u64,
    /// Master seed; every stochastic choice derives from it.
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            link_ps_per_byte: PS_PER_BYTE_100G,
            link_latency_ps: 300 * NS,
            port_queue_capacity: 131072,
            payload_bytes: 1024,
            canary_timeout_ps: US,
            descriptor_slots: 32 * 1024,
            host_window: 0,
            arm_retrans_timers: false,
            // Loss-recovery timer. The paper sets ~2 RTT, where RTT is
            // what a host *observes* (including aggregation timeouts and
            // queueing). A fixed default must exceed any clean completion
            // gap or spurious failure rounds melt the operation down;
            // fault-tolerance experiments override this downward.
            retrans_timeout_ps: 20 * MS,
            max_retries: 3,
            carry_values: false,
            noise_prob: 0.0,
            noise_delay_ps: US,
            bg_message_bytes: 64 * 1024,
            seed: 0xCA11A8,
        }
    }
}

impl SimConfig {
    /// Round-trip estimate host->spine->host for timer defaults.
    pub fn rtt_estimate(&self) -> Time {
        // 4 hops each way + serialization of one MTU packet per hop
        let per_hop = self.link_latency_ps
            + crate::sim::packet::WIRE_BYTES as u64 * self.link_ps_per_byte;
        8 * per_hop
    }

    /// Builder-style helpers used throughout the experiments.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_timeout(mut self, t: Time) -> Self {
        self.canary_timeout_ps = t;
        self
    }

    pub fn with_values(mut self, on: bool) -> Self {
        self.carry_values = on;
        self
    }

    pub fn with_noise(mut self, prob: f64, delay: Time) -> Self {
        self.noise_prob = prob;
        self.noise_delay_ps = delay;
        self
    }

    pub fn with_slots(mut self, slots: u32) -> Self {
        self.descriptor_slots = slots;
        self
    }

    pub fn with_window(mut self, w: u32) -> Self {
        self.host_window = w;
        self
    }

    pub fn with_retrans(mut self, timeout: Time, arm: bool) -> Self {
        self.retrans_timeout_ps = timeout;
        self.arm_retrans_timers = arm;
        self
    }

    pub fn with_payload(mut self, bytes: u32) -> Self {
        self.payload_bytes = bytes;
        self
    }

    /// Full wire size of a reduction data packet under this config.
    pub fn wire_bytes(&self) -> u32 {
        self.payload_bytes + crate::sim::packet::HEADER_OVERHEAD_BYTES
    }

    /// Payload lanes (4-byte elements) per packet.
    pub fn lanes(&self) -> usize {
        (self.payload_bytes / 4) as usize
    }
}

/// Topology shape. The paper's scale setup is `FatTreeConfig::paper()`:
/// 1024 hosts, 32 leaves x 32 hosts, 32 spines.
#[derive(Clone, Copy, Debug)]
pub struct FatTreeConfig {
    pub n_leaf: u32,
    pub hosts_per_leaf: u32,
    pub n_spine: u32,
}

impl FatTreeConfig {
    pub fn paper() -> Self {
        FatTreeConfig {
            n_leaf: 32,
            hosts_per_leaf: 32,
            n_spine: 32,
        }
    }

    /// Small instance for unit tests (64 hosts).
    pub fn small() -> Self {
        FatTreeConfig {
            n_leaf: 4,
            hosts_per_leaf: 16,
            n_spine: 4,
        }
    }

    /// Tiny instance for exhaustive tests (8 hosts).
    pub fn tiny() -> Self {
        FatTreeConfig {
            n_leaf: 2,
            hosts_per_leaf: 4,
            n_spine: 2,
        }
    }

    pub fn n_hosts(&self) -> u32 {
        self.n_leaf * self.hosts_per_leaf
    }

    pub fn n_switches(&self) -> u32 {
        self.n_leaf + self.n_spine
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_setup() {
        let c = SimConfig::default();
        assert_eq!(c.link_ps_per_byte, 80); // 100 Gbps
        assert_eq!(c.link_latency_ps, 300_000); // 300 ns
        assert_eq!(c.canary_timeout_ps, 1_000_000); // 1 us
        assert_eq!(c.descriptor_slots, 32768);
        let t = FatTreeConfig::paper();
        assert_eq!(t.n_hosts(), 1024);
        assert_eq!(t.n_switches(), 64);
    }

    #[test]
    fn builders_compose() {
        let c = SimConfig::default()
            .with_seed(7)
            .with_timeout(3 * US)
            .with_values(true)
            .with_noise(0.1, US);
        assert_eq!(c.seed, 7);
        assert_eq!(c.canary_timeout_ps, 3 * US);
        assert!(c.carry_values);
        assert_eq!(c.noise_prob, 0.1);
    }

    #[test]
    fn rtt_estimate_is_sane() {
        let c = SimConfig::default();
        // ~8 hops of ~386 ns each => a few microseconds
        assert!(c.rtt_estimate() > 2 * US && c.rtt_estimate() < 10 * US);
    }
}
