//! The switch ALU: saturating fixed-point arithmetic, mirroring the L1
//! Pallas kernel (`python/compile/kernels/aggregate.py`) **bit for bit**.
//!
//! The Rust dataplane uses these native functions on the simulator hot
//! path; `rust/tests/pjrt_parity.rs` proves they agree with the
//! Pallas-lowered HLO executed through PJRT, and unit tests here check
//! them against the golden vectors baked into `artifacts/manifest.json`.

/// Largest f32 that converts to i32 without saturation surprises on
/// either side of the bridge (see `kernels/quantize.py`).
pub const Q_CLIP_F32: f32 = 2_147_483_520.0;

/// Element-wise saturating i32 accumulate: `acc[i] += x[i]` (saturating).
#[inline]
pub fn sat_accumulate(acc: &mut [i32], x: &[i32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, &b) in acc.iter_mut().zip(x.iter()) {
        *a = a.saturating_add(b);
    }
}

/// Fold an owned packet payload into an optional accumulator: the
/// first value-carrying contribution *moves* its lanes in (reusing the
/// box the packet arrived with — no copy on the arena-threaded hot
/// path), later ones saturating-accumulate. `Payload::None` (size-only
/// mode) is a no-op. Shared by every aggregation point (Canary
/// descriptors, static-tree partials, the leader fold).
pub fn fold_payload(
    acc: &mut Option<Vec<i32>>,
    payload: crate::sim::packet::Payload,
) {
    if let crate::sim::packet::Payload::Lanes(v) = payload {
        match acc {
            Some(a) => sat_accumulate(a, &v),
            None => *acc = Some(v.into_vec()),
        }
    }
}

/// Saturating fold of packet payload rows (the oracle shape used by the
/// Python `ref.aggregate_ref`).
pub fn aggregate_rows(rows: &[&[i32]], lanes: usize) -> Vec<i32> {
    let mut acc = vec![0i32; lanes];
    for row in rows {
        sat_accumulate(&mut acc, row);
    }
    acc
}

/// Host-side fixed-point quantization: `round(x * 2^frac_bits)` clamped,
/// bit-identical to the Pallas quantize kernel.
#[inline]
pub fn quantize(x: f32, frac_bits: u32) -> i32 {
    let scaled = x * (2.0f32).powi(frac_bits as i32);
    let clipped = scaled.clamp(-Q_CLIP_F32, Q_CLIP_F32);
    // f32::round is round-half-away-from-zero, matching the kernel
    clipped.round() as i32
}

/// Inverse of [`quantize`].
#[inline]
pub fn dequantize(q: i32, frac_bits: u32) -> f32 {
    q as f32 * (2.0f32).powi(-(frac_bits as i32))
}

/// Vector helpers used by the trainer.
pub fn quantize_vec(xs: &[f32], frac_bits: u32) -> Vec<i32> {
    xs.iter().map(|&x| quantize(x, frac_bits)).collect()
}

pub fn dequantize_vec(qs: &[i32], frac_bits: u32) -> Vec<f32> {
    qs.iter().map(|&q| dequantize(q, frac_bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturation_edges() {
        let mut acc = vec![i32::MAX - 1, i32::MIN + 1, 0];
        sat_accumulate(&mut acc, &[5, -5, 7]);
        assert_eq!(acc, vec![i32::MAX, i32::MIN, 7]);
    }

    #[test]
    fn fold_payload_moves_then_accumulates() {
        use crate::sim::packet::Payload;
        let mut acc = None;
        fold_payload(&mut acc, Payload::None);
        assert!(acc.is_none(), "size-only packets fold to nothing");
        fold_payload(&mut acc, Payload::Lanes(vec![1, 2].into()));
        assert_eq!(acc.as_deref(), Some(&[1, 2][..]));
        fold_payload(&mut acc, Payload::Lanes(vec![10, i32::MAX].into()));
        assert_eq!(acc.as_deref(), Some(&[11, i32::MAX][..]));
        fold_payload(&mut acc, Payload::None);
        assert_eq!(acc.as_deref(), Some(&[11, i32::MAX][..]));
    }

    #[test]
    fn aggregate_rows_matches_sequential() {
        let r1 = [1, 2, 3];
        let r2 = [10, 20, 30];
        let out = aggregate_rows(&[&r1, &r2], 3);
        assert_eq!(out, vec![11, 22, 33]);
    }

    #[test]
    fn quantize_roundtrip_bound() {
        for i in -1000..1000 {
            let x = i as f32 * 0.001;
            let dq = dequantize(quantize(x, 20), 20);
            assert!((dq - x).abs() <= 0.5 * 2.0f32.powi(-20) + 1e-9);
        }
    }

    #[test]
    fn quantize_clips() {
        assert_eq!(quantize(1e30, 0), 2_147_483_520);
        assert_eq!(quantize(-1e30, 0), -2_147_483_520);
    }

    /// Golden-vector parity with the Python oracle (and hence the Pallas
    /// kernel), read from artifacts/manifest.json when it exists.
    #[test]
    fn golden_parity_with_python() {
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/manifest.json"
        );
        let Ok(text) = std::fs::read_to_string(path) else {
            eprintln!("skipping golden parity: run `make artifacts` first");
            return;
        };
        let man = crate::util::json::parse(&text).unwrap();
        let g = man.expect("golden");
        let frac = g.expect("frac_bits").as_i64().unwrap() as u32;

        let agg = g.expect("aggregate");
        let n = agg.expect("n").as_i64().unwrap() as usize;
        let lanes = agg.expect("lanes").as_i64().unwrap() as usize;
        let flat: Vec<i32> = agg
            .expect("payloads")
            .int_vec()
            .unwrap()
            .into_iter()
            .map(|v| v as i32)
            .collect();
        let rows: Vec<&[i32]> =
            (0..n).map(|i| &flat[i * lanes..(i + 1) * lanes]).collect();
        let expected: Vec<i32> = agg
            .expect("expected")
            .int_vec()
            .unwrap()
            .into_iter()
            .map(|v| v as i32)
            .collect();
        assert_eq!(aggregate_rows(&rows, lanes), expected);

        let q = g.expect("quantize");
        let xs: Vec<f32> = q
            .expect("x_bits")
            .int_vec()
            .unwrap()
            .into_iter()
            .map(|b| f32::from_bits(b as u32))
            .collect();
        let expected_q: Vec<i32> = q
            .expect("expected_q")
            .int_vec()
            .unwrap()
            .into_iter()
            .map(|v| v as i32)
            .collect();
        assert_eq!(quantize_vec(&xs, frac), expected_q);

        let expected_dq: Vec<f32> = q
            .expect("expected_dq_bits")
            .int_vec()
            .unwrap()
            .into_iter()
            .map(|b| f32::from_bits(b as u32))
            .collect();
        let dq = dequantize_vec(&expected_q, frac);
        assert_eq!(dq.len(), expected_dq.len());
        for (a, b) in dq.iter().zip(expected_dq.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "dequantize bit parity");
        }
    }
}
