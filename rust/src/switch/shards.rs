//! Multicast-group shard encoding (paper Section 4.2).
//!
//! Programmable switches need multicast groups pre-configured, but Canary
//! multicasts to dynamic port sets. Storing a group per possible bitmap is
//! 2^p entries; the paper instead splits the children bitmap into `s`
//! shards of `p/s` bits, prepends the shard index, and pre-configures
//! `s * 2^(p/s)` groups. A p-port multicast then issues `s` shard lookups.
//!
//! The simulator's fan-out uses the bitmap directly (a switch can do
//! that); this module exists to model and test the resource math and is
//! used by the memory-occupancy bench (`figures mem`).

/// Split a `ports`-bit children bitmap into `shards` shard keys.
/// Each key is `(shard_index << shard_width) | shard_bits`.
pub fn encode(bitmap: u64, ports: u32, shards: u32) -> Vec<u64> {
    assert!(ports <= 64 && shards > 0 && ports % shards == 0);
    let width = ports / shards;
    let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
    (0..shards)
        .map(|i| {
            let bits = (bitmap >> (i * width)) & mask;
            ((i as u64) << width) | bits
        })
        .collect()
}

/// Rebuild the port list from the shard keys (what the pre-configured
/// multicast tables resolve to).
pub fn decode(keys: &[u64], ports: u32, shards: u32) -> Vec<u16> {
    assert!(ports % shards == 0);
    let width = ports / shards;
    let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
    let mut out = Vec::new();
    for &key in keys {
        let idx = (key >> width) as u32;
        let bits = key & mask;
        for b in 0..width {
            if bits & (1u64 << b) != 0 {
                out.push((idx * width + b) as u16);
            }
        }
    }
    out.sort_unstable();
    out
}

/// Number of multicast-group table entries required (paper: `2^(p/s)*s`
/// vs `2^p` unsharded; 64 ports / 4 shards -> 256 Ki entries).
pub fn table_entries(ports: u32, shards: u32) -> u64 {
    assert!(ports % shards == 0);
    (1u64 << (ports / shards)) * shards as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn paper_example() {
        // 8 ports, 2 shards, bitmap 0b00101101 -> shards 1_0010 and 0_1101
        let keys = encode(0b0010_1101, 8, 2);
        assert_eq!(keys, vec![(0 << 4) | 0b1101, (1 << 4) | 0b0010]);
        assert_eq!(decode(&keys, 8, 2), vec![0, 2, 3, 5]);
    }

    #[test]
    fn paper_table_sizing() {
        // 64-port switch with 4 shards: 2^16 * 4 = 256 Ki entries
        assert_eq!(table_entries(64, 4), 262_144);
        // unsharded 64 ports would need 2^64 entries — the point
        assert_eq!(table_entries(8, 1), 256);
    }

    #[test]
    fn roundtrip_random_bitmaps() {
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let bitmap = rng.next_u64();
            let keys = encode(bitmap, 64, 4);
            let ports = decode(&keys, 64, 4);
            let rebuilt = ports
                .iter()
                .fold(0u64, |acc, &p| acc | (1u64 << p));
            assert_eq!(rebuilt, bitmap);
        }
    }
}
