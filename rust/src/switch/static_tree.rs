//! Static-tree in-network allreduce (the SHARP / SwitchML / ATP /
//! PANAMA-style baselines of Section 5.2).
//!
//! Trees are configured by a control plane before the job starts (we do
//! it instantaneously at job installation): each on-tree switch knows its
//! parent port, how many children contribute, and the ports to broadcast
//! down. Packets always follow the configured tree — that is exactly the
//! congestion weakness Canary removes.

use std::collections::HashMap;

use crate::sim::packet::{Packet, PacketKind, Payload};
use crate::sim::{Ctx, PacketId, Time};

use super::alu;
use super::SwitchState;

/// Where this switch sits in one configured tree. The same shape
/// covers every level of a multi-tier tree: leaf aggregators combine
/// host contributions, interior switches combine subtree partials, and
/// the root (`parent_port == None`) starts the broadcast.
#[derive(Clone, Debug)]
pub struct TreeRole {
    /// Fixed up-port toward the tree root; `None` at the root itself.
    pub parent_port: Option<u16>,
    /// Contributions to combine at this level before the partial moves
    /// up (or, at the root, before the broadcast starts).
    pub expected: u32,
    /// Down-ports of the reverse tree edges (hosts below a leaf,
    /// subtree heads elsewhere); the broadcast fans out on these.
    pub child_ports: Vec<u16>,
    /// `None` (allreduce/broadcast/barrier): every broadcast clone
    /// carries the value payload. `Some(p)` (reduce): only the clone
    /// on port `p` carries values — every other port gets a
    /// header-only release, so contributor windows still drain while
    /// the result reaches only the root host. `Some(u16::MAX)` marks
    /// a switch entirely off the root's path.
    pub value_port: Option<u16>,
}

/// Per-tenant static configuration: one role per tree index.
#[derive(Clone, Debug, Default)]
pub struct StaticJobInfo {
    pub trees: Vec<Option<TreeRole>>,
}

/// Per-switch static-tree state: configuration + in-flight aggregations.
#[derive(Debug, Default)]
pub struct StaticState {
    pub jobs: HashMap<u16, StaticJobInfo>,
    /// key = (tenant << 32) | block
    pub inflight: HashMap<u64, Agg>,
}

#[derive(Debug)]
pub struct Agg {
    pub count: u32,
    pub counter: u32,
    pub acc: Option<Vec<i32>>,
    /// When the slot was allocated (first contribution) — feeds the
    /// flight recorder's aggregation-wait split; never read otherwise.
    pub alloc_ps: Time,
}

impl StaticState {
    pub fn clear(&mut self) {
        self.inflight.clear();
    }
}

/// Reduce-phase packet at an on-tree switch.
pub fn on_reduce(sw: &mut SwitchState, ctx: &mut Ctx, pid: PacketId) {
    let Some(role) = role_of(sw, ctx.pkt(pid)) else {
        // not on this tree (e.g. transit spine for a bypassing packet):
        // plain-forward toward the root, zero-copy
        let port = super::route_id(sw, ctx, pid);
        ctx.forward(port, pid);
        return;
    };
    let mut pkt = ctx.take(pid);
    let TreeRole {
        parent_port,
        expected,
        child_ports,
        value_port,
    } = role;

    let key = pkt.block_key();
    let now = ctx.now;
    let agg = sw.static_tree.inflight.entry(key).or_insert_with(|| {
        ctx.metrics.on_descriptor_alloc();
        Agg {
            count: 0,
            counter: 0,
            acc: None,
            alloc_ps: now,
        }
    });
    agg.count += 1;
    agg.counter += pkt.counter;
    alu::fold_payload(
        &mut agg.acc,
        std::mem::replace(&mut pkt.payload, Payload::None),
    );
    if agg.count < expected {
        return; // swallow, keep waiting (static trees know their fan-in)
    }

    // complete at this level
    let agg = sw.static_tree.inflight.remove(&key).unwrap();
    ctx.metrics.on_descriptor_free(0);
    // flight recorder: slot residency is this block's aggregation wait
    // at this tree level (static trees never time out)
    ctx.tracer.wait(crate::trace::WaitRecord {
        tenant: pkt.tenant,
        block: pkt.block,
        node: sw.id,
        t_start: agg.alloc_ps,
        t_end: ctx.now,
        via_timeout: false,
    });
    match parent_port {
        Some(parent) => {
            // one partial up the fixed tree edge toward the root
            let mut up = pkt.clone();
            up.kind = PacketKind::StaticReduce;
            up.src = sw.id;
            up.counter = agg.counter;
            up.payload = match agg.acc {
                Some(acc) => Payload::Lanes(acc.into_boxed_slice()),
                None => Payload::None,
            };
            ctx.send(parent, up);
        }
        None => {
            // root: start the broadcast (reduce: values only toward
            // the root host, header-only releases elsewhere)
            for port in child_ports {
                let mut down = pkt.clone();
                down.kind = PacketKind::StaticBroadcast;
                down.src = sw.id;
                down.counter = agg.counter;
                down.payload = match &agg.acc {
                    Some(acc) => {
                        Payload::Lanes(acc.clone().into_boxed_slice())
                    }
                    None => Payload::None,
                };
                if value_port.is_some_and(|vp| vp != port) {
                    down.payload = Payload::None;
                    down.wire_bytes = 64;
                }
                ctx.send(port, down);
            }
        }
    }
}

/// Broadcast-phase packet at an on-tree switch: fan out down the
/// configured reverse edges (interior switches reach their subtree
/// heads, leaves reach their hosts). For a reduce, only the clone on
/// `value_port` keeps the payload; the rest shrink to releases.
pub fn on_broadcast(sw: &mut SwitchState, ctx: &mut Ctx, pid: PacketId) {
    let Some(role) = role_of(sw, ctx.pkt(pid)) else {
        // not configured for this tree: forward toward dst, zero-copy
        let port = super::route_id(sw, ctx, pid);
        ctx.forward(port, pid);
        return;
    };
    let pkt = ctx.take(pid);
    let value_port = role.value_port;
    for port in role.child_ports {
        let mut down = pkt.clone();
        down.src = sw.id;
        if value_port.is_some_and(|vp| vp != port) {
            down.payload = Payload::None;
            down.wire_bytes = 64;
        }
        ctx.send(port, down);
    }
}

fn role_of(sw: &SwitchState, pkt: &Packet) -> Option<TreeRole> {
    sw.static_tree
        .jobs
        .get(&pkt.tenant)?
        .trees
        .get(pkt.tree as usize)?
        .clone()
}
