//! Static-tree in-network allreduce (the SHARP / SwitchML / ATP /
//! PANAMA-style baselines of Section 5.2).
//!
//! Trees are configured by a control plane before the job starts (we do
//! it instantaneously at job installation): each on-tree switch knows its
//! parent port, how many children contribute, and the ports to broadcast
//! down. Packets always follow the configured tree — that is exactly the
//! congestion weakness Canary removes.

use std::collections::HashMap;

use crate::sim::packet::{Packet, PacketKind, Payload};
use crate::sim::Ctx;

use super::alu;
use super::SwitchState;

/// Where this switch sits in one configured tree.
#[derive(Clone, Debug)]
pub enum TreeRole {
    /// Leaf aggregator: combine `expected` host contributions, then send
    /// the partial up `parent_port`; broadcast down `child_ports`.
    Leaf {
        parent_port: u16,
        expected: u32,
        child_ports: Vec<u16>,
    },
    /// Root: combine `expected` leaf partials, then start the broadcast
    /// down `child_ports`.
    Root {
        expected: u32,
        child_ports: Vec<u16>,
    },
}

/// Per-tenant static configuration: one role per tree index.
#[derive(Clone, Debug, Default)]
pub struct StaticJobInfo {
    pub trees: Vec<Option<TreeRole>>,
}

/// Per-switch static-tree state: configuration + in-flight aggregations.
#[derive(Debug, Default)]
pub struct StaticState {
    pub jobs: HashMap<u16, StaticJobInfo>,
    /// key = (tenant << 32) | block
    pub inflight: HashMap<u64, Agg>,
}

#[derive(Debug)]
pub struct Agg {
    pub count: u32,
    pub counter: u32,
    pub acc: Option<Vec<i32>>,
}

impl StaticState {
    pub fn clear(&mut self) {
        self.inflight.clear();
    }
}

/// Reduce-phase packet at an on-tree switch.
pub fn on_reduce(sw: &mut SwitchState, ctx: &mut Ctx, pkt: Packet) {
    let Some(role) = role_of(sw, &pkt) else {
        // not on this tree (e.g. transit spine for a bypassing packet):
        // plain-forward toward the root
        let port = super::route(sw, ctx, &pkt);
        ctx.send(port, pkt);
        return;
    };
    let (expected, parent_port, child_ports) = match role {
        TreeRole::Leaf {
            parent_port,
            expected,
            ..
        } => (expected, Some(parent_port), None),
        TreeRole::Root {
            expected,
            child_ports,
        } => (expected, None, Some(child_ports)),
    };

    let key = pkt.block_key();
    let agg = sw.static_tree.inflight.entry(key).or_insert_with(|| {
        ctx.metrics.on_descriptor_alloc();
        Agg {
            count: 0,
            counter: 0,
            acc: None,
        }
    });
    agg.count += 1;
    agg.counter += pkt.counter;
    if let Payload::Lanes(v) = &pkt.payload {
        match &mut agg.acc {
            Some(acc) => alu::sat_accumulate(acc, v),
            None => agg.acc = Some(v.to_vec()),
        }
    }
    if agg.count < expected {
        return; // swallow, keep waiting (static trees know their fan-in)
    }

    // complete at this level
    let agg = sw.static_tree.inflight.remove(&key).unwrap();
    ctx.metrics.on_descriptor_free(0);
    match (parent_port, child_ports) {
        (Some(parent), _) => {
            // leaf: one partial up the fixed tree edge
            let mut up = pkt.clone();
            up.kind = PacketKind::StaticReduce;
            up.src = sw.id;
            up.counter = agg.counter;
            up.payload = match agg.acc {
                Some(acc) => Payload::Lanes(acc.into_boxed_slice()),
                None => Payload::None,
            };
            ctx.send(parent, up);
        }
        (None, Some(children)) => {
            // root: start the broadcast
            for port in children {
                let mut down = pkt.clone();
                down.kind = PacketKind::StaticBroadcast;
                down.src = sw.id;
                down.counter = agg.counter;
                down.payload = match &agg.acc {
                    Some(acc) => {
                        Payload::Lanes(acc.clone().into_boxed_slice())
                    }
                    None => Payload::None,
                };
                ctx.send(port, down);
            }
        }
        (None, None) => unreachable!(),
    }
}

/// Broadcast-phase packet at an on-tree switch (leaf: fan out to hosts).
pub fn on_broadcast(sw: &mut SwitchState, ctx: &mut Ctx, pkt: Packet) {
    let Some(TreeRole::Leaf { child_ports, .. }) = role_of(sw, &pkt) else {
        // not a configured leaf for this tree: forward toward dst
        let port = super::route(sw, ctx, &pkt);
        ctx.send(port, pkt);
        return;
    };
    for port in child_ports {
        let mut down = pkt.clone();
        down.src = sw.id;
        ctx.send(port, down);
    }
}

fn role_of(sw: &SwitchState, pkt: &Packet) -> Option<TreeRole> {
    sw.static_tree
        .jobs
        .get(&pkt.tenant)?
        .trees
        .get(pkt.tree as usize)?
        .clone()
}
