//! Switch model: forwarding pipeline, routing, and the protocol
//! dataplanes (Canary dynamic trees + static-tree baselines).
//!
//! Node ids and port maps come from the multi-tier Clos builder
//! ([`crate::topology`], DESIGN.md §4): hosts `[0, H)`, then switches
//! tier by tier (leaves/ToRs first, spines/cores last). A tier-`t`
//! switch's ports `[0, down)` go to its children in child order and
//! `[down, down + up)` to its parents in parent order; on the 2-tier
//! paper network this is the familiar leaf map — host ports first, one
//! up-port per spine — and spine port `l` goes down to leaf `l`.
//!
//! All id/port arithmetic lives behind the topology handle: a switch
//! asks [`Clos::hop`] where a destination lies and either forwards on
//! the single valid port (down, or a label-aligned climb toward a
//! switch destination) or lets the configured load balancer pick among
//! the equivalent up-ports.

pub mod alu;
pub mod canary;
pub mod shards;
pub mod static_tree;

use crate::loadbalance::{select_up, LbState, LoadBalancer};
use crate::sim::packet::{Packet, PacketKind};
use crate::sim::{Ctx, NodeId, PacketId};
use crate::topology::{Clos, Hop};

/// Position of the switch in the Clos fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchRole {
    /// Tier 1 — hosts attached below.
    Leaf,
    /// An intermediate tier of a >=3-tier fabric (the pod layer of a
    /// 3-tier Clos). `tier` is its 1-based tier number.
    Aggregation { tier: u8 },
    /// The top tier — the turnaround switches (the paper's "spines";
    /// the core layer of a 3-tier fabric).
    Spine,
}

/// Complete switch state.
pub struct SwitchState {
    pub id: NodeId,
    /// 1-based tier in the Clos fabric.
    pub tier: u8,
    /// Within-tier switch index.
    pub index: u32,
    /// Topology handle (id/port arithmetic for routing decisions).
    pub topo: Clos,
    pub lb: LoadBalancer,
    pub lb_state: LbState,
    pub failed: bool,
    pub canary: canary::Dataplane,
    pub static_tree: static_tree::StaticState,
}

impl SwitchState {
    pub fn new(
        topo: Clos,
        tier: u8,
        index: u32,
        lb: LoadBalancer,
        descriptor_slots: u32,
    ) -> SwitchState {
        let id = topo.switch_id(tier, index);
        SwitchState {
            id,
            tier,
            index,
            topo,
            lb,
            lb_state: LbState::default(),
            failed: false,
            canary: canary::Dataplane::new(descriptor_slots, id as u64),
            static_tree: static_tree::StaticState::default(),
        }
    }

    /// Position of this switch in the fabric, derived from its tier.
    pub fn role(&self) -> SwitchRole {
        if self.tier == 1 {
            SwitchRole::Leaf
        } else if self.tier == self.topo.tiers() {
            SwitchRole::Spine
        } else {
            SwitchRole::Aggregation { tier: self.tier }
        }
    }
}

/// Pick the egress port for `pkt` at this switch (destination-based
/// up/down routing with configurable up-port load balancing on the
/// equivalent-path hops).
pub fn route(sw: &mut SwitchState, ctx: &Ctx, pkt: &Packet) -> u16 {
    match sw.topo.hop_at(sw.tier, sw.index, pkt.dst) {
        Hop::Port(p) => p,
        Hop::Up { base, n, dflt } => {
            let off = select_up(
                &sw.lb,
                &mut sw.lb_state,
                ctx,
                base,
                n,
                dflt,
                pkt.flow ^ pkt.dst as u64,
                if pkt.kind.droppable() { 1 } else { 0 },
            );
            base + off
        }
        Hop::Local => {
            unreachable!("routing a packet addressed to this switch")
        }
    }
}

/// Pick the egress port for the live packet `pid` (see [`route`]).
pub fn route_id(sw: &mut SwitchState, ctx: &Ctx, pid: PacketId) -> u16 {
    let pkt = ctx.pkt(pid);
    route(sw, ctx, pkt)
}

/// Main packet entry point for a switch. Owns the arena entry `pid`:
/// transit traffic is forwarded zero-copy, the aggregation dataplanes
/// take the packet out of the arena when they consume it.
pub fn handle_packet(
    sw: &mut SwitchState,
    ctx: &mut Ctx,
    in_port: u16,
    pid: PacketId,
) {
    if sw.failed {
        ctx.metrics.drops_link_down += 1;
        ctx.free(pid);
        return;
    }
    let (kind, bypass, dst) = {
        let p = ctx.pkt(pid);
        (p.kind, p.bypass, p.dst)
    };
    // Bypass-marked packets skip all processing (Section 4.1).
    if bypass {
        let port = route_id(sw, ctx, pid);
        ctx.forward(port, pid);
        return;
    }
    match kind {
        PacketKind::CanaryReduce => canary::on_reduce(sw, ctx, in_port, pid),
        PacketKind::CanaryBroadcast => canary::on_broadcast(sw, ctx, pid),
        PacketKind::CanaryRestore => {
            if dst == sw.id {
                canary::on_restore(sw, ctx, pid);
            } else {
                let port = route_id(sw, ctx, pid);
                ctx.forward(port, pid);
            }
        }
        PacketKind::StaticReduce => static_tree::on_reduce(sw, ctx, pid),
        PacketKind::StaticBroadcast => {
            static_tree::on_broadcast(sw, ctx, pid)
        }
        // host-to-host traffic: plain forwarding, zero-copy
        PacketKind::CanaryRetransReq
        | PacketKind::CanaryRetransData
        | PacketKind::CanaryFailure
        | PacketKind::CanaryDirect
        | PacketKind::Ring
        | PacketKind::Background
        | PacketKind::TransportAck
        | PacketKind::TransportCnp => {
            let port = route_id(sw, ctx, pid);
            ctx.forward(port, pid);
        }
    }
}

/// Canary descriptor timeout dispatch (from the event loop).
pub fn handle_timeout(
    sw: &mut SwitchState,
    ctx: &mut Ctx,
    slot: u32,
    generation: u64,
) {
    if sw.failed {
        return;
    }
    canary::on_timeout(sw, ctx, slot, generation);
}

/// Fault injection: lose all soft state (Section 3.3 — recovery happens
/// end-to-end, the switch itself does nothing).
pub fn clear_soft_state(sw: &mut SwitchState) {
    sw.failed = true;
    sw.canary.clear();
    sw.static_tree.clear();
}
