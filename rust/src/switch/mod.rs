//! Switch model: forwarding pipeline, routing, and the protocol
//! dataplanes (Canary dynamic trees + static-tree baselines).
//!
//! Node-id layout (fixed by the fat-tree builder): hosts `[0, H)`, leaf
//! switches `[H, H+L)`, spine switches `[H+L, H+L+S)`. Leaf port map:
//! ports `[0, hosts_per_leaf)` go down to hosts, `[hosts_per_leaf, ..)`
//! go up, one per spine. Spine port `l` goes down to leaf `l`.

pub mod alu;
pub mod canary;
pub mod shards;
pub mod static_tree;

use crate::loadbalance::{select_up, LbState, LoadBalancer};
use crate::sim::packet::{Packet, PacketKind};
use crate::sim::{Ctx, NodeId};

/// Position of the switch in the fat tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchRole {
    Leaf { index: u32, first_host: NodeId },
    Spine { index: u32 },
}

/// Complete switch state.
pub struct SwitchState {
    pub id: NodeId,
    pub role: SwitchRole,
    pub lb: LoadBalancer,
    pub lb_state: LbState,
    /// Topology facts needed for local routing decisions.
    pub n_hosts: u32,
    pub n_leaf: u32,
    pub hosts_per_leaf: u32,
    pub n_spine: u32,
    pub failed: bool,
    pub canary: canary::Dataplane,
    pub static_tree: static_tree::StaticState,
}

impl SwitchState {
    /// First up-port index on a leaf.
    #[inline]
    pub fn up_base(&self) -> u16 {
        self.hosts_per_leaf as u16
    }

    /// Classify a node id.
    #[inline]
    pub fn is_host(&self, node: NodeId) -> bool {
        node < self.n_hosts
    }

    #[inline]
    pub fn leaf_index_of_host(&self, host: NodeId) -> u32 {
        host / self.hosts_per_leaf
    }

    #[inline]
    pub fn is_leaf_switch(&self, node: NodeId) -> bool {
        node >= self.n_hosts && node < self.n_hosts + self.n_leaf
    }

    #[inline]
    pub fn is_spine_switch(&self, node: NodeId) -> bool {
        node >= self.n_hosts + self.n_leaf
            && node < self.n_hosts + self.n_leaf + self.n_spine
    }

    #[inline]
    pub fn spine_index(&self, node: NodeId) -> u32 {
        node - self.n_hosts - self.n_leaf
    }

    #[inline]
    pub fn leaf_index(&self, node: NodeId) -> u32 {
        node - self.n_hosts
    }
}

/// Pick the egress port for `pkt` at this switch (destination-based
/// up/down routing with configurable up-port load balancing).
pub fn route(sw: &mut SwitchState, ctx: &Ctx, pkt: &Packet) -> u16 {
    let dst = pkt.dst;
    match sw.role {
        SwitchRole::Leaf { index, first_host } => {
            let up_base = sw.up_base();
            let n_spine = sw.n_spine as u16;
            if sw.is_host(dst) {
                let leaf = sw.leaf_index_of_host(dst);
                if leaf == index {
                    // down to the local host
                    return (dst - first_host) as u16;
                }
                // up: adaptive choice among all spines
                let dflt = (dst % sw.n_spine) as u16;
                let off = select_up(
                    &sw.lb,
                    &mut sw.lb_state,
                    ctx,
                    up_base,
                    n_spine,
                    dflt,
                    pkt.flow ^ dst as u64,
                    if pkt.kind.droppable() { 1 } else { 0 },
                );
                up_base + off
            } else if sw.is_spine_switch(dst) {
                // direct link to that spine
                up_base + sw.spine_index(dst) as u16
            } else {
                // another leaf switch: via any spine
                let dflt = (dst % sw.n_spine) as u16;
                let off = select_up(
                    &sw.lb,
                    &mut sw.lb_state,
                    ctx,
                    up_base,
                    n_spine,
                    dflt,
                    pkt.flow ^ dst as u64,
                    if pkt.kind.droppable() { 1 } else { 0 },
                );
                up_base + off
            }
        }
        SwitchRole::Spine { .. } => {
            if sw.is_host(dst) {
                sw.leaf_index_of_host(dst) as u16
            } else if sw.is_leaf_switch(dst) {
                sw.leaf_index(dst) as u16
            } else {
                unreachable!("spine routing to spine {dst}")
            }
        }
    }
}

/// Main packet entry point for a switch.
pub fn handle_packet(
    sw: &mut SwitchState,
    ctx: &mut Ctx,
    in_port: u16,
    pkt: Packet,
) {
    if sw.failed {
        ctx.metrics.drops_link_down += 1;
        return;
    }
    // Bypass-marked packets skip all processing (Section 4.1).
    if pkt.bypass {
        let port = route(sw, ctx, &pkt);
        ctx.send(port, pkt);
        return;
    }
    match pkt.kind {
        PacketKind::CanaryReduce => canary::on_reduce(sw, ctx, in_port, pkt),
        PacketKind::CanaryBroadcast => canary::on_broadcast(sw, ctx, pkt),
        PacketKind::CanaryRestore => {
            if pkt.dst == sw.id {
                canary::on_restore(sw, ctx, pkt);
            } else {
                let port = route(sw, ctx, &pkt);
                ctx.send(port, pkt);
            }
        }
        PacketKind::StaticReduce => static_tree::on_reduce(sw, ctx, pkt),
        PacketKind::StaticBroadcast => {
            static_tree::on_broadcast(sw, ctx, pkt)
        }
        // host-to-host traffic: plain forwarding
        PacketKind::CanaryRetransReq
        | PacketKind::CanaryRetransData
        | PacketKind::CanaryFailure
        | PacketKind::CanaryDirect
        | PacketKind::Ring
        | PacketKind::Background => {
            let port = route(sw, ctx, &pkt);
            ctx.send(port, pkt);
        }
    }
}

/// Canary descriptor timeout dispatch (from the event loop).
pub fn handle_timeout(
    sw: &mut SwitchState,
    ctx: &mut Ctx,
    slot: u32,
    generation: u64,
) {
    if sw.failed {
        return;
    }
    canary::on_timeout(sw, ctx, slot, generation);
}

/// Fault injection: lose all soft state (Section 3.3 — recovery happens
/// end-to-end, the switch itself does nothing).
pub fn clear_soft_state(sw: &mut SwitchState) {
    sw.failed = true;
    sw.canary.clear();
    sw.static_tree.clear();
}
