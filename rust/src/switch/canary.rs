//! The Canary switch dataplane (paper Sections 3.1-3.2, 4).
//!
//! Soft state only: a hash-indexed descriptor array. Descriptors are
//! allocated on the first reduce packet of a block, hold the saturating
//! accumulator, the children port bitmap, the contribution counter and a
//! timeout; they are freed when the broadcast passes (or when the switch
//! dies — recovery is the leader's job, Section 3.3).

use crate::sim::packet::{Packet, PacketKind, Payload};
use crate::sim::{Ctx, NodeId, PacketId, Time};
use crate::util::rng::splitmix64;

use super::alu;
use super::SwitchState;

/// One reduction-block descriptor (paper Fig. 3 / Section 3.1.3).
#[derive(Clone, Debug)]
pub struct Descriptor {
    /// (tenant << 32) | block — the wire id.
    pub key: u64,
    pub tenant: u16,
    pub block: u32,
    /// Saturating fixed-point accumulator (None in size-only mode).
    pub acc: Option<Vec<i32>>,
    /// Contributions aggregated so far (sum of packet counters).
    pub counter: u32,
    /// Total participating hosts (from the packets).
    pub hosts: u32,
    /// Ports the block's packets arrived from — the dynamic children.
    pub children: u64,
    /// Leader host address (packets' destination).
    pub leader: NodeId,
    /// Partial already forwarded (timeout fired or counter complete):
    /// later arrivals are stragglers.
    pub sent: bool,
    /// Invalidates stale timeout events after slot reuse.
    pub generation: u64,
    pub alloc_time: Time,
}

/// The per-switch Canary state: a fixed-size descriptor array, exactly
/// like the register array of the Tofino prototype (Section 4).
#[derive(Debug)]
pub struct Dataplane {
    pub table: Vec<Option<Descriptor>>,
    /// Static tenant partitioning (Section 5.2.4): with `partitions > 1`
    /// each tenant hashes only within its own disjoint table region, so
    /// concurrent tenants can never collide with each other.
    pub partitions: u32,
    /// Per-switch hash salt. Crucial: with one global hash function two
    /// colliding ids would collide at *every* switch simultaneously,
    /// denying the victim block all in-network aggregation (all its
    /// packets bypass straight to the leader). Per-device hashing
    /// de-correlates collisions, as per-device CRC configs do on real
    /// switches.
    salt: u64,
    next_generation: u64,
}

impl Dataplane {
    pub fn new(slots: u32, salt: u64) -> Dataplane {
        Dataplane {
            table: (0..slots).map(|_| None).collect(),
            partitions: 1,
            salt,
            next_generation: 1,
        }
    }

    /// Hash a block id to a table slot (the prototype uses a hardware
    /// hash unit; we use a strong integer mixer). The tenant selects the
    /// table partition; the block id selects the slot within it.
    #[inline]
    pub fn slot_of(&self, key: u64) -> u32 {
        let tenant = (key >> 32) as u32;
        let region_size =
            (self.table.len() as u64 / self.partitions as u64).max(1);
        let region = (tenant % self.partitions) as u64 * region_size;
        let mut s = key
            ^ 0xD6E8_FEB8_6659_FD93
            ^ self.salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        (region + splitmix64(&mut s) % region_size) as u32
    }

    pub fn live_descriptors(&self) -> usize {
        self.table.iter().filter(|d| d.is_some()).count()
    }

    /// Drop all soft state (switch failure).
    pub fn clear(&mut self) {
        for slot in self.table.iter_mut() {
            *slot = None;
        }
    }
}

/// Handle a reduce-phase packet arriving at `in_port`.
pub fn on_reduce(
    sw: &mut SwitchState,
    ctx: &mut Ctx,
    in_port: u16,
    pid: PacketId,
) {
    let mut pkt = ctx.take(pid);
    let key = pkt.block_key();
    let slot = sw.canary.slot_of(key) as usize;
    match &mut sw.canary.table[slot] {
        None => {
            // first packet of the block: allocate the descriptor,
            // start the timer, swallow the packet (Section 3.1.1)
            let generation = sw.canary.next_generation;
            sw.canary.next_generation += 1;
            let mut acc = None;
            alu::fold_payload(
                &mut acc,
                std::mem::replace(&mut pkt.payload, Payload::None),
            );
            let complete = pkt.counter >= pkt.hosts;
            sw.canary.table[slot] = Some(Descriptor {
                key,
                tenant: pkt.tenant,
                block: pkt.block,
                acc,
                counter: pkt.counter,
                hosts: pkt.hosts,
                children: 1u64 << in_port,
                leader: pkt.dst,
                sent: false,
                generation,
                alloc_time: ctx.now,
            });
            ctx.metrics.on_descriptor_alloc();
            if complete {
                // everything already aggregated upstream: forward now
                forward_partial(sw, ctx, slot, false);
            } else {
                ctx.switch_timeout(
                    ctx.cfg.canary_timeout_ps,
                    slot as u32,
                    generation,
                );
            }
        }
        Some(d) if d.key == key => {
            if !d.sent {
                // aggregate into the descriptor and swallow the packet
                if let (Some(acc), Payload::Lanes(v)) =
                    (&mut d.acc, &pkt.payload)
                {
                    alu::sat_accumulate(acc, v);
                }
                d.counter += pkt.counter;
                d.children |= 1u64 << in_port;
                if d.counter >= d.hosts {
                    // all contributions seen: no need to wait the timer
                    forward_partial(sw, ctx, slot, false);
                }
            } else {
                // straggler: record the child so the broadcast reaches
                // it, then pass the packet through unchanged
                d.children |= 1u64 << in_port;
                ctx.metrics.stragglers += 1;
                let port = super::route(sw, ctx, &pkt);
                ctx.send(port, pkt);
            }
        }
        Some(_) => {
            // collision: annotate with our address + ingress port and
            // bypass-forward straight to the leader (Section 3.2.1)
            ctx.metrics.collisions += 1;
            pkt.collision = Some((sw.id, in_port));
            pkt.bypass = true;
            let port = super::route(sw, ctx, &pkt);
            ctx.send(port, pkt);
        }
    }
}

/// Descriptor timeout fired (or counter completed): send the partial
/// aggregate one hop further toward the leader.
pub fn on_timeout(
    sw: &mut SwitchState,
    ctx: &mut Ctx,
    slot: u32,
    generation: u64,
) {
    let Some(d) = &sw.canary.table[slot as usize] else {
        return; // already broadcast + freed
    };
    if d.generation != generation || d.sent {
        return; // stale timer or already forwarded
    }
    if d.counter < d.hosts {
        // genuinely incomplete: the timeout is cutting stragglers off
        // and emitting a partial aggregate (Section 3.1.1)
        ctx.metrics.partial_aggregates += 1;
    }
    forward_partial(sw, ctx, slot as usize, true);
}

fn forward_partial(
    sw: &mut SwitchState,
    ctx: &mut Ctx,
    slot: usize,
    via_timeout: bool,
) {
    let d = sw.canary.table[slot].as_mut().expect("descriptor");
    d.sent = true;
    // realized-tree capture: this forward *is* one edge set of the
    // dynamic tree (which ports fed this switch for this block)
    ctx.tracer.tree(crate::trace::TreeRecord {
        t_ps: ctx.now,
        tenant: d.tenant as u32,
        block: d.block,
        switch: sw.id,
        children: d.children,
        contributed: d.counter,
        expected: d.hosts,
        via_timeout,
        latency_ps: ctx.now - d.alloc_time,
    });
    // flight recorder: descriptor residency is the aggregation wait of
    // this block at this switch (timeout penalty when forced)
    ctx.tracer.wait(crate::trace::WaitRecord {
        tenant: d.tenant,
        block: d.block,
        node: sw.id,
        t_start: d.alloc_time,
        t_end: ctx.now,
        via_timeout,
    });
    let mut pkt = Packet::data(PacketKind::CanaryReduce, sw.id, d.leader);
    pkt.tenant = d.tenant;
    pkt.block = d.block;
    pkt.counter = d.counter;
    pkt.hosts = d.hosts;
    pkt.flow = d.key;
    if let Some(acc) = &d.acc {
        pkt.payload = Payload::Lanes(acc.clone().into_boxed_slice());
        // the accumulator has served its purpose; children stay
        d.acc = None;
    }
    let port = super::route(sw, ctx, &pkt);
    ctx.send(port, pkt);
}

/// Broadcast-phase packet arriving from our parent: fan out to the
/// recorded children and free the descriptor (Section 3.1.2).
pub fn on_broadcast(sw: &mut SwitchState, ctx: &mut Ctx, pid: PacketId) {
    let pkt = ctx.take(pid);
    let key = pkt.block_key();
    let slot = sw.canary.slot_of(key) as usize;
    match &sw.canary.table[slot] {
        Some(d) if d.key == key => {
            let children = d.children;
            let residency = ctx.now - d.alloc_time;
            sw.canary.table[slot] = None;
            ctx.metrics.on_descriptor_free(residency);
            fan_out(ctx, children, &pkt);
        }
        _ => {
            // no descriptor (collision happened here): drop — the
            // leader restores this subtree explicitly
        }
    }
}

/// Restoration packet addressed to this switch: bootstrap the local
/// broadcast on the ports the leader tells us (Section 3.2.1).
pub fn on_restore(sw: &mut SwitchState, ctx: &mut Ctx, pid: PacketId) {
    let pkt = ctx.take(pid);
    ctx.metrics.restorations += 1;
    // also free any descriptor this id may have (partial children were
    // already served by the regular broadcast path)
    fan_out(ctx, pkt.restore, &pkt);
    let _ = sw;
}

fn fan_out(ctx: &mut Ctx, children: u64, template: &Packet) {
    for port in 0..64u16 {
        if children & (1u64 << port) != 0 {
            let mut out = template.clone();
            out.kind = PacketKind::CanaryBroadcast;
            out.bypass = false;
            out.collision = None;
            out.restore = 0;
            ctx.send(port, out);
        }
    }
}
