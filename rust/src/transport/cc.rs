//! Per-sender congestion-control state machines (DCQCN and Swift-style).
//!
//! A [`FlowCc`] holds the sending rate as a fraction of the NIC line
//! rate; the traffic engine divides its load-derived pacing gap by that
//! fraction, so `rate = 1.0` reproduces the unreactive cadence exactly.
//!
//! **DCQCN** (Zhu et al., SIGCOMM'15), reaction-point side: every CNP
//! cuts the rate multiplicatively by `alpha/2` and refreshes the
//! `alpha` EWMA; in the absence of CNPs the rate recovers toward the
//! pre-cut target — first by halving the gap to it (fast recovery),
//! then by pushing the target up additively. The byte-counter trigger
//! of the original is folded into the timer trigger: one increase step
//! per [`DCQCN_TIMER_PS`] without a CNP.
//!
//! **Swift** (Kumar et al., SIGCOMM'20), simplified to its core AIMD on
//! delay: the sink echoes the largest one-way delay observed since the
//! last ACK (the simulator's picosecond timestamps make this exact);
//! above [`SWIFT_TARGET_DELAY_PS`] the sender cuts multiplicatively in
//! proportion to the overshoot (at most once per
//! [`SWIFT_DECREASE_GUARD_PS`], Swift's once-per-RTT rule), below it
//! the rate climbs additively.

use crate::sim::{Time, US};

use super::TransportSpec;

/// DCQCN alpha EWMA gain (`g` in the paper).
pub const DCQCN_G: f64 = 1.0 / 16.0;
/// DCQCN additive-increase step, as a fraction of line rate.
pub const DCQCN_RAI: f64 = 0.05;
/// DCQCN increase-timer period (one recovery step per period without
/// a CNP).
pub const DCQCN_TIMER_PS: Time = 55 * US;
/// Fast-recovery steps before additive increase starts.
pub const DCQCN_FAST_RECOVERY_STAGES: u32 = 5;

/// Swift target one-way delay (fabric base delay + a shallow-queue
/// allowance; the 2-tier base RTT is ~3 us).
pub const SWIFT_TARGET_DELAY_PS: Time = 5 * US;
/// Swift multiplicative-decrease gain (`beta`).
pub const SWIFT_BETA: f64 = 0.8;
/// Swift maximum fractional cut per decrease event.
pub const SWIFT_MAX_MD: f64 = 0.7;
/// Swift additive-increase step per on-target ACK.
pub const SWIFT_AI: f64 = 0.05;
/// Minimum spacing between Swift decreases (once-per-RTT rule).
pub const SWIFT_DECREASE_GUARD_PS: Time = 10 * US;

/// Rate floor: senders never stall completely (1/128 of line rate).
pub const MIN_RATE: f64 = 1.0 / 128.0;

/// Per-sender congestion-control state. One per background host: the
/// traffic engine transmits one flow at a time, so the host's NIC rate
/// is the flow rate.
#[derive(Clone, Debug)]
pub struct FlowCc {
    spec: TransportSpec,
    /// Current sending rate as a fraction of line rate, in
    /// `[MIN_RATE, 1.0]`.
    rate: f64,
    /// DCQCN target rate (the rate before the last cut).
    target: f64,
    /// DCQCN congestion-extent EWMA.
    alpha: f64,
    /// Completed recovery steps since the last decrease.
    stage: u32,
    last_decrease_ps: Time,
    last_increase_ps: Time,
}

impl FlowCc {
    pub fn new(spec: TransportSpec) -> FlowCc {
        FlowCc {
            spec,
            rate: 1.0,
            target: 1.0,
            alpha: 1.0,
            stage: 0,
            last_decrease_ps: 0,
            last_increase_ps: 0,
        }
    }

    /// Current rate as a fraction of line rate.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Stretch a line-rate pacing gap to the current rate.
    pub fn stretch(&self, gap_ps: u64) -> u64 {
        if self.rate >= 1.0 {
            gap_ps
        } else {
            (gap_ps as f64 / self.rate.max(MIN_RATE)).ceil() as u64
        }
    }

    /// DCQCN reaction point: a CNP arrived for one of our flows.
    pub fn on_cnp(&mut self, now: Time) {
        if self.spec != TransportSpec::Dcqcn {
            return;
        }
        self.alpha = (1.0 - DCQCN_G) * self.alpha + DCQCN_G;
        self.target = self.rate;
        self.rate = (self.rate * (1.0 - self.alpha / 2.0)).max(MIN_RATE);
        self.stage = 0;
        self.last_decrease_ps = now;
        self.last_increase_ps = now;
    }

    /// Swift reaction: an ACK echoed the largest one-way delay since
    /// the previous ACK.
    pub fn on_delay(&mut self, now: Time, delay_ps: Time) {
        if self.spec != TransportSpec::Swift {
            return;
        }
        if delay_ps > SWIFT_TARGET_DELAY_PS {
            if now.saturating_sub(self.last_decrease_ps)
                < SWIFT_DECREASE_GUARD_PS
            {
                return;
            }
            let overshoot = (delay_ps - SWIFT_TARGET_DELAY_PS) as f64
                / delay_ps as f64;
            let cut = (SWIFT_BETA * overshoot).min(SWIFT_MAX_MD);
            self.rate = (self.rate * (1.0 - cut)).max(MIN_RATE);
            self.last_decrease_ps = now;
        } else {
            self.rate = (self.rate + SWIFT_AI).min(1.0);
        }
    }

    /// DCQCN recovery clock, called from the sender's wake path: one
    /// recovery step per [`DCQCN_TIMER_PS`] without a CNP. Also decays
    /// `alpha` so long CNP-free stretches forget past congestion.
    pub fn maybe_increase(&mut self, now: Time) {
        if self.spec != TransportSpec::Dcqcn {
            return;
        }
        if now.saturating_sub(self.last_increase_ps) < DCQCN_TIMER_PS {
            return;
        }
        self.last_increase_ps = now;
        self.alpha *= 1.0 - DCQCN_G;
        self.stage += 1;
        if self.stage > DCQCN_FAST_RECOVERY_STAGES {
            self.target = (self.target + DCQCN_RAI).min(1.0);
        }
        self.rate = ((self.rate + self.target) / 2.0).min(1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_inert_at_line_rate() {
        let mut cc = FlowCc::new(TransportSpec::None);
        cc.on_cnp(0);
        cc.on_delay(0, 100 * US);
        cc.maybe_increase(10 * DCQCN_TIMER_PS);
        assert_eq!(cc.rate(), 1.0);
        assert_eq!(cc.stretch(1000), 1000);
    }

    #[test]
    fn dcqcn_decrease_is_monotone_and_floored() {
        let mut cc = FlowCc::new(TransportSpec::Dcqcn);
        let mut prev = cc.rate();
        for i in 0..64 {
            cc.on_cnp(i * US);
            assert!(cc.rate() < prev || cc.rate() == MIN_RATE);
            assert!(cc.rate() >= MIN_RATE);
            prev = cc.rate();
        }
        assert!(prev <= 2.0 * MIN_RATE, "sustained CNPs drive to the floor");
    }

    #[test]
    fn dcqcn_recovery_is_monotone_back_to_line_rate() {
        let mut cc = FlowCc::new(TransportSpec::Dcqcn);
        for i in 0..10 {
            cc.on_cnp(i * US);
        }
        let mut prev = cc.rate();
        let mut t = 10 * US;
        for _ in 0..200 {
            t += DCQCN_TIMER_PS;
            cc.maybe_increase(t);
            assert!(cc.rate() >= prev, "recovery never decreases");
            prev = cc.rate();
        }
        assert!(prev > 0.99, "recovery reaches line rate, got {prev}");
    }

    #[test]
    fn dcqcn_increase_is_clocked_not_per_call() {
        let mut cc = FlowCc::new(TransportSpec::Dcqcn);
        cc.on_cnp(0);
        let r = cc.rate();
        cc.maybe_increase(US); // within the timer period: no step
        assert_eq!(cc.rate(), r);
        cc.maybe_increase(DCQCN_TIMER_PS + US);
        assert!(cc.rate() > r);
    }

    #[test]
    fn swift_aimd_on_delay_target() {
        let mut cc = FlowCc::new(TransportSpec::Swift);
        // overshoot: multiplicative cut, guarded once per RTT window
        cc.on_delay(SWIFT_DECREASE_GUARD_PS, 4 * SWIFT_TARGET_DELAY_PS);
        let after_cut = cc.rate();
        assert!(after_cut < 1.0);
        cc.on_delay(SWIFT_DECREASE_GUARD_PS + US, 4 * SWIFT_TARGET_DELAY_PS);
        assert_eq!(cc.rate(), after_cut, "decrease guard holds");
        // on-target: additive climb back to line rate
        let mut prev = cc.rate();
        for i in 0..40 {
            cc.on_delay((2 + i) * SWIFT_DECREASE_GUARD_PS, US);
            assert!(cc.rate() >= prev);
            prev = cc.rate();
        }
        assert_eq!(prev, 1.0);
    }

    #[test]
    fn stretch_divides_by_rate() {
        let mut cc = FlowCc::new(TransportSpec::Dcqcn);
        assert_eq!(cc.stretch(1000), 1000);
        for i in 0..4 {
            cc.on_cnp(i * US);
        }
        let g = cc.stretch(1000);
        assert!(g > 1000);
        assert_eq!(g, (1000.0 / cc.rate()).ceil() as u64);
    }
}
