//! Reactive transport for class-1 (background) traffic: ECN marking,
//! DCQCN/Swift-style rate control and per-flow loss recovery.
//!
//! The paper's congestion generators are *unreactive*: background flows
//! inject at a fixed offered load whatever the fabric does, and packets
//! lost to the class-1 policer are simply gone. Real datacenter cross
//! traffic is transport-governed — RoCE fabrics run DCQCN (the setting
//! NetReduce targets for RDMA-compatible in-network reduction) and
//! modern hyperscalers run delay-based congestion control (Swift).
//! Whether Canary's adaptive trees still beat static ones when the
//! competing traffic *backs off on its own* is the question this
//! subsystem lets the simulator ask.
//!
//! Three pieces (DESIGN.md §2.4):
//!
//! - **ECN marking** lives in the sim core (`sim/network.rs`): when
//!   [`crate::config::SimConfig::ecn_enabled`] is set, class-1 packets
//!   are marked CE on enqueue with RED-style probability — zero below
//!   `ecn_kmin_bytes` of instantaneous class-1 backlog, one above
//!   `ecn_kmax_bytes`, linear in between. Reduction traffic (class 0)
//!   is lossless/PFC-paused and is never marked. With transport off the
//!   marking path is a single branch and draws nothing from the RNG, so
//!   every recorded seed stays bit-identical (`tests/transport.rs`).
//! - **Rate control** is a per-sender [`FlowCc`] state machine
//!   ([`cc`]): DCQCN reacts to CNPs echoed by the sink (multiplicative
//!   decrease, alpha-EWMA, fast-recovery + additive increase), Swift to
//!   the one-way delay samples echoed on ACKs (target-delay AIMD). The
//!   current rate stretches the pacing gap the traffic engine derives
//!   from `load` ([`crate::traffic::engine`]).
//! - **Loss recovery**: data packets carry a per-flow sequence number
//!   and the flow's total packet count; sinks track received sequences
//!   per flow ([`SinkFlow`]), deduplicate retransmitted copies, send a
//!   cumulative ACK every [`ACK_EVERY`] packets plus a final ACK on
//!   completion, and senders retransmit the unacked suffix after an RTO
//!   (go-back-N from the cumulative prefix, exponential backoff,
//!   bounded by [`MAX_FLOW_RETRIES`]). FCT/completion metrics therefore
//!   stay meaningful under overload instead of flows silently dying.
//!
//! Pluggability: [`TransportSpec`] rides on
//! [`crate::traffic::TrafficSpec`] (`--transport dcqcn`, JSON
//! `"transport": "swift"`); `TransportSpec::None` — the default — is
//! pinned bit-identical to the pre-transport simulator.

pub mod cc;

pub use cc::FlowCc;

use crate::sim::{NodeId, Time, US};

/// Which congestion-control law governs the background senders.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportSpec {
    /// Unreactive legacy behavior: fixed offered load, no marking, no
    /// recovery. Bit-identical to the pre-transport simulator.
    #[default]
    None,
    /// DCQCN-style: sinks echo CNPs for CE-marked packets, senders do
    /// multiplicative decrease + fast-recovery/additive increase.
    Dcqcn,
    /// Swift-style: sinks echo one-way delay samples on ACKs, senders
    /// run target-delay AIMD on the picosecond timestamps.
    Swift,
}

impl TransportSpec {
    /// Is any reactive transport active?
    pub fn is_on(self) -> bool {
        self != TransportSpec::None
    }

    /// Stable tag for CSV cells and log lines.
    pub fn name(self) -> &'static str {
        match self {
            TransportSpec::None => "none",
            TransportSpec::Dcqcn => "dcqcn",
            TransportSpec::Swift => "swift",
        }
    }

    /// Parse the CLI spelling (`none`, `dcqcn`, `swift`).
    pub fn parse(s: &str) -> Result<TransportSpec, String> {
        match s {
            "none" | "off" => Ok(TransportSpec::None),
            "dcqcn" => Ok(TransportSpec::Dcqcn),
            "swift" => Ok(TransportSpec::Swift),
            other => Err(format!(
                "unknown transport '{other}' (none|dcqcn|swift)"
            )),
        }
    }
}

/// Wire size of the transport control packets (ACK/CNP): header-only
/// frames, far below a data MTU.
pub const CTRL_WIRE_BYTES: u32 = 64;

/// Sinks send a cumulative ACK every this many newly received packets
/// (plus always one on flow completion).
pub const ACK_EVERY: u32 = 8;

/// Minimum spacing between CNPs per flow (RoCE notification-point
/// behavior: at most one CNP per flow per 50 us).
pub const CNP_INTERVAL_PS: Time = 50 * US;

/// RTO retransmission rounds before a sender abandons a flow.
pub const MAX_FLOW_RETRIES: u8 = 8;

/// Go-back-N window: packets retransmitted per RTO round. Bounds the
/// burst a round injects (~70 KB on the wire, inside the 128 KiB
/// class-1 policer share) so recovery cannot self-drop at the sender's
/// own first hop; longer gaps advance over successive rounds as the
/// cumulative ACK moves.
pub const RETRANS_WINDOW_PKTS: u32 = 64;

/// Sink-side flow-table sweeps run every this many data packets
/// (amortizes the `retain` scan, as the flowlet-table eviction does).
pub const SINK_SWEEP_EVERY: u32 = 4096;

/// Sink flow entries idle longer than this many RTOs are evicted. The
/// worst-case sender retry chain (exponential backoff, capped shift)
/// sums to < 96 RTOs, so an entry this stale can never see another
/// packet — eviction only bounds the table.
pub const SINK_EVICT_RTOS: u64 = 128;

/// Sender-side recovery state for one in-flight (fully sent but not
/// fully acked) flow.
#[derive(Clone, Debug)]
pub struct UnackedFlow {
    pub dst: NodeId,
    /// Total data packets in the flow.
    pub pkts: u32,
    /// Highest cumulative contiguous prefix the sink has acked.
    pub acked_prefix: u32,
    /// RTO rounds used so far.
    pub retries: u8,
}

/// Sink-side reassembly state for one flow: a received-sequence bitmap
/// for deduplication, the cumulative prefix for ACKs, and the CNP/delay
/// bookkeeping the congestion-control feedback needs.
#[derive(Clone, Debug)]
pub struct SinkFlow {
    /// Total data packets the sender announced.
    pub total: u32,
    /// Bitmap over sequence numbers (dropped once the flow completes).
    received: Vec<u64>,
    pub n_received: u32,
    /// Length of the contiguous received prefix (cumulative-ACK value).
    pub prefix: u32,
    /// All packets received; the bitmap has been released.
    pub done: bool,
    /// Last CNP emission instant (rate-limits CNPs per flow).
    pub last_cnp_ps: Time,
    /// Largest one-way delay observed since the last ACK (Swift echo).
    pub max_delay_ps: Time,
    /// Newly received packets since the last ACK.
    pub since_ack: u32,
    /// Last packet arrival (stale-entry eviction horizon).
    pub last_seen_ps: Time,
    /// Last duplicate-triggered re-ACK (throttles the re-ACK path: a
    /// whole retransmission round elicits one prefix refresh, not one
    /// control frame per duplicate).
    pub last_reack_ps: Time,
}

impl SinkFlow {
    pub fn new(total: u32) -> SinkFlow {
        SinkFlow {
            total,
            received: vec![0u64; (total as usize).div_ceil(64)],
            n_received: 0,
            prefix: 0,
            done: false,
            last_cnp_ps: 0,
            max_delay_ps: 0,
            since_ack: 0,
            last_seen_ps: 0,
            last_reack_ps: 0,
        }
    }

    /// Record sequence `seq`; returns `false` when it was already seen
    /// (a duplicate from a retransmission round). Out-of-range
    /// sequences (malformed) are treated as duplicates.
    pub fn record(&mut self, seq: u32) -> bool {
        let (word, bit) = (seq as usize / 64, seq as usize % 64);
        if word >= self.received.len() || self.received[word] >> bit & 1 == 1 {
            return false;
        }
        self.received[word] |= 1 << bit;
        self.n_received += 1;
        // advance the cumulative prefix over the bitmap
        while self.prefix < self.total {
            let (w, b) = (self.prefix as usize / 64, self.prefix as usize % 64);
            if self.received[w] >> b & 1 == 0 {
                break;
            }
            self.prefix += 1;
        }
        if self.n_received >= self.total {
            self.done = true;
            self.received = Vec::new(); // release the bitmap
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_round_trip() {
        for t in [TransportSpec::None, TransportSpec::Dcqcn, TransportSpec::Swift] {
            assert_eq!(TransportSpec::parse(t.name()).unwrap(), t);
        }
        assert_eq!(TransportSpec::parse("off").unwrap(), TransportSpec::None);
        assert!(TransportSpec::parse("tcp").is_err());
        assert!(!TransportSpec::None.is_on());
        assert!(TransportSpec::Dcqcn.is_on());
    }

    #[test]
    fn sink_flow_dedups_and_tracks_prefix() {
        let mut f = SinkFlow::new(5);
        assert!(f.record(0));
        assert!(!f.record(0), "duplicate detected");
        assert_eq!(f.prefix, 1);
        assert!(f.record(3), "out of order accepted");
        assert_eq!(f.prefix, 1, "gap holds the prefix");
        assert!(f.record(1));
        assert!(f.record(2));
        assert_eq!(f.prefix, 4, "prefix jumps over the filled gap");
        assert!(!f.done);
        assert!(f.record(4));
        assert!(f.done);
        assert_eq!(f.prefix, 5);
        assert!(f.received.is_empty(), "bitmap released on completion");
        assert!(!f.record(2), "post-completion packets are duplicates");
    }

    #[test]
    fn sink_flow_rejects_out_of_range() {
        let mut f = SinkFlow::new(65);
        assert!(f.record(64), "second bitmap word");
        assert!(!f.record(1000), "out of range is a dup, not a panic");
    }
}
