//! `figures` — regenerate every table/figure of the paper's evaluation.
//! (Filled in by the figure harness; see DESIGN.md §5 for the index.)

fn main() {
    canary::figures::main_entry();
}
