//! Per-host traffic state machine: destination plans, closed/open-loop
//! injection and flow lifecycle bookkeeping.
//!
//! The closed-loop `uniform` path is **bit-compatible** with the legacy
//! `host/background.rs` generator: it performs the same RNG draws in the
//! same order, emits identical packets (src/dst/flow/wire size) and
//! schedules the same wake cadence at `load = 1.0`, so every recorded
//! figure series is unchanged under the default pattern
//! (`tests/traffic_engine.rs` pins this against an inlined replica of
//! the legacy state machine).

use std::collections::{HashMap, VecDeque};

use crate::sim::packet::{Packet, PacketKind};
use crate::sim::{Ctx, NodeId, PacketId, Time};
use crate::transport::{
    self, FlowCc, SinkFlow, TransportSpec, UnackedFlow,
};
use crate::util::rng::Rng;

use super::cdf;
use super::{Injection, TrafficPattern, TrafficSpec};

/// Resolved per-host destination law (computed once at install time by
/// [`build_plans`], so the wake path never re-derives group structure).
#[derive(Clone, Debug)]
pub enum DstPlan {
    /// Fresh uniform-random peer per message (legacy behavior).
    Uniform,
    /// Fixed partner: permutation cycles and incast senders.
    Fixed(NodeId),
    /// With probability `skew` pick one of the `hot` hosts, else a
    /// uniform-random peer.
    Hotspot { hot: Vec<NodeId>, skew: f64 },
    /// Generates nothing (incast sinks; they only absorb).
    Sink,
}

/// A flow that has arrived (open loop) but not started transmitting.
#[derive(Clone, Debug)]
pub struct PendingFlow {
    pub dst: NodeId,
    pub pkts: u32,
    pub flow: u64,
}

/// Traffic-generator state for one host.
pub struct TrafficHost {
    pub job: u32,
    pub spec: TrafficSpec,
    pub plan: DstPlan,
    /// Packets left in the flow currently on the wire.
    pub remaining: u32,
    /// Total packets of the flow currently on the wire (sequence
    /// numbering for the reactive transport).
    pub flow_pkts: u32,
    pub dst: NodeId,
    /// Messages/flows generated so far (also the flow-id low bits).
    pub msg_count: u64,
    /// Flow id of the active flow.
    pub flow: u64,
    /// Open loop: next Poisson arrival instant (valid once `primed`).
    pub next_arrival: Time,
    /// Open loop: arrived flows waiting for the NIC.
    pub backlog: VecDeque<PendingFlow>,
    primed: bool,
    // --- reactive transport (`crate::transport`; unused when off) ---
    /// Sender-side congestion control (rate as a line-rate fraction).
    pub cc: FlowCc,
    /// Sender-side flows awaiting their final ACK, keyed by flow id.
    pub unacked: HashMap<u64, UnackedFlow>,
    /// Sink-side per-flow reassembly/dedup state, keyed by flow id.
    pub sinks: HashMap<u64, SinkFlow>,
    /// Data packets since the last stale sink-entry sweep.
    since_sink_sweep: u32,
}

impl TrafficHost {
    pub fn new(job: u32, spec: TrafficSpec, plan: DstPlan) -> TrafficHost {
        TrafficHost {
            job,
            spec,
            plan,
            remaining: 0,
            flow_pkts: 0,
            dst: 0,
            msg_count: 0,
            flow: 0,
            next_arrival: 0,
            backlog: VecDeque::new(),
            primed: false,
            cc: FlowCc::new(spec.transport),
            unacked: HashMap::new(),
            sinks: HashMap::new(),
            since_sink_sweep: 0,
        }
    }
}

/// Flow label carried by every packet of a message: unique per
/// (host, message) — the same encoding the legacy generator used.
#[inline]
pub fn flow_id(me: NodeId, msg_count: u64) -> u64 {
    ((me as u64) << 32) | msg_count
}

/// Stretch the line-rate serialization gap to the offered load.
/// `load = 1.0` returns `base_ps` exactly (legacy cadence).
#[inline]
pub fn pace(base_ps: u64, load: f64) -> u64 {
    if load >= 1.0 {
        base_ps
    } else {
        ((base_ps as f64) / load.max(1e-9)).ceil() as u64
    }
}

/// Draw the next destination under `plan`, or `None` if this host
/// cannot generate (sink, or fewer than two peers).
fn draw_dst(
    plan: &DstPlan,
    rng: &mut Rng,
    me: NodeId,
    peers: &[NodeId],
) -> Option<NodeId> {
    let uniform = |rng: &mut Rng| -> Option<NodeId> {
        if peers.len() < 2 {
            return None;
        }
        loop {
            let cand = *rng.choose(peers);
            if cand != me {
                return Some(cand);
            }
        }
    };
    match plan {
        DstPlan::Sink => None,
        DstPlan::Fixed(d) => Some(*d),
        DstPlan::Uniform => uniform(rng),
        DstPlan::Hotspot { hot, skew } => {
            if peers.len() < 2 {
                return None;
            }
            // a host that is itself the only hot target falls back to
            // the uniform tail instead of spinning
            let hot_usable = !hot.is_empty() && !(hot.len() == 1 && hot[0] == me);
            if rng.chance(*skew) && hot_usable {
                loop {
                    let cand = hot[rng.index(hot.len())];
                    if cand != me {
                        return Some(cand);
                    }
                }
            } else {
                uniform(rng)
            }
        }
    }
}

/// Draw the next message (destination, packet count) — shared by both
/// injection modes. Pure in everything but the RNG, so the
/// bit-compatibility test can drive it against the legacy state machine
/// directly.
pub fn next_message(
    plan: &DstPlan,
    pattern: TrafficPattern,
    rng: &mut Rng,
    me: NodeId,
    peers: &[NodeId],
    bg_message_bytes: u64,
    payload_bytes: u64,
) -> Option<(NodeId, u32)> {
    let dst = draw_dst(plan, rng, me, peers)?;
    let bytes = match pattern {
        TrafficPattern::Empirical => cdf::sample_bytes(rng),
        _ => bg_message_bytes,
    };
    Some((dst, (bytes.div_ceil(payload_bytes)).max(1) as u32))
}

/// Wake entry point (scheduled by `kick_jobs` at t=0 and self-clocked
/// afterwards).
pub fn on_wake(
    me: NodeId,
    th: &mut TrafficHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    job: u32,
) {
    if matches!(th.plan, DstPlan::Sink) {
        return;
    }
    match th.spec.injection {
        Injection::Closed => closed_wake(me, th, rng, ctx, job),
        Injection::Open => open_wake(me, th, rng, ctx, job),
    }
}

/// Self-clocked stream: one packet per (load-stretched) serialization
/// interval; a new message is drawn whenever the previous one ends.
fn closed_wake(
    me: NodeId,
    th: &mut TrafficHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    job: u32,
) {
    let payload = ctx.cfg.payload_bytes as u64;
    if th.remaining == 0 {
        let msg = {
            let peers = &ctx.jobs[th.job as usize].spec.participants;
            next_message(
                &th.plan,
                th.spec.pattern,
                rng,
                me,
                peers,
                ctx.cfg.bg_message_bytes,
                payload,
            )
        };
        let Some((dst, pkts)) = msg else { return };
        th.dst = dst;
        th.remaining = pkts;
        th.flow_pkts = pkts;
        th.msg_count += 1;
        th.flow = flow_id(me, th.msg_count);
        let now = ctx.now;
        let flow = th.flow;
        ctx.flow_start(dst, flow, now, pkts, pkts as u64 * payload);
        track_unacked(th, dst, pkts);
    }

    let wire = send_data_packet(me, th, ctx, job);

    let mut next = pace(wire * ctx.cfg.link_ps_per_byte, th.spec.load);
    if th.spec.transport.is_on() {
        th.cc.maybe_increase(ctx.now);
        next = th.cc.stretch(next);
    }
    ctx.wake(next, job);
}

/// Register the new flow with the loss-recovery machinery (reactive
/// transport only).
fn track_unacked(th: &mut TrafficHost, dst: NodeId, pkts: u32) {
    if th.spec.transport.is_on() {
        th.unacked.insert(
            th.flow,
            UnackedFlow {
                dst,
                pkts,
                acked_prefix: 0,
                retries: 0,
            },
        );
    }
}

/// Emit one data packet of the active flow; stamps the transport
/// sequence/total/timestamp fields and arms the RTO when the flow's
/// tail leaves. Returns the wire size.
fn send_data_packet(
    me: NodeId,
    th: &mut TrafficHost,
    ctx: &mut Ctx,
    job: u32,
) -> u64 {
    let mut pkt = Packet::data(PacketKind::Background, me, th.dst);
    pkt.wire_bytes = ctx.cfg.wire_bytes();
    pkt.flow = th.flow;
    let reactive = th.spec.transport.is_on();
    if reactive {
        pkt.counter = th.flow_pkts - th.remaining; // sequence number
        pkt.hosts = th.flow_pkts;
        pkt.meta = ctx.now; // send timestamp (Swift delay base)
    }
    let wire = pkt.wire_bytes as u64;
    ctx.send(0, pkt);
    th.remaining -= 1;
    if reactive && th.remaining == 0 {
        arm_rto(ctx, job, th.flow, 0);
    }
    wire
}

/// Arm (or re-arm) the per-flow retransmission timer, with exponential
/// backoff over the retry rounds.
fn arm_rto(ctx: &mut Ctx, job: u32, flow: u64, retries: u8) {
    let delay = ctx.cfg.transport_rto_ps << (retries.min(4) as u32);
    let timer = crate::host::encode_timer(
        crate::host::TIMER_TRANSPORT_RTO,
        job,
        flow as u32, // low bits: the sender's message counter
        0,
    );
    ctx.host_timer(delay, timer);
}

/// Poisson open loop: flows arrive at `load` of the line rate whatever
/// the fabric does; the NIC drains the backlog at full line rate.
fn open_wake(
    me: NodeId,
    th: &mut TrafficHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    job: u32,
) {
    let payload = ctx.cfg.payload_bytes as u64;
    // calibrate on *wire* occupancy so `load` means the same thing in
    // both injection modes: one flow of mean_pkts full wire packets
    // every mean_gap puts the NIC link at `load` (ceil(B/payload) has
    // mean ~ B/payload + 1/2 for the CDF's smooth sizes)
    let mean_pkts = match th.spec.pattern {
        TrafficPattern::Empirical => {
            cdf::mean_bytes() / payload as f64 + 0.5
        }
        _ => (ctx.cfg.bg_message_bytes.div_ceil(payload)).max(1) as f64,
    };
    let mean_gap = mean_pkts
        * ctx.cfg.wire_bytes() as f64
        * ctx.cfg.link_ps_per_byte as f64
        / th.spec.load.max(1e-9);

    if !th.primed {
        th.primed = true;
        th.next_arrival = ctx.now + cdf::sample_exp(rng, mean_gap);
    }

    // absorb every arrival that is due by now
    while th.next_arrival <= ctx.now {
        let born = th.next_arrival;
        th.next_arrival += cdf::sample_exp(rng, mean_gap);
        let msg = {
            let peers = &ctx.jobs[th.job as usize].spec.participants;
            next_message(
                &th.plan,
                th.spec.pattern,
                rng,
                me,
                peers,
                ctx.cfg.bg_message_bytes,
                payload,
            )
        };
        let Some((dst, pkts)) = msg else { return };
        th.msg_count += 1;
        let flow = flow_id(me, th.msg_count);
        // FCT clock starts at *arrival*, so host queueing counts
        ctx.flow_start(dst, flow, born, pkts, pkts as u64 * payload);
        th.backlog.push_back(PendingFlow { dst, pkts, flow });
    }

    if th.remaining == 0 {
        match th.backlog.pop_front() {
            Some(p) => {
                th.dst = p.dst;
                th.remaining = p.pkts;
                th.flow_pkts = p.pkts;
                th.flow = p.flow;
                track_unacked(th, p.dst, p.pkts);
            }
            None => {
                // idle: sleep until the next arrival
                ctx.wake(th.next_arrival - ctx.now, job);
                return;
            }
        }
    }

    let wire = send_data_packet(me, th, ctx, job);
    // the NIC drains at line rate unless the transport says otherwise
    // (arrivals above stay open-loop: offered load is unaffected)
    let mut next = wire * ctx.cfg.link_ps_per_byte;
    if th.spec.transport.is_on() {
        th.cc.maybe_increase(ctx.now);
        next = th.cc.stretch(next);
    }
    ctx.wake(next, job);
}

/// Delivery at a traffic host: data packets are accounted toward their
/// flow's completion (FCT is recorded when the last packet lands);
/// transport ACK/CNP control frames feed the sender-side state. Takes
/// ownership of the arena entry — traffic hosts are sinks.
pub fn on_packet(
    me: NodeId,
    th: &mut TrafficHost,
    ctx: &mut Ctx,
    pid: PacketId,
) {
    let pkt = ctx.take(pid);
    match pkt.kind {
        PacketKind::Background => on_data(me, th, ctx, pkt),
        PacketKind::TransportAck => on_ack(th, ctx, pkt),
        PacketKind::TransportCnp => on_cnp(th, ctx, pkt),
        _ => {}
    }
}

/// Sink-side data path. Without a transport this is the legacy
/// unconditional accounting; with one, the sink deduplicates
/// retransmitted copies, echoes congestion feedback (CNPs for CE marks
/// under DCQCN, max one-way delay on ACKs for Swift) and sends
/// cumulative ACKs every [`transport::ACK_EVERY`] packets plus a final
/// ACK on completion.
fn on_data(me: NodeId, th: &mut TrafficHost, ctx: &mut Ctx, pkt: Packet) {
    let payload = pkt
        .wire_bytes
        .saturating_sub(crate::sim::packet::HEADER_OVERHEAD_BYTES)
        as u64;
    let now = ctx.now;
    let tp = th.spec.transport;
    if !tp.is_on() {
        ctx.metrics.flows.on_delivery(pkt.flow, now, payload);
        return;
    }
    // amortized eviction of stale flow entries — the sink-side twin of
    // the flowlet-table sweep: an entry idle past the sender's longest
    // possible retry chain can never see another packet, so dropping
    // it only bounds the table (long open-loop runs would otherwise
    // leak one entry per flow ever received)
    th.since_sink_sweep += 1;
    if th.since_sink_sweep >= transport::SINK_SWEEP_EVERY {
        th.since_sink_sweep = 0;
        let horizon = transport::SINK_EVICT_RTOS * ctx.cfg.transport_rto_ps;
        // lint: allow(unordered-iter, pure idle-cutoff predicate; no per-entry side effects)
        th.sinks
            .retain(|_, f| now.saturating_sub(f.last_seen_ps) <= horizon);
    }
    let total = pkt.hosts.max(1);
    let sf = th
        .sinks
        .entry(pkt.flow)
        .or_insert_with(|| SinkFlow::new(total));
    sf.last_seen_ps = now;
    // congestion feedback first — it applies to duplicates too (a
    // retransmitted copy that crossed a hot queue is still a signal)
    if pkt.ecn {
        ctx.metrics.flows.ecn_delivered += 1;
        let cnp_due = sf.last_cnp_ps == 0
            || now.saturating_sub(sf.last_cnp_ps)
                >= transport::CNP_INTERVAL_PS;
        if tp == TransportSpec::Dcqcn && cnp_due {
            sf.last_cnp_ps = now;
            ctx.metrics.flows.cnps_sent += 1;
            send_ctrl(ctx, PacketKind::TransportCnp, me, pkt.src, pkt.flow, 0, 0);
        }
    }
    if tp == TransportSpec::Swift {
        sf.max_delay_ps = sf.max_delay_ps.max(now.saturating_sub(pkt.meta));
    }
    if sf.done || !sf.record(pkt.counter) {
        // duplicate of an already-delivered sequence: count the wire
        // cost, never the goodput
        ctx.metrics.flows.dup_pkts += 1;
        ctx.metrics.flows.dup_bytes += payload;
        // a duplicate means the sender's cumulative prefix is stale
        // (lost ACKs — the final one, or enough running ones that its
        // go-back-N window is behind the sink). Re-ACK the current
        // prefix, throttled per flow so one retransmission round
        // elicits one refresh, not one frame per duplicate; echo the
        // real delay sample so a Swift sender doesn't read a healthy
        // fabric out of a loss episode.
        let reack_due = sf.last_reack_ps == 0
            || now.saturating_sub(sf.last_reack_ps)
                >= transport::CNP_INTERVAL_PS;
        if reack_due {
            sf.last_reack_ps = now;
            let (counter, delay) = (
                if sf.done { sf.total } else { sf.prefix },
                sf.max_delay_ps,
            );
            send_ctrl(ctx, PacketKind::TransportAck, me, pkt.src, pkt.flow, counter, delay);
        }
        return;
    }
    ctx.metrics.flows.on_delivery(pkt.flow, now, payload);
    sf.since_ack += 1;
    if sf.done || sf.since_ack >= transport::ACK_EVERY {
        let (prefix, delay) = (
            if sf.done { sf.total } else { sf.prefix },
            sf.max_delay_ps,
        );
        sf.since_ack = 0;
        sf.max_delay_ps = 0;
        send_ctrl(ctx, PacketKind::TransportAck, me, pkt.src, pkt.flow, prefix, delay);
    }
}

/// Sender-side ACK path: advance the acked prefix, retire completed
/// flows, feed the Swift delay sample.
fn on_ack(th: &mut TrafficHost, ctx: &mut Ctx, pkt: Packet) {
    ctx.metrics.flows.acks_received += 1;
    if th.spec.transport == TransportSpec::Swift {
        th.cc.on_delay(ctx.now, pkt.meta);
    }
    let fully_acked = match th.unacked.get_mut(&pkt.flow) {
        Some(u) => {
            u.acked_prefix = u.acked_prefix.max(pkt.counter);
            u.acked_prefix >= u.pkts
        }
        None => false, // late ACK for a completed/abandoned flow
    };
    if fully_acked {
        th.unacked.remove(&pkt.flow);
    }
}

/// Sender-side CNP path (DCQCN reaction point).
fn on_cnp(th: &mut TrafficHost, ctx: &mut Ctx, _pkt: Packet) {
    ctx.metrics.flows.cnps_received += 1;
    th.cc.on_cnp(ctx.now);
}

/// Header-only transport control frame (ACK or CNP).
fn send_ctrl(
    ctx: &mut Ctx,
    kind: PacketKind,
    me: NodeId,
    dst: NodeId,
    flow: u64,
    counter: u32,
    delay: Time,
) {
    let mut pkt = Packet::data(kind, me, dst);
    pkt.wire_bytes = transport::CTRL_WIRE_BYTES;
    pkt.flow = flow;
    pkt.counter = counter;
    pkt.meta = delay;
    ctx.send(0, pkt);
}

/// RTO timer: go-back-N retransmission of the unacked suffix, with a
/// bounded retry budget. A timer whose flow has since been fully acked
/// is a no-op (timers cannot be cancelled).
pub fn on_timer(
    me: NodeId,
    th: &mut TrafficHost,
    ctx: &mut Ctx,
    timer: u64,
) {
    let (kind, job, flow_low, _aux) = crate::host::decode_timer(timer);
    if kind != crate::host::TIMER_TRANSPORT_RTO {
        return;
    }
    let flow = ((me as u64) << 32) | flow_low as u64;
    let (dst, pkts, from, prev_retries) = match th.unacked.get(&flow) {
        Some(u) => (u.dst, u.pkts, u.acked_prefix, u.retries),
        None => return, // fully acked since the timer was armed
    };
    if prev_retries >= transport::MAX_FLOW_RETRIES {
        th.unacked.remove(&flow);
        ctx.metrics.flows.abandoned += 1;
        return;
    }
    let retries = prev_retries + 1;
    if let Some(u) = th.unacked.get_mut(&flow) {
        u.retries = retries;
    }
    ctx.metrics.flows.rto_fired += 1;
    // windowed go-back-N: one round resends at most
    // RETRANS_WINDOW_PKTS from the acked prefix — a burst that fits
    // the class-1 policer share, so recovery cannot self-drop at the
    // sender's own first hop; longer gaps advance over later rounds as
    // the cumulative ACK moves
    let to = pkts.min(from + transport::RETRANS_WINDOW_PKTS);
    for seq in from..to {
        let mut pkt = Packet::data(PacketKind::Background, me, dst);
        pkt.wire_bytes = ctx.cfg.wire_bytes();
        pkt.flow = flow;
        pkt.counter = seq;
        pkt.hosts = pkts;
        pkt.meta = ctx.now;
        ctx.send(0, pkt);
        ctx.metrics.flows.retrans_pkts += 1;
    }
    arm_rto(ctx, job, flow, retries);
}

/// Resolve one [`DstPlan`] per host for `spec`. `hosts` must be sorted
/// ascending (the workload builder's background set is). The `uniform`
/// pattern draws nothing from `rng`, which keeps legacy runs
/// bit-identical.
pub fn build_plans(
    spec: &TrafficSpec,
    hosts: &[NodeId],
    rng: &mut Rng,
) -> Vec<DstPlan> {
    debug_assert!(
        hosts.windows(2).all(|w| w[0] < w[1]),
        "background host set must be sorted"
    );
    let n = hosts.len();
    let pos = |h: NodeId, plans: &mut [DstPlan], plan: DstPlan| {
        let i = hosts.binary_search(&h).expect("host in background set");
        plans[i] = plan;
    };
    match spec.pattern {
        TrafficPattern::Uniform | TrafficPattern::Empirical => {
            vec![DstPlan::Uniform; n]
        }
        TrafficPattern::Permutation => {
            let mut order = hosts.to_vec();
            rng.shuffle(&mut order);
            let mut plans = vec![DstPlan::Uniform; n];
            for i in 0..n {
                pos(order[i], &mut plans, DstPlan::Fixed(order[(i + 1) % n]));
            }
            plans
        }
        TrafficPattern::Incast { fan_in } => {
            let mut order = hosts.to_vec();
            rng.shuffle(&mut order);
            // groups of fan_in+1: first member sinks, the rest stream
            // at it; a trailing singleton just sinks
            let mut plans = vec![DstPlan::Sink; n];
            for chunk in order.chunks(fan_in as usize + 1) {
                let sink = chunk[0];
                for &m in &chunk[1..] {
                    pos(m, &mut plans, DstPlan::Fixed(sink));
                }
            }
            plans
        }
        TrafficPattern::Hotspot { k, skew } => {
            let k = (k as usize).min(n);
            let hot: Vec<NodeId> = rng
                .sample_indices(n, k)
                .into_iter()
                .map(|i| hosts[i])
                .collect();
            (0..n)
                .map(|_| DstPlan::Hotspot {
                    hot: hot.clone(),
                    skew,
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::TrafficSpec;

    #[test]
    fn pace_is_identity_at_full_load() {
        assert_eq!(pace(86_480, 1.0), 86_480);
        assert_eq!(pace(100, 0.5), 200);
        assert_eq!(pace(100, 0.3), 334); // ceil(100/0.3)
    }

    #[test]
    fn flow_ids_match_legacy_encoding() {
        assert_eq!(flow_id(3, 7), (3u64 << 32) | 7);
    }

    #[test]
    fn permutation_plans_form_a_cycle() {
        let hosts: Vec<NodeId> = (10..26).collect();
        let mut rng = Rng::new(5);
        let plans =
            build_plans(&TrafficSpec::permutation(), &hosts, &mut rng);
        let mut dsts: Vec<NodeId> = plans
            .iter()
            .zip(&hosts)
            .map(|(p, &h)| match p {
                DstPlan::Fixed(d) => {
                    assert_ne!(*d, h, "no self-loops");
                    *d
                }
                other => panic!("expected Fixed, got {other:?}"),
            })
            .collect();
        dsts.sort_unstable();
        assert_eq!(dsts, hosts, "every host receives exactly once");
    }

    #[test]
    fn incast_plans_group_senders_on_sinks() {
        let hosts: Vec<NodeId> = (0..20).collect();
        let mut rng = Rng::new(6);
        let plans = build_plans(&TrafficSpec::incast(4), &hosts, &mut rng);
        let sinks: Vec<NodeId> = plans
            .iter()
            .zip(&hosts)
            .filter(|(p, _)| matches!(p, DstPlan::Sink))
            .map(|(_, &h)| h)
            .collect();
        assert_eq!(sinks.len(), 4, "20 hosts / groups of 5 = 4 sinks");
        for (p, &h) in plans.iter().zip(&hosts) {
            if let DstPlan::Fixed(d) = p {
                assert!(sinks.contains(d), "sender {h} targets a sink");
                assert_ne!(*d, h);
            }
        }
    }

    #[test]
    fn hotspot_plans_share_one_hot_set() {
        let hosts: Vec<NodeId> = (0..32).collect();
        let mut rng = Rng::new(7);
        let plans =
            build_plans(&TrafficSpec::hotspot(3, 0.9), &hosts, &mut rng);
        let DstPlan::Hotspot { hot, skew } = &plans[0] else {
            panic!("expected hotspot plan");
        };
        assert_eq!(hot.len(), 3);
        assert_eq!(*skew, 0.9);
        for p in &plans {
            let DstPlan::Hotspot { hot: h, .. } = p else {
                panic!("expected hotspot plan");
            };
            assert_eq!(h, hot, "all hosts aim at the same hot set");
        }
    }

    #[test]
    fn uniform_plans_draw_nothing_from_the_rng() {
        let hosts: Vec<NodeId> = (0..8).collect();
        let mut a = Rng::new(3);
        let mut b = Rng::new(3);
        build_plans(&TrafficSpec::uniform(), &hosts, &mut a);
        build_plans(&TrafficSpec::empirical(), &hosts, &mut b);
        // both leave the RNG untouched => identical next draws
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn sink_never_draws_a_destination() {
        let mut rng = Rng::new(1);
        assert!(draw_dst(&DstPlan::Sink, &mut rng, 0, &[0, 1, 2]).is_none());
    }

    #[test]
    fn hotspot_self_only_falls_back_to_uniform() {
        // host 5 is the single hot target: it must still pick peers
        let plan = DstPlan::Hotspot {
            hot: vec![5],
            skew: 1.0,
        };
        let peers = [4, 5, 6];
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let d = draw_dst(&plan, &mut rng, 5, &peers).unwrap();
            assert_ne!(d, 5);
        }
    }
}
