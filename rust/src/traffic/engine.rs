//! Per-host traffic state machine: destination plans, closed/open-loop
//! injection and flow lifecycle bookkeeping.
//!
//! The closed-loop `uniform` path is **bit-compatible** with the legacy
//! `host/background.rs` generator: it performs the same RNG draws in the
//! same order, emits identical packets (src/dst/flow/wire size) and
//! schedules the same wake cadence at `load = 1.0`, so every recorded
//! figure series is unchanged under the default pattern
//! (`tests/traffic_engine.rs` pins this against an inlined replica of
//! the legacy state machine).

use std::collections::VecDeque;

use crate::sim::packet::{Packet, PacketKind};
use crate::sim::{Ctx, NodeId, Time};
use crate::util::rng::Rng;

use super::cdf;
use super::{Injection, TrafficPattern, TrafficSpec};

/// Resolved per-host destination law (computed once at install time by
/// [`build_plans`], so the wake path never re-derives group structure).
#[derive(Clone, Debug)]
pub enum DstPlan {
    /// Fresh uniform-random peer per message (legacy behavior).
    Uniform,
    /// Fixed partner: permutation cycles and incast senders.
    Fixed(NodeId),
    /// With probability `skew` pick one of the `hot` hosts, else a
    /// uniform-random peer.
    Hotspot { hot: Vec<NodeId>, skew: f64 },
    /// Generates nothing (incast sinks; they only absorb).
    Sink,
}

/// A flow that has arrived (open loop) but not started transmitting.
#[derive(Clone, Debug)]
pub struct PendingFlow {
    pub dst: NodeId,
    pub pkts: u32,
    pub flow: u64,
}

/// Traffic-generator state for one host.
pub struct TrafficHost {
    pub job: u32,
    pub spec: TrafficSpec,
    pub plan: DstPlan,
    /// Packets left in the flow currently on the wire.
    pub remaining: u32,
    pub dst: NodeId,
    /// Messages/flows generated so far (also the flow-id low bits).
    pub msg_count: u64,
    /// Flow id of the active flow.
    pub flow: u64,
    /// Open loop: next Poisson arrival instant (valid once `primed`).
    pub next_arrival: Time,
    /// Open loop: arrived flows waiting for the NIC.
    pub backlog: VecDeque<PendingFlow>,
    primed: bool,
}

impl TrafficHost {
    pub fn new(job: u32, spec: TrafficSpec, plan: DstPlan) -> TrafficHost {
        TrafficHost {
            job,
            spec,
            plan,
            remaining: 0,
            dst: 0,
            msg_count: 0,
            flow: 0,
            next_arrival: 0,
            backlog: VecDeque::new(),
            primed: false,
        }
    }
}

/// Flow label carried by every packet of a message: unique per
/// (host, message) — the same encoding the legacy generator used.
#[inline]
pub fn flow_id(me: NodeId, msg_count: u64) -> u64 {
    ((me as u64) << 32) | msg_count
}

/// Stretch the line-rate serialization gap to the offered load.
/// `load = 1.0` returns `base_ps` exactly (legacy cadence).
#[inline]
pub fn pace(base_ps: u64, load: f64) -> u64 {
    if load >= 1.0 {
        base_ps
    } else {
        ((base_ps as f64) / load.max(1e-9)).ceil() as u64
    }
}

/// Draw the next destination under `plan`, or `None` if this host
/// cannot generate (sink, or fewer than two peers).
fn draw_dst(
    plan: &DstPlan,
    rng: &mut Rng,
    me: NodeId,
    peers: &[NodeId],
) -> Option<NodeId> {
    let uniform = |rng: &mut Rng| -> Option<NodeId> {
        if peers.len() < 2 {
            return None;
        }
        loop {
            let cand = *rng.choose(peers);
            if cand != me {
                return Some(cand);
            }
        }
    };
    match plan {
        DstPlan::Sink => None,
        DstPlan::Fixed(d) => Some(*d),
        DstPlan::Uniform => uniform(rng),
        DstPlan::Hotspot { hot, skew } => {
            if peers.len() < 2 {
                return None;
            }
            // a host that is itself the only hot target falls back to
            // the uniform tail instead of spinning
            let hot_usable = !hot.is_empty() && !(hot.len() == 1 && hot[0] == me);
            if rng.chance(*skew) && hot_usable {
                loop {
                    let cand = hot[rng.index(hot.len())];
                    if cand != me {
                        return Some(cand);
                    }
                }
            } else {
                uniform(rng)
            }
        }
    }
}

/// Draw the next message (destination, packet count) — shared by both
/// injection modes. Pure in everything but the RNG, so the
/// bit-compatibility test can drive it against the legacy state machine
/// directly.
pub fn next_message(
    plan: &DstPlan,
    pattern: TrafficPattern,
    rng: &mut Rng,
    me: NodeId,
    peers: &[NodeId],
    bg_message_bytes: u64,
    payload_bytes: u64,
) -> Option<(NodeId, u32)> {
    let dst = draw_dst(plan, rng, me, peers)?;
    let bytes = match pattern {
        TrafficPattern::Empirical => cdf::sample_bytes(rng),
        _ => bg_message_bytes,
    };
    Some((dst, (bytes.div_ceil(payload_bytes)).max(1) as u32))
}

/// Wake entry point (scheduled by `kick_jobs` at t=0 and self-clocked
/// afterwards).
pub fn on_wake(
    me: NodeId,
    th: &mut TrafficHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    job: u32,
) {
    if matches!(th.plan, DstPlan::Sink) {
        return;
    }
    match th.spec.injection {
        Injection::Closed => closed_wake(me, th, rng, ctx, job),
        Injection::Open => open_wake(me, th, rng, ctx, job),
    }
}

/// Self-clocked stream: one packet per (load-stretched) serialization
/// interval; a new message is drawn whenever the previous one ends.
fn closed_wake(
    me: NodeId,
    th: &mut TrafficHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    job: u32,
) {
    let payload = ctx.cfg.payload_bytes as u64;
    if th.remaining == 0 {
        let msg = {
            let peers = &ctx.jobs[th.job as usize].spec.participants;
            next_message(
                &th.plan,
                th.spec.pattern,
                rng,
                me,
                peers,
                ctx.cfg.bg_message_bytes,
                payload,
            )
        };
        let Some((dst, pkts)) = msg else { return };
        th.dst = dst;
        th.remaining = pkts;
        th.msg_count += 1;
        th.flow = flow_id(me, th.msg_count);
        let now = ctx.now;
        ctx.metrics.flows.on_start(
            th.flow,
            now,
            pkts,
            pkts as u64 * payload,
        );
    }

    let mut pkt = Packet::data(PacketKind::Background, me, th.dst);
    pkt.wire_bytes = ctx.cfg.wire_bytes();
    pkt.flow = th.flow;
    let wire = pkt.wire_bytes as u64;
    ctx.send(0, pkt);
    th.remaining -= 1;

    let next = pace(wire * ctx.cfg.link_ps_per_byte, th.spec.load);
    ctx.wake(next, job);
}

/// Poisson open loop: flows arrive at `load` of the line rate whatever
/// the fabric does; the NIC drains the backlog at full line rate.
fn open_wake(
    me: NodeId,
    th: &mut TrafficHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    job: u32,
) {
    let payload = ctx.cfg.payload_bytes as u64;
    // calibrate on *wire* occupancy so `load` means the same thing in
    // both injection modes: one flow of mean_pkts full wire packets
    // every mean_gap puts the NIC link at `load` (ceil(B/payload) has
    // mean ~ B/payload + 1/2 for the CDF's smooth sizes)
    let mean_pkts = match th.spec.pattern {
        TrafficPattern::Empirical => {
            cdf::mean_bytes() / payload as f64 + 0.5
        }
        _ => (ctx.cfg.bg_message_bytes.div_ceil(payload)).max(1) as f64,
    };
    let mean_gap = mean_pkts
        * ctx.cfg.wire_bytes() as f64
        * ctx.cfg.link_ps_per_byte as f64
        / th.spec.load.max(1e-9);

    if !th.primed {
        th.primed = true;
        th.next_arrival = ctx.now + cdf::sample_exp(rng, mean_gap);
    }

    // absorb every arrival that is due by now
    while th.next_arrival <= ctx.now {
        let born = th.next_arrival;
        th.next_arrival += cdf::sample_exp(rng, mean_gap);
        let msg = {
            let peers = &ctx.jobs[th.job as usize].spec.participants;
            next_message(
                &th.plan,
                th.spec.pattern,
                rng,
                me,
                peers,
                ctx.cfg.bg_message_bytes,
                payload,
            )
        };
        let Some((dst, pkts)) = msg else { return };
        th.msg_count += 1;
        let flow = flow_id(me, th.msg_count);
        // FCT clock starts at *arrival*, so host queueing counts
        ctx.metrics.flows.on_start(flow, born, pkts, pkts as u64 * payload);
        th.backlog.push_back(PendingFlow { dst, pkts, flow });
    }

    if th.remaining == 0 {
        match th.backlog.pop_front() {
            Some(p) => {
                th.dst = p.dst;
                th.remaining = p.pkts;
                th.flow = p.flow;
            }
            None => {
                // idle: sleep until the next arrival
                ctx.wake(th.next_arrival - ctx.now, job);
                return;
            }
        }
    }

    let mut pkt = Packet::data(PacketKind::Background, me, th.dst);
    pkt.wire_bytes = ctx.cfg.wire_bytes();
    pkt.flow = th.flow;
    let wire = pkt.wire_bytes as u64;
    ctx.send(0, pkt);
    th.remaining -= 1;
    ctx.wake(wire * ctx.cfg.link_ps_per_byte, job);
}

/// Delivery at a traffic sink: account the packet toward its flow's
/// completion (FCT is recorded when the last packet lands).
pub fn on_packet(
    _me: NodeId,
    _th: &mut TrafficHost,
    ctx: &mut Ctx,
    pkt: Packet,
) {
    let payload = pkt
        .wire_bytes
        .saturating_sub(crate::sim::packet::HEADER_OVERHEAD_BYTES)
        as u64;
    let now = ctx.now;
    ctx.metrics.flows.on_delivery(pkt.flow, now, payload);
}

/// Resolve one [`DstPlan`] per host for `spec`. `hosts` must be sorted
/// ascending (the workload builder's background set is). The `uniform`
/// pattern draws nothing from `rng`, which keeps legacy runs
/// bit-identical.
pub fn build_plans(
    spec: &TrafficSpec,
    hosts: &[NodeId],
    rng: &mut Rng,
) -> Vec<DstPlan> {
    debug_assert!(
        hosts.windows(2).all(|w| w[0] < w[1]),
        "background host set must be sorted"
    );
    let n = hosts.len();
    let pos = |h: NodeId, plans: &mut [DstPlan], plan: DstPlan| {
        let i = hosts.binary_search(&h).expect("host in background set");
        plans[i] = plan;
    };
    match spec.pattern {
        TrafficPattern::Uniform | TrafficPattern::Empirical => {
            vec![DstPlan::Uniform; n]
        }
        TrafficPattern::Permutation => {
            let mut order = hosts.to_vec();
            rng.shuffle(&mut order);
            let mut plans = vec![DstPlan::Uniform; n];
            for i in 0..n {
                pos(order[i], &mut plans, DstPlan::Fixed(order[(i + 1) % n]));
            }
            plans
        }
        TrafficPattern::Incast { fan_in } => {
            let mut order = hosts.to_vec();
            rng.shuffle(&mut order);
            // groups of fan_in+1: first member sinks, the rest stream
            // at it; a trailing singleton just sinks
            let mut plans = vec![DstPlan::Sink; n];
            for chunk in order.chunks(fan_in as usize + 1) {
                let sink = chunk[0];
                for &m in &chunk[1..] {
                    pos(m, &mut plans, DstPlan::Fixed(sink));
                }
            }
            plans
        }
        TrafficPattern::Hotspot { k, skew } => {
            let k = (k as usize).min(n);
            let hot: Vec<NodeId> = rng
                .sample_indices(n, k)
                .into_iter()
                .map(|i| hosts[i])
                .collect();
            (0..n)
                .map(|_| DstPlan::Hotspot {
                    hot: hot.clone(),
                    skew,
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::TrafficSpec;

    #[test]
    fn pace_is_identity_at_full_load() {
        assert_eq!(pace(86_480, 1.0), 86_480);
        assert_eq!(pace(100, 0.5), 200);
        assert_eq!(pace(100, 0.3), 334); // ceil(100/0.3)
    }

    #[test]
    fn flow_ids_match_legacy_encoding() {
        assert_eq!(flow_id(3, 7), (3u64 << 32) | 7);
    }

    #[test]
    fn permutation_plans_form_a_cycle() {
        let hosts: Vec<NodeId> = (10..26).collect();
        let mut rng = Rng::new(5);
        let plans =
            build_plans(&TrafficSpec::permutation(), &hosts, &mut rng);
        let mut dsts: Vec<NodeId> = plans
            .iter()
            .zip(&hosts)
            .map(|(p, &h)| match p {
                DstPlan::Fixed(d) => {
                    assert_ne!(*d, h, "no self-loops");
                    *d
                }
                other => panic!("expected Fixed, got {other:?}"),
            })
            .collect();
        dsts.sort_unstable();
        assert_eq!(dsts, hosts, "every host receives exactly once");
    }

    #[test]
    fn incast_plans_group_senders_on_sinks() {
        let hosts: Vec<NodeId> = (0..20).collect();
        let mut rng = Rng::new(6);
        let plans = build_plans(&TrafficSpec::incast(4), &hosts, &mut rng);
        let sinks: Vec<NodeId> = plans
            .iter()
            .zip(&hosts)
            .filter(|(p, _)| matches!(p, DstPlan::Sink))
            .map(|(_, &h)| h)
            .collect();
        assert_eq!(sinks.len(), 4, "20 hosts / groups of 5 = 4 sinks");
        for (p, &h) in plans.iter().zip(&hosts) {
            if let DstPlan::Fixed(d) = p {
                assert!(sinks.contains(d), "sender {h} targets a sink");
                assert_ne!(*d, h);
            }
        }
    }

    #[test]
    fn hotspot_plans_share_one_hot_set() {
        let hosts: Vec<NodeId> = (0..32).collect();
        let mut rng = Rng::new(7);
        let plans =
            build_plans(&TrafficSpec::hotspot(3, 0.9), &hosts, &mut rng);
        let DstPlan::Hotspot { hot, skew } = &plans[0] else {
            panic!("expected hotspot plan");
        };
        assert_eq!(hot.len(), 3);
        assert_eq!(*skew, 0.9);
        for p in &plans {
            let DstPlan::Hotspot { hot: h, .. } = p else {
                panic!("expected hotspot plan");
            };
            assert_eq!(h, hot, "all hosts aim at the same hot set");
        }
    }

    #[test]
    fn uniform_plans_draw_nothing_from_the_rng() {
        let hosts: Vec<NodeId> = (0..8).collect();
        let mut a = Rng::new(3);
        let mut b = Rng::new(3);
        build_plans(&TrafficSpec::uniform(), &hosts, &mut a);
        build_plans(&TrafficSpec::empirical(), &hosts, &mut b);
        // both leave the RNG untouched => identical next draws
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn sink_never_draws_a_destination() {
        let mut rng = Rng::new(1);
        assert!(draw_dst(&DstPlan::Sink, &mut rng, 0, &[0, 1, 2]).is_none());
    }

    #[test]
    fn hotspot_self_only_falls_back_to_uniform() {
        // host 5 is the single hot target: it must still pick peers
        let plan = DstPlan::Hotspot {
            hot: vec![5],
            skew: 1.0,
        };
        let peers = [4, 5, 6];
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let d = draw_dst(&plan, &mut rng, 5, &peers).unwrap();
            assert_ne!(d, 5);
        }
    }
}
