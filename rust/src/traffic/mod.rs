//! Flow-level traffic engine: adversarial cross-traffic patterns for the
//! congestion experiments.
//!
//! The paper evaluates Canary against exactly one congestion shape — a
//! random-uniform line-rate stream from every non-participant host
//! (Section 5.2). That shape is gentle: load spreads evenly, so any
//! adaptive scheme looks good. The patterns congestion-aware in-network
//! computing actually has to survive are skewed and bursty (incast fan-in,
//! hot services, heavy-tailed flow sizes — Segal et al., De Sensi et al.
//! *Flare*). This module makes the congestion generator a first-class,
//! pluggable subsystem:
//!
//! - [`TrafficPattern`] — destination/size laws: `uniform` (the paper's
//!   stream, bit-compatible with the legacy generator), `permutation`
//!   (fixed random one-to-one pairing), `incast` (groups of `fan_in`
//!   senders pounding one sink), `hotspot` (a skewed share of all traffic
//!   aimed at `k` hot hosts), and `empirical` (flow sizes drawn from a
//!   bundled web-search-style CDF, [`cdf`]).
//! - [`Injection`] — closed-loop (self-clocked stream: the next message
//!   starts when the previous one finished serializing, paced to `load`)
//!   vs open-loop (flows arrive by a Poisson process at `load` of the
//!   NIC rate regardless of drain progress, so queues can actually grow).
//! - Per-flow lifecycle tracking with flow-completion-time percentiles,
//!   surfaced through `metrics::FlowStats` and the `figures` harness
//!   (`figures traffic`).
//!
//! The per-host state machine lives in [`engine`] and plugs into the
//! host layer as [`crate::host::Proto::Background`]; the bit-compat pin
//! against the retired `host/background.rs` generator lives in
//! `tests/traffic_engine.rs`.

pub mod cdf;
pub mod engine;

pub use engine::{DstPlan, TrafficHost};

use crate::transport::TransportSpec;

/// Destination/size law of the generated cross traffic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TrafficPattern {
    /// Fresh uniform-random destination per message (paper Section 5.2).
    Uniform,
    /// Fixed random one-to-one pairing: every host streams to a single
    /// partner (a permutation cycle), the classic worst case for
    /// oblivious routing.
    Permutation,
    /// Groups of `fan_in` senders all stream to one sink host.
    Incast { fan_in: u32 },
    /// A `skew` fraction of all messages targets `k` hot hosts; the
    /// rest is uniform.
    Hotspot { k: u32, skew: f64 },
    /// Flow sizes drawn from the bundled heavy-tailed web-search CDF
    /// ([`cdf::WEB_SEARCH_CDF`]); destinations uniform.
    Empirical,
}

/// How flows are injected relative to the NIC drain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Injection {
    /// Self-clocked stream: the next packet is scheduled when the
    /// previous one finished serializing, with the gap stretched by
    /// `1/load`. The legacy background generator is `Closed` at
    /// `load = 1.0`.
    Closed,
    /// Poisson flow arrivals at `load` of the NIC line rate,
    /// independent of drain progress; pending flows queue at the host
    /// and FCT includes that queueing delay.
    Open,
}

/// Full cross-traffic specification carried by a
/// [`crate::workload::ScenarioBuilder`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrafficSpec {
    pub pattern: TrafficPattern,
    /// Offered load as a fraction of the NIC line rate, in `(0, 1]`.
    pub load: f64,
    pub injection: Injection,
    /// Reactive transport governing the background senders
    /// ([`crate::transport`]): `None` (the default) is bit-identical to
    /// the unreactive legacy generator; `Dcqcn`/`Swift` turn on ECN
    /// marking, rate control and loss recovery.
    pub transport: TransportSpec,
    /// Optional overrides of the fabric's ECN marking ramp
    /// ([`crate::config::SimConfig::ecn_kmin_bytes`]/`ecn_kmax_bytes`),
    /// applied by the scenario builder when `transport` is on.
    pub ecn_kmin: Option<u64>,
    pub ecn_kmax: Option<u64>,
}

impl Default for TrafficSpec {
    /// The paper's congestion generator (random-uniform, line rate).
    fn default() -> Self {
        TrafficSpec::uniform()
    }
}

impl TrafficSpec {
    /// The paper's Section 5.2 stream: random-uniform destinations at
    /// line rate, closed-loop. Bit-compatible with the legacy
    /// `host/background.rs` generator (`tests/traffic_engine.rs`).
    pub fn uniform() -> Self {
        TrafficSpec {
            pattern: TrafficPattern::Uniform,
            load: 1.0,
            injection: Injection::Closed,
            transport: TransportSpec::None,
            ecn_kmin: None,
            ecn_kmax: None,
        }
    }

    pub fn permutation() -> Self {
        TrafficSpec {
            pattern: TrafficPattern::Permutation,
            load: 1.0,
            injection: Injection::Closed,
            transport: TransportSpec::None,
            ecn_kmin: None,
            ecn_kmax: None,
        }
    }

    pub fn incast(fan_in: u32) -> Self {
        TrafficSpec {
            pattern: TrafficPattern::Incast { fan_in },
            load: 1.0,
            injection: Injection::Closed,
            transport: TransportSpec::None,
            ecn_kmin: None,
            ecn_kmax: None,
        }
    }

    pub fn hotspot(k: u32, skew: f64) -> Self {
        TrafficSpec {
            pattern: TrafficPattern::Hotspot { k, skew },
            load: 1.0,
            injection: Injection::Closed,
            transport: TransportSpec::None,
            ecn_kmin: None,
            ecn_kmax: None,
        }
    }

    /// Heavy-tailed flow sizes with Poisson open-loop arrivals at 60 %
    /// load — the datacenter-trace-style workload.
    pub fn empirical() -> Self {
        TrafficSpec {
            pattern: TrafficPattern::Empirical,
            load: 0.6,
            injection: Injection::Open,
            transport: TransportSpec::None,
            ecn_kmin: None,
            ecn_kmax: None,
        }
    }

    pub fn with_load(mut self, load: f64) -> Self {
        self.load = load;
        self
    }

    pub fn open(mut self) -> Self {
        self.injection = Injection::Open;
        self
    }

    pub fn closed(mut self) -> Self {
        self.injection = Injection::Closed;
        self
    }

    /// Run the background senders under a reactive transport
    /// ([`crate::transport`]).
    pub fn with_transport(mut self, t: TransportSpec) -> Self {
        self.transport = t;
        self
    }

    /// Override the fabric's ECN marking ramp (bytes of class-1
    /// backlog; applied only when a transport is on).
    pub fn with_ecn(mut self, kmin: u64, kmax: u64) -> Self {
        self.ecn_kmin = Some(kmin);
        self.ecn_kmax = Some(kmax);
        self
    }

    /// Short pattern tag for CSV cells and log lines (`incast:8`,
    /// `hotspot:4:0.90`, ...).
    pub fn name(&self) -> String {
        match self.pattern {
            TrafficPattern::Uniform => "uniform".into(),
            TrafficPattern::Permutation => "permutation".into(),
            TrafficPattern::Incast { fan_in } => format!("incast:{fan_in}"),
            TrafficPattern::Hotspot { k, skew } => {
                format!("hotspot:{k}:{skew:.2}")
            }
            TrafficPattern::Empirical => "empirical".into(),
        }
    }

    /// Reject physically meaningless parameters.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.load > 0.0 && self.load <= 1.0) {
            return Err(format!(
                "traffic load must be in (0, 1], got {}",
                self.load
            ));
        }
        if let (Some(kmin), Some(kmax)) = (self.ecn_kmin, self.ecn_kmax) {
            if kmin > kmax {
                return Err(format!(
                    "ECN kmin {kmin} must not exceed kmax {kmax}"
                ));
            }
        }
        if !self.transport.is_on()
            && (self.ecn_kmin.is_some() || self.ecn_kmax.is_some())
        {
            return Err(
                "ECN thresholds are meaningless with transport off".into()
            );
        }
        match self.pattern {
            TrafficPattern::Incast { fan_in } if fan_in == 0 => {
                Err("incast fan_in must be >= 1".into())
            }
            TrafficPattern::Hotspot { k, skew } => {
                if k == 0 {
                    return Err("hotspot k must be >= 1".into());
                }
                if !(0.0..=1.0).contains(&skew) {
                    return Err(format!(
                        "hotspot skew must be in [0, 1], got {skew}"
                    ));
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    /// Parse a CLI pattern string. Grammar:
    ///
    /// ```text
    /// none | off
    /// uniform | permutation | empirical
    /// incast:<fan_in>
    /// hotspot:<k>[:<skew>]            (skew defaults to 0.9)
    /// <pattern>@open | <pattern>@closed
    /// ```
    ///
    /// `Ok(None)` means traffic is off. The offered load is a separate
    /// knob (`--bg-load`, [`TrafficSpec::with_load`]).
    pub fn parse(s: &str) -> Result<Option<TrafficSpec>, String> {
        let (body, injection) = match s.split_once('@') {
            None => (s, None),
            Some((b, "open")) => (b, Some(Injection::Open)),
            Some((b, "closed")) => (b, Some(Injection::Closed)),
            Some((_, other)) => {
                return Err(format!(
                    "bad injection suffix '@{other}' (open|closed)"
                ))
            }
        };
        let mut parts = body.split(':');
        let head = parts.next().unwrap_or("");
        let args: Vec<&str> = parts.collect();
        let want = |n: usize| -> Result<(), String> {
            if args.len() == n {
                Ok(())
            } else {
                Err(format!(
                    "pattern '{head}' takes {n} argument(s), got {}",
                    args.len()
                ))
            }
        };
        let num = |i: usize, what: &str| -> Result<u32, String> {
            args[i]
                .parse::<u32>()
                .map_err(|_| format!("bad {what} '{}'", args[i]))
        };
        let mut spec = match head {
            "none" | "off" => {
                want(0)?;
                if injection.is_some() {
                    return Err("'none' takes no @injection".into());
                }
                return Ok(None);
            }
            "uniform" => {
                want(0)?;
                TrafficSpec::uniform()
            }
            "permutation" => {
                want(0)?;
                TrafficSpec::permutation()
            }
            "incast" => {
                want(1)?;
                TrafficSpec::incast(num(0, "incast fan_in")?)
            }
            "hotspot" => {
                if args.is_empty() || args.len() > 2 {
                    return Err(
                        "hotspot takes <k>[:<skew>] argument(s)".into()
                    );
                }
                let k = num(0, "hotspot k")?;
                let skew = if args.len() == 2 {
                    args[1]
                        .parse::<f64>()
                        .map_err(|_| format!("bad hotspot skew '{}'", args[1]))?
                } else {
                    0.9
                };
                TrafficSpec::hotspot(k, skew)
            }
            "empirical" => {
                want(0)?;
                TrafficSpec::empirical()
            }
            other => {
                return Err(format!(
                    "unknown traffic pattern '{other}' (none|uniform|\
                     permutation|incast:F|hotspot:K[:SKEW]|empirical)"
                ))
            }
        };
        if let Some(i) = injection {
            spec.injection = i;
        }
        spec.validate()?;
        Ok(Some(spec))
    }

    /// Parse a JSON traffic description, e.g.
    /// `{"pattern": "incast", "fan_in": 32, "load": 0.6,
    /// "injection": "open"}`. `{"pattern": "none"}` turns traffic off.
    pub fn from_json(text: &str) -> Result<Option<TrafficSpec>, String> {
        let v = crate::util::json::parse(text)?;
        let pat = v
            .get("pattern")
            .and_then(|p| p.as_str())
            .ok_or("missing string key 'pattern'")?;
        let int_key = |key: &str| -> Result<u32, String> {
            let i = v
                .get(key)
                .and_then(|x| x.as_i64())
                .ok_or_else(|| format!("'{pat}' needs integer key '{key}'"))?;
            u32::try_from(i).map_err(|_| format!("'{key}' out of range: {i}"))
        };
        let mut spec = match pat {
            "none" | "off" => return Ok(None),
            "uniform" => TrafficSpec::uniform(),
            "permutation" => TrafficSpec::permutation(),
            "incast" => TrafficSpec::incast(int_key("fan_in")?),
            "hotspot" => {
                let skew = v
                    .get("skew")
                    .and_then(|x| x.as_f64())
                    .unwrap_or(0.9);
                TrafficSpec::hotspot(int_key("k")?, skew)
            }
            "empirical" => TrafficSpec::empirical(),
            other => return Err(format!("unknown traffic pattern '{other}'")),
        };
        if let Some(load) = v.get("load").and_then(|x| x.as_f64()) {
            spec.load = load;
        }
        match v.get("injection").and_then(|x| x.as_str()) {
            None => {}
            Some("open") => spec.injection = Injection::Open,
            Some("closed") => spec.injection = Injection::Closed,
            Some(other) => {
                return Err(format!(
                    "bad injection '{other}' (open|closed)"
                ))
            }
        }
        if let Some(t) = v.get("transport").and_then(|x| x.as_str()) {
            spec.transport = TransportSpec::parse(t)?;
        }
        let ecn_key = |key: &str| -> Result<Option<u64>, String> {
            match v.get(key) {
                None => Ok(None),
                Some(x) => {
                    let i = x.as_i64().ok_or_else(|| {
                        format!("'{key}' must be an integer byte count")
                    })?;
                    u64::try_from(i)
                        .map(Some)
                        .map_err(|_| format!("'{key}' out of range: {i}"))
                }
            }
        };
        spec.ecn_kmin = ecn_key("ecn_kmin")?;
        spec.ecn_kmax = ecn_key("ecn_kmax")?;
        spec.validate()?;
        Ok(Some(spec))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_patterns() {
        assert_eq!(TrafficSpec::parse("none").unwrap(), None);
        assert_eq!(TrafficSpec::parse("off").unwrap(), None);
        assert_eq!(
            TrafficSpec::parse("uniform").unwrap(),
            Some(TrafficSpec::uniform())
        );
        assert_eq!(
            TrafficSpec::parse("incast:32").unwrap(),
            Some(TrafficSpec::incast(32))
        );
        let h = TrafficSpec::parse("hotspot:4:0.8").unwrap().unwrap();
        assert_eq!(
            h.pattern,
            TrafficPattern::Hotspot { k: 4, skew: 0.8 }
        );
        let h = TrafficSpec::parse("hotspot:4").unwrap().unwrap();
        assert_eq!(
            h.pattern,
            TrafficPattern::Hotspot { k: 4, skew: 0.9 }
        );
        let e = TrafficSpec::parse("empirical").unwrap().unwrap();
        assert_eq!(e.injection, Injection::Open);
    }

    #[test]
    fn parse_injection_suffix() {
        let s = TrafficSpec::parse("permutation@open").unwrap().unwrap();
        assert_eq!(s.injection, Injection::Open);
        let s = TrafficSpec::parse("empirical@closed").unwrap().unwrap();
        assert_eq!(s.injection, Injection::Closed);
        assert!(TrafficSpec::parse("uniform@sideways").is_err());
        assert!(TrafficSpec::parse("none@open").is_err());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(TrafficSpec::parse("blizzard").is_err());
        assert!(TrafficSpec::parse("incast").is_err());
        assert!(TrafficSpec::parse("incast:many").is_err());
        assert!(TrafficSpec::parse("incast:0").is_err());
        assert!(TrafficSpec::parse("hotspot:4:1.5").is_err());
        assert!(TrafficSpec::parse("uniform:3").is_err());
    }

    #[test]
    fn validate_load_bounds() {
        assert!(TrafficSpec::uniform().with_load(0.0).validate().is_err());
        assert!(TrafficSpec::uniform().with_load(1.5).validate().is_err());
        assert!(TrafficSpec::uniform().with_load(0.3).validate().is_ok());
    }

    #[test]
    fn json_round_trip() {
        let s = TrafficSpec::from_json(
            r#"{"pattern": "incast", "fan_in": 8, "load": 0.5,
                "injection": "open"}"#,
        )
        .unwrap()
        .unwrap();
        assert_eq!(s.pattern, TrafficPattern::Incast { fan_in: 8 });
        assert_eq!(s.load, 0.5);
        assert_eq!(s.injection, Injection::Open);
        assert_eq!(
            TrafficSpec::from_json(r#"{"pattern": "none"}"#).unwrap(),
            None
        );
        assert!(TrafficSpec::from_json(r#"{"pattern": "incast"}"#).is_err());
        assert!(
            TrafficSpec::from_json(r#"{"pattern": "uniform", "load": 2}"#)
                .is_err()
        );
        assert!(TrafficSpec::from_json(r#"{"load": 0.5}"#).is_err());
    }

    #[test]
    fn json_transport_keys() {
        let s = TrafficSpec::from_json(
            r#"{"pattern": "incast", "fan_in": 32, "transport": "dcqcn",
                "ecn_kmin": 8192, "ecn_kmax": 32768}"#,
        )
        .unwrap()
        .unwrap();
        assert_eq!(s.transport, TransportSpec::Dcqcn);
        assert_eq!(s.ecn_kmin, Some(8192));
        assert_eq!(s.ecn_kmax, Some(32768));
        let s = TrafficSpec::from_json(
            r#"{"pattern": "uniform", "transport": "swift"}"#,
        )
        .unwrap()
        .unwrap();
        assert_eq!(s.transport, TransportSpec::Swift);
        // garbage transport / inverted ramp / knobs without transport
        assert!(TrafficSpec::from_json(
            r#"{"pattern": "uniform", "transport": "tcp"}"#
        )
        .is_err());
        assert!(TrafficSpec::from_json(
            r#"{"pattern": "uniform", "transport": "dcqcn",
                "ecn_kmin": 9000, "ecn_kmax": 100}"#
        )
        .is_err());
        assert!(TrafficSpec::from_json(
            r#"{"pattern": "uniform", "ecn_kmin": 100}"#
        )
        .is_err());
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(TrafficSpec::uniform().name(), "uniform");
        assert_eq!(TrafficSpec::incast(8).name(), "incast:8");
        assert_eq!(TrafficSpec::hotspot(4, 0.9).name(), "hotspot:4:0.90");
        assert_eq!(TrafficSpec::empirical().name(), "empirical");
    }
}
