//! Bundled empirical flow-size distribution and arrival sampling.
//!
//! The `empirical` traffic pattern draws flow sizes from a web-search /
//! hadoop-style heavy-tailed CDF (the shape popularized by the DCTCP
//! measurement study and reused by most datacenter-transport papers):
//! ~60 % of flows are short queries under 35 KB, but the top 5 % of
//! flows carry most of the bytes. Arrivals are open-loop Poisson, so
//! offered load is independent of how congested the fabric already is.

use crate::sim::Time;
use crate::util::rng::Rng;

/// Piecewise-linear CDF as `(flow_bytes, cumulative_probability)`
/// points; sampling interpolates linearly between consecutive points
/// (and between [`CDF_MIN_BYTES`] and the first point).
pub const WEB_SEARCH_CDF: &[(u64, f64)] = &[
    (6_000, 0.15),
    (13_000, 0.30),
    (19_000, 0.45),
    (33_000, 0.60),
    (53_000, 0.70),
    (133_000, 0.80),
    (667_000, 0.90),
    (1_467_000, 0.95),
    (2_107_000, 0.98),
    (6_667_000, 1.00),
];

/// Smallest flow the distribution produces (one short RPC).
pub const CDF_MIN_BYTES: u64 = 1_000;

/// Inverse-transform sample of the bundled flow-size CDF.
pub fn sample_bytes(rng: &mut Rng) -> u64 {
    let u = rng.f64();
    let mut prev_b = CDF_MIN_BYTES as f64;
    let mut prev_p = 0.0f64;
    for &(bytes, p) in WEB_SEARCH_CDF {
        if u <= p {
            let w = if p > prev_p { (u - prev_p) / (p - prev_p) } else { 0.0 };
            let b = prev_b + w * (bytes as f64 - prev_b);
            return b as u64;
        }
        prev_b = bytes as f64;
        prev_p = p;
    }
    // u in [0,1) and the last point has p = 1.0, so this is unreachable;
    // keep the tail value as a safe fallback.
    WEB_SEARCH_CDF[WEB_SEARCH_CDF.len() - 1].0
}

/// Analytic mean of the piecewise-linear distribution, used to convert
/// an offered load into a Poisson arrival rate.
pub fn mean_bytes() -> f64 {
    let mut mean = 0.0;
    let mut prev_b = CDF_MIN_BYTES as f64;
    let mut prev_p = 0.0f64;
    for &(bytes, p) in WEB_SEARCH_CDF {
        // each linear segment contributes (mass) * (midpoint)
        mean += (p - prev_p) * (prev_b + bytes as f64) / 2.0;
        prev_b = bytes as f64;
        prev_p = p;
    }
    mean
}

/// Exponential inter-arrival sample with the given mean (picoseconds),
/// clamped to at least 1 ps so time always advances.
pub fn sample_exp(rng: &mut Rng, mean_ps: f64) -> Time {
    let u = rng.f64();
    (-(1.0 - u).ln() * mean_ps).max(1.0) as Time
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_is_monotone_and_complete() {
        let mut prev_b = CDF_MIN_BYTES;
        let mut prev_p = 0.0;
        for &(b, p) in WEB_SEARCH_CDF {
            assert!(b > prev_b, "sizes must increase");
            assert!(p > prev_p, "probabilities must increase");
            prev_b = b;
            prev_p = p;
        }
        assert_eq!(prev_p, 1.0, "CDF must end at probability 1");
    }

    #[test]
    fn samples_stay_in_range() {
        let mut rng = Rng::new(42);
        let max = WEB_SEARCH_CDF[WEB_SEARCH_CDF.len() - 1].0;
        for _ in 0..10_000 {
            let b = sample_bytes(&mut rng);
            assert!((CDF_MIN_BYTES..=max).contains(&b), "sample {b}");
        }
    }

    #[test]
    fn sample_mean_matches_analytic() {
        let mut rng = Rng::new(7);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| sample_bytes(&mut rng) as f64).sum();
        let empirical = sum / n as f64;
        let analytic = mean_bytes();
        // heavy tail => slow convergence; 5 % is plenty to catch a
        // broken interpolation
        assert!(
            (empirical - analytic).abs() / analytic < 0.05,
            "empirical {empirical:.0} vs analytic {analytic:.0}"
        );
    }

    #[test]
    fn exponential_mean_and_positivity() {
        let mut rng = Rng::new(9);
        let mean = 1_000_000.0; // 1 us
        let n = 100_000;
        let sum: f64 =
            (0..n).map(|_| sample_exp(&mut rng, mean) as f64).sum();
        let emp = sum / n as f64;
        assert!((emp - mean).abs() / mean < 0.03, "mean {emp:.0}");
        assert!(sample_exp(&mut rng, 0.0) >= 1);
    }
}
