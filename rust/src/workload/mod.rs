//! Experiment construction: placement policies and the
//! [`ScenarioBuilder`]/[`JobBuilder`] pair — the one path through which
//! every experiment (single job, multi-tenant, any collective, any
//! algo) is assembled.
//!
//! The paper's protocol (Section 5.2) is the default: pick the
//! collective's hosts uniformly at random ([`Placement::RandomUniform`]),
//! let the remaining hosts generate cross traffic (the paper's shape is
//! [`TrafficSpec::uniform`]; the traffic engine adds adversarial
//! patterns, [`crate::traffic`]), pick static-tree roots at random,
//! repeat with fresh seeds. A scenario may carry any number of jobs,
//! each with its own algo, [`Collective`], placement policy, tenant,
//! data size and start-time offset; cross traffic always lands on the
//! hosts no job claimed.
//!
//! Determinism contract: for a single RandomUniform allreduce job the
//! builder makes exactly the RNG draws of the pre-redesign
//! `build_scenario` free function, in the same order, so every recorded
//! figure series is bit-identical for the same placement seed
//! (`tests/placement.rs` pins this against an inlined replica of the
//! legacy placement).

use crate::collectives::runner::{install_background_job, install_job};
use crate::collectives::{Algo, Collective, JobSpec};
use crate::config::{ClosConfig, SimConfig};
use crate::faults::FaultSpec;
use crate::loadbalance::LoadBalancer;
use crate::sim::{Network, NodeBody, NodeId, Time};
use crate::topology::{build, FatTree};
use crate::trace::{TraceSpec, Tracer};
use crate::traffic::TrafficSpec;
use crate::util::rng::Rng;

/// How a job's participant set is carved out of the free host pool.
#[derive(Clone, Debug, PartialEq)]
pub enum Placement {
    /// Uniformly random hosts (the paper's Section 5.2 protocol;
    /// bit-compatible with the pre-redesign placement for the same
    /// seed).
    RandomUniform,
    /// Fill whole leaves/ToRs (in random leaf order): the job occupies
    /// the minimum number of leaf domains, the locality-friendly
    /// schedule real cluster managers aim for.
    ClusteredByLeaf,
    /// Round-robin one host per leaf (in leaf index order): maximal
    /// spread, every block crosses the core.
    Striped,
    /// Exactly these hosts (must be free), in rank order after sorting.
    Explicit(Vec<NodeId>),
}

impl Placement {
    /// Parse the CLI spelling (`random`, `clustered`, `striped`).
    pub fn parse(s: &str) -> Result<Placement, String> {
        match s {
            "random" => Ok(Placement::RandomUniform),
            "clustered" => Ok(Placement::ClusteredByLeaf),
            "striped" => Ok(Placement::Striped),
            _ => Err(format!(
                "unknown placement '{s}' (random|clustered|striped)"
            )),
        }
    }

    pub fn name(&self) -> String {
        match self {
            Placement::RandomUniform => "random".into(),
            Placement::ClusteredByLeaf => "clustered".into(),
            Placement::Striped => "striped".into(),
            Placement::Explicit(_) => "explicit".into(),
        }
    }

    /// Pick `n` participants out of `free` (sorted ascending), remove
    /// them from the pool and return them sorted ascending (the order
    /// defines ranks). `Explicit` ignores `n`.
    pub fn pick(
        &self,
        ft: &FatTree,
        free: &mut Vec<NodeId>,
        n: u32,
        rng: &mut Rng,
    ) -> Vec<NodeId> {
        let n = n as usize;
        let chosen: Vec<NodeId> = match self {
            Placement::RandomUniform => {
                assert!(
                    n <= free.len(),
                    "placement wants {n} hosts, only {} free",
                    free.len()
                );
                let idx = rng.sample_indices(free.len(), n);
                let mut v: Vec<NodeId> =
                    idx.iter().map(|&i| free[i]).collect();
                v.sort_unstable();
                v
            }
            Placement::ClusteredByLeaf => {
                // leaves that still have free hosts, visited in random
                // order, each drained before the next is touched
                let by_leaf = group_by_leaf(ft, free);
                let mut leaves: Vec<u32> = by_leaf.keys().copied().collect();
                rng.shuffle(&mut leaves);
                let mut v = Vec::with_capacity(n);
                'leaves: for l in leaves {
                    for &h in &by_leaf[&l] {
                        v.push(h);
                        if v.len() == n {
                            break 'leaves;
                        }
                    }
                }
                assert!(
                    v.len() == n,
                    "placement wants {n} hosts, only {} free",
                    free.len()
                );
                v.sort_unstable();
                v
            }
            Placement::Striped => {
                // one host per leaf per round, leaves in index order
                let mut by_leaf = group_by_leaf(ft, free);
                let mut v = Vec::with_capacity(n);
                while v.len() < n {
                    let before = v.len();
                    for q in by_leaf.values_mut() {
                        if v.len() == n {
                            break;
                        }
                        if !q.is_empty() {
                            v.push(q.remove(0));
                        }
                    }
                    assert!(
                        v.len() > before,
                        "placement wants {n} hosts, only {before} free"
                    );
                }
                v.sort_unstable();
                v
            }
            Placement::Explicit(hosts) => {
                let mut v = hosts.clone();
                v.sort_unstable();
                v.dedup();
                assert_eq!(
                    v.len(),
                    hosts.len(),
                    "explicit placement repeats hosts"
                );
                for &h in &v {
                    assert!(
                        free.binary_search(&h).is_ok(),
                        "explicit host {h} is not free (taken or absent)"
                    );
                }
                v
            }
        };
        free.retain(|h| chosen.binary_search(h).is_err());
        chosen
    }
}

/// Free hosts bucketed per leaf, leaves in index order (hosts within a
/// bucket stay in ascending id order because `free` is sorted).
fn group_by_leaf(
    ft: &FatTree,
    free: &[NodeId],
) -> std::collections::BTreeMap<u32, Vec<NodeId>> {
    let mut by_leaf: std::collections::BTreeMap<u32, Vec<NodeId>> =
        Default::default();
    for &h in free {
        by_leaf.entry(ft.leaf_of_host(h)).or_default().push(h);
    }
    by_leaf
}

/// One collective job to be placed into a scenario. Build with
/// [`JobBuilder::new`] and the chained setters; defaults are the
/// paper's single-allreduce protocol.
#[derive(Clone, Debug)]
pub struct JobBuilder {
    algo: Algo,
    collective: Collective,
    hosts: u32,
    data_bytes: u64,
    placement: Placement,
    start_ps: Time,
    record_results: bool,
    tenant: Option<u16>,
}

impl JobBuilder {
    pub fn new(algo: Algo) -> JobBuilder {
        JobBuilder {
            algo,
            collective: Collective::Allreduce,
            hosts: 2,
            data_bytes: 4 << 20,
            placement: Placement::RandomUniform,
            start_ps: 0,
            record_results: false,
            tenant: None,
        }
    }

    /// Number of participating hosts (ignored by
    /// [`Placement::Explicit`], which fixes the set itself).
    pub fn hosts(mut self, n: u32) -> Self {
        self.hosts = n;
        self
    }

    /// Application bytes per host (forced to 0 by
    /// [`Collective::Barrier`]).
    pub fn data_bytes(mut self, bytes: u64) -> Self {
        self.data_bytes = bytes;
        self
    }

    pub fn collective(mut self, c: Collective) -> Self {
        self.collective = c;
        self
    }

    pub fn placement(mut self, p: Placement) -> Self {
        self.placement = p;
        self
    }

    /// Start-time offset: this job's hosts wake at `t` (ps) instead of 0.
    pub fn start_at(mut self, t: Time) -> Self {
        self.start_ps = t;
        self
    }

    /// Keep per-host result payloads for value verification
    /// ([`crate::collectives::verify_job`]); pair with
    /// `SimConfig::with_values(true)`.
    pub fn record_results(mut self, on: bool) -> Self {
        self.record_results = on;
        self
    }

    /// Override the tenant id (default: job position + 1).
    pub fn tenant(mut self, t: u16) -> Self {
        self.tenant = Some(t);
        self
    }
}

/// Built experiment, ready to run.
pub struct Experiment {
    pub net: Network,
    pub ft: FatTree,
    /// Index of the first collective job (the common single-job case).
    pub job: u32,
    /// All collective job indices, in installation order.
    pub jobs: Vec<u32>,
}

/// Declarative scenario: a fabric, shared sim/load-balancer settings,
/// optional cross traffic, and any number of collective jobs.
///
/// `build(seed)` assembles the network: placement and sim randomness
/// both derive from the placement seed, so one scenario + seed is one
/// fully-determined world that can be replayed under different
/// protocols.
#[derive(Clone, Debug)]
pub struct ScenarioBuilder {
    pub topo: ClosConfig,
    pub sim: SimConfig,
    pub lb: LoadBalancer,
    pub traffic: Option<TrafficSpec>,
    /// Fault plan installed on the built network (loss probability plus
    /// the churn-event timeline). Empty by default — and an empty plan
    /// is provably inert (tests/churn.rs).
    pub faults: FaultSpec,
    /// Telemetry spec (`trace/`): `None` (the default) leaves the
    /// network's tracer off, which is zero-footprint (tests/trace.rs).
    pub trace: Option<TraceSpec>,
    jobs: Vec<JobBuilder>,
}

impl ScenarioBuilder {
    pub fn new(topo: ClosConfig) -> ScenarioBuilder {
        ScenarioBuilder {
            topo,
            sim: SimConfig::default(),
            lb: LoadBalancer::default(),
            traffic: None,
            faults: FaultSpec::default(),
            trace: None,
            jobs: Vec::new(),
        }
    }

    /// The paper's standard single-job scenario: 512 random hosts on
    /// the 1024-host fabric, 4 MiB, uniform line-rate cross traffic.
    pub fn paper_default(algo: Algo) -> ScenarioBuilder {
        ScenarioBuilder::new(ClosConfig::paper())
            .traffic(Some(TrafficSpec::uniform()))
            .job(JobBuilder::new(algo).hosts(512).data_bytes(4 << 20))
    }

    pub fn sim(mut self, sim: SimConfig) -> Self {
        self.sim = sim;
        self
    }

    pub fn lb(mut self, lb: LoadBalancer) -> Self {
        self.lb = lb;
        self
    }

    /// Cross traffic generated by the hosts no job claims; `None`
    /// leaves the fabric quiet, `Some(TrafficSpec::uniform())` is the
    /// paper's random-uniform line-rate stream. Applies to single- and
    /// multi-job scenarios alike.
    pub fn traffic(mut self, spec: Option<TrafficSpec>) -> Self {
        self.traffic = spec;
        self
    }

    /// Install a fault plan (random loss + scheduled churn events).
    pub fn faults(mut self, spec: FaultSpec) -> Self {
        self.faults = spec;
        self
    }

    /// Enable telemetry recording (`Some(spec)`) on the built network.
    pub fn trace(mut self, spec: Option<TraceSpec>) -> Self {
        self.trace = spec;
        self
    }

    /// Append a job. Placement draws happen in append order.
    pub fn job(mut self, jb: JobBuilder) -> Self {
        self.jobs.push(jb);
        self
    }

    /// Append `n` identically-shaped jobs (the multi-tenant pattern).
    pub fn jobs(mut self, n: u32, jb: JobBuilder) -> Self {
        for _ in 0..n {
            self.jobs.push(jb.clone());
        }
        self
    }

    /// Number of jobs added so far.
    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Assemble the network with randomized placement derived from
    /// `placement_seed` (independent from the sim seed so the same
    /// placement can be replayed under different protocols).
    pub fn build(&self, placement_seed: u64) -> Experiment {
        assert!(
            !self.jobs.is_empty(),
            "a scenario needs at least one job"
        );
        let mut sim = self.sim.clone();
        // placement and sim randomness both derive from the placement
        // seed so one scenario+seed is one fully-determined world
        sim.seed = sim.seed ^ placement_seed.wrapping_mul(0x9E3779B97F4A7C15);
        // a reactive cross-traffic transport turns on ECN marking in
        // the sim core (and may override the marking ramp); with
        // transport off nothing changes, keeping legacy seeds
        // bit-identical (tests/transport.rs)
        if let Some(spec) = &self.traffic {
            if spec.transport.is_on() {
                sim.ecn_enabled = true;
                if let Some(k) = spec.ecn_kmin {
                    sim.ecn_kmin_bytes = k;
                }
                if let Some(k) = spec.ecn_kmax {
                    sim.ecn_kmax_bytes = k;
                }
                assert!(
                    sim.ecn_kmin_bytes <= sim.ecn_kmax_bytes,
                    "ECN kmin {} exceeds kmax {}",
                    sim.ecn_kmin_bytes,
                    sim.ecn_kmax_bytes
                );
            }
        }
        let (mut net, ft) = build(self.topo, sim, self.lb.clone());
        net.faults = self.faults.clone();
        // enable the tracer before jobs are installed so install-time
        // spans land too
        if let Some(ts) = &self.trace {
            net.tracer = Tracer::on(ts.clone());
        }

        // statically partition the descriptor table across tenants, as
        // most in-network algorithms do and the paper adopts for
        // fairness (5.2.4): each tenant hashes into a disjoint region
        // of every switch's table
        if self.jobs.len() > 1 {
            let n = self.jobs.len() as u32;
            for node in net.nodes.iter_mut() {
                if let NodeBody::Switch(sw) = &mut node.body {
                    sw.canary.partitions = n;
                }
            }
        }

        let mut rng = Rng::new(placement_seed);
        let mut free: Vec<NodeId> = ft.all_hosts();
        let mut jobs = Vec::with_capacity(self.jobs.len());
        for (j, jb) in self.jobs.iter().enumerate() {
            let participants =
                jb.placement.pick(&ft, &mut free, jb.hosts, &mut rng);
            let tree_roots = match jb.algo {
                Algo::StaticTree { n_trees } => {
                    random_roots(&ft, &mut rng, n_trees as usize)
                }
                _ => vec![],
            };
            // barrier: one genuinely empty block — no application data
            // and a single-lane payload, so the wire carries a
            // header-sized packet per host instead of a full MTU
            let (data_bytes, payload_bytes) = match jb.collective {
                Collective::Barrier => (0, 4.min(net.cfg.payload_bytes)),
                _ => (jb.data_bytes, net.cfg.payload_bytes),
            };
            let spec = JobSpec {
                tenant: jb.tenant.unwrap_or((j + 1) as u16),
                algo: jb.algo,
                collective: jb.collective,
                participants,
                data_bytes,
                window: net.cfg.host_window,
                payload_bytes,
                tree_roots,
                start_ps: jb.start_ps,
                record_results: jb.record_results,
            };
            jobs.push(install_job(&mut net, &ft, spec));
        }

        // cross traffic on every host no job claimed — in multi-job
        // scenarios exactly as in single-job ones
        if let Some(spec) = self.traffic {
            if free.len() >= 2 {
                install_background_job(&mut net, free.clone(), spec, &mut rng);
            }
        }
        let job = jobs[0];
        Experiment { net, ft, job, jobs }
    }
}

/// Distinct random top-tier roots (paper: static-tree roots picked at
/// random per run).
pub fn random_roots(ft: &FatTree, rng: &mut Rng, n: usize) -> Vec<NodeId> {
    let spines = ft.all_spines();
    let idx = rng.sample_indices(spines.len(), n.min(spines.len()));
    idx.into_iter().map(|i| spines[i]).collect()
}
