//! Experiment workload construction: random host placement, congestion
//! generators and the standard scenarios used by the figure harness.
//!
//! The paper's protocol (Section 5.2): pick the allreduce hosts uniformly
//! at random, let the remaining hosts generate random-uniform traffic,
//! pick static-tree roots at random, repeat 5 times with fresh seeds.

use crate::collectives::runner::{
    install_background_job, install_canary_job, install_ring_job,
    install_static_job,
};
use crate::collectives::Algo;
use crate::config::{FatTreeConfig, SimConfig};
use crate::loadbalance::LoadBalancer;
use crate::sim::{Network, NodeId};
use crate::topology::{build, FatTree};
use crate::util::rng::Rng;

/// One standard experiment: a single allreduce (+ optional congestion).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub topo: FatTreeConfig,
    pub sim: SimConfig,
    pub lb: LoadBalancer,
    pub algo: Algo,
    /// Number of hosts running the allreduce.
    pub n_allreduce_hosts: u32,
    /// Remaining hosts generate random-uniform congestion.
    pub congestion: bool,
    /// Application bytes per host.
    pub data_bytes: u64,
    pub record_results: bool,
}

impl Scenario {
    pub fn paper_default(algo: Algo) -> Scenario {
        Scenario {
            topo: FatTreeConfig::paper(),
            sim: SimConfig::default(),
            lb: LoadBalancer::default(),
            algo,
            n_allreduce_hosts: 512,
            congestion: true,
            data_bytes: 4 * 1024 * 1024,
            record_results: false,
        }
    }
}

/// Built experiment, ready to run.
pub struct Experiment {
    pub net: Network,
    pub ft: FatTree,
    /// Index of the (single) allreduce job.
    pub job: u32,
}

/// Build a [`Scenario`] with randomized placement derived from
/// `placement_seed` (independent from the sim seed so the same placement
/// can be replayed under different protocols).
pub fn build_scenario(sc: &Scenario, placement_seed: u64) -> Experiment {
    let mut sim = sc.sim.clone();
    // placement and sim randomness both derive from the placement seed so
    // one scenario+seed is one fully-determined world
    sim.seed = sim.seed ^ placement_seed.wrapping_mul(0x9E3779B97F4A7C15);
    let (mut net, ft) = build(sc.topo, sim, sc.lb.clone());
    let mut rng = Rng::new(placement_seed);

    let all: Vec<NodeId> = ft.all_hosts();
    let chosen_idx =
        rng.sample_indices(all.len(), sc.n_allreduce_hosts as usize);
    let mut participants: Vec<NodeId> =
        chosen_idx.iter().map(|&i| all[i]).collect();
    participants.sort_unstable();

    let job = match sc.algo {
        Algo::Canary => install_canary_job(
            &mut net,
            1,
            participants.clone(),
            sc.data_bytes,
            sc.record_results,
        ),
        Algo::StaticTree { n_trees } => {
            let roots = random_roots(&ft, &mut rng, n_trees as usize);
            install_static_job(
                &mut net,
                &ft,
                1,
                participants.clone(),
                sc.data_bytes,
                roots,
                sc.record_results,
            )
        }
        Algo::Ring => {
            install_ring_job(&mut net, 1, participants.clone(), sc.data_bytes)
        }
        Algo::Background => panic!("background is not an allreduce"),
    };

    if sc.congestion {
        let bg: Vec<NodeId> = all
            .iter()
            .copied()
            .filter(|h| !participants.contains(h))
            .collect();
        if bg.len() >= 2 {
            install_background_job(&mut net, bg);
        }
    }
    Experiment { net, ft, job }
}

/// Distinct random spine roots (paper: roots picked at random per run).
pub fn random_roots(ft: &FatTree, rng: &mut Rng, n: usize) -> Vec<NodeId> {
    let spines = ft.all_spines();
    let idx = rng.sample_indices(spines.len(), n.min(spines.len()));
    idx.into_iter().map(|i| spines[i]).collect()
}

/// Multi-tenant scenario (Fig. 10): partition `n_jobs * hosts_per_job`
/// hosts into equal concurrent allreduces, all of the same `algo`.
pub fn build_multi_tenant(
    topo: FatTreeConfig,
    sim: SimConfig,
    lb: LoadBalancer,
    algo: Algo,
    n_jobs: u32,
    data_bytes: u64,
    placement_seed: u64,
) -> (Network, FatTree, Vec<u32>) {
    let mut sim = sim;
    sim.seed = sim.seed ^ placement_seed.wrapping_mul(0x9E3779B97F4A7C15);
    let (mut net, ft) = build(topo, sim, lb);
    // statically partition the descriptor table across tenants, as most
    // in-network algorithms do and the paper adopts for fairness (5.2.4):
    // each tenant hashes into a disjoint region of every switch's table
    for node in net.nodes.iter_mut() {
        if let crate::sim::NodeBody::Switch(sw) = &mut node.body {
            sw.canary.partitions = n_jobs.max(1);
        }
    }
    let mut rng = Rng::new(placement_seed);

    let mut all: Vec<NodeId> = ft.all_hosts();
    rng.shuffle(&mut all);
    let per_job = (all.len() as u32 / n_jobs).max(1);

    let mut jobs = Vec::new();
    for j in 0..n_jobs {
        let lo = (j * per_job) as usize;
        let hi = ((j + 1) * per_job) as usize;
        let mut participants: Vec<NodeId> = all[lo..hi].to_vec();
        participants.sort_unstable();
        let tenant = (j + 1) as u16;
        let job = match algo {
            Algo::Canary => install_canary_job(
                &mut net,
                tenant,
                participants,
                data_bytes,
                false,
            ),
            Algo::StaticTree { n_trees } => {
                let roots = random_roots(&ft, &mut rng, n_trees as usize);
                install_static_job(
                    &mut net,
                    &ft,
                    tenant,
                    participants,
                    data_bytes,
                    roots,
                    false,
                )
            }
            Algo::Ring => {
                install_ring_job(&mut net, tenant, participants, data_bytes)
            }
            Algo::Background => unreachable!(),
        };
        jobs.push(job);
    }
    (net, ft, jobs)
}
