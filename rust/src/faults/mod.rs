//! Fault injection: random packet loss and scheduled switch failures.
//!
//! The paper treats both identically at the protocol level (Section 3.3):
//! the leader times out / hosts time out, retransmission requests flow to
//! the leader, and either the finished result is re-sent or the block is
//! reduced again from scratch under a fresh id.

use crate::sim::{NodeId, Time};

/// Declarative fault plan, installed before the run.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Per-delivery probability of dropping a non-background packet.
    pub loss_prob: f64,
    /// (time, switch) pairs: at `time` the switch dies (its links go
    /// down, its soft state is lost).
    pub switch_failures: Vec<(Time, NodeId)>,
}

impl FaultPlan {
    pub fn with_loss(mut self, p: f64) -> Self {
        self.loss_prob = p;
        self
    }

    pub fn with_switch_failure(mut self, t: Time, node: NodeId) -> Self {
        self.switch_failures.push((t, node));
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder() {
        let f = FaultPlan::default()
            .with_loss(0.01)
            .with_switch_failure(100, 7)
            .with_switch_failure(200, 9);
        assert_eq!(f.loss_prob, 0.01);
        assert_eq!(f.switch_failures.len(), 2);
    }
}
