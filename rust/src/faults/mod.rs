//! Fault injection: random packet loss plus a scheduled timeline of
//! typed churn events (link flaps, switch failures with optional
//! recovery, straggler hosts).
//!
//! The paper treats loss and switch death identically at the protocol
//! level (Section 3.3): the leader times out / hosts time out,
//! retransmission requests flow to the leader, and either the finished
//! result is re-sent or the block is reduced again from scratch under a
//! fresh id. The churn timeline (DESIGN.md §2.6) extends that to the
//! *dynamic* fabric the paper's mechanism is designed for: a downed
//! link drops/queues nothing, a failed switch blackholes all its ports
//! until recovery, and a straggler host runs all its protocol timers
//! `slowdown`x slower — stressing exactly the timeout-driven partial
//! aggregation that distinguishes Canary from static trees.
//!
//! A [`FaultSpec`] is declarative: it is installed before the run (via
//! `ScenarioBuilder::faults` or directly on `Network::faults`) and
//! `Network::kick_jobs` converts it into sim-core events. An empty
//! timeline schedules nothing and draws nothing from the RNG, so a run
//! with `FaultSpec::default()` is bit-identical to a fault-free run
//! (pinned in `tests/churn.rs`).

use crate::sim::{NodeId, Time, US};

/// One scheduled churn event.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultEvent {
    /// The bidirectional link between nodes `a` and `b` goes down at
    /// `down_at` and comes back at `up_at`. Packets queued on it are
    /// dropped (`drops_link_down`), packets routed onto it while down
    /// are dropped at enqueue, and adaptive/flowlet routing steers
    /// around it via the port-down bit (`Ctx::port_alive`).
    LinkFlap {
        a: NodeId,
        b: NodeId,
        down_at: Time,
        up_at: Time,
    },
    /// The switch dies at `at`: every link touching it goes down and
    /// its soft state (descriptors, flowlet tables) is lost. With
    /// `recover_at` set the links come back up at that time; the soft
    /// state stays lost — leaders re-reduce affected blocks, exactly
    /// the Section 3.3 loss-equivalence.
    SwitchFail {
        switch: NodeId,
        at: Time,
        recover_at: Option<Time>,
    },
    /// Every protocol timer of `host` is stretched by `slowdown`x for
    /// the whole run (injection pacing, retry timers — everything that
    /// goes through `Ctx::host_timer`). `slowdown == 1` is provably
    /// inert. This is the adversary of the Canary aggregation timeout:
    /// switches stop waiting for the straggler's contributions and
    /// forward partial aggregates instead.
    StragglerHost { host: NodeId, slowdown: u32 },
}

/// Declarative fault plan: random loss plus the churn-event timeline.
/// Installed before the run; see the module docs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Per-delivery probability of dropping a non-background packet.
    pub loss_prob: f64,
    /// Scheduled churn events, in any order (scheduling sorts by time
    /// via the event queue).
    pub events: Vec<FaultEvent>,
}

/// Backwards-compatible alias (the pre-churn name).
pub type FaultPlan = FaultSpec;

impl FaultSpec {
    pub fn with_loss(mut self, p: f64) -> Self {
        self.loss_prob = p;
        self
    }

    /// A link flap between nodes `a` and `b` (either direction order).
    pub fn with_link_flap(
        mut self,
        a: NodeId,
        b: NodeId,
        down_at: Time,
        up_at: Time,
    ) -> Self {
        assert!(down_at < up_at, "flap must go down before it comes up");
        self.events.push(FaultEvent::LinkFlap {
            a,
            b,
            down_at,
            up_at,
        });
        self
    }

    /// Legacy spelling: a permanent switch failure at `t`.
    pub fn with_switch_failure(self, t: Time, node: NodeId) -> Self {
        self.with_switch_fail(node, t, None)
    }

    /// A switch failure at `at`, optionally recovering at `recover_at`.
    pub fn with_switch_fail(
        mut self,
        switch: NodeId,
        at: Time,
        recover_at: Option<Time>,
    ) -> Self {
        if let Some(r) = recover_at {
            assert!(at < r, "switch must fail before it recovers");
        }
        self.events.push(FaultEvent::SwitchFail {
            switch,
            at,
            recover_at,
        });
        self
    }

    /// Stretch all of `host`'s protocol timers by `slowdown`x.
    pub fn with_straggler(mut self, host: NodeId, slowdown: u32) -> Self {
        assert!(slowdown >= 1, "slowdown factor must be >= 1");
        self.events
            .push(FaultEvent::StragglerHost { host, slowdown });
        self
    }

    /// Nothing to inject: no loss, no events. An empty spec leaves a
    /// run bit-identical to one with no spec at all.
    pub fn is_empty(&self) -> bool {
        self.loss_prob == 0.0 && self.events.is_empty()
    }

    /// Parse the CLI spelling: comma-separated items, times in µs.
    ///
    /// ```text
    /// loss:P                    random loss probability P
    /// flap:A:B:DOWN_US:UP_US    link A<->B down at DOWN_US, up at UP_US
    /// fail:SW:AT_US[:REC_US]    switch SW dies at AT_US (recovers at REC_US)
    /// straggler:H:FACTOR        host H's timers run FACTOR x slower
    /// ```
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        for item in s.split(',').filter(|i| !i.is_empty()) {
            let parts: Vec<&str> = item.split(':').collect();
            let num = |i: usize, what: &str| -> Result<u64, String> {
                parts
                    .get(i)
                    .ok_or_else(|| format!("'{item}' is missing {what}"))?
                    .parse::<u64>()
                    .map_err(|_| format!("bad {what} in '{item}'"))
            };
            match parts[0] {
                "loss" => {
                    let p: f64 = parts
                        .get(1)
                        .ok_or_else(|| {
                            format!("'{item}' is missing a probability")
                        })?
                        .parse()
                        .map_err(|_| format!("bad probability in '{item}'"))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!(
                            "loss probability {p} outside [0, 1]"
                        ));
                    }
                    spec.loss_prob = p;
                }
                "flap" => {
                    if parts.len() != 5 {
                        return Err(format!(
                            "'{item}' wants flap:A:B:DOWN_US:UP_US"
                        ));
                    }
                    let (a, b) =
                        (num(1, "node a")? as NodeId, num(2, "node b")? as NodeId);
                    let (down, up) =
                        (num(3, "down time")? * US, num(4, "up time")? * US);
                    if down >= up {
                        return Err(format!(
                            "'{item}': down time must precede up time"
                        ));
                    }
                    spec = spec.with_link_flap(a, b, down, up);
                }
                "fail" => {
                    if parts.len() != 3 && parts.len() != 4 {
                        return Err(format!(
                            "'{item}' wants fail:SW:AT_US[:REC_US]"
                        ));
                    }
                    let sw = num(1, "switch id")? as NodeId;
                    let at = num(2, "fail time")? * US;
                    let rec = if parts.len() == 4 {
                        let r = num(3, "recovery time")? * US;
                        if at >= r {
                            return Err(format!(
                                "'{item}': failure must precede recovery"
                            ));
                        }
                        Some(r)
                    } else {
                        None
                    };
                    spec = spec.with_switch_fail(sw, at, rec);
                }
                "straggler" => {
                    if parts.len() != 3 {
                        return Err(format!(
                            "'{item}' wants straggler:H:FACTOR"
                        ));
                    }
                    let host = num(1, "host id")? as NodeId;
                    let factor = num(2, "slowdown factor")? as u32;
                    if factor < 1 {
                        return Err(format!(
                            "'{item}': slowdown factor must be >= 1"
                        ));
                    }
                    spec = spec.with_straggler(host, factor);
                }
                other => {
                    return Err(format!(
                        "unknown fault item '{other}' \
                         (loss|flap|fail|straggler)"
                    ))
                }
            }
        }
        Ok(spec)
    }

    /// Parse a JSON fault description, e.g.
    ///
    /// ```json
    /// {"loss": 0.01, "events": [
    ///   {"kind": "link_flap", "a": 8, "b": 12,
    ///    "down_at_us": 5, "up_at_us": 40},
    ///   {"kind": "switch_fail", "switch": 12, "at_us": 5,
    ///    "recover_at_us": 40},
    ///   {"kind": "straggler", "host": 3, "slowdown": 4}
    /// ]}
    /// ```
    pub fn from_json(text: &str) -> Result<FaultSpec, String> {
        let v = crate::util::json::parse(text)?;
        let mut spec = FaultSpec::default();
        if let Some(p) = v.get("loss").and_then(|x| x.as_f64()) {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("loss probability {p} outside [0, 1]"));
            }
            spec.loss_prob = p;
        }
        let Some(events) = v.get("events") else {
            return Ok(spec);
        };
        let events = events
            .as_array()
            .ok_or("'events' must be an array of fault objects")?;
        for e in events {
            let kind = e
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or("fault event needs a string 'kind'")?;
            let int_key = |key: &str| -> Result<u64, String> {
                let i = e
                    .get(key)
                    .and_then(|x| x.as_i64())
                    .ok_or_else(|| {
                        format!("'{kind}' needs integer key '{key}'")
                    })?;
                u64::try_from(i)
                    .map_err(|_| format!("'{key}' out of range: {i}"))
            };
            match kind {
                "link_flap" => {
                    let (a, b) =
                        (int_key("a")? as NodeId, int_key("b")? as NodeId);
                    let down = int_key("down_at_us")? * US;
                    let up = int_key("up_at_us")? * US;
                    if down >= up {
                        return Err(
                            "link_flap: down_at_us must precede up_at_us"
                                .into(),
                        );
                    }
                    spec = spec.with_link_flap(a, b, down, up);
                }
                "switch_fail" => {
                    let sw = int_key("switch")? as NodeId;
                    let at = int_key("at_us")? * US;
                    let rec = match e.get("recover_at_us") {
                        None => None,
                        Some(_) => {
                            let r = int_key("recover_at_us")? * US;
                            if at >= r {
                                return Err("switch_fail: at_us must \
                                            precede recover_at_us"
                                    .into());
                            }
                            Some(r)
                        }
                    };
                    spec = spec.with_switch_fail(sw, at, rec);
                }
                "straggler" => {
                    let host = int_key("host")? as NodeId;
                    let slowdown = int_key("slowdown")? as u32;
                    if slowdown < 1 {
                        return Err(
                            "straggler: slowdown must be >= 1".into()
                        );
                    }
                    spec = spec.with_straggler(host, slowdown);
                }
                other => {
                    return Err(format!(
                        "unknown fault kind '{other}' \
                         (link_flap|switch_fail|straggler)"
                    ))
                }
            }
        }
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder() {
        let f = FaultSpec::default()
            .with_loss(0.01)
            .with_switch_failure(100, 7)
            .with_switch_failure(200, 9);
        assert_eq!(f.loss_prob, 0.01);
        assert_eq!(f.events.len(), 2);
        assert_eq!(
            f.events[0],
            FaultEvent::SwitchFail {
                switch: 7,
                at: 100,
                recover_at: None
            }
        );
        assert!(!f.is_empty());
        assert!(FaultSpec::default().is_empty());
    }

    #[test]
    fn typed_builders() {
        let f = FaultSpec::default()
            .with_link_flap(8, 12, 5 * US, 40 * US)
            .with_switch_fail(12, 5 * US, Some(40 * US))
            .with_straggler(3, 4);
        assert_eq!(f.events.len(), 3);
        assert_eq!(
            f.events[2],
            FaultEvent::StragglerHost { host: 3, slowdown: 4 }
        );
    }

    #[test]
    fn cli_parse_roundtrip() {
        let f = FaultSpec::parse(
            "loss:0.02,flap:8:12:5:40,fail:12:5:40,fail:9:7,straggler:3:4",
        )
        .unwrap();
        assert_eq!(f.loss_prob, 0.02);
        assert_eq!(
            f.events,
            vec![
                FaultEvent::LinkFlap {
                    a: 8,
                    b: 12,
                    down_at: 5 * US,
                    up_at: 40 * US
                },
                FaultEvent::SwitchFail {
                    switch: 12,
                    at: 5 * US,
                    recover_at: Some(40 * US)
                },
                FaultEvent::SwitchFail {
                    switch: 9,
                    at: 7 * US,
                    recover_at: None
                },
                FaultEvent::StragglerHost { host: 3, slowdown: 4 },
            ]
        );
        assert!(FaultSpec::parse("").unwrap().is_empty());
    }

    #[test]
    fn cli_parse_rejects_garbage() {
        assert!(FaultSpec::parse("loss:2.0").is_err());
        assert!(FaultSpec::parse("flap:1:2:40:5").is_err());
        assert!(FaultSpec::parse("flap:1:2:5").is_err());
        assert!(FaultSpec::parse("fail:1:40:5").is_err());
        assert!(FaultSpec::parse("straggler:1:0").is_err());
        assert!(FaultSpec::parse("teleport:1:2").is_err());
    }

    #[test]
    fn json_parse() {
        let f = FaultSpec::from_json(
            r#"{"loss": 0.01, "events": [
                 {"kind": "link_flap", "a": 8, "b": 12,
                  "down_at_us": 5, "up_at_us": 40},
                 {"kind": "switch_fail", "switch": 12, "at_us": 5,
                  "recover_at_us": 40},
                 {"kind": "straggler", "host": 3, "slowdown": 4}
               ]}"#,
        )
        .unwrap();
        assert_eq!(f.loss_prob, 0.01);
        assert_eq!(f.events.len(), 3);
        assert_eq!(
            f.events[1],
            FaultEvent::SwitchFail {
                switch: 12,
                at: 5 * US,
                recover_at: Some(40 * US)
            }
        );
        assert!(FaultSpec::from_json(r#"{}"#).unwrap().is_empty());
        assert!(FaultSpec::from_json(
            r#"{"events": [{"kind": "warp"}]}"#
        )
        .is_err());
    }
}
