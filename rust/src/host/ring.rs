//! Host-based bandwidth-optimal ring allreduce (Patarasuk & Yuan), the
//! paper's host-based baseline.
//!
//! Data is split into N chunks; 2(N-1) pipelined steps, each moving one
//! chunk to the ring successor (reduce-scatter then allgather). The
//! dependency is **per packet**: packet `p` of step `s+1` can be sent as
//! soon as packet `p` of step `s` arrived (the element-wise reduction
//! needs only that packet's elements). This is how production rings
//! (e.g. NCCL) pipeline, and it hides the per-step hop latency under the
//! chunk serialization time whenever `chunk_time >= hop_latency`.

use crate::sim::packet::{Packet, PacketKind};
use crate::sim::{Ctx, NodeId, PacketId};
use crate::trace::SpanKind;

/// Ring protocol state for one participating host.
pub struct RingHost {
    pub job: u32,
    pub rank: u32,
    pub n: u32,
    /// Packets per chunk (chunk = ceil(data/N), packetized at the MTU).
    pub chunk_packets: u32,
    /// 2(N-1) total steps.
    pub total_steps: u32,
    /// Received packet count per step.
    pub recv: Vec<u32>,
    pub finished: bool,
}

impl RingHost {
    pub fn new(
        job: u32,
        rank: u32,
        n: u32,
        data_bytes: u64,
        payload_bytes: u32,
    ) -> RingHost {
        let payload = payload_bytes as u64;
        let chunk_bytes = data_bytes.div_ceil(n as u64);
        let chunk_packets = chunk_bytes.div_ceil(payload).max(1) as u32;
        let total_steps = if n > 1 { 2 * (n - 1) } else { 0 };
        RingHost {
            job,
            rank,
            n,
            chunk_packets,
            total_steps,
            recv: vec![0; total_steps as usize],
            finished: false,
        }
    }

    fn successor(&self, ctx: &Ctx) -> NodeId {
        let p = &ctx.jobs[self.job as usize].spec.participants;
        p[(self.rank as usize + 1) % p.len()]
    }
}

pub fn on_wake(me: NodeId, rh: &mut RingHost, ctx: &mut Ctx) {
    if rh.n == 1 {
        // degenerate ring: nothing to exchange
        finish(rh, ctx);
        return;
    }
    ctx.tracer
        .span(ctx.now, SpanKind::FirstSend, rh.job, me, Some(0), 0);
    // inject the whole step-0 chunk; the NIC serializes at line rate
    for p in 0..rh.chunk_packets {
        send_packet(me, rh, ctx, 0, p);
    }
}

fn send_packet(
    me: NodeId,
    rh: &mut RingHost,
    ctx: &mut Ctx,
    step: u32,
    p: u32,
) {
    let dst = rh.successor(ctx);
    let wire = ctx.jobs[rh.job as usize].spec.wire_bytes();
    let mut pkt = Packet::data(PacketKind::Ring, me, dst);
    pkt.tenant = ctx.jobs[rh.job as usize].spec.tenant;
    pkt.meta = step as u64;
    pkt.block = p;
    pkt.wire_bytes = wire;
    pkt.flow = ((me as u64) << 32) | step as u64;
    ctx.send(0, pkt);
}

pub fn on_packet(me: NodeId, rh: &mut RingHost, ctx: &mut Ctx, pid: PacketId) {
    let pkt = ctx.take(pid);
    let step = pkt.meta as u32;
    if step >= rh.total_steps || rh.finished {
        return;
    }
    rh.recv[step as usize] += 1;
    // per-packet pipelining: this packet's elements are reduced and can
    // move on immediately
    if step + 1 < rh.total_steps {
        send_packet(me, rh, ctx, step + 1, pkt.block);
    }
    if rh.recv[step as usize] == rh.chunk_packets
        && rh.recv.iter().all(|&c| c >= rh.chunk_packets)
    {
        finish(rh, ctx);
    }
}

fn finish(rh: &mut RingHost, ctx: &mut Ctx) {
    if rh.finished {
        return;
    }
    rh.finished = true;
    let rank = rh.rank;
    let now = ctx.now;
    ctx.tracer.span(
        now,
        SpanKind::HostDone,
        rh.job,
        ctx.node_id,
        None,
        rank as u64,
    );
    ctx.jobs[rh.job as usize].host_finished(rank, now);
}
