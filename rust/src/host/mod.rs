//! Host protocol engines: the Canary host/leader logic, the static-tree
//! and ring baselines. Cross-traffic generation is the flow-level engine
//! in [`crate::traffic`] (its per-host state machine plugs in as
//! [`Proto::Background`]).
//!
//! Hosts are event-driven: `handle_wake` starts a job's injection,
//! `handle_packet` advances the protocol, `handle_timer` drives
//! retransmission and the Section 5.2.5 noise delays.

pub mod canary_host;
pub mod ring;
pub mod static_host;

use crate::sim::{Ctx, NodeId, PacketId};
use crate::traffic::{engine, TrafficHost};
use crate::util::rng::Rng;

/// Per-host protocol state.
pub enum Proto {
    Idle,
    Canary(canary_host::CanaryHost),
    Static(static_host::StaticHost),
    Ring(ring::RingHost),
    Background(TrafficHost),
}

/// A host node.
pub struct HostState {
    pub id: NodeId,
    pub rng: Rng,
    pub proto: Proto,
}

impl HostState {
    pub fn new(id: NodeId, rng: Rng) -> HostState {
        HostState {
            id,
            rng,
            proto: Proto::Idle,
        }
    }
}

// ---- host timer encoding -------------------------------------------------
// [63:56] kind | [55:40] job | [39:8] block | [7:0] aux (retry round)

pub const TIMER_RETRANS: u8 = 1;
pub const TIMER_DELAYED_SEND: u8 = 2;
pub const TIMER_DELAYED_STATIC: u8 = 3;
/// Line-rate injection stream clock (one packet per serialization slot).
pub const TIMER_STREAM: u8 = 4;
/// Background-flow retransmission timeout (reactive transport; the
/// `block` field carries the flow id's low 32 bits).
pub const TIMER_TRANSPORT_RTO: u8 = 5;

#[inline]
pub fn encode_timer(kind: u8, job: u32, block: u32, aux: u8) -> u64 {
    debug_assert!(job < (1 << 16));
    ((kind as u64) << 56)
        | ((job as u64) << 40)
        | ((block as u64) << 8)
        | aux as u64
}

#[inline]
pub fn decode_timer(t: u64) -> (u8, u32, u32, u8) {
    (
        (t >> 56) as u8,
        ((t >> 40) & 0xFFFF) as u32,
        ((t >> 8) & 0xFFFF_FFFF) as u32,
        (t & 0xFF) as u8,
    )
}

/// Packet entry point. Hosts terminate every packet they receive, so
/// each protocol handler takes the packet out of the arena itself
/// (mismatched strays are freed here).
pub fn handle_packet(
    h: &mut HostState,
    ctx: &mut Ctx,
    _in_port: u16,
    pid: PacketId,
) {
    use crate::sim::packet::PacketKind as K;
    let kind = ctx.pkt(pid).kind;
    match (&mut h.proto, kind) {
        (Proto::Canary(ch), _) => {
            canary_host::on_packet(h.id, ch, &mut h.rng, ctx, pid)
        }
        (Proto::Static(sh), K::StaticBroadcast) => {
            static_host::on_broadcast(h.id, sh, ctx, pid)
        }
        (Proto::Ring(rh), K::Ring) => ring::on_packet(h.id, rh, ctx, pid),
        (
            Proto::Background(bg),
            K::Background | K::TransportAck | K::TransportCnp,
        ) => {
            // sink: account the delivery toward its flow's completion;
            // ACK/CNP control frames feed the reactive transport
            engine::on_packet(h.id, bg, ctx, pid)
        }
        _ => ctx.free(pid), // stray packet for an idle/mismatched host
    }
}

/// Timer entry point.
pub fn handle_timer(h: &mut HostState, ctx: &mut Ctx, timer: u64) {
    match &mut h.proto {
        Proto::Canary(ch) => {
            canary_host::on_timer(h.id, ch, &mut h.rng, ctx, timer)
        }
        Proto::Static(sh) => {
            static_host::on_timer(h.id, sh, &mut h.rng, ctx, timer)
        }
        Proto::Background(bg) => {
            engine::on_timer(h.id, bg, ctx, timer)
        }
        _ => {}
    }
}

/// Job kick-off entry point.
pub fn handle_wake(h: &mut HostState, ctx: &mut Ctx, job: u32) {
    match &mut h.proto {
        Proto::Canary(ch) => canary_host::on_wake(h.id, ch, &mut h.rng, ctx),
        Proto::Static(sh) => static_host::on_wake(h.id, sh, &mut h.rng, ctx),
        Proto::Ring(rh) => ring::on_wake(h.id, rh, ctx),
        Proto::Background(bg) => {
            engine::on_wake(h.id, bg, &mut h.rng, ctx, job)
        }
        Proto::Idle => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_roundtrip() {
        let t = encode_timer(TIMER_RETRANS, 65_535, 4_000_000_000, 255);
        assert_eq!(decode_timer(t), (TIMER_RETRANS, 65_535, 4_000_000_000, 255));
        let t = encode_timer(TIMER_DELAYED_SEND, 3, 17, 0);
        assert_eq!(decode_timer(t), (TIMER_DELAYED_SEND, 3, 17, 0));
    }
}
