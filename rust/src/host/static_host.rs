//! Static-tree host protocol: line-rate self-clocked block injection
//! toward the per-tree root switch; completion on the switch-initiated
//! broadcast. (The baselines assume a reliable network, as the paper's
//! do.)

use crate::sim::packet::{Packet, PacketKind, Payload};
use crate::sim::{Ctx, NodeId, PacketId};
use crate::trace::SpanKind;
use crate::util::rng::Rng;

use super::{encode_timer, TIMER_STREAM};

/// Static-tree protocol state for one participating host.
pub struct StaticHost {
    pub job: u32,
    pub rank: u32,
    pub total_blocks: u32,
    pub next_block: u32,
    pub inflight: u32,
    pub stalled: bool,
    pub done: Vec<bool>,
    pub done_count: u32,
    pub finished: bool,
}

impl StaticHost {
    pub fn new(job: u32, rank: u32, total_blocks: u32) -> StaticHost {
        StaticHost {
            job,
            rank,
            total_blocks,
            next_block: 0,
            inflight: 0,
            stalled: false,
            done: vec![false; total_blocks as usize],
            done_count: 0,
            finished: false,
        }
    }
}

pub fn on_wake(me: NodeId, sh: &mut StaticHost, rng: &mut Rng, ctx: &mut Ctx) {
    pump(me, sh, rng, ctx);
}

/// Emit the next block at line rate (same pacing as the Canary hosts).
fn pump(me: NodeId, sh: &mut StaticHost, rng: &mut Rng, ctx: &mut Ctx) {
    if sh.next_block >= sh.total_blocks {
        return;
    }
    let window = ctx.jobs[sh.job as usize].spec.window;
    if window > 0 && sh.inflight >= window {
        sh.stalled = true;
        return;
    }
    // NIC pacing under backpressure (see canary_host::pump)
    let wire_bytes = ctx.jobs[sh.job as usize].spec.wire_bytes() as u64;
    if ctx.port_class0_bytes(0) > 8 * wire_bytes {
        let retry = wire_bytes * ctx.cfg.link_ps_per_byte;
        ctx.host_timer(retry, encode_timer(TIMER_STREAM, sh.job, 0, 0));
        return;
    }
    let idx = sh.next_block;
    sh.next_block += 1;
    sh.inflight += 1;
    if idx == 0 {
        ctx.tracer
            .span(ctx.now, SpanKind::FirstSend, sh.job, me, Some(idx), 0);
    }
    if idx + 1 == sh.total_blocks {
        ctx.tracer
            .span(ctx.now, SpanKind::LastSend, sh.job, me, Some(idx), 0);
    }
    send_block(me, sh, ctx, idx);

    let wire = ctx.jobs[sh.job as usize].spec.wire_bytes() as u64
        * ctx.cfg.link_ps_per_byte;
    let mut gap = wire;
    if ctx.cfg.noise_prob > 0.0 && rng.chance(ctx.cfg.noise_prob) {
        gap += ctx.cfg.noise_delay_ps; // OS-noise stream stall (5.2.5)
    }
    ctx.host_timer(gap, encode_timer(TIMER_STREAM, sh.job, 0, 0));
}

fn send_block(me: NodeId, sh: &mut StaticHost, ctx: &mut Ctx, idx: u32) {
    let spec = &ctx.jobs[sh.job as usize].spec;
    let n_trees = spec.tree_roots.len().max(1);
    let tree = (idx as usize % n_trees) as u8;
    let root = spec.tree_roots[tree as usize];
    let mut pkt = Packet::data(PacketKind::StaticReduce, me, root);
    pkt.tenant = spec.tenant;
    pkt.block = idx;
    pkt.tree = tree;
    pkt.counter = 1;
    pkt.hosts = spec.participants.len() as u32;
    pkt.wire_bytes = spec.wire_bytes();
    pkt.flow = ((me as u64) << 32) | idx as u64;
    if ctx.cfg.carry_values {
        pkt.payload = Payload::Lanes(
            spec.payload_of(me, idx, spec.lanes()).into_boxed_slice(),
        );
    }
    ctx.send(0, pkt);
}

pub fn on_broadcast(
    me: NodeId,
    sh: &mut StaticHost,
    ctx: &mut Ctx,
    pid: PacketId,
) {
    let pkt = ctx.take(pid);
    let idx = pkt.block;
    if idx >= sh.total_blocks || sh.done[idx as usize] {
        return;
    }
    sh.done[idx as usize] = true;
    sh.done_count += 1;
    sh.inflight = sh.inflight.saturating_sub(1);
    if let Some(lanes) = pkt.payload.lanes() {
        let rank = sh.rank;
        ctx.jobs[sh.job as usize].record_result(rank, idx, lanes);
    }
    if sh.stalled {
        sh.stalled = false;
        // resume the stream; refills are not noise-delayed (the noise
        // draw happens on the pacing clock)
        let mut quiet = Rng::new(0);
        pump(me, sh, &mut quiet, ctx);
    }
    if sh.done_count == sh.total_blocks && !sh.finished {
        sh.finished = true;
        let rank = sh.rank;
        let now = ctx.now;
        ctx.tracer
            .span(now, SpanKind::HostDone, sh.job, me, None, rank as u64);
        ctx.jobs[sh.job as usize].host_finished(rank, now);
    }
}

pub fn on_timer(
    me: NodeId,
    sh: &mut StaticHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    timer: u64,
) {
    let (kind, _job, _idx, _aux) = super::decode_timer(timer);
    if kind == TIMER_STREAM {
        pump(me, sh, rng, ctx);
    }
}
