//! Canary host protocol: line-rate self-clocked injection, the leader
//! role (final aggregation, broadcast, tree restoration, retransmission
//! handling — Sections 3.1.4, 3.2.1, 3.3) and the host-side loss
//! recovery (retransmission requests, retry rounds, host-based fallback).
//!
//! Hosts stream their blocks open-loop at line rate, as in the paper's
//! calibrated simulations; the number of in-flight blocks is then bounded
//! by the bandwidth-delay product (the Section 3.2.2 memory model relies
//! on exactly this). An optional window (`SimConfig::host_window > 0`)
//! caps in-flight blocks for memory-constrained scenarios.

use std::collections::HashMap;

use crate::sim::packet::{Packet, PacketKind, Payload};
use crate::sim::{Ctx, NodeId, PacketId, Time};
use crate::trace::SpanKind;
use crate::util::rng::Rng;

use super::{
    encode_timer, TIMER_DELAYED_SEND, TIMER_RETRANS, TIMER_STREAM,
};

/// Leader-side state for one block this host leads (Section 3.1.4).
#[derive(Debug, Default)]
pub struct LeaderBlock {
    /// Current retry round; stale-round packets are discarded.
    pub round: u8,
    /// Contributions aggregated so far (incl. our own once added).
    pub counter: u32,
    pub own_added: bool,
    pub acc: Option<Vec<i32>>,
    /// Collided switches -> children-port bitmap to restore.
    pub restore: HashMap<NodeId, u64>,
    pub complete: bool,
    pub result: Option<Vec<i32>>,
    /// Last failure-notice time (rate-limits retry rounds).
    pub last_failure: Time,
    /// When the first *packet* contribution of the current round landed
    /// — the leader's aggregation wait for the flight recorder (the
    /// leader's own locally-added share is deliberately excluded so the
    /// critical-path walk descends into the reduce DAG).
    pub first_contrib_ps: Option<Time>,
}

/// Canary protocol state for one participating host.
pub struct CanaryHost {
    pub job: u32,
    pub rank: u32,
    pub total_blocks: u32,
    /// Next block index the injection stream will emit.
    pub next_block: u32,
    pub inflight: u32,
    /// Stream paused waiting for window space.
    pub stalled: bool,
    pub done: Vec<bool>,
    pub done_count: u32,
    pub finished: bool,
    /// Blocks this host leads, by original block index.
    pub leader: HashMap<u32, LeaderBlock>,
    /// Retry round per block as known by this host.
    pub round: Vec<u8>,
}

impl CanaryHost {
    pub fn new(job: u32, rank: u32, total_blocks: u32) -> CanaryHost {
        CanaryHost {
            job,
            rank,
            total_blocks,
            next_block: 0,
            inflight: 0,
            stalled: false,
            done: vec![false; total_blocks as usize],
            done_count: 0,
            finished: false,
            leader: HashMap::new(),
            round: vec![0; total_blocks as usize],
        }
    }

    fn wire_id(&self, idx: u32) -> u32 {
        idx + self.round[idx as usize] as u32 * self.total_blocks
    }

    fn orig_of(&self, wire_id: u32) -> u32 {
        wire_id % self.total_blocks
    }
}

/// Job start: begin the line-rate injection stream.
pub fn on_wake(me: NodeId, ch: &mut CanaryHost, rng: &mut Rng, ctx: &mut Ctx) {
    pump(me, ch, rng, ctx);
}

/// Emit the next block, then re-arm the stream clock one serialization
/// interval later (line-rate pacing; the NIC queue never builds up).
fn pump(me: NodeId, ch: &mut CanaryHost, rng: &mut Rng, ctx: &mut Ctx) {
    if ch.next_block >= ch.total_blocks {
        return;
    }
    let window = ctx.jobs[ch.job as usize].spec.window;
    if window > 0 && ch.inflight >= window {
        ch.stalled = true; // resume on next completion
        return;
    }
    // NIC pacing: when the uplink is backpressured (paused leaf), hold
    // the stream so the host queue stays bounded
    let wire_bytes = ctx.jobs[ch.job as usize].spec.wire_bytes() as u64;
    if ctx.port_class0_bytes(0) > 8 * wire_bytes {
        let retry = wire_bytes * ctx.cfg.link_ps_per_byte;
        ctx.host_timer(retry, encode_timer(TIMER_STREAM, ch.job, 0, 0));
        return;
    }
    let idx = ch.next_block;
    ch.next_block += 1;
    ch.inflight += 1;
    if idx == 0 {
        ctx.tracer
            .span(ctx.now, SpanKind::FirstSend, ch.job, me, Some(idx), 0);
    }
    if idx + 1 == ch.total_blocks {
        ctx.tracer
            .span(ctx.now, SpanKind::LastSend, ch.job, me, Some(idx), 0);
    }
    activate_block(me, ch, ctx, idx);

    let wire = ctx.jobs[ch.job as usize].spec.wire_bytes() as u64
        * ctx.cfg.link_ps_per_byte;
    // OS noise (Section 5.2.5): with probability p the next transmission
    // is delayed by `noise_delay_ps` (the stream blocks, as real OS
    // noise would block the sending process)
    let mut gap = wire;
    if ctx.cfg.noise_prob > 0.0 && rng.chance(ctx.cfg.noise_prob) {
        gap += ctx.cfg.noise_delay_ps;
    }
    ctx.host_timer(gap, encode_timer(TIMER_STREAM, ch.job, 0, 0));
}

fn activate_block(me: NodeId, ch: &mut CanaryHost, ctx: &mut Ctx, idx: u32) {
    let spec = &ctx.jobs[ch.job as usize].spec;
    let leader = spec.leader_of(idx);
    if leader == me {
        leader_add_own(me, ch, ctx, idx);
    } else {
        send_data_now(me, ch, ctx, idx, false);
        if ctx.cfg.arm_retrans_timers {
            let retrans = ctx.cfg.retrans_timeout_ps;
            ctx.host_timer(
                retrans,
                encode_timer(TIMER_RETRANS, ch.job, idx, 0),
            );
        }
    }
}

fn send_data_now(
    me: NodeId,
    ch: &mut CanaryHost,
    ctx: &mut Ctx,
    idx: u32,
    direct: bool,
) {
    let spec = &ctx.jobs[ch.job as usize].spec;
    let leader = spec.leader_of(idx);
    let tenant = spec.tenant;
    let hosts = spec.participants.len() as u32;
    let lanes = spec.lanes();
    let wire = spec.wire_bytes();
    let payload = ctx
        .cfg
        .carry_values
        .then(|| spec.payload_of(me, idx, lanes));
    let kind = if direct {
        PacketKind::CanaryDirect
    } else {
        PacketKind::CanaryReduce
    };
    let mut pkt = Packet::data(kind, me, leader);
    pkt.tenant = tenant;
    pkt.block = ch.wire_id(idx);
    pkt.counter = 1;
    pkt.hosts = hosts;
    pkt.bypass = direct;
    pkt.wire_bytes = wire;
    pkt.flow = ((me as u64) << 32) | pkt.block as u64;
    if let Some(p) = payload {
        pkt.payload = Payload::Lanes(p.into_boxed_slice());
    }
    ctx.send(0, pkt);
}

/// Leader folds its own contribution in locally (it never hits the wire,
/// Section 3.1.4).
fn leader_add_own(me: NodeId, ch: &mut CanaryHost, ctx: &mut Ctx, idx: u32) {
    let spec = &ctx.jobs[ch.job as usize].spec;
    let lanes = spec.lanes();
    let own = ctx
        .cfg
        .carry_values
        .then(|| spec.payload_of(me, idx, lanes));
    let lb = ch.leader.entry(idx).or_default();
    debug_assert!(!lb.own_added);
    lb.own_added = true;
    lb.counter += 1;
    if let Some(own) = own {
        match &mut lb.acc {
            Some(acc) => crate::switch::alu::sat_accumulate(acc, &own),
            None => lb.acc = Some(own),
        }
    }
    leader_check_complete(me, ch, ctx, idx);
}

/// Packet arrival at a Canary host (takes ownership of the arena
/// entry — hosts terminate every packet addressed to them).
pub fn on_packet(
    me: NodeId,
    ch: &mut CanaryHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    pid: PacketId,
) {
    let pkt = ctx.take(pid);
    match pkt.kind {
        PacketKind::CanaryReduce | PacketKind::CanaryDirect => {
            leader_on_contribution(me, ch, rng, ctx, pkt)
        }
        PacketKind::CanaryBroadcast | PacketKind::CanaryRetransData => {
            let orig = ch.orig_of(pkt.block);
            mark_done(me, ch, rng, ctx, orig, pkt.payload.lanes());
        }
        PacketKind::CanaryRetransReq => {
            leader_on_retrans_req(me, ch, rng, ctx, pkt)
        }
        PacketKind::CanaryFailure => on_failure_notice(me, ch, ctx, pkt),
        _ => {}
    }
}

/// Leader: aggregate an arriving (partial) contribution.
fn leader_on_contribution(
    me: NodeId,
    ch: &mut CanaryHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    pkt: Packet,
) {
    let orig = ch.orig_of(pkt.block);
    let round = (pkt.block / ch.total_blocks) as u8;
    let lb = ch.leader.entry(orig).or_default();
    if round != lb.round || lb.complete {
        return; // stale round, or late straggler after completion
    }
    if lb.first_contrib_ps.is_none() {
        lb.first_contrib_ps = Some(ctx.now);
    }
    lb.counter += pkt.counter;
    crate::switch::alu::fold_payload(&mut lb.acc, pkt.payload);
    if let Some((sw, port)) = pkt.collision {
        *lb.restore.entry(sw).or_insert(0) |= 1u64 << port;
    }
    leader_check_complete(me, ch, ctx, orig);
    let _ = rng;
}

fn leader_check_complete(
    me: NodeId,
    ch: &mut CanaryHost,
    ctx: &mut Ctx,
    idx: u32,
) {
    let hosts = ctx.jobs[ch.job as usize].spec.participants.len() as u32;
    let tenant = ctx.jobs[ch.job as usize].spec.tenant;
    let wire = ctx.jobs[ch.job as usize].spec.wire_bytes();
    // reduce: the result stays here — the "broadcast" shrinks to a
    // header-only release wave that still frees switch descriptors and
    // unblocks the contributors' windows (Section 6)
    let stays = ctx.jobs[ch.job as usize]
        .spec
        .collective
        .result_stays_at_root();
    let Some(lb) = ch.leader.get_mut(&idx) else { return };
    if lb.complete || !lb.own_added || lb.counter < hosts {
        return;
    }
    lb.complete = true;
    ctx.tracer.span(
        ctx.now,
        SpanKind::Aggregated,
        ch.job,
        me,
        Some(idx),
        hosts as u64,
    );
    lb.result = lb.acc.take();
    let result = lb.result.clone();
    let mut restores: Vec<(NodeId, u64)> =
        lb.restore.iter().map(|(&k, &v)| (k, v)).collect();
    restores.sort_unstable_by_key(|&(sw, _)| sw);
    let first_contrib = lb.first_contrib_ps;
    let wire_id = ch.wire_id(idx);
    // flight recorder: leader residency from the first packet
    // contribution until completion is this block's final agg wait
    if let Some(t0) = first_contrib {
        ctx.tracer.wait(crate::trace::WaitRecord {
            tenant,
            block: wire_id,
            node: me,
            t_start: t0,
            t_end: ctx.now,
            via_timeout: false,
        });
    }
    let bcast_wire = if stays { 64 } else { wire };
    let bcast_payload = if stays { None } else { result.as_ref() };

    // broadcast down the recorded dynamic tree (single packet up to our
    // leaf, which fans out along descriptor children)
    if hosts > 1 {
        let mut pkt = Packet::data(PacketKind::CanaryBroadcast, me, me);
        pkt.tenant = tenant;
        pkt.block = wire_id;
        pkt.counter = hosts;
        pkt.hosts = hosts;
        pkt.wire_bytes = bcast_wire;
        if let Some(r) = bcast_payload {
            pkt.payload = Payload::Lanes(r.clone().into_boxed_slice());
        }
        ctx.send(0, pkt);
        ctx.tracer.span(
            ctx.now,
            SpanKind::Broadcast,
            ch.job,
            me,
            Some(idx),
            hosts as u64,
        );
    }
    // tree restoration packets for collided switches (Section 3.2.1),
    // in switch-id order so seeded runs emit them identically
    for (sw, bitmap) in restores {
        let mut pkt = Packet::data(PacketKind::CanaryRestore, me, sw);
        pkt.tenant = tenant;
        pkt.block = wire_id;
        pkt.hosts = hosts;
        pkt.restore = bitmap;
        pkt.wire_bytes = bcast_wire;
        if let Some(r) = bcast_payload {
            pkt.payload = Payload::Lanes(r.clone().into_boxed_slice());
        }
        ctx.send(0, pkt);
    }

    // our own copy of the block is complete
    let lanes = result;
    let mut quiet = Rng::new(0);
    mark_done(me, ch, &mut quiet, ctx, idx, lanes.as_deref());
}

/// Leader: a host suspects loss for `pkt.block` (Section 3.3).
fn leader_on_retrans_req(
    me: NodeId,
    ch: &mut CanaryHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    pkt: Packet,
) {
    ctx.metrics.retrans_requests += 1;
    let orig = ch.orig_of(pkt.block);
    ctx.tracer.span(
        ctx.now,
        SpanKind::RetransReq,
        ch.job,
        me,
        Some(orig),
        pkt.src as u64,
    );
    let spec = &ctx.jobs[ch.job as usize].spec;
    let tenant = spec.tenant;
    let hosts = spec.participants.len() as u32;
    let participants = spec.participants.clone();
    let wire = spec.wire_bytes();
    let stays = spec.collective.result_stays_at_root();
    let retrans_timeout = ctx.cfg.retrans_timeout_ps;
    let now = ctx.now;

    let wire_id = ch.wire_id(orig);
    let lb = ch.leader.entry(orig).or_default();
    if lb.complete {
        // loss was in the broadcast phase: re-send the reduced data
        // at full wire size (header-only for a reduce, whose result
        // stays at the root)
        let mut out = Packet::data(PacketKind::CanaryRetransData, me, pkt.src);
        out.tenant = tenant;
        out.block = wire_id;
        out.hosts = hosts;
        out.wire_bytes = if stays { 64 } else { wire };
        if !stays {
            if let Some(r) = &lb.result {
                out.payload = Payload::Lanes(r.clone().into_boxed_slice());
            }
        }
        ctx.send(0, out);
        return;
    }
    // loss was in the reduce phase: the leader cannot know which packet
    // died -> re-issue the whole block under a fresh id (rate-limited)
    if now.saturating_sub(lb.last_failure) < retrans_timeout
        && lb.last_failure != 0
    {
        return;
    }
    lb.last_failure = now;
    lb.round += 1;
    lb.counter = 0;
    lb.acc = None;
    lb.own_added = false;
    lb.restore.clear();
    lb.first_contrib_ps = None;
    let round = lb.round;
    ch.round[orig as usize] = round;
    ctx.metrics.failures += 1;
    ctx.tracer.span(
        ctx.now,
        SpanKind::RetryRound,
        ch.job,
        me,
        Some(orig),
        round as u64,
    );

    for &h in participants.iter() {
        if h == me {
            continue;
        }
        let mut out = Packet::data(PacketKind::CanaryFailure, me, h);
        out.tenant = tenant;
        out.block = orig; // original index; new round in meta
        out.meta = round as u64;
        out.hosts = hosts;
        out.wire_bytes = 64;
        ctx.send(0, out);
    }
    // re-fold our own contribution under the new round
    leader_add_own(me, ch, ctx, orig);
    let _ = rng;
}

/// Host: the leader asked us to re-issue a block under a new round.
fn on_failure_notice(
    me: NodeId,
    ch: &mut CanaryHost,
    ctx: &mut Ctx,
    pkt: Packet,
) {
    let idx = pkt.block;
    let new_round = pkt.meta as u8;
    if idx >= ch.total_blocks
        || ch.done[idx as usize]
        || ch.round[idx as usize] >= new_round
        || idx >= ch.next_block
    {
        return; // done, stale, or not yet streamed (leader will get it)
    }
    ch.round[idx as usize] = new_round;
    // blocks that failed too often go host-based (Section 3.3)
    let direct = new_round as u32 >= ctx.cfg.max_retries;
    if direct {
        ctx.metrics.fallbacks += 1;
        ctx.tracer.span(
            ctx.now,
            SpanKind::Fallback,
            ch.job,
            me,
            Some(idx),
            new_round as u64,
        );
    }
    send_data_now(me, ch, ctx, idx, direct);
    if ctx.cfg.arm_retrans_timers {
        let retrans = ctx.cfg.retrans_timeout_ps;
        ctx.host_timer(
            retrans,
            encode_timer(TIMER_RETRANS, ch.job, idx, new_round),
        );
    }
}

/// A block's fully-reduced data arrived (broadcast or retransmission).
fn mark_done(
    me: NodeId,
    ch: &mut CanaryHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    idx: u32,
    lanes: Option<&[i32]>,
) {
    if ch.done[idx as usize] {
        return;
    }
    ch.done[idx as usize] = true;
    ch.done_count += 1;
    ch.inflight = ch.inflight.saturating_sub(1);
    if let Some(lanes) = lanes {
        let rank = ch.rank;
        ctx.jobs[ch.job as usize].record_result(rank, idx, lanes);
    }
    if ch.stalled {
        ch.stalled = false;
        pump(me, ch, rng, ctx);
    }
    if ch.done_count == ch.total_blocks && !ch.finished {
        ch.finished = true;
        let rank = ch.rank;
        let now = ctx.now;
        ctx.tracer
            .span(now, SpanKind::HostDone, ch.job, me, None, rank as u64);
        ctx.jobs[ch.job as usize].host_finished(rank, now);
    }
}

/// Host timers: the stream clock, retransmission checks, delayed sends.
pub fn on_timer(
    me: NodeId,
    ch: &mut CanaryHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    timer: u64,
) {
    let (kind, _job, idx, retry) = super::decode_timer(timer);
    match kind {
        TIMER_STREAM => pump(me, ch, rng, ctx),
        TIMER_RETRANS => {
            if ch.done[idx as usize] {
                return;
            }
            let spec = &ctx.jobs[ch.job as usize].spec;
            let leader = spec.leader_of(idx);
            let tenant = spec.tenant;
            let mut req =
                Packet::data(PacketKind::CanaryRetransReq, me, leader);
            req.tenant = tenant;
            req.block = ch.wire_id(idx);
            req.hosts = spec.participants.len() as u32;
            req.wire_bytes = 64; // header-only control packet
            ctx.send(0, req);
            if retry as u32 >= ctx.cfg.max_retries {
                ctx.metrics.fallbacks += 1;
                send_data_now(me, ch, ctx, idx, true);
            }
            let backoff =
                ctx.cfg.retrans_timeout_ps << (retry.min(5) as u64);
            ctx.host_timer(
                backoff,
                encode_timer(
                    TIMER_RETRANS,
                    ch.job,
                    idx,
                    retry.saturating_add(1),
                ),
            );
        }
        TIMER_DELAYED_SEND => {
            if !ch.done[idx as usize] {
                send_data_now(me, ch, ctx, idx, false);
            }
        }
        _ => {}
    }
}
