//! Background congestion generator: the paper's random uniform injection
//! pattern (Section 5.2). Each host streams at line rate; every
//! `bg_message_bytes` it re-draws a uniformly random destination, so the
//! congestion pattern keeps shifting and exercises Canary's adaptivity.

use crate::sim::packet::{Packet, PacketKind};
use crate::sim::{Ctx, NodeId};
use crate::util::rng::Rng;

/// Background-traffic state for one host.
pub struct BgHost {
    pub job: u32,
    /// Packets left in the current message.
    pub remaining: u32,
    pub dst: NodeId,
    pub msg_count: u64,
}

impl BgHost {
    pub fn new(job: u32) -> BgHost {
        BgHost {
            job,
            remaining: 0,
            dst: 0,
            msg_count: 0,
        }
    }
}

/// Self-clocked injection: one packet per wire-serialization interval,
/// i.e. exactly line rate at the NIC.
pub fn on_wake(
    me: NodeId,
    bg: &mut BgHost,
    rng: &mut Rng,
    ctx: &mut Ctx,
    job: u32,
) {
    if bg.remaining == 0 {
        // new message: pick a random peer (not ourselves)
        let participants = &ctx.jobs[bg.job as usize].spec.participants;
        if participants.len() < 2 {
            return;
        }
        loop {
            let cand = *rng.choose(participants);
            if cand != me {
                bg.dst = cand;
                break;
            }
        }
        let payload = ctx.cfg.payload_bytes as u64;
        bg.remaining = (ctx.cfg.bg_message_bytes.div_ceil(payload)).max(1)
            as u32;
        bg.msg_count += 1;
    }

    let mut pkt = Packet::data(PacketKind::Background, me, bg.dst);
    pkt.wire_bytes = ctx.cfg.wire_bytes();
    pkt.flow = ((me as u64) << 32) | bg.msg_count;
    let wire = pkt.wire_bytes as u64;
    ctx.send(0, pkt);
    bg.remaining -= 1;

    let next = wire * ctx.cfg.link_ps_per_byte;
    ctx.wake(next, job);
}
