//! Legacy home of the background congestion generator.
//!
//! The paper's random uniform injection pattern (Section 5.2) used to be
//! implemented here as a standalone state machine; it is now the
//! `uniform` pattern of the flow-level traffic engine
//! ([`crate::traffic`]), which adds permutation/incast/hotspot/empirical
//! patterns, closed- vs open-loop injection and per-flow FCT tracking.
//! The engine's closed-loop uniform path is bit-compatible with the old
//! generator (same RNG draws, packets and wake cadence —
//! `tests/traffic_engine.rs`); this module keeps the legacy names alive
//! for existing call sites.

pub use crate::traffic::engine::{on_packet, on_wake};

/// Legacy name for the per-host traffic-generator state.
pub type BgHost = crate::traffic::TrafficHost;
