//! Global simulation counters and post-run analysis helpers
//! (link-utilization distributions, average network utilization,
//! per-flow lifecycle / FCT tracking for the traffic engine, and
//! descriptor-memory accounting for the Section 3.2.2 model).

use std::collections::HashMap;

use crate::sim::{Network, PacketKind, Time};
use crate::util::stats::Histogram;

/// A background flow in flight: born at `born`, complete when all
/// `expected` packets have been delivered to the destination host.
#[derive(Clone, Debug)]
struct LiveFlow {
    born: Time,
    expected: u32,
    seen: u32,
}

/// Per-flow lifecycle tracking for the traffic engine
/// (`crate::traffic`): flow starts are registered by the generating
/// host, deliveries by the sink, and the flow-completion time (FCT) is
/// recorded when the last packet lands. Flows whose packets are dropped
/// by the overflow policer simply never complete — the completion
/// fraction is part of the signal.
#[derive(Clone, Debug, Default)]
pub struct FlowStats {
    pub started: u64,
    pub completed: u64,
    /// Application bytes offered by started flows.
    pub offered_bytes: u64,
    /// Application bytes delivered to sinks, first copies only
    /// (goodput; duplicates land in `dup_bytes`).
    pub delivered_bytes: u64,
    /// Completion time of every finished flow, in completion order on
    /// the owning shard (shard-concatenated after a sharded run — only
    /// percentiles are read from it, which are order-free).
    // fp: excluded(sample order is engine-layout-dependent; the multiset is fingerprinted via fct_digest)
    pub fct_ps: Vec<Time>,
    /// Commutative digest over the (flow, fct) multiset: each
    /// completion adds a hash of the pair, so the digest is identical
    /// for any completion order — the property that lets serial and
    /// sharded runs fingerprint identically while still pinning every
    /// individual flow-completion time.
    pub fct_digest: u64,
    // --- reactive-transport accounting (`crate::transport`) ---
    /// CE-marked data packets accepted at sinks.
    pub ecn_delivered: u64,
    /// CNPs emitted by sinks (DCQCN notification points).
    pub cnps_sent: u64,
    /// CNPs received by senders (<= sent: CNPs are droppable).
    pub cnps_received: u64,
    /// Cumulative ACKs received by senders.
    pub acks_received: u64,
    /// Data packets re-sent by RTO rounds.
    pub retrans_pkts: u64,
    /// Retransmitted copies a sink had already seen (deduplicated —
    /// they never count toward `delivered_bytes` or completion).
    pub dup_pkts: u64,
    /// Application bytes in those duplicate copies (throughput =
    /// `delivered_bytes + dup_bytes`, goodput = `delivered_bytes`).
    pub dup_bytes: u64,
    /// RTO timer firings that triggered a retransmission round.
    pub rto_fired: u64,
    /// Flows abandoned after exhausting their retry budget.
    pub abandoned: u64,
    live: HashMap<u64, LiveFlow>,
}

impl FlowStats {
    /// A host started (closed loop) or received the arrival of (open
    /// loop) a new flow of `expected_pkts` packets.
    pub fn on_start(
        &mut self,
        flow: u64,
        born: Time,
        expected_pkts: u32,
        bytes: u64,
    ) {
        self.on_offer(bytes);
        self.register(flow, born, expected_pkts);
    }

    /// Sender half of [`FlowStats::on_start`]: offered-load accounting
    /// only. Split out for the sharded engine, where the sender and the
    /// sink of a flow may live on different shards ([`Ctx::flow_start`]
    /// books the offer locally and hands the registration off).
    ///
    /// [`Ctx::flow_start`]: crate::sim::Ctx::flow_start
    pub fn on_offer(&mut self, bytes: u64) {
        self.started += 1;
        self.offered_bytes += bytes;
    }

    /// Sink half of [`FlowStats::on_start`]: make the flow live so its
    /// deliveries are tracked toward an FCT.
    pub fn register(&mut self, flow: u64, born: Time, expected_pkts: u32) {
        self.live.insert(
            flow,
            LiveFlow {
                born,
                expected: expected_pkts,
                seen: 0,
            },
        );
    }

    /// One packet of `flow` reached its destination host.
    pub fn on_delivery(&mut self, flow: u64, now: Time, bytes: u64) {
        self.delivered_bytes += bytes;
        if let Some(f) = self.live.get_mut(&flow) {
            f.seen += 1;
            if f.seen >= f.expected {
                let born = f.born;
                self.live.remove(&flow);
                self.completed += 1;
                let fct = now.saturating_sub(born);
                self.fct_ps.push(fct);
                let mut s = fct ^ flow.rotate_left(17);
                self.fct_digest = self
                    .fct_digest
                    .wrapping_add(crate::util::rng::splitmix64(&mut s));
            }
        }
    }

    /// Fold one shard's flow accounting into `self` (sharded-engine
    /// merge): counters add, FCT samples concatenate in shard order
    /// (percentile-safe — only the digest is fingerprinted), and the
    /// still-live maps union (a flow is tracked by exactly one sink, so
    /// the key sets are disjoint).
    pub fn merge(&mut self, other: &FlowStats) {
        self.started += other.started;
        self.completed += other.completed;
        self.offered_bytes += other.offered_bytes;
        self.delivered_bytes += other.delivered_bytes;
        self.fct_ps.extend_from_slice(&other.fct_ps);
        self.fct_digest = self.fct_digest.wrapping_add(other.fct_digest);
        self.ecn_delivered += other.ecn_delivered;
        self.cnps_sent += other.cnps_sent;
        self.cnps_received += other.cnps_received;
        self.acks_received += other.acks_received;
        self.retrans_pkts += other.retrans_pkts;
        self.dup_pkts += other.dup_pkts;
        self.dup_bytes += other.dup_bytes;
        self.rto_fired += other.rto_fired;
        self.abandoned += other.abandoned;
        // lint: allow(unordered-iter, disjoint-key map union; insertion order never observed)
        for (k, v) in &other.live {
            self.live.insert(*k, v.clone());
        }
    }

    /// Flows started but not yet (or never) completed.
    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Ids of in-flight flows, sorted so any report or export of
    /// live-flow state is byte-stable across processes (the backing
    /// map is hash-ordered).
    pub fn live_flow_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.live.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Completed / started (0 when no flow ever started).
    pub fn completion_fraction(&self) -> f64 {
        if self.started == 0 {
            0.0
        } else {
            self.completed as f64 / self.started as f64
        }
    }

    /// FCT percentile in microseconds over completed flows
    /// (`q` in `[0, 100]`; 0 when nothing completed).
    pub fn fct_percentile_us(&self, q: f64) -> f64 {
        self.fct_percentiles_us(&[q])[0]
    }

    /// Goodput bytes: unique application bytes that reached sinks.
    pub fn goodput_bytes(&self) -> u64 {
        self.delivered_bytes
    }

    /// Throughput bytes: everything sinks absorbed, duplicates
    /// included — the wire cost of loss recovery.
    pub fn throughput_bytes(&self) -> u64 {
        self.delivered_bytes + self.dup_bytes
    }

    /// Several FCT percentiles at once — converts and sorts the sample
    /// vector a single time.
    pub fn fct_percentiles_us(&self, qs: &[f64]) -> Vec<f64> {
        let mut us: Vec<f64> = self
            .fct_ps
            .iter()
            .map(|&p| crate::sim::ps_to_us(p))
            .collect();
        us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        qs.iter()
            .map(|&q| crate::util::stats::percentile_sorted(&us, q))
            .collect()
    }
}

/// Engine-throughput numbers for the run (filled in by
/// `Network::run`/`run_all` when a run segment ends). `wall_secs` is
/// host wall-clock measurement — the only non-deterministic field in
/// all of [`Metrics`]; it never feeds back into the simulation and is
/// excluded from [`Metrics::fingerprint`].
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Events dispatched (mirror of `Network::events_processed`).
    pub events: u64,
    /// Wall-clock seconds spent in the dispatch loop (accumulated over
    /// `run`/`run_all` segments).
    pub wall_secs: f64,
    /// Peak simultaneously-live packets in the arena. After a sharded
    /// run: sum of per-shard peaks (an upper bound on the serial peak —
    /// the shard peaks need not coincide in time).
    // fp: excluded(capacity gauge depends on the engine layout: per-shard peaks sum to an overestimate)
    pub peak_live_packets: u64,
    /// Arena slab size — equals the peak, since freed slots recycle.
    // fp: excluded(capacity gauge depends on the engine layout, like peak_live_packets)
    pub arena_slots: u64,
    /// Packet allocations served (slab growth + free-list reuse).
    // fp: excluded(cross-shard handoffs re-allocate on the owner shard, inflating the count vs serial)
    pub arena_allocs: u64,
}

impl EngineStats {
    /// Events per wall-clock second (0 when nothing ran).
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.events as f64 / self.wall_secs
        }
    }
}

/// Counters accumulated during a run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub pkts_delivered: u64,
    /// Deliveries by packet kind. Index through [`Metrics::on_delivery`]
    /// / [`Metrics::pkts_of_kind`], never by raw arithmetic — a new
    /// `PacketKind` variant then can't silently misalign counters.
    pub pkts_by_kind: [u64; PacketKind::COUNT],
    /// Droppable (background) packets lost to queue overflow.
    pub drops_overflow: u64,
    /// Class-1 packets CE-marked by switch queues (each packet is
    /// marked at most once, at the first over-threshold hop).
    pub ecn_marks: u64,
    /// Packets lost because a link/switch was down.
    pub drops_link_down: u64,
    /// Random loss injected by the fault plan.
    pub drops_injected: u64,
    /// Canary: packets that arrived after their descriptor's timeout and
    /// were forwarded immediately (Section 3.1.1).
    pub stragglers: u64,
    /// Canary: descriptor-table collisions (Section 3.2.1).
    pub collisions: u64,
    /// Canary: restoration packets sent by leaders.
    pub restorations: u64,
    /// Canary: retransmission requests received by leaders.
    pub retrans_requests: u64,
    /// Canary: failure notices broadcast (block retried from scratch).
    pub failures: u64,
    /// Blocks that fell back to the host-based path.
    pub fallbacks: u64,
    /// Switch failures injected.
    pub switch_failures: u64,
    /// Switch recoveries fired (churn timeline).
    pub switch_recoveries: u64,
    /// Link-down flap edges fired (churn timeline).
    pub link_flaps: u64,
    /// Link-up flap edges fired (churn timeline).
    pub link_recoveries: u64,
    /// Straggler hosts installed with a slowdown factor > 1.
    pub straggler_slowdowns: u64,
    /// Canary: descriptor timeouts that fired with an incomplete
    /// contribution counter and forwarded a *partial* aggregate —
    /// the paper's best-effort escape hatch (Section 3.1.1). Zero on
    /// a clean run: complete blocks forward from `on_reduce`, and a
    /// timeout finding `counter == hosts` is a straggler-passthrough
    /// race, not a partial emission.
    pub partial_aggregates: u64,
    /// Allreduce jobs that finished within the run's time bound...
    pub jobs_completed: u64,
    /// ...and those that did not (stalled/aborted — the documented
    /// degradation outcome for engines without recovery machinery).
    pub jobs_stalled: u64,
    /// Descriptor allocations / deallocations (leak check: must balance
    /// at the end of a clean run).
    pub descriptors_allocated: u64,
    pub descriptors_freed: u64,
    /// High-water mark of live descriptors over all switches. After a
    /// sharded run: sum of per-shard high-water marks (upper bound).
    // fp: excluded(capacity gauge depends on the engine layout: per-shard peaks sum to an overestimate)
    pub descriptor_high_water: u64,
    /// Currently live descriptors (maintained by the dataplane).
    // fp: excluded(gauge: always descriptors_allocated - descriptors_freed, both already mixed)
    pub descriptors_live: u64,
    /// Sum over descriptors of (dealloc - alloc) time, for mean residency.
    pub descriptor_residency_ps: u64,
    /// Background-flow lifecycle tracking (traffic engine).
    pub flows: FlowStats,
    /// Engine throughput / packet-arena accounting.
    pub engine: EngineStats,
}

impl Metrics {
    /// Count one delivered packet of `kind` (total + per-kind).
    #[inline]
    pub fn on_delivery(&mut self, kind: PacketKind) {
        self.pkts_delivered += 1;
        self.pkts_by_kind[kind as usize] += 1;
    }

    /// Deliveries of one packet kind (named accessor over the raw
    /// per-kind array).
    #[inline]
    pub fn pkts_of_kind(&self, kind: PacketKind) -> u64 {
        self.pkts_by_kind[kind as usize]
    }

    pub fn on_descriptor_alloc(&mut self) {
        self.descriptors_allocated += 1;
        self.descriptors_live += 1;
        self.descriptor_high_water =
            self.descriptor_high_water.max(self.descriptors_live);
    }

    pub fn on_descriptor_free(&mut self, residency: Time) {
        self.descriptors_freed += 1;
        self.descriptors_live = self.descriptors_live.saturating_sub(1);
        self.descriptor_residency_ps += residency;
    }

    /// Fold one shard's counters into `self` (sharded-engine merge).
    /// Every counter is owner-attributed — a delivery, drop, mark or
    /// descriptor op happens on exactly one shard — so plain sums
    /// reproduce the serial totals. High-water gauges sum to an upper
    /// bound (documented on the fields, excluded from the
    /// fingerprint). `engine` is deliberately untouched: the sharded
    /// engine fills it in once, from its own coordinator clock and the
    /// per-shard arenas (`sim/shard.rs`).
    pub fn merge(&mut self, other: &Metrics) {
        self.pkts_delivered += other.pkts_delivered;
        for (a, b) in
            self.pkts_by_kind.iter_mut().zip(&other.pkts_by_kind)
        {
            *a += b;
        }
        self.drops_overflow += other.drops_overflow;
        self.ecn_marks += other.ecn_marks;
        self.drops_link_down += other.drops_link_down;
        self.drops_injected += other.drops_injected;
        self.stragglers += other.stragglers;
        self.collisions += other.collisions;
        self.restorations += other.restorations;
        self.retrans_requests += other.retrans_requests;
        self.failures += other.failures;
        self.fallbacks += other.fallbacks;
        self.switch_failures += other.switch_failures;
        self.switch_recoveries += other.switch_recoveries;
        self.link_flaps += other.link_flaps;
        self.link_recoveries += other.link_recoveries;
        self.straggler_slowdowns += other.straggler_slowdowns;
        self.partial_aggregates += other.partial_aggregates;
        self.jobs_completed += other.jobs_completed;
        self.jobs_stalled += other.jobs_stalled;
        self.descriptors_allocated += other.descriptors_allocated;
        self.descriptors_freed += other.descriptors_freed;
        self.descriptor_high_water += other.descriptor_high_water;
        self.descriptors_live += other.descriptors_live;
        self.descriptor_residency_ps += other.descriptor_residency_ps;
        self.flows.merge(&other.flows);
    }

    /// One 64-bit digest of everything a run's outcome hangs on: event
    /// and delivery counts, every drop/protocol counter, the flow
    /// lifecycle totals and the commutative FCT digest. Two seeded
    /// runs of the same scenario must produce the same fingerprint bit
    /// for bit — at *any* shard count, which is why every mixed
    /// quantity is owner-attributed (sums over shards) and
    /// engine-layout gauges (arena peaks, high-water marks,
    /// wall-clock) are excluded — see the `fp: excluded` field
    /// annotations. The CI `determinism` job and `tests/pdes.rs` pin
    /// exactly this (`--fingerprint` on the CLI prints it).
    pub fn fingerprint(&self, now: Time, events: u64) -> u64 {
        let mut h: u64 = 0x9E37_79B9_7F4A_7C15;
        let mut mix = |x: u64| {
            let mut s = h ^ x.wrapping_mul(0xA24B_AED4_963E_E407);
            h = crate::util::rng::splitmix64(&mut s);
        };
        mix(events);
        mix(now);
        mix(self.pkts_delivered);
        for &k in &self.pkts_by_kind {
            mix(k);
        }
        mix(self.drops_overflow);
        mix(self.ecn_marks);
        mix(self.drops_link_down);
        mix(self.drops_injected);
        mix(self.stragglers);
        mix(self.collisions);
        mix(self.restorations);
        mix(self.retrans_requests);
        mix(self.failures);
        mix(self.fallbacks);
        mix(self.switch_failures);
        mix(self.switch_recoveries);
        mix(self.link_flaps);
        mix(self.link_recoveries);
        mix(self.straggler_slowdowns);
        mix(self.partial_aggregates);
        mix(self.jobs_completed);
        mix(self.jobs_stalled);
        mix(self.descriptors_allocated);
        mix(self.descriptors_freed);
        mix(self.descriptor_residency_ps);
        let f = &self.flows;
        mix(f.started);
        mix(f.completed);
        mix(f.offered_bytes);
        mix(f.delivered_bytes);
        mix(f.ecn_delivered);
        mix(f.cnps_sent);
        mix(f.cnps_received);
        mix(f.acks_received);
        mix(f.retrans_pkts);
        mix(f.dup_pkts);
        mix(f.dup_bytes);
        mix(f.rto_fired);
        mix(f.abandoned);
        mix(f.fct_digest);
        h
    }
}

/// Per-link utilization samples over a window, as in Fig. 7b / Fig. 10b
/// (each sample is one link; utilization = busy time / wall time).
pub fn link_utilizations(net: &Network, end: Time) -> Vec<f64> {
    (0..net.links.len())
        .map(|l| net.link_utilization(l, end))
        .collect()
}

/// Average network utilization (mean over all links), the scalar the
/// paper quotes alongside Fig. 7b (40.2 % / 29.5 % / 20.9 %).
pub fn average_network_utilization(net: &Network, end: Time) -> f64 {
    let u = link_utilizations(net, end);
    crate::util::stats::mean(&u)
}

/// Utilization histogram in the paper's Fig. 7b bucketing (10 % buckets).
pub fn utilization_histogram(net: &Network, end: Time) -> Histogram {
    let mut h = Histogram::new(0.0, 1.0, 10);
    for u in link_utilizations(net, end) {
        h.add(u);
    }
    h
}

/// Section 3.2.2 analytical bound on per-switch descriptor memory:
/// `b * (2d(l+t) + r)` bytes.
pub fn memory_model_bytes(
    bandwidth_bytes_per_s: f64,
    diameter: u32,
    hop_latency_s: f64,
    timeout_s: f64,
    leader_time_s: f64,
) -> f64 {
    bandwidth_bytes_per_s
        * (2.0 * diameter as f64 * (hop_latency_s + timeout_s)
            + leader_time_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_kind_delivery_accessors() {
        let mut m = Metrics::default();
        m.on_delivery(PacketKind::CanaryReduce);
        m.on_delivery(PacketKind::CanaryReduce);
        m.on_delivery(PacketKind::TransportCnp);
        assert_eq!(m.pkts_delivered, 3);
        assert_eq!(m.pkts_of_kind(PacketKind::CanaryReduce), 2);
        assert_eq!(m.pkts_of_kind(PacketKind::TransportCnp), 1);
        assert_eq!(m.pkts_of_kind(PacketKind::Ring), 0);
        // the named accessors index the same array the fingerprint
        // walks — the per-kind sum must match the delivered total
        assert_eq!(m.pkts_by_kind.iter().sum::<u64>(), m.pkts_delivered);
    }

    #[test]
    fn descriptor_accounting() {
        let mut m = Metrics::default();
        m.on_descriptor_alloc();
        m.on_descriptor_alloc();
        assert_eq!(m.descriptor_high_water, 2);
        m.on_descriptor_free(100);
        assert_eq!(m.descriptors_live, 1);
        m.on_descriptor_free(50);
        assert_eq!(m.descriptors_live, 0);
        assert_eq!(m.descriptors_allocated, m.descriptors_freed);
        assert_eq!(m.descriptor_residency_ps, 150);
    }

    #[test]
    fn flow_lifecycle_and_fct() {
        let mut f = FlowStats::default();
        f.on_start(1, 100, 2, 2048);
        f.on_start(2, 200, 1, 1024);
        assert_eq!(f.started, 2);
        assert_eq!(f.live_count(), 2);
        // out-of-order deliveries across flows
        f.on_delivery(2, 700, 1024);
        assert_eq!(f.completed, 1);
        assert_eq!(f.fct_ps, vec![500]);
        f.on_delivery(1, 400, 1024);
        assert_eq!(f.completed, 1, "flow 1 needs both packets");
        f.on_delivery(1, 900, 1024);
        assert_eq!(f.completed, 2);
        assert_eq!(f.fct_ps, vec![500, 800]);
        assert_eq!(f.live_count(), 0);
        assert_eq!(f.completion_fraction(), 1.0);
        assert_eq!(f.delivered_bytes, 3072);
        // unknown flow ids (e.g. pre-run stragglers) are byte-counted
        // but otherwise ignored
        f.on_delivery(99, 1000, 10);
        assert_eq!(f.completed, 2);
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let a = Metrics {
            pkts_delivered: 10,
            flows: FlowStats {
                fct_ps: vec![1, 2, 3],
                ..Default::default()
            },
            ..Default::default()
        };
        let mut b = a.clone();
        assert_eq!(a.fingerprint(99, 5), b.fingerprint(99, 5));
        // wall-clock must never perturb the digest
        b.engine.wall_secs = 123.4;
        assert_eq!(a.fingerprint(99, 5), b.fingerprint(99, 5));
        b.pkts_delivered += 1;
        assert_ne!(a.fingerprint(99, 5), b.fingerprint(99, 5));
        // the raw FCT sample *vector* is layout-dependent (shard
        // concatenation order) and must not feed the digest — the
        // multiset is pinned through fct_digest instead
        let mut c = a.clone();
        c.flows.fct_ps = vec![1, 3, 2];
        assert_eq!(a.fingerprint(99, 5), c.fingerprint(99, 5));
        c.flows.fct_digest = c.flows.fct_digest.wrapping_add(1);
        assert_ne!(a.fingerprint(99, 5), c.fingerprint(99, 5));
        // now and event count feed the digest too
        assert_ne!(a.fingerprint(99, 5), a.fingerprint(100, 5));
        assert_ne!(a.fingerprint(99, 5), a.fingerprint(99, 6));
    }

    #[test]
    fn fct_digest_is_commutative_and_sensitive() {
        // two flows completing in either order: same digest
        let run = |order: [(u64, Time); 2]| {
            let mut f = FlowStats::default();
            f.on_start(1, 100, 1, 10);
            f.on_start(2, 100, 1, 10);
            for (flow, at) in order {
                f.on_delivery(flow, at, 10);
            }
            f.fct_digest
        };
        assert_eq!(run([(1, 400), (2, 900)]), run([(2, 900), (1, 400)]));
        // a different completion time for the same flow: different digest
        assert_ne!(run([(1, 400), (2, 900)]), run([(1, 401), (2, 900)]));
        // the same FCT on a different flow id: different digest
        let mut f = FlowStats::default();
        f.on_start(3, 100, 1, 10);
        f.on_start(4, 100, 1, 10);
        f.on_delivery(3, 400, 10);
        let mut g = FlowStats::default();
        g.on_start(3, 100, 1, 10);
        g.on_start(4, 100, 1, 10);
        g.on_delivery(4, 400, 10);
        assert_ne!(f.fct_digest, g.fct_digest);
    }

    #[test]
    fn split_flow_start_halves_compose_and_merge() {
        // on_offer + register on separate stats (the cross-shard path)
        // must sum/merge to exactly what one on_start produces
        let mut serial = FlowStats::default();
        serial.on_start(7, 50, 2, 4096);
        serial.on_delivery(7, 300, 2048);
        serial.on_delivery(7, 700, 2048);

        let mut sender = FlowStats::default();
        sender.on_offer(4096);
        let mut sink = FlowStats::default();
        sink.register(7, 50, 2);
        sink.on_delivery(7, 300, 2048);
        sink.on_delivery(7, 700, 2048);
        let mut merged = FlowStats::default();
        merged.merge(&sender);
        merged.merge(&sink);
        assert_eq!(merged.started, serial.started);
        assert_eq!(merged.offered_bytes, serial.offered_bytes);
        assert_eq!(merged.completed, serial.completed);
        assert_eq!(merged.delivered_bytes, serial.delivered_bytes);
        assert_eq!(merged.fct_ps, serial.fct_ps);
        assert_eq!(merged.fct_digest, serial.fct_digest);
        assert_eq!(merged.live_count(), 0);
    }

    #[test]
    fn metrics_merge_sums_owner_attributed_counters() {
        let mut a = Metrics::default();
        a.on_delivery(PacketKind::CanaryReduce);
        a.on_descriptor_alloc();
        a.on_descriptor_free(40);
        a.link_flaps = 1;
        let mut b = Metrics::default();
        b.on_delivery(PacketKind::Background);
        b.on_delivery(PacketKind::CanaryReduce);
        b.drops_overflow = 3;
        let mut m = Metrics::default();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.pkts_delivered, 3);
        assert_eq!(m.pkts_of_kind(PacketKind::CanaryReduce), 2);
        assert_eq!(m.pkts_of_kind(PacketKind::Background), 1);
        assert_eq!(m.drops_overflow, 3);
        assert_eq!(m.link_flaps, 1);
        assert_eq!(m.descriptors_allocated, 1);
        assert_eq!(m.descriptors_freed, 1);
        assert_eq!(m.descriptor_residency_ps, 40);
        // merge order must not matter for the fingerprint
        let mut n = Metrics::default();
        n.merge(&b);
        n.merge(&a);
        assert_eq!(m.fingerprint(9, 9), n.fingerprint(9, 9));
    }

    #[test]
    fn engine_stats_throughput() {
        assert_eq!(EngineStats::default().events_per_sec(), 0.0);
        let e = EngineStats {
            events: 1_000_000,
            wall_secs: 0.5,
            ..Default::default()
        };
        assert!((e.events_per_sec() - 2_000_000.0).abs() < 1e-6);
    }

    #[test]
    fn fct_percentiles_in_us() {
        let mut f = FlowStats::default();
        for (i, fct) in [1_000_000u64, 2_000_000, 3_000_000]
            .into_iter()
            .enumerate()
        {
            let flow = i as u64;
            f.on_start(flow, 0, 1, 1);
            f.on_delivery(flow, fct, 1);
        }
        assert!((f.fct_percentile_us(50.0) - 2.0).abs() < 1e-9);
        assert!((f.fct_percentile_us(100.0) - 3.0).abs() < 1e-9);
        assert_eq!(FlowStats::default().fct_percentile_us(50.0), 0.0);
    }

    #[test]
    fn paper_memory_example() {
        // Paper: 100 Gbps, d=5, l=300ns, t=1us, r=1us => ~175 KiB
        let bytes = memory_model_bytes(12.5e9, 5, 300e-9, 1e-6, 1e-6);
        let kib = bytes / 1024.0;
        assert!(
            (kib - 175.0).abs() < 15.0,
            "expected ~175 KiB, got {kib:.1}"
        );
    }
}
