//! Global simulation counters and post-run analysis helpers
//! (link-utilization distributions, average network utilization,
//! per-flow lifecycle / FCT tracking for the traffic engine, and
//! descriptor-memory accounting for the Section 3.2.2 model).

use std::collections::HashMap;

use crate::sim::{Network, PacketKind, Time};
use crate::util::stats::Histogram;

/// A background flow in flight: born at `born`, complete when all
/// `expected` packets have been delivered to the destination host.
#[derive(Clone, Debug)]
struct LiveFlow {
    born: Time,
    expected: u32,
    seen: u32,
}

/// Per-flow lifecycle tracking for the traffic engine
/// (`crate::traffic`): flow starts are registered by the generating
/// host, deliveries by the sink, and the flow-completion time (FCT) is
/// recorded when the last packet lands. Flows whose packets are dropped
/// by the overflow policer simply never complete — the completion
/// fraction is part of the signal.
#[derive(Clone, Debug, Default)]
pub struct FlowStats {
    pub started: u64,
    pub completed: u64,
    /// Application bytes offered by started flows.
    pub offered_bytes: u64,
    /// Application bytes delivered to sinks, first copies only
    /// (goodput; duplicates land in `dup_bytes`).
    pub delivered_bytes: u64,
    /// Completion time of every finished flow, in event order.
    pub fct_ps: Vec<Time>,
    // --- reactive-transport accounting (`crate::transport`) ---
    /// CE-marked data packets accepted at sinks.
    pub ecn_delivered: u64,
    /// CNPs emitted by sinks (DCQCN notification points).
    pub cnps_sent: u64,
    /// CNPs received by senders (<= sent: CNPs are droppable).
    pub cnps_received: u64,
    /// Cumulative ACKs received by senders.
    pub acks_received: u64,
    /// Data packets re-sent by RTO rounds.
    pub retrans_pkts: u64,
    /// Retransmitted copies a sink had already seen (deduplicated —
    /// they never count toward `delivered_bytes` or completion).
    pub dup_pkts: u64,
    /// Application bytes in those duplicate copies (throughput =
    /// `delivered_bytes + dup_bytes`, goodput = `delivered_bytes`).
    pub dup_bytes: u64,
    /// RTO timer firings that triggered a retransmission round.
    pub rto_fired: u64,
    /// Flows abandoned after exhausting their retry budget.
    pub abandoned: u64,
    live: HashMap<u64, LiveFlow>,
}

impl FlowStats {
    /// A host started (closed loop) or received the arrival of (open
    /// loop) a new flow of `expected_pkts` packets.
    pub fn on_start(
        &mut self,
        flow: u64,
        born: Time,
        expected_pkts: u32,
        bytes: u64,
    ) {
        self.started += 1;
        self.offered_bytes += bytes;
        self.live.insert(
            flow,
            LiveFlow {
                born,
                expected: expected_pkts,
                seen: 0,
            },
        );
    }

    /// One packet of `flow` reached its destination host.
    pub fn on_delivery(&mut self, flow: u64, now: Time, bytes: u64) {
        self.delivered_bytes += bytes;
        if let Some(f) = self.live.get_mut(&flow) {
            f.seen += 1;
            if f.seen >= f.expected {
                let born = f.born;
                self.live.remove(&flow);
                self.completed += 1;
                self.fct_ps.push(now.saturating_sub(born));
            }
        }
    }

    /// Flows started but not yet (or never) completed.
    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Ids of in-flight flows, sorted so any report or export of
    /// live-flow state is byte-stable across processes (the backing
    /// map is hash-ordered).
    pub fn live_flow_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.live.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Completed / started (0 when no flow ever started).
    pub fn completion_fraction(&self) -> f64 {
        if self.started == 0 {
            0.0
        } else {
            self.completed as f64 / self.started as f64
        }
    }

    /// FCT percentile in microseconds over completed flows
    /// (`q` in `[0, 100]`; 0 when nothing completed).
    pub fn fct_percentile_us(&self, q: f64) -> f64 {
        self.fct_percentiles_us(&[q])[0]
    }

    /// Goodput bytes: unique application bytes that reached sinks.
    pub fn goodput_bytes(&self) -> u64 {
        self.delivered_bytes
    }

    /// Throughput bytes: everything sinks absorbed, duplicates
    /// included — the wire cost of loss recovery.
    pub fn throughput_bytes(&self) -> u64 {
        self.delivered_bytes + self.dup_bytes
    }

    /// Several FCT percentiles at once — converts and sorts the sample
    /// vector a single time.
    pub fn fct_percentiles_us(&self, qs: &[f64]) -> Vec<f64> {
        let mut us: Vec<f64> = self
            .fct_ps
            .iter()
            .map(|&p| crate::sim::ps_to_us(p))
            .collect();
        us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        qs.iter()
            .map(|&q| crate::util::stats::percentile_sorted(&us, q))
            .collect()
    }
}

/// Engine-throughput numbers for the run (filled in by
/// `Network::run`/`run_all` when a run segment ends). `wall_secs` is
/// host wall-clock measurement — the only non-deterministic field in
/// all of [`Metrics`]; it never feeds back into the simulation and is
/// excluded from [`Metrics::fingerprint`].
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Events dispatched (mirror of `Network::events_processed`).
    pub events: u64,
    /// Wall-clock seconds spent in the dispatch loop (accumulated over
    /// `run`/`run_all` segments).
    pub wall_secs: f64,
    /// Peak simultaneously-live packets in the arena.
    pub peak_live_packets: u64,
    /// Arena slab size — equals the peak, since freed slots recycle.
    pub arena_slots: u64,
    /// Packet allocations served (slab growth + free-list reuse).
    pub arena_allocs: u64,
}

impl EngineStats {
    /// Events per wall-clock second (0 when nothing ran).
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.events as f64 / self.wall_secs
        }
    }
}

/// Counters accumulated during a run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub pkts_delivered: u64,
    /// Deliveries by packet kind. Index through [`Metrics::on_delivery`]
    /// / [`Metrics::pkts_of_kind`], never by raw arithmetic — a new
    /// `PacketKind` variant then can't silently misalign counters.
    pub pkts_by_kind: [u64; PacketKind::COUNT],
    /// Droppable (background) packets lost to queue overflow.
    pub drops_overflow: u64,
    /// Class-1 packets CE-marked by switch queues (each packet is
    /// marked at most once, at the first over-threshold hop).
    pub ecn_marks: u64,
    /// Packets lost because a link/switch was down.
    pub drops_link_down: u64,
    /// Random loss injected by the fault plan.
    pub drops_injected: u64,
    /// Canary: packets that arrived after their descriptor's timeout and
    /// were forwarded immediately (Section 3.1.1).
    pub stragglers: u64,
    /// Canary: descriptor-table collisions (Section 3.2.1).
    pub collisions: u64,
    /// Canary: restoration packets sent by leaders.
    pub restorations: u64,
    /// Canary: retransmission requests received by leaders.
    pub retrans_requests: u64,
    /// Canary: failure notices broadcast (block retried from scratch).
    pub failures: u64,
    /// Blocks that fell back to the host-based path.
    pub fallbacks: u64,
    /// Switch failures injected.
    pub switch_failures: u64,
    /// Switch recoveries fired (churn timeline).
    pub switch_recoveries: u64,
    /// Link-down flap edges fired (churn timeline).
    pub link_flaps: u64,
    /// Link-up flap edges fired (churn timeline).
    pub link_recoveries: u64,
    /// Straggler hosts installed with a slowdown factor > 1.
    pub straggler_slowdowns: u64,
    /// Canary: descriptor timeouts that fired with an incomplete
    /// contribution counter and forwarded a *partial* aggregate —
    /// the paper's best-effort escape hatch (Section 3.1.1). Zero on
    /// a clean run: complete blocks forward from `on_reduce`, and a
    /// timeout finding `counter == hosts` is a straggler-passthrough
    /// race, not a partial emission.
    pub partial_aggregates: u64,
    /// Allreduce jobs that finished within the run's time bound...
    pub jobs_completed: u64,
    /// ...and those that did not (stalled/aborted — the documented
    /// degradation outcome for engines without recovery machinery).
    pub jobs_stalled: u64,
    /// Descriptor allocations / deallocations (leak check: must balance
    /// at the end of a clean run).
    pub descriptors_allocated: u64,
    pub descriptors_freed: u64,
    /// High-water mark of live descriptors over all switches.
    pub descriptor_high_water: u64,
    /// Currently live descriptors (maintained by the dataplane).
    // fp: excluded(gauge: always descriptors_allocated - descriptors_freed, both already mixed)
    pub descriptors_live: u64,
    /// Sum over descriptors of (dealloc - alloc) time, for mean residency.
    pub descriptor_residency_ps: u64,
    /// Background-flow lifecycle tracking (traffic engine).
    pub flows: FlowStats,
    /// Engine throughput / packet-arena accounting.
    pub engine: EngineStats,
}

impl Metrics {
    /// Count one delivered packet of `kind` (total + per-kind).
    #[inline]
    pub fn on_delivery(&mut self, kind: PacketKind) {
        self.pkts_delivered += 1;
        self.pkts_by_kind[kind as usize] += 1;
    }

    /// Deliveries of one packet kind (named accessor over the raw
    /// per-kind array).
    #[inline]
    pub fn pkts_of_kind(&self, kind: PacketKind) -> u64 {
        self.pkts_by_kind[kind as usize]
    }

    pub fn on_descriptor_alloc(&mut self) {
        self.descriptors_allocated += 1;
        self.descriptors_live += 1;
        self.descriptor_high_water =
            self.descriptor_high_water.max(self.descriptors_live);
    }

    pub fn on_descriptor_free(&mut self, residency: Time) {
        self.descriptors_freed += 1;
        self.descriptors_live = self.descriptors_live.saturating_sub(1);
        self.descriptor_residency_ps += residency;
    }

    /// One 64-bit digest of everything a run's outcome hangs on: event
    /// and delivery counts, every drop/protocol counter, the flow
    /// lifecycle totals and each recorded FCT sample, plus the
    /// deterministic arena peaks. Two seeded runs of the same scenario
    /// must produce the same fingerprint bit for bit — the CI
    /// `determinism` job and `tests/scheduler.rs` pin exactly this
    /// (`--fingerprint` on the CLI prints it). Wall-clock fields are
    /// excluded by construction.
    pub fn fingerprint(&self, now: Time, events: u64) -> u64 {
        let mut h: u64 = 0x9E37_79B9_7F4A_7C15;
        let mut mix = |x: u64| {
            let mut s = h ^ x.wrapping_mul(0xA24B_AED4_963E_E407);
            h = crate::util::rng::splitmix64(&mut s);
        };
        mix(events);
        mix(now);
        mix(self.pkts_delivered);
        for &k in &self.pkts_by_kind {
            mix(k);
        }
        mix(self.drops_overflow);
        mix(self.ecn_marks);
        mix(self.drops_link_down);
        mix(self.drops_injected);
        mix(self.stragglers);
        mix(self.collisions);
        mix(self.restorations);
        mix(self.retrans_requests);
        mix(self.failures);
        mix(self.fallbacks);
        mix(self.switch_failures);
        mix(self.switch_recoveries);
        mix(self.link_flaps);
        mix(self.link_recoveries);
        mix(self.straggler_slowdowns);
        mix(self.partial_aggregates);
        mix(self.jobs_completed);
        mix(self.jobs_stalled);
        mix(self.descriptors_allocated);
        mix(self.descriptors_freed);
        mix(self.descriptor_high_water);
        mix(self.descriptor_residency_ps);
        let f = &self.flows;
        mix(f.started);
        mix(f.completed);
        mix(f.offered_bytes);
        mix(f.delivered_bytes);
        mix(f.ecn_delivered);
        mix(f.cnps_sent);
        mix(f.cnps_received);
        mix(f.acks_received);
        mix(f.retrans_pkts);
        mix(f.dup_pkts);
        mix(f.dup_bytes);
        mix(f.rto_fired);
        mix(f.abandoned);
        for &fct in &f.fct_ps {
            mix(fct);
        }
        mix(self.engine.peak_live_packets);
        mix(self.engine.arena_slots);
        mix(self.engine.arena_allocs);
        h
    }
}

/// Per-link utilization samples over a window, as in Fig. 7b / Fig. 10b
/// (each sample is one link; utilization = busy time / wall time).
pub fn link_utilizations(net: &Network, end: Time) -> Vec<f64> {
    (0..net.links.len())
        .map(|l| net.link_utilization(l, end))
        .collect()
}

/// Average network utilization (mean over all links), the scalar the
/// paper quotes alongside Fig. 7b (40.2 % / 29.5 % / 20.9 %).
pub fn average_network_utilization(net: &Network, end: Time) -> f64 {
    let u = link_utilizations(net, end);
    crate::util::stats::mean(&u)
}

/// Utilization histogram in the paper's Fig. 7b bucketing (10 % buckets).
pub fn utilization_histogram(net: &Network, end: Time) -> Histogram {
    let mut h = Histogram::new(0.0, 1.0, 10);
    for u in link_utilizations(net, end) {
        h.add(u);
    }
    h
}

/// Section 3.2.2 analytical bound on per-switch descriptor memory:
/// `b * (2d(l+t) + r)` bytes.
pub fn memory_model_bytes(
    bandwidth_bytes_per_s: f64,
    diameter: u32,
    hop_latency_s: f64,
    timeout_s: f64,
    leader_time_s: f64,
) -> f64 {
    bandwidth_bytes_per_s
        * (2.0 * diameter as f64 * (hop_latency_s + timeout_s)
            + leader_time_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_kind_delivery_accessors() {
        let mut m = Metrics::default();
        m.on_delivery(PacketKind::CanaryReduce);
        m.on_delivery(PacketKind::CanaryReduce);
        m.on_delivery(PacketKind::TransportCnp);
        assert_eq!(m.pkts_delivered, 3);
        assert_eq!(m.pkts_of_kind(PacketKind::CanaryReduce), 2);
        assert_eq!(m.pkts_of_kind(PacketKind::TransportCnp), 1);
        assert_eq!(m.pkts_of_kind(PacketKind::Ring), 0);
        // the named accessors index the same array the fingerprint
        // walks — the per-kind sum must match the delivered total
        assert_eq!(m.pkts_by_kind.iter().sum::<u64>(), m.pkts_delivered);
    }

    #[test]
    fn descriptor_accounting() {
        let mut m = Metrics::default();
        m.on_descriptor_alloc();
        m.on_descriptor_alloc();
        assert_eq!(m.descriptor_high_water, 2);
        m.on_descriptor_free(100);
        assert_eq!(m.descriptors_live, 1);
        m.on_descriptor_free(50);
        assert_eq!(m.descriptors_live, 0);
        assert_eq!(m.descriptors_allocated, m.descriptors_freed);
        assert_eq!(m.descriptor_residency_ps, 150);
    }

    #[test]
    fn flow_lifecycle_and_fct() {
        let mut f = FlowStats::default();
        f.on_start(1, 100, 2, 2048);
        f.on_start(2, 200, 1, 1024);
        assert_eq!(f.started, 2);
        assert_eq!(f.live_count(), 2);
        // out-of-order deliveries across flows
        f.on_delivery(2, 700, 1024);
        assert_eq!(f.completed, 1);
        assert_eq!(f.fct_ps, vec![500]);
        f.on_delivery(1, 400, 1024);
        assert_eq!(f.completed, 1, "flow 1 needs both packets");
        f.on_delivery(1, 900, 1024);
        assert_eq!(f.completed, 2);
        assert_eq!(f.fct_ps, vec![500, 800]);
        assert_eq!(f.live_count(), 0);
        assert_eq!(f.completion_fraction(), 1.0);
        assert_eq!(f.delivered_bytes, 3072);
        // unknown flow ids (e.g. pre-run stragglers) are byte-counted
        // but otherwise ignored
        f.on_delivery(99, 1000, 10);
        assert_eq!(f.completed, 2);
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let a = Metrics {
            pkts_delivered: 10,
            flows: FlowStats {
                fct_ps: vec![1, 2, 3],
                ..Default::default()
            },
            ..Default::default()
        };
        let mut b = a.clone();
        assert_eq!(a.fingerprint(99, 5), b.fingerprint(99, 5));
        // wall-clock must never perturb the digest
        b.engine.wall_secs = 123.4;
        assert_eq!(a.fingerprint(99, 5), b.fingerprint(99, 5));
        b.pkts_delivered += 1;
        assert_ne!(a.fingerprint(99, 5), b.fingerprint(99, 5));
        // order of FCT samples matters, not just their multiset
        let mut c = a.clone();
        c.flows.fct_ps = vec![1, 3, 2];
        assert_ne!(a.fingerprint(99, 5), c.fingerprint(99, 5));
        // now and event count feed the digest too
        assert_ne!(a.fingerprint(99, 5), a.fingerprint(100, 5));
        assert_ne!(a.fingerprint(99, 5), a.fingerprint(99, 6));
    }

    #[test]
    fn engine_stats_throughput() {
        assert_eq!(EngineStats::default().events_per_sec(), 0.0);
        let e = EngineStats {
            events: 1_000_000,
            wall_secs: 0.5,
            ..Default::default()
        };
        assert!((e.events_per_sec() - 2_000_000.0).abs() < 1e-6);
    }

    #[test]
    fn fct_percentiles_in_us() {
        let mut f = FlowStats::default();
        for (i, fct) in [1_000_000u64, 2_000_000, 3_000_000]
            .into_iter()
            .enumerate()
        {
            let flow = i as u64;
            f.on_start(flow, 0, 1, 1);
            f.on_delivery(flow, fct, 1);
        }
        assert!((f.fct_percentile_us(50.0) - 2.0).abs() < 1e-9);
        assert!((f.fct_percentile_us(100.0) - 3.0).abs() < 1e-9);
        assert_eq!(FlowStats::default().fct_percentile_us(50.0), 0.0);
    }

    #[test]
    fn paper_memory_example() {
        // Paper: 100 Gbps, d=5, l=300ns, t=1us, r=1us => ~175 KiB
        let bytes = memory_model_bytes(12.5e9, 5, 300e-9, 1e-6, 1e-6);
        let kib = bytes / 1024.0;
        assert!(
            (kib - 175.0).abs() < 15.0,
            "expected ~175 KiB, got {kib:.1}"
        );
    }
}
