//! Global simulation counters and post-run analysis helpers
//! (link-utilization distributions, average network utilization,
//! descriptor-memory accounting for the Section 3.2.2 model).

use crate::sim::{Network, Time};
use crate::util::stats::Histogram;

/// Counters accumulated during a run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub pkts_delivered: u64,
    /// Deliveries by packet kind (indexed by `PacketKind as usize`).
    pub pkts_by_kind: [u64; 11],
    /// Droppable (background) packets lost to queue overflow.
    pub drops_overflow: u64,
    /// Packets lost because a link/switch was down.
    pub drops_link_down: u64,
    /// Random loss injected by the fault plan.
    pub drops_injected: u64,
    /// Canary: packets that arrived after their descriptor's timeout and
    /// were forwarded immediately (Section 3.1.1).
    pub stragglers: u64,
    /// Canary: descriptor-table collisions (Section 3.2.1).
    pub collisions: u64,
    /// Canary: restoration packets sent by leaders.
    pub restorations: u64,
    /// Canary: retransmission requests received by leaders.
    pub retrans_requests: u64,
    /// Canary: failure notices broadcast (block retried from scratch).
    pub failures: u64,
    /// Blocks that fell back to the host-based path.
    pub fallbacks: u64,
    /// Switch failures injected.
    pub switch_failures: u64,
    /// Descriptor allocations / deallocations (leak check: must balance
    /// at the end of a clean run).
    pub descriptors_allocated: u64,
    pub descriptors_freed: u64,
    /// High-water mark of live descriptors over all switches.
    pub descriptor_high_water: u64,
    /// Currently live descriptors (maintained by the dataplane).
    pub descriptors_live: u64,
    /// Sum over descriptors of (dealloc - alloc) time, for mean residency.
    pub descriptor_residency_ps: u64,
}

impl Metrics {
    pub fn on_descriptor_alloc(&mut self) {
        self.descriptors_allocated += 1;
        self.descriptors_live += 1;
        self.descriptor_high_water =
            self.descriptor_high_water.max(self.descriptors_live);
    }

    pub fn on_descriptor_free(&mut self, residency: Time) {
        self.descriptors_freed += 1;
        self.descriptors_live = self.descriptors_live.saturating_sub(1);
        self.descriptor_residency_ps += residency;
    }
}

/// Per-link utilization samples over a window, as in Fig. 7b / Fig. 10b
/// (each sample is one link; utilization = busy time / wall time).
pub fn link_utilizations(net: &Network, end: Time) -> Vec<f64> {
    (0..net.links.len())
        .map(|l| net.link_utilization(l, end))
        .collect()
}

/// Average network utilization (mean over all links), the scalar the
/// paper quotes alongside Fig. 7b (40.2 % / 29.5 % / 20.9 %).
pub fn average_network_utilization(net: &Network, end: Time) -> f64 {
    let u = link_utilizations(net, end);
    crate::util::stats::mean(&u)
}

/// Utilization histogram in the paper's Fig. 7b bucketing (10 % buckets).
pub fn utilization_histogram(net: &Network, end: Time) -> Histogram {
    let mut h = Histogram::new(0.0, 1.0, 10);
    for u in link_utilizations(net, end) {
        h.add(u);
    }
    h
}

/// Section 3.2.2 analytical bound on per-switch descriptor memory:
/// `b * (2d(l+t) + r)` bytes.
pub fn memory_model_bytes(
    bandwidth_bytes_per_s: f64,
    diameter: u32,
    hop_latency_s: f64,
    timeout_s: f64,
    leader_time_s: f64,
) -> f64 {
    bandwidth_bytes_per_s
        * (2.0 * diameter as f64 * (hop_latency_s + timeout_s)
            + leader_time_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptor_accounting() {
        let mut m = Metrics::default();
        m.on_descriptor_alloc();
        m.on_descriptor_alloc();
        assert_eq!(m.descriptor_high_water, 2);
        m.on_descriptor_free(100);
        assert_eq!(m.descriptors_live, 1);
        m.on_descriptor_free(50);
        assert_eq!(m.descriptors_live, 0);
        assert_eq!(m.descriptors_allocated, m.descriptors_freed);
        assert_eq!(m.descriptor_residency_ps, 150);
    }

    #[test]
    fn paper_memory_example() {
        // Paper: 100 Gbps, d=5, l=300ns, t=1us, r=1us => ~175 KiB
        let bytes = memory_model_bytes(12.5e9, 5, 300e-9, 1e-6, 1e-6);
        let kib = bytes / 1024.0;
        assert!(
            (kib - 175.0).abs() < 15.0,
            "expected ~175 KiB, got {kib:.1}"
        );
    }
}
