//! Minimal string-backed error type (anyhow substitute, DESIGN.md §7).
//!
//! The binaries and the PJRT runtime only ever *report* errors — they
//! never match on variants — so a message-carrying newtype plus a
//! `Context` trait covers every call site without an external crate.

use std::fmt;

/// A plain error message.
#[derive(Clone)]
pub struct Error(String);

/// `Result` defaulting to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything displayable (drop-in for
    /// `anyhow::Error::msg`).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// `main() -> Result<(), Error>` prints the `Debug` form on exit; keep
// it human-readable.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

/// Attach context to a failing `Result`/`Option` (the `anyhow::Context`
/// subset this crate uses).
pub trait Context<T> {
    fn context(self, msg: &str) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: &str) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_context() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
        assert_eq!(format!("{e:?}"), "boom");

        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");

        let o: Option<u32> = None;
        assert!(o.with_context(|| "missing".into()).is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn conversions() {
        let _: Error = "s".into();
        let _: Error = String::from("s").into();
        let io = std::io::Error::new(std::io::ErrorKind::Other, "io");
        assert_eq!(Error::from(io).to_string(), "io");
    }
}
