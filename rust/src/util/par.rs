//! Zero-crate fork/join helper (rayon substitute, DESIGN.md §7): fan a
//! pure indexed job out over `std::thread` scoped workers.
//!
//! Results land in index order whatever the thread scheduling does, so
//! figure series stay deterministic; the simulator itself is
//! single-threaded per run and every run owns its state, which makes
//! per-seed / per-cell fan-out embarrassingly parallel.

/// Compute `f(0..n)` across OS threads and return the results in index
/// order. `f` must be `Sync` (it is shared by reference); each result
/// slot is written by exactly one worker.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(n.max(1));
    let mut out: Vec<Option<T>> =
        std::iter::repeat_with(|| None).take(n).collect();
    if workers <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = Some(f(i));
        }
    } else {
        // static round-robin split: disjoint &mut slots per worker, no
        // locks, deterministic result placement
        let mut buckets: Vec<Vec<(usize, &mut Option<T>)>> =
            (0..workers).map(|_| Vec::new()).collect();
        for (i, slot) in out.iter_mut().enumerate() {
            buckets[i % workers].push((i, slot));
        }
        let f = &f;
        std::thread::scope(|s| {
            for bucket in buckets {
                s.spawn(move || {
                    for (i, slot) in bucket {
                        *slot = Some(f(i));
                    }
                });
            }
        });
    }
    out.into_iter()
        .map(|v| v.expect("par_map worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        let got = par_map(100, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn handles_empty_and_single() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn runs_real_work_on_many_items() {
        // more items than any realistic worker count
        let got = par_map(257, |i| {
            let mut acc = 0u64;
            for k in 0..100 {
                acc = acc.wrapping_add((i as u64).wrapping_mul(k));
            }
            acc
        });
        assert_eq!(got.len(), 257);
        assert_eq!(got[0], 0);
        assert_eq!(got[2], (0..100u64).map(|k| 2 * k).sum::<u64>());
    }
}
