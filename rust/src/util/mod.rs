//! Small self-contained utilities substituting for crates that are not
//! available in this offline build environment (DESIGN.md §7):
//!
//! - [`rng`] — xoshiro256**/SplitMix64 (substitute for `rand`)
//! - [`json`] — minimal JSON parser/writer (substitute for `serde_json`)
//! - [`cli`] — flag-style argument parser (substitute for `clap`)
//! - [`error`] — string-backed error + context (substitute for `anyhow`)
//! - [`stats`] — means, percentiles, histograms
//! - [`bench`] — measured-iteration micro-bench harness (substitute for
//!   `criterion`; used by the `harness = false` bench targets)
//! - [`par`] — scoped-thread fork/join map (substitute for `rayon`;
//!   used by the figure harness for per-seed fan-out)
//! - [`proptest_lite`] — seeded random property-test runner (substitute
//!   for `proptest`)

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod par;
pub mod proptest_lite;
pub mod rng;
pub mod stats;
