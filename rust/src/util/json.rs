//! Minimal JSON parser + writer (serde_json substitute, DESIGN.md §7).
//!
//! Covers exactly what this project needs: the artifact `manifest.json`
//! (objects / arrays / strings / numbers / bools / null) and the result
//! files emitted by the figure harness. Numbers are kept as f64 with an
//! i64 fast path (manifest payload vectors are 32-bit-exact in f64).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Integers that fit i64 exactly (covers all manifest ints incl. u32
    /// bit patterns and i32 payloads).
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; panics with a useful message.
    pub fn expect(&self, key: &str) -> &Value {
        self.get(key)
            .unwrap_or_else(|| panic!("missing JSON key '{key}' in {self:?}"))
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Array of integers as `Vec<i64>`.
    pub fn int_vec(&self) -> Option<Vec<i64>> {
        self.as_array()?.iter().map(|v| v.as_i64()).collect()
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_to(&mut out);
        out
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Value::Float(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_to(out);
                }
                out.push(']');
            }
            Value::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(map));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("short \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?;
        if !text.contains(['.', 'e', 'E']) {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Value::Float)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = obj(vec![
            ("a", Value::Int(3)),
            ("b", Value::Array(vec![Value::Float(1.5), Value::Null])),
            ("c", Value::Str("x\"y\n".into())),
            ("d", Value::Bool(true)),
        ]);
        let text = v.to_json();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn parses_nested_manifest_shape() {
        let text = r#"{"artifacts": {"m": {"file": "m.hlo.txt",
            "inputs": [{"dtype": "float32", "shape": [10, 2]}]}},
            "packet_lanes": 256}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.expect("packet_lanes").as_i64(), Some(256));
        let inputs = v
            .expect("artifacts")
            .expect("m")
            .expect("inputs")
            .as_array()
            .unwrap();
        assert_eq!(
            inputs[0].expect("shape").int_vec(),
            Some(vec![10, 2])
        );
    }

    #[test]
    fn negative_and_large_ints_exact() {
        let v = parse("[-2147483648, 2147483647, 4294967295]").unwrap();
        assert_eq!(
            v.int_vec().unwrap(),
            vec![-2147483648, 2147483647, 4294967295]
        );
    }

    #[test]
    fn float_parsing() {
        let v = parse("[1.5, -2e3, 0.25]").unwrap();
        let f: Vec<f64> =
            v.as_array().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(f, vec![1.5, -2000.0, 0.25]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{key: 1}").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("hello").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse("\"a\\u0041b\"").unwrap();
        assert_eq!(v.as_str(), Some("aAb"));
    }
}
