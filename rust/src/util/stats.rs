//! Descriptive statistics used by the metrics and bench modules.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation; 0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// [`percentile`] over an already-ascending slice — sort once, read
/// many quantiles.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q));
    if sorted.is_empty() {
        return 0.0;
    }
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets
/// (out-of-range samples clamp into the edge buckets).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            total: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * bins as f64)
            .floor()
            .clamp(0.0, (bins - 1) as f64) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Bucket fractions (sums to 1 when non-empty).
    pub fn fractions(&self) -> Vec<f64> {
        self.counts
            .iter()
            .map(|&c| {
                if self.total == 0 {
                    0.0
                } else {
                    c as f64 / self.total as f64
                }
            })
            .collect()
    }

    /// Midpoint of bucket `i` (for plotting/printing).
    pub fn bucket_mid(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        // unsorted input routes through a sort; the _sorted variant
        // reads the buffer as-is
        assert_eq!(percentile(&[4.0, 1.0, 3.0, 2.0], 100.0), 4.0);
        assert!((percentile_sorted(&xs, 25.0) - 1.75).abs() < 1e-12);
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let xs = [42.0];
        for q in [0.0, 37.0, 50.0, 99.9, 100.0] {
            assert_eq!(percentile(&xs, q), 42.0);
            assert_eq!(percentile_sorted(&xs, q), 42.0);
        }
    }

    #[test]
    fn ties_interpolate_flat() {
        // repeated values: any quantile landing inside the tied run
        // must return the tied value exactly (no interpolation drift)
        let xs = [1.0, 5.0, 5.0, 5.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 75.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.add(0.05);
        h.add(0.95);
        h.add(1.5); // clamps into last bucket
        h.add(-0.5); // clamps into first bucket
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 2);
        assert_eq!(h.total, 4);
        let f = h.fractions();
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((h.bucket_mid(0) - 0.05).abs() < 1e-12);
    }
}
