//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic component of the simulator (workload generators, host
//! placement, loss injection, noise) draws from an explicitly-seeded
//! [`Rng`], so every experiment is exactly reproducible from its config.

/// SplitMix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded with SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // avoid the all-zero state (astronomically unlikely, but cheap)
        if s == [0; 4] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per host) from this RNG.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// i32 uniform over the full range.
    #[inline]
    pub fn i32(&mut self) -> i32 {
        self.next_u64() as i32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices out of `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut all: Vec<usize> = (0..n).collect();
        self.shuffle(&mut all);
        all.truncate(k);
        all
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let s = r.sample_indices(100, 30);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 30);
    }

    #[test]
    fn forked_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
