//! Tiny flag-style CLI argument parser (clap substitute, DESIGN.md §7).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and leading
//! positional arguments. Unknown flags are an error so typos don't pass
//! silently.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    known: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`, accepting only the listed flag names.
    pub fn parse(
        argv: impl IntoIterator<Item = String>,
        known_flags: &[&str],
    ) -> Result<Args, String> {
        let mut args = Args {
            known: known_flags.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if !args.known.iter().any(|k| *k == key) {
                    return Err(format!(
                        "unknown flag --{key} (known: {})",
                        args.known.join(", ")
                    ));
                }
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        // value unless next token is another flag / absent
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => {
                                it.next().unwrap()
                            }
                            _ => "true".to_string(),
                        }
                    }
                };
                args.flags.insert(key, val);
            } else {
                args.positional.push(arg);
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| format!("bad value for --{key}: '{s}'")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_and_positionals() {
        let a = Args::parse(argv("run --hosts 64 --size=4096 --verbose"),
                            &["hosts", "size", "verbose"]).unwrap();
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("hosts"), Some("64"));
        assert_eq!(a.get_parse::<usize>("size", 0).unwrap(), 4096);
        assert!(a.flag("verbose"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn rejects_unknown() {
        assert!(Args::parse(argv("--nope 1"), &["yes"]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(""), &["x"]).unwrap();
        assert_eq!(a.get_or("x", "7"), "7");
        assert_eq!(a.get_parse::<u64>("x", 9).unwrap(), 9);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(argv("--a --b 3"), &["a", "b"]).unwrap();
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("3"));
    }

    #[test]
    fn transport_flags_parse_when_known() {
        // the canary CLI registers the transport/ECN knobs; unknown
        // spellings must still be rejected, not silently dropped
        let known = &["traffic", "transport", "ecn-kmin", "ecn-kmax"];
        let a = Args::parse(
            argv("run --traffic incast:8 --transport dcqcn \
                  --ecn-kmin 8192 --ecn-kmax=32768"),
            known,
        )
        .unwrap();
        assert_eq!(a.get("transport"), Some("dcqcn"));
        assert_eq!(a.get_parse::<u64>("ecn-kmin", 0).unwrap(), 8192);
        assert_eq!(a.get_parse::<u64>("ecn-kmax", 0).unwrap(), 32768);
        assert!(Args::parse(argv("--ecn-min 1"), known).is_err());
    }
}
