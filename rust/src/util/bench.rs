//! Micro-bench harness for the `harness = false` bench targets
//! (criterion substitute, DESIGN.md §7).
//!
//! Warms up, then runs measured iterations until both a minimum iteration
//! count and a minimum wall time are reached, and prints
//! `name  mean ± stddev  (iters)` rows comparable to criterion output.
//! Returns the per-iteration mean so callers can record before/after in
//! EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

use super::stats;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub mean: Duration,
    pub stddev: Duration,
    pub iters: usize,
}

impl Measurement {
    pub fn print(&self) {
        println!(
            "{:<44} {:>12.3?} ± {:>10.3?}  ({} iters)",
            self.name, self.mean, self.stddev, self.iters
        );
    }
}

/// Benchmark `f`, auto-scaling iterations to `min_time` of wall clock.
pub fn bench<F: FnMut()>(name: &str, min_time: Duration, mut f: F) -> Measurement {
    // warm-up: one untimed call
    f();
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_time || samples.len() < 5 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= 10_000 {
            break;
        }
    }
    let m = Measurement {
        name: name.to_string(),
        mean: Duration::from_secs_f64(stats::mean(&samples)),
        stddev: Duration::from_secs_f64(stats::stddev(&samples)),
        iters: samples.len(),
    };
    m.print();
    m
}

/// Throughput helper: items/second given a per-iteration item count.
pub fn throughput(m: &Measurement, items_per_iter: f64) -> f64 {
    items_per_iter / m.mean.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = bench("noop-loop", Duration::from_millis(20), || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.iters >= 5);
        assert!(m.mean.as_nanos() > 0);
    }

    #[test]
    fn throughput_math() {
        let m = Measurement {
            name: "x".into(),
            mean: Duration::from_millis(10),
            stddev: Duration::ZERO,
            iters: 1,
        };
        assert!((throughput(&m, 100.0) - 10_000.0).abs() < 1e-6);
    }
}
