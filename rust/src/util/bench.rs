//! Micro-bench harness for the `harness = false` bench targets
//! (criterion substitute, DESIGN.md §7).
//!
//! Warms up, then runs measured iterations until both a minimum iteration
//! count and a minimum wall time are reached, and prints
//! `name  mean ± stddev  (iters)` rows comparable to criterion output.
//! Returns the per-iteration mean so callers can record before/after in
//! EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

use super::stats;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub mean: Duration,
    pub stddev: Duration,
    pub iters: usize,
}

impl Measurement {
    pub fn print(&self) {
        println!(
            "{:<44} {:>12.3?} ± {:>10.3?}  ({} iters)",
            self.name, self.mean, self.stddev, self.iters
        );
    }
}

/// Benchmark `f`, auto-scaling iterations to `min_time` of wall clock.
///
/// Warm-up runs untimed calls until 10 % of `min_time` has elapsed (at
/// least one call) — a single call under-warms multi-ms scenario
/// benches, whose first iteration pays page faults and cold caches.
/// The measured phase then runs until `min_time` is met with no hard
/// sample cap: sub-microsecond bodies are *batched* so each recorded
/// sample covers at least ~10 µs of work, which bounds the sample
/// vector without truncating the run before `min_time` (the old fixed
/// 10 000-sample cap cut fast bodies off early and skewed the stddev
/// toward the cold start).
pub fn bench<F: FnMut()>(name: &str, min_time: Duration, mut f: F) -> Measurement {
    let warm_deadline = min_time.mul_f64(0.10);
    let warm_start = Instant::now();
    let mut warm_calls = 0u64;
    loop {
        f();
        warm_calls += 1;
        if warm_start.elapsed() >= warm_deadline {
            break;
        }
    }
    // batch sub-microsecond bodies: ~10 us of work per recorded sample
    let per_call = warm_start.elapsed().as_secs_f64() / warm_calls as f64;
    let batch = ((10e-6 / per_call.max(1e-12)) as usize).clamp(1, 1 << 20);

    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_time || samples.len() < 5 {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / batch as f64);
    }
    let m = Measurement {
        name: name.to_string(),
        mean: Duration::from_secs_f64(stats::mean(&samples)),
        stddev: Duration::from_secs_f64(stats::stddev(&samples)),
        iters: samples.len() * batch,
    };
    m.print();
    m
}

/// Throughput helper: items/second given a per-iteration item count.
pub fn throughput(m: &Measurement, items_per_iter: f64) -> f64 {
    items_per_iter / m.mean.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = bench("noop-loop", Duration::from_millis(20), || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.iters >= 5);
        assert!(m.mean.as_nanos() > 0);
    }

    /// A sub-microsecond body must keep measuring until `min_time` is
    /// met (the old 10 000-sample cap truncated it after ~1 ms) — with
    /// batching, total calls far exceed the old cap.
    #[test]
    fn fast_bodies_fill_min_time() {
        let min_time = Duration::from_millis(50);
        let t0 = Instant::now();
        let m = bench("noop", min_time, || {
            std::hint::black_box(1u64);
        });
        assert!(t0.elapsed() >= min_time, "run truncated before min_time");
        assert!(m.iters > 10_000, "old cap would have stopped at 10k");
    }

    #[test]
    fn throughput_math() {
        let m = Measurement {
            name: "x".into(),
            mean: Duration::from_millis(10),
            stddev: Duration::ZERO,
            iters: 1,
        };
        assert!((throughput(&m, 100.0) - 10_000.0).abs() < 1e-6);
    }
}
