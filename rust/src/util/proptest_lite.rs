//! Seeded random property-test runner (proptest substitute, DESIGN.md §7).
//!
//! No shrinking — but every failure prints the exact case seed, so a
//! failing property reproduces with `check_property_seeded(name, seed, f)`.
//! Used by the coordinator invariants tests (routing, batching, descriptor
//! state, end-to-end allreduce value correctness).

use super::rng::Rng;

/// Run `cases` random cases of property `f`. Each case gets an
/// independent RNG derived from `base_seed` and the case index; the
/// property returns `Err(reason)` to fail.
pub fn check_property<F>(name: &str, base_seed: u64, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(reason) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} \
                 (reproduce with seed {case_seed:#x}): {reason}"
            );
        }
    }
}

/// Re-run a single failing case by its printed seed.
pub fn check_property_seeded<F>(name: &str, case_seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    if let Err(reason) = f(&mut rng) {
        panic!("property '{name}' failed (seed {case_seed:#x}): {reason}");
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_property("trivial", 1, 50, |rng| {
            count += 1;
            let x = rng.gen_range(100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check_property("fails", 2, 10, |rng| {
            if rng.gen_range(4) == 3 {
                Err("hit the bad value".into())
            } else {
                Ok(())
            }
        });
    }
}
