//! `canary` — the leader CLI.
//!
//! Subcommands:
//!   run      one allreduce experiment (algo/hosts/size/congestion/...)
//!   train    data-parallel training with simulated gradient allreduce
//!   mem      print the Section 3.2.2 switch-memory model
//!   info     artifact manifest summary
//!   lint     determinism/ownership static analysis over rust/src
//!
//! Figure regeneration lives in the `figures` binary.

use canary::collectives::{runner, verify_job, Algo, Collective};
use canary::config::{parse_oversub, ClosConfig, SimConfig};
use canary::faults::FaultSpec;
use canary::util::error::Result;
use canary::loadbalance::parse_policy;
use canary::metrics::{average_network_utilization, memory_model_bytes};
use canary::report::gbps;
use canary::runtime::Runtime;
use canary::sim::{ps_to_us, PacketKind, US};
use canary::traffic::TrafficSpec;
use canary::trace::TraceSpec;
use canary::train::{TrainConfig, Trainer};
use canary::transport::TransportSpec;
use canary::util::cli::Args;
use canary::workload::{JobBuilder, Placement, ScenarioBuilder};

const USAGE: &str = "\
canary — congestion-aware in-network allreduce (paper reproduction)

USAGE:
  canary run   [--algo canary|static1|static4|ring] [--hosts N]
               [--collective allreduce|reduce:R|broadcast:R|barrier]
               [--placement random|clustered|striped] [--jobs N]
               [--size BYTES] [--congestion true|false] [--seed S]
               [--traffic none|uniform|permutation|incast:F|hotspot:K[:S]
                          |empirical[@open|@closed]]
               [--bg-load L] [--traffic-json FILE]
               [--transport none|dcqcn|swift] [--ecn-kmin B] [--ecn-kmax B]
               [--timeout-us T] [--retrans-us T]
               [--lb adaptive|ecmp|minqueue|flowlet]
               [--topo paper|small|tiny[3]|huge3|giant3|colossal4]
               [--tiers 2|3] [--oversub A:B] [--shards N]
               [--topo-json FILE] [--values] [--fingerprint]
               [--faults loss:P,flap:A:B:DOWN_US:UP_US,
                         fail:SW:AT_US[:REC_US],straggler:H:FACTOR]
               [--faults-json FILE]
               [--trace[=CADENCE_US]] [--trace-blocks N] [--trace-dir DIR]
               [--paranoid]
  canary train [--preset tiny|base] [--workers N] [--steps N] [--lr F]
               [--algo ...] [--comm-every N] [--seed S]
  canary mem   [--timeout-us T] [--diameter D]
  canary info
  canary lint  [CRATE_DIR]   (exit 1 on unannotated findings)
";

fn parse_algo(s: &str) -> Result<Algo, String> {
    match s {
        "canary" => Ok(Algo::Canary),
        "ring" => Ok(Algo::Ring),
        _ => {
            if let Some(n) = s.strip_prefix("static") {
                let n: u8 = n.parse().map_err(|_| format!("bad algo '{s}'"))?;
                Ok(Algo::StaticTree { n_trees: n })
            } else {
                Err(format!("unknown algo '{s}'"))
            }
        }
    }
}

/// Resolve a topology preset name at the requested tier count.
fn parse_topo(s: &str, tiers: u8) -> Result<ClosConfig, String> {
    match (s, tiers) {
        ("paper", 2) => Ok(ClosConfig::paper()),
        ("small", 2) => Ok(ClosConfig::small()),
        ("tiny", 2) => Ok(ClosConfig::tiny()),
        ("paper", 3) | ("paper3", _) => Ok(ClosConfig::paper3()),
        ("small", 3) | ("small3", _) => Ok(ClosConfig::small3()),
        ("tiny", 3) | ("tiny3", _) => Ok(ClosConfig::tiny3()),
        ("huge", 3) | ("huge3", _) => Ok(ClosConfig::huge3()),
        ("giant", 3) | ("giant3", _) => Ok(ClosConfig::giant3()),
        ("colossal", 4) | ("colossal4", _) => Ok(ClosConfig::colossal4()),
        _ => Err(format!(
            "unknown topo '{s}' at {tiers} tiers \
             (paper|small|tiny|paper3|small3|tiny3|huge3|giant3|\
             colossal4; --tiers 2|3)"
        )),
    }
}

/// Combine --topo/--tiers/--oversub/--topo-json into one shape.
fn resolve_topo(args: &Args) -> Result<ClosConfig> {
    let tiers: u8 = args.get_parse("tiers", 2)?;
    let mut topo = match args.get("topo-json") {
        Some(path) => {
            if args.get("topo").is_some() || args.get("tiers").is_some() {
                return Err("--topo-json conflicts with --topo/--tiers \
                            (the JSON file fully defines the shape)"
                    .into());
            }
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("reading {path}: {e}"))?;
            ClosConfig::from_json(&text)?
        }
        None => parse_topo(args.get_or("topo", "paper"), tiers)?,
    };
    if let Some(o) = args.get("oversub") {
        let (num, den) = parse_oversub(o)?;
        topo = topo.with_oversub(num, den);
        // refuse ratios the radixes cannot realize exactly — otherwise
        // the run would silently use a different taper than reported
        for t in 1..topo.tiers as usize {
            if topo.down[t - 1] * den % num != 0 {
                return Err(format!(
                    "oversub {num}:{den} is not exactly achievable at \
                     tier {t} (down radix {}): nearest uplink count is {}",
                    topo.down[t - 1],
                    topo.up[t]
                )
                .into());
            }
        }
    }
    topo.validate()?;
    Ok(topo)
}

/// Combine --traffic/--traffic-json/--bg-load (and the legacy
/// --congestion switch) into the scenario's cross-traffic spec.
fn resolve_traffic(args: &Args) -> Result<Option<TrafficSpec>> {
    if args.get("congestion").is_some()
        && (args.get("traffic").is_some()
            || args.get("traffic-json").is_some())
    {
        return Err("--congestion conflicts with --traffic/--traffic-json \
                    (the pattern string already says on/off: use \
                    --traffic none)"
            .into());
    }
    let mut spec = match (args.get("traffic-json"), args.get("traffic")) {
        (Some(_), Some(_)) => {
            return Err("--traffic-json conflicts with --traffic \
                        (the JSON file fully defines the pattern)"
                .into())
        }
        (Some(path), None) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("reading {path}: {e}"))?;
            TrafficSpec::from_json(&text)?
        }
        (None, Some(s)) => TrafficSpec::parse(s)?,
        // legacy switch: --congestion true/false = uniform on/off
        (None, None) => (args.get_or("congestion", "true") == "true")
            .then(TrafficSpec::uniform),
    };
    if let Some(l) = args.get("bg-load") {
        let load: f64 =
            l.parse().map_err(|_| format!("bad --bg-load '{l}'"))?;
        match spec.as_mut() {
            Some(s) => s.load = load,
            None => {
                return Err(
                    "--bg-load is meaningless with traffic off".into()
                )
            }
        }
    }
    // reactive transport + ECN marking-ramp knobs (crate::transport)
    if let Some(t) = args.get("transport") {
        let t = TransportSpec::parse(t)?;
        match spec.as_mut() {
            Some(s) => s.transport = t,
            None if t.is_on() => {
                return Err("--transport is meaningless with traffic off \
                            (pick a --traffic pattern)"
                    .into())
            }
            None => {}
        }
    }
    let ecn_flag = |flag: &str| -> Result<Option<u64>> {
        match args.get(flag) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("bad --{flag} '{v}'").into()),
        }
    };
    let (kmin, kmax) = (ecn_flag("ecn-kmin")?, ecn_flag("ecn-kmax")?);
    if kmin.is_some() || kmax.is_some() {
        match spec.as_mut() {
            Some(s) => {
                s.ecn_kmin = kmin.or(s.ecn_kmin);
                s.ecn_kmax = kmax.or(s.ecn_kmax);
            }
            None => {
                return Err(
                    "--ecn-kmin/--ecn-kmax are meaningless with traffic \
                     off"
                    .into(),
                )
            }
        }
    }
    if let Some(s) = &spec {
        s.validate()?;
        // a one-sided override must still yield a sane *effective*
        // ramp against the other side's default — catch it here as a
        // usage error instead of panicking inside the builder
        if s.transport.is_on() {
            let d = SimConfig::default();
            let kmin = s.ecn_kmin.unwrap_or(d.ecn_kmin_bytes);
            let kmax = s.ecn_kmax.unwrap_or(d.ecn_kmax_bytes);
            if kmin > kmax {
                return Err(format!(
                    "effective ECN ramp is inverted: kmin {kmin} > kmax \
                     {kmax} (defaults {} / {}; set both --ecn-kmin and \
                     --ecn-kmax)",
                    d.ecn_kmin_bytes, d.ecn_kmax_bytes
                )
                .into());
            }
        }
    }
    Ok(spec)
}

/// `--trace` / `--trace=CADENCE_US` / `--trace-blocks N` into an
/// optional telemetry spec (absent flags = tracing off =
/// zero-footprint). `--trace-blocks N` arms the flight recorder on N
/// seed-selected blocks per job and implies `--trace`.
fn resolve_trace(args: &Args) -> Result<Option<TraceSpec>> {
    let spec = match args.get("trace") {
        None => None,
        Some("true") => Some(TraceSpec::default()),
        Some(v) => {
            let us: u64 = v
                .parse()
                .map_err(|_| format!("bad --trace cadence '{v}' (µs)"))?;
            if us == 0 {
                return Err("--trace cadence must be >= 1 µs".into());
            }
            Some(TraceSpec::default().with_cadence(us * US))
        }
    };
    match args.get("trace-blocks") {
        None => Ok(spec),
        Some(v) => {
            let n: u32 = v
                .parse()
                .map_err(|_| format!("bad --trace-blocks '{v}'"))?;
            Ok(Some(
                spec.unwrap_or_default().with_blocks(n),
            ))
        }
    }
}

/// Combine --faults/--faults-json into the scenario's fault plan
/// (random loss + scheduled churn events; see `canary::faults`).
fn resolve_faults(args: &Args) -> Result<FaultSpec> {
    match (args.get("faults-json"), args.get("faults")) {
        (Some(_), Some(_)) => Err("--faults-json conflicts with --faults \
                                   (the JSON file fully defines the plan)"
            .into()),
        (Some(path), None) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("reading {path}: {e}"))?;
            Ok(FaultSpec::from_json(&text)?)
        }
        (None, Some(s)) => Ok(FaultSpec::parse(s)?),
        (None, None) => Ok(FaultSpec::default()),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let algo = parse_algo(args.get_or("algo", "canary"))?;
    let collective = Collective::parse(args.get_or("collective", "allreduce"))?;
    let placement = Placement::parse(args.get_or("placement", "random"))?;
    let topo = resolve_topo(args)?;
    let n_jobs: u32 = args.get_parse("jobs", 1)?;
    if n_jobs == 0 {
        return Err("--jobs must be >= 1".into());
    }
    let hosts: u32 =
        args.get_parse("hosts", (topo.n_hosts() / 2 / n_jobs).max(2))?;
    if hosts < 1 {
        return Err("--hosts must be >= 1".into());
    }
    if (hosts as u64) * (n_jobs as u64) > topo.n_hosts() as u64 {
        return Err(format!(
            "{n_jobs} job(s) x {hosts} hosts exceed the topology's {} hosts",
            topo.n_hosts()
        )
        .into());
    }
    if let Some(root) = collective.root_rank() {
        if root >= hosts {
            return Err(format!(
                "collective root rank {root} is out of range for \
                 --hosts {hosts} (ranks are 0..{hosts})"
            )
            .into());
        }
    }
    let size: u64 = args.get_parse("size", 4 * 1024 * 1024)?;
    let traffic = resolve_traffic(args)?;
    let faults = resolve_faults(args)?;
    let seed: u64 = args.get_parse("seed", 1)?;
    let timeout_us: u64 = args.get_parse("timeout-us", 1)?;
    let retrans_us: u64 = args.get_parse("retrans-us", 0)?;
    let lb = parse_policy(args.get_or("lb", "adaptive"))?;
    let values = args.flag("values");

    let window: u32 = args.get_parse("window", 0)?;
    // 0 = serial engine (default); N >= 1 = sharded PDES engine with N
    // space-partitioned workers (DESIGN.md §2.10). --shards 1 is
    // fingerprint-identical to serial; any fixed N is deterministic.
    let shards: u32 = args.get_parse("shards", 0)?;
    if shards > 256 {
        return Err(format!(
            "--shards {shards} is out of range (max 256)"
        )
        .into());
    }
    let mut sim = SimConfig::default()
        .with_timeout(timeout_us * US)
        .with_window(window)
        .with_shards(shards)
        .with_values(values)
        .with_paranoid(args.flag("paranoid"));
    if retrans_us > 0 {
        sim = sim.with_retrans(retrans_us * US, true);
    }
    let sc = ScenarioBuilder::new(topo)
        .sim(sim)
        .lb(lb)
        .traffic(traffic)
        .faults(faults)
        .trace(resolve_trace(args)?)
        .jobs(
            n_jobs,
            JobBuilder::new(algo)
                .collective(collective)
                .hosts(hosts)
                .data_bytes(size)
                .placement(placement.clone())
                .record_results(values),
        );
    let mut exp = sc.build(seed);
    let results = runner::run_to_completion(&mut exp.net, u64::MAX);
    let r = &results[0];
    println!(
        "algo={} collective={} placement={} jobs={} hosts={} size={}B \
         traffic={} tiers={}",
        r.algo.name(),
        r.collective.name(),
        placement.name(),
        n_jobs,
        r.n_hosts,
        r.data_bytes,
        traffic
            .map(|t| {
                let tp = if t.transport.is_on() {
                    format!(",{}", t.transport.name())
                } else {
                    String::new()
                };
                format!("{}(load={:.2}{tp})", t.name(), t.load)
            })
            .unwrap_or_else(|| "none".into()),
        topo.tiers
    );
    for (i, r) in results.iter().enumerate() {
        let prefix = if results.len() > 1 {
            format!("job {i} (tenant {}): ", r.tenant)
        } else {
            String::new()
        };
        println!(
            "{prefix}runtime: {:.1} us   goodput: {} Gbps",
            r.runtime_ps.map(ps_to_us).unwrap_or(f64::NAN),
            gbps(r.goodput_gbps)
        );
    }
    if values && algo.carries_values() {
        for &job in &exp.jobs {
            verify_job(&exp.net.jobs[job as usize])
                .map_err(|e| format!("value verification failed: {e}"))?;
        }
        println!(
            "values verified: every required (rank, block) result is the \
             exact expected {}",
            match collective {
                Collective::Broadcast { .. } => "root payload",
                _ => "saturating fixed-point sum",
            }
        );
    }
    println!(
        "events: {}   avg network utilization: {:.1}%",
        exp.net.events_processed,
        100.0 * average_network_utilization(&exp.net, exp.net.now)
    );
    println!("{}", canary::report::engine_summary(&exp.net.metrics));
    if canary::report::fault_activity(&exp.net.metrics) {
        println!("{}", canary::report::fault_summary(&exp.net.metrics));
    }
    if args.flag("fingerprint") {
        // bit-exact digest of the run's outcome (CI `determinism` job:
        // two seeded runs must print the same line)
        println!(
            "fingerprint: {:016x}",
            exp.net
                .metrics
                .fingerprint(exp.net.now, exp.net.events_processed)
        );
    }
    println!(
        "collisions: {}  stragglers: {}  restorations: {}  drops(bg): {}  \
         ecn marks: {}",
        exp.net.metrics.collisions,
        exp.net.metrics.stragglers,
        exp.net.metrics.restorations,
        exp.net.metrics.drops_overflow,
        exp.net.metrics.ecn_marks
    );
    let by_kind = |k: PacketKind| exp.net.metrics.pkts_of_kind(k);
    println!(
        "pkts by kind: reduce {} bcast {} restore {} rdata {} rreq {} fail {} direct {}",
        by_kind(PacketKind::CanaryReduce),
        by_kind(PacketKind::CanaryBroadcast),
        by_kind(PacketKind::CanaryRestore),
        by_kind(PacketKind::CanaryRetransData),
        by_kind(PacketKind::CanaryRetransReq),
        by_kind(PacketKind::CanaryFailure),
        by_kind(PacketKind::CanaryDirect),
    );
    println!(
        "descriptors: alloc {} freed {} live {} highwater {}",
        exp.net.metrics.descriptors_allocated,
        exp.net.metrics.descriptors_freed,
        exp.net.metrics.descriptors_live,
        exp.net.metrics.descriptor_high_water
    );
    if traffic.is_some() {
        println!("{}", canary::report::flow_summary(&exp.net.metrics.flows));
    }
    if exp.net.tracer.enabled() {
        let dir = args.get_or("trace-dir", "results/trace");
        let paths = canary::trace::export(&exp.net, dir)
            .map_err(|e| format!("writing trace artifacts to {dir}: {e}"))?;
        let (evicted, span_drops, tree_drops) = exp.net.tracer.dropped();
        println!(
            "trace: {} samples, {} spans, {} tree records \
             (dropped: {evicted} samples, {span_drops} spans, \
             {tree_drops} trees)",
            exp.net.tracer.n_samples(),
            exp.net.tracer.spans().len(),
            exp.net.tracer.tree_records().len(),
        );
        let blocks = canary::trace::critical_paths(&exp.net);
        if !blocks.is_empty() {
            let (hop_drops, wait_drops) = exp.net.tracer.flight_dropped();
            println!(
                "flight recorder: {} hops, {} waits, {} critical paths \
                 (dropped: {hop_drops} hops, {wait_drops} waits)",
                exp.net.tracer.hops().len(),
                exp.net.tracer.waits().len(),
                blocks.len(),
            );
            canary::report::critical_path_breakdown(&blocks).print();
        }
        for p in paths {
            println!("  wrote {p}");
        }
    }
    if args.flag("debug-links") {
        let end = exp.net.now;
        let mut busiest: Vec<(f64, usize)> = (0..exp.net.links.len())
            .map(|l| (exp.net.link_utilization(l, end), l))
            .collect();
        busiest.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        println!("busiest links:");
        for (u, l) in busiest.iter().take(8) {
            let link = &exp.net.links[*l];
            println!(
                "  {} p{} -> {} p{}  util {:.1}%  bytes {}",
                link.from,
                link.from_port,
                link.to,
                link.to_port,
                100.0 * u,
                link.bytes_tx
            );
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = TrainConfig {
        preset: args.get_or("preset", "base").to_string(),
        workers: args.get_parse("workers", 4)?,
        steps: args.get_parse("steps", 50)?,
        lr: args.get_parse("lr", 0.5)?,
        algo: parse_algo(args.get_or("algo", "canary"))?,
        comm_every: args.get_parse("comm-every", 10)?,
        congestion: true,
        seed: args.get_parse("seed", 0xBEEF)?,
    };
    let rt = Runtime::load(Runtime::default_dir())?;
    let mut trainer = Trainer::new(&rt, cfg)?;
    println!(
        "training preset={} P={} workers={}",
        trainer.cfg.preset, trainer.param_count, trainer.cfg.workers
    );
    let logs = trainer.train()?;
    for l in &logs {
        let comm = l
            .comm_ps
            .map(|c| format!("{:.1} us", ps_to_us(c)))
            .unwrap_or_else(|| "-".into());
        println!(
            "step {:>4}  loss {:.4}  comm {}  wall {:.0} ms",
            l.step, l.mean_loss, comm, l.wall_ms
        );
    }
    Ok(())
}

fn cmd_mem(args: &Args) -> Result<()> {
    let timeout_us: f64 = args.get_parse("timeout-us", 1.0)?;
    let d: u32 = args.get_parse("diameter", 5)?;
    let bytes =
        memory_model_bytes(12.5e9, d, 300e-9, timeout_us * 1e-6, 1e-6);
    println!(
        "memory model: b(2d(l+t)+r) = {:.1} KiB per switch \
         (100 Gbps, d={d}, l=300ns, t={timeout_us}us, r=1us)",
        bytes / 1024.0
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::load(Runtime::default_dir())?;
    println!("artifacts in {}:", rt.dir.display());
    for (name, sig) in &rt.manifest.artifacts {
        println!(
            "  {name:<28} {} -> {} tensors",
            sig.file,
            sig.outputs.len()
        );
    }
    for (name, m) in &rt.manifest.models {
        println!(
            "  model {name}: P={} vocab={} d={} layers={} T={} B={}",
            m.param_count, m.vocab, m.d_model, m.n_layers, m.seq_len, m.batch
        );
    }
    Ok(())
}

/// `canary lint [CRATE_DIR]` — run the determinism/ownership static
/// analysis (crate::lint, DESIGN.md §2.8) over `CRATE_DIR/src`
/// (default: this crate's own source tree). Exits non-zero when any
/// unannotated finding remains, so CI can gate on it.
fn cmd_lint(args: &Args) -> Result<()> {
    let root = match args.positional.get(1) {
        Some(dir) => std::path::PathBuf::from(dir),
        None => std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")),
    };
    let findings = canary::lint::lint_tree(&root);
    if findings.is_empty() {
        println!(
            "lint: clean — D1 unordered-iter, D2 wall-clock, D3 rng, \
             D4 fp-coverage, D5 cli-doc hold over {}",
            root.join("src").display()
        );
        return Ok(());
    }
    for f in &findings {
        println!("{f}");
    }
    Err(format!("lint: {} finding(s)", findings.len()).into())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        argv,
        &[
            "algo", "collective", "placement", "jobs", "hosts", "size",
            "congestion", "traffic", "bg-load", "traffic-json", "seed",
            "transport", "ecn-kmin", "ecn-kmax", "timeout-us", "lb",
            "topo", "tiers", "oversub", "topo-json", "values", "preset",
            "workers", "steps", "lr", "comm-every", "diameter", "window",
            "debug-links", "fingerprint", "faults", "faults-json",
            "retrans-us", "trace", "trace-blocks", "trace-dir", "paranoid",
            "shards",
        ],
    )?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("train") => cmd_train(&args),
        Some("mem") => cmd_mem(&args),
        Some("info") => cmd_info(),
        Some("lint") => cmd_lint(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
