//! Job installation and experiment running: wires protocol engines onto
//! hosts, configures static trees on switches, kicks everything off and
//! collects the results.
//!
//! Installation is driven entirely by a [`JobSpec`] (which carries the
//! algo, the [`Collective`], the participant set resolved by a
//! [`crate::workload::Placement`] policy, and the start-time offset);
//! experiments are assembled through
//! [`crate::workload::ScenarioBuilder`] — there is no per-algorithm
//! public install surface anymore.

use crate::collectives::{Algo, Collective, JobRuntime, JobSpec};
use crate::host::{
    canary_host::CanaryHost, ring::RingHost, static_host::StaticHost, Proto,
};
use crate::sim::{Network, NodeBody, NodeId, Time};
use crate::switch::static_tree::TreeRole;
use crate::topology::{FatTree, Hop};
use crate::trace::SpanKind;
use crate::traffic::{engine, TrafficHost, TrafficSpec};
use crate::util::rng::Rng;

/// Result summary of one finished (or timed-out) collective job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub tenant: u16,
    pub algo: Algo,
    pub collective: Collective,
    pub n_hosts: usize,
    pub data_bytes: u64,
    pub runtime_ps: Option<Time>,
    pub goodput_gbps: Option<f64>,
    /// Did the job finish inside the run's time bound? `false` is the
    /// documented degradation outcome (stall/abort) for engines without
    /// recovery machinery under unrecovered faults — static trees and
    /// ring stall when their fixed path dies, Canary falls back or
    /// retries (DESIGN.md §2.6).
    pub completed: bool,
}

fn set_proto(net: &mut Network, host: NodeId, proto: Proto) {
    match &mut net.nodes[host as usize].body {
        NodeBody::Host(h) => {
            assert!(
                matches!(h.proto, Proto::Idle),
                "host {host} already has a protocol installed"
            );
            h.proto = proto;
        }
        _ => panic!("node {host} is not a host"),
    }
}

/// Install one collective job described by `spec`. Returns the job
/// index. The caller (the scenario builder) has already resolved
/// placement, tenant, tree roots and the start offset.
pub(crate) fn install_job(
    net: &mut Network,
    ft: &FatTree,
    spec: JobSpec,
) -> u32 {
    assert!(
        !spec.participants.is_empty(),
        "a collective job needs participants"
    );
    if let Some(root) = spec.collective.root_rank() {
        assert!(
            (root as usize) < spec.participants.len(),
            "root rank {root} out of range for {} participants",
            spec.participants.len()
        );
    }
    net.tracer.span(
        0,
        SpanKind::Install,
        net.jobs.len() as u32,
        spec.participants[0],
        None,
        spec.participants.len() as u64,
    );
    // flight recorder: seed-derived per-job block selection (no-op when
    // tracing is off or trace_blocks == 0)
    net.tracer
        .register_job(net.cfg.seed, spec.tenant, spec.total_blocks());
    match spec.algo {
        Algo::Canary => install_canary_job(net, spec),
        Algo::StaticTree { .. } => install_static_job(net, ft, spec),
        Algo::Ring => install_ring_job(net, spec),
        Algo::Background => {
            panic!("background traffic is installed via its TrafficSpec")
        }
    }
}

/// Install a Canary job. Derived collectives ride the same machinery:
/// the leader arrangement and completion rule come from
/// `spec.collective` (see [`crate::collectives::derived`]).
fn install_canary_job(net: &mut Network, spec: JobSpec) -> u32 {
    let total_blocks = spec.total_blocks();
    let participants = spec.participants.clone();
    let job = net.jobs.len() as u32;
    net.jobs.push(JobRuntime::new(spec));
    for (rank, &h) in participants.iter().enumerate() {
        set_proto(
            net,
            h,
            Proto::Canary(CanaryHost::new(job, rank as u32, total_blocks)),
        );
    }
    job
}

/// Install a static-tree in-network job with `spec.tree_roots.len()`
/// trees (SHARP-like for 1 tree, PANAMA-like for several).
///
/// On a multi-tier Clos, each tree is the label-aligned spanning tree of
/// its root: every participating leaf aggregates its local hosts, every
/// intermediate tier aggregates the partials of the aligned switches one
/// tier down, and the root combines one partial per top-level subtree.
///
/// For a `reduce` collective the *aggregation* tree is unchanged and the
/// broadcast still reaches every participant — but only the clones on
/// the path toward the root host carry the value payload ("static-tree
/// root completion"); everyone else receives a header-only release that
/// drains their injection window. Only the root holds the result.
fn install_static_job(net: &mut Network, ft: &FatTree, spec: JobSpec) -> u32 {
    let roots = spec.tree_roots.clone();
    assert!(!roots.is_empty(), "static trees need at least one root");
    let tenant = spec.tenant;
    let participants = spec.participants.clone();
    // reduce: the one host the broadcast must still reach
    let reduce_root_host = spec
        .collective
        .result_stays_at_root()
        .then(|| spec.leader_of(0));
    let total_blocks = spec.total_blocks();
    let job = net.jobs.len() as u32;
    net.jobs.push(JobRuntime::new(spec));
    for (rank, &h) in participants.iter().enumerate() {
        set_proto(
            net,
            h,
            Proto::Static(StaticHost::new(job, rank as u32, total_blocks)),
        );
    }

    // ---- control plane: configure the trees on the switches ----
    // participating leaves/ToRs and their member hosts' down-ports
    let tiers = ft.tiers();
    let mut leaf_members: std::collections::BTreeMap<u32, Vec<u16>> =
        Default::default();
    for &h in &participants {
        leaf_members
            .entry(ft.leaf_of_host(h))
            .or_default()
            .push(ft.leaf_host_port(h));
    }
    // reduce: the one down-port (if any) whose broadcast clone keeps
    // the value payload — the edge on the path toward the root host;
    // `u16::MAX` marks a switch entirely off that path. `None` for the
    // collectives whose broadcast delivers values everywhere.
    let value_port = |tier: u8, idx: u32, ports: &[u16]| -> Option<u16> {
        reduce_root_host.map(|rh| match ft.hop_at(tier, idx, rh) {
            Hop::Port(p) if ports.contains(&p) => p,
            _ => u16::MAX,
        })
    };
    for (t, &root) in roots.iter().enumerate() {
        let (root_tier, root_idx) = ft.switch_at(root);
        assert_eq!(root_tier, tiers, "tree roots must be top-tier switches");
        // climb tier by tier along the root's bottom label: at each
        // tier the on-tree switches aggregate their subtree and send
        // the partial up the one aligned edge toward the root
        let mut members = leaf_members.clone();
        for tier in 1..tiers {
            let m_up = ft.cfg.down[tier as usize];
            let w_t = ft.w(tier);
            let c_next = ft.climb_digit(tier, root_idx);
            let mut parents: std::collections::BTreeMap<u32, Vec<u16>> =
                Default::default();
            for (&idx, ports) in &members {
                let top = idx / w_t;
                debug_assert_eq!(
                    idx % w_t,
                    root_idx % w_t,
                    "off the root's line"
                );
                let role = TreeRole {
                    parent_port: Some(ft.up_port(tier, c_next)),
                    expected: ports.len() as u32,
                    child_ports: ports.clone(),
                    value_port: value_port(tier, idx, ports),
                };
                install_tree_role(
                    net,
                    ft.switch_id(tier, idx),
                    tenant,
                    t,
                    roots.len(),
                    role,
                );
                parents
                    .entry(ft.parent_index(tier, idx, c_next))
                    .or_default()
                    .push((top % m_up) as u16);
            }
            members = parents;
        }
        // the climb converges on the root, which starts the broadcast
        assert_eq!(members.len(), 1);
        // lint: allow(unordered-iter, single entry, pinned by the assert_eq just above)
        let (&idx, ports) = members.iter().next().unwrap();
        assert_eq!(ft.switch_id(tiers, idx), root);
        let role = TreeRole {
            parent_port: None,
            expected: ports.len() as u32,
            child_ports: ports.clone(),
            value_port: value_port(tiers, idx, ports),
        };
        install_tree_role(net, root, tenant, t, roots.len(), role);
    }
    job
}

fn install_tree_role(
    net: &mut Network,
    switch: NodeId,
    tenant: u16,
    tree: usize,
    n_trees: usize,
    role: TreeRole,
) {
    match &mut net.nodes[switch as usize].body {
        NodeBody::Switch(sw) => {
            let info =
                sw.static_tree.jobs.entry(tenant).or_default();
            if info.trees.len() < n_trees {
                info.trees.resize(n_trees, None);
            }
            info.trees[tree] = Some(role);
        }
        _ => panic!("node {switch} is not a switch"),
    }
}

/// Install a host-based ring job (bandwidth-optimal allreduce; derived
/// collectives fall back to the same exchange, with the reduce
/// completion rule applied by the job runtime).
fn install_ring_job(net: &mut Network, spec: JobSpec) -> u32 {
    let participants = spec.participants.clone();
    let n = participants.len() as u32;
    let data_bytes = spec.data_bytes;
    let payload = spec.payload_bytes;
    let job = net.jobs.len() as u32;
    net.jobs.push(JobRuntime::new(spec));
    for (rank, &h) in participants.iter().enumerate() {
        set_proto(
            net,
            h,
            Proto::Ring(RingHost::new(
                job,
                rank as u32,
                n,
                data_bytes,
                payload,
            )),
        );
    }
    job
}

/// Install a cross-traffic job on `hosts` (sorted ascending) following
/// `spec`. `rng` resolves pattern structure (permutation cycle, incast
/// groups, hot set); the `uniform` pattern draws nothing from it, which
/// keeps legacy runs bit-identical.
pub(crate) fn install_background_job(
    net: &mut Network,
    hosts: Vec<NodeId>,
    spec: TrafficSpec,
    rng: &mut Rng,
) -> u32 {
    let plans = engine::build_plans(&spec, &hosts, rng);
    let job_spec = JobSpec {
        tenant: u16::MAX,
        algo: Algo::Background,
        collective: Collective::Allreduce,
        participants: hosts.clone(),
        data_bytes: 0,
        window: 0,
        payload_bytes: net.cfg.payload_bytes,
        tree_roots: vec![],
        start_ps: 0,
        record_results: false,
    };
    let job = net.jobs.len() as u32;
    net.jobs.push(JobRuntime::new(job_spec));
    for (&h, plan) in hosts.iter().zip(plans) {
        set_proto(net, h, Proto::Background(TrafficHost::new(job, spec, plan)));
    }
    job
}

/// Kick all jobs and run to completion (or `max_time`). Returns one
/// [`JobResult`] per collective job, in installation order.
pub fn run_to_completion(net: &mut Network, max_time: Time) -> Vec<JobResult> {
    net.kick_jobs();
    net.run(max_time);
    for (idx, j) in net.jobs.iter().enumerate() {
        if j.spec.algo.is_allreduce() {
            if let Some(finish) = j.finish {
                net.metrics.jobs_completed += 1;
                net.tracer.span(
                    finish,
                    SpanKind::Complete,
                    idx as u32,
                    j.spec.participants[0],
                    None,
                    j.spec.participants.len() as u64,
                );
            } else {
                net.metrics.jobs_stalled += 1;
                net.tracer.span(
                    net.now,
                    SpanKind::Stalled,
                    idx as u32,
                    j.spec.participants[0],
                    None,
                    j.spec.participants.len() as u64,
                );
            }
        }
    }
    net.jobs
        .iter()
        .filter(|j| j.spec.algo.is_allreduce())
        .map(|j| JobResult {
            tenant: j.spec.tenant,
            algo: j.spec.algo,
            collective: j.spec.collective,
            n_hosts: j.spec.participants.len(),
            data_bytes: j.spec.data_bytes,
            runtime_ps: j.runtime_ps(),
            goodput_gbps: j.goodput_gbps(),
            completed: j.finish.is_some(),
        })
        .collect()
}
