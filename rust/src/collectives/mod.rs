//! Collective-operation jobs: specification, runtime progress tracking,
//! and the deterministic per-host block payload generator used for
//! value-correctness verification.
//!
//! Derived collectives (Section 6 of the paper) — `reduce`, `broadcast`
//! and `barrier` — are expressed on top of the allreduce machinery in
//! [`derived`].

pub mod derived;
pub mod runner;

use crate::sim::{NodeId, Time};
use crate::util::rng::splitmix64;

/// Which allreduce algorithm a job runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// The paper's contribution: congestion-aware dynamic trees.
    Canary,
    /// State-of-the-art in-network with `n_trees` static trees
    /// (1 = SHARP/SwitchML/ATP-like, 4 = PANAMA-like).
    StaticTree { n_trees: u8 },
    /// Host-based bandwidth-optimal ring allreduce.
    Ring,
    /// Random-uniform congestion generator (not an allreduce).
    Background,
}

impl Algo {
    pub fn is_allreduce(&self) -> bool {
        !matches!(self, Algo::Background)
    }

    pub fn name(&self) -> String {
        match self {
            Algo::Canary => "canary".into(),
            Algo::StaticTree { n_trees } => format!("static{n_trees}"),
            Algo::Ring => "ring".into(),
            Algo::Background => "background".into(),
        }
    }
}

/// Immutable description of one job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub tenant: u16,
    pub algo: Algo,
    /// Participating hosts; order defines ranks (and the ring order).
    pub participants: Vec<NodeId>,
    /// Application data per host, in bytes.
    pub data_bytes: u64,
    /// In-flight block window per host.
    pub window: u32,
    /// Payload bytes per packet (copied from `SimConfig` at install).
    pub payload_bytes: u32,
    /// Static trees only: the chosen root spine per tree.
    pub tree_roots: Vec<NodeId>,
    /// Keep per-host result payloads for verification (tests only).
    pub record_results: bool,
}

impl JobSpec {
    /// Number of MTU blocks each host reduces.
    pub fn total_blocks(&self) -> u32 {
        self.data_bytes.div_ceil(self.payload_bytes as u64).max(1) as u32
    }

    /// Wire size of one reduction data packet.
    pub fn wire_bytes(&self) -> u32 {
        self.payload_bytes + crate::sim::packet::HEADER_OVERHEAD_BYTES
    }

    /// Lanes (4-byte elements) per packet.
    pub fn lanes(&self) -> usize {
        (self.payload_bytes / 4) as usize
    }

    /// The leader host of a block (Canary round-robins leaders,
    /// Section 3.1.4).
    pub fn leader_of(&self, block_index: u32) -> NodeId {
        self.participants[block_index as usize % self.participants.len()]
    }

    /// Rank of a host in this job.
    pub fn rank_of(&self, host: NodeId) -> Option<u32> {
        self.participants
            .iter()
            .position(|&h| h == host)
            .map(|r| r as u32)
    }
}

/// Mutable job progress, updated by host protocol engines via `Ctx`.
pub struct JobRuntime {
    pub spec: JobSpec,
    pub start: Time,
    pub finish: Option<Time>,
    pub hosts_finished: u32,
    pub per_host_finish: Vec<Option<Time>>,
    /// Recorded result payloads (rank, block) -> lanes, if enabled.
    pub results: std::collections::HashMap<(u32, u32), Vec<i32>>,
}

impl JobRuntime {
    pub fn new(spec: JobSpec) -> JobRuntime {
        let n = spec.participants.len();
        JobRuntime {
            spec,
            start: 0,
            finish: None,
            hosts_finished: 0,
            per_host_finish: vec![None; n],
            results: Default::default(),
        }
    }

    /// A host completed all its blocks.
    pub fn host_finished(&mut self, rank: u32, now: Time) {
        let slot = &mut self.per_host_finish[rank as usize];
        if slot.is_none() {
            *slot = Some(now);
            self.hosts_finished += 1;
            if self.hosts_finished == self.spec.participants.len() as u32 {
                self.finish = Some(now);
            }
        }
    }

    pub fn record_result(&mut self, rank: u32, block: u32, lanes: &[i32]) {
        if self.spec.record_results {
            self.results.insert((rank, block), lanes.to_vec());
        }
    }

    /// Completion time (ps), if finished.
    pub fn runtime_ps(&self) -> Option<Time> {
        self.finish.map(|f| f - self.start)
    }

    /// Per-host goodput in Gbps: data size over completion time.
    pub fn goodput_gbps(&self) -> Option<f64> {
        self.runtime_ps()
            .map(|t| crate::sim::goodput_gbps(self.spec.data_bytes, t))
    }
}

/// Deterministic per-(tenant, host, block) payload. Values are kept small
/// (±2^20) so sums over <=2048 hosts cannot saturate — which makes the
/// switch's saturating aggregation exactly equal to the integer sum, and
/// the expected value independently computable.
pub fn block_payload(
    tenant: u16,
    host: NodeId,
    block_index: u32,
    lanes: usize,
) -> Vec<i32> {
    let mut s = (tenant as u64) << 48 | (host as u64) << 24
        | block_index as u64;
    (0..lanes)
        .map(|_| (splitmix64(&mut s) % (1 << 21)) as i32 - (1 << 20))
        .collect()
}

/// The expected allreduce result for one block: saturating fold over all
/// participants (equals the exact sum with `block_payload` values).
pub fn expected_block_sum(
    tenant: u16,
    participants: &[NodeId],
    block_index: u32,
    lanes: usize,
) -> Vec<i32> {
    let mut acc = vec![0i32; lanes];
    for &h in participants {
        let p = block_payload(tenant, h, block_index, lanes);
        crate::switch::alu::sat_accumulate(&mut acc, &p);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n: usize) -> JobSpec {
        JobSpec {
            tenant: 1,
            algo: Algo::Canary,
            participants: (0..n as u32).collect(),
            data_bytes: 10_000,
            window: 4,
            payload_bytes: 1024,
            tree_roots: vec![],
            record_results: false,
        }
    }

    #[test]
    fn blocks_round_up() {
        let s = spec(4);
        // 10_000 / 1024 = 9.77 -> 10 blocks
        assert_eq!(s.total_blocks(), 10);
    }

    #[test]
    fn leaders_round_robin() {
        let s = spec(3);
        assert_eq!(s.leader_of(0), 0);
        assert_eq!(s.leader_of(1), 1);
        assert_eq!(s.leader_of(5), 2);
    }

    #[test]
    fn job_finishes_when_all_hosts_do() {
        let mut j = JobRuntime::new(spec(2));
        j.host_finished(0, 100);
        assert!(j.finish.is_none());
        j.host_finished(0, 150); // duplicate ignored
        assert!(j.finish.is_none());
        j.host_finished(1, 200);
        assert_eq!(j.finish, Some(200));
        assert_eq!(j.runtime_ps(), Some(200));
    }

    #[test]
    fn payload_deterministic_and_distinct() {
        let a = block_payload(1, 5, 7, 16);
        let b = block_payload(1, 5, 7, 16);
        let c = block_payload(1, 6, 7, 16);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|&v| v.abs() <= 1 << 20));
    }

    #[test]
    fn expected_sum_matches_manual() {
        let hosts = [0u32, 1, 2];
        let exp = expected_block_sum(9, &hosts, 3, 8);
        let mut manual = vec![0i64; 8];
        for &h in &hosts {
            for (m, v) in manual
                .iter_mut()
                .zip(block_payload(9, h, 3, 8).iter())
            {
                *m += *v as i64;
            }
        }
        let manual: Vec<i32> = manual.into_iter().map(|v| v as i32).collect();
        assert_eq!(exp, manual, "no saturation expected at this scale");
    }
}
