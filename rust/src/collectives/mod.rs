//! Collective-operation jobs: the typed [`Collective`] carried by every
//! [`JobSpec`], runtime progress tracking with per-collective completion
//! rules, the deterministic per-host block payload generator, and the
//! [`verify_job`] value checker used in `record_results` mode.
//!
//! Derived collectives (Section 6 of the paper) — `reduce`, `broadcast`
//! and `barrier` — run end to end on the allreduce machinery: the
//! arrangement helpers live in [`derived`], the leader forcing in
//! [`JobSpec::leader_of`], and the completion rules in
//! [`JobRuntime::host_finished`]. Jobs are installed through
//! [`crate::workload::ScenarioBuilder`].

pub mod derived;
pub mod runner;

use crate::sim::{NodeId, Time};
use crate::util::rng::splitmix64;

/// Which allreduce algorithm a job runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// The paper's contribution: congestion-aware dynamic trees.
    Canary,
    /// State-of-the-art in-network with `n_trees` static trees
    /// (1 = SHARP/SwitchML/ATP-like, 4 = PANAMA-like).
    StaticTree { n_trees: u8 },
    /// Host-based bandwidth-optimal ring allreduce.
    Ring,
    /// Random-uniform congestion generator (not an allreduce).
    Background,
}

impl Algo {
    pub fn is_allreduce(&self) -> bool {
        !matches!(self, Algo::Background)
    }

    /// Does this engine move real lane values through the fabric (and
    /// can therefore be value-verified in `record_results` mode)?
    pub fn carries_values(&self) -> bool {
        matches!(self, Algo::Canary | Algo::StaticTree { .. })
    }

    pub fn name(&self) -> String {
        match self {
            Algo::Canary => "canary".into(),
            Algo::StaticTree { n_trees } => format!("static{n_trees}"),
            Algo::Ring => "ring".into(),
            Algo::Background => "background".into(),
        }
    }
}

/// Which collective operation a job performs (paper Section 6: the
/// derived collectives are expressed on the allreduce machinery).
///
/// `root` is always a **rank** (an index into `JobSpec::participants`),
/// not a raw node id, so the same job description works under any
/// [`crate::workload::Placement`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Collective {
    /// Every participant contributes and receives the sum.
    Allreduce,
    /// Every participant contributes; only the root holds the sum.
    /// Leaders are forced to the root (Section 6, "selecting as leader
    /// node the destination") and the value broadcast is suppressed.
    Reduce { root: u32 },
    /// The root's data reaches every participant: the root leads every
    /// block and the other participants contribute the neutral element
    /// (zeros), so the aggregated "sum" *is* the root's payload.
    Broadcast { root: u32 },
    /// A zero-byte allreduce: one empty block, done when everyone has
    /// seen its completion.
    Barrier,
}

impl Collective {
    /// Parse the CLI spelling: `allreduce`, `reduce:R`, `broadcast:R`,
    /// `barrier` (`R` = root rank).
    pub fn parse(s: &str) -> Result<Collective, String> {
        if s == "allreduce" {
            return Ok(Collective::Allreduce);
        }
        if s == "barrier" {
            return Ok(Collective::Barrier);
        }
        let parse_root = |spec: &str, what: &str| -> Result<u32, String> {
            spec.parse::<u32>()
                .map_err(|_| format!("bad {what} root rank '{spec}'"))
        };
        if let Some(r) = s.strip_prefix("reduce:") {
            return Ok(Collective::Reduce {
                root: parse_root(r, "reduce")?,
            });
        }
        if let Some(r) = s.strip_prefix("broadcast:") {
            return Ok(Collective::Broadcast {
                root: parse_root(r, "broadcast")?,
            });
        }
        Err(format!(
            "unknown collective '{s}' \
             (allreduce|reduce:R|broadcast:R|barrier)"
        ))
    }

    pub fn name(&self) -> String {
        match self {
            Collective::Allreduce => "allreduce".into(),
            Collective::Reduce { root } => format!("reduce:{root}"),
            Collective::Broadcast { root } => format!("broadcast:{root}"),
            Collective::Barrier => "barrier".into(),
        }
    }

    /// The rank pinned as the leader of every block, if any.
    pub fn root_rank(&self) -> Option<u32> {
        match self {
            Collective::Reduce { root }
            | Collective::Broadcast { root } => Some(*root),
            _ => None,
        }
    }

    /// The single rank whose completion finishes the job (`None` = all
    /// ranks must finish, the allreduce rule).
    pub fn completion_rank(&self) -> Option<u32> {
        match self {
            Collective::Reduce { root } => Some(*root),
            _ => None,
        }
    }

    /// Is the result delivered only to the root (the value broadcast is
    /// then a header-only descriptor release)?
    pub fn result_stays_at_root(&self) -> bool {
        matches!(self, Collective::Reduce { .. })
    }
}

/// Immutable description of one job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub tenant: u16,
    pub algo: Algo,
    /// Which collective operation this job performs.
    pub collective: Collective,
    /// Participating hosts; order defines ranks (and the ring order).
    pub participants: Vec<NodeId>,
    /// Application data per host, in bytes.
    pub data_bytes: u64,
    /// In-flight block window per host.
    pub window: u32,
    /// Payload bytes per packet (copied from `SimConfig` at install).
    pub payload_bytes: u32,
    /// Static trees only: the chosen root spine per tree.
    pub tree_roots: Vec<NodeId>,
    /// Start-time offset: the job's hosts wake at this simulated time.
    pub start_ps: Time,
    /// Keep per-host result payloads for verification (tests only).
    pub record_results: bool,
}

impl JobSpec {
    /// Number of MTU blocks each host reduces.
    pub fn total_blocks(&self) -> u32 {
        self.data_bytes.div_ceil(self.payload_bytes as u64).max(1) as u32
    }

    /// Wire size of one reduction data packet.
    pub fn wire_bytes(&self) -> u32 {
        self.payload_bytes + crate::sim::packet::HEADER_OVERHEAD_BYTES
    }

    /// Lanes (4-byte elements) per packet.
    pub fn lanes(&self) -> usize {
        (self.payload_bytes / 4) as usize
    }

    /// The leader host of a block. Allreduce and barrier round-robin
    /// leaders (Section 3.1.4); reduce and broadcast force every block's
    /// leader to the root (Section 6).
    pub fn leader_of(&self, block_index: u32) -> NodeId {
        match self.collective.root_rank() {
            Some(root) => self.participants[root as usize],
            None => self.participants
                [block_index as usize % self.participants.len()],
        }
    }

    /// Rank of a host in this job.
    pub fn rank_of(&self, host: NodeId) -> Option<u32> {
        self.participants
            .iter()
            .position(|&h| h == host)
            .map(|r| r as u32)
    }

    /// The lane values `host` contributes to `block_index`: the
    /// deterministic per-host payload, except that broadcast
    /// non-roots contribute the neutral element (zeros) so the
    /// aggregate equals the root's data.
    pub fn payload_of(
        &self,
        host: NodeId,
        block_index: u32,
        lanes: usize,
    ) -> Vec<i32> {
        if let Collective::Broadcast { root } = self.collective {
            if self.participants[root as usize] != host {
                return vec![0i32; lanes];
            }
        }
        block_payload(self.tenant, host, block_index, lanes)
    }

    /// The value every completed copy of `block_index` must hold.
    pub fn expected_block(&self, block_index: u32, lanes: usize) -> Vec<i32> {
        match self.collective {
            Collective::Broadcast { root } => block_payload(
                self.tenant,
                self.participants[root as usize],
                block_index,
                lanes,
            ),
            _ => expected_block_sum(
                self.tenant,
                &self.participants,
                block_index,
                lanes,
            ),
        }
    }
}

/// Mutable job progress, updated by host protocol engines via `Ctx`.
/// `Clone` so the sharded engine (`sim/shard.rs`) can replicate the job
/// table into every shard and merge the rank-disjoint progress back.
#[derive(Clone)]
pub struct JobRuntime {
    pub spec: JobSpec,
    pub start: Time,
    pub finish: Option<Time>,
    pub hosts_finished: u32,
    pub per_host_finish: Vec<Option<Time>>,
    /// Recorded result payloads (rank, block) -> lanes, if enabled.
    pub results: std::collections::HashMap<(u32, u32), Vec<i32>>,
}

impl JobRuntime {
    pub fn new(spec: JobSpec) -> JobRuntime {
        let n = spec.participants.len();
        let start = spec.start_ps;
        JobRuntime {
            spec,
            start,
            finish: None,
            hosts_finished: 0,
            per_host_finish: vec![None; n],
            results: Default::default(),
        }
    }

    /// A host completed all its blocks. The job's completion rule is
    /// per-collective: an allreduce/broadcast/barrier finishes when all
    /// ranks do, a reduce finishes the moment the root rank holds all
    /// blocks (the other ranks only ever contribute).
    pub fn host_finished(&mut self, rank: u32, now: Time) {
        let slot = &mut self.per_host_finish[rank as usize];
        if slot.is_some() {
            return;
        }
        *slot = Some(now);
        self.hosts_finished += 1;
        if self.finish.is_none() {
            let complete = match self.spec.collective.completion_rank() {
                Some(root) => rank == root,
                None => {
                    self.hosts_finished
                        == self.spec.participants.len() as u32
                }
            };
            if complete {
                self.finish = Some(now);
            }
        }
    }

    pub fn record_result(&mut self, rank: u32, block: u32, lanes: &[i32]) {
        if self.spec.record_results {
            self.results.insert((rank, block), lanes.to_vec());
        }
    }

    /// Fold one shard's copy of this job into `self` (sharded-engine
    /// merge). Each rank runs on exactly one shard, so the per-rank
    /// finish slots are disjoint across copies; `hosts_finished` and
    /// `finish` are recomputed from the union with the same completion
    /// rule as [`JobRuntime::host_finished`] — which makes the merged
    /// table identical to what a serial run would have produced.
    pub fn merge_from(&mut self, other: &JobRuntime) {
        for (slot, o) in
            self.per_host_finish.iter_mut().zip(&other.per_host_finish)
        {
            if slot.is_none() {
                *slot = *o;
            }
        }
        // lint: allow(unordered-iter, extend of rank-keyed results; read back by key, never iterated for output)
        self.results
            .extend(other.results.iter().map(|(k, v)| (*k, v.clone())));
        self.hosts_finished =
            self.per_host_finish.iter().filter(|s| s.is_some()).count()
                as u32;
        self.finish = match self.spec.collective.completion_rank() {
            Some(root) => self.per_host_finish[root as usize],
            None => {
                if self.hosts_finished
                    == self.spec.participants.len() as u32
                {
                    self.per_host_finish.iter().flatten().copied().max()
                } else {
                    None
                }
            }
        };
    }

    /// Completion time (ps), if finished.
    pub fn runtime_ps(&self) -> Option<Time> {
        self.finish.map(|f| f - self.start)
    }

    /// Per-host goodput in Gbps: data size over completion time.
    pub fn goodput_gbps(&self) -> Option<f64> {
        self.runtime_ps()
            .map(|t| crate::sim::goodput_gbps(self.spec.data_bytes, t))
    }
}

/// Deterministic per-(tenant, host, block) payload. Values are kept small
/// (±2^20) so sums over <=2048 hosts cannot saturate — which makes the
/// switch's saturating aggregation exactly equal to the integer sum, and
/// the expected value independently computable.
pub fn block_payload(
    tenant: u16,
    host: NodeId,
    block_index: u32,
    lanes: usize,
) -> Vec<i32> {
    let mut s = (tenant as u64) << 48 | (host as u64) << 24
        | block_index as u64;
    (0..lanes)
        .map(|_| (splitmix64(&mut s) % (1 << 21)) as i32 - (1 << 20))
        .collect()
}

/// The expected allreduce result for one block: saturating fold over all
/// participants (equals the exact sum with `block_payload` values).
pub fn expected_block_sum(
    tenant: u16,
    participants: &[NodeId],
    block_index: u32,
    lanes: usize,
) -> Vec<i32> {
    let mut acc = vec![0i32; lanes];
    for &h in participants {
        let p = block_payload(tenant, h, block_index, lanes);
        crate::switch::alu::sat_accumulate(&mut acc, &p);
    }
    acc
}

/// Value-verify one finished job against its collective's semantics
/// (`record_results` mode): every rank that must hold the result —
/// all of them for allreduce/broadcast/barrier, only the root for
/// reduce — is checked block by block against [`JobSpec::expected_block`].
///
/// Engines that model sizes only (ring, background) are verified for
/// completion alone.
pub fn verify_job(job: &JobRuntime) -> Result<(), String> {
    let spec = &job.spec;
    if job.finish.is_none() {
        return Err(format!(
            "{} job (tenant {}) did not finish: {}/{} hosts done",
            spec.collective.name(),
            spec.tenant,
            job.hosts_finished,
            spec.participants.len()
        ));
    }
    if !spec.algo.carries_values() {
        return Ok(());
    }
    if !spec.record_results {
        return Err("verify_job needs record_results".into());
    }
    let lanes = spec.lanes();
    let ranks: Vec<u32> = match spec.collective.completion_rank() {
        Some(root) => vec![root],
        None => (0..spec.participants.len() as u32).collect(),
    };
    for block in 0..spec.total_blocks() {
        let expected = spec.expected_block(block, lanes);
        for &rank in &ranks {
            match job.results.get(&(rank, block)) {
                None => {
                    return Err(format!(
                        "missing result rank {rank} block {block}"
                    ))
                }
                Some(got) if got != &expected => {
                    return Err(format!(
                        "wrong value rank {rank} block {block}"
                    ))
                }
                _ => {}
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n: usize) -> JobSpec {
        JobSpec {
            tenant: 1,
            algo: Algo::Canary,
            collective: Collective::Allreduce,
            participants: (0..n as u32).collect(),
            data_bytes: 10_000,
            window: 4,
            payload_bytes: 1024,
            tree_roots: vec![],
            start_ps: 0,
            record_results: false,
        }
    }

    #[test]
    fn blocks_round_up() {
        let s = spec(4);
        // 10_000 / 1024 = 9.77 -> 10 blocks
        assert_eq!(s.total_blocks(), 10);
    }

    #[test]
    fn leaders_round_robin() {
        let s = spec(3);
        assert_eq!(s.leader_of(0), 0);
        assert_eq!(s.leader_of(1), 1);
        assert_eq!(s.leader_of(5), 2);
    }

    #[test]
    fn collective_parse_and_names() {
        assert_eq!(
            Collective::parse("allreduce").unwrap(),
            Collective::Allreduce
        );
        assert_eq!(
            Collective::parse("reduce:3").unwrap(),
            Collective::Reduce { root: 3 }
        );
        assert_eq!(
            Collective::parse("broadcast:0").unwrap(),
            Collective::Broadcast { root: 0 }
        );
        assert_eq!(
            Collective::parse("barrier").unwrap(),
            Collective::Barrier
        );
        assert!(Collective::parse("reduce").is_err());
        assert!(Collective::parse("reduce:x").is_err());
        assert!(Collective::parse("gather:0").is_err());
        for c in [
            Collective::Allreduce,
            Collective::Reduce { root: 2 },
            Collective::Broadcast { root: 2 },
            Collective::Barrier,
        ] {
            assert_eq!(Collective::parse(&c.name()).unwrap(), c);
        }
    }

    #[test]
    fn derived_leaders_are_forced_to_the_root() {
        let mut s = spec(4);
        s.collective = Collective::Reduce { root: 2 };
        for b in 0..8 {
            assert_eq!(s.leader_of(b), 2);
        }
        s.collective = Collective::Broadcast { root: 1 };
        for b in 0..8 {
            assert_eq!(s.leader_of(b), 1);
        }
    }

    #[test]
    fn broadcast_neutral_contributions_and_expectation() {
        let mut s = spec(3);
        s.collective = Collective::Broadcast { root: 1 };
        // non-roots contribute zeros; the expected block is the root's
        assert_eq!(s.payload_of(0, 2, 8), vec![0i32; 8]);
        assert_eq!(s.payload_of(2, 2, 8), vec![0i32; 8]);
        let root_data = block_payload(1, 1, 2, 8);
        assert_eq!(s.payload_of(1, 2, 8), root_data);
        assert_eq!(s.expected_block(2, 8), root_data);
        // and the allreduce expectation is the plain sum
        s.collective = Collective::Allreduce;
        assert_eq!(
            s.expected_block(2, 8),
            expected_block_sum(1, &s.participants, 2, 8)
        );
    }

    #[test]
    fn reduce_completes_on_the_root_alone() {
        let mut sp = spec(3);
        sp.collective = Collective::Reduce { root: 1 };
        let mut j = JobRuntime::new(sp);
        j.host_finished(0, 100);
        assert!(j.finish.is_none());
        j.host_finished(1, 250);
        assert_eq!(j.finish, Some(250));
        // later ranks don't move the completion time
        j.host_finished(2, 400);
        assert_eq!(j.finish, Some(250));
    }

    #[test]
    fn start_offset_shifts_runtime_accounting() {
        let mut sp = spec(2);
        sp.start_ps = 1_000;
        let mut j = JobRuntime::new(sp);
        j.host_finished(0, 5_000);
        j.host_finished(1, 6_000);
        assert_eq!(j.runtime_ps(), Some(5_000));
    }

    #[test]
    fn job_finishes_when_all_hosts_do() {
        let mut j = JobRuntime::new(spec(2));
        j.host_finished(0, 100);
        assert!(j.finish.is_none());
        j.host_finished(0, 150); // duplicate ignored
        assert!(j.finish.is_none());
        j.host_finished(1, 200);
        assert_eq!(j.finish, Some(200));
        assert_eq!(j.runtime_ps(), Some(200));
    }

    #[test]
    fn payload_deterministic_and_distinct() {
        let a = block_payload(1, 5, 7, 16);
        let b = block_payload(1, 5, 7, 16);
        let c = block_payload(1, 6, 7, 16);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|&v| v.abs() <= 1 << 20));
    }

    #[test]
    fn expected_sum_matches_manual() {
        let hosts = [0u32, 1, 2];
        let exp = expected_block_sum(9, &hosts, 3, 8);
        let mut manual = vec![0i64; 8];
        for &h in &hosts {
            for (m, v) in manual
                .iter_mut()
                .zip(block_payload(9, h, 3, 8).iter())
            {
                *m += *v as i64;
            }
        }
        let manual: Vec<i32> = manual.into_iter().map(|v| v as i32).collect();
        assert_eq!(exp, manual, "no saturation expected at this scale");
    }
}
