//! Derived collectives (paper Section 6, "Support for other
//! collectives"): `reduce`, `broadcast`, and `barrier` expressed on the
//! allreduce machinery. Since the Collective API redesign these run end
//! to end — [`crate::collectives::Collective`] is carried by every
//! [`crate::collectives::JobSpec`] and the host engines consult it:
//!
//! - **reduce(root)**: every block's leader is forced to the root
//!   (Section 6: "selecting as leader node the destination"); on Canary
//!   the value broadcast is replaced by a header-only descriptor
//!   release, on static trees only the broadcast clones on the path
//!   toward the root host carry values — every other participant gets
//!   a header-only release that drains its injection window. The job
//!   completes when the root holds all blocks
//!   ([`crate::collectives::Collective::completion_rank`]).
//! - **broadcast(src)**: the source leads every block and the other
//!   participants contribute the neutral element (zeros), so the
//!   aggregated "sum" *is* the source's data and the ordinary broadcast
//!   phase delivers it to everyone.
//! - **barrier**: a zero-byte allreduce — one empty block, complete when
//!   every participant has seen it.
//!
//! This module keeps the small arrangement helpers that predate the
//! typed API (they remain the paper-faithful definitions the tests pin).

use crate::sim::packet::PAYLOAD_BYTES;
use crate::sim::NodeId;

/// Block count for a barrier: a single (empty) block.
pub fn barrier_blocks() -> u32 {
    1
}

/// Data size that makes every participant lead exactly once (useful for
/// stress tests of the leader role).
pub fn one_block_per_leader_bytes(n_hosts: usize) -> u64 {
    n_hosts as u64 * PAYLOAD_BYTES as u64
}

/// Leader arrangement for a `reduce` toward `root`: every block is led
/// by the root (Section 6: "selecting as leader node the destination").
pub fn reduce_leader_of(root: NodeId, _block: u32) -> NodeId {
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(barrier_blocks(), 1);
        assert_eq!(one_block_per_leader_bytes(4), 4 * 1024);
        assert_eq!(reduce_leader_of(7, 123), 7);
    }
}
