//! Derived collectives (paper Section 6, "Support for other
//! collectives"): `reduce`, `broadcast`, and `barrier` expressed on the
//! allreduce machinery.
//!
//! - **reduce(root)**: an allreduce whose leader is forced to the
//!   destination host and whose broadcast phase is skipped — modelled as
//!   a Canary job where only the root needs the result, so completion is
//!   the leader completing all blocks.
//! - **barrier**: a zero-byte allreduce (one empty block).
//! - **broadcast(src)**: the source plays leader for every block and
//!   starts the broadcast immediately (no aggregation): modelled as a
//!   1-contributor Canary job whose broadcast fans out to all hosts.
//!
//! These reuse the verbatim job machinery; what changes is the
//! participant/leader arrangement and the completion rule, so they are
//! thin wrappers producing `JobSpec`-compatible setups.

use crate::sim::packet::PAYLOAD_BYTES;
use crate::sim::NodeId;

/// Block count for a barrier: a single (empty) block.
pub fn barrier_blocks() -> u32 {
    1
}

/// Data size that makes every participant lead exactly once (useful for
/// stress tests of the leader role).
pub fn one_block_per_leader_bytes(n_hosts: usize) -> u64 {
    n_hosts as u64 * PAYLOAD_BYTES as u64
}

/// Leader arrangement for a `reduce` toward `root`: every block is led
/// by the root (Section 6: "selecting as leader node the destination").
pub fn reduce_leader_of(root: NodeId, _block: u32) -> NodeId {
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(barrier_blocks(), 1);
        assert_eq!(one_block_per_leader_bytes(4), 4 * 1024);
        assert_eq!(reduce_leader_of(7, 123), 7);
    }
}
