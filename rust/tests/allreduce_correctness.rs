//! End-to-end value correctness: every participating host must receive,
//! for every block, exactly the saturating fixed-point sum of all
//! participants' payloads — under dynamic trees, collisions, stragglers,
//! congestion, and adaptive routing.
//!
//! These are the coordinator invariants the paper's protocol must
//! guarantee (Sections 3.1-3.2); they are checked with the
//! `proptest_lite` randomized-property harness.

use canary::collectives::{expected_block_sum, runner, Algo};
use canary::config::{FatTreeConfig, SimConfig};
use canary::loadbalance::LoadBalancer;
use canary::sim::US;
use canary::traffic::TrafficSpec;
use canary::util::proptest_lite::check_property;
use canary::util::rng::Rng;
use canary::workload::{build_scenario, Scenario};

/// Verify all recorded results of job 0 against the expected sums.
fn verify_all_results(
    exp: &canary::workload::Experiment,
) -> Result<(), String> {
    let job = &exp.net.jobs[exp.job as usize];
    let spec = &job.spec;
    let total_blocks = spec.total_blocks();
    let n = spec.participants.len() as u32;
    if job.finish.is_none() {
        return Err(format!(
            "job did not finish (hosts done: {}/{n})",
            job.hosts_finished
        ));
    }
    let lanes = spec.lanes();
    let mut checked = 0usize;
    for block in 0..total_blocks {
        let expected = expected_block_sum(
            spec.tenant,
            &spec.participants,
            block,
            lanes,
        );
        for rank in 0..n {
            let Some(got) = job.results.get(&(rank, block)) else {
                // the leader of a block keeps its result locally; it is
                // recorded too, so every (rank, block) must exist
                return Err(format!(
                    "missing result rank {rank} block {block}"
                ));
            };
            if got != &expected {
                return Err(format!(
                    "wrong value rank {rank} block {block}"
                ));
            }
            checked += 1;
        }
    }
    assert_eq!(checked, (total_blocks * n) as usize);
    Ok(())
}

fn values_scenario(
    topo: FatTreeConfig,
    sim: SimConfig,
    algo: Algo,
    hosts: u32,
    congestion: bool,
    data_bytes: u64,
) -> Scenario {
    Scenario {
        topo,
        sim: sim.with_values(true),
        lb: LoadBalancer::default(),
        algo,
        n_allreduce_hosts: hosts,
        traffic: congestion.then(TrafficSpec::uniform),
        data_bytes,
        record_results: true,
    }
}

fn run_and_verify(sc: &Scenario, seed: u64) -> Result<(), String> {
    let mut exp = build_scenario(sc, seed);
    runner::run_to_completion(&mut exp.net, 200_000 * US);
    verify_all_results(&exp)?;
    // descriptor soft-state must fully drain on a clean run
    let m = &exp.net.metrics;
    if m.descriptors_live != 0 {
        return Err(format!("{} descriptors leaked", m.descriptors_live));
    }
    Ok(())
}

#[test]
fn canary_correct_tiny_no_congestion() {
    let sc = values_scenario(
        FatTreeConfig::tiny(),
        SimConfig::default(),
        Algo::Canary,
        6,
        false,
        16 * 1024,
    );
    run_and_verify(&sc, 7).unwrap();
}

#[test]
fn canary_correct_with_congestion_and_random_sizes() {
    check_property("canary-values-congested", 0xC0, 8, |rng: &mut Rng| {
        let hosts = 3 + rng.gen_range(10) as u32;
        let kib = 1 + rng.gen_range(24);
        let sc = values_scenario(
            FatTreeConfig::small(),
            SimConfig::default(),
            Algo::Canary,
            hosts,
            true,
            kib * 1024,
        );
        run_and_verify(&sc, rng.next_u64())
    });
}

#[test]
fn canary_correct_under_forced_collisions() {
    // 4 descriptor slots per switch: nearly every concurrent block
    // collides, so the tree-restoration path carries most subtrees
    check_property("canary-collisions", 0xC1, 6, |rng: &mut Rng| {
        let sc = values_scenario(
            FatTreeConfig::tiny(),
            SimConfig::default().with_slots(4),
            Algo::Canary,
            4 + rng.gen_range(4) as u32,
            false,
            16 * 1024,
        );
        let mut exp = build_scenario(&sc, rng.next_u64());
        runner::run_to_completion(&mut exp.net, 200_000 * US);
        if exp.net.metrics.collisions == 0 {
            return Err("expected collisions with 4 slots".into());
        }
        verify_all_results(&exp)
    });
}

#[test]
fn canary_correct_with_tiny_timeout_all_stragglers() {
    // 50 ns timeout: descriptors fire before most packets arrive, so the
    // protocol must stay correct when almost everything is a straggler
    let sc = values_scenario(
        FatTreeConfig::tiny(),
        SimConfig::default().with_timeout(50_000),
        Algo::Canary,
        8,
        false,
        8 * 1024,
    );
    let mut exp = build_scenario(&sc, 3);
    runner::run_to_completion(&mut exp.net, 200_000 * US);
    assert!(exp.net.metrics.stragglers > 0, "expected stragglers");
    verify_all_results(&exp).unwrap();
}

#[test]
fn canary_correct_with_huge_timeout() {
    // 50 us timeout: full aggregation at every hop, minimal packets
    let sc = values_scenario(
        FatTreeConfig::tiny(),
        SimConfig::default().with_timeout(50 * US),
        Algo::Canary,
        8,
        false,
        8 * 1024,
    );
    run_and_verify(&sc, 4).unwrap();
}

#[test]
fn static_tree_correct_one_and_four_trees() {
    for n_trees in [1u8, 4] {
        check_property("static-values", 0xC2, 4, |rng: &mut Rng| {
            let sc = values_scenario(
                FatTreeConfig::small(),
                SimConfig::default(),
                Algo::StaticTree { n_trees },
                3 + rng.gen_range(12) as u32,
                rng.chance(0.5),
                (1 + rng.gen_range(16)) * 1024,
            );
            run_and_verify(&sc, rng.next_u64())
        });
    }
}

#[test]
fn single_block_and_barrier_sizes() {
    // data smaller than one packet (barrier-like) still works
    for &bytes in &[1u64, 4, 1024] {
        let sc = values_scenario(
            FatTreeConfig::tiny(),
            SimConfig::default(),
            Algo::Canary,
            5,
            false,
            bytes,
        );
        run_and_verify(&sc, 9).unwrap();
    }
}

#[test]
fn two_hosts_minimum() {
    let sc = values_scenario(
        FatTreeConfig::tiny(),
        SimConfig::default(),
        Algo::Canary,
        2,
        false,
        4 * 1024,
    );
    run_and_verify(&sc, 11).unwrap();
}

#[test]
fn ring_completes_at_expected_bandwidth() {
    // not value-carrying, but timing must match the analytic model
    let sc = Scenario {
        topo: FatTreeConfig::small(),
        sim: SimConfig::default(),
        lb: LoadBalancer::default(),
        algo: Algo::Ring,
        n_allreduce_hosts: 16,
        traffic: None,
        data_bytes: 1 << 20,
        record_results: false,
    };
    let mut exp = build_scenario(&sc, 5);
    let res = runner::run_to_completion(&mut exp.net, 200_000 * US);
    let g = res[0].goodput_gbps.expect("ring finished");
    // bandwidth-optimal ring: B/2 * N/(N-1) * payload efficiency ~ 45;
    // accept a generous band
    assert!(g > 30.0 && g < 60.0, "ring goodput {g}");
}

#[test]
fn multi_tenant_concurrent_jobs_all_correct() {
    use canary::workload::build_multi_tenant;
    let (mut net, _ft, jobs) = build_multi_tenant(
        FatTreeConfig::small(),
        SimConfig::default().with_values(true),
        LoadBalancer::default(),
        Algo::Canary,
        4,
        8 * 1024,
        77,
    );
    // enable result recording on every job
    for j in net.jobs.iter_mut() {
        j.spec.record_results = true;
    }
    runner::run_to_completion(&mut net, 200_000 * US);
    for &job in &jobs {
        let j = &net.jobs[job as usize];
        assert!(j.finish.is_some(), "tenant {} unfinished", j.spec.tenant);
        let lanes = j.spec.lanes();
        for block in 0..j.spec.total_blocks() {
            let expected = expected_block_sum(
                j.spec.tenant,
                &j.spec.participants,
                block,
                lanes,
            );
            for rank in 0..j.spec.participants.len() as u32 {
                assert_eq!(
                    j.results.get(&(rank, block)).expect("result"),
                    &expected,
                    "tenant {} rank {rank} block {block}",
                    j.spec.tenant
                );
            }
        }
    }
}

#[test]
fn all_load_balancers_preserve_correctness() {
    for lb in [
        LoadBalancer::DefaultAdaptive { threshold: 0.5 },
        LoadBalancer::Ecmp,
        LoadBalancer::MinQueue,
        LoadBalancer::Flowlet { gap_ps: 5 * US },
    ] {
        let mut sc = values_scenario(
            FatTreeConfig::small(),
            SimConfig::default(),
            Algo::Canary,
            10,
            true,
            8 * 1024,
        );
        sc.lb = lb;
        run_and_verify(&sc, 13).unwrap();
    }
}
