//! End-to-end value correctness: every participating host must receive,
//! for every block, exactly the saturating fixed-point sum of all
//! participants' payloads — under dynamic trees, collisions, stragglers,
//! congestion, and adaptive routing. The derived collectives (Section 6)
//! are held to their own semantics: a reduce's root holds the sum, a
//! broadcast delivers the root's payload everywhere, a barrier is a
//! one-empty-block allreduce.
//!
//! These are the coordinator invariants the paper's protocol must
//! guarantee (Sections 3.1-3.2, 6); they are checked with the
//! `proptest_lite` randomized-property harness.

use canary::collectives::{
    runner, verify_job, Algo, Collective,
};
use canary::config::{FatTreeConfig, SimConfig};
use canary::faults::FaultPlan;
use canary::loadbalance::LoadBalancer;
use canary::sim::US;
use canary::traffic::TrafficSpec;
use canary::util::proptest_lite::check_property;
use canary::util::rng::Rng;
use canary::workload::{JobBuilder, ScenarioBuilder};

/// Verify all recorded results of the experiment's first job.
fn verify_all_results(
    exp: &canary::workload::Experiment,
) -> Result<(), String> {
    verify_job(&exp.net.jobs[exp.job as usize])
}

fn values_scenario(
    topo: FatTreeConfig,
    sim: SimConfig,
    algo: Algo,
    hosts: u32,
    congestion: bool,
    data_bytes: u64,
) -> ScenarioBuilder {
    ScenarioBuilder::new(topo)
        .sim(sim.with_values(true))
        .traffic(congestion.then(TrafficSpec::uniform))
        .job(
            JobBuilder::new(algo)
                .hosts(hosts)
                .data_bytes(data_bytes)
                .record_results(true),
        )
}

fn run_and_verify(sc: &ScenarioBuilder, seed: u64) -> Result<(), String> {
    let mut exp = sc.build(seed);
    runner::run_to_completion(&mut exp.net, 200_000 * US);
    verify_all_results(&exp)?;
    // descriptor soft-state must fully drain on a clean run
    let m = &exp.net.metrics;
    if m.descriptors_live != 0 {
        return Err(format!("{} descriptors leaked", m.descriptors_live));
    }
    Ok(())
}

#[test]
fn canary_correct_tiny_no_congestion() {
    let sc = values_scenario(
        FatTreeConfig::tiny(),
        SimConfig::default(),
        Algo::Canary,
        6,
        false,
        16 * 1024,
    );
    run_and_verify(&sc, 7).unwrap();
}

#[test]
fn canary_correct_with_congestion_and_random_sizes() {
    check_property("canary-values-congested", 0xC0, 8, |rng: &mut Rng| {
        let hosts = 3 + rng.gen_range(10) as u32;
        let kib = 1 + rng.gen_range(24);
        let sc = values_scenario(
            FatTreeConfig::small(),
            SimConfig::default(),
            Algo::Canary,
            hosts,
            true,
            kib * 1024,
        );
        run_and_verify(&sc, rng.next_u64())
    });
}

#[test]
fn canary_correct_under_forced_collisions() {
    // 4 descriptor slots per switch: nearly every concurrent block
    // collides, so the tree-restoration path carries most subtrees
    check_property("canary-collisions", 0xC1, 6, |rng: &mut Rng| {
        let sc = values_scenario(
            FatTreeConfig::tiny(),
            SimConfig::default().with_slots(4),
            Algo::Canary,
            4 + rng.gen_range(4) as u32,
            false,
            16 * 1024,
        );
        let mut exp = sc.build(rng.next_u64());
        runner::run_to_completion(&mut exp.net, 200_000 * US);
        if exp.net.metrics.collisions == 0 {
            return Err("expected collisions with 4 slots".into());
        }
        verify_all_results(&exp)
    });
}

#[test]
fn canary_correct_with_tiny_timeout_all_stragglers() {
    // 50 ns timeout: descriptors fire before most packets arrive, so the
    // protocol must stay correct when almost everything is a straggler
    let sc = values_scenario(
        FatTreeConfig::tiny(),
        SimConfig::default().with_timeout(50_000),
        Algo::Canary,
        8,
        false,
        8 * 1024,
    );
    let mut exp = sc.build(3);
    runner::run_to_completion(&mut exp.net, 200_000 * US);
    assert!(exp.net.metrics.stragglers > 0, "expected stragglers");
    verify_all_results(&exp).unwrap();
}

#[test]
fn canary_correct_with_huge_timeout() {
    // 50 us timeout: full aggregation at every hop, minimal packets
    let sc = values_scenario(
        FatTreeConfig::tiny(),
        SimConfig::default().with_timeout(50 * US),
        Algo::Canary,
        8,
        false,
        8 * 1024,
    );
    run_and_verify(&sc, 4).unwrap();
}

#[test]
fn static_tree_correct_one_and_four_trees() {
    for n_trees in [1u8, 4] {
        check_property("static-values", 0xC2, 4, |rng: &mut Rng| {
            let sc = values_scenario(
                FatTreeConfig::small(),
                SimConfig::default(),
                Algo::StaticTree { n_trees },
                3 + rng.gen_range(12) as u32,
                rng.chance(0.5),
                (1 + rng.gen_range(16)) * 1024,
            );
            run_and_verify(&sc, rng.next_u64())
        });
    }
}

#[test]
fn single_block_and_barrier_sizes() {
    // data smaller than one packet (barrier-like) still works
    for &bytes in &[1u64, 4, 1024] {
        let sc = values_scenario(
            FatTreeConfig::tiny(),
            SimConfig::default(),
            Algo::Canary,
            5,
            false,
            bytes,
        );
        run_and_verify(&sc, 9).unwrap();
    }
}

#[test]
fn two_hosts_minimum() {
    let sc = values_scenario(
        FatTreeConfig::tiny(),
        SimConfig::default(),
        Algo::Canary,
        2,
        false,
        4 * 1024,
    );
    run_and_verify(&sc, 11).unwrap();
}

#[test]
fn ring_completes_at_expected_bandwidth() {
    // not value-carrying, but timing must match the analytic model
    let sc = ScenarioBuilder::new(FatTreeConfig::small())
        .job(JobBuilder::new(Algo::Ring).hosts(16).data_bytes(1 << 20));
    let mut exp = sc.build(5);
    let res = runner::run_to_completion(&mut exp.net, 200_000 * US);
    let g = res[0].goodput_gbps.expect("ring finished");
    // bandwidth-optimal ring: B/2 * N/(N-1) * payload efficiency ~ 45;
    // accept a generous band
    assert!(g > 30.0 && g < 60.0, "ring goodput {g}");
}

#[test]
fn multi_tenant_concurrent_jobs_all_correct() {
    let sc = ScenarioBuilder::new(FatTreeConfig::small())
        .sim(SimConfig::default().with_values(true))
        .jobs(
            4,
            JobBuilder::new(Algo::Canary)
                .hosts(16)
                .data_bytes(8 * 1024)
                .record_results(true),
        );
    let mut exp = sc.build(77);
    runner::run_to_completion(&mut exp.net, 200_000 * US);
    assert_eq!(exp.jobs.len(), 4);
    for &job in &exp.jobs {
        verify_job(&exp.net.jobs[job as usize]).unwrap_or_else(|e| {
            panic!(
                "tenant {}: {e}",
                exp.net.jobs[job as usize].spec.tenant
            )
        });
    }
}

// ---- derived collectives (Section 6) end to end ----------------------

/// Reduce/broadcast/barrier under uniform cross traffic, all engines:
/// value semantics hold per collective (reduce: root holds the sum;
/// broadcast: everyone holds the root's payload; barrier: one empty
/// block everywhere). Ring carries no values and is verified for
/// completion.
#[test]
fn derived_collectives_correct_under_cross_traffic() {
    let collectives = [
        Collective::Reduce { root: 0 },
        Collective::Reduce { root: 3 },
        Collective::Broadcast { root: 0 },
        Collective::Broadcast { root: 2 },
        Collective::Barrier,
    ];
    for c in collectives {
        for algo in [
            Algo::Canary,
            Algo::StaticTree { n_trees: 1 },
            Algo::StaticTree { n_trees: 4 },
            Algo::Ring,
        ] {
            let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
                .sim(SimConfig::default().with_values(true))
                .traffic(Some(TrafficSpec::uniform()))
                .job(
                    JobBuilder::new(algo)
                        .collective(c)
                        .hosts(5)
                        .data_bytes(8 * 1024)
                        .record_results(true),
                );
            let mut exp = sc.build(23);
            runner::run_to_completion(&mut exp.net, 200_000 * US);
            verify_job(&exp.net.jobs[exp.job as usize]).unwrap_or_else(
                |e| panic!("{} on {}: {e}", c.name(), algo.name()),
            );
        }
    }
}

#[test]
fn derived_collectives_correct_under_packet_drops() {
    // random loss + retransmission timers: the recovery machinery must
    // preserve each collective's value semantics, not just allreduce's
    check_property("derived-loss", 0xD0, 4, |rng: &mut Rng| {
        let collectives = [
            Collective::Reduce { root: 1 },
            Collective::Broadcast { root: 1 },
            Collective::Barrier,
        ];
        let c = *rng.choose(&collectives);
        let hosts = 4 + rng.gen_range(4) as u32;
        let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
            .sim(
                SimConfig::default()
                    .with_values(true)
                    .with_retrans(200 * US, true),
            )
            .job(
                JobBuilder::new(Algo::Canary)
                    .collective(c)
                    .hosts(hosts)
                    .data_bytes(4 * 1024)
                    .record_results(true),
            );
        let mut exp = sc.build(rng.next_u64());
        exp.net.faults = FaultPlan::default().with_loss(0.02);
        runner::run_to_completion(&mut exp.net, 2_000_000 * US);
        verify_job(&exp.net.jobs[exp.job as usize])
            .map_err(|e| format!("{}: {e}", c.name()))
    });
}

/// A bounded in-flight window must not deadlock a reduce: non-root
/// participants never receive result values, but the release wave
/// (header-only on Canary, payload-stripped broadcast clones on static
/// trees) must still drain their windows so later blocks flow.
#[test]
fn reduce_completes_with_a_bounded_window() {
    for algo in [Algo::Canary, Algo::StaticTree { n_trees: 1 }] {
        // 32 blocks against a 4-block window: completion requires ~8
        // window refills at every non-root host
        let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
            .sim(SimConfig::default().with_values(true).with_window(4))
            .job(
                JobBuilder::new(algo)
                    .collective(Collective::Reduce { root: 0 })
                    .hosts(6)
                    .data_bytes(32 * 1024)
                    .record_results(true),
            );
        let mut exp = sc.build(29);
        runner::run_to_completion(&mut exp.net, 200_000 * US);
        verify_job(&exp.net.jobs[exp.job as usize])
            .unwrap_or_else(|e| panic!("windowed reduce on {}: {e}", algo.name()));
    }
}

/// A canary reduce must not ship result payloads back down the fabric:
/// its release wave is header-only (the root already has the sum), while
/// an allreduce's broadcast carries full packets.
#[test]
fn reduce_release_wave_is_header_only() {
    let bcast_bytes = |collective: Collective| -> u64 {
        let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
            .sim(SimConfig::default().with_values(true))
            .job(
                JobBuilder::new(Algo::Canary)
                    .collective(collective)
                    .hosts(6)
                    .data_bytes(32 * 1024)
                    .record_results(true),
            );
        let mut exp = sc.build(31);
        runner::run_to_completion(&mut exp.net, 200_000 * US);
        verify_job(&exp.net.jobs[exp.job as usize]).unwrap();
        exp.net.links.iter().map(|l| l.bytes_tx).sum()
    };
    let allreduce = bcast_bytes(Collective::Allreduce);
    let reduce = bcast_bytes(Collective::Reduce { root: 0 });
    assert!(
        (reduce as f64) < 0.75 * allreduce as f64,
        "reduce moved {reduce} B vs allreduce {allreduce} B — the \
         broadcast phase should have shrunk to headers"
    );
}

#[test]
fn all_load_balancers_preserve_correctness() {
    for lb in [
        LoadBalancer::DefaultAdaptive { threshold: 0.5 },
        LoadBalancer::Ecmp,
        LoadBalancer::MinQueue,
        LoadBalancer::Flowlet { gap_ps: 5 * US },
    ] {
        let sc = values_scenario(
            FatTreeConfig::small(),
            SimConfig::default(),
            Algo::Canary,
            10,
            true,
            8 * 1024,
        )
        .lb(lb);
        run_and_verify(&sc, 13).unwrap();
    }
}
