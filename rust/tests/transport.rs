//! Reactive-transport guarantees (DESIGN.md §2.4): the default
//! `TransportSpec::None` is bit-identical to the pre-transport
//! simulator and leaves zero transport footprint (so every recorded
//! BENCH/figure series stays valid); ECN marking is deterministic at a
//! step threshold; DCQCN/Swift back off, recover losses via RTO
//! retransmission and complete flows an unreactive sender loses; and
//! the CNP/retransmit accounting obeys its invariants end to end.
//!
//! (The DCQCN decrease/recovery monotonicity of the `FlowCc` state
//! machine itself is unit-tested in `transport::cc`.)

mod common;

use canary::collectives::Algo;
use canary::config::{FatTreeConfig, SimConfig};
use canary::metrics::Metrics;
use canary::sim::{PacketKind, Time, US};
use canary::traffic::TrafficSpec;
use canary::transport::TransportSpec;
use canary::workload::{JobBuilder, ScenarioBuilder};
use common::{figure_scenario, incast_scenario};

/// Everything a run's outcome hangs on, bitwise.
#[allow(clippy::type_complexity)]
fn fingerprint(
    m: &Metrics,
    now: Time,
    events: u64,
) -> (u64, Time, u64, u64, u64, u64, u64, Vec<Time>) {
    (
        events,
        now,
        m.pkts_delivered,
        m.drops_overflow,
        m.flows.started,
        m.flows.completed,
        m.flows.delivered_bytes,
        m.flows.fct_ps.clone(),
    )
}

fn assert_zero_transport_footprint(m: &Metrics) {
    assert_eq!(m.ecn_marks, 0, "marking ran with transport off");
    assert_eq!(m.pkts_of_kind(PacketKind::TransportAck), 0);
    assert_eq!(m.pkts_of_kind(PacketKind::TransportCnp), 0);
    let f = &m.flows;
    assert_eq!(
        (
            f.ecn_delivered,
            f.cnps_sent,
            f.cnps_received,
            f.acks_received,
            f.retrans_pkts,
            f.dup_pkts,
            f.dup_bytes,
            f.rto_fired,
            f.abandoned,
        ),
        (0, 0, 0, 0, 0, 0, 0, 0, 0),
        "transport counters moved with transport off"
    );
}

/// Bit-compat pin: with `TransportSpec::None` (the default) the
/// recorded figure scenario's final metrics are bit-identical whatever
/// the transport-layer knobs say, and the transport machinery leaves
/// zero footprint — the ECN/CC/recovery code is provably inert, so
/// every recorded BENCH series stays valid. (That the engine's send
/// path makes the seed's exact RNG draws/packets/cadence is pinned
/// separately against an inlined legacy replica in
/// `tests/traffic_engine.rs`; together the two pins cover the
/// transport-off surface.)
#[test]
fn transport_none_is_bit_identical_and_footprint_free() {
    let baseline = {
        let mut exp = figure_scenario(SimConfig::default()).build(42);
        canary::collectives::runner::run_to_completion(&mut exp.net, u64::MAX);
        assert_zero_transport_footprint(&exp.net.metrics);
        fingerprint(&exp.net.metrics, exp.net.now, exp.net.events_processed)
    };
    // crank every transport-layer knob; with transport off none of
    // them may perturb a single event
    let mut sim = SimConfig::default().with_transport_rto(US);
    sim.ecn_kmin_bytes = 1;
    sim.ecn_kmax_bytes = 2;
    let perturbed = {
        let mut exp = figure_scenario(sim).build(42);
        canary::collectives::runner::run_to_completion(&mut exp.net, u64::MAX);
        assert_zero_transport_footprint(&exp.net.metrics);
        fingerprint(&exp.net.metrics, exp.net.now, exp.net.events_processed)
    };
    assert_eq!(baseline, perturbed, "transport knobs leaked into a None run");
    assert!(baseline.4 > 0, "cross traffic generated no flows");
}

/// ECN marking at a forced hotspot: with `kmin == kmax` the RED ramp
/// degenerates to the deterministic DCTCP-style step, so two runs mark
/// the exact same packets; an unreachably high threshold marks nothing.
#[test]
fn ecn_marking_is_deterministic_at_a_forced_hotspot() {
    let run = |kmin: u64, kmax: u64| {
        let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
            .traffic(Some(
                TrafficSpec::incast(5)
                    .with_transport(TransportSpec::Dcqcn)
                    .with_ecn(kmin, kmax),
            ))
            .job(JobBuilder::new(Algo::Canary).hosts(2).data_bytes(64 * 1024));
        let mut exp = sc.build(7);
        exp.net.kick_jobs();
        exp.net.run_all(500 * US);
        (exp.net.metrics.ecn_marks, exp.net.events_processed)
    };
    let a = run(4096, 4096);
    let b = run(4096, 4096);
    assert_eq!(a, b, "step-threshold marking must be deterministic");
    assert!(a.0 > 0, "a 5:1 incast must cross a 4 KiB threshold");
    let silent = run(1 << 40, 1 << 40);
    assert_eq!(silent.0, 0, "unreachable threshold must never mark");
}

/// Loss recovery end to end on `tiny`: the unreactive sender loses
/// flow tails to the policer and they die silently; DCQCN backs off
/// and retransmits, so the background completion fraction improves —
/// the acceptance shape of the transport subsystem.
#[test]
fn dcqcn_retransmits_and_improves_completion_under_incast_overload() {
    let run = |tp: TransportSpec| {
        let mut exp = incast_scenario(tp).build(11);
        exp.net.kick_jobs();
        exp.net.run_all(3000 * US);
        exp.net
    };
    let none = run(TransportSpec::None);
    let dcqcn = run(TransportSpec::Dcqcn);

    let nf = &none.metrics.flows;
    assert!(none.metrics.drops_overflow > 0, "overload must drop");
    assert!(nf.started > 0);
    assert!(
        nf.completion_fraction() < 0.9,
        "unreactive overload should lose flows, completed {:.2}",
        nf.completion_fraction()
    );

    let df = &dcqcn.metrics.flows;
    assert!(dcqcn.metrics.ecn_marks > 0, "marking must engage");
    assert!(df.cnps_sent > 0, "sinks must echo CNPs");
    assert!(df.cnps_received > 0, "senders must hear CNPs");
    assert!(df.retrans_pkts > 0, "lost tails must be retransmitted");
    assert!(df.completed > 0);
    assert!(
        df.completion_fraction() > nf.completion_fraction(),
        "reactive {:.3} must beat unreactive {:.3}",
        df.completion_fraction(),
        nf.completion_fraction()
    );
}

/// Swift (delay-based) also reacts and recovers: ACKs flow back,
/// retransmission fills policer losses, completion beats unreactive.
#[test]
fn swift_reacts_and_completes_flows() {
    let run = |tp: TransportSpec| {
        let mut exp = incast_scenario(tp).build(13);
        exp.net.kick_jobs();
        exp.net.run_all(3000 * US);
        exp.net
    };
    let none = run(TransportSpec::None);
    let swift = run(TransportSpec::Swift);
    let sf = &swift.metrics.flows;
    assert!(sf.acks_received > 0, "delay samples must reach senders");
    assert_eq!(sf.cnps_sent, 0, "Swift never emits CNPs");
    assert!(sf.completed > 0);
    assert!(
        sf.completion_fraction()
            > none.metrics.flows.completion_fraction(),
        "swift {:.3} must beat unreactive {:.3}",
        sf.completion_fraction(),
        none.metrics.flows.completion_fraction()
    );
}

/// CNP / retransmission accounting invariants in `FlowStats`, checked
/// on a run where everything engages.
#[test]
fn cnp_and_retransmit_accounting_invariants() {
    let mut exp = incast_scenario(TransportSpec::Dcqcn).build(17);
    exp.net.kick_jobs();
    exp.net.run_all(3000 * US);
    let m = &exp.net.metrics;
    let f = &m.flows;

    // CNPs: received <= sent (they ride the droppable class), sent <=
    // CE deliveries (at most one CNP per marked delivery, interval-
    // limited), CE deliveries <= marks (marked packets can be dropped
    // downstream of the marking queue, never unmarked)
    assert!(f.cnps_received <= f.cnps_sent);
    assert!(f.cnps_sent <= f.ecn_delivered);
    assert!(f.ecn_delivered <= m.ecn_marks);

    // recovery: every duplicate a sink absorbed is a retransmitted
    // copy; goodput counts first copies only
    assert!(f.dup_pkts <= f.retrans_pkts);
    assert!(f.throughput_bytes() >= f.goodput_bytes());
    assert_eq!(f.throughput_bytes() - f.goodput_bytes(), f.dup_bytes);

    // lifecycle stays consistent under retransmission and dedup
    assert_eq!(f.fct_ps.len() as u64, f.completed);
    assert_eq!(f.live_count() as u64 + f.completed, f.started);
    assert!(f.delivered_bytes <= f.offered_bytes);

    // control frames actually crossed the fabric
    assert!(m.pkts_of_kind(PacketKind::TransportAck) > 0);
    assert!(m.pkts_of_kind(PacketKind::TransportCnp) > 0);
}

/// The whole reactive stack is deterministic from its seed (the new
/// RNG draws — RED marking — come from the seeded sim stream).
#[test]
fn reactive_runs_are_deterministic() {
    for tp in [TransportSpec::Dcqcn, TransportSpec::Swift] {
        let run = || {
            let mut exp = incast_scenario(tp).build(23);
            exp.net.kick_jobs();
            exp.net.run_all(1000 * US);
            (
                exp.net.events_processed,
                exp.net.metrics.ecn_marks,
                exp.net.metrics.flows.completed,
                exp.net.metrics.flows.retrans_pkts,
                exp.net.metrics.flows.fct_ps.clone(),
            )
        };
        assert_eq!(run(), run(), "non-deterministic {:?} run", tp);
    }
}
