//! The lint pass linting itself (DESIGN.md §2.8): fixture snippets
//! with seeded D1–D5 violations that must be flagged, the annotation
//! grammar (a reasoned `allow` suppresses, a bare one is a finding),
//! the clean-tree pin (`canary lint` over this crate reports nothing),
//! and the runtime half — the conservation audit passes on a clean run
//! and fires on an injected arena leak / byte-accounting skew.

use std::path::Path;

use canary::collectives::{runner, Algo};
use canary::config::FatTreeConfig;
use canary::lint::rules::{lint_cli_docs, lint_source};
use canary::lint::{lint_tree, Rule};
use canary::sim::invariants::audit;
use canary::sim::{Packet, PacketKind};
use canary::workload::{Experiment, JobBuilder, ScenarioBuilder};

fn rules_of(file: &str, text: &str) -> Vec<Rule> {
    lint_source(file, text).into_iter().map(|f| f.rule).collect()
}

// ------------------------------------------------- D1 unordered-iter

const D1_METHOD: &str = r#"
struct S {
    jobs: HashMap<u64, u32>,
}
fn f(s: &S) {
    for (k, v) in s.jobs.iter() {
        drop((k, v));
    }
}
"#;

#[test]
fn d1_flags_iter_over_a_hash_map_field() {
    assert_eq!(rules_of("x.rs", D1_METHOD), vec![Rule::UnorderedIter]);
}

const D1_FOR: &str = r#"
fn f() {
    let table: HashSet<u32> = HashSet::new();
    for k in table {
        drop(k);
    }
}
"#;

#[test]
fn d1_flags_a_for_loop_over_a_hash_set_binding() {
    assert_eq!(rules_of("x.rs", D1_FOR), vec![Rule::UnorderedIter]);
}

const D1_SORTED: &str = r#"
fn f(jobs: &S) {
    let live: HashMap<u64, u32> = HashMap::new();
    let mut v: Vec<u64> = live.keys().copied().collect();
    v.sort_unstable();
}
"#;

#[test]
fn d1_accepts_a_site_that_provably_sorts() {
    assert_eq!(rules_of("x.rs", D1_SORTED), vec![]);
}

const D1_ALLOWED: &str = r#"
struct S {
    jobs: HashMap<u64, u32>,
}
fn f(s: &mut S) {
    // lint: allow(unordered-iter, pure predicate; no side effects)
    s.jobs.retain(|_, v| *v > 0);
}
"#;

#[test]
fn d1_accepts_a_reasoned_allow_annotation() {
    assert_eq!(rules_of("x.rs", D1_ALLOWED), vec![]);
}

const D1_BARE_ALLOW: &str = r#"
struct S {
    jobs: HashMap<u64, u32>,
}
fn f(s: &mut S) {
    s.jobs.retain(|_, v| *v > 0); // lint: allow(unordered-iter)
}
"#;

#[test]
fn d1_rejects_an_allow_annotation_without_a_reason() {
    let findings = lint_source("x.rs", D1_BARE_ALLOW);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("needs a reason"), "{:?}", findings[0]);
}

const D1_VEC: &str = r#"
fn f() {
    let jobs: Vec<u32> = Vec::new();
    for j in jobs.iter() {
        drop(j);
    }
}
"#;

#[test]
fn d1_ignores_iteration_over_ordered_containers() {
    assert_eq!(rules_of("x.rs", D1_VEC), vec![]);
}

// --------------------------------------------------- D2 wall-clock

const D2_BAD: &str = r#"
fn f() -> std::time::Instant {
    std::time::Instant::now()
}
"#;

#[test]
fn d2_flags_wall_clock_outside_the_allowlist() {
    assert_eq!(rules_of("x.rs", D2_BAD), vec![Rule::WallClock; 2]);
}

#[test]
fn d2_accepts_the_bench_harness() {
    assert_eq!(rules_of("util/bench.rs", D2_BAD), vec![]);
}

const D2_FP: &str = r#"
fn fingerprint(t: std::time::SystemTime) -> u64 {
    // lint: allow(wall-clock, trying to excuse the inexcusable)
    0
}
"#;

#[test]
fn d2_never_excuses_wall_clock_in_a_fingerprint_file() {
    assert_eq!(rules_of("x.rs", D2_FP), vec![Rule::WallClock]);
}

// ---------------------------------------------------------- D3 rng

const D3_BAD: &str = r#"
fn f() -> u64 {
    let mut r = rand::thread_rng();
    r.gen()
}
"#;

#[test]
fn d3_flags_ambient_entropy() {
    assert_eq!(rules_of("x.rs", D3_BAD), vec![Rule::Rng]);
}

#[test]
fn d3_exempts_the_sanctioned_rng_module() {
    assert_eq!(rules_of("util/rng.rs", D3_BAD), vec![]);
}

// -------------------------------------------------- D4 fp-coverage

const D4_MISSING: &str = r#"
pub struct Metrics {
    pub covered: u64,
    pub escaped: u64,
}
impl Metrics {
    pub fn fingerprint(&self) -> u64 {
        self.covered
    }
}
"#;

#[test]
fn d4_flags_a_counter_missing_from_the_digest() {
    let findings = lint_source("metrics.rs", D4_MISSING);
    assert_eq!(
        findings.iter().map(|f| f.rule).collect::<Vec<_>>(),
        vec![Rule::FpCoverage],
        "{findings:?}"
    );
    assert!(findings[0].message.contains("escaped"), "{:?}", findings[0]);
}

const D4_EXCLUDED: &str = r#"
pub struct Metrics {
    pub covered: u64,
    // fp: excluded(derived gauge, both inputs already mixed)
    pub escaped: u64,
}
impl Metrics {
    pub fn fingerprint(&self) -> u64 {
        self.covered
    }
}
"#;

#[test]
fn d4_accepts_a_reasoned_exclusion() {
    assert_eq!(rules_of("metrics.rs", D4_EXCLUDED), vec![]);
}

#[test]
fn d4_is_inert_in_files_without_a_fingerprint() {
    let no_fp = "pub struct Metrics {\n    pub escaped: u64,\n}\n";
    assert_eq!(rules_of("other.rs", no_fp), vec![]);
}

// ------------------------------------------------------ D5 cli-doc

#[test]
fn d5_flags_an_undocumented_flag() {
    let root = Path::new(env!("CARGO_TARGET_TMPDIR")).join("lint_d5_fixture");
    let src = root.join("src");
    std::fs::create_dir_all(&src).unwrap();
    std::fs::write(
        src.join("main.rs"),
        "fn main() {\n    let a = Args::parse(&argv, &[\"documented\", \"missing\"]);\n}\n",
    )
    .unwrap();
    std::fs::write(root.join("README.md"), "Pass `--documented` to do things.\n").unwrap();
    let findings = lint_cli_docs(&root);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, Rule::CliDoc);
    assert!(findings[0].message.contains("--missing"), "{:?}", findings[0]);
}

// ------------------------------------------------- the clean-tree pin

/// `canary lint` over this crate's own source tree reports nothing:
/// every surviving hash-iteration or wall-clock site carries a
/// reasoned annotation, every counter is in the digest or excluded
/// with a reason, every CLI flag is documented. New violations fail
/// here (and in the CI lint job) before they can fail a fingerprint.
#[test]
fn the_tree_is_clean() {
    let findings = lint_tree(Path::new(env!("CARGO_MANIFEST_DIR")));
    let listing: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(listing.is_empty(), "lint findings:\n{}", listing.join("\n"));
}

// ------------------------------------------- the conservation audit

fn clean_run() -> Experiment {
    let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
        .job(JobBuilder::new(Algo::Canary).hosts(4).data_bytes(16 * 1024));
    let mut exp = sc.build(42);
    runner::run_to_completion(&mut exp.net, u64::MAX);
    exp
}

#[test]
fn audit_passes_on_a_clean_drained_run() {
    let exp = clean_run();
    assert_eq!(audit(&exp.net), Ok(()));
}

#[test]
fn audit_fires_on_an_injected_arena_leak() {
    let mut exp = clean_run();
    exp.net.arena.alloc(Packet::data(PacketKind::CanaryReduce, 0, 1));
    let violations = audit(&exp.net).unwrap_err();
    assert!(violations.iter().any(|v| v.contains("arena")), "leak not caught: {violations:?}");
}

#[test]
fn audit_fires_on_byte_accounting_skew() {
    let mut exp = clean_run();
    exp.net.links[0].queued_bytes += 64;
    let violations = audit(&exp.net).unwrap_err();
    assert!(
        violations.iter().any(|v| v.contains("queued_bytes")),
        "skew not caught: {violations:?}"
    );
}

#[test]
fn audit_fires_on_a_descriptor_gauge_skew() {
    let mut exp = clean_run();
    exp.net.metrics.descriptors_live += 1;
    let violations = audit(&exp.net).unwrap_err();
    assert!(
        violations.iter().any(|v| v.contains("descriptors")),
        "gauge skew not caught: {violations:?}"
    );
}
