//! Traffic-engine guarantees: the closed-loop `uniform` pattern is
//! bit-compatible with the legacy background generator (so every
//! recorded figure series is unchanged under the default pattern), every
//! pattern is deterministic from its seed, pattern structure lands on
//! the installed hosts as specified, and flow/FCT accounting is
//! consistent end to end.

use canary::collectives::Algo;
use canary::config::{FatTreeConfig, SimConfig};
use canary::sim::{NodeBody, NodeId, US};
use canary::traffic::engine::{self, next_message, DstPlan};
use canary::traffic::{TrafficPattern, TrafficSpec};
use canary::util::rng::Rng;
use canary::workload::{JobBuilder, ScenarioBuilder};

fn scenario(traffic: Option<TrafficSpec>) -> ScenarioBuilder {
    ScenarioBuilder::new(FatTreeConfig::small())
        .traffic(traffic)
        .job(JobBuilder::new(Algo::Canary).hosts(8).data_bytes(64 * 1024))
}

/// The legacy `host/background.rs` message draw, reproduced verbatim:
/// uniform peer re-drawn until it differs from `me`, fixed message size
/// in MTU packets. The engine's closed-loop uniform path must make the
/// exact same RNG calls in the same order.
fn legacy_next_message(
    rng: &mut Rng,
    me: NodeId,
    participants: &[NodeId],
    bg_message_bytes: u64,
    payload_bytes: u64,
) -> Option<(NodeId, u32)> {
    if participants.len() < 2 {
        return None;
    }
    let dst = loop {
        let cand = *rng.choose(participants);
        if cand != me {
            break cand;
        }
    };
    Some((dst, (bg_message_bytes.div_ceil(payload_bytes)).max(1) as u32))
}

#[test]
fn uniform_is_bit_compatible_with_legacy_generator() {
    let cfg = SimConfig::default();
    // irregular peer set incl. `me`, as a real background job sees it
    let peers: Vec<NodeId> = vec![3, 7, 8, 12, 19, 23, 31, 40, 41, 57];
    for me in [3u32, 19, 57] {
        let mut legacy_rng = Rng::new(0xBEEF ^ me as u64);
        let mut engine_rng = Rng::new(0xBEEF ^ me as u64);
        for step in 0..1000 {
            let legacy = legacy_next_message(
                &mut legacy_rng,
                me,
                &peers,
                cfg.bg_message_bytes,
                cfg.payload_bytes as u64,
            );
            let engine = next_message(
                &DstPlan::Uniform,
                TrafficPattern::Uniform,
                &mut engine_rng,
                me,
                &peers,
                cfg.bg_message_bytes,
                cfg.payload_bytes as u64,
            );
            assert_eq!(legacy, engine, "diverged at step {step} (me={me})");
        }
    }
    // same wake cadence at full load: exactly one wire serialization
    let wire = cfg.wire_bytes() as u64;
    assert_eq!(
        engine::pace(wire * cfg.link_ps_per_byte, 1.0),
        wire * cfg.link_ps_per_byte
    );
    // and the same flow-label encoding
    assert_eq!(engine::flow_id(5, 9), ((5u64) << 32) | 9);
}

#[test]
fn every_pattern_is_deterministic_from_its_seed() {
    let specs = [
        TrafficSpec::uniform(),
        TrafficSpec::permutation(),
        TrafficSpec::incast(4),
        TrafficSpec::hotspot(3, 0.9),
        TrafficSpec::empirical(),
        TrafficSpec::uniform().with_load(0.5),
        TrafficSpec::permutation().open().with_load(0.6),
    ];
    for spec in specs {
        let run = || {
            // fixed window (no early allreduce exit) so every pattern
            // generates a substantial, fully comparable event stream
            let mut exp = scenario(Some(spec)).build(42);
            exp.net.kick_jobs();
            exp.net.run_all(500 * US);
            let m = &exp.net.metrics;
            (
                exp.net.events_processed,
                m.pkts_delivered,
                m.flows.started,
                m.flows.completed,
                m.flows.fct_ps.clone(),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "non-deterministic run for {}", spec.name());
        assert!(
            a.2 > 0,
            "{}: background hosts generated no flows",
            spec.name()
        );
    }
}

/// Pull the installed traffic plan off every background host.
fn installed_plans(
    exp: &canary::workload::Experiment,
) -> Vec<(NodeId, DstPlan)> {
    let mut plans = Vec::new();
    for node in &exp.net.nodes {
        if let NodeBody::Host(h) = &node.body {
            if let canary::host::Proto::Background(th) = &h.proto {
                plans.push((node.id, th.plan.clone()));
            }
        }
    }
    plans
}

#[test]
fn permutation_installs_a_self_free_cycle() {
    let exp = scenario(Some(TrafficSpec::permutation())).build(7);
    let plans = installed_plans(&exp);
    assert!(plans.len() >= 2);
    let senders: Vec<NodeId> = plans.iter().map(|(h, _)| *h).collect();
    let mut dsts = Vec::new();
    for (h, p) in &plans {
        match p {
            DstPlan::Fixed(d) => {
                assert_ne!(d, h, "no self-loops");
                assert!(senders.contains(d), "partner is a bg host");
                dsts.push(*d);
            }
            other => panic!("expected Fixed plan, got {other:?}"),
        }
    }
    dsts.sort_unstable();
    let mut expect = senders.clone();
    expect.sort_unstable();
    assert_eq!(dsts, expect, "every bg host receives exactly one stream");
}

#[test]
fn incast_installs_sinks_and_aimed_senders() {
    let fan_in = 4u32;
    let exp = scenario(Some(TrafficSpec::incast(fan_in))).build(7);
    let plans = installed_plans(&exp);
    let sinks: Vec<NodeId> = plans
        .iter()
        .filter(|(_, p)| matches!(p, DstPlan::Sink))
        .map(|(h, _)| *h)
        .collect();
    assert!(!sinks.is_empty());
    let mut fan_counts = std::collections::BTreeMap::new();
    for (h, p) in &plans {
        if let DstPlan::Fixed(d) = p {
            assert!(sinks.contains(d), "sender {h} must target a sink");
            *fan_counts.entry(*d).or_insert(0u32) += 1;
        }
    }
    for (_, count) in fan_counts {
        assert!(count <= fan_in, "group larger than fan_in");
    }
}

#[test]
fn flow_accounting_is_consistent_end_to_end() {
    let mut exp = scenario(Some(TrafficSpec::uniform())).build(11);
    exp.net.kick_jobs();
    exp.net.run_all(500 * US);
    let f = &exp.net.metrics.flows;
    assert!(f.started > 0, "flows must start");
    assert!(f.completed > 0, "some flows must complete");
    assert!(f.completed <= f.started);
    assert_eq!(f.fct_ps.len() as u64, f.completed);
    assert_eq!(f.live_count() as u64 + f.completed, f.started);
    assert!(f.delivered_bytes <= f.offered_bytes);
    // each completed message is 64 KiB at line rate: its FCT is at
    // least the pure serialization time of the message
    let cfg = SimConfig::default();
    let min_fct = (cfg.bg_message_bytes / cfg.payload_bytes as u64)
        * cfg.wire_bytes() as u64
        * cfg.link_ps_per_byte;
    let p50 = f.fct_percentile_us(50.0);
    assert!(
        p50 >= canary::sim::ps_to_us(min_fct),
        "p50 {p50} us below serialization floor"
    );
    assert!(f.fct_percentile_us(99.0) >= p50);
}

#[test]
fn open_loop_empirical_draws_heavy_tailed_flows() {
    let mut exp = scenario(Some(TrafficSpec::empirical())).build(13);
    exp.net.kick_jobs();
    exp.net.run_all(2000 * US);
    let f = &exp.net.metrics.flows;
    assert!(f.started > 0, "Poisson arrivals must fire");
    assert!(f.completed > 0, "short flows must complete");
    // heavy tail: mean offered flow size far above the median flow size
    let mean_flow = f.offered_bytes as f64 / f.started as f64;
    assert!(
        mean_flow > 10_000.0,
        "mean offered flow {mean_flow:.0} B too small for the CDF"
    );
}

#[test]
fn lower_load_offers_fewer_bytes() {
    let run = |load: f64| {
        let spec = TrafficSpec::uniform().with_load(load);
        let mut exp = scenario(Some(spec)).build(17);
        exp.net.kick_jobs();
        exp.net.run_all(2000 * US);
        exp.net.metrics.flows.offered_bytes
    };
    let full = run(1.0);
    let third = run(0.3);
    assert!(
        (third as f64) < 0.6 * full as f64,
        "load 0.3 offered {third} B vs {full} B at line rate"
    );
}
