//! Rust-native ALU vs Pallas-kernel-via-PJRT bit parity, and runtime
//! round trips for the model artifacts. Requires `make artifacts`
//! (skips gracefully when artifacts/ is absent so `cargo test` works in
//! a fresh checkout).

use canary::runtime::{
    lit_f32, lit_i32, lit_i32_2d, lit_u32_scalar, to_f32, to_f32_scalar,
    to_i32, Runtime,
};
use canary::switch::alu;
use canary::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT tests: {e}");
            None
        }
    }
}

#[test]
fn aggregate_kernel_matches_native_alu() {
    let Some(rt) = runtime() else { return };
    let lanes = rt.manifest.packet_lanes;
    for w in [2usize, 4, 8, 16] {
        let exe = rt.compile(&format!("aggregate_w{w}")).unwrap();
        let mut rng = Rng::new(w as u64);
        // include saturation-edge values
        let mut payloads: Vec<i32> =
            (0..w * lanes).map(|_| rng.i32()).collect();
        payloads[0] = i32::MAX;
        payloads[lanes] = i32::MAX; // second row, lane 0 -> saturates
        let lit = lit_i32_2d(&payloads, w, lanes).unwrap();
        let out = exe.run(&[lit]).unwrap();
        let got = to_i32(&out[0]).unwrap();

        let rows: Vec<&[i32]> =
            (0..w).map(|i| &payloads[i * lanes..(i + 1) * lanes]).collect();
        let expected = alu::aggregate_rows(&rows, lanes);
        assert_eq!(got, expected, "aggregate_w{w} parity");
    }
}

#[test]
fn quantize_kernels_match_native() {
    let Some(rt) = runtime() else { return };
    let lanes = rt.manifest.packet_lanes;
    let q = rt.compile("quantize_block").unwrap();
    let dq = rt.compile("dequantize_block").unwrap();
    let mut rng = Rng::new(99);
    let xs: Vec<f32> = (0..lanes)
        .map(|i| match i % 5 {
            0 => (rng.f64() as f32 - 0.5) * 4.0,
            1 => (rng.f64() as f32) * 1e-6,
            2 => (rng.f64() as f32) * 5000.0,
            3 => -(rng.f64() as f32) * 5000.0,
            _ => 0.0,
        })
        .collect();
    let out = q.run(&[lit_f32(&xs)]).unwrap();
    let got_q = to_i32(&out[0]).unwrap();
    let expect_q = alu::quantize_vec(&xs, 20);
    assert_eq!(got_q, expect_q, "quantize parity");

    let out = dq.run(&[lit_i32(&got_q)]).unwrap();
    let got_dq = to_f32(&out[0]).unwrap();
    let expect_dq = alu::dequantize_vec(&got_q, 20);
    for (a, b) in got_dq.iter().zip(expect_dq.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "dequantize bit parity");
    }
}

#[test]
fn model_artifacts_roundtrip() {
    let Some(rt) = runtime() else { return };
    if !rt.manifest.models.contains_key("tiny") {
        eprintln!("skipping: tiny preset not lowered");
        return;
    }
    let model = rt.manifest.models["tiny"].clone();
    let init = rt.compile("tiny_init_params").unwrap();
    let step = rt.compile("tiny_train_step").unwrap();
    let apply = rt.compile("tiny_apply_update").unwrap();
    let eval = rt.compile("tiny_eval_loss").unwrap();

    let params = to_f32(&init.run(&[lit_u32_scalar(1)]).unwrap()[0]).unwrap();
    assert_eq!(params.len(), model.param_count);
    // deterministic init
    let params2 =
        to_f32(&init.run(&[lit_u32_scalar(1)]).unwrap()[0]).unwrap();
    assert_eq!(params, params2);

    let mut rng = Rng::new(3);
    let tokens: Vec<i32> = (0..model.batch * model.seq_len)
        .map(|_| rng.gen_range(model.vocab as u64) as i32)
        .collect();
    let tok = lit_i32_2d(&tokens, model.batch, model.seq_len).unwrap();
    let out = step.run(&[lit_f32(&params), tok]).unwrap();
    let loss = to_f32_scalar(&out[0]).unwrap();
    let qgrads = to_i32(&out[1]).unwrap();
    assert!(loss.is_finite());
    // initial loss near ln(vocab) for random tokens
    let ln_v = (model.vocab as f32).ln();
    assert!((loss - ln_v).abs() < 2.0, "loss {loss} vs ln(V) {ln_v}");
    assert!(qgrads.iter().any(|&g| g != 0), "gradient all-zero");

    // one SGD step must change the params and keep them finite
    let out = apply
        .run(&[
            lit_f32(&params),
            lit_i32(&qgrads),
            canary::runtime::lit_f32_scalar(0.1),
            canary::runtime::lit_f32_scalar(1.0),
        ])
        .unwrap();
    let new_params = to_f32(&out[0]).unwrap();
    assert_ne!(params, new_params);
    assert!(new_params.iter().all(|p| p.is_finite()));

    // eval_loss agrees with train_step's loss on the same batch
    let tok = lit_i32_2d(&tokens, model.batch, model.seq_len).unwrap();
    let out = eval.run(&[lit_f32(&params), tok]).unwrap();
    let eval_loss = to_f32_scalar(&out[0]).unwrap();
    assert!((eval_loss - loss).abs() < 1e-4);
}

#[test]
fn trainer_loss_decreases_tiny() {
    let Some(rt) = runtime() else { return };
    if !rt.manifest.models.contains_key("tiny") {
        return;
    }
    let cfg = canary::train::TrainConfig {
        preset: "tiny".into(),
        workers: 2,
        steps: 25,
        lr: 0.5,
        algo: canary::collectives::Algo::Canary,
        comm_every: 10,
        congestion: true,
        seed: 7,
    };
    let mut trainer = canary::train::Trainer::new(&rt, cfg).unwrap();
    let logs = trainer.train().unwrap();
    let first: f32 =
        logs[..5].iter().map(|l| l.mean_loss).sum::<f32>() / 5.0;
    let last: f32 = logs[logs.len() - 5..]
        .iter()
        .map(|l| l.mean_loss)
        .sum::<f32>()
        / 5.0;
    assert!(
        last < first - 0.1,
        "loss did not decrease: first {first:.3} last {last:.3}"
    );
    // the simulated allreduce produced real communication times
    assert!(logs.iter().any(|l| l.comm_ps.is_some()));
    assert!(logs
        .iter()
        .filter_map(|l| l.comm_ps)
        .all(|c| c > 0));
}
