//! Scheduler + arena guarantees for the calendar-queue rewrite
//! (DESIGN.md §2.5): the calendar queue pops in exactly the reference
//! `BinaryHeap` order on random event streams with duplicate
//! timestamps (property test), a recycled `PacketId` from a stale
//! generation is rejected, a clean run returns every packet to the
//! arena (no id leaks), and seeded end-to-end runs are bit-identical —
//! the same pin the CI `determinism` job holds from the outside via
//! `canary run --fingerprint`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use canary::collectives::Algo;
use canary::config::FatTreeConfig;
use canary::prop_assert;
use canary::sim::{Event, EventQueue, Packet, PacketArena, PacketKind, MS};
use canary::traffic::TrafficSpec;
use canary::transport::TransportSpec;
use canary::util::proptest_lite::check_property;
use canary::workload::{JobBuilder, ScenarioBuilder};

fn ev(tag: usize) -> Event {
    Event::TxDone { link: tag }
}

fn tag_of(e: &Event) -> usize {
    match e {
        Event::TxDone { link } => *link,
        other => panic!("unexpected event {other:?}"),
    }
}

/// Calendar-queue pops match a reference global heap ordered by
/// `(time, seq)` — on streams that hit all three tiers (current slot,
/// wheel window, overflow horizon), force duplicate timestamps, and
/// interleave pops with pushes (including pushes *behind* the popped
/// frontier, which the queue must order first).
#[test]
fn calendar_queue_matches_reference_heap() {
    check_property("scheduler equivalence", 0xCA1E, 150, |rng| {
        let mut q = EventQueue::new();
        let mut reference: BinaryHeap<Reverse<(u64, u64, usize)>> =
            BinaryHeap::new();
        let mut seq = 0u64;
        let mut next_tag = 0usize;
        let base = rng.next_u64() % (1u64 << 40);
        let n_ops = 200 + rng.index(800);
        for _ in 0..n_ops {
            if rng.chance(0.6) || q.is_empty() {
                let t = base
                    + match rng.index(4) {
                        // dense duplicates inside one wheel slot
                        0 => rng.next_u64() % 16,
                        // same-slot spread
                        1 => rng.next_u64() % (1 << 16),
                        // across the wheel window (~268 us)
                        2 => rng.next_u64() % (1 << 28),
                        // far beyond the horizon (up to ~100 ms)
                        _ => rng.next_u64() % (100 * MS),
                    };
                q.push(t, ev(next_tag));
                reference.push(Reverse((t, seq, next_tag)));
                seq += 1;
                next_tag += 1;
            } else {
                let got = q.pop();
                let want = reference.pop();
                match (got, want) {
                    (Some((t, e)), Some(Reverse((rt, _, rtag)))) => {
                        prop_assert!(
                            t == rt && tag_of(&e) == rtag,
                            "popped ({t}, {}), reference ({rt}, {rtag})",
                            tag_of(&e)
                        );
                    }
                    (None, None) => {}
                    (g, w) => {
                        return Err(format!(
                            "length divergence: got {g:?}, want {w:?}"
                        ))
                    }
                }
                prop_assert!(
                    q.len() == reference.len(),
                    "len {} != reference {}",
                    q.len(),
                    reference.len()
                );
            }
        }
        while let Some(Reverse((rt, _, rtag))) = reference.pop() {
            let (t, e) = q
                .pop()
                .ok_or_else(|| "queue drained before reference".to_string())?;
            prop_assert!(
                t == rt && tag_of(&e) == rtag,
                "drain popped ({t}, {}), reference ({rt}, {rtag})",
                tag_of(&e)
            );
        }
        prop_assert!(q.pop().is_none(), "queue outlived reference");
        prop_assert!(q.is_empty(), "is_empty disagrees after drain");
        Ok(())
    });
}

/// A recycled `PacketId` from a stale generation must be rejected by
/// every accessor — a handler that both forwards and frees an id can
/// never alias the unrelated packet now occupying the slot.
#[test]
fn recycled_packet_id_from_stale_generation_is_rejected() {
    let mut a = PacketArena::new();
    let stale = a.alloc(Packet::data(PacketKind::Background, 0, 1));
    assert_eq!(a.take(stale).dst, 1);
    // the freed slot is recycled for an unrelated packet
    let recycled = a.alloc(Packet::data(PacketKind::Ring, 2, 3));
    assert_eq!(a.slot_count(), 1, "second alloc must reuse the slot");
    assert!(a.get(stale).is_none(), "stale read leaked the new packet");
    assert!(a.get_mut(stale).is_none());
    assert!(a.try_take(stale).is_none());
    assert_eq!(a.get(recycled).map(|p| p.dst), Some(3));
}

/// Every delivered packet id is consumed exactly once: after a fully
/// drained run the arena holds zero live packets, and its slab never
/// grew past the peak number of simultaneously in-flight packets.
#[test]
fn clean_runs_return_every_packet_to_the_arena() {
    for algo in [Algo::Canary, Algo::StaticTree { n_trees: 1 }, Algo::Ring] {
        let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
            .job(JobBuilder::new(algo).hosts(6).data_bytes(64 * 1024));
        let mut exp = sc.build(3);
        exp.net.kick_jobs();
        exp.net.run_all(u64::MAX);
        assert!(exp.net.queue.is_empty(), "{algo:?}: events left behind");
        assert_eq!(
            exp.net.arena.live(),
            0,
            "{algo:?}: packet ids leaked (taken/forwarded/freed violated)"
        );
        assert!(exp.net.arena.peak_live() > 0, "{algo:?}: nothing flew");
        assert_eq!(
            exp.net.arena.slot_count() as u32,
            exp.net.arena.peak_live(),
            "{algo:?}: slab grew past the live peak (free list bypassed)"
        );
    }
}

fn fingerprint_of(sc: &ScenarioBuilder, seed: u64) -> u64 {
    let mut exp = sc.build(seed);
    canary::collectives::runner::run_to_completion(&mut exp.net, u64::MAX);
    exp.net
        .metrics
        .fingerprint(exp.net.now, exp.net.events_processed)
}

/// The scheduler+arena rewrite preserves bit-reproducibility: the same
/// seeded scenario produces the same fingerprint, run after run — with
/// plain uniform cross traffic and under the reactive-transport incast
/// (ECN marks, CNPs, RTO retransmissions all included in the digest).
#[test]
fn seeded_runs_are_bit_identical() {
    let plain = ScenarioBuilder::new(FatTreeConfig::small())
        .traffic(Some(TrafficSpec::uniform()))
        .job(JobBuilder::new(Algo::Canary).hosts(8).data_bytes(64 * 1024));
    assert_eq!(fingerprint_of(&plain, 42), fingerprint_of(&plain, 42));
    assert_ne!(
        fingerprint_of(&plain, 42),
        fingerprint_of(&plain, 43),
        "distinct seeds collapsed to one world"
    );

    let reactive = ScenarioBuilder::new(FatTreeConfig::small())
        .traffic(Some(
            TrafficSpec::incast(8).with_transport(TransportSpec::Dcqcn),
        ))
        .job(JobBuilder::new(Algo::Canary).hosts(8).data_bytes(64 * 1024));
    assert_eq!(fingerprint_of(&reactive, 7), fingerprint_of(&reactive, 7));
}
