//! Protocol-level behaviour: routing reachability, congestion response,
//! straggler/collision machinery, background traffic, fair queueing, and
//! the goodput relations the paper's evaluation depends on.

use canary::collectives::{runner, Algo};
use canary::config::FatTreeConfig;
use canary::loadbalance::LoadBalancer;
use canary::sim::US;
use canary::traffic::TrafficSpec;
use canary::util::proptest_lite::check_property;
use canary::util::rng::Rng;
use canary::workload::{JobBuilder, ScenarioBuilder};

fn scenario(
    algo: Algo,
    hosts: u32,
    congestion: bool,
    data_kib: u64,
) -> ScenarioBuilder {
    ScenarioBuilder::new(FatTreeConfig::small())
        .traffic(congestion.then(TrafficSpec::uniform))
        .job(JobBuilder::new(algo).hosts(hosts).data_bytes(data_kib * 1024))
}

#[test]
fn all_algorithms_complete_on_random_placements() {
    check_property("completion", 0xA0, 10, |rng: &mut Rng| {
        let algos = [
            Algo::Canary,
            Algo::Ring,
            Algo::StaticTree { n_trees: 1 },
            Algo::StaticTree { n_trees: 4 },
        ];
        let algo = *rng.choose(&algos);
        let hosts = 2 + rng.gen_range(20) as u32;
        let sc = scenario(algo, hosts, rng.chance(0.5), 1 + rng.gen_range(64));
        let mut exp = sc.build(rng.next_u64());
        let res = runner::run_to_completion(&mut exp.net, 500_000 * US);
        if res[0].runtime_ps.is_none() {
            return Err(format!("{algo:?} with {hosts} hosts timed out"));
        }
        Ok(())
    });
}

#[test]
fn in_network_beats_ring_without_congestion() {
    // the paper's headline 2x claim (Fig. 2, no congestion)
    let mut goodputs = std::collections::HashMap::new();
    for algo in [Algo::Ring, Algo::Canary, Algo::StaticTree { n_trees: 1 }] {
        let sc = scenario(algo, 32, false, 1024);
        let mut exp = sc.build(5);
        let res = runner::run_to_completion(&mut exp.net, 500_000 * US);
        goodputs.insert(algo.name(), res[0].goodput_gbps.unwrap());
    }
    let ring = goodputs["ring"];
    let canary = goodputs["canary"];
    let st1 = goodputs["static1"];
    assert!(
        canary > 1.5 * ring,
        "canary {canary:.1} vs ring {ring:.1}: expected ~2x"
    );
    assert!(
        st1 > 1.5 * ring,
        "static1 {st1:.1} vs ring {ring:.1}: expected ~2x"
    );
}

#[test]
fn canary_beats_static_tree_under_congestion() {
    // the paper's core result (Fig. 7a / Fig. 8)
    let seeds = [1u64, 2, 3];
    let mut canary_sum = 0.0;
    let mut st1_sum = 0.0;
    for &seed in &seeds {
        let sc = scenario(Algo::Canary, 32, true, 1024);
        let mut exp = sc.build(seed);
        canary_sum += runner::run_to_completion(&mut exp.net, 500_000 * US)
            [0]
        .goodput_gbps
        .unwrap();
        let sc = scenario(Algo::StaticTree { n_trees: 1 }, 32, true, 1024);
        let mut exp = sc.build(seed);
        st1_sum += runner::run_to_completion(&mut exp.net, 500_000 * US)[0]
            .goodput_gbps
            .unwrap();
    }
    assert!(
        canary_sum > st1_sum,
        "canary {canary_sum:.1} should beat static1 {st1_sum:.1} \
         under congestion"
    );
}

#[test]
fn congestion_hurts_static_tree_more_than_canary() {
    let run = |algo: Algo, cong: bool| -> f64 {
        let mut acc = 0.0;
        for seed in [1u64, 2] {
            let sc = scenario(algo, 32, cong, 1024);
            let mut exp = sc.build(seed);
            acc += runner::run_to_completion(&mut exp.net, 500_000 * US)
                [0]
            .goodput_gbps
            .unwrap();
        }
        acc / 2.0
    };
    let canary_drop =
        run(Algo::Canary, false) / run(Algo::Canary, true).max(1e-9);
    let st_drop = run(Algo::StaticTree { n_trees: 1 }, false)
        / run(Algo::StaticTree { n_trees: 1 }, true).max(1e-9);
    assert!(
        st_drop > canary_drop,
        "static tree should degrade more (st {st_drop:.2}x vs \
         canary {canary_drop:.2}x)"
    );
}

#[test]
fn straggler_count_scales_inversely_with_timeout() {
    // Cascaded equal timeouts always make later aggregation levels'
    // partials stragglers at the root (they arrive one timeout late),
    // so even long timeouts show a few; but shorter timeouts must show
    // *many* more (Section 3.1.1 / Fig. 11).
    let run = |timeout_ps: u64| -> u64 {
        let mut sc = scenario(Algo::Canary, 16, false, 256);
        sc.sim = sc.sim.with_timeout(timeout_ps);
        let mut exp = sc.build(9);
        runner::run_to_completion(&mut exp.net, 500_000 * US);
        exp.net.metrics.stragglers
    };
    let short = run(50_000); // 50 ns: everything straggles
    let normal = run(US); // paper default
    assert!(short > 0, "short timeout must produce stragglers");
    assert!(
        short > 4 * normal.max(1),
        "short {short} vs normal {normal}: expected far more stragglers"
    );
}

#[test]
fn background_traffic_saturates_and_drops() {
    // congestion generator alone: run for a fixed window and verify the
    // links carry traffic and overflow policing kicks in
    let sc = scenario(Algo::Canary, 2, true, 1);
    let mut exp = sc.build(31);
    exp.net.kick_jobs();
    exp.net.run_all(2000 * US);
    let m = &exp.net.metrics;
    assert!(m.pkts_delivered > 10_000, "bg delivered {}", m.pkts_delivered);
    assert!(m.drops_overflow > 0, "expected overflow drops");
}

#[test]
fn fair_queueing_splits_a_shared_link() {
    // one allreduce host pair + heavy background through the same leaf:
    // neither class may starve
    let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
        .traffic(Some(TrafficSpec::uniform()))
        .job(JobBuilder::new(Algo::Canary).hosts(4).data_bytes(512 * 1024));
    let mut exp = sc.build(17);
    let res = runner::run_to_completion(&mut exp.net, 500_000 * US);
    let g = res[0].goodput_gbps.unwrap();
    // must make progress but cannot hold the full line rate
    assert!(g > 10.0, "starved: {g:.1} Gbps");
}

#[test]
fn ecmp_is_worse_than_adaptive_under_congestion() {
    let run = |lb: LoadBalancer| -> f64 {
        let mut acc = 0.0;
        for seed in [11u64, 12, 13] {
            let mut sc = scenario(Algo::Canary, 32, true, 1024);
            sc.lb = lb.clone();
            let mut exp = sc.build(seed);
            acc += runner::run_to_completion(&mut exp.net, 500_000 * US)
                [0]
            .goodput_gbps
            .unwrap();
        }
        acc
    };
    let adaptive = run(LoadBalancer::DefaultAdaptive { threshold: 0.5 });
    let ecmp = run(LoadBalancer::Ecmp);
    // ECMP is congestion-oblivious; it should not win
    assert!(
        adaptive >= ecmp * 0.95,
        "adaptive {adaptive:.1} vs ecmp {ecmp:.1}"
    );
}

#[test]
fn derived_collectives_shapes() {
    use canary::collectives::derived;
    assert_eq!(derived::barrier_blocks(), 1);
    // a "reduce": leader pinned at the destination — every block same
    for b in 0..10 {
        assert_eq!(derived::reduce_leader_of(3, b), 3);
    }
}

#[test]
fn multicast_shard_tables_fit_paper_budget() {
    use canary::switch::shards;
    // 64-port switch, 4 shards: 256 Ki entries (Section 4.2)
    assert!(shards::table_entries(64, 4) <= 1 << 18);
}
