//! Churn & failure scenario engine (DESIGN.md §2.6): scheduled link
//! flaps, timed switch failure/recovery and straggler hosts installed
//! through `FaultSpec` — pinned for determinism and inertness, checked
//! end to end (Canary survives a mid-operation flap with exact values,
//! static trees and ring stall as documented), and property-tested for
//! packet-arena leaks under arbitrary finite fault timelines.

mod common;

use canary::collectives::{runner, Algo};
use canary::config::{FatTreeConfig, SimConfig};
use canary::faults::FaultSpec;
use canary::loadbalance::LoadBalancer;
use canary::sim::{Network, NodeBody, US};
use canary::topology::FatTree;
use canary::util::proptest_lite::check_property;
use canary::util::rng::Rng;
use canary::workload::{JobBuilder, ScenarioBuilder};
use common::{fingerprint_bounded, lossy_scenario, verify};

/// Total dead-port reroutes across every switch (the loadbalance
/// reconvergence counter — stays zero on a healthy fabric).
fn dead_reroutes(net: &Network) -> u64 {
    net.nodes
        .iter()
        .map(|n| match &n.body {
            NodeBody::Switch(sw) => sw.lb_state.dead_reroutes,
            NodeBody::Host(_) => 0,
        })
        .sum()
}

/// A timeline exercising every scheduled event type at once.
fn busy_spec() -> FaultSpec {
    let ft = FatTree { cfg: FatTreeConfig::tiny() };
    FaultSpec::default()
        .with_link_flap(0, 8, 5 * US, 40 * US)
        .with_straggler(3, 4)
        .with_switch_fail(ft.spine_id(1), 20 * US, Some(60 * US))
}

// ---------------------------------------------------------------- pins

/// Determinism: the same seed and the same fault timeline reproduce
/// the run bit for bit; a different seed lands in a different world.
#[test]
fn faulted_runs_are_deterministic_from_their_seed() {
    let sc = lossy_scenario(8, 64).faults(busy_spec());
    let bound = 5_000_000 * US;
    assert_eq!(
        fingerprint_bounded(&sc, 42, bound),
        fingerprint_bounded(&sc, 42, bound),
        "same seed + same FaultSpec diverged"
    );
    assert_ne!(
        fingerprint_bounded(&sc, 42, bound),
        fingerprint_bounded(&sc, 43, bound),
        "distinct seeds collapsed to one world"
    );
}

/// Inertness: an empty fault timeline (and a slowdown-1 "straggler")
/// is bit-identical to the fault-free build, and no fault counter
/// moves — the engine is provably free for every recorded series.
#[test]
fn empty_fault_timeline_is_bit_identical_to_fault_free() {
    let bound = 2_000_000 * US;
    let plain = fingerprint_bounded(&lossy_scenario(6, 8), 42, bound);
    let empty = fingerprint_bounded(
        &lossy_scenario(6, 8).faults(FaultSpec::default()),
        42,
        bound,
    );
    let unit_straggler = fingerprint_bounded(
        &lossy_scenario(6, 8)
            .faults(FaultSpec::default().with_straggler(2, 1)),
        42,
        bound,
    );
    assert_eq!(plain, empty, "an empty FaultSpec perturbed the run");
    assert_eq!(plain, unit_straggler, "slowdown 1 perturbed the run");

    let mut exp =
        lossy_scenario(6, 8).faults(FaultSpec::default()).build(42);
    runner::run_to_completion(&mut exp.net, bound);
    let m = &exp.net.metrics;
    assert_eq!(
        (
            m.link_flaps,
            m.link_recoveries,
            m.switch_failures,
            m.switch_recoveries,
            m.straggler_slowdowns,
            m.drops_link_down,
            m.drops_injected,
            m.partial_aggregates,
            m.jobs_stalled,
        ),
        (0, 0, 0, 0, 0, 0, 0, 0, 0),
        "fault counters moved on an empty timeline"
    );
    assert!(
        !canary::report::fault_activity(m),
        "clean run reported fault activity"
    );
    assert_eq!(m.jobs_completed, 1);
    assert_eq!(dead_reroutes(&exp.net), 0, "healthy fabric rerouted");
}

// ------------------------------------------------------- end to end

/// Canary completes a value-verified allreduce across a mid-operation
/// flap of a host access link (the host is fully cut for 35 us; the
/// leader protocol recovers every lost block once the link returns).
#[test]
fn canary_survives_mid_operation_access_link_flap() {
    let sc = lossy_scenario(8, 64)
        .faults(FaultSpec::default().with_link_flap(0, 8, 5 * US, 40 * US));
    let mut exp = sc.build(31);
    let res = runner::run_to_completion(&mut exp.net, 5_000_000 * US);
    assert!(res[0].completed, "canary did not recover from the flap");
    verify(&exp).unwrap();
    let m = &exp.net.metrics;
    assert_eq!((m.link_flaps, m.link_recoveries), (1, 1));
    assert!(m.drops_link_down > 0, "the flap window hit no traffic");
    assert_eq!((m.jobs_completed, m.jobs_stalled), (1, 0));
}

/// Same on the 3-tier fabric, flapping a leaf->agg uplink: the leaf
/// still has a second parent, so the fabric stays connected throughout.
#[test]
fn canary_survives_leaf_uplink_flap_on_tiny3() {
    let ft = FatTree { cfg: FatTreeConfig::tiny3() };
    let leaf = ft.switch_id(1, 0);
    let parent = ft.switch_id(2, ft.parent_index(1, 0, 0));
    let sc = ScenarioBuilder::new(FatTreeConfig::tiny3())
        .sim(
            SimConfig::default()
                .with_values(true)
                .with_retrans(200 * US, true),
        )
        .faults(
            FaultSpec::default().with_link_flap(leaf, parent, 5 * US, 40 * US),
        )
        .job(
            JobBuilder::new(Algo::Canary)
                .hosts(8)
                .data_bytes(64 * 1024)
                .record_results(true),
        );
    let mut exp = sc.build(17);
    let res = runner::run_to_completion(&mut exp.net, 5_000_000 * US);
    assert!(res[0].completed, "canary did not recover on tiny3");
    verify(&exp).unwrap();
    assert_eq!(exp.net.metrics.link_flaps, 1);
    assert_eq!(exp.net.metrics.link_recoveries, 1);
}

/// The documented degradation contrast (DESIGN.md §2.6): under the
/// exact flap Canary survives above, engines without recovery
/// machinery lose in-flight packets and stall — the run ends inside
/// the time bound with the job unfinished and counted as stalled.
#[test]
fn static_tree_and_ring_stall_under_the_same_flap() {
    for algo in [Algo::StaticTree { n_trees: 1 }, Algo::Ring] {
        let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
            .faults(
                FaultSpec::default().with_link_flap(0, 8, 5 * US, 40 * US),
            )
            .job(JobBuilder::new(algo).hosts(8).data_bytes(64 * 1024));
        let mut exp = sc.build(9);
        let res = runner::run_to_completion(&mut exp.net, 10_000 * US);
        assert!(!res[0].completed, "{algo:?} has no recovery, yet finished");
        assert!(res[0].runtime_ps.is_none(), "{algo:?} reported a runtime");
        let m = &exp.net.metrics;
        assert!(m.drops_link_down > 0, "{algo:?}: flap hit no traffic");
        assert_eq!(
            (m.jobs_completed, m.jobs_stalled),
            (0, 1),
            "{algo:?}: completion split wrong"
        );
    }
}

/// Routing reconvergence: with a leaf->spine uplink down for the whole
/// run, up-hop selection must re-route around the dead port (the
/// port-down bit) — and Canary's recovery machinery patches the
/// down-direction losses the local bit cannot see, so the job still
/// completes with exact values.
#[test]
fn load_balancer_reroutes_around_a_downed_uplink() {
    let ft = FatTree { cfg: FatTreeConfig::tiny() };
    let spine = ft.spine_id(0);
    let sc = lossy_scenario(8, 64)
        .lb(LoadBalancer::Ecmp)
        .faults(
            FaultSpec::default().with_link_flap(8, spine, 1, 1_000_000 * US),
        );
    let mut exp = sc.build(13);
    let res = runner::run_to_completion(&mut exp.net, 5_000_000 * US);
    assert!(res[0].completed, "canary did not route around the dead spine");
    verify(&exp).unwrap();
    assert!(
        dead_reroutes(&exp.net) > 0,
        "no up-hop ever re-picked around the dead port"
    );
}

// ------------------------------------------------- timeout sensitivity

/// Shrinking the Canary timeout under a straggler host monotonically
/// increases partial-aggregate emissions (non-strict): each smaller
/// timeout fires at least as often before the slow host's
/// contributions arrive. Values stay exact throughout — partials are
/// patched by the leader protocol.
#[test]
fn shrinking_timeout_increases_partials_under_a_straggler() {
    let timeouts = [256 * US, 16 * US, US];
    let mut partials = Vec::new();
    for &t in &timeouts {
        let mut sc = lossy_scenario(8, 4)
            .faults(FaultSpec::default().with_straggler(3, 16));
        sc.sim.canary_timeout_ps = t;
        let mut exp = sc.build(77);
        let res = runner::run_to_completion(&mut exp.net, 5_000_000 * US);
        assert!(res[0].completed, "timeout {t} ps: run did not complete");
        verify(&exp).unwrap();
        assert_eq!(exp.net.metrics.straggler_slowdowns, 1);
        partials.push(exp.net.metrics.partial_aggregates);
    }
    assert!(
        partials.windows(2).all(|w| w[0] <= w[1]),
        "partials must be non-decreasing as the timeout shrinks \
         (timeouts {timeouts:?} -> partials {partials:?})"
    );
    assert!(
        partials[timeouts.len() - 1] > 0,
        "the aggressive timeout never fired on a 16x straggler"
    );
}

/// An oversized timeout must never deadlock: the aggregation simply
/// waits the straggler out and completes inside the simulated-time
/// bound without a single partial emission.
#[test]
fn oversized_timeout_waits_out_the_straggler_without_deadlock() {
    let mut sc = lossy_scenario(8, 4)
        .faults(FaultSpec::default().with_straggler(5, 8));
    sc.sim.canary_timeout_ps = 100_000 * US;
    let mut exp = sc.build(99);
    let res = runner::run_to_completion(&mut exp.net, 1_000_000 * US);
    assert!(
        res[0].completed && res[0].runtime_ps.is_some(),
        "oversized timeout deadlocked the aggregation"
    );
    verify(&exp).unwrap();
    assert_eq!(
        exp.net.metrics.partial_aggregates,
        0,
        "a timeout far beyond the runtime still fired"
    );
}

// ------------------------------------------------------ leak property

/// Any random finite fault timeline — flaps on access links, a timed
/// spine failure with recovery, a straggler — drains cleanly for every
/// engine: no event left behind, every packet returned to the arena,
/// and the arena slab never grew past its live peak (the scheduler
/// suite's zero-leak bar, now under churn). This is what pins the
/// take-down path's drop-vs-flush accounting.
#[test]
fn random_fault_timelines_never_leak_arena_packets() {
    check_property("churn-drain", 0xC4, 6, |rng: &mut Rng| {
        let ft = FatTree { cfg: FatTreeConfig::tiny() };
        let mut spec = FaultSpec::default();
        for _ in 0..(1 + rng.gen_range(3)) {
            let h = rng.gen_range(8) as u32;
            let leaf = ft.switch_id(1, ft.leaf_of_host(h));
            let down = (1 + rng.gen_range(50)) * US;
            let up = down + (1 + rng.gen_range(100)) * US;
            spec = spec.with_link_flap(h, leaf, down, up);
        }
        if rng.chance(0.5) {
            let host = rng.gen_range(8) as u32;
            let factor = 1 + rng.gen_range(4) as u32;
            spec = spec.with_straggler(host, factor);
        }
        if rng.chance(0.5) {
            let at = (1 + rng.gen_range(30)) * US;
            spec = spec.with_switch_fail(
                ft.spine_id(rng.gen_range(2) as u32),
                at,
                Some(at + 50 * US),
            );
        }
        for algo in [Algo::Canary, Algo::StaticTree { n_trees: 1 }, Algo::Ring] {
            let mut sc = ScenarioBuilder::new(FatTreeConfig::tiny())
                .faults(spec.clone())
                .job(JobBuilder::new(algo).hosts(6).data_bytes(32 * 1024));
            if algo == Algo::Canary {
                // arm recovery so the canary run converges rather than
                // re-arming retransmission timers forever
                sc = sc.sim(SimConfig::default().with_retrans(200 * US, true));
            }
            let mut exp = sc.build(rng.next_u64());
            exp.net.kick_jobs();
            exp.net.run_all(u64::MAX);
            if !exp.net.queue.is_empty() {
                return Err(format!("{algo:?}: events left behind"));
            }
            if exp.net.arena.live() != 0 {
                return Err(format!(
                    "{algo:?}: {} packet ids leaked under {spec:?}",
                    exp.net.arena.live()
                ));
            }
            if exp.net.arena.peak_live() == 0 {
                return Err(format!("{algo:?}: nothing flew"));
            }
            if exp.net.arena.slot_count() as u32 != exp.net.arena.peak_live() {
                return Err(format!(
                    "{algo:?}: slab grew past the live peak"
                ));
            }
        }
        Ok(())
    });
}
