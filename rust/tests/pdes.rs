//! Sharded bounded-window PDES engine (DESIGN.md §2.10): shard-count
//! invariance pins and properties. The contract under test:
//!
//! * `shards = 1` is **bit-identical** (metrics fingerprint) to the
//!   serial engine (`shards = 0`) on every scenario — clean, faulted,
//!   cross-traffic, multi-tier;
//! * the fingerprint is **invariant in the shard count**: any fixed
//!   `N` reproduces the serial world, and the same `(seed, N)` is
//!   deterministic run to run;
//! * cross-shard packet migration never leaks arena slots and never
//!   strands events (the scheduler suite's zero-leak bar, space-
//!   parallel edition). Causality itself — no handoff ever delivered
//!   into a shard's past — is a debug assertion on the barrier path,
//!   armed in every run below, plus the grid property tests in
//!   `sim/shard.rs`.

mod common;

use canary::collectives::{runner, Algo};
use canary::config::{FatTreeConfig, SimConfig};
use canary::faults::FaultSpec;
use canary::sim::US;
use canary::topology::FatTree;
use canary::traffic::TrafficSpec;
use canary::util::proptest_lite::check_property;
use canary::util::rng::Rng;
use canary::workload::{JobBuilder, ScenarioBuilder};
use common::{fingerprint_bounded, lossy_scenario, verify};

/// Rebuildable scenario table: clean, churny (flap + straggler +
/// timed spine failure), and cross-traffic worlds, each a fresh
/// builder per call so every engine variant starts identical.
fn scenario(kind: &str) -> ScenarioBuilder {
    match kind {
        "clean" => lossy_scenario(8, 64),
        "churny" => {
            let ft = FatTree { cfg: FatTreeConfig::tiny() };
            lossy_scenario(8, 64).faults(
                FaultSpec::default()
                    .with_link_flap(0, 8, 5 * US, 40 * US)
                    .with_straggler(3, 4)
                    .with_switch_fail(ft.spine_id(1), 20 * US, Some(60 * US)),
            )
        }
        "traffic" => ScenarioBuilder::new(FatTreeConfig::tiny())
            .sim(SimConfig::default().with_values(true))
            .traffic(Some(TrafficSpec::uniform()))
            .job(
                JobBuilder::new(Algo::Canary)
                    .hosts(6)
                    .data_bytes(64 * 1024)
                    .record_results(true),
            ),
        "tier3" => ScenarioBuilder::new(FatTreeConfig::small3())
            .sim(SimConfig::default().with_values(true))
            .job(
                JobBuilder::new(Algo::Canary)
                    .hosts(16)
                    .data_bytes(32 * 1024)
                    .record_results(true),
            ),
        other => panic!("unknown scenario '{other}'"),
    }
}

/// Fingerprint of `kind` under a given shard count (0 = serial).
fn fp(kind: &str, shards: u32, seed: u64) -> u64 {
    let mut sc = scenario(kind);
    sc.sim.shards = shards;
    fingerprint_bounded(&sc, seed, 5_000_000 * US)
}

// ------------------------------------------------------ invariance pins

/// `--shards 1` runs the full split/barrier/merge machinery with one
/// worker and must land on the serial engine's exact fingerprint, on
/// every scenario kind.
#[test]
fn one_shard_is_bit_identical_to_serial() {
    for kind in ["clean", "churny", "traffic", "tier3"] {
        assert_eq!(
            fp(kind, 0, 42),
            fp(kind, 1, 42),
            "{kind}: shards=1 diverged from the serial engine"
        );
    }
}

/// The shard count is not allowed to be observable: 2 and 4 shards
/// reproduce the serial fingerprint bit for bit (the conservative
/// window protocol never reorders anything).
#[test]
fn shard_count_is_not_observable_in_the_fingerprint() {
    for kind in ["clean", "churny", "traffic", "tier3"] {
        let serial = fp(kind, 0, 42);
        for shards in [2, 4] {
            assert_eq!(
                serial,
                fp(kind, shards, 42),
                "{kind}: shards={shards} diverged from serial"
            );
        }
    }
}

/// Fixed (seed, shard count) is deterministic run to run, and the
/// seed still matters (the worlds are distinct, not degenerate).
#[test]
fn sharded_runs_are_deterministic_from_their_seed() {
    assert_eq!(
        fp("churny", 4, 42),
        fp("churny", 4, 42),
        "same seed + same shard count diverged"
    );
    assert_ne!(
        fp("churny", 4, 42),
        fp("churny", 4, 43),
        "distinct seeds collapsed to one world"
    );
}

// --------------------------------------------------------- end to end

/// A sharded 3-tier run completes with exact allreduce values — the
/// merge path reassembles per-host results, not just counters.
#[test]
fn sharded_allreduce_produces_exact_values() {
    for shards in [1, 3, 4] {
        let mut sc = scenario("tier3");
        sc.sim.shards = shards;
        let mut exp = sc.build(7);
        let res = runner::run_to_completion(&mut exp.net, u64::MAX);
        assert!(res[0].completed, "shards={shards}: job did not complete");
        verify(&exp).unwrap_or_else(|e| {
            panic!("shards={shards}: values wrong: {e}")
        });
    }
}

/// Canary survives a mid-operation access-link flap under the sharded
/// engine exactly as it does serially — recovery machinery (retrans
/// timers, restore traffic) works across the shard boundary.
#[test]
fn sharded_canary_survives_a_flap_with_recovery() {
    let mut sc = scenario("churny");
    sc.sim.shards = 4;
    let mut exp = sc.build(31);
    let res = runner::run_to_completion(&mut exp.net, 5_000_000 * US);
    assert!(res[0].completed, "sharded canary did not recover");
    verify(&exp).unwrap();
    let m = &exp.net.metrics;
    assert_eq!((m.link_flaps, m.link_recoveries), (1, 1));
    assert_eq!((m.switch_failures, m.switch_recoveries), (1, 1));
}

// ------------------------------------------------------ leak property

/// Random scenarios (hosts, payload, faults, shard count): the
/// sharded engine matches the serial fingerprint, drains every event,
/// and returns every packet to the arena — including packets that
/// migrated across shards mid-flight.
#[test]
fn random_scenarios_shard_invariant_and_leak_free() {
    check_property("pdes-invariance", 0x5A4D, 6, |rng: &mut Rng| {
        let hosts = 4 + rng.gen_range(5) as u32; // 4..=8
        let kib = 8 << rng.gen_range(3); // 8/16/32 KiB
        let shards = 2 + rng.gen_range(3) as u32; // 2..=4
        let seed = rng.next_u64();
        let mut spec = FaultSpec::default();
        if rng.chance(0.5) {
            let down = (1 + rng.gen_range(30)) * US;
            spec = spec.with_link_flap(0, 8, down, down + 35 * US);
        }
        if rng.chance(0.3) {
            spec = spec.with_straggler(rng.gen_range(hosts as u64) as u32, 3);
        }
        // both engines are driven identically: kick, then drain every
        // event (run_all) so the leak check sees the final world
        let drained_fp = |n_shards: u32| {
            let mut sc = lossy_scenario(hosts, kib).faults(spec.clone());
            sc.sim.shards = n_shards;
            let mut exp = sc.build(seed);
            exp.net.kick_jobs();
            exp.net.run_all(u64::MAX);
            let f = exp
                .net
                .metrics
                .fingerprint(exp.net.now, exp.net.events_processed);
            (f, exp)
        };
        let (serial, _) = drained_fp(0);
        let (sharded, exp) = drained_fp(shards);
        if serial != sharded {
            return Err(format!(
                "shards={shards} diverged from serial under {spec:?}"
            ));
        }
        if exp.net.arena.live() != 0 {
            return Err(format!(
                "{} packet ids leaked across the shard boundary",
                exp.net.arena.live()
            ));
        }
        if !exp.net.queue.is_empty() {
            return Err("events left behind after the merge".into());
        }
        Ok(())
    });
}
