//! Shared scenario setup for the integration-test crates.
//!
//! Each crate pulls these in with `mod common;`. The helpers were
//! extracted from `tests/fault_tolerance.rs` / `tests/transport.rs`
//! where they had been copy-pasted; `tests/churn.rs` reuses them for
//! the fault-injection harness. Any single crate uses a subset, hence
//! the dead_code allow.
#![allow(dead_code)]

use canary::collectives::{runner, verify_job, Algo};
use canary::config::{FatTreeConfig, SimConfig};
use canary::sim::{Time, US};
use canary::traffic::TrafficSpec;
use canary::transport::TransportSpec;
use canary::workload::{Experiment, JobBuilder, ScenarioBuilder};

/// Canary allreduce on the tiny fabric with value recording and a
/// short loss-recovery timer — the base scenario of the fault and
/// churn suites (loss/flap/failure specs are layered on per test).
pub fn lossy_scenario(hosts: u32, kib: u64) -> ScenarioBuilder {
    ScenarioBuilder::new(FatTreeConfig::tiny())
        .sim(
            SimConfig::default()
                .with_values(true)
                // short loss-recovery timer so tests converge quickly
                .with_retrans(200 * US, true),
        )
        .job(
            JobBuilder::new(Algo::Canary)
                .hosts(hosts)
                .data_bytes(kib * 1024)
                .record_results(true),
        )
}

/// The recorded fig2-style congestion cell at test scale: a Canary
/// allreduce on the 64-host fabric under the paper's uniform line-rate
/// cross traffic (the same scenario `tests/traffic_engine.rs` pins
/// against the inlined legacy generator).
pub fn figure_scenario(sim: SimConfig) -> ScenarioBuilder {
    ScenarioBuilder::new(FatTreeConfig::small())
        .sim(sim)
        .traffic(Some(TrafficSpec::uniform()))
        .job(JobBuilder::new(Algo::Canary).hosts(8).data_bytes(64 * 1024))
}

/// Tiny-fabric incast overload: 2 hosts run the allreduce, the other
/// 6 form one 5-into-1 incast group at line rate — the sink's downlink
/// is 5x oversubscribed, so the class-1 policer must drop.
pub fn incast_scenario(tp: TransportSpec) -> ScenarioBuilder {
    ScenarioBuilder::new(FatTreeConfig::tiny())
        .traffic(Some(TrafficSpec::incast(5).with_transport(tp)))
        .job(JobBuilder::new(Algo::Canary).hosts(2).data_bytes(64 * 1024))
}

/// Check the experiment's job produced exact allreduce values.
pub fn verify(exp: &Experiment) -> Result<(), String> {
    verify_job(&exp.net.jobs[exp.job as usize])
}

/// Run a scenario to completion and digest everything the outcome
/// hangs on into one u64 (same shape `tests/scheduler.rs` pins on).
pub fn fingerprint_of(sc: &ScenarioBuilder, seed: u64) -> u64 {
    fingerprint_bounded(sc, seed, u64::MAX)
}

/// [`fingerprint_of`] with an explicit simulated-time bound, for runs
/// that may legitimately stall (faulted scenarios).
pub fn fingerprint_bounded(
    sc: &ScenarioBuilder,
    seed: u64,
    max_time: Time,
) -> u64 {
    let mut exp = sc.build(seed);
    runner::run_to_completion(&mut exp.net, max_time);
    exp.net
        .metrics
        .fingerprint(exp.net.now, exp.net.events_processed)
}
