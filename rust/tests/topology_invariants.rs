//! Topology invariants (DESIGN.md §4), checked with the
//! `proptest_lite` randomized-property harness over both 2-tier and
//! 3-tier Clos builds at several oversubscription ratios:
//!
//! - id arithmetic round-trips (tier/index <-> node id, contiguous
//!   tier bases, node counts);
//! - link symmetry (every directed link has its exact reverse, landing
//!   on the matching port);
//! - per-node port counts match the configured radixes;
//! - up/down reachability: any host reaches any host (valley-free:
//!   tiers rise then fall) and any switch (the restoration path) under
//!   arbitrary adaptive up-port choices.

use canary::config::{ClosConfig, SimConfig};
use canary::loadbalance::LoadBalancer;
use canary::sim::{Network, NodeBody, NodeId};
use canary::topology::{build, Clos, Hop};
use canary::util::proptest_lite::check_property;
use canary::util::rng::Rng;

/// Random small 2- or 3-tier shape at a random oversubscription.
fn random_cfg(rng: &mut Rng) -> ClosConfig {
    let oversubs = [(1u32, 1u32), (2, 1), (4, 1)];
    let &(num, den) = rng.choose(&oversubs);
    let cfg = if rng.chance(0.5) {
        ClosConfig::two_tier(
            2 + rng.gen_range(3) as u32, // leaves
            2 + rng.gen_range(7) as u32, // hosts per leaf
            2 + rng.gen_range(3) as u32, // spines
        )
    } else {
        ClosConfig::three_tier(
            2 + rng.gen_range(5) as u32, // hosts per ToR
            2 + rng.gen_range(3) as u32, // ToRs per pod
            2 + rng.gen_range(3) as u32, // pods
            2 + rng.gen_range(3) as u32, // aggs per pod
            1 + rng.gen_range(3) as u32, // cores per group
        )
    };
    let cfg = cfg.with_oversub(num, den);
    cfg.validate().expect("generated shape must be valid");
    cfg
}

fn build_cfg(cfg: ClosConfig) -> (Network, Clos) {
    build(cfg, SimConfig::default(), LoadBalancer::default())
}

/// Follow `hop()` from `src` to `dst`, resolving free up-hops with
/// `rng`. Returns the node path or an error if `dst` is not reached.
fn walk(
    net: &Network,
    ft: &Clos,
    rng: &mut Rng,
    src: NodeId,
    dst: NodeId,
) -> Result<Vec<NodeId>, String> {
    let mut at = src;
    let mut path = vec![src];
    let max_hops = 2 * ft.tiers() as usize + 2;
    for _ in 0..max_hops {
        if at == dst {
            return Ok(path);
        }
        let port = match ft.hop(at, dst) {
            Hop::Local => return Ok(path),
            Hop::Port(p) => p,
            Hop::Up { base, n, dflt } => {
                if dflt >= n {
                    return Err(format!(
                        "dflt {dflt} out of range {n} at node {at}"
                    ));
                }
                // adversarial LB: any of the n equivalent ports
                base + rng.gen_range(n as u64) as u16
            }
        };
        let node = &net.nodes[at as usize];
        let Some(&link) = node.ports.get(port as usize) else {
            return Err(format!("node {at} has no port {port}"));
        };
        at = net.links[link].to;
        path.push(at);
    }
    Err(format!("no route {src}->{dst} within {max_hops} hops: {path:?}"))
}

#[test]
fn ids_partition_and_round_trip() {
    check_property("topology-ids", 0x10, 25, |rng: &mut Rng| {
        let cfg = random_cfg(rng);
        let (net, ft) = build_cfg(cfg);
        let mut expect_id = cfg.n_hosts();
        for t in 1..=cfg.tiers {
            if ft.tier_base(t) != expect_id {
                return Err(format!("tier {t} base mismatch"));
            }
            for idx in 0..cfg.tier_size(t) {
                let id = ft.switch_id(t, idx);
                if id != expect_id {
                    return Err(format!("non-contiguous id at tier {t}"));
                }
                if ft.node_tier(id) != t || ft.switch_at(id) != (t, idx) {
                    return Err(format!("round-trip failed for node {id}"));
                }
                expect_id += 1;
            }
        }
        for h in 0..cfg.n_hosts() {
            if ft.node_tier(h) != 0 {
                return Err(format!("host {h} misclassified"));
            }
        }
        if net.nodes.len() as u32 != cfg.n_hosts() + cfg.n_switches() {
            return Err("node count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn links_are_symmetric() {
    check_property("link-symmetry", 0x11, 25, |rng: &mut Rng| {
        let cfg = random_cfg(rng);
        let (net, _) = build_cfg(cfg);
        for l in &net.links {
            let reverse_id = net.nodes[l.to as usize]
                .ports
                .get(l.to_port as usize)
                .copied()
                .ok_or_else(|| {
                    format!("{}->{}: no reverse port", l.from, l.to)
                })?;
            let r = &net.links[reverse_id];
            if r.to != l.from || r.to_port != l.from_port {
                return Err(format!(
                    "asymmetric link {}:{} -> {}:{} (reverse {}:{})",
                    l.from, l.from_port, l.to, l.to_port, r.to, r.to_port
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn port_counts_match_radixes() {
    check_property("port-counts", 0x12, 25, |rng: &mut Rng| {
        let cfg = random_cfg(rng);
        let (net, ft) = build_cfg(cfg);
        for n in &net.nodes {
            let want = match &n.body {
                NodeBody::Host(_) => 1,
                NodeBody::Switch(_) => {
                    let (t, _) = ft.switch_at(n.id);
                    let down = cfg.down[t as usize - 1];
                    let up = if t == cfg.tiers {
                        0
                    } else {
                        cfg.up[t as usize]
                    };
                    (down + up) as usize
                }
            };
            if n.ports.len() != want {
                return Err(format!(
                    "node {} has {} ports, want {want}",
                    n.id,
                    n.ports.len()
                ));
            }
        }
        // directed links: one per port plus one uplink per host
        let total: usize =
            net.nodes.iter().map(|n| n.ports.len()).sum();
        if net.links.len() != total {
            return Err("dangling links".into());
        }
        Ok(())
    });
}

#[test]
fn any_host_reaches_any_host_valley_free() {
    check_property("host-reachability", 0x13, 25, |rng: &mut Rng| {
        let cfg = random_cfg(rng);
        let (net, ft) = build_cfg(cfg);
        let h = cfg.n_hosts() as u64;
        for _ in 0..30 {
            let src = rng.gen_range(h) as NodeId;
            let dst = rng.gen_range(h) as NodeId;
            let path = walk(&net, &ft, rng, src, dst)?;
            if src == dst {
                continue;
            }
            // valley-free: tier sequence strictly rises, then falls
            let tiers: Vec<u8> =
                path.iter().map(|&n| ft.node_tier(n)).collect();
            let peak = tiers.iter().position(|&t| {
                t == *tiers.iter().max().unwrap()
            });
            let peak = peak.unwrap();
            let up_ok = tiers[..=peak].windows(2).all(|w| w[1] == w[0] + 1);
            let down_ok =
                tiers[peak..].windows(2).all(|w| w[1] + 1 == w[0]);
            if !up_ok || !down_ok {
                return Err(format!(
                    "path {src}->{dst} is not valley-free: {tiers:?}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn any_host_reaches_any_switch() {
    // the Canary restoration path: leaders address packets to arbitrary
    // collided switches anywhere in the fabric
    check_property("switch-reachability", 0x14, 25, |rng: &mut Rng| {
        let cfg = random_cfg(rng);
        let (net, ft) = build_cfg(cfg);
        for _ in 0..30 {
            let src = rng.gen_range(cfg.n_hosts() as u64) as NodeId;
            let dst = cfg.n_hosts()
                + rng.gen_range(cfg.n_switches() as u64) as NodeId;
            walk(&net, &ft, rng, src, dst)?;
        }
        Ok(())
    });
}

#[test]
fn two_tier_layout_is_frozen() {
    // the legacy fixed layout of the paper network is a wire contract:
    // hosts [0,H), leaves [H,H+L), spines [H+L,H+L+S), leaf ports hosts
    // first then one up-port per spine, spine port l down to leaf l
    let cfg = ClosConfig::paper();
    let (net, ft) = build_cfg(cfg);
    assert_eq!(ft.leaf_id(0), 1024);
    assert_eq!(ft.spine_id(0), 1024 + 32);
    assert_eq!(ft.leaf_of_host(1023), 31);
    assert_eq!(ft.leaf_host_port(33), 1);
    assert_eq!(ft.leaf_up_port(5), 37);
    assert_eq!(ft.spine_down_port(7), 7);
    // leaf 3's up-port to spine 2 lands on spine 2's in-port 3
    let link = net.nodes[ft.leaf_id(3) as usize].ports
        [ft.leaf_up_port(2) as usize];
    let l = &net.links[link];
    assert_eq!(l.to, ft.spine_id(2));
    assert_eq!(l.to_port, ft.spine_down_port(3));
}

#[test]
fn oversubscription_shapes_the_uplinks() {
    for &(num, den, up1, up2) in
        &[(1u32, 1u32, 16u32, 8u32), (2, 1, 8, 4), (4, 1, 4, 2)]
    {
        let cfg = ClosConfig::paper3().with_oversub(num, den);
        assert_eq!(cfg.up[1], up1, "{num}:{den} ToR uplinks");
        assert_eq!(cfg.up[2], up2, "{num}:{den} agg uplinks");
        let (net, ft) = build_cfg(cfg);
        // every ToR really has down * den / num up-ports
        let tor = &net.nodes[ft.leaf_id(0) as usize];
        assert_eq!(tor.ports.len() as u32, cfg.down[0] + up1);
    }
}
