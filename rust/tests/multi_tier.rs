//! End-to-end behaviour on 3-tier oversubscribed Clos fabrics
//! (DESIGN.md §4): every algorithm completes, values stay exact under
//! dynamic trees / collisions / congestion across three switch tiers,
//! and the clos3 experiment's Canary-vs-static comparison runs at every
//! oversubscription ratio.

use canary::collectives::{runner, verify_job, Algo, Collective};
use canary::config::{ClosConfig, SimConfig};
use canary::sim::US;
use canary::traffic::TrafficSpec;
use canary::util::proptest_lite::check_property;
use canary::util::rng::Rng;
use canary::workload::{JobBuilder, ScenarioBuilder};

fn scenario3(
    topo: ClosConfig,
    algo: Algo,
    hosts: u32,
    congestion: bool,
    data_kib: u64,
    values: bool,
) -> ScenarioBuilder {
    ScenarioBuilder::new(topo)
        .sim(SimConfig::default().with_values(values))
        .traffic(congestion.then(TrafficSpec::uniform))
        .job(
            JobBuilder::new(algo)
                .hosts(hosts)
                .data_bytes(data_kib * 1024)
                .record_results(values),
        )
}

fn verify_values(exp: &canary::workload::Experiment) -> Result<(), String> {
    verify_job(&exp.net.jobs[exp.job as usize])
}

#[test]
fn all_algorithms_complete_on_three_tiers() {
    check_property("clos3-completion", 0x30, 10, |rng: &mut Rng| {
        let algos = [
            Algo::Canary,
            Algo::Ring,
            Algo::StaticTree { n_trees: 1 },
            Algo::StaticTree { n_trees: 4 },
        ];
        let algo = *rng.choose(&algos);
        let oversubs = [(1u32, 1u32), (2, 1), (4, 1)];
        let &(num, den) = rng.choose(&oversubs);
        let topo = ClosConfig::small3().with_oversub(num, den);
        let hosts = 2 + rng.gen_range(20) as u32;
        let sc = scenario3(
            topo,
            algo,
            hosts,
            rng.chance(0.5),
            1 + rng.gen_range(32),
            false,
        );
        let mut exp = sc.build(rng.next_u64());
        let res = runner::run_to_completion(&mut exp.net, 500_000 * US);
        if res[0].runtime_ps.is_none() {
            return Err(format!(
                "{algo:?} with {hosts} hosts timed out at {num}:{den}"
            ));
        }
        Ok(())
    });
}

#[test]
fn canary_values_exact_across_three_tiers() {
    check_property("clos3-canary-values", 0x31, 6, |rng: &mut Rng| {
        let hosts = 3 + rng.gen_range(12) as u32;
        let sc = scenario3(
            ClosConfig::small3(),
            Algo::Canary,
            hosts,
            true,
            1 + rng.gen_range(8),
            true,
        );
        let mut exp = sc.build(rng.next_u64());
        runner::run_to_completion(&mut exp.net, 500_000 * US);
        verify_values(&exp)
    });
}

#[test]
fn static_tree_values_exact_across_three_tiers() {
    // the 3-level static tree: ToR -> pod aggregation -> core root
    for n_trees in [1u8, 4] {
        let sc = scenario3(
            ClosConfig::small3(),
            Algo::StaticTree { n_trees },
            24,
            false,
            16,
            true,
        );
        let mut exp = sc.build(11);
        runner::run_to_completion(&mut exp.net, 500_000 * US);
        verify_values(&exp).unwrap();
    }
}

#[test]
fn derived_collectives_run_across_three_tiers() {
    // reduce/broadcast/barrier on the tiny3 fabric, every engine: the
    // acceptance surface for the Collective API on multi-tier fabrics
    let collectives = [
        Collective::Reduce { root: 0 },
        Collective::Broadcast { root: 0 },
        Collective::Barrier,
    ];
    for c in collectives {
        for algo in [
            Algo::Canary,
            Algo::StaticTree { n_trees: 1 },
            Algo::Ring,
        ] {
            let sc = ScenarioBuilder::new(ClosConfig::tiny3())
                .sim(SimConfig::default().with_values(true))
                .job(
                    JobBuilder::new(algo)
                        .collective(c)
                        .hosts(6)
                        .data_bytes(8 * 1024)
                        .record_results(true),
                );
            let mut exp = sc.build(13);
            runner::run_to_completion(&mut exp.net, 500_000 * US);
            verify_values(&exp).unwrap_or_else(|e| {
                panic!("{} on {} (tiny3): {e}", c.name(), algo.name())
            });
        }
    }
}

#[test]
fn canary_restoration_works_across_tiers() {
    // a tiny descriptor table forces collisions, so leaders must send
    // restoration packets to switches at every tier (host -> switch
    // routing through the aligned climb)
    let mut sc = scenario3(
        ClosConfig::small3(),
        Algo::Canary,
        16,
        false,
        32,
        true,
    );
    sc.sim = sc.sim.with_slots(4);
    let mut exp = sc.build(5);
    runner::run_to_completion(&mut exp.net, 500_000 * US);
    assert!(
        exp.net.metrics.collisions > 0,
        "4-slot tables must collide"
    );
    verify_values(&exp).unwrap();
}

#[test]
fn oversubscribed_comparison_runs_end_to_end() {
    // the clos3 figure's core claim-check at CI scale: Canary and the
    // static trees both finish on a tapered fabric, under congestion
    for &(num, den) in &[(2u32, 1u32), (4, 1)] {
        let topo = ClosConfig::small3().with_oversub(num, den);
        let mut goodputs = Vec::new();
        for algo in [Algo::StaticTree { n_trees: 1 }, Algo::Canary] {
            let sc = scenario3(topo, algo, 32, true, 64, false);
            let mut exp = sc.build(9);
            let res =
                runner::run_to_completion(&mut exp.net, 2_000_000 * US);
            let g = res[0]
                .goodput_gbps
                .unwrap_or_else(|| panic!("{algo:?} timed out {num}:{den}"));
            assert!(g > 0.0);
            goodputs.push((algo.name(), g));
        }
        println!("oversub {num}:{den}: {goodputs:?}");
    }
}

#[test]
fn deeper_fabric_uses_more_switch_hops() {
    // same hosts, same job: a 3-tier reduce path must traverse more
    // aggregation stages than the 2-tier one (sanity that packets
    // really cross the core and are not short-circuited)
    let mut descriptor_allocs = Vec::new();
    for topo in [ClosConfig::small(), ClosConfig::small3()] {
        let sc = scenario3(topo, Algo::Canary, 16, false, 16, false);
        let mut exp = sc.build(3);
        runner::run_to_completion(&mut exp.net, 500_000 * US);
        assert!(exp.net.jobs[0].finish.is_some());
        descriptor_allocs.push(exp.net.metrics.descriptors_allocated);
    }
    assert!(
        descriptor_allocs[1] > descriptor_allocs[0],
        "3-tier paths must allocate descriptors at more stages: {descriptor_allocs:?}"
    );
}
