//! Telemetry recorder (DESIGN.md §2.7/§2.9): the four collectors — the
//! time-series sampler, the job lifecycle spans, the realized
//! dynamic-tree capture, and the per-block flight recorder — observed
//! end to end on a churny Canary run, plus the zero-footprint
//! contract: with tracing off (and even with it on) the seeded
//! fingerprint is bit-identical, because sampler ticks live outside
//! `events_processed` and never advance the clock, and the flight
//! recorder only ever observes state the simulation already computed.

mod common;

use canary::collectives::runner;
use canary::faults::FaultSpec;
use canary::sim::US;
use canary::trace::{SpanKind, TraceSpec};
use canary::util::json;
use canary::workload::ScenarioBuilder;
use common::{fingerprint_bounded, lossy_scenario, verify};

/// The churn scenario of the trace suite: an access-link flap plus a
/// 16x straggler under an aggressive 1 µs aggregation timeout, so the
/// dynamic-tree collector sees timeout-fired partial aggregations.
fn churny() -> ScenarioBuilder {
    let mut sc = lossy_scenario(8, 4).faults(
        FaultSpec::default()
            .with_link_flap(0, 8, 5 * US, 40 * US)
            .with_straggler(3, 16),
    );
    sc.sim.canary_timeout_ps = US;
    sc
}

const BOUND: u64 = 5_000_000 * US;

// ---------------------------------------------------------------- pins

/// The zero-footprint contract, both halves. (a) Tracing off is the
/// deterministic baseline: same seed, same fingerprint. (b) Turning
/// tracing ON still reproduces that fingerprint bit for bit — the
/// recorder draws no RNG, schedules nothing the simulation reads, and
/// its ticks stay outside `events_processed` and `now`.
#[test]
fn tracing_is_zero_footprint_on_the_seeded_fingerprint() {
    let off = fingerprint_bounded(&churny(), 42, BOUND);
    let off2 = fingerprint_bounded(&churny(), 42, BOUND);
    assert_eq!(off, off2, "untraced runs diverged at the same seed");
    let on = fingerprint_bounded(
        &churny().trace(Some(TraceSpec::default())),
        42,
        BOUND,
    );
    assert_eq!(
        off, on,
        "enabling --trace perturbed the simulation fingerprint"
    );
    // a non-default cadence is equally invisible
    let fast = fingerprint_bounded(
        &churny().trace(Some(TraceSpec::default().with_cadence(US / 4))),
        42,
        BOUND,
    );
    assert_eq!(off, fast, "sampler cadence leaked into the simulation");
    // ... and so is the flight recorder, at any --trace-blocks setting
    for blocks in [1, 3, 1000] {
        let fr = fingerprint_bounded(
            &churny()
                .trace(Some(TraceSpec::default().with_blocks(blocks))),
            42,
            BOUND,
        );
        assert_eq!(
            off, fr,
            "--trace-blocks={blocks} perturbed the simulation fingerprint"
        );
    }
}

// ---------------------------------------------- collectors, end to end

/// One traced churny run feeds all three collectors: the sampler
/// produced ticks, every lifecycle phase left a span, and the
/// dynamic-tree capture recorded at least one timeout-fired *partial*
/// aggregation (fewer contributors than expected) — while values stay
/// exact and the fault is fully recovered from.
#[test]
fn traced_churn_run_feeds_all_three_collectors() {
    let mut exp = churny().trace(Some(TraceSpec::default())).build(77);
    let res = runner::run_to_completion(&mut exp.net, BOUND);
    assert!(res[0].completed, "traced churn run did not complete");
    verify(&exp).unwrap();

    // collector 1: time series
    let tracer = &exp.net.tracer;
    assert!(tracer.n_samples() > 0, "sampler never ticked");
    let last = tracer.samples().last().unwrap();
    assert!(
        last.t_ps <= exp.net.now + TraceSpec::default().cadence_ps,
        "sampler ran past the end of the simulation"
    );

    // collector 2: lifecycle spans, in causal order
    let kinds: Vec<SpanKind> =
        tracer.spans().iter().map(|s| s.kind).collect();
    for want in [
        SpanKind::Install,
        SpanKind::Kick,
        SpanKind::FirstSend,
        SpanKind::LastSend,
        SpanKind::Aggregated,
        SpanKind::Broadcast,
        SpanKind::HostDone,
        SpanKind::Complete,
    ] {
        assert!(
            kinds.contains(&want),
            "lifecycle span {} missing (got {kinds:?})",
            want.name()
        );
    }
    let pos = |k: SpanKind| kinds.iter().position(|&x| x == k).unwrap();
    assert!(pos(SpanKind::Install) < pos(SpanKind::FirstSend));
    assert!(pos(SpanKind::FirstSend) < pos(SpanKind::Complete));

    // collector 3: realized dynamic trees
    let trees = tracer.tree_records();
    assert!(!trees.is_empty(), "no aggregation forwards recorded");
    assert!(
        trees.iter().all(|r| r.contributed <= r.expected.max(1)),
        "a forward claims more contributors than participants"
    );
    let partial = trees
        .iter()
        .filter(|r| r.via_timeout && r.contributed < r.expected)
        .count();
    assert!(
        partial >= 1,
        "no timeout-fired partial aggregation was captured \
         (metrics says {})",
        exp.net.metrics.partial_aggregates
    );
    assert!(
        exp.net.metrics.partial_aggregates >= 1,
        "scenario no longer produces partial aggregations"
    );
}

// ------------------------------------------------------------- exports

/// `trace::export` writes the four artifacts, non-empty and
/// parseable: the timeline CSV with its pinned header (now carrying
/// the `samples_dropped` gauge), the span CSV, the realized-tree JSON,
/// and the flight recorder's critical-path JSON (both round-tripped
/// through `util::json`).
#[test]
fn export_writes_four_parseable_artifacts() {
    let mut exp = churny()
        .trace(Some(TraceSpec::default().with_blocks(3)))
        .build(77);
    runner::run_to_completion(&mut exp.net, BOUND);

    let dir = std::env::temp_dir()
        .join(format!("canary_trace_test_{}", std::process::id()));
    let dir = dir.to_str().unwrap().to_string();
    let paths = canary::trace::export(&exp.net, &dir).unwrap();
    assert_eq!(paths.len(), 4, "expected exactly four artifacts");

    let timeline = std::fs::read_to_string(format!(
        "{dir}/trace_timeline.csv"
    ))
    .unwrap();
    let mut lines = timeline.lines();
    assert_eq!(
        lines.next().unwrap(),
        "t_us,link,from,to,queued_bytes,class0_bytes,util_pct,drops,\
         alive,arena_live,live_desc,ecn_marks,samples_dropped",
        "timeline header drifted"
    );
    assert!(lines.next().is_some(), "timeline has no data rows");

    let spans =
        std::fs::read_to_string(format!("{dir}/trace_spans.csv")).unwrap();
    assert!(spans.lines().count() > 1, "span CSV has no data rows");
    assert!(spans.contains("complete"), "no completion span exported");

    let trees =
        std::fs::read_to_string(format!("{dir}/trace_trees.json")).unwrap();
    let v = json::parse(&trees).expect("trace_trees.json is not JSON");
    let n = match v.get("forwards_total") {
        Some(json::Value::Int(n)) => *n,
        other => panic!("forwards_total missing/mistyped: {other:?}"),
    };
    assert!(n > 0, "tree export saw no forwards");

    let crit = std::fs::read_to_string(format!(
        "{dir}/trace_critical_paths.json"
    ))
    .unwrap();
    let v =
        json::parse(&crit).expect("trace_critical_paths.json is not JSON");
    let n = match v.get("blocks_traced") {
        Some(json::Value::Int(n)) => *n,
        other => panic!("blocks_traced missing/mistyped: {other:?}"),
    };
    assert!(n > 0, "critical-path export traced no blocks");

    let _ = std::fs::remove_dir_all(&dir);
}

// ----------------------------------------------------- flight recorder

/// The profiler's headline invariant (DESIGN.md §2.9): for every traced
/// block, the critical path's components — queueing + serialization +
/// propagation + aggregation wait + timeout penalty — tile its
/// end-to-end latency ps-exactly. Checked on the seeded churny run,
/// where timeout penalties actually occur.
#[test]
fn critical_path_components_tile_end_to_end_latency() {
    let mut exp = churny()
        .trace(Some(TraceSpec::default().with_blocks(3)))
        .build(77);
    let res = runner::run_to_completion(&mut exp.net, BOUND);
    assert!(res[0].completed, "traced churn run did not complete");

    assert!(
        !exp.net.tracer.hops().is_empty(),
        "flight recorder logged no hops"
    );
    let paths = canary::trace::critical_paths(&exp.net);
    assert!(!paths.is_empty(), "no critical paths reconstructed");
    for p in &paths {
        assert!(p.t_end > p.t_start, "degenerate path for block {}", p.block);
        assert_eq!(
            p.components_ps(),
            p.e2e_ps(),
            "components do not tile block {} (tenant {}): \
             q {} + ser {} + prop {} + wait {} + timeout {} != {}",
            p.block,
            p.tenant,
            p.queue_ps,
            p.ser_ps,
            p.prop_ps,
            p.agg_wait_ps,
            p.timeout_penalty_ps,
            p.e2e_ps()
        );
        // steps are contiguous in time, newest-first reversed to
        // oldest-first
        for w in p.steps.windows(2) {
            assert_eq!(
                w[0].t_end, w[1].t_start,
                "gap in critical path of block {}",
                p.block
            );
        }
    }
    // the churny scenario fires timeouts; at least one traced path
    // should attribute some latency to them
    assert!(
        paths.iter().any(|p| p.timeout_penalty_ps > 0),
        "no traced path carries a timeout penalty on the churny run"
    );
}

/// Sampling determinism contract: two identical traced runs emit
/// byte-identical `trace_critical_paths.json` — block selection is
/// seed-derived and the export path is fully ordered.
#[test]
fn identical_traced_runs_emit_byte_identical_critical_paths() {
    let run = |tag: &str| {
        let mut exp = churny()
            .trace(Some(TraceSpec::default().with_blocks(3)))
            .build(123);
        runner::run_to_completion(&mut exp.net, BOUND);
        let dir = std::env::temp_dir().join(format!(
            "canary_trace_det_{}_{tag}",
            std::process::id()
        ));
        let dir = dir.to_str().unwrap().to_string();
        canary::trace::export(&exp.net, &dir).unwrap();
        let bytes =
            std::fs::read(format!("{dir}/trace_critical_paths.json"))
                .unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        bytes
    };
    let (a, b) = (run("a"), run("b"));
    assert!(!a.is_empty(), "critical-path artifact is empty");
    assert_eq!(a, b, "identical traced runs produced different artifacts");
}
