//! Telemetry recorder (DESIGN.md §2.7): the three collectors — the
//! time-series sampler, the job lifecycle spans, and the realized
//! dynamic-tree capture — observed end to end on a churny Canary run,
//! plus the zero-footprint contract: with tracing off (and even with
//! it on) the seeded fingerprint is bit-identical, because sampler
//! ticks live outside `events_processed` and never advance the clock.

mod common;

use canary::collectives::runner;
use canary::faults::FaultSpec;
use canary::sim::US;
use canary::trace::{SpanKind, TraceSpec};
use canary::util::json;
use canary::workload::ScenarioBuilder;
use common::{fingerprint_bounded, lossy_scenario, verify};

/// The churn scenario of the trace suite: an access-link flap plus a
/// 16x straggler under an aggressive 1 µs aggregation timeout, so the
/// dynamic-tree collector sees timeout-fired partial aggregations.
fn churny() -> ScenarioBuilder {
    let mut sc = lossy_scenario(8, 4).faults(
        FaultSpec::default()
            .with_link_flap(0, 8, 5 * US, 40 * US)
            .with_straggler(3, 16),
    );
    sc.sim.canary_timeout_ps = US;
    sc
}

const BOUND: u64 = 5_000_000 * US;

// ---------------------------------------------------------------- pins

/// The zero-footprint contract, both halves. (a) Tracing off is the
/// deterministic baseline: same seed, same fingerprint. (b) Turning
/// tracing ON still reproduces that fingerprint bit for bit — the
/// recorder draws no RNG, schedules nothing the simulation reads, and
/// its ticks stay outside `events_processed` and `now`.
#[test]
fn tracing_is_zero_footprint_on_the_seeded_fingerprint() {
    let off = fingerprint_bounded(&churny(), 42, BOUND);
    let off2 = fingerprint_bounded(&churny(), 42, BOUND);
    assert_eq!(off, off2, "untraced runs diverged at the same seed");
    let on = fingerprint_bounded(
        &churny().trace(Some(TraceSpec::default())),
        42,
        BOUND,
    );
    assert_eq!(
        off, on,
        "enabling --trace perturbed the simulation fingerprint"
    );
    // a non-default cadence is equally invisible
    let fast = fingerprint_bounded(
        &churny().trace(Some(TraceSpec::default().with_cadence(US / 4))),
        42,
        BOUND,
    );
    assert_eq!(off, fast, "sampler cadence leaked into the simulation");
}

// ---------------------------------------------- collectors, end to end

/// One traced churny run feeds all three collectors: the sampler
/// produced ticks, every lifecycle phase left a span, and the
/// dynamic-tree capture recorded at least one timeout-fired *partial*
/// aggregation (fewer contributors than expected) — while values stay
/// exact and the fault is fully recovered from.
#[test]
fn traced_churn_run_feeds_all_three_collectors() {
    let mut exp = churny().trace(Some(TraceSpec::default())).build(77);
    let res = runner::run_to_completion(&mut exp.net, BOUND);
    assert!(res[0].completed, "traced churn run did not complete");
    verify(&exp).unwrap();

    // collector 1: time series
    let tracer = &exp.net.tracer;
    assert!(tracer.n_samples() > 0, "sampler never ticked");
    let last = tracer.samples().last().unwrap();
    assert!(
        last.t_ps <= exp.net.now + TraceSpec::default().cadence_ps,
        "sampler ran past the end of the simulation"
    );

    // collector 2: lifecycle spans, in causal order
    let kinds: Vec<SpanKind> =
        tracer.spans().iter().map(|s| s.kind).collect();
    for want in [
        SpanKind::Install,
        SpanKind::Kick,
        SpanKind::FirstSend,
        SpanKind::LastSend,
        SpanKind::Aggregated,
        SpanKind::Broadcast,
        SpanKind::HostDone,
        SpanKind::Complete,
    ] {
        assert!(
            kinds.contains(&want),
            "lifecycle span {} missing (got {kinds:?})",
            want.name()
        );
    }
    let pos = |k: SpanKind| kinds.iter().position(|&x| x == k).unwrap();
    assert!(pos(SpanKind::Install) < pos(SpanKind::FirstSend));
    assert!(pos(SpanKind::FirstSend) < pos(SpanKind::Complete));

    // collector 3: realized dynamic trees
    let trees = tracer.tree_records();
    assert!(!trees.is_empty(), "no aggregation forwards recorded");
    assert!(
        trees.iter().all(|r| r.contributed <= r.expected.max(1)),
        "a forward claims more contributors than participants"
    );
    let partial = trees
        .iter()
        .filter(|r| r.via_timeout && r.contributed < r.expected)
        .count();
    assert!(
        partial >= 1,
        "no timeout-fired partial aggregation was captured \
         (metrics says {})",
        exp.net.metrics.partial_aggregates
    );
    assert!(
        exp.net.metrics.partial_aggregates >= 1,
        "scenario no longer produces partial aggregations"
    );
}

// ------------------------------------------------------------- exports

/// `trace::export` writes the three artifacts, non-empty and
/// parseable: the timeline CSV with its pinned header, the span CSV,
/// and the realized-tree JSON (round-tripped through `util::json`).
#[test]
fn export_writes_three_parseable_artifacts() {
    let mut exp = churny().trace(Some(TraceSpec::default())).build(77);
    runner::run_to_completion(&mut exp.net, BOUND);

    let dir = std::env::temp_dir()
        .join(format!("canary_trace_test_{}", std::process::id()));
    let dir = dir.to_str().unwrap().to_string();
    let paths = canary::trace::export(&exp.net, &dir).unwrap();
    assert_eq!(paths.len(), 3, "expected exactly three artifacts");

    let timeline = std::fs::read_to_string(format!(
        "{dir}/trace_timeline.csv"
    ))
    .unwrap();
    let mut lines = timeline.lines();
    assert_eq!(
        lines.next().unwrap(),
        "t_us,link,from,to,queued_bytes,class0_bytes,util_pct,drops,\
         alive,arena_live,live_desc,ecn_marks",
        "timeline header drifted"
    );
    assert!(lines.next().is_some(), "timeline has no data rows");

    let spans =
        std::fs::read_to_string(format!("{dir}/trace_spans.csv")).unwrap();
    assert!(spans.lines().count() > 1, "span CSV has no data rows");
    assert!(spans.contains("complete"), "no completion span exported");

    let trees =
        std::fs::read_to_string(format!("{dir}/trace_trees.json")).unwrap();
    let v = json::parse(&trees).expect("trace_trees.json is not JSON");
    let n = match v.get("forwards_total") {
        Some(json::Value::Int(n)) => *n,
        other => panic!("forwards_total missing/mistyped: {other:?}"),
    };
    assert!(n > 0, "tree export saw no forwards");

    let _ = std::fs::remove_dir_all(&dir);
}
