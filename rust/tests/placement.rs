//! Placement-policy invariants and the redesign's bit-compat pin.
//!
//! The Collective API replaced the `build_scenario`/`build_multi_tenant`
//! free functions with the `ScenarioBuilder` path; the contract is that
//! a single `RandomUniform` allreduce job makes **exactly** the RNG
//! draws of the old placement in the same order, so every recorded
//! figure series is bit-identical for the same placement seed. This
//! file pins that against an inlined replica of the legacy placement,
//! and checks the structural invariants of the new policies.

use canary::collectives::{runner, Algo, Collective};
use canary::config::FatTreeConfig;
use canary::sim::{NodeId, US};
use canary::traffic::TrafficSpec;
use canary::util::rng::Rng;
use canary::workload::{JobBuilder, Placement, ScenarioBuilder};

/// The pre-redesign `build_scenario` placement, reproduced verbatim:
/// one `Rng::new(placement_seed)`, `sample_indices` over all hosts,
/// participants sorted; static roots sampled next from the same stream;
/// background = the non-participants in ascending id order.
fn legacy_placement(
    topo: FatTreeConfig,
    n_hosts: u32,
    static_trees: Option<usize>,
    placement_seed: u64,
) -> (Vec<NodeId>, Vec<NodeId>, Vec<NodeId>) {
    let mut rng = Rng::new(placement_seed);
    let all: Vec<NodeId> = (0..topo.n_hosts()).collect();
    let chosen_idx = rng.sample_indices(all.len(), n_hosts as usize);
    let mut participants: Vec<NodeId> =
        chosen_idx.iter().map(|&i| all[i]).collect();
    participants.sort_unstable();
    let roots = match static_trees {
        Some(n) => {
            // legacy random_roots: sample over the spine list
            let spines: Vec<NodeId> = (topo.n_hosts() + topo.n_leaf()
                ..topo.n_hosts() + topo.n_leaf() + topo.n_spine())
                .collect();
            let idx = rng.sample_indices(spines.len(), n.min(spines.len()));
            idx.into_iter().map(|i| spines[i]).collect()
        }
        None => vec![],
    };
    let bg: Vec<NodeId> = all
        .iter()
        .copied()
        .filter(|h| participants.binary_search(h).is_err())
        .collect();
    (participants, roots, bg)
}

fn built_sets(
    sc: &ScenarioBuilder,
    seed: u64,
) -> (Vec<NodeId>, Vec<NodeId>, Vec<NodeId>) {
    let exp = sc.build(seed);
    let job = &exp.net.jobs[exp.job as usize];
    let bg = exp
        .net
        .jobs
        .iter()
        .find(|j| !j.spec.algo.is_allreduce())
        .map(|j| j.spec.participants.clone())
        .unwrap_or_default();
    (
        job.spec.participants.clone(),
        job.spec.tree_roots.clone(),
        bg,
    )
}

#[test]
fn random_uniform_is_bit_identical_to_the_legacy_placement() {
    let topo = FatTreeConfig::small();
    for seed in [1u64, 42, 1000, 0xDEAD_BEEF] {
        // canary job + uniform cross traffic (the standard figure cell)
        let sc = ScenarioBuilder::new(topo)
            .traffic(Some(TrafficSpec::uniform()))
            .job(JobBuilder::new(Algo::Canary).hosts(24).data_bytes(8192));
        let (got_p, got_r, got_bg) = built_sets(&sc, seed);
        let (want_p, want_r, want_bg) =
            legacy_placement(topo, 24, None, seed);
        assert_eq!(got_p, want_p, "participants diverged at seed {seed}");
        assert_eq!(got_r, want_r);
        assert_eq!(got_bg, want_bg, "background set diverged at seed {seed}");

        // static-tree job: the root draw must follow the participant
        // draw on the same stream, as before
        let sc = ScenarioBuilder::new(topo)
            .traffic(Some(TrafficSpec::uniform()))
            .job(
                JobBuilder::new(Algo::StaticTree { n_trees: 4 })
                    .hosts(24)
                    .data_bytes(8192),
            );
        let (got_p, got_r, got_bg) = built_sets(&sc, seed);
        let (want_p, want_r, want_bg) =
            legacy_placement(topo, 24, Some(4), seed);
        assert_eq!(got_p, want_p);
        assert_eq!(got_r, want_r, "tree roots diverged at seed {seed}");
        assert_eq!(got_bg, want_bg);
    }
}

#[test]
fn random_uniform_runs_are_fully_deterministic() {
    // same scenario + seed twice: identical event streams end to end
    let run = || {
        let sc = ScenarioBuilder::new(FatTreeConfig::small())
            .traffic(Some(TrafficSpec::uniform()))
            .job(JobBuilder::new(Algo::Canary).hosts(16).data_bytes(32 * 1024));
        let mut exp = sc.build(7);
        let r = runner::run_to_completion(&mut exp.net, u64::MAX);
        (exp.net.events_processed, r[0].runtime_ps)
    };
    assert_eq!(run(), run());
}

#[test]
fn clustered_placement_stays_within_leaf_boundaries() {
    let topo = FatTreeConfig::small(); // 4 leaves x 16 hosts
    let per_leaf = topo.hosts_per_leaf();
    for (hosts, want_leaves) in [(16u32, 1usize), (20, 2), (48, 3)] {
        let sc = ScenarioBuilder::new(topo).job(
            JobBuilder::new(Algo::Canary)
                .hosts(hosts)
                .data_bytes(1024)
                .placement(Placement::ClusteredByLeaf),
        );
        let exp = sc.build(3);
        let spec = &exp.net.jobs[exp.job as usize].spec;
        let mut leaves: Vec<u32> = spec
            .participants
            .iter()
            .map(|&h| exp.ft.leaf_of_host(h))
            .collect();
        leaves.sort_unstable();
        leaves.dedup();
        assert_eq!(
            leaves.len(),
            want_leaves,
            "{hosts} hosts at {per_leaf}/leaf must fill exactly \
             {want_leaves} leaves"
        );
        // all but (at most) one leaf must be completely full
        let mut counts = std::collections::BTreeMap::new();
        for &h in &spec.participants {
            *counts.entry(exp.ft.leaf_of_host(h)).or_insert(0u32) += 1;
        }
        let partial =
            counts.values().filter(|&&c| c < per_leaf).count();
        assert!(partial <= 1, "clustering left {partial} partial leaves");
    }
}

#[test]
fn striped_placement_round_robins_the_leaves() {
    let topo = FatTreeConfig::small(); // 4 leaves x 16 hosts
    for hosts in [4u32, 10, 33] {
        let sc = ScenarioBuilder::new(topo).job(
            JobBuilder::new(Algo::Canary)
                .hosts(hosts)
                .data_bytes(1024)
                .placement(Placement::Striped),
        );
        let exp = sc.build(5);
        let spec = &exp.net.jobs[exp.job as usize].spec;
        let mut counts = std::collections::BTreeMap::new();
        for &h in &spec.participants {
            *counts.entry(exp.ft.leaf_of_host(h)).or_insert(0u32) += 1;
        }
        // every leaf is touched, and the per-leaf counts are balanced
        assert_eq!(counts.len() as u32, topo.n_leaf().min(hosts));
        let min = counts.values().min().unwrap();
        let max = counts.values().max().unwrap();
        assert!(
            max - min <= 1,
            "striping must balance leaves, got {counts:?}"
        );
    }
}

#[test]
fn explicit_placement_is_used_verbatim() {
    let hosts = vec![3u32, 17, 40, 62];
    let sc = ScenarioBuilder::new(FatTreeConfig::small()).job(
        JobBuilder::new(Algo::Canary)
            .data_bytes(1024)
            .placement(Placement::Explicit(hosts.clone())),
    );
    let exp = sc.build(9);
    let spec = &exp.net.jobs[exp.job as usize].spec;
    assert_eq!(spec.participants, hosts);
}

#[test]
fn multi_job_placements_are_disjoint_and_traffic_gets_the_rest() {
    let topo = FatTreeConfig::small();
    let sc = ScenarioBuilder::new(topo)
        .traffic(Some(TrafficSpec::uniform()))
        .job(
            JobBuilder::new(Algo::Canary)
                .hosts(16)
                .data_bytes(4096)
                .placement(Placement::ClusteredByLeaf),
        )
        .job(
            JobBuilder::new(Algo::Ring)
                .hosts(12)
                .data_bytes(4096)
                .placement(Placement::Striped),
        )
        .job(JobBuilder::new(Algo::Canary).hosts(8).data_bytes(4096));
    let exp = sc.build(11);
    let mut seen = std::collections::BTreeSet::new();
    let mut total = 0usize;
    for j in &exp.net.jobs {
        for &h in &j.spec.participants {
            assert!(seen.insert(h), "host {h} claimed twice");
        }
        total += j.spec.participants.len();
    }
    // 16 + 12 + 8 participants + the rest as background
    assert_eq!(total, topo.n_hosts() as usize);
    let bg = exp
        .net
        .jobs
        .iter()
        .find(|j| !j.spec.algo.is_allreduce())
        .expect("cross traffic must be installed in multi-job scenarios");
    assert_eq!(bg.spec.participants.len(), 64 - 16 - 12 - 8);
    // tenants are distinct and the descriptor table is partitioned
    let tenants: Vec<u16> = exp
        .net
        .jobs
        .iter()
        .filter(|j| j.spec.algo.is_allreduce())
        .map(|j| j.spec.tenant)
        .collect();
    assert_eq!(tenants, vec![1, 2, 3]);
}

#[test]
fn start_offsets_delay_job_kickoff() {
    let offset = 50 * US;
    let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
        .job(JobBuilder::new(Algo::Canary).hosts(4).data_bytes(4096))
        .job(
            JobBuilder::new(Algo::Canary)
                .hosts(4)
                .data_bytes(4096)
                .start_at(offset),
        );
    let mut exp = sc.build(13);
    runner::run_to_completion(&mut exp.net, u64::MAX);
    let first = &exp.net.jobs[exp.jobs[0] as usize];
    let second = &exp.net.jobs[exp.jobs[1] as usize];
    let f1 = first.finish.expect("job 0 finished");
    let f2 = second.finish.expect("job 1 finished");
    assert!(f2 >= offset, "delayed job finished before it started");
    assert!(f1 < offset, "tiny transfer should finish before t=50us");
    // runtime excludes the offset
    assert_eq!(second.start, offset);
    assert_eq!(second.runtime_ps(), Some(f2 - offset));
}

#[test]
fn mixed_collectives_share_one_fabric() {
    // a reduce, a broadcast and a barrier as concurrent tenants, plus
    // cross traffic: all complete on one network
    let sc = ScenarioBuilder::new(FatTreeConfig::small())
        .traffic(Some(TrafficSpec::uniform()))
        .job(
            JobBuilder::new(Algo::Canary)
                .collective(Collective::Reduce { root: 0 })
                .hosts(8)
                .data_bytes(8 * 1024),
        )
        .job(
            JobBuilder::new(Algo::Canary)
                .collective(Collective::Broadcast { root: 1 })
                .hosts(8)
                .data_bytes(8 * 1024),
        )
        .job(
            JobBuilder::new(Algo::Canary)
                .collective(Collective::Barrier)
                .hosts(8),
        );
    let mut exp = sc.build(17);
    let results = runner::run_to_completion(&mut exp.net, 500_000 * US);
    assert_eq!(results.len(), 3);
    for r in &results {
        assert!(
            r.runtime_ps.is_some(),
            "{} did not finish",
            r.collective.name()
        );
    }
}
