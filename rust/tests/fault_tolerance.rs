//! Fault tolerance (paper Section 3.3): random packet loss and switch
//! failures are handled end-to-end by the leader protocol — blocks are
//! retransmitted or re-reduced under fresh ids, and values stay exact.
//!
//! Scheduled churn (link flaps, timed switch recovery, stragglers)
//! lives in `tests/churn.rs`; this suite covers the random-loss and
//! permanent-failure half of the `FaultSpec` surface.

mod common;

use canary::collectives::{runner, Algo, Collective};
use canary::config::{FatTreeConfig, SimConfig};
use canary::faults::FaultSpec;
use canary::sim::US;
use canary::topology::FatTree;
use canary::util::proptest_lite::check_property;
use canary::util::rng::Rng;
use canary::workload::{JobBuilder, ScenarioBuilder};
use common::{lossy_scenario, verify};

#[test]
fn survives_random_packet_loss() {
    check_property("loss-recovery", 0xF0, 5, |rng: &mut Rng| {
        let sc = lossy_scenario(4 + rng.gen_range(4) as u32, 4)
            .faults(FaultSpec::default().with_loss(0.02));
        let mut exp = sc.build(rng.next_u64());
        runner::run_to_completion(&mut exp.net, 2_000_000 * US);
        if exp.net.metrics.drops_injected == 0 {
            return Err("no loss was injected".into());
        }
        verify(&exp)
    });
}

#[test]
fn survives_heavy_packet_loss() {
    let sc = lossy_scenario(4, 2)
        .faults(FaultSpec::default().with_loss(0.10));
    let mut exp = sc.build(42);
    runner::run_to_completion(&mut exp.net, 5_000_000 * US);
    verify(&exp).unwrap();
    // heavy loss must have exercised the failure/retry machinery
    let m = &exp.net.metrics;
    assert!(
        m.retrans_requests > 0,
        "expected retransmission requests, metrics: {m:?}"
    );
}

#[test]
fn survives_spine_switch_failure() {
    // kill one spine mid-transfer: its soft state is lost; the leaders
    // recover every affected block (loss-equivalent, Section 3.3).
    // fail mid-transfer (a 64 KiB allreduce runs for tens of us)
    let spine = FatTree { cfg: FatTreeConfig::tiny() }.spine_id(0);
    let sc = lossy_scenario(8, 64)
        .faults(FaultSpec::default().with_switch_fail(spine, 5 * US, None));
    let mut exp = sc.build(21);
    runner::run_to_completion(&mut exp.net, 5_000_000 * US);
    assert_eq!(exp.net.metrics.switch_failures, 1);
    assert_eq!(exp.net.metrics.switch_recoveries, 0);
    verify(&exp).unwrap();
}

#[test]
fn fallback_to_host_based_reduction() {
    // max_retries 0 forces direct (host-based) contributions on the
    // first failure round, which must still produce exact results
    let mut sc = lossy_scenario(5, 2)
        .faults(FaultSpec::default().with_loss(0.05));
    sc.sim.max_retries = 0;
    let mut exp = sc.build(33);
    runner::run_to_completion(&mut exp.net, 5_000_000 * US);
    verify(&exp).unwrap();
}

#[test]
fn clean_run_has_no_recovery_activity() {
    let sc = lossy_scenario(6, 4);
    let mut exp = sc.build(55);
    runner::run_to_completion(&mut exp.net, 2_000_000 * US);
    verify(&exp).unwrap();
    let m = &exp.net.metrics;
    assert_eq!(m.failures, 0);
    assert_eq!(m.drops_injected, 0);
}

#[test]
fn derived_collectives_survive_packet_loss() {
    // the loss-recovery machinery must stay correct when leaders are
    // pinned to a root (reduce/broadcast) and for the one-block barrier
    let collectives = [
        Collective::Reduce { root: 0 },
        Collective::Broadcast { root: 2 },
        Collective::Barrier,
    ];
    for c in collectives {
        let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
            .sim(
                SimConfig::default()
                    .with_values(true)
                    .with_retrans(200 * US, true),
            )
            .faults(FaultSpec::default().with_loss(0.03))
            .job(
                JobBuilder::new(Algo::Canary)
                    .collective(c)
                    .hosts(6)
                    .data_bytes(4 * 1024)
                    .record_results(true),
            );
        let mut exp = sc.build(19);
        runner::run_to_completion(&mut exp.net, 5_000_000 * US);
        assert!(
            exp.net.metrics.drops_injected > 0,
            "{}: no loss injected",
            c.name()
        );
        verify(&exp).unwrap_or_else(|e| panic!("{}: {e}", c.name()));
    }
}
