"""Layer-2: the JAX model — a decoder-only transformer LM.

This is the paper's *motivating workload* (distributed training whose
gradient allreduce Canary accelerates). The whole training computation is
expressed against a single **flat f32 parameter vector** so the Rust side
manages exactly one buffer; unflattening happens inside the traced function
and is free after XLA fusion.

Artifacts lowered by ``aot.py`` (all pure functions of their inputs):

- ``init_params(seed)                -> f32[P]``
- ``train_step(flat, tokens)         -> (loss f32[], qgrads i32[P])`` —
  fwd+bwd and fixed-point packing of the gradient via the L1 Pallas
  quantize kernel, so L1 lowers into the same HLO module.
- ``apply_update(flat, qsum, lr, nw) -> f32[P]`` — dequantize the
  allreduced (summed) fixed-point gradient, average over ``nw`` workers,
  SGD step.
- ``eval_loss(flat, tokens)          -> f32[]``

The gradient leaves ``train_step`` already quantized: the wire format of
Canary packets *is* the int32 fixed-point produced here, and the simulated
switches aggregate it with the saturating ALU adds of ``kernels.aggregate``.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import dequantize, quantize


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer hyper-parameters; ``name`` selects a preset."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int
    frac_bits: int = 20

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


PRESETS = {
    # tiny: unit tests / CI — sub-second end to end
    "tiny": ModelConfig("tiny", 256, 64, 2, 4, 256, 32, 4),
    # small: quickstart example (~0.9M params)
    "small": ModelConfig("small", 512, 128, 2, 4, 512, 64, 8),
    # base: default train_e2e model (~3.6M params)
    "base": ModelConfig("base", 512, 256, 4, 8, 1024, 128, 8),
    # large: ~100M params, the paper-scale validation target
    "large": ModelConfig("large", 8192, 768, 12, 12, 3072, 256, 8),
}


def param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list defining the flat-vector layout."""
    d, f, v, t = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    spec = [("tok_emb", (v, d)), ("pos_emb", (t, d))]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.ln1_g", (d,)),
            (f"l{i}.ln1_b", (d,)),
            (f"l{i}.wq", (d, d)),
            (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)),
            (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2_g", (d,)),
            (f"l{i}.ln2_b", (d,)),
            (f"l{i}.w1", (d, f)),
            (f"l{i}.b1", (f,)),
            (f"l{i}.w2", (f, d)),
            (f"l{i}.b2", (d,)),
        ]
    spec += [("lnf_g", (d,)), ("lnf_b", (d,)), ("head", (d, v))]
    return spec


def param_count(cfg: ModelConfig) -> int:
    """Total number of scalar parameters P."""
    total = 0
    for _, shape in param_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def unflatten(cfg: ModelConfig, flat: jax.Array) -> dict:
    """Slice the flat vector into the named parameter dict."""
    params, off = {}, 0
    for name, shape in param_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        params[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
        off += n
    return params


def flatten(cfg: ModelConfig, params: dict) -> jax.Array:
    """Concatenate the parameter dict back into the flat layout."""
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in param_spec(cfg)]
    )


def init_params(cfg: ModelConfig, seed: jax.Array) -> jax.Array:
    """Initialize the flat parameter vector from a uint32 seed scalar."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    parts = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            parts.append(jnp.ones(shape, jnp.float32).reshape(-1))
        elif name.endswith(("_b", ".b1", ".b2")):
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = (1.0 / fan_in) ** 0.5
            parts.append(
                (jax.random.normal(sub, shape, jnp.float32) * std).reshape(-1)
            )
    return jnp.concatenate(parts)


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(cfg: ModelConfig, p, i, x):
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def proj(w):
        return (x @ p[f"l{i}.{w}"]).reshape(b, t, h, hd).transpose(0, 2, 1, 3)

    q, k, v = proj("wq"), proj("wk"), proj("wv")
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ p[f"l{i}.wo"]


def _mlp(p, i, x):
    h = jax.nn.gelu(x @ p[f"l{i}.w1"] + p[f"l{i}.b1"])
    return h @ p[f"l{i}.w2"] + p[f"l{i}.b2"]


def forward_logits(cfg: ModelConfig, flat: jax.Array, tokens: jax.Array):
    """``tokens i32[B, T] -> logits f32[B, T, V]`` (causal LM)."""
    p = unflatten(cfg, flat)
    b, t = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][:t][None, :, :]
    for i in range(cfg.n_layers):
        x = x + _attention(
            cfg, p, i, _layer_norm(x, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
        )
        x = x + _mlp(p, i, _layer_norm(x, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"]))
    x = _layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["head"]


def loss_fn(cfg: ModelConfig, flat: jax.Array, tokens: jax.Array):
    """Mean next-token cross-entropy over ``tokens[:, 1:]``."""
    logits = forward_logits(cfg, flat, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, flat: jax.Array, tokens: jax.Array):
    """Fwd+bwd, then fixed-point-pack the gradient (L1 Pallas kernel)."""
    loss, grads = jax.value_and_grad(
        lambda fp: loss_fn(cfg, fp, tokens)
    )(flat)
    qgrads = quantize(grads, frac_bits=cfg.frac_bits)
    return loss, qgrads


def apply_update(
    cfg: ModelConfig,
    flat: jax.Array,
    qsum: jax.Array,
    lr: jax.Array,
    n_workers: jax.Array,
):
    """SGD step from the allreduced (summed) fixed-point gradient."""
    gsum = dequantize(qsum, frac_bits=cfg.frac_bits)
    return flat - lr * (gsum / n_workers)
