"""AOT bridge: lower the L2/L1 computations to HLO **text** artifacts.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (``make artifacts``):

    python -m compile.aot --outdir ../artifacts --presets tiny,base

and never again at runtime — the Rust binary is self-contained afterwards.
Also writes ``manifest.json`` describing every artifact's I/O signature,
the model configs, and golden vectors for the Rust bit-parity tests.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import aggregate, quantize, dequantize
from .kernels import ref

# Payload lanes per Canary packet in the scale simulations: 256 x 4 B
# elements (Section 5.1 runs all in-network algorithms with 256 elements).
PACKET_LANES = 256


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(*args):
    return [
        {"dtype": str(a.dtype), "shape": list(a.shape)} for a in args
    ]


def lower_model_artifacts(cfg: M.ModelConfig, outdir: str, manifest: dict):
    """Lower init/train_step/apply_update/eval_loss for one preset."""
    p = M.param_count(cfg)
    b, t = cfg.batch, cfg.seq_len
    flat = jax.ShapeDtypeStruct((p,), jnp.float32)
    tokens = jax.ShapeDtypeStruct((b, t), jnp.int32)
    qsum = jax.ShapeDtypeStruct((p,), jnp.int32)
    scalar_f = jax.ShapeDtypeStruct((), jnp.float32)
    seed = jax.ShapeDtypeStruct((), jnp.uint32)

    arts = {
        "init_params": (lambda s: (M.init_params(cfg, s),), (seed,)),
        "train_step": (
            lambda fp, tk: M.train_step(cfg, fp, tk),
            (flat, tokens),
        ),
        "apply_update": (
            lambda fp, qs, lr, nw: (M.apply_update(cfg, fp, qs, lr, nw),),
            (flat, qsum, scalar_f, scalar_f),
        ),
        "eval_loss": (
            lambda fp, tk: (M.loss_fn(cfg, fp, tk),),
            (flat, tokens),
        ),
    }
    for name, (fn, in_spec) in arts.items():
        fname = f"{cfg.name}_{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        lowered = jax.jit(fn).lower(*in_spec)
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        out_shapes = jax.eval_shape(fn, *in_spec)
        manifest["artifacts"][f"{cfg.name}_{name}"] = {
            "file": fname,
            "inputs": _sig(*in_spec),
            "outputs": _sig(*out_shapes),
        }
        print(f"  wrote {fname}")

    manifest["models"][cfg.name] = {
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "frac_bits": cfg.frac_bits,
        "param_count": p,
    }


def lower_kernel_artifacts(outdir: str, manifest: dict):
    """Standalone L1 kernels: switch aggregation + quantize pair."""
    for w in (2, 4, 8, 16):
        spec = jax.ShapeDtypeStruct((w, PACKET_LANES), jnp.int32)
        fn = lambda x: (aggregate(x),)
        name = f"aggregate_w{w}"
        lowered = jax.jit(fn).lower(spec)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": _sig(spec),
            "outputs": [{"dtype": "int32", "shape": [PACKET_LANES]}],
        }
        print(f"  wrote {fname}")

    fspec = jax.ShapeDtypeStruct((PACKET_LANES,), jnp.float32)
    qspec = jax.ShapeDtypeStruct((PACKET_LANES,), jnp.int32)
    for name, fn, spec, out_dt in (
        ("quantize_block", lambda x: (quantize(x, frac_bits=20),), fspec, "int32"),
        ("dequantize_block", lambda q: (dequantize(q, frac_bits=20),), qspec, "float32"),
    ):
        lowered = jax.jit(fn).lower(spec)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": _sig(spec),
            "outputs": [{"dtype": out_dt, "shape": [PACKET_LANES]}],
        }
        print(f"  wrote {fname}")


def golden_vectors() -> dict:
    """Small reference vectors for Rust <-> Pallas bit-parity tests.

    f32 arrays are encoded as u32 bit patterns so JSON round-trips exactly.
    """
    rng = np.random.default_rng(0xC0FFEE)
    payloads = rng.integers(-(2**30), 2**30, size=(6, 16), dtype=np.int32)
    # force saturation on two lanes
    payloads[:, 0] = 2**30 + 12345
    payloads[:, 1] = -(2**30) - 54321
    agg = ref.aggregate_ref(payloads)

    x = (rng.standard_normal(24) * 3.0).astype(np.float32)
    x[0] = 3000.0  # saturates at frac_bits=20
    x[1] = -3000.0
    q = ref.quantize_ref(x, frac_bits=20)
    dq = ref.dequantize_ref(q, frac_bits=20)

    return {
        "frac_bits": 20,
        "aggregate": {
            "payloads": payloads.reshape(-1).tolist(),
            "n": int(payloads.shape[0]),
            "lanes": int(payloads.shape[1]),
            "expected": agg.tolist(),
        },
        "quantize": {
            "x_bits": x.view(np.uint32).tolist(),
            "expected_q": q.tolist(),
            "expected_dq_bits": dq.view(np.uint32).tolist(),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--presets",
        default="tiny,base",
        help="comma-separated model presets to lower (tiny,small,base,large)",
    )
    # kept for Makefile compatibility: --out <file> also sets outdir
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    outdir = os.path.dirname(args.out) if args.out else args.outdir
    os.makedirs(outdir, exist_ok=True)

    manifest = {
        "packet_lanes": PACKET_LANES,
        "artifacts": {},
        "models": {},
        "golden": golden_vectors(),
    }
    print("lowering kernel artifacts")
    lower_kernel_artifacts(outdir, manifest)
    for preset in args.presets.split(","):
        cfg = M.PRESETS[preset.strip()]
        print(f"lowering model artifacts for preset '{cfg.name}'")
        lower_model_artifacts(cfg, outdir, manifest)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(outdir, 'manifest.json')}")
    # marker file used by `make -q artifacts` freshness checks
    with open(os.path.join(outdir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
