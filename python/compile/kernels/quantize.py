"""Host-side f32 <-> fixed-point packing as Pallas kernels.

Programmable switches have no floating-point units (paper Section 6), so
hosts convert gradient values to fixed point before they hit the wire:
``q = round(x * 2^f)`` clipped to the int32 range. The inverse divides by
the scale. Both are expressed as lane-tiled Pallas kernels so they lower
into the same HLO module as the L2 train step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_TILE = 128

# Largest f32 that converts to int32 without UB on either side of the
# bridge: 2147483520 = nextafter(2^31, 0) in f32. Clamping to +/- this value
# in the *float* domain before the cast gives bit parity with the Rust
# mirror (`x.clamp(-Q_CLIP, Q_CLIP) as i32`).
Q_CLIP_F32 = 2147483520.0


def _quantize_kernel(x_ref, scale_ref, o_ref):
    scaled = x_ref[...] * scale_ref[0]
    clipped = jnp.clip(scaled, -Q_CLIP_F32, Q_CLIP_F32)
    # round-half-away-from-zero, matching Rust's f32::round()
    rounded = jnp.where(
        clipped >= 0.0, jnp.floor(clipped + 0.5), jnp.ceil(clipped - 0.5)
    )
    o_ref[...] = rounded.astype(jnp.int32)


def _dequantize_kernel(q_ref, inv_scale_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * inv_scale_ref[0]


def _tiled_call(kernel, x, aux, out_dtype, interpret):
    (n,) = x.shape
    pad = (-n) % LANE_TILE
    padded = jnp.pad(x, (0, pad))
    out = pl.pallas_call(
        kernel,
        grid=((n + pad) // LANE_TILE,),
        in_specs=[
            pl.BlockSpec((LANE_TILE,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((LANE_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), out_dtype),
        interpret=interpret,
    )(padded, aux)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("frac_bits", "interpret"))
def quantize(
    x: jax.Array, *, frac_bits: int = 20, interpret: bool = True
) -> jax.Array:
    """f32[n] -> fixed-point int32[n] with scale ``2**frac_bits``."""
    scale = jnp.array([float(2**frac_bits)], jnp.float32)
    return _tiled_call(
        _quantize_kernel, x.astype(jnp.float32), scale, jnp.int32, interpret
    )


@functools.partial(jax.jit, static_argnames=("frac_bits", "interpret"))
def dequantize(
    q: jax.Array, *, frac_bits: int = 20, interpret: bool = True
) -> jax.Array:
    """Fixed-point int32[n] -> f32[n] with scale ``2**frac_bits``."""
    inv = jnp.array([1.0 / float(2**frac_bits)], jnp.float32)
    return _tiled_call(
        _dequantize_kernel, q.astype(jnp.int32), inv, jnp.float32, interpret
    )
