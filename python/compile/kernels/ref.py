"""Pure-jnp/numpy oracles for the Pallas kernels.

These are the CORE correctness signal: ``python/tests/test_kernels.py``
sweeps shapes/values with hypothesis and asserts the Pallas kernels match
these references exactly (integer kernels) / to f32 ulp (float kernels).
They are also mirrored, bit-for-bit, by the Rust dataplane
(``rust/src/switch/alu.rs``) — the manifest carries golden vectors produced
here so the Rust tests can assert parity without a Python runtime.
"""

import numpy as np

I32_MAX = 2**31 - 1
I32_MIN = -(2**31)
Q_CLIP_F32 = 2147483520.0


def sat_add_i32_ref(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Element-wise saturating int32 add (int64 intermediate)."""
    s = a.astype(np.int64) + b.astype(np.int64)
    return np.clip(s, I32_MIN, I32_MAX).astype(np.int32)


def aggregate_ref(payloads: np.ndarray) -> np.ndarray:
    """Sequential saturating int32 fold along axis 0 (order matters only
    when saturation occurs; otherwise equals the plain sum)."""
    acc = np.zeros(payloads.shape[1:], np.int32)
    for row in payloads.astype(np.int32):
        acc = sat_add_i32_ref(acc, row)
    return acc


def quantize_ref(x: np.ndarray, frac_bits: int = 20) -> np.ndarray:
    """f32 -> fixed-point int32: round-half-away-from-zero of x * 2^f,
    clamped to the float-domain clip used by the kernel and Rust."""
    scaled = x.astype(np.float32) * np.float32(2.0**frac_bits)
    clipped = np.clip(scaled, -Q_CLIP_F32, Q_CLIP_F32)
    rounded = np.where(
        clipped >= 0.0,
        np.floor(clipped + np.float32(0.5)),
        np.ceil(clipped - np.float32(0.5)),
    ).astype(np.float32)
    return rounded.astype(np.int32)


def dequantize_ref(q: np.ndarray, frac_bits: int = 20) -> np.ndarray:
    """Fixed-point int32 -> f32."""
    return (q.astype(np.float32) * np.float32(1.0 / 2.0**frac_bits)).astype(
        np.float32
    )
