"""Switch-ALU aggregation as a Pallas kernel.

The Canary dataplane accumulates the int32 lanes of every reduction packet
into the descriptor's accumulator with *saturating* adds — this is what the
Tofino ALUs do, and what keeps fixed-point aggregation order-independent in
the absence of overflow (and deterministic-to-the-bit even with it, given a
fixed arrival order).

TPU adaptation (DESIGN.md §3): payloads are laid out ``[n_packets, lanes]``
in HBM; the BlockSpec streams ``[n_packets, LANE_TILE]`` tiles into VMEM and
the accumulation runs on the VPU (element-wise work — the MXU plays no role
here). The accumulator tile stays VMEM-resident across the sequential packet
loop, mirroring the Tofino register array that holds the descriptor.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One lane tile per grid step. 128 int32 lanes == 512 B == one VPU-friendly
# vector register row; also exactly the paper's Tofino payload (128 B) x4.
LANE_TILE = 128

_I32_MAX = 2**31 - 1
_I32_MIN = -(2**31)


def sat_add_i32(a: jax.Array, b: jax.Array) -> jax.Array:
    """Element-wise saturating int32 add, pure int32 arithmetic.

    Mirrors Rust's ``i32::saturating_add`` bit-for-bit: overflow is detected
    with int32 comparisons only (``a + b`` may wrap in the untaken branch;
    XLA integer add is two's-complement so the wrapped value is well defined
    and then discarded by the select).
    """
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    imax = jnp.full_like(a, _I32_MAX)
    imin = jnp.full_like(a, _I32_MIN)
    pos_ovf = (b > 0) & (a > imax - b)
    neg_ovf = (b < 0) & (a < imin - b)
    return jnp.where(pos_ovf, imax, jnp.where(neg_ovf, imin, a + b))


def _aggregate_kernel(p_ref, o_ref):
    """Sequentially fold ``n`` packet payload rows into the accumulator."""
    n = p_ref.shape[0]

    def body(i, acc):
        return sat_add_i32(acc, p_ref[i, :])

    o_ref[...] = jax.lax.fori_loop(
        0, n, body, jnp.zeros(o_ref.shape, jnp.int32)
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def aggregate(payloads: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Saturating int32 sum of packet payloads along axis 0.

    Args:
      payloads: ``int32[n_packets, lanes]``; ``lanes`` is padded internally
        to a multiple of ``LANE_TILE``.

    Returns:
      ``int32[lanes]`` — the descriptor accumulator after all packets.
    """
    if payloads.ndim != 2:
        raise ValueError(f"payloads must be rank 2, got {payloads.shape}")
    n, lanes = payloads.shape
    pad = (-lanes) % LANE_TILE
    padded = jnp.pad(payloads.astype(jnp.int32), ((0, 0), (0, pad)))
    out = pl.pallas_call(
        _aggregate_kernel,
        grid=((lanes + pad) // LANE_TILE,),
        in_specs=[pl.BlockSpec((n, LANE_TILE), lambda i: (0, i))],
        out_specs=pl.BlockSpec((LANE_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((lanes + pad,), jnp.int32),
        interpret=interpret,
    )(padded)
    return out[:lanes]
