"""Layer-1 Pallas kernels (build-time only).

These kernels model the compute hot-spots of Canary:

- ``aggregate``: the switch-ALU emulation — saturating int32 lane-wise
  accumulation of packet payloads into a descriptor accumulator.
- ``quantize`` / ``dequantize``: the host-side f32 <-> fixed-point packing
  used to put gradients on the wire (programmable switches have no FPU,
  Section 6 of the paper).

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls) and are checked against the pure-jnp oracles in ``ref.py``.
"""

from .aggregate import aggregate, sat_add_i32
from .quantize import dequantize, quantize, Q_CLIP_F32

__all__ = ["aggregate", "sat_add_i32", "quantize", "dequantize", "Q_CLIP_F32"]
