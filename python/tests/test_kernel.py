"""Pallas kernels vs pure-numpy oracles — the CORE correctness signal.

Hypothesis sweeps shapes and value distributions (including the saturation
and clipping edges) and asserts exact (integer) / bit-exact (float) parity
with ``compile.kernels.ref``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    Q_CLIP_F32,
    aggregate,
    dequantize,
    quantize,
    sat_add_i32,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=40, deadline=None)

i32s = st.integers(min_value=-(2**31), max_value=2**31 - 1)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 12),
    lanes=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
    extreme=st.booleans(),
)
def test_aggregate_matches_ref(n, lanes, seed, extreme):
    rng = np.random.default_rng(seed)
    if extreme:
        # values near the int32 edges to exercise saturation
        p = rng.integers(-(2**31), 2**31, size=(n, lanes), dtype=np.int64)
        p = p.astype(np.int32)
    else:
        p = rng.integers(-(2**20), 2**20, size=(n, lanes), dtype=np.int32)
    out = np.asarray(aggregate(p))
    exp = ref.aggregate_ref(p)
    np.testing.assert_array_equal(out, exp)


@settings(**SETTINGS)
@given(a=i32s, b=i32s)
def test_sat_add_scalar_pairs(a, b):
    av = np.array([a], np.int32)
    bv = np.array([b], np.int32)
    out = np.asarray(sat_add_i32(av, bv))
    np.testing.assert_array_equal(out, ref.sat_add_i32_ref(av, bv))


def test_aggregate_saturates_and_sticks():
    # once saturated, further positive adds keep the lane at I32_MAX
    p = np.full((8, 4), 2**30, np.int32)
    out = np.asarray(aggregate(p))
    assert (out == ref.I32_MAX).all()


def test_aggregate_zero_identity():
    p = np.zeros((3, 17), np.int32)
    assert (np.asarray(aggregate(p)) == 0).all()


def test_aggregate_order_independent_without_saturation():
    rng = np.random.default_rng(5)
    p = rng.integers(-(2**20), 2**20, size=(6, 64), dtype=np.int32)
    a = np.asarray(aggregate(p))
    b = np.asarray(aggregate(p[::-1].copy()))
    np.testing.assert_array_equal(a, b)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 500),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-4, 1.0, 100.0, 5000.0]),
    frac_bits=st.sampled_from([8, 16, 20, 24]),
)
def test_quantize_matches_ref(n, seed, scale, frac_bits):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * scale).astype(np.float32)
    q = np.asarray(quantize(x, frac_bits=frac_bits))
    np.testing.assert_array_equal(q, ref.quantize_ref(x, frac_bits))


@settings(**SETTINGS)
@given(n=st.integers(1, 500), seed=st.integers(0, 2**31 - 1))
def test_dequantize_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(-(2**31), 2**31, size=n, dtype=np.int64).astype(np.int32)
    dq = np.asarray(dequantize(q))
    np.testing.assert_array_equal(dq, ref.dequantize_ref(q))


def test_quantize_clips_at_int_range():
    x = np.array([1e30, -1e30, np.float32(Q_CLIP_F32)], np.float32)
    q = np.asarray(quantize(x, frac_bits=0))
    assert q[0] == 2147483520 and q[1] == -2147483520


@settings(**SETTINGS)
@given(n=st.integers(1, 200), seed=st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_bound(n, seed):
    # |dequantize(quantize(x)) - x| <= 0.5 * 2^-f for in-range values
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    dq = np.asarray(dequantize(quantize(x, frac_bits=20), frac_bits=20))
    assert np.abs(dq - x).max() <= 0.5 * 2.0**-20 + 1e-9


def test_quantize_fixed_point_sum_is_exact():
    # the whole point of fixed point on the wire: int sums commute exactly
    rng = np.random.default_rng(11)
    xs = rng.standard_normal((8, 128)).astype(np.float32)
    qs = np.stack([np.asarray(quantize(x)) for x in xs])
    total_fwd = ref.aggregate_ref(qs)
    total_rev = ref.aggregate_ref(qs[::-1].copy())
    np.testing.assert_array_equal(total_fwd, total_rev)
