"""L2 model tests: shapes, flat-vector layout, gradient packing, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.PRESETS["tiny"]


def _tokens(seed=0, cfg=CFG):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq_len)).astype(
        np.int32
    )


def test_param_count_matches_spec():
    p = M.param_count(CFG)
    total = sum(int(np.prod(s)) for _, s in M.param_spec(CFG))
    assert p == total > 0


def test_flatten_unflatten_roundtrip():
    flat = M.init_params(CFG, jnp.uint32(1))
    params = M.unflatten(CFG, flat)
    flat2 = M.flatten(CFG, params)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(flat2))


def test_init_deterministic_in_seed():
    a = np.asarray(M.init_params(CFG, jnp.uint32(7)))
    b = np.asarray(M.init_params(CFG, jnp.uint32(7)))
    c = np.asarray(M.init_params(CFG, jnp.uint32(8)))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_forward_shapes_and_finiteness():
    flat = M.init_params(CFG, jnp.uint32(2))
    toks = _tokens()
    logits = M.forward_logits(CFG, flat, jnp.asarray(toks))
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform():
    flat = M.init_params(CFG, jnp.uint32(3))
    loss = float(M.loss_fn(CFG, flat, jnp.asarray(_tokens())))
    assert abs(loss - np.log(CFG.vocab)) < 1.5


def test_train_step_outputs():
    flat = M.init_params(CFG, jnp.uint32(4))
    loss, qg = M.train_step(CFG, flat, jnp.asarray(_tokens()))
    assert qg.shape == flat.shape and qg.dtype == jnp.int32
    assert np.isfinite(float(loss))
    assert int(np.abs(np.asarray(qg)).sum()) > 0  # non-trivial gradient


def test_train_step_grad_matches_direct_grad():
    flat = M.init_params(CFG, jnp.uint32(5))
    toks = jnp.asarray(_tokens(9))
    _, qg = M.train_step(CFG, flat, toks)
    g = jax.grad(lambda fp: M.loss_fn(CFG, fp, toks))(flat)
    np.testing.assert_array_equal(
        np.asarray(qg), ref.quantize_ref(np.asarray(g), CFG.frac_bits)
    )


def test_apply_update_math():
    flat = M.init_params(CFG, jnp.uint32(6))
    qsum = jnp.asarray(
        np.random.default_rng(0).integers(
            -(2**24), 2**24, size=flat.shape, dtype=np.int32
        )
    )
    lr, nw = jnp.float32(0.1), jnp.float32(4.0)
    out = np.asarray(M.apply_update(CFG, flat, qsum, lr, nw))
    exp = np.asarray(flat) - 0.1 * (
        ref.dequantize_ref(np.asarray(qsum), CFG.frac_bits) / 4.0
    )
    np.testing.assert_allclose(out, exp, rtol=1e-6, atol=1e-7)


def test_loss_decreases_on_learnable_data():
    # affine markov-chain tokens — a few SGD steps must reduce the loss
    cfg = CFG
    rng = np.random.default_rng(7)

    def batch():
        seq = [rng.integers(0, cfg.vocab, size=(cfg.batch, 1))]
        for _ in range(cfg.seq_len - 1):
            seq.append((seq[-1] * 5 + 17) % cfg.vocab)
        return np.concatenate(seq, axis=1).astype(np.int32)

    step = jax.jit(lambda fp, tk: M.train_step(cfg, fp, tk))
    upd = jax.jit(lambda fp, qs: M.apply_update(
        cfg, fp, qs, jnp.float32(0.5), jnp.float32(1.0)
    ))
    flat = M.init_params(cfg, jnp.uint32(42))
    losses = []
    for _ in range(25):
        loss, qg = step(flat, jnp.asarray(batch()))
        flat = upd(flat, qg)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_causality():
    # changing a future token must not change earlier logits
    flat = M.init_params(CFG, jnp.uint32(8))
    toks = _tokens(3)
    la = np.asarray(M.forward_logits(CFG, flat, jnp.asarray(toks)))
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 1) % CFG.vocab
    lb = np.asarray(M.forward_logits(CFG, flat, jnp.asarray(toks2)))
    np.testing.assert_allclose(la[:, :-1], lb[:, :-1], rtol=1e-6, atol=1e-6)
