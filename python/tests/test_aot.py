"""AOT bridge tests: artifacts lower, parse, and the manifest is faithful."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

ARTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def artifacts_dir(tmp_path_factory):
    """Use the repo artifacts/ if present (built by `make artifacts`),
    otherwise lower a fresh tiny-only set into a temp dir."""
    if os.path.exists(os.path.join(ARTS, "manifest.json")):
        return ARTS
    out = tmp_path_factory.mktemp("arts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(out),
         "--presets", "tiny"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    return str(out)


def _manifest(artifacts_dir):
    with open(os.path.join(artifacts_dir, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_all_files(artifacts_dir):
    man = _manifest(artifacts_dir)
    assert man["packet_lanes"] == 256
    for name, art in man["artifacts"].items():
        path = os.path.join(artifacts_dir, art["file"])
        assert os.path.exists(path), f"missing artifact {name}"
        assert os.path.getsize(path) > 100


def test_hlo_text_is_parseable_hlo(artifacts_dir):
    man = _manifest(artifacts_dir)
    for art in man["artifacts"].values():
        with open(os.path.join(artifacts_dir, art["file"])) as f:
            text = f.read()
        assert text.startswith("HloModule")
        assert "ENTRY" in text


def test_signatures_match_model_config(artifacts_dir):
    man = _manifest(artifacts_dir)
    for preset, mc in man["models"].items():
        p = mc["param_count"]
        ts = man["artifacts"][f"{preset}_train_step"]
        assert ts["inputs"][0] == {"dtype": "float32", "shape": [p]}
        assert ts["inputs"][1] == {
            "dtype": "int32",
            "shape": [mc["batch"], mc["seq_len"]],
        }
        assert ts["outputs"][0]["shape"] == []
        assert ts["outputs"][1] == {"dtype": "int32", "shape": [p]}


def test_golden_vectors_self_consistent(artifacts_dir):
    from compile.kernels import ref

    g = _manifest(artifacts_dir)["golden"]
    agg = g["aggregate"]
    p = np.array(agg["payloads"], np.int32).reshape(agg["n"], agg["lanes"])
    np.testing.assert_array_equal(
        ref.aggregate_ref(p), np.array(agg["expected"], np.int32)
    )
    q = g["quantize"]
    x = np.array(q["x_bits"], np.uint32).view(np.float32)
    np.testing.assert_array_equal(
        ref.quantize_ref(x, g["frac_bits"]),
        np.array(q["expected_q"], np.int32),
    )
    dq = np.array(q["expected_dq_bits"], np.uint32).view(np.float32)
    np.testing.assert_array_equal(
        ref.dequantize_ref(np.array(q["expected_q"], np.int32),
                           g["frac_bits"]),
        dq,
    )
