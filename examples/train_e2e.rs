//! End-to-end validation driver (DESIGN.md §6): data-parallel training
//! of the AOT-compiled transformer LM with its gradient allreduce
//! simulated on the congested fat tree.
//!
//! All three layers compose here:
//!   L1  Pallas quantize kernel — inside the train_step HLO
//!   L2  JAX transformer fwd/bwd — AOT HLO executed via PJRT from Rust
//!   L3  this coordinator — the Canary network simulation + the
//!       saturating fixed-point gradient aggregation (switch ALU)
//!
//!     cargo run --release --example train_e2e -- \
//!         [--preset tiny|base] [--workers N] [--steps N] [--algo canary]
//!
//! Results are recorded in EXPERIMENTS.md.

use canary::collectives::Algo;
use canary::runtime::Runtime;
use canary::sim::ps_to_us;
use canary::train::{TrainConfig, Trainer};
use canary::util::cli::Args;

fn main() -> canary::util::error::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        argv,
        &["preset", "workers", "steps", "lr", "algo", "comm-every", "seed"],
    )?;

    let algo = match args.get_or("algo", "canary") {
        "canary" => Algo::Canary,
        "ring" => Algo::Ring,
        "static1" => Algo::StaticTree { n_trees: 1 },
        "static4" => Algo::StaticTree { n_trees: 4 },
        other => return Err(format!("unknown algo {other}").into()),
    };
    let cfg = TrainConfig {
        preset: args.get_or("preset", "base").to_string(),
        workers: args.get_parse("workers", 4)?,
        steps: args.get_parse("steps", 200)?,
        lr: args.get_parse("lr", 0.5)?,
        algo,
        comm_every: args.get_parse("comm-every", 10)?,
        congestion: true,
        seed: args.get_parse("seed", 0xBEEF)?,
    };

    let rt = Runtime::load(Runtime::default_dir())?;
    let mut trainer = Trainer::new(&rt, cfg)?;
    println!(
        "# train_e2e preset={} params={} workers={} steps={} algo={}",
        trainer.cfg.preset,
        trainer.param_count,
        trainer.cfg.workers,
        trainer.cfg.steps,
        trainer.cfg.algo.name(),
    );
    println!("step,loss,comm_us,wall_ms");
    let t0 = std::time::Instant::now();
    let logs = trainer.train()?;
    for l in &logs {
        println!(
            "{},{:.4},{},{:.0}",
            l.step,
            l.mean_loss,
            l.comm_ps
                .map(|c| format!("{:.1}", ps_to_us(c)))
                .unwrap_or_default(),
            l.wall_ms
        );
    }
    let first = &logs[..logs.len().min(10)];
    let last = &logs[logs.len().saturating_sub(10)..];
    let f: f32 =
        first.iter().map(|l| l.mean_loss).sum::<f32>() / first.len() as f32;
    let l: f32 =
        last.iter().map(|l| l.mean_loss).sum::<f32>() / last.len() as f32;
    println!(
        "# loss {f:.4} -> {l:.4} over {} steps in {:.1}s wall",
        logs.len(),
        t0.elapsed().as_secs_f64()
    );
    let comms: Vec<f64> = logs
        .iter()
        .filter_map(|x| x.comm_ps.map(ps_to_us))
        .collect();
    if !comms.is_empty() {
        println!(
            "# simulated gradient allreduce: mean {:.1} us over {} samples \
             ({} workers, {} B gradient)",
            comms.iter().sum::<f64>() / comms.len() as f64,
            comms.len(),
            trainer.cfg.workers,
            trainer.param_count * 4,
        );
    }
    Ok(())
}
