//! Quickstart: run one 4 MiB allreduce on a 64-host fat tree with and
//! without congestion, comparing Canary against the static-tree and
//! ring baselines.
//!
//!     cargo run --release --example quickstart

use canary::collectives::{runner, Algo};
use canary::config::FatTreeConfig;
use canary::report::{gbps, Series};
use canary::traffic::TrafficSpec;
use canary::workload::{JobBuilder, ScenarioBuilder};

fn main() {
    let algos = [
        Algo::Ring,
        Algo::StaticTree { n_trees: 1 },
        Algo::StaticTree { n_trees: 4 },
        Algo::Canary,
    ];
    let mut table = Series::new(
        "quickstart",
        &["algo", "no_congestion_gbps", "congestion_gbps"],
    );
    for algo in algos {
        let mut row = vec![algo.name()];
        for traffic in [None, Some(TrafficSpec::uniform())] {
            let sc = ScenarioBuilder::new(FatTreeConfig::small())
                .traffic(traffic)
                .job(JobBuilder::new(algo).hosts(32).data_bytes(4 << 20));
            let mut exp = sc.build(42);
            let results = runner::run_to_completion(&mut exp.net, u64::MAX);
            row.push(gbps(results[0].goodput_gbps));
        }
        table.push(row);
    }
    table.print();
    println!(
        "Expected shape: in-network ~2x ring when idle; under congestion \
         the static tree degrades while Canary holds (paper Fig. 2/7a)."
    );
}
