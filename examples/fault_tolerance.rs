//! Fault-tolerance demo (paper Section 3.3): run a value-carrying
//! allreduce while injecting random packet loss and killing a spine
//! switch mid-operation, then verify every host still holds the exact
//! saturating fixed-point sum.
//!
//!     cargo run --release --example fault_tolerance -- \
//!         [--loss 0.02] [--hosts 8] [--kill-spine]

use canary::collectives::{runner, verify_job, Algo};
use canary::config::{FatTreeConfig, SimConfig};
use canary::faults::FaultPlan;
use canary::sim::US;
use canary::util::cli::Args;
use canary::workload::{JobBuilder, ScenarioBuilder};

fn main() -> canary::util::error::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args =
        Args::parse(argv, &["loss", "hosts", "kill-spine", "seed"])?;
    let loss: f64 = args.get_parse("loss", 0.02)?;
    let hosts: u32 = args.get_parse("hosts", 8)?;
    let seed: u64 = args.get_parse("seed", 7)?;

    let sc = ScenarioBuilder::new(FatTreeConfig::tiny())
        .sim(
            SimConfig::default()
                .with_values(true)
                .with_retrans(200 * US, true),
        )
        .job(
            JobBuilder::new(Algo::Canary)
                .hosts(hosts)
                .data_bytes(64 * 1024)
                .record_results(true),
        );
    let mut exp = sc.build(seed);
    exp.net.faults = FaultPlan::default().with_loss(loss);
    if args.flag("kill-spine") {
        let spine = exp.ft.spine_id(0);
        exp.net.faults = exp
            .net
            .faults
            .clone()
            .with_switch_failure(5 * US, spine);
        println!("scheduled: spine {spine} dies at t=5us");
    }
    println!("injecting {:.1}% random packet loss", loss * 100.0);

    let results = runner::run_to_completion(&mut exp.net, 10_000_000 * US);
    let r = &results[0];
    let m = &exp.net.metrics;
    println!(
        "finished: runtime {:?} us",
        r.runtime_ps.map(|t| t as f64 / 1e6)
    );
    println!(
        "recovery activity: {} drops injected, {} retrans requests, \
         {} failure rounds, {} fallbacks, {} switch failures",
        m.drops_injected,
        m.retrans_requests,
        m.failures,
        m.fallbacks,
        m.switch_failures
    );

    // verify every host's every block
    let job = &exp.net.jobs[exp.job as usize];
    verify_job(job).expect("value verification");
    let verified =
        job.spec.total_blocks() as usize * job.spec.participants.len();
    println!(
        "verified {verified} (host, block) results — all exact \
         saturating fixed-point sums. Recovery preserved correctness."
    );
    Ok(())
}
