//! Reactive vs unreactive cross traffic, side by side: a 4 MiB Canary
//! allreduce shares a 64-host fat tree with an 8-way incast overload,
//! with the background senders unreactive (the paper's worst case),
//! under DCQCN, and under Swift-style delay control.
//!
//! The unreactive column shows flows dying silently at the class-1
//! policer (low completion); the reactive columns show the transport
//! backing off (CNPs / delay cuts), recovering losses (retransmits) and
//! completing far more flows — while the allreduce goodput column shows
//! what that does to the reduction.
//!
//!     cargo run --release --example reactive_cross_traffic

use canary::collectives::{runner, Algo};
use canary::config::FatTreeConfig;
use canary::report::{gbps, Series};
use canary::traffic::TrafficSpec;
use canary::transport::TransportSpec;
use canary::workload::{JobBuilder, ScenarioBuilder};

fn main() {
    let mut table = Series::new(
        "reactive_cross_traffic",
        &[
            "transport",
            "allreduce_gbps",
            "flows_completed_pct",
            "fct_p50_us",
            "fct_p99_us",
            "ecn_marks",
            "cnps",
            "retrans_pkts",
        ],
    );
    for tp in [
        TransportSpec::None,
        TransportSpec::Dcqcn,
        TransportSpec::Swift,
    ] {
        let traffic = TrafficSpec::incast(8).with_transport(tp);
        let sc = ScenarioBuilder::new(FatTreeConfig::small())
            .traffic(Some(traffic))
            .job(JobBuilder::new(Algo::Canary).hosts(32).data_bytes(4 << 20));
        let mut exp = sc.build(42);
        let results = runner::run_to_completion(&mut exp.net, u64::MAX);
        let m = &exp.net.metrics;
        let p = m.flows.fct_percentiles_us(&[50.0, 99.0]);
        table.push(vec![
            tp.name().to_string(),
            gbps(results[0].goodput_gbps),
            format!("{:.1}", 100.0 * m.flows.completion_fraction()),
            format!("{:.1}", p[0]),
            format!("{:.1}", p[1]),
            m.ecn_marks.to_string(),
            m.flows.cnps_received.to_string(),
            m.flows.retrans_pkts.to_string(),
        ]);
    }
    table.print();
    println!(
        "Expected shape: with transport none the incast senders never \
         back off, the policer drops their tails and most flows never \
         complete. DCQCN/Swift mark, echo and back off, so completion \
         jumps while the reduction keeps its goodput."
    );
}
