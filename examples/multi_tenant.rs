//! Multi-tenant scenario (paper Section 5.2.4 / Fig. 10): partition the
//! cluster into N concurrent allreduce jobs and report each tenant's
//! goodput plus the fleet average.
//!
//!     cargo run --release --example multi_tenant -- \
//!         [--jobs 8] [--algo canary] [--size 4194304] [--topo small]

use canary::collectives::{runner, Algo};
use canary::config::{FatTreeConfig, SimConfig};
use canary::loadbalance::LoadBalancer;
use canary::report::{gbps, Series};
use canary::util::cli::Args;
use canary::util::stats::mean;
use canary::workload::build_multi_tenant;

fn main() -> canary::util::error::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(argv, &["jobs", "algo", "size", "topo", "seed"])?;
    let n_jobs: u32 = args.get_parse("jobs", 8)?;
    let size: u64 = args.get_parse("size", 4 << 20)?;
    let seed: u64 = args.get_parse("seed", 1)?;
    let topo = match args.get_or("topo", "small") {
        "paper" => FatTreeConfig::paper(),
        "small" => FatTreeConfig::small(),
        "tiny" => FatTreeConfig::tiny(),
        t => return Err(format!("unknown topo {t}").into()),
    };
    let algo = match args.get_or("algo", "canary") {
        "canary" => Algo::Canary,
        "ring" => Algo::Ring,
        "static1" => Algo::StaticTree { n_trees: 1 },
        "static4" => Algo::StaticTree { n_trees: 4 },
        other => return Err(format!("unknown algo {other}").into()),
    };

    let (mut net, _ft, jobs) = build_multi_tenant(
        topo,
        SimConfig::default(),
        LoadBalancer::default(),
        algo,
        n_jobs,
        size,
        seed,
    );
    println!(
        "descriptor table statically partitioned: {} slots per tenant",
        net.cfg.descriptor_slots
    );
    let results = runner::run_to_completion(&mut net, u64::MAX);

    let mut table =
        Series::new("multi_tenant", &["tenant", "hosts", "goodput_gbps"]);
    let mut all = Vec::new();
    for (&job, r) in jobs.iter().zip(results.iter()) {
        let _ = job;
        table.push(vec![
            r.tenant.to_string(),
            r.n_hosts.to_string(),
            gbps(r.goodput_gbps),
        ]);
        if let Some(g) = r.goodput_gbps {
            all.push(g);
        }
    }
    table.print();
    println!(
        "average goodput over {} concurrent {}-host allreduces: {:.1} Gbps",
        n_jobs,
        results[0].n_hosts,
        mean(&all)
    );
    println!(
        "collisions: {}  (tenants share no descriptors — Section 3.4)",
        net.metrics.collisions
    );
    Ok(())
}
