//! Multi-tenant scenario (paper Section 5.2.4 / Fig. 10): partition the
//! cluster into N concurrent allreduce jobs and report each tenant's
//! goodput plus the fleet average — now with cross traffic from the
//! hosts no tenant claims (the unified builder supports it in multi-job
//! scenarios exactly as in single-job ones) and a selectable placement
//! policy per tenant.
//!
//!     cargo run --release --example multi_tenant -- \
//!         [--jobs 8] [--algo canary] [--size 4194304] [--topo small] \
//!         [--placement random|clustered|striped] [--cross-traffic]

use canary::collectives::{runner, Algo};
use canary::config::FatTreeConfig;
use canary::report::{gbps, Series};
use canary::traffic::TrafficSpec;
use canary::util::cli::Args;
use canary::util::stats::mean;
use canary::workload::{JobBuilder, Placement, ScenarioBuilder};

fn main() -> canary::util::error::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        argv,
        &["jobs", "algo", "size", "topo", "seed", "placement", "cross-traffic"],
    )?;
    let n_jobs: u32 = args.get_parse("jobs", 8)?;
    if n_jobs == 0 {
        return Err("--jobs must be >= 1".into());
    }
    let size: u64 = args.get_parse("size", 4 << 20)?;
    let seed: u64 = args.get_parse("seed", 1)?;
    let placement = Placement::parse(args.get_or("placement", "random"))?;
    let cross = args.flag("cross-traffic");
    let topo = match args.get_or("topo", "small") {
        "paper" => FatTreeConfig::paper(),
        "small" => FatTreeConfig::small(),
        "tiny" => FatTreeConfig::tiny(),
        t => return Err(format!("unknown topo {t}").into()),
    };
    let algo = match args.get_or("algo", "canary") {
        "canary" => Algo::Canary,
        "ring" => Algo::Ring,
        "static1" => Algo::StaticTree { n_trees: 1 },
        "static4" => Algo::StaticTree { n_trees: 4 },
        other => return Err(format!("unknown algo {other}").into()),
    };

    // with cross traffic on, leave a quarter of the fabric to the
    // background hosts; otherwise partition every host across tenants
    let claimable = if cross {
        topo.n_hosts() * 3 / 4
    } else {
        topo.n_hosts()
    };
    let per_job = (claimable / n_jobs).max(1);
    let sc = ScenarioBuilder::new(topo)
        .traffic(cross.then(TrafficSpec::uniform))
        .jobs(
            n_jobs,
            JobBuilder::new(algo)
                .hosts(per_job)
                .data_bytes(size)
                .placement(placement.clone()),
        );
    let mut exp = sc.build(seed);
    println!(
        "descriptor table statically partitioned: {} slots per tenant \
         ({} placement, cross traffic {})",
        exp.net.cfg.descriptor_slots / n_jobs,
        placement.name(),
        if cross { "on" } else { "off" }
    );
    let results = runner::run_to_completion(&mut exp.net, u64::MAX);

    let mut table =
        Series::new("multi_tenant", &["tenant", "hosts", "goodput_gbps"]);
    let mut all = Vec::new();
    for r in results.iter() {
        table.push(vec![
            r.tenant.to_string(),
            r.n_hosts.to_string(),
            gbps(r.goodput_gbps),
        ]);
        if let Some(g) = r.goodput_gbps {
            all.push(g);
        }
    }
    table.print();
    println!(
        "average goodput over {} concurrent {}-host allreduces: {:.1} Gbps",
        n_jobs,
        results[0].n_hosts,
        mean(&all)
    );
    if cross {
        println!("{}", canary::report::flow_summary(&exp.net.metrics.flows));
    }
    println!(
        "collisions: {}  (tenants share no descriptors — Section 3.4)",
        exp.net.metrics.collisions
    );
    Ok(())
}
