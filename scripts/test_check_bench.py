#!/usr/bin/env python3
"""Unit tests for the CI bench gate (scripts/check_bench.py).

Run directly:  python3 scripts/test_check_bench.py

Covers the pure gate() verdicts at and around the tolerance boundary,
and the end-to-end exit codes of main() via subprocess on temp JSON —
in particular that a null baseline is a loud FAILURE (the seed shipped
a null baseline that the old script reported-and-passed on, gating
nothing).
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

HERE = os.path.dirname(os.path.abspath(__file__))
SCRIPT = os.path.join(HERE, "check_bench.py")
sys.path.insert(0, HERE)

import check_bench  # noqa: E402


class GateLogic(unittest.TestCase):
    def test_equal_passes(self):
        verdict, ratio = check_bench.gate(100.0, 100.0)
        self.assertEqual(verdict, "pass")
        self.assertAlmostEqual(ratio, 1.0)

    def test_small_regression_within_tolerance_passes(self):
        verdict, _ = check_bench.gate(80.0, 100.0)  # -20% < 25% tolerance
        self.assertEqual(verdict, "pass")

    def test_boundary_regression_passes(self):
        # exactly at (1 - MAX_REGRESSION): not *more than* 25% slower
        verdict, _ = check_bench.gate(75.0, 100.0)
        self.assertEqual(verdict, "pass")

    def test_past_boundary_regression_fails(self):
        verdict, ratio = check_bench.gate(74.9, 100.0)
        self.assertEqual(verdict, "fail")
        self.assertLess(ratio, 1.0 - check_bench.MAX_REGRESSION)

    def test_large_regression_fails(self):
        self.assertEqual(check_bench.gate(10.0, 100.0)[0], "fail")

    def test_improvement_within_tolerance_passes(self):
        self.assertEqual(check_bench.gate(120.0, 100.0)[0], "pass")

    def test_boundary_improvement_passes(self):
        self.assertEqual(check_bench.gate(125.0, 100.0)[0], "pass")

    def test_large_improvement_flags_fast(self):
        verdict, ratio = check_bench.gate(200.0, 100.0)
        self.assertEqual(verdict, "fast")
        self.assertAlmostEqual(ratio, 2.0)

    def test_custom_tolerance(self):
        self.assertEqual(check_bench.gate(89.0, 100.0, 0.10)[0], "fail")
        self.assertEqual(check_bench.gate(91.0, 100.0, 0.10)[0], "pass")


class MainExitCodes(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()

    def tearDown(self):
        self.dir.cleanup()

    def _write(self, name, payload):
        path = os.path.join(self.dir.name, name)
        with open(path, "w") as f:
            json.dump(payload, f)
        return path

    def _run(self, cur_path, base_path):
        return subprocess.run(
            [sys.executable, SCRIPT, cur_path, base_path],
            capture_output=True,
            text=True,
        )

    def _current(self, eps):
        return {
            "bench": "scale_weak_sweep",
            "headline_cell": "canary_4096hosts_3tier_cross",
            "headline_events": 123456,
            "events_per_sec": eps,
        }

    def test_healthy_run_exits_zero(self):
        cur = self._write("cur.json", self._current(1.0e6))
        base = self._write("base.json", {"events_per_sec": 1.0e6})
        r = self._run(cur, base)
        self.assertEqual(r.returncode, 0, r.stderr)
        self.assertIn("PASS", r.stdout)

    def test_null_baseline_fails_loudly(self):
        cur = self._write("cur.json", self._current(1.0e6))
        base = self._write("base.json", {"events_per_sec": None})
        r = self._run(cur, base)
        self.assertNotEqual(r.returncode, 0)
        self.assertIn("unarmed", r.stderr)
        # refresh instructions must be in the failure message
        self.assertIn("bench_baselines", r.stderr)

    def test_missing_baseline_fails(self):
        cur = self._write("cur.json", self._current(1.0e6))
        r = self._run(cur, os.path.join(self.dir.name, "nope.json"))
        self.assertNotEqual(r.returncode, 0)
        self.assertIn("not found", r.stderr)

    def test_regression_fails(self):
        cur = self._write("cur.json", self._current(0.5e6))
        base = self._write("base.json", {"events_per_sec": 1.0e6})
        r = self._run(cur, base)
        self.assertNotEqual(r.returncode, 0)
        self.assertIn("regressed", r.stderr)

    def test_big_improvement_passes_with_note(self):
        cur = self._write("cur.json", self._current(2.0e6))
        base = self._write("base.json", {"events_per_sec": 1.0e6})
        r = self._run(cur, base)
        self.assertEqual(r.returncode, 0, r.stderr)
        self.assertIn("faster", r.stdout)

    def test_missing_current_fails(self):
        base = self._write("base.json", {"events_per_sec": 1.0e6})
        r = self._run(os.path.join(self.dir.name, "nope.json"), base)
        self.assertNotEqual(r.returncode, 0)

    def test_nonnumeric_current_fails(self):
        cur = self._write("cur.json", self._current("fast"))
        base = self._write("base.json", {"events_per_sec": 1.0e6})
        r = self._run(cur, base)
        self.assertNotEqual(r.returncode, 0)
        self.assertIn("positive", r.stderr)

    def test_invalid_json_fails(self):
        path = os.path.join(self.dir.name, "bad.json")
        with open(path, "w") as f:
            f.write("{not json")
        base = self._write("base.json", {"events_per_sec": 1.0e6})
        r = self._run(path, base)
        self.assertNotEqual(r.returncode, 0)
        self.assertIn("not valid JSON", r.stderr)


class WriteBaseline(unittest.TestCase):
    """--write-baseline: emit a filled baseline from a run's output."""

    # reuse the temp-dir fixture and helpers without inheriting (and
    # re-running) the gate-mode test methods
    setUp = MainExitCodes.setUp
    tearDown = MainExitCodes.tearDown
    _write = MainExitCodes._write
    _run = MainExitCodes._run
    _current = MainExitCodes._current

    def _run_write(self, cur_path, out_path):
        return subprocess.run(
            [sys.executable, SCRIPT, "--write-baseline", cur_path,
             out_path],
            capture_output=True,
            text=True,
        )

    def test_round_trip_arms_the_gate(self):
        cur = self._write("cur.json", self._current(1.5e6))
        out = os.path.join(self.dir.name, "proposed.json")
        r = self._run_write(cur, out)
        self.assertEqual(r.returncode, 0, r.stderr)
        self.assertIn("wrote baseline", r.stdout)
        with open(out) as f:
            baseline = json.load(f)
        # the emitted file is a complete, armed baseline ...
        self.assertEqual(baseline["events_per_sec"], 1.5e6)
        self.assertEqual(
            baseline["headline_cell"], "canary_4096hosts_3tier_cross"
        )
        self.assertEqual(baseline["headline_events"], 123456)
        # ... that passes the gate against its own source
        self.assertEqual(
            check_bench.gate(1.5e6, baseline["events_per_sec"]),
            ("pass", 1.0),
        )
        r2 = self._run(cur, out)
        self.assertEqual(r2.returncode, 0, r2.stderr)
        self.assertIn("PASS", r2.stdout)

    def test_null_current_refused(self):
        cur = self._write("cur.json", self._current(None))
        out = os.path.join(self.dir.name, "proposed.json")
        r = self._run_write(cur, out)
        self.assertNotEqual(r.returncode, 0)
        self.assertIn("positive", r.stderr)
        self.assertFalse(os.path.exists(out))

    def test_missing_current_refused(self):
        out = os.path.join(self.dir.name, "proposed.json")
        r = self._run_write(
            os.path.join(self.dir.name, "nope.json"), out
        )
        self.assertNotEqual(r.returncode, 0)
        self.assertIn("not found", r.stderr)
        self.assertFalse(os.path.exists(out))


if __name__ == "__main__":
    unittest.main(verbosity=2)
