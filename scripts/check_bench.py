#!/usr/bin/env python3
"""Gate CI on the engine-throughput trajectory (EXPERIMENTS.md §Scale).

Usage:
    python3 scripts/check_bench.py CURRENT.json BASELINE.json
    python3 scripts/check_bench.py --write-baseline CURRENT.json OUT.json

CURRENT.json is the `BENCH_scale.json` a fresh `figures scale --scale
ci` (or `cargo bench --bench paper_figures`) just wrote; BASELINE.json
is the checked-in reference under `scripts/bench_baselines/`. The gate
compares the *headline* events/sec — the serial re-run of the largest
Canary cell — and fails (exit 1) when the current run is more than
MAX_REGRESSION (25 %) slower than the baseline.

A baseline with "events_per_sec": null is an UNARMED gate: it compares
nothing and protects nothing. This script fails loudly on it (it used
to report-and-pass, which let the null seed baseline ride along
unnoticed for several PRs) — record a real measurement to arm it.

Updating (or first recording) the baseline
------------------------------------------
    cargo run --release --bin figures -- scale --scale ci --out results
    python3 scripts/check_bench.py --write-baseline \
        results/BENCH_scale.json scripts/bench_baselines/BENCH_scale.json
    git add scripts/bench_baselines/BENCH_scale.json   # commit with the PR

`--write-baseline` validates the run (positive events/sec) and emits a
filled baseline that passes the gate against its own source; the CI
bench job uploads one as the `bench-proposed-baseline` artifact on
every run, so arming the gate is download-copy-commit.

Record the before/after numbers in EXPERIMENTS.md §Scale alongside the
refresh. Baselines are machine-dependent: refresh them from a CI run's
uploaded `bench-json` artifact, not from a laptop, so the comparison
stays apples-to-apples. The 25 % tolerance absorbs normal
runner-to-runner jitter; if the gate flaps without a real change,
re-measure on CI before loosening anything.

The pure comparison lives in gate() so scripts/test_check_bench.py can
unit-test it without benchmark files.
"""

import json
import sys

MAX_REGRESSION = 0.25  # fail when current < (1 - this) * baseline

REFRESH_STEPS = (
    "  cargo run --release --bin figures -- scale --scale ci --out results\n"
    "  cp results/BENCH_scale.json scripts/bench_baselines/BENCH_scale.json\n"
    "  git add scripts/bench_baselines/BENCH_scale.json\n"
    "(refresh from a CI run's uploaded bench-json artifact, not a "
    "laptop — see this script's header)"
)


def gate(cur, base, max_regression=MAX_REGRESSION):
    """Pure gate verdict for a current vs. baseline events/sec pair.

    Returns (verdict, ratio) with verdict one of:
      "fail" — current regressed past the tolerance
      "fast" — current improved past the tolerance (refresh suggested)
      "pass" — within tolerance
    Both inputs must already be validated positive numbers.
    """
    ratio = cur / base
    if ratio < 1.0 - max_regression:
        return "fail", ratio
    if ratio > 1.0 + max_regression:
        return "fast", ratio
    return "pass", ratio


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None
    except json.JSONDecodeError as e:
        sys.exit(f"check_bench: {path} is not valid JSON: {e}")


def load_current(current_path):
    """Load and validate a fresh run's results; returns (doc, eps)."""
    current = load(current_path)
    if current is None:
        sys.exit(f"check_bench: current results {current_path} not found "
                 "(did the scale sweep run?)")
    cur = current.get("events_per_sec")
    if not isinstance(cur, (int, float)) or cur <= 0:
        sys.exit(f"check_bench: {current_path} has no positive "
                 f"events_per_sec (got {cur!r})")
    return current, cur


def write_baseline(current_path, out_path):
    """Emit a filled baseline from a validated run's output.

    The emitted file passes gate() against its own source by
    construction (ratio exactly 1.0); committing it to
    scripts/bench_baselines/ arms the regression gate.
    """
    current, cur = load_current(current_path)
    baseline = {
        "_note": ("Baseline emitted by check_bench.py --write-baseline "
                  "from a measured run. Refresh from a CI run's uploaded "
                  "bench artifact, not a laptop — see "
                  "scripts/check_bench.py's header."),
        "bench": current.get("bench", "scale_weak_sweep"),
        "scale": current.get("scale", "?"),
        "headline_cell": current.get("headline_cell", "?"),
        "headline_events": current.get("headline_events"),
        "events_per_sec": cur,
    }
    with open(out_path, "w") as f:
        json.dump(baseline, f, indent=2)
        f.write("\n")
    print(f"check_bench: wrote baseline {out_path} "
          f"({cur / 1e6:.2f} M events/s, cell "
          f"{baseline['headline_cell']})")


def main():
    argv = sys.argv[1:]
    if len(argv) == 3 and argv[0] == "--write-baseline":
        return write_baseline(argv[1], argv[2])
    if len(argv) != 2:
        sys.exit(__doc__)
    current_path, baseline_path = argv

    current, cur = load_current(current_path)

    baseline = load(baseline_path)
    if baseline is None:
        # a *missing* baseline file is a broken gate (typo'd path,
        # renamed file) — same disease as a null value, same cure
        sys.exit(f"check_bench: baseline {baseline_path} not found — "
                 "refusing to run unarmed; record one:\n" + REFRESH_STEPS)
    base = baseline.get("events_per_sec")
    cell = current.get("headline_cell", "?")
    print(f"check_bench: headline cell {cell}")
    print(f"check_bench: current  {cur / 1e6:8.2f} M events/s "
          f"({current.get('headline_events', '?')} events)")

    if base is None:
        sys.exit(f"check_bench: FAIL — baseline in {baseline_path} is "
                 "null, so the regression gate is unarmed and gates "
                 "NOTHING. Record a real baseline:\n" + REFRESH_STEPS)
    if not isinstance(base, (int, float)) or base <= 0:
        sys.exit(f"check_bench: baseline {baseline_path} has a "
                 f"non-positive events_per_sec ({base!r}) — fix or "
                 "re-record it")

    verdict, ratio = gate(cur, base)
    print(f"check_bench: baseline {base / 1e6:8.2f} M events/s "
          f"(current/baseline = {ratio:.3f})")
    if verdict == "fail":
        sys.exit(f"check_bench: FAIL — events/sec regressed "
                 f"{(1.0 - ratio) * 100.0:.1f}% "
                 f"(> {MAX_REGRESSION * 100:.0f}% tolerance). If this "
                 "change intentionally trades throughput, refresh the "
                 "baseline per the script header and document it in "
                 "EXPERIMENTS.md §Scale.")
    if verdict == "fast":
        print(f"check_bench: current is {(ratio - 1.0) * 100.0:.1f}% "
              "faster than the baseline — consider refreshing it so the "
              "gate protects the new level.")
    print("check_bench: PASS")


if __name__ == "__main__":
    main()
