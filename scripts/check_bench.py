#!/usr/bin/env python3
"""Gate CI on the engine-throughput trajectory (EXPERIMENTS.md §Scale).

Usage:
    python3 scripts/check_bench.py CURRENT.json BASELINE.json

CURRENT.json is the `BENCH_scale.json` a fresh `figures scale --scale
ci` (or `cargo bench --bench paper_figures`) just wrote; BASELINE.json
is the checked-in reference under `scripts/bench_baselines/`. The gate
compares the *headline* events/sec — the serial re-run of the largest
Canary cell — and fails (exit 1) when the current run is more than
MAX_REGRESSION (25 %) slower than the baseline.

Updating the baseline
---------------------
When a PR legitimately changes engine throughput (or to record the
first real measurement — the seed baseline ships with
"events_per_sec": null, which makes this script report-and-pass):

    cargo run --release --bin figures -- scale --scale ci --out results
    cp results/BENCH_scale.json scripts/bench_baselines/BENCH_scale.json
    git add scripts/bench_baselines/BENCH_scale.json   # commit with the PR

Record the before/after numbers in EXPERIMENTS.md §Scale alongside the
refresh. Baselines are machine-dependent: refresh them from a CI run's
uploaded `bench-json` artifact, not from a laptop, so the comparison
stays apples-to-apples. The 25 % tolerance absorbs normal
runner-to-runner jitter; if the gate flaps without a real change,
re-measure on CI before loosening anything.
"""

import json
import sys

MAX_REGRESSION = 0.25  # fail when current < (1 - this) * baseline


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None
    except json.JSONDecodeError as e:
        sys.exit(f"check_bench: {path} is not valid JSON: {e}")


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    current_path, baseline_path = sys.argv[1], sys.argv[2]

    current = load(current_path)
    if current is None:
        sys.exit(f"check_bench: current results {current_path} not found "
                 "(did the scale sweep run?)")
    cur = current.get("events_per_sec")
    if not isinstance(cur, (int, float)) or cur <= 0:
        sys.exit(f"check_bench: {current_path} has no positive "
                 f"events_per_sec (got {cur!r})")

    baseline = load(baseline_path)
    if baseline is None:
        # a *missing* baseline file is a broken gate (typo'd path,
        # renamed file), not a bootstrap: only an explicitly committed
        # "events_per_sec": null may pass unarmed
        sys.exit(f"check_bench: baseline {baseline_path} not found — "
                 "refusing to run unarmed; commit a baseline (or the "
                 "null-valued seed file) at that path")
    base = baseline.get("events_per_sec")
    cell = current.get("headline_cell", "?")
    print(f"check_bench: headline cell {cell}")
    print(f"check_bench: current  {cur / 1e6:8.2f} M events/s "
          f"({current.get('headline_events', '?')} events)")

    if base is None:
        print(f"check_bench: baseline in {baseline_path} is null — "
              "PASS (bootstrap).")
        print("check_bench: record one with the steps in this script's "
              "header to arm the regression gate.")
        return
    if not isinstance(base, (int, float)) or base <= 0:
        sys.exit(f"check_bench: baseline {baseline_path} has a "
                 f"non-positive events_per_sec ({base!r}) — fix or "
                 "re-record it")

    ratio = cur / base
    print(f"check_bench: baseline {base / 1e6:8.2f} M events/s "
          f"(current/baseline = {ratio:.3f})")
    if ratio < 1.0 - MAX_REGRESSION:
        sys.exit(f"check_bench: FAIL — events/sec regressed "
                 f"{(1.0 - ratio) * 100.0:.1f}% "
                 f"(> {MAX_REGRESSION * 100:.0f}% tolerance). If this "
                 "change intentionally trades throughput, refresh the "
                 "baseline per the script header and document it in "
                 "EXPERIMENTS.md §Scale.")
    if ratio > 1.0 + MAX_REGRESSION:
        print(f"check_bench: current is {(ratio - 1.0) * 100.0:.1f}% "
              "faster than the baseline — consider refreshing it so the "
              "gate protects the new level.")
    print("check_bench: PASS")


if __name__ == "__main__":
    main()
