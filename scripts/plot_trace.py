#!/usr/bin/env python3
"""ASCII renderer for the trace artifacts (DESIGN.md §2.7).

Usage:
    python3 scripts/plot_trace.py [--dir results/trace] [--links N]
    python3 scripts/plot_trace.py --check

Reads the four files a traced run exports (``--trace`` on the main
binary, or ``figures trace``):

* ``trace_timeline.csv`` — sampler ticks: per-link queue depth and
  utilization plus global gauges (live arena packets, live switch
  descriptors, cumulative ECN marks, sampler-ring evictions). Rendered
  as one sparkline per busiest link and one per global gauge; a
  nonzero ``samples_dropped`` count is called out explicitly.
* ``trace_spans.csv`` — job lifecycle spans (install → kick → sends →
  aggregated → broadcast → host_done → complete/stalled, plus
  recovery markers). Rendered as a time-ordered table.
* ``trace_trees.json`` — realized dynamic trees: one record per
  switch aggregation forward (contributing ports, expected vs actual
  fan-in, timeout flag). Rendered as a fan-in histogram and a
  per-block forward list.
* ``trace_critical_paths.json`` — flight-recorder critical paths
  (``--trace-blocks N``): per traced block, where its end-to-end
  latency went — queueing, serialization, propagation, aggregation
  wait, timeout penalty. Rendered as one stacked component bar per
  block. Absent on runs without ``--trace-blocks``; handled as
  optional.

Stdlib only (csv/json/argparse) — no matplotlib, runs anywhere CI
does. ``--check`` runs the internal self-tests on synthetic data and
exits 0/1; the CI lint job runs it on every push.
"""

import argparse
import csv
import json
import os
import sys

BARS = " .:-=+*#%@"


def spark(values, width=60):
    """Downsample `values` to `width` buckets and render one line."""
    if not values:
        return ""
    if len(values) > width:
        # bucket-max keeps bursts visible where bucket-mean hides them
        n = len(values)
        values = [
            max(values[i * n // width:(i + 1) * n // width] or [0.0])
            for i in range(width)
        ]
    lo, hi = min(values), max(values)
    span = (hi - lo) or 1.0
    idx = [int((v - lo) / span * (len(BARS) - 1)) for v in values]
    return "".join(BARS[i] for i in idx)


def load_timeline(path):
    """Split the timeline into global-gauge rows and per-link rows."""
    gauges = []  # (t_us, arena_live, live_desc, ecn_marks, samples_dropped)
    links = {}  # link id -> list of (t_us, queued_bytes, util_pct)
    with open(path, newline="") as f:
        for row in csv.DictReader(f):
            t = float(row["t_us"])
            if row["link"] == "-1":
                gauges.append(
                    (
                        t,
                        int(row["arena_live"]),
                        int(row["live_desc"]),
                        int(row["ecn_marks"]),
                        # absent in pre-flight-recorder exports
                        int(row.get("samples_dropped") or 0),
                    )
                )
            else:
                links.setdefault(int(row["link"]), []).append(
                    (t, int(row["queued_bytes"]), float(row["util_pct"]))
                )
    return gauges, links


def render_timeline(gauges, links, top_n):
    out = []
    if gauges:
        t0, t1 = gauges[0][0], gauges[-1][0]
        out.append(
            f"timeline: {len(gauges)} ticks, {t0:.1f} .. {t1:.1f} us"
        )
        dropped = gauges[-1][4]
        if dropped:
            out.append(
                f"  WARNING: sampler ring overflowed — {dropped} oldest "
                "ticks dropped (raise the ring cap or the cadence)"
            )
        for label, i in (("arena_live", 1), ("live_desc", 2), ("ecn", 3)):
            vals = [float(g[i]) for g in gauges]
            out.append(
                f"  {label:>10} [{min(vals):>8.0f}..{max(vals):>8.0f}] "
                f"{spark(vals)}"
            )
    # busiest links by peak queue depth
    ranked = sorted(
        links.items(),
        key=lambda kv: max(q for _, q, _ in kv[1]),
        reverse=True,
    )[:top_n]
    if ranked:
        out.append(f"busiest {len(ranked)} links (peak queued bytes):")
        for link, rows in ranked:
            q = [float(r[1]) for r in rows]
            out.append(
                f"  link {link:>4} [{min(q):>8.0f}..{max(q):>8.0f}] "
                f"{spark(q)}"
            )
    return "\n".join(out)


def render_spans(path, limit=40):
    out = []
    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))
    out.append(f"spans: {len(rows)} recorded")
    shown = rows[:limit]
    for r in shown:
        blk = "" if r["block"] == "-1" else f" block {r['block']}"
        out.append(
            f"  {float(r['t_us']):>10.1f} us  job {r['job']} "
            f"node {r['node']:>4}  {r['kind']}{blk}"
        )
    if len(rows) > len(shown):
        out.append(f"  ... {len(rows) - len(shown)} more")
    return "\n".join(out)


def render_trees(path):
    with open(path) as f:
        t = json.load(f)
    out = [
        "realized trees: {} forwards ({} via timeout, {} partial)".format(
            t["forwards_total"], t["timeout_forwards"], t["partial_forwards"]
        )
    ]
    h = t.get("fanin_histogram")
    if h and sum(h["counts"]):
        total = sum(h["counts"])
        width = (h["hi"] - h["lo"]) / len(h["counts"])
        out.append("fan-in fraction (contributed/expected):")
        for i, c in enumerate(h["counts"]):
            if not c:
                continue
            frac = c / total
            bar = "#" * max(1, int(frac * 50))
            mid = h["lo"] + (i + 0.5) * width
            out.append(f"  {mid:>5.2f}  {bar} {c}")
    blocks = t.get("blocks", {})
    partials = [
        (key, fw)
        for key, fwds in sorted(blocks.items())
        for fw in fwds
        if fw["contributed"] < fw["expected"]
    ]
    if partials:
        out.append(f"partial forwards ({len(partials)}):")
        for key, fw in partials[:20]:
            out.append(
                "  {:>10.1f} us  {}  sw {}  {}/{} ports {}{}".format(
                    fw["t_us"],
                    key,
                    fw["switch"],
                    fw["contributed"],
                    fw["expected"],
                    fw["ports"],
                    "  (timeout)" if fw["via_timeout"] else "",
                )
            )
    return "\n".join(out)


CP_COMPONENTS = (
    ("q", "queueing_ps"),
    ("s", "serialization_ps"),
    ("p", "propagation_ps"),
    ("w", "agg_wait_ps"),
    ("T", "timeout_penalty_ps"),
)


def render_critical_paths(path, bar_width=40):
    """Stacked per-component latency bars, one per traced block."""
    with open(path) as f:
        t = json.load(f)
    out = [
        "critical paths: {} blocks traced "
        "({} hops, {} waits recorded; {} hops, {} waits dropped)".format(
            t["blocks_traced"],
            t["hops_recorded"],
            t["waits_recorded"],
            t["hops_dropped"],
            t["waits_dropped"],
        )
    ]
    for p in t.get("paths", []):
        e2e = max(p["e2e_ps"], 1)
        bar = "".join(
            ch * round(bar_width * p[key] / e2e)
            for ch, key in CP_COMPONENTS
        )[:bar_width]
        pcts = "  ".join(
            "{} {:.0f}%".format(ch, 100.0 * p[key] / e2e)
            for ch, key in CP_COMPONENTS
        )
        out.append(
            "  t{}/b{:<5} {:>9.1f} us  {:>3} hops "
            "|{:<{w}}| {}".format(
                p["tenant"],
                p["block"],
                p["e2e_ps"] / 1e6,
                p["hops"],
                bar,
                pcts,
                w=bar_width,
            )
        )
    if t.get("paths"):
        out.append(
            "  legend: q queueing  s serialization  p propagation  "
            "w aggregation wait  T timeout penalty"
        )
    return "\n".join(out)


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default="results/trace")
    ap.add_argument("--links", type=int, default=8)
    ap.add_argument(
        "--check",
        action="store_true",
        help="run internal self-tests on synthetic data and exit",
    )
    args = ap.parse_args(argv)
    if args.check:
        return self_test()

    timeline = os.path.join(args.dir, "trace_timeline.csv")
    spans = os.path.join(args.dir, "trace_spans.csv")
    trees = os.path.join(args.dir, "trace_trees.json")
    missing = [p for p in (timeline, spans, trees) if not os.path.exists(p)]
    if missing:
        print(f"missing artifacts: {', '.join(missing)}", file=sys.stderr)
        print("run with --trace (or `figures trace`) first", file=sys.stderr)
        return 1
    gauges, links = load_timeline(timeline)
    print(render_timeline(gauges, links, args.links))
    print()
    print(render_spans(spans))
    print()
    print(render_trees(trees))
    crit = os.path.join(args.dir, "trace_critical_paths.json")
    if os.path.exists(crit):
        print()
        print(render_critical_paths(crit))
    else:
        print(
            "\n(no trace_critical_paths.json — run with --trace-blocks N "
            "to arm the flight recorder)"
        )
    return 0


# --------------------------------------------------------- self-tests

TIMELINE_HEADER = (
    "t_us,link,from,to,queued_bytes,class0_bytes,util_pct,drops,"
    "alive,arena_live,live_desc,ecn_marks,samples_dropped"
)


def self_test():
    import tempfile

    failures = []

    def check(name, cond):
        if not cond:
            failures.append(name)

    # spark: constant, ramp, empty, downsampled burst
    check("spark empty", spark([]) == "")
    check("spark const", set(spark([5.0] * 10)) == {BARS[0]})
    ramp = spark([float(i) for i in range(10)])
    check("spark ramp ends high", ramp[-1] == BARS[-1])
    burst = spark([0.0] * 200 + [9.0] + [0.0] * 200, width=20)
    check("spark keeps bursts", BARS[-1] in burst)

    with tempfile.TemporaryDirectory() as d:
        tpath = os.path.join(d, "trace_timeline.csv")
        with open(tpath, "w") as f:
            f.write(TIMELINE_HEADER + "\n")
            f.write("0.0,-1,-1,-1,128,128,,,,3,2,0,7\n")
            f.write("0.0,4,0,8,128,128,55.0,0,true,,,,\n")
            f.write("1.0,-1,-1,-1,0,0,,,,1,0,2,7\n")
        gauges, links = load_timeline(tpath)
        check("gauge rows parsed", len(gauges) == 2)
        check("gauge ecn cumulative", gauges[-1][3] == 2)
        check("gauge samples_dropped", gauges[-1][4] == 7)
        check("link rows parsed", list(links) == [4])
        rendered = render_timeline(gauges, links, 8)
        check("timeline mentions link", "link    4" in rendered)
        check(
            "overflow warned", "7 oldest ticks dropped" in rendered
        )

        # pre-flight-recorder exports lack the samples_dropped column
        old = os.path.join(d, "trace_timeline_old.csv")
        with open(old, "w") as f:
            f.write(TIMELINE_HEADER.rsplit(",", 1)[0] + "\n")
            f.write("0.0,-1,-1,-1,128,128,,,,3,2,0\n")
        og, _ = load_timeline(old)
        check("legacy timeline parses", og[-1][4] == 0)
        check(
            "no spurious warning",
            "dropped" not in render_timeline(og, {}, 8),
        )

        spath = os.path.join(d, "trace_spans.csv")
        with open(spath, "w") as f:
            f.write("t_us,kind,job,node,block,detail\n")
            f.write("0.0,install,0,1,-1,8\n")
            f.write("12.5,aggregated,0,9,3,8\n")
        srendered = render_spans(spath)
        check("span count", "2 recorded" in srendered)
        check("span block", "block 3" in srendered)
        check("span blockless", "block -1" not in srendered)

        jpath = os.path.join(d, "trace_trees.json")
        with open(jpath, "w") as f:
            json.dump(
                {
                    "forwards_total": 2,
                    "timeout_forwards": 1,
                    "partial_forwards": 1,
                    "dropped_records": 0,
                    "fanin_histogram": {
                        "lo": 0.0,
                        "hi": 1.0,
                        "counts": [1, 0, 0, 0, 0, 0, 0, 1],
                    },
                    "blocks": {
                        "t0/b0": [
                            {
                                "t_us": 3.0,
                                "switch": 9,
                                "ports": [0, 1],
                                "contributed": 2,
                                "expected": 2,
                                "via_timeout": False,
                                "latency_us": 1.0,
                            },
                            {
                                "t_us": 9.0,
                                "switch": 9,
                                "ports": [0],
                                "contributed": 1,
                                "expected": 2,
                                "via_timeout": True,
                                "latency_us": 7.0,
                            },
                        ]
                    },
                },
                f,
            )
        trendered = render_trees(jpath)
        check("tree totals", "2 forwards (1 via timeout" in trendered)
        check("tree partial listed", "1/2 ports [0]" in trendered)
        check("tree timeout tagged", "(timeout)" in trendered)

        cpath = os.path.join(d, "trace_critical_paths.json")
        with open(cpath, "w") as f:
            json.dump(
                {
                    "blocks_traced": 1,
                    "hops_recorded": 3,
                    "hops_dropped": 0,
                    "waits_recorded": 2,
                    "waits_dropped": 0,
                    "paths": [
                        {
                            "tenant": 0,
                            "block": 5,
                            "t_start_ps": 0,
                            "t_end_ps": 1255,
                            "e2e_ps": 1255,
                            "total_ps": 1255,
                            "queueing_ps": 15,
                            "serialization_ps": 60,
                            "propagation_ps": 90,
                            "agg_wait_ps": 90,
                            "timeout_penalty_ps": 1000,
                            "hops": 3,
                            "waits": 2,
                            "steps": [],
                        }
                    ],
                },
                f,
            )
        crendered = render_critical_paths(cpath)
        check("cp totals", "1 blocks traced" in crendered)
        check("cp block listed", "t0/b5" in crendered)
        check("cp timeout dominates", "T 80%" in crendered)
        check("cp bar stacked", "T" * 20 in crendered)
        check("cp legend", "timeout penalty" in crendered)

    if failures:
        print("FAIL: " + ", ".join(failures), file=sys.stderr)
        return 1
    print("plot_trace self-tests passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
